//! END-TO-END DRIVER (paper Figure 5 / §5.3): gradient monitoring on two
//! contrasting sixteen-layer MLPs (1024-wide, ~17M parameters each).
//!
//! Exercises every layer of the system on a real workload:
//!   L1 Pallas EMA sketch updates + L2 jax train step (AOT, via PJRT) —
//!   the "healthy" (Kaiming/ReLU/Adam) and "problematic" (negative-bias/
//!   SGD) configurations train for several hundred steps while sketches
//!   accumulate in-graph;
//!   L3 monitor service consumes per-step ||Z||_F and stable-rank metrics,
//!   diagnoses the pathology, and reports the constant-memory story
//!   (1.7 MB sketches vs 320 MB traditional checkpoints at T=5).
//!
//! The run (loss curves, diagnosis, memory) is recorded in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example gradient_monitoring -- [--epochs N]`
//!
//! **Remote mode** (`--remote ADDR`): instead of monitoring in-process,
//! stream a native synthetic monitored run into a `sketchd` daemon
//! (DESIGN.md §5) via the serve wire protocol and read the diagnosis
//! back over the network — no AOT artifacts required.  Start a daemon
//! first (`sketchgrad serve` or the `sketchd` binary), then:
//! `cargo run --release --example gradient_monitoring -- --remote 127.0.0.1:7070`

use anyhow::{ensure, Result};
use sketchgrad::config::{ExperimentConfig, Variant};
use sketchgrad::coordinator::experiments::curve_table;
use sketchgrad::coordinator::{
    diagnose_run, open_runtime, run_classifier, Trainer, VariantRun,
};
use sketchgrad::data::{make_chunks, synth_mnist, Init};
use sketchgrad::memory::{fmt_bytes, monitor16_dims, MemoryModel};
use sketchgrad::monitor::{MonitorConfig, MonitorHub};
use sketchgrad::util::cli::Args;
use sketchgrad::util::rng::Rng;

fn main() -> Result<()> {
    let mut args = Args::parse_env()?;
    let epochs = args.opt_usize("epochs", 2)?;
    let train_size = args.opt_usize("train-size", 128 * 40)?;
    let seed = args.opt_u64("seed", 42)?;
    let remote = args.opt("remote");
    args.finish()?;

    if let Some(addr) = remote {
        return run_remote(&addr, seed);
    }

    let rt = open_runtime()?;
    println!("Figure 5 end-to-end driver — 16-layer x 1024 MLPs, r=4, beta=0.9");
    println!("platform: {}\n", rt.platform());

    // --- healthy: Kaiming + ReLU + Adam (monitor16_healthy_chunk) -------
    println!("== training HEALTHY configuration ==");
    let healthy_cfg = ExperimentConfig {
        name: "healthy".into(),
        family: "monitor16".into(),
        variant: Variant::Monitored,
        rank: 4,
        adaptive: false,
        epochs,
        train_size,
        test_size: 128 * 20,
        seed,
        ..Default::default()
    };
    let healthy = run_classifier(&rt, &healthy_cfg, false)?;
    for e in &healthy.epochs {
        println!(
            "  epoch {}: loss {:.4} acc {:.3} ({:.2} steps/s)",
            e.epoch, e.mean_loss, e.mean_accuracy, e.steps_per_sec
        );
    }

    // --- problematic: negative bias + SGD (monitor16_problematic_chunk) -
    println!("== training PROBLEMATIC configuration ==");
    let problematic = run_problematic(&rt, epochs, train_size, seed)?;
    for e in &problematic.epochs {
        println!(
            "  epoch {}: loss {:.4} acc {:.3} ({:.2} steps/s)",
            e.epoch, e.mean_loss, e.mean_accuracy, e.steps_per_sec
        );
    }

    println!("\n{}", curve_table(&[&healthy, &problematic]));

    // --- hub-multiplexed diagnosis over the sketch metrics ---------------
    // Both runs monitored as tenants of ONE MonitorHub, each with its own
    // config and constant-memory rolling state.  Short demo run: shrink
    // the diagnostic window so the detectors activate within a couple of
    // epochs.
    let cfg = MonitorConfig {
        window: 20,
        ..MonitorConfig::for_rank(4)
    };
    let mut hub = MonitorHub::new();
    let mut session_ids = Vec::new();
    for (label, run) in [("healthy", &healthy), ("problematic", &problematic)]
    {
        let id = hub.register(label, cfg.clone(), 15)?;
        for m in &run.history {
            hub.observe(id, m)?;
        }
        hub.report_sketch_bytes(id, run.measured_sketch_bytes)?;
        session_ids.push((label, id, run));
    }
    for (label, id, run) in &session_ids {
        let session = hub.session(*id)?;
        let d = session.diagnose();
        let last = run.history.last().unwrap();
        let sr: f32 = last.stable_rank.iter().sum::<f32>()
            / last.stable_rank.len() as f32;
        let z: f32 =
            last.z_norm.iter().sum::<f32>() / last.z_norm.len() as f32;
        println!(
            "[{label}] final mean ||Z||_F {z:.3}  stable rank {sr:.2}/9  \
             healthy={}  monitor state {}",
            session.is_healthy(),
            fmt_bytes(session.monitor_bytes()),
        );
        if !d.notes.is_empty() {
            println!("         detectors: {:?}", d.notes);
        }
        let _ = diagnose_run(run, 4, 15);
    }
    let report = hub.aggregate();
    println!(
        "hub aggregate: {}/{} healthy, monitor state {} across tenants",
        report.healthy,
        report.sessions,
        fmt_bytes(report.monitor_bytes)
    );

    // --- the memory headline --------------------------------------------
    let m = MemoryModel::new(&monitor16_dims(), 128);
    println!("\nmonitoring memory (paper §5.3):");
    for t in [5usize, 50, 500] {
        println!(
            "  T={t:>3}: traditional {} -> sketched {} ({:.2}% reduction)",
            fmt_bytes(m.monitoring_traditional(t)),
            fmt_bytes(m.monitoring_sketched(4)),
            100.0 * m.monitoring_reduction(t, 4)
        );
    }
    println!(
        "  measured sketch state in trainer: healthy {} / problematic {}",
        fmt_bytes(healthy.measured_sketch_bytes),
        fmt_bytes(problematic.measured_sketch_bytes)
    );
    println!("\ngradient_monitoring driver OK");
    Ok(())
}

/// Remote mode: a healthy and a problematic synthetic run stream their
/// activations into a `sketchd` daemon, which owns the engines and the
/// hub; only the problematic session may come back flagged.
fn run_remote(addr: &str, seed: u64) -> Result<()> {
    use sketchgrad::data::ActStream;
    use sketchgrad::serve::{SessionSpec, SketchClient};

    const STEPS: usize = 60;
    const N_B: usize = 32;
    let dims = [64usize, 32, 16];

    let (mut client, info) = SketchClient::connect(addr)?;
    println!(
        "remote mode: {} proto v{} at {addr} ({}/{} sessions)",
        info.server, info.proto, info.sessions, info.max_sessions
    );

    let mut sessions = Vec::new();
    for (label, problematic) in [("healthy", false), ("problematic", true)] {
        let mut sess = client.open_session(&SessionSpec {
            name: label.into(),
            layer_dims: dims.to_vec(),
            rank: 4,
            beta: 0.9,
            seed: seed + problematic as u64,
            window: STEPS / 4,
            collapse_frac: 0.25,
        })?;
        let mut stream = ActStream::new(&dims, problematic, seed);
        for step in 0..STEPS {
            let nb = if step == STEPS - 1 { N_B / 3 } else { N_B };
            let loss = stream.loss_at(step, STEPS);
            sess.ingest(loss, &stream.next_batch(nb), false)?;
        }
        sessions.push((label, problematic, sess.id()));
    }

    println!("\n| session | steps | engine bytes | monitor bytes | healthy |");
    println!("|---|---|---|---|---|");
    for (label, problematic, session) in &sessions {
        let d = client.session(*session).diagnose()?;
        println!(
            "| {label} | {} | {} | {} | {} |",
            d.steps_seen,
            fmt_bytes(d.engine_bytes as usize),
            fmt_bytes(d.monitor_bytes as usize),
            d.healthy
        );
        ensure!(
            d.healthy != *problematic,
            "{label} mis-diagnosed: {:?}",
            d.diagnosis
        );
    }
    let (path, bytes, n) = client.snapshot()?;
    println!(
        "\ndaemon snapshotted {n} sessions to {path} ({}); sessions stay \
         live for reconnect/restart",
        fmt_bytes(bytes as usize)
    );
    for (_, _, session) in &sessions {
        client.session(*session).close()?;
    }
    println!("remote gradient_monitoring driver OK");
    Ok(())
}

fn run_problematic(
    rt: &sketchgrad::runtime::Runtime,
    epochs: usize,
    train_size: usize,
    seed: u64,
) -> Result<VariantRun> {
    let artifact = "monitor16_problematic_chunk";
    let entry = rt.manifest.get(artifact)?;
    let chunk_k = entry.meta_usize("chunk")?;
    let n_b = entry.meta_usize("n_b")?;
    let mut trainer =
        Trainer::new(rt, artifact, Init::KaimingNegBias(-3.0), seed)?;
    let train = synth_mnist(train_size, seed);
    let mut data_rng = Rng::new(seed ^ 0xDA7A);
    let mut wall = 0.0;
    let mut steps = 0;
    for _ in 0..epochs {
        let chunks = make_chunks(&train, n_b, chunk_k, &mut data_rng, &[784]);
        let s = trainer.run_epoch(&chunks)?;
        wall += s.wall_secs;
        steps += s.steps;
    }
    let dims = entry.meta_dims()?;
    let model = MemoryModel::new(&dims, n_b);
    Ok(VariantRun {
        label: "problematic".into(),
        epochs: trainer.epochs.clone(),
        final_eval_loss: f32::NAN,
        final_eval_acc: f32::NAN,
        model_bytes: model.sketch_state(4),
        measured_sketch_bytes: trainer.sketch_bytes(),
        rank_decisions: Vec::new(),
        steps_per_sec: steps as f64 / wall.max(1e-9),
        history: trainer.history,
    })
}
