//! Adaptive-rank controller demo (paper Algorithm 1 / §4.3): watch the
//! patience state machine move rank along the compiled ladder while the
//! trainer hot-swaps executables and re-initialises sketches.
//!
//! Run: `cargo run --release --example adaptive_rank_demo`

use anyhow::Result;
use sketchgrad::config::{ExperimentConfig, Variant};
use sketchgrad::coordinator::{
    open_runtime, run_classifier, AdaptiveConfig, AdaptiveRank, RankDecision,
};
use sketchgrad::memory::fmt_bytes;
use sketchgrad::sketch::{SketchConfig, Sketcher};

fn main() -> Result<()> {
    // Part 1: the controller driving a native SketchEngine on a synthetic
    // loss trace — improvement, then plateau, then improvement again.
    // Every non-Keep decision re-initialises the engine at the new k.
    println!("== Algorithm 1 driving a SketchEngine (synthetic loss trace) ==");
    let mut engine = SketchConfig::builder()
        .layer_dims(&[256, 128, 64]) // heterogeneous widths
        .rank(4)
        .beta(0.9)
        .seed(42)
        .build_engine()?;
    let mut ctl = AdaptiveRank::new(AdaptiveConfig {
        r0: 4,
        p_decrease: 2,
        p_increase: 2,
        ..Default::default()
    });
    let trace = [
        2.0, 1.5, 1.1, 0.9, // improving -> decrease pressure
        0.9, 0.9, 0.9, 0.9, // plateau -> increase pressure
        0.7, 0.5, 0.4, // improving again
    ];
    for (i, &loss) in trace.iter().enumerate() {
        let d = ctl.observe_with_engine(loss, &mut engine);
        println!(
            "epoch {i:>2}: loss {loss:.2} -> rank {:>2} k={} sketch mem {} ({d:?})",
            ctl.rank,
            engine.k(),
            fmt_bytes(engine.memory()),
        );
    }

    // Part 2: live, on the MNIST sketched artifacts (small run).
    println!("\n== live adaptive run on MNIST (sketched, ladder {{2,4,8,16}}) ==");
    let rt = match open_runtime() {
        Ok(rt) => rt,
        Err(e) => {
            println!("skipping live run (artifacts not built): {e:#}");
            println!("adaptive_rank_demo OK");
            return Ok(());
        }
    };
    let cfg = ExperimentConfig {
        name: "adaptive_demo".into(),
        family: "mnist".into(),
        variant: Variant::Sketched,
        rank: 2,
        adaptive: true,
        adaptive_cfg: AdaptiveConfig {
            r0: 2,
            p_decrease: 2,
            p_increase: 1,
            min_rel_improvement: 5e-2, // aggressive so switches happen fast
            ..Default::default()
        },
        epochs: 5,
        train_size: 128 * 20,
        test_size: 128 * 10,
        seed: 42,
        ..Default::default()
    };
    let run = run_classifier(&rt, &cfg, false)?;
    for e in &run.epochs {
        println!(
            "epoch {}: loss {:.4} acc {:.3}",
            e.epoch, e.mean_loss, e.mean_accuracy
        );
    }
    if run.rank_decisions.is_empty() {
        println!("(no rank changes triggered on this trace)");
    }
    for (epoch, d) in &run.rank_decisions {
        let what = match d {
            RankDecision::Decrease(r) => format!("decrease -> r={r}"),
            RankDecision::Increase(r) => format!("increase -> r={r}"),
            RankDecision::Reset(r) => format!("reset -> r={r}"),
            RankDecision::Keep => "keep".into(),
        };
        println!("epoch {epoch}: {what} (sketches re-initialised, executable swapped)");
    }
    println!("adaptive_rank_demo OK");
    Ok(())
}
