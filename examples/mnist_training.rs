//! MNIST training comparison (paper Figure 1 workload): standard vs
//! fixed-rank sketched vs adaptive sketched backpropagation, with the
//! accuracy/memory tradeoff table the figure reports.
//!
//! Run: `cargo run --release --example mnist_training -- [--epochs N]`

use anyhow::Result;
use sketchgrad::config::{ExperimentConfig, Variant};
use sketchgrad::coordinator::experiments::curve_table;
use sketchgrad::coordinator::{figure_table, open_runtime, run_classifier};
use sketchgrad::memory::fmt_bytes;
use sketchgrad::util::cli::Args;

fn main() -> Result<()> {
    let mut args = Args::parse_env()?;
    let epochs = args.opt_usize("epochs", 4)?;
    let train_size = args.opt_usize("train-size", 128 * 50)?;
    args.finish()?;

    let mk = |name: &str, variant: Variant, adaptive: bool| ExperimentConfig {
        name: name.into(),
        family: "mnist".into(),
        variant,
        rank: 2,
        adaptive,
        epochs,
        train_size,
        test_size: 128 * 50,
        seed: 42,
        ..Default::default()
    };

    // Modelled sketch footprint per rank, from the engine accountant
    // (what a native SketchEngine over the MNIST MLP would hold) —
    // needs no artifacts.
    println!("sketch memory across the compiled ladder (MNIST 3x512, n_b=128):");
    for r in [2usize, 4, 8, 16] {
        let cfg = mk("accountant", Variant::Sketched, false)
            .sketch_builder(&[512, 512, 512])
            .rank(r)
            .build()?;
        println!("  r={r:>2}: {}", fmt_bytes(cfg.expected_bytes(&[128])));
    }

    let rt = open_runtime()?;
    println!("\n== standard backprop ==");
    let std = run_classifier(&rt, &mk("standard", Variant::Standard, false), false)?;
    println!("== sketched backprop (fixed r=2) ==");
    let fixed = run_classifier(&rt, &mk("sketched_r2", Variant::Sketched, false), false)?;
    println!("== sketched backprop (adaptive r in [2,16]) ==");
    let adaptive = run_classifier(&rt, &mk("adaptive", Variant::Sketched, true), false)?;

    println!("{}", curve_table(&[&std, &fixed, &adaptive]));
    println!(
        "{}",
        figure_table("Figure 1 — MNIST accuracy/memory", &[&std, &fixed, &adaptive])
    );
    if !adaptive.rank_decisions.is_empty() {
        println!("adaptive decisions: {:?}", adaptive.rank_decisions);
    }

    // The paper's qualitative claims, asserted:
    let acc_std = std.epochs.last().unwrap().mean_accuracy;
    let acc_fix = fixed.epochs.last().unwrap().mean_accuracy;
    println!(
        "\naccuracy gap (standard - sketched r2): {:.3} (paper: 3-5 pts)",
        acc_std - acc_fix
    );
    Ok(())
}
