//! PINN experiment (paper Figures 3-4): solve the 2D Poisson equation with
//! monitoring-only sketching and verify solution quality is untouched.
//!
//! Run: `cargo run --release --example pinn_poisson -- [--chunks N] [--fields]`

use anyhow::Result;
use sketchgrad::coordinator::{open_runtime, run_pinn};
use sketchgrad::memory::fmt_bytes;
use sketchgrad::monitor::{MonitorConfig, MonitorHub};
use sketchgrad::pinn::{exact_field, field_summary};
use sketchgrad::util::cli::Args;

fn main() -> Result<()> {
    let mut args = Args::parse_env()?;
    let chunks = args.opt_usize("chunks", 15)?; // x K=20 steps each
    let fields = args.flag("fields");
    args.finish()?;

    let rt = open_runtime()?;
    println!("PINN: -Lap u = 4 pi^2 sin(2 pi x) sin(2 pi y) on [0,1]^2");
    println!("{} steps of Adam per variant\n", chunks * 20);

    let std = run_pinn(&rt, "standard", 2, chunks, 42)?;
    let mon2 = run_pinn(&rt, "monitored", 2, chunks, 42)?;
    let mon4 = run_pinn(&rt, "monitored", 4, chunks, 42)?;

    println!("| variant | first loss | final loss | L2 rel err | sketch overhead |");
    println!("|---|---|---|---|---|");
    for r in [&std, &mon2, &mon4] {
        println!(
            "| {} | {:.3} | {:.4} | {:.4} | {} |",
            r.label,
            r.losses.first().copied().unwrap_or(f32::NAN),
            r.losses.last().copied().unwrap_or(f32::NAN),
            r.l2_rel_err,
            fmt_bytes(r.sketch_bytes)
        );
    }

    // Both monitored variants as tenants of one MonitorHub: a healthy
    // PINN run should raise no pathology flags at either rank.
    let mut hub = MonitorHub::new();
    for (rank, run) in [(2usize, &mon2), (4, &mon4)] {
        let cfg = MonitorConfig {
            window: (run.history.len() / 4).max(5),
            ..MonitorConfig::for_rank(rank)
        };
        let n_layers = run
            .history
            .first()
            .map(|m| m.z_norm.len())
            .unwrap_or(0);
        let id = hub.register(&run.label, cfg, n_layers)?;
        for m in &run.history {
            hub.observe(id, m)?;
        }
        hub.report_sketch_bytes(id, run.sketch_bytes)?;
    }
    let report = hub.aggregate();
    println!(
        "\nmonitor hub: {}/{} sessions healthy, monitor state {}, sketch state {}",
        report.healthy,
        report.sessions,
        fmt_bytes(report.monitor_bytes),
        fmt_bytes(report.sketch_bytes)
    );
    for (_, name, d) in &report.flagged {
        println!("  flagged {name}: {:?}", d.notes);
    }

    // Paper claim: identical solution quality across variants (Fig. 3/4).
    let spread = (std.l2_rel_err - mon2.l2_rel_err).abs().max(
        (std.l2_rel_err - mon4.l2_rel_err).abs(),
    );
    println!(
        "\nL2-error spread across variants: {spread:.5} (paper: identical, 0.31 each)"
    );

    if fields {
        println!("{}", field_summary(&exact_field(51), 51, "exact u*"));
        println!("{}", field_summary(&std.u_field, 51, "standard u"));
        println!("{}", field_summary(&mon2.u_field, 51, "monitored(r=2) u"));
        println!("{}", field_summary(&mon2.err_field, 51, "monitored |u-u*|"));
    }
    println!("pinn_poisson OK");
    Ok(())
}
