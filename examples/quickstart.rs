//! Quickstart: the smallest end-to-end use of the public API.
//!
//! Part 1 needs nothing but the crate: a `SketchEngine` built through
//! `SketchConfigBuilder` ingests a heterogeneous-width activation stream
//! (including a tail batch) and reports metrics + memory.
//!
//! Part 2 (skipped gracefully when artifacts are absent) loads the
//! single-step MNIST artifacts (standard + sketched r=2), runs a handful
//! of optimizer steps on synthetic data through the PJRT runtime, and
//! prints side-by-side losses plus the sketch-derived monitoring metrics
//! — the whole three-layer stack.
//!
//! Run: `cargo run --release --example quickstart`
//! (build artifacts first with `make artifacts` for part 2)

use std::collections::HashMap;

use anyhow::Result;
use sketchgrad::coordinator::{init_state, open_runtime};
use sketchgrad::data::{synth_mnist, Init};
use sketchgrad::memory::fmt_bytes;
use sketchgrad::runtime::Tensor;
use sketchgrad::sketch::{Mat, SketchConfig, Sketcher};
use sketchgrad::util::rng::Rng;

fn main() -> Result<()> {
    // ---- Part 1: native SketchEngine on a funnel MLP ----------------
    let mut engine = SketchConfig::builder()
        .layer_dims(&[128, 64, 32]) // heterogeneous hidden widths
        .rank(2)
        .beta(0.9)
        .seed(42)
        .build_engine()?;
    let mut rng = Rng::new(7);
    println!("SketchEngine: dims 128/64/32, k = {}", engine.k());
    for step in 0..8 {
        // Tail batch on the last step — smaller than the nominal 32.
        let n_b = if step == 7 { 11 } else { 32 };
        let acts = vec![
            Mat::gaussian(n_b, 784, &mut rng), // input batch
            Mat::gaussian(n_b, 128, &mut rng),
            Mat::gaussian(n_b, 64, &mut rng),
            Mat::gaussian(n_b, 32, &mut rng),
        ];
        engine.ingest(&acts)?;
    }
    for (l, m) in engine.metrics().iter().enumerate() {
        println!(
            "  layer {l}: ||Z||_F {:>7.3}  stable rank {:.2}/{}",
            m.z_norm,
            m.stable_rank,
            engine.k()
        );
    }
    println!(
        "  batch sizes seen {:?}; engine memory {} (accountant {})",
        engine.batch_sizes_seen(),
        fmt_bytes(engine.memory()),
        fmt_bytes(
            engine
                .config()
                .expected_bytes(&engine.batch_sizes_seen())
        ),
    );

    // ---- Part 2: the AOT three-layer stack --------------------------
    let rt = match open_runtime() {
        Ok(rt) => rt,
        Err(e) => {
            println!("\nskipping AOT part (artifacts not built): {e:#}");
            println!("quickstart OK");
            return Ok(());
        }
    };
    println!("\nPJRT platform: {}", rt.platform());

    let std_exe = rt.load("mnist_std_step")?;
    let sk_exe = rt.load("mnist_sk_r2_step")?;

    let mut rng = Rng::new(42);
    let mut std_state = init_state(&std_exe.entry, Init::Xavier(1.0), &mut rng)?;
    let mut rng2 = Rng::new(42);
    let mut sk_state = init_state(&sk_exe.entry, Init::Xavier(1.0), &mut rng2)?;

    let data = synth_mnist(128 * 20, 7);
    println!("\nstep | standard loss | sketched loss | ||Z|| (layer 0) | stable rank");
    println!("-----|---------------|---------------|-----------------|------------");
    for step in 0..20 {
        let mut xs = Vec::with_capacity(128 * 784);
        let mut ys = Vec::with_capacity(128);
        for b in 0..128 {
            let i = step * 128 + b;
            xs.extend_from_slice(data.x_row(i));
            ys.push(data.ys[i]);
        }
        let bx = Tensor::from_f32(&[128, 784], xs);
        let by = Tensor::from_i32(&[128], ys);
        let mut extra: HashMap<&str, Tensor> = HashMap::new();
        extra.insert("batch_x", bx);
        extra.insert("batch_y", by);

        let inputs = std_state.ordered_inputs(&std_exe.entry, &extra)?;
        let outs = std_exe.run(&inputs)?;
        let m_std = std_state.absorb_outputs(&std_exe.entry, outs)?;

        let inputs = sk_state.ordered_inputs(&sk_exe.entry, &extra)?;
        let outs = sk_exe.run(&inputs)?;
        let m_sk = sk_state.absorb_outputs(&sk_exe.entry, outs)?;

        println!(
            "{:>4} | {:>13.4} | {:>13.4} | {:>15.3} | {:>10.2}",
            step,
            m_std["loss"].scalar()?,
            m_sk["loss"].scalar()?,
            m_sk["z_norm"].f32_data()?[0],
            m_sk["stable_rank"].f32_data()?[0],
        );
    }

    println!(
        "\nsketch state held by the sketched variant: {}",
        fmt_bytes(sk_state.sketch_bytes())
    );
    println!("quickstart OK");
    Ok(())
}
