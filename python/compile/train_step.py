"""Train-step builders: assemble forward + sketch updates + (sketched)
backward + optimizer into a single flat-argument function ready for AOT
lowering, together with the input/output specs the rust runtime needs.

Variants (paper §5.1.1):
  standard   exact backprop, no sketches (baseline)
  sketched   Eq. 8 gradients from reconstructed activations, hidden layers
  monitored  exact backprop for updates + EMA sketch accumulation for
             diagnostics only (the PINN / Fig-5 deployment mode)

Every builder returns ``(fn, in_specs, out_specs)`` where specs are ordered
``ArgSpec(name, shape, dtype)`` lists; aot.py serialises them into
``artifacts/manifest.json`` and the rust side constructs literals in exactly
that order.  Chunked builders wrap K consecutive optimizer steps in a
``lax.fori_loop`` over stacked batch data so one PJRT call advances K steps
(amortising host<->device literal traffic; see EXPERIMENTS.md §Perf L3).
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax.numpy as jnp
from jax import lax

from . import model as M
from . import optim, sketching


class ArgSpec(NamedTuple):
    name: str
    shape: tuple
    dtype: str  # "f32" | "i32"


class StepConfig(NamedTuple):
    spec: M.MLPSpec
    variant: str  # standard | sketched | monitored
    optimizer: str  # adam | sgd
    n_b: int
    r: int = 2
    beta: float = 0.95
    lr: float = 1e-3
    chunk: int = 0  # 0 = single step; K > 0 = K fused steps
    power_iters: int = 24
    emit_grad_norms: bool = True

    @property
    def k(self) -> int:
        return 2 * self.r + 1

    @property
    def uses_sketches(self) -> bool:
        return self.variant in ("sketched", "monitored")


def _param_specs(spec: M.MLPSpec, prefix: str = "") -> list[ArgSpec]:
    out = []
    for l in range(spec.n_layers):
        d_out, d_in = spec.dims[l + 1], spec.dims[l]
        out.append(ArgSpec(f"{prefix}w{l}", (d_out, d_in), "f32"))
        out.append(ArgSpec(f"{prefix}b{l}", (d_out,), "f32"))
    return out


def _sketch_specs(cfg: StepConfig) -> list[ArgSpec]:
    lh, d, k = cfg.spec.n_hidden, cfg.spec.d_hidden, cfg.k
    return [
        ArgSpec("sketch_x", (lh, d, k), "f32"),
        ArgSpec("sketch_y", (lh, d, k), "f32"),
        ArgSpec("sketch_z", (lh, d, k), "f32"),
        ArgSpec("proj_upsilon", (cfg.n_b, k), "f32"),
        ArgSpec("proj_omega", (cfg.n_b, k), "f32"),
        ArgSpec("proj_phi", (cfg.n_b, k), "f32"),
        ArgSpec("proj_psi", (lh, k), "f32"),
    ]


def input_specs(cfg: StepConfig) -> list[ArgSpec]:
    specs = _param_specs(cfg.spec)
    if cfg.optimizer == "adam":
        specs += _param_specs(cfg.spec, "m_")
        specs += _param_specs(cfg.spec, "v_")
        specs.append(ArgSpec("t", (), "f32"))
    if cfg.uses_sketches:
        specs += _sketch_specs(cfg)
    d_in = cfg.spec.dims[0]
    if cfg.chunk:
        specs.append(ArgSpec("batch_x", (cfg.chunk, cfg.n_b, d_in), "f32"))
        specs.append(ArgSpec("batch_y", (cfg.chunk, cfg.n_b), "i32"))
    else:
        specs.append(ArgSpec("batch_x", (cfg.n_b, d_in), "f32"))
        specs.append(ArgSpec("batch_y", (cfg.n_b,), "i32"))
    return specs


def output_specs(cfg: StepConfig) -> list[ArgSpec]:
    specs = _param_specs(cfg.spec, "out_")
    if cfg.optimizer == "adam":
        specs += _param_specs(cfg.spec, "out_m_")
        specs += _param_specs(cfg.spec, "out_v_")
        specs.append(ArgSpec("out_t", (), "f32"))
    if cfg.uses_sketches:
        lh, d, k = cfg.spec.n_hidden, cfg.spec.d_hidden, cfg.k
        specs.append(ArgSpec("out_sketch_x", (lh, d, k), "f32"))
        specs.append(ArgSpec("out_sketch_y", (lh, d, k), "f32"))
        specs.append(ArgSpec("out_sketch_z", (lh, d, k), "f32"))
    kdim = (cfg.chunk,) if cfg.chunk else ()
    specs.append(ArgSpec("loss", kdim, "f32"))
    specs.append(ArgSpec("accuracy", kdim, "f32"))
    if cfg.uses_sketches:
        lh = cfg.spec.n_hidden
        specs.append(ArgSpec("z_norm", kdim + (lh,), "f32"))
        specs.append(ArgSpec("stable_rank", kdim + (lh,), "f32"))
        specs.append(ArgSpec("y_norm", kdim + (lh,), "f32"))
        specs.append(ArgSpec("x_norm", kdim + (lh,), "f32"))
    if cfg.emit_grad_norms:
        specs.append(
            ArgSpec("grad_norm", kdim + (cfg.spec.n_layers,), "f32")
        )
    return specs


def _unflatten_params(args: list, spec: M.MLPSpec, offset: int):
    params = []
    for _ in range(spec.n_layers):
        params.append((args[offset], args[offset + 1]))
        offset += 2
    return params, offset


def _flatten_params(params) -> list:
    out = []
    for w, b in params:
        out += [w, b]
    return out


def _core_step(cfg: StepConfig, params, opt_state, sk_state, proj, x, y):
    """One optimizer step.  Returns (params, opt_state, sk_state, metrics)
    where metrics is a flat list ordered per ``output_specs`` tail."""
    logits, acts = M.mlp_forward(params, x, cfg.spec)
    loss, delta, acc = M.softmax_xent(logits, y)

    if cfg.uses_sketches:
        sk_state = M.update_all_sketches(sk_state, proj, acts, cfg.beta)

    recon = None
    if cfg.variant == "sketched":
        recon = M.reconstruct_hidden_acts(
            sk_state, proj, cfg.spec.n_hidden, acts
        )
    grads = M.mlp_backward(params, acts, delta, cfg.spec, recon)

    if cfg.optimizer == "adam":
        m, v, t = opt_state
        params, m, v, t = optim.adam_update(
            params, grads, m, v, t, cfg.lr
        )
        opt_state = (m, v, t)
    else:
        params = optim.sgd_update(params, grads, cfg.lr)

    metrics = [loss, acc]
    if cfg.uses_sketches:
        zn, sr, yn, xn = sketching.monitor_metrics(
            sk_state, cfg.power_iters
        )
        metrics += [zn, sr, yn, xn]
    if cfg.emit_grad_norms:
        gn = jnp.stack(
            [jnp.sqrt(jnp.sum(gw * gw)) for gw, _ in grads]
        )
        metrics.append(gn)
    return params, opt_state, sk_state, metrics


def _parse_args(cfg: StepConfig, args):
    """Split the flat argument list per ``input_specs`` ordering."""
    i = 0
    params, i = _unflatten_params(args, cfg.spec, i)
    opt_state = None
    if cfg.optimizer == "adam":
        m, i = _unflatten_params(args, cfg.spec, i)
        v, i = _unflatten_params(args, cfg.spec, i)
        t = args[i]
        i += 1
        opt_state = (m, v, t)
    sk_state, proj = None, None
    if cfg.uses_sketches:
        sk_state = sketching.SketchState(args[i], args[i + 1], args[i + 2])
        proj = sketching.Projections(
            args[i + 3], args[i + 4], args[i + 5], args[i + 6]
        )
        i += 7
    x, y = args[i], args[i + 1]
    return params, opt_state, sk_state, proj, x, y


def _flatten_state(cfg: StepConfig, params, opt_state, sk_state) -> list:
    out = _flatten_params(params)
    if cfg.optimizer == "adam":
        m, v, t = opt_state
        out += _flatten_params(m) + _flatten_params(v) + [t]
    if cfg.uses_sketches:
        out += [sk_state.x, sk_state.y, sk_state.z]
    return out


def build_step(cfg: StepConfig) -> tuple[Callable, list[ArgSpec], list[ArgSpec]]:
    """Single-step artifact: one forward/backward/update per call."""
    assert cfg.chunk == 0

    def fn(*args):
        params, opt_state, sk_state, proj, x, y = _parse_args(cfg, args)
        params, opt_state, sk_state, metrics = _core_step(
            cfg, params, opt_state, sk_state, proj, x, y
        )
        return tuple(_flatten_state(cfg, params, opt_state, sk_state) + metrics)

    return fn, input_specs(cfg), output_specs(cfg)


def build_chunk(cfg: StepConfig) -> tuple[Callable, list[ArgSpec], list[ArgSpec]]:
    """Chunked artifact: ``cfg.chunk`` consecutive steps fused into one
    ``lax.fori_loop`` over stacked batch data.  Metric outputs gain a
    leading K axis."""
    assert cfg.chunk > 0
    k_steps = cfg.chunk
    # State outputs: params (+ adam m/v/t) (+ sketch x/y/z); the rest of
    # output_specs are per-step metrics that gain a leading K axis.
    n_state = 2 * cfg.spec.n_layers
    if cfg.optimizer == "adam":
        n_state += 4 * cfg.spec.n_layers + 1
    if cfg.uses_sketches:
        n_state += 3
    n_metrics = len(output_specs(cfg)) - n_state

    def fn(*args):
        params, opt_state, sk_state, proj, xs, ys = _parse_args(cfg, args)
        metric_specs = output_specs(cfg)[-n_metrics:]
        metric_acc = [
            jnp.zeros((k_steps,) + s.shape[1:], jnp.float32)
            for s in metric_specs
        ]

        def body(step, carry):
            params, opt_state, sk_state, metric_acc = carry
            x = lax.dynamic_index_in_dim(xs, step, 0, keepdims=False)
            y = lax.dynamic_index_in_dim(ys, step, 0, keepdims=False)
            params, opt_state, sk_state, metrics = _core_step(
                cfg, params, opt_state, sk_state, proj, x, y
            )
            metric_acc = [
                lax.dynamic_update_slice_in_dim(acc, m[None], step, axis=0)
                for acc, m in zip(metric_acc, metrics)
            ]
            return (params, opt_state, sk_state, metric_acc)

        params, opt_state, sk_state, metric_acc = lax.fori_loop(
            0, k_steps, body, (params, opt_state, sk_state, metric_acc)
        )
        return tuple(
            _flatten_state(cfg, params, opt_state, sk_state) + metric_acc
        )

    return fn, input_specs(cfg), output_specs(cfg)


def build(cfg: StepConfig):
    return build_chunk(cfg) if cfg.chunk else build_step(cfg)


# ---------------------------------------------------------------------------
# CNN-MLP (CIFAR, Fig. 2)
# ---------------------------------------------------------------------------

from . import cnn as C  # noqa: E402


class CNNStepConfig(NamedTuple):
    cnn: "C.CNNSpec"
    variant: str  # standard | sketched | monitored
    n_b: int
    r: int = 2
    beta: float = 0.95
    lr: float = 1e-3
    chunk: int = 0
    power_iters: int = 24
    emit_grad_norms: bool = True

    @property
    def k(self) -> int:
        return 2 * self.r + 1

    @property
    def uses_sketches(self) -> bool:
        return self.variant in ("sketched", "monitored")


def _conv_param_specs(cnn: "C.CNNSpec", prefix: str = "") -> list[ArgSpec]:
    out = []
    chans = cnn.channels
    for i in range(len(chans) - 1):
        out.append(
            ArgSpec(f"{prefix}conv_k{i}", (chans[i + 1], chans[i], 3, 3), "f32")
        )
        out.append(ArgSpec(f"{prefix}conv_b{i}", (chans[i + 1],), "f32"))
    return out


def cnn_input_specs(cfg: CNNStepConfig) -> list[ArgSpec]:
    fc = cfg.cnn.fc_spec
    specs = _conv_param_specs(cfg.cnn) + _param_specs(fc)
    specs += _conv_param_specs(cfg.cnn, "m_") + _param_specs(fc, "m_")
    specs += _conv_param_specs(cfg.cnn, "v_") + _param_specs(fc, "v_")
    specs.append(ArgSpec("t", (), "f32"))
    if cfg.uses_sketches:
        lh, d, k = fc.n_hidden, fc.d_hidden, cfg.k
        specs += [
            ArgSpec("sketch_x", (lh, d, k), "f32"),
            ArgSpec("sketch_y", (lh, d, k), "f32"),
            ArgSpec("sketch_z", (lh, d, k), "f32"),
            ArgSpec("proj_upsilon", (cfg.n_b, k), "f32"),
            ArgSpec("proj_omega", (cfg.n_b, k), "f32"),
            ArgSpec("proj_phi", (cfg.n_b, k), "f32"),
            ArgSpec("proj_psi", (lh, k), "f32"),
        ]
    hw = cfg.cnn.in_hw
    cin = cfg.cnn.channels[0]
    if cfg.chunk:
        specs.append(ArgSpec("batch_x", (cfg.chunk, cfg.n_b, cin, hw, hw), "f32"))
        specs.append(ArgSpec("batch_y", (cfg.chunk, cfg.n_b), "i32"))
    else:
        specs.append(ArgSpec("batch_x", (cfg.n_b, cin, hw, hw), "f32"))
        specs.append(ArgSpec("batch_y", (cfg.n_b,), "i32"))
    return specs


def cnn_output_specs(cfg: CNNStepConfig) -> list[ArgSpec]:
    fc = cfg.cnn.fc_spec
    specs = _conv_param_specs(cfg.cnn, "out_") + _param_specs(fc, "out_")
    specs += _conv_param_specs(cfg.cnn, "out_m_") + _param_specs(fc, "out_m_")
    specs += _conv_param_specs(cfg.cnn, "out_v_") + _param_specs(fc, "out_v_")
    specs.append(ArgSpec("out_t", (), "f32"))
    if cfg.uses_sketches:
        lh, d, k = fc.n_hidden, fc.d_hidden, cfg.k
        specs += [
            ArgSpec("out_sketch_x", (lh, d, k), "f32"),
            ArgSpec("out_sketch_y", (lh, d, k), "f32"),
            ArgSpec("out_sketch_z", (lh, d, k), "f32"),
        ]
    kdim = (cfg.chunk,) if cfg.chunk else ()
    specs.append(ArgSpec("loss", kdim, "f32"))
    specs.append(ArgSpec("accuracy", kdim, "f32"))
    if cfg.uses_sketches:
        lh = fc.n_hidden
        specs += [
            ArgSpec("z_norm", kdim + (lh,), "f32"),
            ArgSpec("stable_rank", kdim + (lh,), "f32"),
            ArgSpec("y_norm", kdim + (lh,), "f32"),
            ArgSpec("x_norm", kdim + (lh,), "f32"),
        ]
    if cfg.emit_grad_norms:
        n_mats = (len(cfg.cnn.channels) - 1) + fc.n_layers
        specs.append(ArgSpec("grad_norm", kdim + (n_mats,), "f32"))
    return specs


def _cnn_core_step(cfg: CNNStepConfig, conv_params, fc_params, opt_state,
                   sk_state, proj, x, y):
    fc = cfg.cnn.fc_spec
    logits, feats, fc_acts = C.cnn_forward(conv_params, fc_params, x, cfg.cnn)
    loss, delta, acc = M.softmax_xent(logits, y)

    if cfg.uses_sketches:
        sk_state = M.update_all_sketches(sk_state, proj, fc_acts, cfg.beta)
    recon = None
    if cfg.variant == "sketched":
        recon = M.reconstruct_hidden_acts(sk_state, proj, fc.n_hidden, fc_acts)
    conv_grads, fc_grads = C.cnn_backward(
        conv_params, fc_params, x, feats, fc_acts, delta, cfg.cnn, recon
    )

    all_params = list(conv_params) + list(fc_params)
    all_grads = list(conv_grads) + list(fc_grads)
    m, v, t = opt_state
    all_params, m, v, t = optim.adam_update(all_params, all_grads, m, v, t, cfg.lr)
    n_conv = len(cfg.cnn.channels) - 1
    conv_params = all_params[:n_conv]
    fc_params = all_params[n_conv:]

    metrics = [loss, acc]
    if cfg.uses_sketches:
        zn, sr, yn, xn = sketching.monitor_metrics(sk_state, cfg.power_iters)
        metrics += [zn, sr, yn, xn]
    if cfg.emit_grad_norms:
        gn = jnp.stack([jnp.sqrt(jnp.sum(gw * gw)) for gw, _ in all_grads])
        metrics.append(gn)
    return conv_params, fc_params, (m, v, t), sk_state, metrics


def _cnn_parse_args(cfg: CNNStepConfig, args):
    n_conv = len(cfg.cnn.channels) - 1
    fc = cfg.cnn.fc_spec
    i = 0

    def take_pairs(n, i):
        out = []
        for _ in range(n):
            out.append((args[i], args[i + 1]))
            i += 2
        return out, i

    conv_params, i = take_pairs(n_conv, i)
    fc_params, i = take_pairs(fc.n_layers, i)
    m_conv, i = take_pairs(n_conv, i)
    m_fc, i = take_pairs(fc.n_layers, i)
    v_conv, i = take_pairs(n_conv, i)
    v_fc, i = take_pairs(fc.n_layers, i)
    t = args[i]
    i += 1
    sk_state, proj = None, None
    if cfg.uses_sketches:
        sk_state = sketching.SketchState(args[i], args[i + 1], args[i + 2])
        proj = sketching.Projections(args[i + 3], args[i + 4], args[i + 5], args[i + 6])
        i += 7
    x, y = args[i], args[i + 1]
    return conv_params, fc_params, (m_conv + m_fc, v_conv + v_fc, t), sk_state, proj, x, y


def _cnn_flatten_state(cfg, conv_params, fc_params, opt_state, sk_state):
    m, v, t = opt_state
    out = _flatten_params(conv_params) + _flatten_params(fc_params)
    out += _flatten_params(m) + _flatten_params(v) + [t]
    if cfg.uses_sketches:
        out += [sk_state.x, sk_state.y, sk_state.z]
    return out


def build_cnn(cfg: CNNStepConfig):
    """CNN-MLP train-step artifact (single or chunked)."""

    def single(conv_params, fc_params, opt_state, sk_state, proj, x, y):
        return _cnn_core_step(cfg, conv_params, fc_params, opt_state, sk_state, proj, x, y)

    if cfg.chunk == 0:
        def fn(*args):
            conv_params, fc_params, opt_state, sk_state, proj, x, y = _cnn_parse_args(cfg, args)
            conv_params, fc_params, opt_state, sk_state, metrics = single(
                conv_params, fc_params, opt_state, sk_state, proj, x, y)
            return tuple(_cnn_flatten_state(cfg, conv_params, fc_params, opt_state, sk_state) + metrics)
        return fn, cnn_input_specs(cfg), cnn_output_specs(cfg)

    k_steps = cfg.chunk
    n_conv = len(cfg.cnn.channels) - 1
    n_mats = n_conv + cfg.cnn.fc_spec.n_layers
    n_state = 2 * n_mats * 3 + 1 + (3 if cfg.uses_sketches else 0)
    n_metrics = len(cnn_output_specs(cfg)) - n_state

    def fn(*args):
        conv_params, fc_params, opt_state, sk_state, proj, xs, ys = _cnn_parse_args(cfg, args)
        metric_specs = cnn_output_specs(cfg)[-n_metrics:]
        metric_acc = [jnp.zeros((k_steps,) + s.shape[1:], jnp.float32) for s in metric_specs]

        def body(step, carry):
            conv_params, fc_params, opt_state, sk_state, metric_acc = carry
            x = lax.dynamic_index_in_dim(xs, step, 0, keepdims=False)
            y = lax.dynamic_index_in_dim(ys, step, 0, keepdims=False)
            conv_params, fc_params, opt_state, sk_state, metrics = single(
                conv_params, fc_params, opt_state, sk_state, proj, x, y)
            metric_acc = [
                lax.dynamic_update_slice_in_dim(acc, mm[None], step, axis=0)
                for acc, mm in zip(metric_acc, metrics)
            ]
            return (conv_params, fc_params, opt_state, sk_state, metric_acc)

        conv_params, fc_params, opt_state, sk_state, metric_acc = lax.fori_loop(
            0, k_steps, body, (conv_params, fc_params, opt_state, sk_state, metric_acc))
        return tuple(_cnn_flatten_state(cfg, conv_params, fc_params, opt_state, sk_state) + metric_acc)

    return fn, cnn_input_specs(cfg), cnn_output_specs(cfg)


# ---------------------------------------------------------------------------
# PINN (2D Poisson, Figs. 3-4) — monitoring-only sketching
# ---------------------------------------------------------------------------

import jax  # noqa: E402

from . import pinn as P  # noqa: E402


class PINNStepConfig(NamedTuple):
    pinn: "P.PINNSpec"
    variant: str  # standard | monitored
    n_f: int = 256  # interior collocation batch
    n_bc: int = 64  # boundary batch
    r: int = 2
    beta: float = 0.95
    lr: float = 1e-3
    chunk: int = 0
    power_iters: int = 16
    emit_grad_norms: bool = True

    @property
    def k(self) -> int:
        return 2 * self.r + 1

    @property
    def uses_sketches(self) -> bool:
        return self.variant == "monitored"


def pinn_input_specs(cfg: PINNStepConfig) -> list[ArgSpec]:
    spec = cfg.pinn.mlp_spec
    specs = _param_specs(spec)
    specs += _param_specs(spec, "m_") + _param_specs(spec, "v_")
    specs.append(ArgSpec("t", (), "f32"))
    if cfg.uses_sketches:
        lh, d, k = spec.n_hidden, spec.d_hidden, cfg.k
        specs += [
            ArgSpec("sketch_x", (lh, d, k), "f32"),
            ArgSpec("sketch_y", (lh, d, k), "f32"),
            ArgSpec("sketch_z", (lh, d, k), "f32"),
            ArgSpec("proj_upsilon", (cfg.n_f, k), "f32"),
            ArgSpec("proj_omega", (cfg.n_f, k), "f32"),
            ArgSpec("proj_phi", (cfg.n_f, k), "f32"),
            ArgSpec("proj_psi", (lh, k), "f32"),
        ]
    if cfg.chunk:
        specs.append(ArgSpec("interior", (cfg.chunk, cfg.n_f, 2), "f32"))
        specs.append(ArgSpec("boundary", (cfg.chunk, cfg.n_bc, 2), "f32"))
    else:
        specs.append(ArgSpec("interior", (cfg.n_f, 2), "f32"))
        specs.append(ArgSpec("boundary", (cfg.n_bc, 2), "f32"))
    return specs


def pinn_output_specs(cfg: PINNStepConfig) -> list[ArgSpec]:
    spec = cfg.pinn.mlp_spec
    specs = _param_specs(spec, "out_")
    specs += _param_specs(spec, "out_m_") + _param_specs(spec, "out_v_")
    specs.append(ArgSpec("out_t", (), "f32"))
    if cfg.uses_sketches:
        lh, d, k = spec.n_hidden, spec.d_hidden, cfg.k
        specs += [
            ArgSpec("out_sketch_x", (lh, d, k), "f32"),
            ArgSpec("out_sketch_y", (lh, d, k), "f32"),
            ArgSpec("out_sketch_z", (lh, d, k), "f32"),
        ]
    kdim = (cfg.chunk,) if cfg.chunk else ()
    specs += [
        ArgSpec("loss", kdim, "f32"),
        ArgSpec("pde_mse", kdim, "f32"),
        ArgSpec("bc_mse", kdim, "f32"),
    ]
    if cfg.uses_sketches:
        lh = spec.n_hidden
        specs += [
            ArgSpec("z_norm", kdim + (lh,), "f32"),
            ArgSpec("stable_rank", kdim + (lh,), "f32"),
            ArgSpec("y_norm", kdim + (lh,), "f32"),
            ArgSpec("x_norm", kdim + (lh,), "f32"),
        ]
    if cfg.emit_grad_norms:
        specs.append(ArgSpec("grad_norm", kdim + (spec.n_layers,), "f32"))
    return specs


def _pinn_core_step(cfg: PINNStepConfig, params, opt_state, sk_state, proj,
                    interior, boundary):
    spec = cfg.pinn

    def loss_fn(plist):
        pl_pairs = [(plist[2 * i], plist[2 * i + 1]) for i in range(len(plist) // 2)]
        total, pde, bc = P.pinn_loss(pl_pairs, interior, boundary, spec)
        return total, (pde, bc)

    flat = _flatten_params(params)
    (total, (pde, bc)), flat_grads = jax.value_and_grad(loss_fn, has_aux=True)(flat)
    grads = [(flat_grads[2 * i], flat_grads[2 * i + 1]) for i in range(len(flat_grads) // 2)]

    if cfg.uses_sketches:
        # Monitoring hooks: recompute forward activations on the interior
        # batch (cheap, matches the paper's forward-hook accumulation).
        _, acts = M.mlp_forward(params, interior, spec.mlp_spec)
        sk_state = M.update_all_sketches(sk_state, proj, acts, cfg.beta)

    m, v, t = opt_state
    params, m, v, t = optim.adam_update(params, grads, m, v, t, cfg.lr)
    opt_state = (m, v, t)

    metrics = [total, pde, bc]
    if cfg.uses_sketches:
        zn, sr, yn, xn = sketching.monitor_metrics(sk_state, cfg.power_iters)
        metrics += [zn, sr, yn, xn]
    if cfg.emit_grad_norms:
        gn = jnp.stack([jnp.sqrt(jnp.sum(gw * gw)) for gw, _ in grads])
        metrics.append(gn)
    return params, opt_state, sk_state, metrics


def build_pinn(cfg: PINNStepConfig):
    spec = cfg.pinn.mlp_spec

    def parse(args):
        i = 0
        params, i = _unflatten_params(args, spec, i)
        m, i = _unflatten_params(args, spec, i)
        v, i = _unflatten_params(args, spec, i)
        t = args[i]
        i += 1
        sk_state, proj = None, None
        if cfg.uses_sketches:
            sk_state = sketching.SketchState(args[i], args[i + 1], args[i + 2])
            proj = sketching.Projections(args[i + 3], args[i + 4], args[i + 5], args[i + 6])
            i += 7
        return params, (m, v, t), sk_state, proj, args[i], args[i + 1]

    def flatten_state(params, opt_state, sk_state):
        m, v, t = opt_state
        out = _flatten_params(params) + _flatten_params(m) + _flatten_params(v) + [t]
        if cfg.uses_sketches:
            out += [sk_state.x, sk_state.y, sk_state.z]
        return out

    if cfg.chunk == 0:
        def fn(*args):
            params, opt_state, sk_state, proj, interior, boundary = parse(args)
            params, opt_state, sk_state, metrics = _pinn_core_step(
                cfg, params, opt_state, sk_state, proj, interior, boundary)
            return tuple(flatten_state(params, opt_state, sk_state) + metrics)
        return fn, pinn_input_specs(cfg), pinn_output_specs(cfg)

    k_steps = cfg.chunk
    n_state = 6 * spec.n_layers + 1 + (3 if cfg.uses_sketches else 0)
    n_metrics = len(pinn_output_specs(cfg)) - n_state

    def fn(*args):
        params, opt_state, sk_state, proj, interiors, boundaries = parse(args)
        metric_specs = pinn_output_specs(cfg)[-n_metrics:]
        metric_acc = [jnp.zeros((k_steps,) + s.shape[1:], jnp.float32) for s in metric_specs]

        def body(step, carry):
            params, opt_state, sk_state, metric_acc = carry
            interior = lax.dynamic_index_in_dim(interiors, step, 0, keepdims=False)
            boundary = lax.dynamic_index_in_dim(boundaries, step, 0, keepdims=False)
            params, opt_state, sk_state, metrics = _pinn_core_step(
                cfg, params, opt_state, sk_state, proj, interior, boundary)
            metric_acc = [
                lax.dynamic_update_slice_in_dim(acc, mm[None], step, axis=0)
                for acc, mm in zip(metric_acc, metrics)
            ]
            return (params, opt_state, sk_state, metric_acc)

        params, opt_state, sk_state, metric_acc = lax.fori_loop(
            0, k_steps, body, (params, opt_state, sk_state, metric_acc))
        return tuple(flatten_state(params, opt_state, sk_state) + metric_acc)

    return fn, pinn_input_specs(cfg), pinn_output_specs(cfg)


def build_pinn_eval(pinn_spec: "P.PINNSpec", n_grid: int):
    """Evaluation artifact: params + (n_grid, 2) points -> (u, u_exact,
    abs_err, l2_rel_err).  Used for Fig. 4 fields and the 0.31 headline."""
    spec = pinn_spec.mlp_spec
    in_specs = _param_specs(spec) + [ArgSpec("grid", (n_grid, 2), "f32")]
    out_specs = [
        ArgSpec("u", (n_grid,), "f32"),
        ArgSpec("u_exact", (n_grid,), "f32"),
        ArgSpec("abs_err", (n_grid,), "f32"),
        ArgSpec("l2_rel_err", (), "f32"),
    ]

    def fn(*args):
        params, i = _unflatten_params(args, spec, 0)
        grid = args[i]
        u = P.u_batch(params, grid, pinn_spec)
        ue = P.exact_solution(grid)
        err = jnp.abs(u - ue)
        rel = jnp.sqrt(jnp.sum((u - ue) ** 2)) / jnp.sqrt(jnp.sum(ue**2))
        return (u, ue, err, rel)

    return fn, in_specs, out_specs


# ---------------------------------------------------------------------------
# Reconstruction-bound validation artifact (Thm 4.2)
# ---------------------------------------------------------------------------


def build_recon_eval(n_b: int, d: int, r: int):
    """Single-shot sketch->reconstruct of one activation matrix: inputs the
    batch A and fresh projections, builds the three sketches with beta=0
    (pure batch contribution), reconstructs via the fused Eq. 6-7 path and
    returns (A_tilde, fro_err).  The tail energy tau_{r+1}(A) for the
    sqrt(6) bound is computed rust-side (Jacobi eigensolver)."""
    k = 2 * r + 1
    in_specs = [
        ArgSpec("a", (n_b, d), "f32"),
        ArgSpec("proj_upsilon", (n_b, k), "f32"),
        ArgSpec("proj_omega", (n_b, k), "f32"),
        ArgSpec("proj_phi", (n_b, k), "f32"),
        ArgSpec("proj_psi", (k,), "f32"),
    ]
    out_specs = [
        ArgSpec("a_tilde", (n_b, d), "f32"),
        ArgSpec("fro_err", (), "f32"),
    ]

    def fn(a, upsilon, omega, phi, psi):
        from .kernels.ema_update import ema_sketch_update

        zero = jnp.zeros((d, k), jnp.float32)
        x_s = ema_sketch_update(a, upsilon, zero, 0.0)
        y_s = ema_sketch_update(a, omega, zero, 0.0)
        z_s = ema_sketch_update(a, phi, zero, 0.0, psi)
        a_t = sketching.reconstruct_batch_activations(x_s, y_s, z_s, omega)
        err = jnp.sqrt(jnp.sum((a - a_t) ** 2))
        return (a_t, err)

    return fn, in_specs, out_specs
