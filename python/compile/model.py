"""L2 model: feed-forward MLP with explicit forward and *manual* backward.

The backward pass is written out rather than taken from ``jax.grad`` because
the paper's contribution (Eq. 8) replaces one specific factor of the weight
gradient — the stored input activation — with its sketch reconstruction,
while the error signals ``delta`` stay exact to preserve the chain rule
(paper §4.2 and Alg. 2).  An explicit backward makes that substitution a
one-line swap and keeps the lowered HLO auditable.

Sketch-triplet indexing (our reading of the paper's per-layer triplets;
DESIGN.md §2/S1 documents the ambiguity):

* hidden activations are ``A^[1] .. A^[L-1]`` (uniform width ``h``); the
  input ``A^[0] = x`` is the mini-batch itself (already resident, never
  sketched) and logits are consumed immediately.
* triplet ``j`` (0-indexed ``j-1`` in the stacked state) sketches:
  ``X_j <- A^[j-1]`` for ``j >= 2`` (input patterns), ``X_1 <- A^[1]``
  (self — the input to weight 1 has non-uniform width), and
  ``Y_j, Z_j <- A^[j]`` (output/interaction patterns).
* sketched gradients: ``grad W^[l] = delta^[l]^T @ A_tilde^[l-1]`` for
  ``l >= 2`` where ``A_tilde^[l-1]`` reconstructs from triplet ``l-1``;
  weight 1 always uses the exact input batch.
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import jax.numpy as jnp

from . import sketching
from .kernels.grad_outer import grad_outer
from .kernels.ref import grad_outer_ref


class MLPSpec(NamedTuple):
    """Architecture: ``dims = (d_in, h, ..., h, d_out)``, L = len(dims)-1
    weight layers, activation in {"tanh", "relu"}."""

    dims: tuple
    activation: str

    @property
    def n_layers(self) -> int:
        return len(self.dims) - 1

    @property
    def n_hidden(self) -> int:
        return len(self.dims) - 2

    @property
    def d_hidden(self) -> int:
        return self.dims[1]


def activate(pre: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "tanh":
        return jnp.tanh(pre)
    if kind == "relu":
        return jnp.maximum(pre, 0.0)
    raise ValueError(f"unknown activation {kind!r}")


def activate_grad_from_value(a: jnp.ndarray, kind: str) -> jnp.ndarray:
    """sigma'(pre) expressed through the activation *value* so the backward
    pass needs no pre-activation storage (tanh' = 1 - a^2; relu' = [a > 0])."""
    if kind == "tanh":
        return 1.0 - a * a
    if kind == "relu":
        return (a > 0.0).astype(a.dtype)
    raise ValueError(f"unknown activation {kind!r}")


def mlp_forward(
    params: Sequence[tuple[jnp.ndarray, jnp.ndarray]],
    x: jnp.ndarray,
    spec: MLPSpec,
) -> tuple[jnp.ndarray, list[jnp.ndarray]]:
    """Returns ``(logits, acts)`` with ``acts[j] = A^[j]`` for
    ``j = 0..L-1`` (``acts[0] = x``); logits are not activated."""
    acts = [x]
    a = x
    n = spec.n_layers
    for l, (w, b) in enumerate(params):
        pre = a @ w.T + b[None, :]
        if l < n - 1:
            a = activate(pre, spec.activation)
            acts.append(a)
        else:
            return pre, acts
    raise AssertionError("empty params")


def softmax_xent(
    logits: jnp.ndarray, labels: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Mean cross-entropy over the batch with int32 ``labels``.

    Returns ``(loss, delta_logits, accuracy)`` where ``delta_logits`` is the
    exact dL/dlogits = (softmax - onehot)/n_b used to seed the backward pass.
    """
    n_b, n_cls = logits.shape
    zmax = jnp.max(logits, axis=1, keepdims=True)
    shifted = logits - zmax
    logsumexp = jnp.log(jnp.sum(jnp.exp(shifted), axis=1, keepdims=True))
    log_probs = shifted - logsumexp
    onehot = (labels[:, None] == jnp.arange(n_cls)[None, :]).astype(
        logits.dtype
    )
    loss = -jnp.sum(onehot * log_probs) / n_b
    delta = (jnp.exp(log_probs) - onehot) / n_b
    pred = jnp.argmax(logits, axis=1)
    acc = jnp.mean((pred == labels).astype(jnp.float32))
    return loss, delta, acc


def mlp_backward(
    params: Sequence[tuple[jnp.ndarray, jnp.ndarray]],
    acts: Sequence[jnp.ndarray],
    delta_logits: jnp.ndarray,
    spec: MLPSpec,
    recon_acts: dict[int, jnp.ndarray] | None = None,
    use_pallas: bool = True,
) -> list[tuple[jnp.ndarray, jnp.ndarray]]:
    """Manual backward for the MLP.

    ``recon_acts`` maps hidden-activation index ``j`` (matching ``acts``)
    to the sketch-reconstructed ``A_tilde^[j]``; when present it replaces
    the stored activation in that weight layer's gradient (paper Eq. 8) —
    error propagation stays exact.
    """
    outer = grad_outer if use_pallas else grad_outer_ref
    n = spec.n_layers
    grads: list = [None] * n
    delta = delta_logits
    for l in range(n - 1, -1, -1):
        a_in = acts[l]
        if recon_acts is not None and l in recon_acts:
            a_in = recon_acts[l]
        grad_w = outer(delta, a_in)
        grad_b = jnp.sum(delta, axis=0)
        grads[l] = (grad_w, grad_b)
        if l > 0:
            w, _ = params[l]
            delta = (delta @ w) * activate_grad_from_value(
                acts[l], spec.activation
            )
    return grads


def update_all_sketches(
    state: sketching.SketchState,
    proj: sketching.Projections,
    acts: Sequence[jnp.ndarray],
    beta: float,
    use_pallas: bool = True,
) -> sketching.SketchState:
    """Eqs. 5a-5c for every hidden activation.  Triplet ``t = j - 1`` for
    hidden activation ``A^[j]``; its X-sketch input is ``A^[j-1]`` for
    ``j >= 2`` and ``A^[1]`` itself for ``j = 1`` (see module docstring)."""
    n_hidden = len(acts) - 1
    for j in range(1, n_hidden + 1):
        a_in = acts[j - 1] if j >= 2 else acts[1]
        state = sketching.update_layer_sketches(
            state, proj, j - 1, a_in, acts[j], beta, use_pallas
        )
    return state


def reconstruct_hidden_acts(
    state: sketching.SketchState,
    proj: sketching.Projections,
    n_hidden: int,
    acts: Sequence[jnp.ndarray] | None = None,
) -> dict[int, jnp.ndarray]:
    """Reconstruct every hidden activation ``A_tilde^[j]`` (Eq. 7, fused
    form) keyed by activation index ``j`` for use in ``mlp_backward``.

    When the live forward activations ``acts`` are provided, each
    reconstruction is trust-region clipped against the current batch's
    actual activation norm — the stabilisation that keeps sketched
    training convergent on correlated data (see sketching.py)."""
    recon = {}
    for j in range(1, n_hidden + 1):
        t = j - 1
        norm_ref = None
        if acts is not None:
            a = acts[j]
            norm_ref = jnp.sqrt(jnp.sum(a * a) + 1e-12)
        recon[j] = sketching.reconstruct_batch_activations_lsq(
            state, proj, t, norm_ref
        )
    return recon
