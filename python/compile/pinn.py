"""Physics-informed neural network for the 2D Poisson problem
(paper §5.1.2 / §5.2.2, Figs. 3-4):

    -Laplace(u) = 4 pi^2 sin(2 pi x) sin(2 pi y)   on [0,1]^2,  u = 0 on the
    boundary, exact solution u*(x,y) = 0.5 sin(2 pi x) sin(2 pi y).

PDE residuals need exact second derivatives of the network output, so the
paper deploys sketching in *monitoring-only* mode here: parameter updates
use exact ``jax.grad`` of the composite loss while EMA sketches accumulate
from the forward activations for diagnostics (paper's "forward hooks").

The Laplacian is forward-over-reverse (``jax.hessian`` trace via vmap);
everything lowers to plain HLO — no LAPACK custom-calls.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import model as M

TWO_PI = 2.0 * math.pi


class PINNSpec(NamedTuple):
    dims: tuple = (2, 50, 50, 50, 1)
    activation: str = "tanh"
    bc_weight: float = 10.0

    @property
    def mlp_spec(self) -> M.MLPSpec:
        return M.MLPSpec(dims=self.dims, activation=self.activation)


def forcing(xy: jnp.ndarray) -> jnp.ndarray:
    """f(x,y) = 4 pi^2 sin(2 pi x) sin(2 pi y) for points (n, 2)."""
    return (
        4.0
        * math.pi**2
        * jnp.sin(TWO_PI * xy[:, 0])
        * jnp.sin(TWO_PI * xy[:, 1])
    )


def exact_solution(xy: jnp.ndarray) -> jnp.ndarray:
    """u*(x,y) = 0.5 sin(2 pi x) sin(2 pi y) (satisfies -Lap u = f, u=0 on
    the boundary of the unit square)."""
    return 0.5 * jnp.sin(TWO_PI * xy[:, 0]) * jnp.sin(TWO_PI * xy[:, 1])


def u_scalar(params, xy: jnp.ndarray, spec: PINNSpec) -> jnp.ndarray:
    """Network value at a single point (2,) -> scalar."""
    logits, _ = M.mlp_forward(params, xy[None, :], spec.mlp_spec)
    return logits[0, 0]


def u_batch(params, xy: jnp.ndarray, spec: PINNSpec) -> jnp.ndarray:
    logits, _ = M.mlp_forward(params, xy, spec.mlp_spec)
    return logits[:, 0]


def laplacian(params, xy: jnp.ndarray, spec: PINNSpec) -> jnp.ndarray:
    """Trace of the Hessian of u at each point, vmapped over the batch."""

    def lap_one(pt):
        h = jax.hessian(lambda p: u_scalar(params, p, spec))(pt)
        return h[0, 0] + h[1, 1]

    return jax.vmap(lap_one)(xy)


def pinn_loss(
    params,
    interior: jnp.ndarray,
    boundary: jnp.ndarray,
    spec: PINNSpec,
):
    """Composite loss = PDE residual MSE + weighted boundary MSE.
    Returns (total, pde_mse, bc_mse)."""
    lap = laplacian(params, interior, spec)
    res = -lap - forcing(interior)
    pde_mse = jnp.mean(res * res)
    ub = u_batch(params, boundary, spec)
    bc_mse = jnp.mean(ub * ub)
    return pde_mse + spec.bc_weight * bc_mse, pde_mse, bc_mse


def l2_relative_error(
    params, grid: jnp.ndarray, spec: PINNSpec
) -> jnp.ndarray:
    """||u - u*||_2 / ||u*||_2 over an evaluation point set (paper reports
    0.31 on testing points)."""
    u = u_batch(params, grid, spec)
    ue = exact_solution(grid)
    return jnp.sqrt(jnp.sum((u - ue) ** 2)) / jnp.sqrt(jnp.sum(ue**2))
