"""Hybrid CNN-MLP for the CIFAR experiment (paper §5.1.2, Fig. 2).

Convolutional feature extraction (two conv/relu/maxpool stages) followed by
three 512-wide fully-connected layers + a 10-class head.  Sketching applies
*only* to the dense hidden layers — the paper's selective-deployment
demonstration — so:

* the conv block trains with exact gradients obtained through ``jax.vjp``
  (conv transpose ops are native HLO, LAPACK-free);
* the FC block reuses the manual MLP forward/backward from ``model.py``
  with sketch reconstruction swapped into Eq. 8 exactly as for MNIST;
* the flattened conv features act as the FC block's "input batch" (exact,
  resident — the analogue of the MNIST input layer).

Input layout is NCHW (n_b, 3, 32, 32); the feature dim after two 2x2 pools
is 64 * 8 * 8 = 4096.
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from . import model as M


class CNNSpec(NamedTuple):
    """Conv stages are fixed (paper gives no exact extractor; this matches
    the description's scale): 3->32->64 channels, 3x3 SAME kernels,
    2x2 max pools.  ``fc_dims`` = (4096, 512, 512, 512, 10)."""

    in_hw: int = 32
    channels: tuple = (3, 32, 64)
    fc_dims: tuple = (4096, 512, 512, 512, 10)
    activation: str = "relu"

    @property
    def fc_spec(self) -> M.MLPSpec:
        return M.MLPSpec(dims=self.fc_dims, activation=self.activation)

    @property
    def feat_dim(self) -> int:
        hw = self.in_hw // 4  # two 2x2 pools
        return self.channels[-1] * hw * hw


ConvParams = Sequence[tuple[jnp.ndarray, jnp.ndarray]]


def conv_forward(conv_params: ConvParams, x: jnp.ndarray) -> jnp.ndarray:
    """Two conv/relu/pool stages -> flattened features (n_b, feat_dim)."""
    a = x
    for kern, bias in conv_params:
        a = lax.conv_general_dilated(
            a,
            kern,
            window_strides=(1, 1),
            padding="SAME",
            dimension_numbers=("NCHW", "OIHW", "NCHW"),
        )
        a = a + bias[None, :, None, None]
        a = jnp.maximum(a, 0.0)
        a = lax.reduce_window(
            a,
            -jnp.inf,
            lax.max,
            window_dimensions=(1, 1, 2, 2),
            window_strides=(1, 1, 2, 2),
            padding="VALID",
        )
    n_b = a.shape[0]
    return a.reshape(n_b, -1)


def cnn_forward(
    conv_params: ConvParams,
    fc_params,
    x: jnp.ndarray,
    spec: CNNSpec,
):
    """Full forward.  Returns (logits, feats, fc_acts) where ``fc_acts``
    follows model.mlp_forward's convention with ``fc_acts[0] = feats``."""
    feats = conv_forward(conv_params, x)
    logits, fc_acts = M.mlp_forward(fc_params, feats, spec.fc_spec)
    return logits, feats, fc_acts


def cnn_backward(
    conv_params: ConvParams,
    fc_params,
    x: jnp.ndarray,
    feats: jnp.ndarray,
    fc_acts,
    delta_logits: jnp.ndarray,
    spec: CNNSpec,
    recon_acts=None,
):
    """Backward: manual through the FC block (sketched per Eq. 8 when
    ``recon_acts`` given), then ``jax.vjp`` pullback of the cotangent
    ``delta_feats`` through the conv block for exact conv grads."""
    fc_spec = spec.fc_spec
    fc_grads = M.mlp_backward(
        fc_params, fc_acts, delta_logits, fc_spec, recon_acts
    )
    # delta on the flattened features: chain through FC layer 0 (exact).
    delta = delta_logits
    n = fc_spec.n_layers
    for l in range(n - 1, 0, -1):
        w, _ = fc_params[l]
        delta = (delta @ w) * M.activate_grad_from_value(
            fc_acts[l], fc_spec.activation
        )
    w0, _ = fc_params[0]
    delta_feats = delta @ w0  # (n_b, feat_dim)

    _, vjp_fn = jax.vjp(lambda cp: conv_forward(cp, x), list(conv_params))
    (conv_grads,) = vjp_fn(delta_feats)
    return conv_grads, fc_grads
