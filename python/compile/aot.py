"""AOT compiler: lower every artifact in the registry to HLO *text* and
emit ``artifacts/manifest.json`` describing each artifact's exact flat
input/output interface for the rust runtime.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
XLA the rust ``xla`` crate links) rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --out-dir ../artifacts [--only REGEX] [--list]

Python runs ONLY here (build time); the rust binary is self-contained once
``artifacts/`` is populated.
"""

from __future__ import annotations

import argparse
import json
import os
import re
import sys
import time

import jax
from jax._src.lib import xla_client as xc

from . import cnn as C
from . import model as M
from . import pinn as P
from . import train_step as TS

# ---------------------------------------------------------------------------
# Experiment architectures (paper §5.1.2)
# ---------------------------------------------------------------------------

MNIST_SPEC = M.MLPSpec(dims=(784, 512, 512, 512, 10), activation="tanh")
MONITOR_SPEC = M.MLPSpec(
    dims=(784,) + (1024,) * 15 + (10,), activation="relu"
)
CNN_SPEC = C.CNNSpec()
PINN_SPEC = P.PINNSpec()

N_B = 128  # paper: all experiments use batch size 128
RANK_LADDER = (2, 4, 8, 16)  # paper: adaptive range r in [2, 16]

MNIST_CHUNK = 50
MONITOR_CHUNK = 20
CIFAR_CHUNK = 10
PINN_CHUNK = 20
PINN_EVAL_GRID = 51 * 51


def _registry() -> dict:
    """name -> zero-arg builder returning (fn, in_specs, out_specs, meta)."""
    reg: dict = {}

    def add(name, builder, **meta):
        def thunk(builder=builder, meta=meta):
            fn, ins, outs = builder()
            return fn, ins, outs, meta

        assert name not in reg, name
        reg[name] = thunk

    def mlp_meta(spec, cfg, arch):
        return dict(
            kind="mlp",
            arch=arch,
            dims=list(spec.dims),
            activation=spec.activation,
            variant=cfg.variant,
            optimizer=cfg.optimizer,
            n_b=cfg.n_b,
            r=cfg.r,
            k=cfg.k,
            beta=cfg.beta,
            lr=cfg.lr,
            chunk=cfg.chunk,
        )

    # --- MNIST MLP (Fig. 1): single-step (quickstart/tests) + chunked ----
    for chunk, tag in ((0, "step"), (MNIST_CHUNK, "chunk")):
        cfg = TS.StepConfig(
            spec=MNIST_SPEC, variant="standard", optimizer="adam",
            n_b=N_B, chunk=chunk,
        )
        add(f"mnist_std_{tag}", lambda cfg=cfg: TS.build(cfg),
            **mlp_meta(MNIST_SPEC, cfg, "mnist"))
    cfg = TS.StepConfig(
        spec=MNIST_SPEC, variant="sketched", optimizer="adam",
        n_b=N_B, r=2, beta=0.95, chunk=0,
    )
    add("mnist_sk_r2_step", lambda cfg=cfg: TS.build(cfg),
        **mlp_meta(MNIST_SPEC, cfg, "mnist"))
    for r in RANK_LADDER:
        cfg = TS.StepConfig(
            spec=MNIST_SPEC, variant="sketched", optimizer="adam",
            n_b=N_B, r=r, beta=0.95, chunk=MNIST_CHUNK,
        )
        add(f"mnist_sk_r{r}_chunk", lambda cfg=cfg: TS.build(cfg),
            **mlp_meta(MNIST_SPEC, cfg, "mnist"))

    # --- Gradient monitoring 16x1024 (Fig. 5): monitored mode, r=4 -------
    # Healthy (Adam) follows the family_mon_r{r} convention so the
    # generic resolver finds it; the problematic twin differs by
    # optimizer (SGD) and is addressed by its explicit name.
    for opt, name in (("adam", "monitor16_mon_r4_chunk"),
                      ("sgd", "monitor16_problematic_chunk")):
        cfg = TS.StepConfig(
            spec=MONITOR_SPEC, variant="monitored", optimizer=opt,
            n_b=N_B, r=4, beta=0.9, chunk=MONITOR_CHUNK,
            lr=1e-3 if opt == "adam" else 1e-2,
        )
        add(name, lambda cfg=cfg: TS.build(cfg),
            **mlp_meta(MONITOR_SPEC, cfg, "monitor16"))

    # --- CIFAR hybrid CNN-MLP (Fig. 2) ------------------------------------
    def cnn_meta(cfg):
        return dict(
            kind="cnn",
            arch="cifar",
            channels=list(cfg.cnn.channels),
            fc_dims=list(cfg.cnn.fc_dims),
            in_hw=cfg.cnn.in_hw,
            variant=cfg.variant,
            optimizer="adam",
            n_b=cfg.n_b,
            r=cfg.r,
            k=cfg.k,
            beta=cfg.beta,
            lr=cfg.lr,
            chunk=cfg.chunk,
        )

    ccfg = TS.CNNStepConfig(cnn=CNN_SPEC, variant="standard", n_b=N_B,
                            chunk=CIFAR_CHUNK)
    add("cifar_std_chunk", lambda cfg=ccfg: TS.build_cnn(cfg), **cnn_meta(ccfg))
    for r in RANK_LADDER:
        ccfg = TS.CNNStepConfig(cnn=CNN_SPEC, variant="sketched", n_b=N_B,
                                r=r, beta=0.95, chunk=CIFAR_CHUNK)
        add(f"cifar_sk_r{r}_chunk", lambda cfg=ccfg: TS.build_cnn(cfg),
            **cnn_meta(ccfg))

    # --- PINN 2D Poisson (Figs. 3-4): standard + monitored ladder ---------
    def pinn_meta(cfg):
        return dict(
            kind="pinn",
            arch="pinn",
            dims=list(cfg.pinn.dims),
            variant=cfg.variant,
            optimizer="adam",
            n_f=cfg.n_f,
            n_bc=cfg.n_bc,
            r=cfg.r,
            k=cfg.k,
            beta=cfg.beta,
            lr=cfg.lr,
            chunk=cfg.chunk,
            bc_weight=cfg.pinn.bc_weight,
        )

    pcfg = TS.PINNStepConfig(pinn=PINN_SPEC, variant="standard",
                             chunk=PINN_CHUNK)
    add("pinn_std_chunk", lambda cfg=pcfg: TS.build_pinn(cfg), **pinn_meta(pcfg))
    for r in RANK_LADDER:
        pcfg = TS.PINNStepConfig(pinn=PINN_SPEC, variant="monitored", r=r,
                                 beta=0.95, chunk=PINN_CHUNK)
        add(f"pinn_mon_r{r}_chunk", lambda cfg=pcfg: TS.build_pinn(cfg),
            **pinn_meta(pcfg))

    add("pinn_eval",
        lambda: TS.build_pinn_eval(PINN_SPEC, PINN_EVAL_GRID),
        kind="pinn_eval", arch="pinn", dims=list(PINN_SPEC.dims),
        n_grid=PINN_EVAL_GRID)

    # --- Reconstruction-bound validation (Thm 4.2) ------------------------
    for r in RANK_LADDER:
        add(f"recon_eval_r{r}",
            lambda r=r: TS.build_recon_eval(N_B, 512, r),
            kind="recon_eval", n_b=N_B, d=512, r=r, k=2 * r + 1)

    return reg


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


_DTYPES = {"f32": "float32", "i32": "int32"}


def lower_one(name: str, thunk, out_dir: str) -> dict:
    import jax.numpy as jnp

    fn, ins, outs, meta = thunk()
    specs = [
        jax.ShapeDtypeStruct(tuple(s.shape), getattr(jnp, _DTYPES[s.dtype]))
        for s in ins
    ]
    t0 = time.time()
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    dt = time.time() - t0
    print(f"  {name}: {len(text) / 1e6:.2f} MB HLO in {dt:.1f}s", flush=True)
    return {
        "file": f"{name}.hlo.txt",
        "inputs": [
            {"name": s.name, "shape": list(s.shape), "dtype": s.dtype}
            for s in ins
        ],
        "outputs": [
            {"name": s.name, "shape": list(s.shape), "dtype": s.dtype}
            for s in outs
        ],
        "meta": meta,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="regex filter on names")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    reg = _registry()
    names = sorted(reg)
    if args.only:
        pat = re.compile(args.only)
        names = [n for n in names if pat.search(n)]
    if args.list:
        print("\n".join(names))
        return

    os.makedirs(args.out_dir, exist_ok=True)
    manifest_path = os.path.join(args.out_dir, "manifest.json")
    manifest = {"version": 1, "n_b": N_B, "rank_ladder": list(RANK_LADDER),
                "artifacts": {}}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            try:
                manifest = json.load(f)
            except json.JSONDecodeError:
                pass

    t0 = time.time()
    print(f"lowering {len(names)} artifacts -> {args.out_dir}", flush=True)
    for name in names:
        manifest["artifacts"][name] = lower_one(name, reg[name], args.out_dir)
        # Incremental write so a crash keeps completed entries.
        with open(manifest_path, "w") as f:
            json.dump(manifest, f, indent=1)
    print(f"done in {time.time() - t0:.0f}s; manifest -> {manifest_path}")


if __name__ == "__main__":
    main()
