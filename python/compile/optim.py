"""In-graph optimizers (Adam and SGD) threaded through the AOT artifacts.

The optimizer lives inside the lowered train step so the rust coordinator
never touches parameter math — it only shuttles state tensors.  Adam follows
Kingma & Ba exactly (the paper trains with Adam lr=1e-3; the 'problematic'
Fig-5 configuration uses plain SGD).
"""

from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp

Params = Sequence[tuple[jnp.ndarray, jnp.ndarray]]


def adam_update(
    params: Params,
    grads: Params,
    m: Params,
    v: Params,
    t: jnp.ndarray,
    lr: float,
    beta1: float = 0.9,
    beta2: float = 0.999,
    eps: float = 1e-8,
):
    """One Adam step over per-layer (w, b) pairs.

    ``t`` is the *previous* step count (f32 scalar); returns
    ``(new_params, new_m, new_v, new_t)`` with ``new_t = t + 1`` used for
    bias correction.
    """
    t_new = t + 1.0
    bc1 = 1.0 - jnp.power(beta1, t_new)
    bc2 = 1.0 - jnp.power(beta2, t_new)
    new_params, new_m, new_v = [], [], []
    for (w, b), (gw, gb), (mw, mb), (vw, vb) in zip(params, grads, m, v):
        mw = beta1 * mw + (1.0 - beta1) * gw
        mb = beta1 * mb + (1.0 - beta1) * gb
        vw = beta2 * vw + (1.0 - beta2) * gw * gw
        vb = beta2 * vb + (1.0 - beta2) * gb * gb
        w = w - lr * (mw / bc1) / (jnp.sqrt(vw / bc2) + eps)
        b = b - lr * (mb / bc1) / (jnp.sqrt(vb / bc2) + eps)
        new_params.append((w, b))
        new_m.append((mw, mb))
        new_v.append((vw, vb))
    return new_params, new_m, new_v, t_new


def sgd_update(params: Params, grads: Params, lr: float):
    """Plain SGD (no momentum), as in the paper's 'problematic' Fig-5
    configuration."""
    return [
        (w - lr * gw, b - lr * gb)
        for (w, b), (gw, gb) in zip(params, grads)
    ]
