"""EMA three-sketch framework (paper §4): sketch state, updates (Eqs. 5a-5c),
two-stage reconstruction (Eqs. 6-7) and the sketch-derived monitoring
metrics (§4.6).

State layout
------------
Hidden layers are uniform (``d_hidden``), so per-layer sketches are stacked
into single arrays — this keeps the AOT artifact interface small and lets the
rust coordinator treat sketch state as three tensors:

    X: (L_h, d, k)   input-pattern sketches   (Eq. 5a)
    Y: (L_h, d, k)   output-pattern sketches  (Eq. 5b)
    Z: (L_h, d, s)   interaction sketches     (Eq. 5c)
    psi: (L_h, s)    layer-specific interaction weights Psi^[l]

with shared batch projections Upsilon/Omega (n_b, k) and Phi (n_b, s),
k = s = 2r + 1 (paper §4.1).

Reconstruction (algebraic fusion)
---------------------------------
The paper states Eq. 6 as the d x d feature-space structure
``G = Q_Y C Q_X^T`` followed by Eq. 7's batch projection
``A_tilde = Omega pinv(Y_s) G``.  Expanding ``pinv(Y_s) = R_Y^{-1} Q_Y^T``
and using ``Q_Y^T Q_Y = I`` collapses the pipeline to

    A_tilde = Omega @ R_Y^{-1} @ C @ Q_X^T                      (*)

with every intermediate k x k until the final (n_b, k) x (k, d) product —
the d x d matrix is never formed.  ``reconstruct_gema`` still materialises
Eq. 6 verbatim for the bound-validation harness; the train path uses (*).
This fusion is recorded in EXPERIMENTS.md §Perf (L2).
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from . import linalg
from .kernels.ema_update import ema_sketch_update
from .kernels.ref import ema_sketch_update_ref


class SketchState(NamedTuple):
    """EMA sketch state for all hidden layers of one network."""

    x: jnp.ndarray  # (L_h, d, k)
    y: jnp.ndarray  # (L_h, d, k)
    z: jnp.ndarray  # (L_h, d, s)


class Projections(NamedTuple):
    """Shared batch projections + per-layer interaction weights (§4.1)."""

    upsilon: jnp.ndarray  # (n_b, k)
    omega: jnp.ndarray  # (n_b, k)
    phi: jnp.ndarray  # (n_b, s)
    psi: jnp.ndarray  # (L_h, s)


def rank_dims(r: int) -> tuple[int, int]:
    """k = s = 2r + 1 (paper §4.1; the control framework's s = 2k + 1 is
    deliberately collapsed by the paper for batch-sized projections)."""
    k = 2 * r + 1
    return k, k


def update_layer_sketches(
    state: SketchState,
    proj: Projections,
    layer: int,
    a_in: jnp.ndarray,
    a_out: jnp.ndarray,
    beta: float,
    use_pallas: bool = True,
) -> SketchState:
    """Apply Eqs. 5a-5c for one hidden layer.

    ``a_in``  — activations entering the layer's weight (A^[l-1], n_b x d)
    ``a_out`` — activations leaving the layer's nonlinearity (A^[l], n_b x d)

    ``use_pallas=False`` routes through the jnp oracle; the AOT path keeps
    the Pallas kernel so the fused update lowers into the artifact.
    """
    upd = ema_sketch_update if use_pallas else ema_sketch_update_ref
    x_l = upd(a_in, proj.upsilon, state.x[layer], beta)
    y_l = upd(a_out, proj.omega, state.y[layer], beta)
    z_l = upd(a_out, proj.phi, state.z[layer], beta, proj.psi[layer])
    return SketchState(
        x=state.x.at[layer].set(x_l),
        y=state.y.at[layer].set(y_l),
        z=state.z.at[layer].set(z_l),
    )


def reconstruct_core(
    x_s: jnp.ndarray, y_s: jnp.ndarray, z_s: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Two-stage least-squares core (paper §4.2 steps 1-2).

    Returns ``(q_y, r_y, c, q_x)`` with
      q_y (d, k), r_y (k, k): economy QR of the Y-sketch
      q_x (d, k):             economy QR of the X-sketch
      c   (k, k):             transformation core C = P_X^T (Q_Y^T Z)^T
    """
    q_y, r_y = linalg.mgs_qr(y_s)
    q_x, _ = linalg.mgs_qr(x_s)
    # Step 1: C_inter = argmin ||Q_Y C - Z||_F = Q_Y^T Z (orthonormal Q_Y).
    c_inter = q_y.T @ z_s  # (k, s) with s == k
    # Step 2: P_X from QR of X^T (k x d wide), then
    # C = argmin ||P_X C - C_inter^T|| = P_X^T C_inter^T.
    p_x = linalg.householder_qr_wide(x_s.T)
    c = p_x.T @ c_inter.T
    return q_y, r_y, c, q_x


def reconstruct_gema(
    x_s: jnp.ndarray, y_s: jnp.ndarray, z_s: jnp.ndarray
) -> jnp.ndarray:
    """Paper Eq. 6 verbatim: the d x d feature-space EMA structure
    ``G = Q_Y C Q_X^T``.  Diagnostic/validation path only (the train path
    uses the fused form below)."""
    q_y, _, c, q_x = reconstruct_core(x_s, y_s, z_s)
    return q_y @ c @ q_x.T


# Trust-region factor for the reconstruction norm clip: Y = A^T Omega has
# E||Y||_F^2 = k ||A||_F^2, so ||Y||_F / sqrt(k) estimates ||A||_F; the
# reconstruction is rescaled whenever it exceeds CLIP_GAMMA times that.
# Without the clip the paper's Eq. 7 (Omega R_Y^{-1} C Q_X^T, with C built
# from an *independent* projection) amplifies by 1000x on fast-decaying
# sketch spectra — measured in tests/test_sketching.py and EXPERIMENTS.md.
CLIP_GAMMA = 3.0


def reconstruct_batch_activations(
    x_s: jnp.ndarray,
    y_s: jnp.ndarray,
    z_s: jnp.ndarray,
    omega: jnp.ndarray,
    norm_ref: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Paper Eq. 7 via the algebraically fused form (*) in the module
    docstring: ``A_tilde = Omega R_Y^{-1} C Q_X^T`` (n_b x d), with a
    trust-region norm clip (see ``CLIP_GAMMA``).

    ``norm_ref``: Frobenius norm of the activation matrix ``A_tilde`` is
    standing in for.  During sketched backprop the *current batch's*
    activation is alive in-graph at reconstruction time, so the clip can be
    exact: highly correlated activations make the EMA sketch spectrum decay
    fast and the unclipped Eq. 7 drifts upward run-away (measured: MNIST
    tanh net diverges at ~epoch 2 without this; EXPERIMENTS.md §Stability).
    Falls back to the Y-sketch energy estimate ``||Y||_F / sqrt(k)``.
    """
    _, r_y, c, q_x = reconstruct_core(x_s, y_s, z_s)
    # R_Y^{-1} C by truncated triangular solve (never forms the inverse).
    ry_inv_c = linalg.solve_upper_triangular(r_y, c)  # (k, k)
    coeff = omega @ ry_inv_c  # (n_b, k)
    a_tilde = coeff @ q_x.T  # (n_b, d)
    if norm_ref is None:
        k = y_s.shape[1]
        norm_ref = jnp.sqrt(jnp.sum(y_s * y_s) / k + 1e-12)
    a_t_norm = jnp.sqrt(jnp.sum(a_tilde * a_tilde) + 1e-12)
    scale = jnp.minimum(1.0, CLIP_GAMMA * norm_ref / a_t_norm)
    return a_tilde * scale


def reconstruct_batch_activations_lsq(
    state: "SketchState",
    proj: "Projections",
    layer: int,
    norm_ref: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Sequential least-squares reconstruction using ALL THREE sketches.

    The EMA sketches are exact projections of the (never-materialised)
    EMA activation matrix (Lemma 4.1): ``X = A_e^T Ups``, ``Y = A_e^T Om``,
    ``Z = (A_e^T Phi) . psi^T`` with A_e^T of shape (d, n_b).  Stacking
    ``P = [Ups | Om | Phi]`` (n_b, 3k) and ``S = [X | Y | Z / psi]``
    (d, 3k), the minimum-norm least-squares estimate of the batch-space
    activations is

        A_tilde = Q_P R_P^{-T} S^T          (P = Q_P R_P economy QR)

    i.e. the orthogonal projection of A_e onto the 3k-dimensional span of
    the known projections.  This is the control framework's "sequential
    least-squares procedure" (paper §4.2) carried out against the *known*
    batch projections — including the Psi un-scaling the paper's Eq. 6-7
    drops.  Being a projection it is non-expansive, which is what makes
    sketched training stable on correlated activations where the Eq. 7
    pipeline (kept as ``reconstruct_batch_activations`` for diagnostics
    and the bound harness) measurably diverges (EXPERIMENTS.md
    §Stability).  The train-step path uses this routine.
    """
    x_s = state.x[layer]
    y_s = state.y[layer]
    z_s = state.z[layer]
    psi = proj.psi[layer]
    psi_safe = jnp.where(jnp.abs(psi) < 1e-3, 1e-3, psi)
    z_unscaled = z_s / psi_safe[None, :]
    s_mat = jnp.concatenate([x_s, y_s, z_unscaled], axis=1)  # (d, 3k)
    p_mat = jnp.concatenate(
        [proj.upsilon, proj.omega, proj.phi], axis=1
    )  # (n_b, 3k)
    q_p, r_p = linalg.mgs_qr(p_mat)  # n_b >= 3k in all experiment configs
    # A_tilde = Q_P R_P^{-T} S^T: lower-triangular solve then project.
    w = linalg.solve_lower_triangular(r_p.T, s_mat.T)  # (3k, d)
    a_tilde = q_p @ w  # (n_b, d)
    if norm_ref is not None:
        a_t_norm = jnp.sqrt(jnp.sum(a_tilde * a_tilde) + 1e-12)
        scale = jnp.minimum(1.0, CLIP_GAMMA * norm_ref / a_t_norm)
        a_tilde = a_tilde * scale
    return a_tilde


def reconstruct_batch_activations_unfused(
    x_s: jnp.ndarray,
    y_s: jnp.ndarray,
    z_s: jnp.ndarray,
    omega: jnp.ndarray,
) -> jnp.ndarray:
    """Eq. 7 exactly as written (Omega pinv(Y) G with the d x d G formed).
    Used by tests to prove the fused path is numerically identical and by
    the perf harness as the 'before' datapoint."""
    g = reconstruct_gema(x_s, y_s, z_s)
    pinv_y = linalg.pinv_tall_via_qr(y_s)  # (k, d)
    return omega @ pinv_y @ g


def monitor_metrics(
    state: SketchState, power_iters: int = 24
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Sketch-derived monitoring metrics (paper §4.6) for every hidden
    layer, returned as (L_h,) vectors:

      z_norm      ||Z_s||_F        gradient-magnitude proxy
      stable_rank ||Y||_F^2/||Y||_2^2  gradient-diversity metric
      y_norm      ||Y_s||_F        activation-energy proxy
      x_norm      ||X_s||_F        input-energy proxy
    """
    l_h = state.x.shape[0]
    z_norms = []
    s_ranks = []
    y_norms = []
    x_norms = []
    for layer in range(l_h):
        z_norms.append(jnp.sqrt(jnp.sum(state.z[layer] ** 2)))
        s_ranks.append(linalg.stable_rank(state.y[layer], power_iters))
        y_norms.append(jnp.sqrt(jnp.sum(state.y[layer] ** 2)))
        x_norms.append(jnp.sqrt(jnp.sum(state.x[layer] ** 2)))
    return (
        jnp.stack(z_norms),
        jnp.stack(s_ranks),
        jnp.stack(y_norms),
        jnp.stack(x_norms),
    )
