"""Pallas kernel: tiled weight-gradient assembly ``grad = delta^T @ A``
(paper Eq. 8), the second L1 hot-spot.

``delta`` (n_b x d_out) are the exact backpropagated error signals, ``A``
(n_b x d_in) the (reconstructed) input activations; the output is the
d_out x d_in weight gradient.  Classic MXU-shaped matmul: grid tiles both
output dims, the batch dimension (n_b = 128 in every paper experiment) is
the contraction axis and a full (n_b, tile) slab of each operand fits VMEM.

Tile choice: 128 x 128 output tiles are MXU-native; layers narrower than a
tile (PINN's 50-wide, the 10-class logits) collapse to a single block so no
shape in the paper's experiments needs padding on CPU-interpret.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _grad_outer_kernel(delta_ref, a_ref, out_ref):
    out_ref[...] = jnp.dot(
        delta_ref[...].T, a_ref[...], preferred_element_type=jnp.float32
    )


def _pick_tile(dim: int, target: int = 128) -> int:
    """Largest divisor of ``dim`` that is <= target and power-of-two-ish;
    falls back to dim (single block)."""
    cand = target
    while cand >= 8:
        if dim % cand == 0:
            return cand
        cand //= 2
    return dim


@functools.partial(jax.named_call, name="grad_outer")
def grad_outer(
    delta: jnp.ndarray,
    a: jnp.ndarray,
    tile_out: int | None = None,
    tile_in: int | None = None,
) -> jnp.ndarray:
    n_b, d_out = delta.shape
    n_b2, d_in = a.shape
    assert n_b == n_b2, (n_b, n_b2)
    if tile_out is None:
        tile_out = _pick_tile(d_out)
    if tile_in is None:
        tile_in = _pick_tile(d_in)
    assert d_out % tile_out == 0 and d_in % tile_in == 0

    grid = (d_out // tile_out, d_in // tile_in)
    return pl.pallas_call(
        _grad_outer_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_b, tile_out), lambda i, j: (0, i)),
            pl.BlockSpec((n_b, tile_in), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tile_out, tile_in), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d_out, d_in), jnp.float32),
        interpret=True,
    )(delta, a)
