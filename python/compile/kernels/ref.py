"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here written in
the most obvious jnp form.  pytest (``python/tests/test_kernels.py``) sweeps
shapes/dtypes with hypothesis and asserts allclose between kernel and oracle;
this is the core L1 correctness signal.
"""

from __future__ import annotations

import jax.numpy as jnp


def ema_sketch_update_ref(
    a: jnp.ndarray,
    proj: jnp.ndarray,
    s_old: jnp.ndarray,
    beta: float,
    col_scale: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """EMA sketch update (paper Eqs. 5a-5c):

        S_new = beta * S_old + (1 - beta) * (A^T @ proj) [* col_scale]

    ``a``:        (n_b, d)   batch activation matrix
    ``proj``:     (n_b, k)   shared batch projection (Upsilon/Omega/Phi)
    ``s_old``:    (d, k)     current EMA sketch
    ``col_scale``:(k,)       optional per-column weights (Psi for Z-sketch)
    """
    contrib = a.T @ proj
    if col_scale is not None:
        contrib = contrib * col_scale[None, :]
    return beta * s_old + (1.0 - beta) * contrib


def grad_outer_ref(delta: jnp.ndarray, a: jnp.ndarray) -> jnp.ndarray:
    """Weight-gradient assembly (paper Eq. 8): ``grad = delta^T @ a``.

    ``delta``: (n_b, d_out) backpropagated error signals
    ``a``:     (n_b, d_in)  (reconstructed) input activations
    returns    (d_out, d_in)
    """
    return delta.T @ a


def recon_project_ref(proj_rows: jnp.ndarray, g_ema: jnp.ndarray) -> jnp.ndarray:
    """Batch-space projection (paper Eq. 7): ``A_tilde = proj_rows @ g_ema``.

    ``proj_rows``: (n_b, d) the factor ``Omega @ pinv(Y_s)`` already pushed
                   through ``Q_Y C``, leaving the dense (n_b, d) x (d, d)
                   product that dominates reconstruction cost.
    ``g_ema``:     (d, d) feature-space EMA structure (or its right factor)
    """
    return proj_rows @ g_ema
