"""Pallas kernel: fused EMA sketch update (the paper's L1 hot-spot).

Computes, in one pass over the activation matrix,

    S_new = beta * S_old + (1 - beta) * (A^T @ P) [* col_scale]

for activation ``A`` (n_b x d), shared batch projection ``P`` (n_b x k) and
EMA sketch ``S`` (d x k).  A naive port does the matmul then an axpy —
two passes over a d x k temporary.  The fused kernel streams one
``block_d``-wide slice of ``A`` HBM->VMEM per grid step, runs the MXU on the
(block_d, n_b) x (n_b, k) product and blends the EMA in the epilogue, so the
sketch tile is read and written exactly once.

TPU mapping (DESIGN.md §Hardware-Adaptation): grid over d; per-step VMEM is
``block_d*n_b + n_b*k + 2*block_d*k`` floats.  k = 2r+1 <= 33 is below the
128-lane MXU tile so the k axis is padded to lane width by Mosaic; the
padding tax is accounted in the roofline estimate, not hidden.

Runs under ``interpret=True`` everywhere in this repo (CPU PJRT cannot
execute Mosaic custom-calls); correctness is pinned to ``ref.py`` by pytest.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ema_kernel(a_ref, p_ref, s_ref, out_ref, *, beta: float):
    # a_ref: (n_b, block_d) slice of A; p_ref: (n_b, k); s_ref: (block_d, k)
    contrib = jnp.dot(
        a_ref[...].T, p_ref[...], preferred_element_type=jnp.float32
    )
    out_ref[...] = beta * s_ref[...] + (1.0 - beta) * contrib


def _ema_kernel_scaled(a_ref, p_ref, s_ref, scale_ref, out_ref, *, beta: float):
    contrib = jnp.dot(
        a_ref[...].T, p_ref[...], preferred_element_type=jnp.float32
    )
    contrib = contrib * scale_ref[...]  # (1, k) broadcast down block_d rows
    out_ref[...] = beta * s_ref[...] + (1.0 - beta) * contrib


def pick_block_d(d: int, n_b: int, k: int, vmem_budget: int = 1 << 21) -> int:
    """Largest power-of-two divisor of ``d`` (capped at 512) whose working
    set fits the VMEM budget (floats): block_d*n_b + n_b*k + 2*block_d*k.
    Falls back to ``d`` itself when d has no useful power-of-two divisor
    (e.g. the 50-wide PINN layers run as a single block).
    """
    best = d
    cand = 512
    while cand >= 8:
        if d % cand == 0:
            floats = cand * n_b + n_b * k + 2 * cand * k
            if floats <= vmem_budget:
                best = cand
                break
        cand //= 2
    return best


@functools.partial(jax.named_call, name="ema_sketch_update")
def ema_sketch_update(
    a: jnp.ndarray,
    proj: jnp.ndarray,
    s_old: jnp.ndarray,
    beta: float,
    col_scale: jnp.ndarray | None = None,
    block_d: int | None = None,
) -> jnp.ndarray:
    """Fused EMA sketch update; see module docstring.  ``beta`` is a static
    compile-time constant (fixed per experiment, paper §3.3)."""
    n_b, d = a.shape
    k = proj.shape[1]
    assert s_old.shape == (d, k), (s_old.shape, d, k)
    if block_d is None:
        block_d = pick_block_d(d, n_b, k)
    assert d % block_d == 0, (d, block_d)
    grid = (d // block_d,)

    a_spec = pl.BlockSpec((n_b, block_d), lambda i: (0, i))
    p_spec = pl.BlockSpec((n_b, k), lambda i: (0, 0))
    s_spec = pl.BlockSpec((block_d, k), lambda i: (i, 0))
    out_spec = pl.BlockSpec((block_d, k), lambda i: (i, 0))

    if col_scale is None:
        return pl.pallas_call(
            functools.partial(_ema_kernel, beta=beta),
            grid=grid,
            in_specs=[a_spec, p_spec, s_spec],
            out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct((d, k), jnp.float32),
            interpret=True,
        )(a, proj, s_old)

    scale2d = col_scale.reshape(1, k)
    scale_spec = pl.BlockSpec((1, k), lambda i: (0, 0))
    return pl.pallas_call(
        functools.partial(_ema_kernel_scaled, beta=beta),
        grid=grid,
        in_specs=[a_spec, p_spec, s_spec, scale_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((d, k), jnp.float32),
        interpret=True,
    )(a, proj, s_old, scale2d)
