"""Pure-jnp dense linear algebra for the AOT path.

xla_extension 0.5.1 (the XLA the rust `xla` crate links) cannot execute the
typed-FFI LAPACK custom-calls that jax's CPU lowering emits for
``jnp.linalg.qr`` / ``cholesky`` / ``triangular_solve`` / ``svd``.  Every
factorization used inside an AOT-lowered computation therefore lives here,
written only in terms of native-HLO ops (dot, while/fori_loop, select,
dynamic slicing, reductions).

All routines are differentiable-free utilities used inside manually written
forward/backward passes; they never need custom VJPs.

Shapes follow the paper's reconstruction pipeline (Antil & Verma 2025, §4.2):
sketch matrices are ``d x k`` with ``k = 2r + 1 << d``, so the tall QRs run
modified Gram-Schmidt over k columns and the wide QR (for ``P_X``) runs
masked Householder over k rows.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

# Numerical floor used when normalizing nearly-dependent columns; keeps the
# factorizations total (no NaNs) for rank-deficient EMA sketches early in
# training when sketches are still near zero.
_EPS = 1e-12


def mgs_qr(a: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Economy QR of a tall matrix ``a`` (m x n, m >= n) via modified
    Gram-Schmidt with one re-orthogonalisation pass ("MGS2", numerically
    comparable to Householder for the well-separated spectra we see here).

    Returns ``(q, r)`` with ``q`` m x n (orthonormal columns) and ``r``
    n x n upper triangular.  Lowered entirely to dot/fori_loop HLO.
    """
    m, n = a.shape

    def body(j, qr):
        q, r = qr
        v = lax.dynamic_slice_in_dim(a, j, 1, axis=1)  # m x 1
        # First projection pass against all previous columns.  Columns >= j
        # of q are still zero, so projecting against the full q is exact and
        # keeps shapes static.
        coeff1 = q.T @ v  # n x 1
        v = v - q @ coeff1
        # Re-orthogonalisation pass (classical "twice is enough").
        coeff2 = q.T @ v
        v = v - q @ coeff2
        coeff = coeff1 + coeff2
        norm = jnp.sqrt(jnp.sum(v * v) + _EPS)
        qj = v / norm
        q = lax.dynamic_update_slice_in_dim(q, qj, j, axis=1)
        rj = coeff.at[j, 0].set(norm)  # r column j: projections + diag norm
        r = lax.dynamic_update_slice_in_dim(r, rj, j, axis=1)
        return (q, r)

    q0 = jnp.zeros((m, n), a.dtype)
    r0 = jnp.zeros((n, n), a.dtype)
    q, r = lax.fori_loop(0, n, body, (q0, r0))
    return q, r


def householder_qr_wide(a: jnp.ndarray) -> jnp.ndarray:
    """Q factor (k x k, fully orthogonal) of the QR of a wide matrix ``a``
    (k x d, k <= d) via masked Householder reflections.

    Only the orthogonal factor is returned because the paper's Step-2 only
    consumes ``P_X`` (the triangular factor of ``(X_s)^T`` is discarded).
    Masking replaces dynamic column-length slicing so every iterate keeps a
    static shape.
    """
    k, d = a.shape
    rows = jnp.arange(k)

    def body(j, state):
        r_mat, q = state
        x = lax.dynamic_slice_in_dim(r_mat, j, 1, axis=1)[:, 0]  # column j
        mask = (rows >= j).astype(a.dtype)
        x = x * mask  # zero entries above the pivot
        alpha = jnp.sqrt(jnp.sum(x * x) + _EPS)
        pivot = x[j]
        # Standard sign choice avoids cancellation.
        alpha = jnp.where(pivot >= 0, -alpha, alpha)
        v = x.at[j].add(-alpha)
        vnorm2 = jnp.sum(v * v) + _EPS
        v = v / jnp.sqrt(vnorm2)
        v = v[:, None]  # k x 1 unit reflector
        r_mat = r_mat - 2.0 * v @ (v.T @ r_mat)
        q = q - 2.0 * (q @ v) @ v.T
        return (r_mat, q)

    q0 = jnp.eye(k, dtype=a.dtype)
    _, q = lax.fori_loop(0, k, body, (a, q0))
    return q


def solve_upper_triangular(
    r: jnp.ndarray, b: jnp.ndarray, rcond: float = 1e-4
) -> jnp.ndarray:
    """Solve ``r x = b`` for upper-triangular ``r`` (n x n) and ``b``
    (n x p) by back-substitution with static shapes.

    Truncated solve: solution rows whose pivot ``|R_ii|`` falls below
    ``rcond * max|diag|`` are zeroed rather than divided through — the
    triangular-solve analogue of a truncated pseudoinverse.  The paper's
    Eq. 7 applies ``pinv(Y_s) = R_Y^{-1} Q_Y^T`` unregularized; when the
    EMA sketch spectrum decays fast the trailing pivots underflow and the
    substitution chain amplifies the reconstruction by 1000x (observed at
    r >= 8 on decaying-spectrum activations).  Applied identically in the
    rust substrate (DESIGN.md §7).
    """
    n = r.shape[0]
    diag_mag = jnp.abs(jnp.diagonal(r))
    floor = rcond * jnp.max(diag_mag)

    def body(i, x):
        row = n - 1 - i
        r_row = lax.dynamic_slice_in_dim(r, row, 1, axis=0)  # 1 x n
        # sum_{j>row} r[row, j] x[j, :] — columns <= row of x are still the
        # unsolved zeros, so a full product plus the not-yet-written rows of
        # x works with a mask on r_row instead of dynamic slicing.
        mask = (jnp.arange(n) > row).astype(r.dtype)[None, :]
        acc = (r_row * mask) @ x  # 1 x p
        diag = r_row[0, row]
        ok = jnp.abs(diag) >= floor
        safe_diag = jnp.where(ok, diag, 1.0)
        xi = (lax.dynamic_slice_in_dim(b, row, 1, axis=0) - acc) / safe_diag
        xi = jnp.where(ok, xi, 0.0)  # truncate unstable directions
        return lax.dynamic_update_slice_in_dim(x, xi, row, axis=0)

    x0 = jnp.zeros_like(b)
    return lax.fori_loop(0, n, body, x0)


def solve_lower_triangular(
    l: jnp.ndarray, b: jnp.ndarray, rcond: float = 1e-4
) -> jnp.ndarray:
    """Solve ``l x = b`` for lower-triangular ``l`` by forward
    substitution, with the same truncated-pivot policy as the upper
    solver."""
    n = l.shape[0]
    diag_mag = jnp.abs(jnp.diagonal(l))
    floor = rcond * jnp.max(diag_mag)

    def body(row, x):
        l_row = lax.dynamic_slice_in_dim(l, row, 1, axis=0)  # 1 x n
        mask = (jnp.arange(n) < row).astype(l.dtype)[None, :]
        acc = (l_row * mask) @ x
        diag = l_row[0, row]
        ok = jnp.abs(diag) >= floor
        safe_diag = jnp.where(ok, diag, 1.0)
        xi = (lax.dynamic_slice_in_dim(b, row, 1, axis=0) - acc) / safe_diag
        xi = jnp.where(ok, xi, 0.0)
        return lax.dynamic_update_slice_in_dim(x, xi, row, axis=0)

    x0 = jnp.zeros_like(b)
    return lax.fori_loop(0, n, body, x0)


def pinv_tall_via_qr(a: jnp.ndarray) -> jnp.ndarray:
    """Moore-Penrose pseudoinverse of a tall full-column-rank matrix
    ``a`` (m x n): ``a^+ = R^{-1} Q^T`` from the economy QR.
    Returns an n x m matrix.
    """
    q, r = mgs_qr(a)
    return solve_upper_triangular(r, q.T)


def spectral_norm(a: jnp.ndarray, iters: int = 24) -> jnp.ndarray:
    """Largest singular value of ``a`` by power iteration on ``a^T a``.

    Deterministic start vector (normalized ones + index ramp) keeps the
    artifact RNG-free; ``iters`` is fixed so the loop unrolls to a While
    with static trip count.
    """
    n = a.shape[1]
    v0 = jnp.ones((n,), a.dtype) + 0.01 * jnp.arange(n, dtype=a.dtype)
    v0 = v0 / jnp.sqrt(jnp.sum(v0 * v0))

    def body(_, v):
        w = a.T @ (a @ v)
        return w / jnp.sqrt(jnp.sum(w * w) + _EPS)

    v = lax.fori_loop(0, iters, body, v0)
    av = a @ v
    return jnp.sqrt(jnp.sum(av * av) + _EPS)


def stable_rank(a: jnp.ndarray, iters: int = 24) -> jnp.ndarray:
    """Stable rank ``||a||_F^2 / ||a||_2^2`` (paper §4.6), the sketch-based
    gradient-diversity metric computed from Y-sketches.
    """
    fro2 = jnp.sum(a * a)
    spec = spectral_norm(a, iters)
    return fro2 / (spec * spec + _EPS)
