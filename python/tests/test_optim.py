"""Adam/SGD in-graph optimizers vs reference implementations."""

import numpy as np
import jax.numpy as jnp

from compile import optim


def _pairs(rng, shapes):
    return [
        (
            jnp.asarray(rng.standard_normal(s), jnp.float32),
            jnp.asarray(rng.standard_normal(s[0]), jnp.float32),
        )
        for s in shapes
    ]


def test_adam_matches_reference():
    rng = np.random.default_rng(0)
    shapes = [(4, 3), (2, 4)]
    params = _pairs(rng, shapes)
    grads = _pairs(rng, shapes)
    m = [(jnp.zeros_like(w), jnp.zeros_like(b)) for w, b in params]
    v = [(jnp.zeros_like(w), jnp.zeros_like(b)) for w, b in params]
    t = jnp.asarray(0.0, jnp.float32)
    lr, b1, b2, eps = 1e-3, 0.9, 0.999, 1e-8

    new_p, new_m, new_v, new_t = optim.adam_update(params, grads, m, v, t, lr)
    assert float(new_t) == 1.0

    # Reference (numpy, step 1).
    for (w, _), (gw, _), (nw, _), (nmw, _), (nvw, _) in zip(
        params, grads, new_p, new_m, new_v
    ):
        mw = (1 - b1) * np.asarray(gw)
        vw = (1 - b2) * np.asarray(gw) ** 2
        bc1 = 1 - b1**1
        bc2 = 1 - b2**1
        want = np.asarray(w) - lr * (mw / bc1) / (np.sqrt(vw / bc2) + eps)
        np.testing.assert_allclose(np.asarray(nw), want, atol=1e-6)
        np.testing.assert_allclose(np.asarray(nmw), mw, atol=1e-7)
        np.testing.assert_allclose(np.asarray(nvw), vw, atol=1e-7)


def test_adam_two_steps_bias_correction():
    rng = np.random.default_rng(1)
    shapes = [(3, 3)]
    params = _pairs(rng, shapes)
    grads = _pairs(rng, shapes)
    m = [(jnp.zeros((3, 3)), jnp.zeros(3))]
    v = [(jnp.zeros((3, 3)), jnp.zeros(3))]
    t = jnp.asarray(0.0, jnp.float32)
    p1, m1, v1, t1 = optim.adam_update(params, grads, m, v, t, 1e-2)
    p2, _, _, t2 = optim.adam_update(p1, grads, m1, v1, t1, 1e-2)
    assert float(t2) == 2.0
    # Constant gradient: the update keeps moving in the same direction.
    d1 = np.asarray(p1[0][0]) - np.asarray(params[0][0])
    d2 = np.asarray(p2[0][0]) - np.asarray(p1[0][0])
    assert np.sign(d1).tolist() == np.sign(d2).tolist()


def test_sgd_formula():
    rng = np.random.default_rng(2)
    params = _pairs(rng, [(4, 2)])
    grads = _pairs(rng, [(4, 2)])
    out = optim.sgd_update(params, grads, 0.1)
    np.testing.assert_allclose(
        np.asarray(out[0][0]),
        np.asarray(params[0][0]) - 0.1 * np.asarray(grads[0][0]),
        atol=1e-7,
    )
