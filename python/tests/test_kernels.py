"""L1 correctness: Pallas kernels vs the pure-jnp oracles in ref.py.

Hypothesis sweeps shapes and values; interpret=True makes the kernels run
on CPU so allclose against the oracle is the ground-truth signal.
"""

import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

from compile.kernels import ref
from compile.kernels.ema_update import ema_sketch_update, pick_block_d
from compile.kernels.grad_outer import grad_outer


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


@pytest.mark.parametrize("n_b,d,k", [(8, 16, 5), (128, 512, 5), (128, 512, 33), (64, 50, 9), (16, 1024, 9)])
@pytest.mark.parametrize("beta", [0.0, 0.9, 0.95])
def test_ema_update_matches_ref(n_b, d, k, beta):
    rng = np.random.default_rng(0)
    a, p, s = _rand(rng, n_b, d), _rand(rng, n_b, k), _rand(rng, d, k)
    out = ema_sketch_update(a, p, s, beta)
    want = ref.ema_sketch_update_ref(a, p, s, beta)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n_b,d,k", [(8, 16, 5), (128, 512, 9)])
def test_ema_update_with_col_scale(n_b, d, k):
    rng = np.random.default_rng(1)
    a, p, s = _rand(rng, n_b, d), _rand(rng, n_b, k), _rand(rng, d, k)
    scale = _rand(rng, k)
    out = ema_sketch_update(a, p, s, 0.9, scale)
    want = ref.ema_sketch_update_ref(a, p, s, 0.9, scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize(
    "n_b,d_out,d_in",
    [(8, 16, 16), (128, 512, 512), (128, 10, 512), (128, 512, 784), (64, 50, 50)],
)
def test_grad_outer_matches_ref(n_b, d_out, d_in):
    rng = np.random.default_rng(2)
    delta, a = _rand(rng, n_b, d_out), _rand(rng, n_b, d_in)
    out = grad_outer(delta, a)
    want = ref.grad_outer_ref(delta, a)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_pick_block_d_divides_and_fits():
    for d in [50, 512, 784, 1024]:
        b = pick_block_d(d, 128, 33)
        assert d % b == 0
        assert b * 128 + 128 * 33 + 2 * b * 33 <= (1 << 21) or b == d


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(
        n_b=st.sampled_from([4, 16, 64]),
        d=st.sampled_from([8, 32, 50, 128]),
        r=st.integers(min_value=1, max_value=8),
        beta=st.floats(min_value=0.0, max_value=0.99),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_ema_update_hypothesis(n_b, d, r, beta, seed):
        k = 2 * r + 1
        rng = np.random.default_rng(seed)
        a, p, s = _rand(rng, n_b, d), _rand(rng, n_b, k), _rand(rng, d, k)
        out = ema_sketch_update(a, p, s, float(beta))
        want = ref.ema_sketch_update_ref(a, p, s, float(beta))
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(want), rtol=1e-4, atol=1e-4
        )

    @settings(max_examples=25, deadline=None)
    @given(
        n_b=st.sampled_from([4, 16, 128]),
        d_out=st.sampled_from([8, 10, 64, 512]),
        d_in=st.sampled_from([8, 50, 512]),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_grad_outer_hypothesis(n_b, d_out, d_in, seed):
        rng = np.random.default_rng(seed)
        delta, a = _rand(rng, n_b, d_out), _rand(rng, n_b, d_in)
        np.testing.assert_allclose(
            np.asarray(grad_outer(delta, a)),
            np.asarray(ref.grad_outer_ref(delta, a)),
            rtol=1e-4,
            atol=1e-4,
        )
