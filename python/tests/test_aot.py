"""AOT contract tests: registry integrity, spec/function consistency, and
manifest round-trips — what the rust runtime depends on."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import train_step as TS


def test_registry_names_cover_experiment_index():
    reg = aot._registry()
    names = set(reg)
    # Every figure's artifacts exist.
    for required in [
        "mnist_std_chunk",
        "mnist_sk_r2_chunk",
        "mnist_sk_r16_chunk",
        "mnist_std_step",
        "mnist_sk_r2_step",
        "cifar_std_chunk",
        "cifar_sk_r2_chunk",
        "monitor16_mon_r4_chunk",
        "monitor16_problematic_chunk",
        "pinn_std_chunk",
        "pinn_mon_r2_chunk",
        "pinn_eval",
        "recon_eval_r2",
    ]:
        assert required in names, required


@pytest.mark.parametrize(
    "name", ["mnist_std_step", "mnist_sk_r2_step", "recon_eval_r4", "pinn_eval"]
)
def test_spec_shapes_match_function(name):
    # Building + abstract-evaluating each registered artifact must produce
    # outputs matching the declared output specs exactly.
    reg = aot._registry()
    fn, ins, outs, _meta = reg[name]()
    specs = [
        jax.ShapeDtypeStruct(tuple(s.shape), jnp.float32 if s.dtype == "f32" else jnp.int32)
        for s in ins
    ]
    out_shapes = jax.eval_shape(fn, *specs)
    assert len(out_shapes) == len(outs)
    for got, spec in zip(out_shapes, outs):
        assert tuple(got.shape) == tuple(spec.shape), spec.name
        want_dtype = jnp.float32 if spec.dtype == "f32" else jnp.int32
        assert got.dtype == want_dtype, spec.name


def test_state_round_trip_naming():
    # Every out_<name> output must correspond to an input <name> with the
    # same shape — the rust StateStore round-trip contract.
    reg = aot._registry()
    for name in ["mnist_sk_r2_chunk", "monitor16_mon_r4_chunk", "pinn_mon_r2_chunk"]:
        _fn, ins, outs, _ = reg[name]()
        in_map = {s.name: s for s in ins}
        for o in outs:
            if o.name.startswith("out_"):
                src = o.name[4:]
                assert src in in_map, f"{name}: {o.name} has no input twin"
                assert tuple(in_map[src].shape) == tuple(o.shape), o.name


def test_manifest_file_is_consistent():
    path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    man = json.load(open(path))
    assert man["n_b"] == 128
    assert man["rank_ladder"] == [2, 4, 8, 16]
    for name, entry in man["artifacts"].items():
        hlo = os.path.join(os.path.dirname(path), entry["file"])
        assert os.path.exists(hlo), name
        assert entry["inputs"], name
        assert entry["outputs"], name


def test_chunk_and_step_variants_agree_on_one_step():
    # A chunk artifact with K=1 must equal the single-step artifact.
    import compile.model as M

    spec = M.MLPSpec(dims=(10, 8, 8, 4), activation="tanh")
    base = dict(spec=spec, variant="sketched", optimizer="adam", n_b=8, r=1,
                beta=0.9, power_iters=4)
    f_step, ins_s, outs_s = TS.build(TS.StepConfig(chunk=0, **base))
    f_chunk, ins_c, outs_c = TS.build(TS.StepConfig(chunk=1, **base))

    rng = np.random.default_rng(3)
    args_s, args_c = [], []
    for s_spec, c_spec in zip(ins_s, ins_c):
        if s_spec.dtype == "i32":
            v = rng.integers(0, 4, s_spec.shape).astype(np.int32)
            args_s.append(jnp.asarray(v))
            args_c.append(jnp.asarray(v.reshape(c_spec.shape)))
        else:
            v = (rng.standard_normal(s_spec.shape) * 0.1).astype(np.float32)
            args_s.append(jnp.asarray(v))
            args_c.append(jnp.asarray(v.reshape(c_spec.shape)))
    out_s = jax.jit(f_step)(*args_s)
    out_c = jax.jit(f_chunk)(*args_c)
    for spec_s, a, b in zip(outs_s, out_s, out_c):
        np.testing.assert_allclose(
            np.asarray(a).ravel(), np.asarray(b).ravel(), atol=2e-5,
            err_msg=spec_s.name,
        )
