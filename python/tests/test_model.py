"""The manual backward passes (model.mlp_backward, cnn.cnn_backward) must
agree exactly with jax autodiff when no sketching substitution is made —
the correctness foundation that makes the Eq.-8 swap auditable."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import cnn as C
from compile import model as M


def make_params(rng, dims):
    return [
        (
            jnp.asarray(rng.standard_normal((dims[i + 1], dims[i])) * 0.2, jnp.float32),
            jnp.asarray(rng.standard_normal(dims[i + 1]) * 0.05, jnp.float32),
        )
        for i in range(len(dims) - 1)
    ]


@pytest.mark.parametrize("activation", ["tanh", "relu"])
@pytest.mark.parametrize("dims", [(12, 8, 8, 5), (20, 16, 16, 16, 3)])
def test_manual_backward_matches_autodiff(activation, dims):
    rng = np.random.default_rng(0)
    spec = M.MLPSpec(dims=dims, activation=activation)
    params = make_params(rng, dims)
    x = jnp.asarray(rng.standard_normal((16, dims[0])), jnp.float32)
    y = jnp.asarray(rng.integers(0, dims[-1], 16), jnp.int32)

    # Manual path.
    logits, acts = M.mlp_forward(params, x, spec)
    loss, delta, _acc = M.softmax_xent(logits, y)
    manual = M.mlp_backward(params, acts, delta, spec, use_pallas=False)

    # Autodiff reference.
    def loss_fn(flat):
        ps = [(flat[2 * i], flat[2 * i + 1]) for i in range(len(flat) // 2)]
        lg, _ = M.mlp_forward(ps, x, spec)
        ls, _, _ = M.softmax_xent(lg, y)
        return ls

    flat = [t for wb in params for t in wb]
    auto = jax.grad(loss_fn)(flat)
    for i, (gw, gb) in enumerate(manual):
        np.testing.assert_allclose(
            np.asarray(gw), np.asarray(auto[2 * i]), atol=2e-5,
            err_msg=f"w{i}"
        )
        np.testing.assert_allclose(
            np.asarray(gb), np.asarray(auto[2 * i + 1]), atol=2e-5,
            err_msg=f"b{i}"
        )


def test_cnn_backward_matches_autodiff():
    rng = np.random.default_rng(1)
    spec = C.CNNSpec(in_hw=8, channels=(3, 4, 6), fc_dims=(24, 16, 16, 16, 5))
    conv_params = [
        (
            jnp.asarray(rng.standard_normal((4, 3, 3, 3)) * 0.2, jnp.float32),
            jnp.zeros(4, jnp.float32),
        ),
        (
            jnp.asarray(rng.standard_normal((6, 4, 3, 3)) * 0.2, jnp.float32),
            jnp.zeros(6, jnp.float32),
        ),
    ]
    fc_params = make_params(rng, spec.fc_dims)
    x = jnp.asarray(rng.standard_normal((8, 3, 8, 8)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 5, 8), jnp.int32)

    logits, feats, fc_acts = C.cnn_forward(conv_params, fc_params, x, spec)
    loss, delta, _ = M.softmax_xent(logits, y)
    conv_g, fc_g = C.cnn_backward(
        conv_params, fc_params, x, feats, fc_acts, delta, spec
    )

    def loss_fn(cp, fp):
        lg, _, _ = C.cnn_forward(cp, fp, x, spec)
        ls, _, _ = M.softmax_xent(lg, y)
        return ls

    auto_c, auto_f = jax.grad(loss_fn, argnums=(0, 1))(
        [list(p) for p in conv_params], [list(p) for p in fc_params]
    )
    for i, (gk, gb) in enumerate(conv_g):
        np.testing.assert_allclose(
            np.asarray(gk), np.asarray(auto_c[i][0]), atol=3e-5
        )
        np.testing.assert_allclose(
            np.asarray(gb), np.asarray(auto_c[i][1]), atol=3e-5
        )
    for i, (gw, gb) in enumerate(fc_g):
        np.testing.assert_allclose(
            np.asarray(gw), np.asarray(auto_f[i][0]), atol=3e-5
        )
        np.testing.assert_allclose(
            np.asarray(gb), np.asarray(auto_f[i][1]), atol=3e-5
        )


def test_softmax_xent_properties():
    rng = np.random.default_rng(2)
    logits = jnp.asarray(rng.standard_normal((32, 10)) * 3, jnp.float32)
    y = jnp.asarray(rng.integers(0, 10, 32), jnp.int32)
    loss, delta, acc = M.softmax_xent(logits, y)
    assert float(loss) > 0
    assert 0.0 <= float(acc) <= 1.0
    # delta rows sum to zero (softmax - onehot) / n.
    np.testing.assert_allclose(
        np.asarray(delta).sum(axis=1), 0.0, atol=1e-6
    )
    # Shift invariance of the loss.
    loss2, _, _ = M.softmax_xent(logits + 100.0, y)
    assert abs(float(loss) - float(loss2)) < 1e-4


def test_activation_grad_from_value():
    a = jnp.asarray([[-0.5, 0.0, 0.9]], jnp.float32)
    g_tanh = M.activate_grad_from_value(a, "tanh")
    np.testing.assert_allclose(np.asarray(g_tanh), 1 - np.asarray(a) ** 2)
    g_relu = M.activate_grad_from_value(a, "relu")
    np.testing.assert_allclose(np.asarray(g_relu), [[0.0, 0.0, 1.0]])
