"""L2 sketching framework: EMA updates (Eqs. 5a-5c), reconstruction
(Eqs. 6-7, fused == unfused), Lemma 4.1's expansion, Thm 4.2's bound
behaviour, and the monitoring metrics."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import sketching
from compile.kernels.ref import ema_sketch_update_ref


def make_proj(rng, n_b, lh, r):
    k, _ = sketching.rank_dims(r)
    return sketching.Projections(
        upsilon=jnp.asarray(rng.standard_normal((n_b, k)), jnp.float32),
        omega=jnp.asarray(rng.standard_normal((n_b, k)), jnp.float32),
        phi=jnp.asarray(rng.standard_normal((n_b, k)), jnp.float32),
        psi=jnp.asarray(rng.standard_normal((lh, k)), jnp.float32),
    )


def zero_state(lh, d, r):
    k, s = sketching.rank_dims(r)
    return sketching.SketchState(
        x=jnp.zeros((lh, d, k)), y=jnp.zeros((lh, d, k)), z=jnp.zeros((lh, d, s))
    )


def test_rank_dims():
    assert sketching.rank_dims(2) == (5, 5)
    assert sketching.rank_dims(16) == (33, 33)


def test_lemma_4_1_ema_expansion():
    # X_n must equal (1-b) sum_j b^{n-j} A_j^T Upsilon exactly.
    rng = np.random.default_rng(0)
    n_b, d, r, beta = 8, 12, 2, 0.8
    proj = make_proj(rng, n_b, 1, r)
    state = zero_state(1, d, r)
    batches = [
        jnp.asarray(rng.standard_normal((n_b, d)), jnp.float32) for _ in range(5)
    ]
    for a in batches:
        state = sketching.update_layer_sketches(state, proj, 0, a, a, beta)
    n = len(batches)
    want = sum(
        (1 - beta) * beta ** (n - 1 - j) * (a.T @ proj.upsilon)
        for j, a in enumerate(batches)
    )
    np.testing.assert_allclose(np.asarray(state.x[0]), np.asarray(want), atol=1e-4)


def test_update_matches_ref_oracle():
    rng = np.random.default_rng(1)
    n_b, d, r = 16, 32, 2
    proj = make_proj(rng, n_b, 1, r)
    state = zero_state(1, d, r)
    a = jnp.asarray(rng.standard_normal((n_b, d)), jnp.float32)
    state = sketching.update_layer_sketches(state, proj, 0, a, a, 0.9)
    want_x = ema_sketch_update_ref(a, proj.upsilon, jnp.zeros((d, 5)), 0.9)
    np.testing.assert_allclose(np.asarray(state.x[0]), np.asarray(want_x), atol=1e-5)
    want_z = ema_sketch_update_ref(a, proj.phi, jnp.zeros((d, 5)), 0.9, proj.psi[0])
    np.testing.assert_allclose(np.asarray(state.z[0]), np.asarray(want_z), atol=1e-5)


def test_fused_reconstruction_equals_unfused():
    rng = np.random.default_rng(2)
    n_b, d, r = 16, 24, 3
    proj = make_proj(rng, n_b, 1, r)
    state = zero_state(1, d, r)
    a = jnp.asarray(rng.standard_normal((n_b, d)), jnp.float32)
    state = sketching.update_layer_sketches(state, proj, 0, a, a, 0.0)
    fused = sketching.reconstruct_batch_activations(
        state.x[0], state.y[0], state.z[0], proj.omega
    )
    unfused = sketching.reconstruct_batch_activations_unfused(
        state.x[0], state.y[0], state.z[0], proj.omega
    )
    np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused), atol=1e-3)


def test_reconstruction_error_bounded_across_ranks():
    # Thm 4.2 behaviour on a decaying spectrum: with the rcond-clamped
    # solve (DESIGN.md §7) the reconstruction must stay BOUNDED at every
    # rank (the unregularized paper pipeline blows up by 1000x at r >= 8
    # because trailing R_Y diagonals underflow) and the moderate-rank
    # error must not exceed the rank-1 error by more than ~2x.
    rng = np.random.default_rng(3)
    n_b, d = 32, 64
    u, s, vt = np.linalg.svd(rng.standard_normal((n_b, d)), full_matrices=False)
    decay = np.exp(-0.4 * np.arange(len(s)))
    a = (u * (s * decay)) @ vt
    a = jnp.asarray(a, jnp.float32)
    a_norm = float(jnp.linalg.norm(a))
    errs = []
    for r in [1, 3, 6, 10]:
        proj = make_proj(rng, n_b, 1, r)
        state = zero_state(1, d, r)
        state = sketching.update_layer_sketches(state, proj, 0, a, a, 0.0)
        at = sketching.reconstruct_batch_activations(
            state.x[0], state.y[0], state.z[0], proj.omega
        )
        errs.append(float(jnp.linalg.norm(at - a)))
    # No blow-up: every error bounded by a small multiple of ||A||.
    assert all(e < 5.0 * a_norm for e in errs), errs
    assert errs[2] < 4.0 * errs[0], errs


def test_monitor_metrics_shapes_and_sanity():
    rng = np.random.default_rng(4)
    n_b, d, r, lh = 16, 32, 4, 3
    proj = make_proj(rng, n_b, lh, r)
    state = zero_state(lh, d, r)
    acts = [jnp.asarray(rng.standard_normal((n_b, d)), jnp.float32) for _ in range(lh + 1)]
    for j in range(1, lh + 1):
        a_in = acts[j - 1] if j >= 2 else acts[1]
        state = sketching.update_layer_sketches(state, proj, j - 1, a_in, acts[j], 0.5)
    zn, sr, yn, xn = sketching.monitor_metrics(state, power_iters=24)
    for v in (zn, sr, yn, xn):
        assert v.shape == (lh,)
        assert np.isfinite(np.asarray(v)).all()
    k = 2 * r + 1
    # Stable rank of the Y-sketch is in (1, k]; the DISCRIMINATIVE property
    # (healthy >> collapsed, paper Fig. 5) is asserted below by comparing
    # against a rank-1 collapsed activation pattern.
    assert 1.0 < float(sr.min()) <= k + 1e-3, np.asarray(sr)
    collapsed = zero_state(1, d, r)
    one_dir = jnp.asarray(
        np.outer(rng.standard_normal(n_b), rng.standard_normal(d)), jnp.float32
    )
    collapsed = sketching.update_layer_sketches(
        collapsed, proj, 0, one_dir, one_dir, 0.5
    )
    _, sr_c, _, _ = sketching.monitor_metrics(collapsed, power_iters=24)
    assert float(sr_c[0]) < 1.2, np.asarray(sr_c)
    assert float(sr.min()) > 1.5 * float(sr_c[0])


def test_gema_shape():
    rng = np.random.default_rng(5)
    n_b, d, r = 8, 16, 2
    proj = make_proj(rng, n_b, 1, r)
    state = zero_state(1, d, r)
    a = jnp.asarray(rng.standard_normal((n_b, d)), jnp.float32)
    state = sketching.update_layer_sketches(state, proj, 0, a, a, 0.0)
    g = sketching.reconstruct_gema(state.x[0], state.y[0], state.z[0])
    assert g.shape == (d, d)


def test_zero_sketch_reconstruction_is_finite():
    rng = np.random.default_rng(6)
    proj = make_proj(rng, 8, 1, 2)
    state = zero_state(1, 16, 2)
    at = sketching.reconstruct_batch_activations(
        state.x[0], state.y[0], state.z[0], proj.omega
    )
    assert np.isfinite(np.asarray(at)).all()


def test_lsq_reconstruction_stable_and_accurate():
    # The train-path LSQ reconstruction: non-expansive and at least as
    # accurate as the Eq. 6-7 pipeline on decaying-spectrum activations
    # (the regime where Eq. 6-7 diverges; EXPERIMENTS.md §Stability).
    rng = np.random.default_rng(8)
    n_b, d, r = 64, 48, 3
    u = rng.standard_normal((n_b, 4)).astype(np.float32)
    v = rng.standard_normal((4, d)).astype(np.float32)
    a = jnp.asarray(u @ v + 0.02 * rng.standard_normal((n_b, d)), jnp.float32)
    proj = make_proj(rng, n_b, 1, r)
    state = zero_state(1, d, r)
    state = sketching.update_layer_sketches(state, proj, 0, a, a, 0.0)
    lsq = sketching.reconstruct_batch_activations_lsq(state, proj, 0)
    eq7 = sketching.reconstruct_batch_activations(
        state.x[0], state.y[0], state.z[0], proj.omega
    )
    a_norm = float(jnp.linalg.norm(a))
    assert float(jnp.linalg.norm(lsq)) < 1.05 * a_norm  # non-expansive
    err_lsq = float(jnp.linalg.norm(lsq - a))
    err_eq7 = float(jnp.linalg.norm(eq7 - a))
    assert err_lsq <= err_eq7 * 1.05, (err_lsq, err_eq7)
    # Signal capture: the projection retains a meaningful fraction of the
    # energy.  The min-norm estimate projects the batch side onto the
    # 3k-dim span of the random projections, so the retained fraction is
    # O(sqrt(3k/n_b)) — assert error strictly below ||A|| with margin.
    assert err_lsq < 0.92 * a_norm, (err_lsq, a_norm)


def test_solve_lower_triangular():
    from compile import linalg
    rng = np.random.default_rng(9)
    lt = np.tril(rng.standard_normal((7, 7)).astype(np.float32)) + 3 * np.eye(
        7, dtype=np.float32
    )
    b = rng.standard_normal((7, 3)).astype(np.float32)
    x = np.asarray(linalg.solve_lower_triangular(jnp.asarray(lt), jnp.asarray(b)))
    np.testing.assert_allclose(lt @ x, b, atol=1e-4)
