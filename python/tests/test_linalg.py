"""Pure-jnp linear algebra vs numpy/LAPACK ground truth.

These routines replace the LAPACK custom-calls banned from the AOT path
(DESIGN.md §7); correctness here is what makes the in-graph reconstruction
trustworthy.
"""

import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

from compile import linalg


@pytest.mark.parametrize("m,n", [(8, 3), (64, 9), (512, 33), (50, 5)])
def test_mgs_qr(m, n):
    rng = np.random.default_rng(0)
    a = rng.standard_normal((m, n)).astype(np.float32)
    q, r = linalg.mgs_qr(jnp.asarray(a))
    q, r = np.asarray(q), np.asarray(r)
    np.testing.assert_allclose(q @ r, a, atol=5e-5)
    np.testing.assert_allclose(q.T @ q, np.eye(n), atol=5e-5)
    assert np.allclose(r, np.triu(r))


@pytest.mark.parametrize("k,d", [(5, 64), (9, 512), (33, 128)])
def test_householder_wide_q(k, d):
    rng = np.random.default_rng(1)
    a = rng.standard_normal((k, d)).astype(np.float32)
    p = np.asarray(linalg.householder_qr_wide(jnp.asarray(a)))
    np.testing.assert_allclose(p.T @ p, np.eye(k), atol=5e-5)
    # Compare with numpy's QR up to per-column sign.
    qn, _ = np.linalg.qr(a)
    sgn = np.sign(np.sum(p * qn, axis=0))
    sgn[sgn == 0] = 1.0
    np.testing.assert_allclose(p * sgn[None, :], qn, atol=5e-4)


@pytest.mark.parametrize("n,p", [(5, 3), (9, 9), (33, 1)])
def test_solve_upper_triangular(n, p):
    rng = np.random.default_rng(2)
    r = np.triu(rng.standard_normal((n, n)).astype(np.float32)) + 2 * np.eye(
        n, dtype=np.float32
    )
    b = rng.standard_normal((n, p)).astype(np.float32)
    x = np.asarray(linalg.solve_upper_triangular(jnp.asarray(r), jnp.asarray(b)))
    np.testing.assert_allclose(r @ x, b, atol=1e-4)


@pytest.mark.parametrize("m,n", [(64, 5), (128, 9)])
def test_pinv_tall(m, n):
    rng = np.random.default_rng(3)
    a = rng.standard_normal((m, n)).astype(np.float32)
    pinv = np.asarray(linalg.pinv_tall_via_qr(jnp.asarray(a)))
    np.testing.assert_allclose(pinv, np.linalg.pinv(a), atol=1e-4)


def test_spectral_norm_and_stable_rank():
    rng = np.random.default_rng(4)
    a = rng.standard_normal((64, 9)).astype(np.float32)
    sv = np.linalg.svd(a, compute_uv=False)
    spec = float(linalg.spectral_norm(jnp.asarray(a), 48))
    assert abs(spec - sv[0]) / sv[0] < 1e-3
    sr = float(linalg.stable_rank(jnp.asarray(a), 48))
    want = float((sv**2).sum() / sv[0] ** 2)
    assert abs(sr - want) / want < 1e-3


def test_zero_matrix_is_total():
    # All routines must stay finite on degenerate input (EPS floors).
    z = jnp.zeros((16, 5), jnp.float32)
    q, r = linalg.mgs_qr(z)
    assert np.isfinite(np.asarray(q)).all()
    assert np.isfinite(np.asarray(linalg.pinv_tall_via_qr(z))).all()
    assert np.isfinite(float(linalg.spectral_norm(z)))


if HAVE_HYPOTHESIS:

    @settings(max_examples=30, deadline=None)
    @given(
        m=st.integers(min_value=4, max_value=80),
        n=st.integers(min_value=1, max_value=12),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_mgs_qr_hypothesis(m, n, seed):
        if n > m:
            n = m
        rng = np.random.default_rng(seed)
        a = rng.standard_normal((m, n)).astype(np.float32)
        q, r = linalg.mgs_qr(jnp.asarray(a))
        np.testing.assert_allclose(np.asarray(q) @ np.asarray(r), a, atol=1e-4)
