//! Fault-injection integration (DESIGN.md §11): failpoints armed
//! through the daemon's shared registry must produce *contained*,
//! typed, observable failures — an injected handler panic costs
//! exactly one request, an injected snapshot failure is counted and
//! surfaced as `Error::Internal`, and a daemon killed without its
//! final snapshot resumes exactly-once through the client's replay
//! ring.

use sketchgrad::config::{ArchiveConfig, ObsConfig, ServeConfig};
use sketchgrad::data::ActStream;
use sketchgrad::serve::obs::events::kind;
use sketchgrad::serve::proto::SessionSpec;
use sketchgrad::serve::{Daemon, Error, SketchClient};

fn test_config(tag: &str) -> ServeConfig {
    ServeConfig {
        addr: "127.0.0.1:0".into(),
        max_sessions: 8,
        snapshot_interval_secs: 0,
        session_quota_bytes: 0,
        snapshot_path: std::env::temp_dir()
            .join(format!("sketchd-fi-{tag}-{}.snap", std::process::id()))
            .to_string_lossy()
            .into_owned(),
        threads: 1,
        shards: 1,
        archive: ArchiveConfig::default(),
        obs: ObsConfig::default(),
        fault: String::new(),
    }
}

fn spec(name: &str) -> SessionSpec {
    SessionSpec {
        name: name.into(),
        layer_dims: vec![16, 8],
        rank: 3,
        beta: 0.9,
        seed: 7,
        window: 8,
        collapse_frac: 0.25,
    }
}

/// An injected handler panic is caught at the shard's isolation
/// boundary: the panicking request gets a typed `Internal` reply, the
/// *same connection* keeps working, the daemon counts the panic, and
/// the journal records it.
#[test]
fn handler_panic_costs_exactly_one_request() {
    let cfg = test_config("panic");
    let snap = cfg.snapshot_path.clone();
    let daemon = Daemon::bind(cfg).unwrap();
    let addr = daemon.local_addr().unwrap().to_string();
    let handle = daemon.spawn().unwrap();
    let (mut client, _info) = SketchClient::connect(&addr).unwrap();

    handle.faults().arm("handler=panic@oneshot").unwrap();
    match client.metrics() {
        Err(Error::Internal(msg)) => {
            assert!(msg.contains("panicked"), "{msg}")
        }
        other => panic!("expected Internal from panic, got {other:?}"),
    }

    // Same connection, next request: the shard survived the panic and
    // the counter records it.
    let m = client.metrics().unwrap();
    assert_eq!(m.handler_panics, 1);
    let ev = client.events(128).unwrap();
    assert!(
        ev.events.iter().any(|e| e.kind == kind::HANDLER_PANIC),
        "journal must record the caught handler panic"
    );

    handle.stop().unwrap();
    let _ = std::fs::remove_file(&snap);
}

/// An injected failure in the snapshot rename step surfaces as a typed
/// `Internal` reply to the requesting client, bumps the
/// `snapshot_failures` counter, and — being a oneshot — the next
/// snapshot attempt succeeds.
#[test]
fn injected_snapshot_failure_is_typed_and_counted() {
    let cfg = test_config("snapfail");
    let snap = cfg.snapshot_path.clone();
    let _ = std::fs::remove_file(&snap);
    let daemon = Daemon::bind(cfg).unwrap();
    let addr = daemon.local_addr().unwrap().to_string();
    let handle = daemon.spawn().unwrap();
    let (mut client, _info) = SketchClient::connect(&addr).unwrap();
    let sess = client.open_session(&spec("snapfail")).unwrap();
    let id = sess.id();

    handle.faults().arm("snapshot.rename=err@oneshot").unwrap();
    match client.snapshot() {
        Err(Error::Internal(msg)) => {
            assert!(msg.contains("snapshot failed"), "{msg}")
        }
        other => panic!("expected Internal from snap fault, got {other:?}"),
    }
    let m = client.metrics().unwrap();
    assert_eq!(m.snapshot_failures, 1);

    // The oneshot disarmed itself: the retry lands a real snapshot.
    let (_, _, sessions) = client.snapshot().unwrap();
    assert_eq!(sessions, 1);
    client.session(id).close().unwrap();

    handle.stop().unwrap();
    let _ = std::fs::remove_file(&snap);
}

/// Kill the daemon (no final snapshot — a crash) after a durable
/// snapshot mid-run; the client's `ResumableSession` reconnects to the
/// restarted daemon and replays its ring.  The daemon re-acks the
/// already-applied prefix and applies only the lost tail: the final
/// ack shows exactly-once ingest across the crash.
#[test]
fn killed_daemon_resumes_exactly_once_via_replay() {
    let cfg = test_config("resume");
    let snap = cfg.snapshot_path.clone();
    let _ = std::fs::remove_file(&snap);
    let daemon = Daemon::bind(cfg.clone()).unwrap();
    let addr = daemon.local_addr().unwrap().to_string();
    let handle = daemon.spawn().unwrap();

    let (mut client, _info) = SketchClient::connect(&addr).unwrap();
    let sess = client.open_session(&spec("resume")).unwrap();
    assert_eq!(sess.epoch(), 1);
    let mut sess = sess.resumable(32).unwrap();
    let mut stream = ActStream::new(&[16, 8], false, 7);
    for _ in 0..4 {
        sess.ingest(0.1, &stream.next_batch(4), false).unwrap();
    }
    // Durability floor: seqs 1..=4 are snapshotted; everything after
    // exists only in the client's replay ring.
    sess.client().snapshot().unwrap();
    for _ in 0..3 {
        sess.ingest(0.2, &stream.next_batch(4), false).unwrap();
    }
    assert_eq!(sess.replays(), 0);

    handle.kill().unwrap();
    let mut cfg2 = cfg;
    cfg2.addr = addr.clone();
    let daemon2 = Daemon::bind(cfg2).unwrap();
    assert_eq!(daemon2.session_count(), 1);
    let handle2 = daemon2.spawn().unwrap();

    // The next ingest hits the dead socket, reconnects, and replays
    // the whole ring: the daemon re-acks 1..=4 and applies 5..=8.
    let mut last = sess.ingest(0.3, &stream.next_batch(4), false).unwrap();
    assert!(sess.replays() >= 1, "kill must force a replay recovery");
    assert_eq!(last.batches, 8);
    assert_eq!(last.acked_seq, 8);
    for _ in 0..4 {
        last = sess.ingest(0.4, &stream.next_batch(4), false).unwrap();
    }
    assert_eq!(last.batches, 12, "lost or duplicated ingests");
    assert_eq!(last.acked_seq, 12);
    sess.close().unwrap();

    handle2.stop().unwrap();
    let _ = std::fs::remove_file(&snap);
}

/// Quota backpressure must stay retryable under resumable sessions: a
/// `Busy` reply guarantees the daemon applied nothing and its acked
/// seq did not move, so the client rolls the frame back and reuses the
/// seq — the session must NOT wedge on a permanent "ingest seq gap"
/// after the first Busy (the daemon keeps expecting the rejected seq).
#[test]
fn busy_backpressure_does_not_wedge_resumable_sessions() {
    let mut cfg = test_config("busyresume");
    // Small enough that a paced run trips quota Busy every few steps,
    // large enough that a single drained frame always fits.
    cfg.session_quota_bytes = 8192;
    let snap = cfg.snapshot_path.clone();
    let daemon = Daemon::bind(cfg).unwrap();
    let addr = daemon.local_addr().unwrap().to_string();
    let handle = daemon.spawn().unwrap();

    let (mut client, _info) = SketchClient::connect(&addr).unwrap();
    let sess = client.open_session(&spec("busyresume")).unwrap();
    let mut sess = sess.resumable(64).unwrap();
    let mut stream = ActStream::new(&[16, 8], false, 7);

    let mut applied = 0u64;
    let mut busy_hits = 0u32;
    let mut last_batches = 0u64;
    for _ in 0..24 {
        let acts = stream.next_batch(4);
        let reply = match sess.ingest(0.1, &acts, false) {
            Ok(r) => r,
            Err(Error::Busy { .. }) => {
                busy_hits += 1;
                // The documented remedy: Diagnose drains the quota
                // counter; the retry reuses the rolled-back seq.
                sess.diagnose().unwrap();
                sess.ingest(0.1, &acts, false).unwrap()
            }
            Err(e) => panic!("resumable ingest failed: {e}"),
        };
        applied += 1;
        assert_eq!(
            reply.acked_seq, applied,
            "seq accounting drifted after {busy_hits} Busy rejections"
        );
        last_batches = reply.batches;
    }
    assert!(busy_hits >= 1, "quota never tripped Busy — test is vacuous");
    assert_eq!(last_batches, applied, "lost or duplicated ingests");
    sess.close().unwrap();

    handle.stop().unwrap();
    let _ = std::fs::remove_file(&snap);
}

/// An injected handler panic mid-run costs one typed `Internal` reply;
/// the rejected frame rolls back and the retried seq keeps the
/// exactly-once accounting exact (the panic fires before the engine
/// mutation, so the daemon applied nothing).
#[test]
fn injected_panic_keeps_resumable_accounting_exact() {
    let cfg = test_config("panicresume");
    let snap = cfg.snapshot_path.clone();
    let daemon = Daemon::bind(cfg).unwrap();
    let addr = daemon.local_addr().unwrap().to_string();
    let handle = daemon.spawn().unwrap();

    let (mut client, _info) = SketchClient::connect(&addr).unwrap();
    let sess = client.open_session(&spec("panicresume")).unwrap();
    let mut sess = sess.resumable(32).unwrap();
    let mut stream = ActStream::new(&[16, 8], false, 7);
    for _ in 0..3 {
        sess.ingest(0.1, &stream.next_batch(4), false).unwrap();
    }

    handle.faults().arm("handler=panic@oneshot").unwrap();
    let acts = stream.next_batch(4);
    match sess.ingest(0.2, &acts, false) {
        Err(Error::Internal(msg)) => {
            assert!(msg.contains("panicked"), "{msg}")
        }
        other => panic!("expected Internal from panic, got {other:?}"),
    }
    // Same step, same (rolled-back) seq: the retry must apply cleanly.
    let reply = sess.ingest(0.2, &acts, false).unwrap();
    assert_eq!(reply.acked_seq, 4);
    let mut last = reply;
    for _ in 0..2 {
        last = sess.ingest(0.3, &stream.next_batch(4), false).unwrap();
    }
    assert_eq!(last.acked_seq, 6);
    assert_eq!(last.batches, 6, "lost or duplicated ingests");
    assert_eq!(sess.client().metrics().unwrap().handler_panics, 1);
    sess.close().unwrap();

    handle.stop().unwrap();
    let _ = std::fs::remove_file(&snap);
}

/// A malformed `serve.fault` spec is rejected at bind time with a
/// diagnosable error naming the config key.
#[test]
fn invalid_fault_spec_fails_bind() {
    let mut cfg = test_config("badspec");
    cfg.fault = "handler@panic".into();
    let err = match Daemon::bind(cfg) {
        Err(e) => format!("{e:#}"),
        Ok(_) => panic!("bind accepted a malformed fault spec"),
    };
    assert!(err.contains("serve.fault"), "{err}");
}
