//! Monitor-service integration (the Fig-5 claim): metrics from real AOT
//! monitored training runs must separate healthy from problematic
//! configurations, and the baseline comparison must hold on measured bytes.

use sketchgrad::baselines::FullMonitor;
use sketchgrad::coordinator::Trainer;
use sketchgrad::data::{make_chunks, synth_mnist, Init};
use sketchgrad::memory::monitor16_dims;
use sketchgrad::monitor::{MonitorConfig, MonitorService};
use sketchgrad::runtime::Runtime;
use sketchgrad::sketch::Mat;
use sketchgrad::util::rng::Rng;
use std::path::PathBuf;

fn runtime() -> Option<Runtime> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Runtime::new(&dir).expect("runtime"))
}

fn run_monitor16(
    rt: &Runtime,
    artifact: &str,
    init: Init,
) -> Vec<sketchgrad::coordinator::StepMetrics> {
    let mut trainer = Trainer::new(rt, artifact, init, 42).unwrap();
    let data = synth_mnist(128 * 20, 42);
    let mut rng = Rng::new(7);
    let chunks = make_chunks(&data, 128, 20, &mut rng, &[784]);
    trainer.run_chunk(&chunks[0]).unwrap();
    trainer.history
}

#[test]
fn healthy_vs_problematic_metrics_separate() {
    let Some(rt) = runtime() else { return };
    let healthy = run_monitor16(&rt, "monitor16_mon_r4_chunk", Init::Kaiming);
    let problematic = run_monitor16(
        &rt,
        "monitor16_problematic_chunk",
        Init::KaimingNegBias(-3.0),
    );

    let mean = |ms: &[sketchgrad::coordinator::StepMetrics],
                f: fn(&sketchgrad::coordinator::StepMetrics) -> f32|
     -> f32 { ms.iter().map(f).sum::<f32>() / ms.len() as f32 };
    let z_h = mean(&healthy, |m| {
        m.z_norm.iter().sum::<f32>() / m.z_norm.len() as f32
    });
    let z_p = mean(&problematic, |m| {
        m.z_norm.iter().sum::<f32>() / m.z_norm.len() as f32
    });
    // Healthy gradients live; problematic ReLU units starved by the -3
    // bias produce near-zero activations/sketches (paper Fig. 5 shape).
    assert!(
        z_h > 10.0 * z_p,
        "||Z|| must separate: healthy {z_h} vs problematic {z_p}"
    );

    let sr_h = mean(&healthy, |m| {
        m.stable_rank.iter().sum::<f32>() / m.stable_rank.len() as f32
    });
    let sr_p = mean(&problematic, |m| {
        m.stable_rank.iter().sum::<f32>() / m.stable_rank.len() as f32
    });
    assert!(
        sr_h > 2.0 * sr_p,
        "stable rank must separate: healthy {sr_h} vs problematic {sr_p}"
    );

    // Loss separation: healthy decreasing, problematic flat at ~ln(10).
    let h_last = healthy.last().unwrap().loss;
    let p_last = problematic.last().unwrap().loss;
    assert!(h_last < 2.0, "healthy should be learning, loss {h_last}");
    assert!(
        (p_last - 2.3026).abs() < 0.05,
        "problematic should be stuck at ln(10), loss {p_last}"
    );
}

#[test]
fn monitor_service_flags_the_problematic_run_only() {
    let Some(rt) = runtime() else { return };
    let cfg = MonitorConfig {
        window: 5,
        ..MonitorConfig::for_rank(4)
    };
    let healthy = run_monitor16(&rt, "monitor16_mon_r4_chunk", Init::Kaiming);
    let problematic = run_monitor16(
        &rt,
        "monitor16_problematic_chunk",
        Init::KaimingNegBias(-3.0),
    );

    let diagnose = |history: &[sketchgrad::coordinator::StepMetrics]| {
        let mut svc = MonitorService::new(cfg.clone(), 15);
        for m in history {
            svc.observe(m);
        }
        (svc.diagnose(), svc.is_healthy())
    };
    let (d_h, ok_h) = diagnose(&healthy);
    let (d_p, ok_p) = diagnose(&problematic);
    assert!(ok_h, "healthy run flagged: {d_h:?}");
    assert!(!ok_p, "problematic run not flagged: {d_p:?}");
    assert!(d_p.diversity_collapse || d_p.stagnation, "{d_p:?}");
}

#[test]
fn measured_monitoring_memory_ratio() {
    // The Fig-5 memory claim on *measured* bytes: real full-gradient
    // checkpoints for the 16x1024 net over T=5 vs the monitor service.
    let dims = monitor16_dims();
    let mut rng = Rng::new(3);
    let mut full = FullMonitor::new(5);
    for step in 0..5 {
        let grads: Vec<Mat> = dims
            .windows(2)
            .map(|w| Mat::gaussian(w[1], w[0], &mut rng))
            .collect();
        full.record(step, grads);
    }
    let svc = MonitorService::new(MonitorConfig::for_rank(4), 15);
    // Sketch state (1.6 MB) + service summaries vs 295 MB of checkpoints.
    let sketch_state = {
        use sketchgrad::sketch::{SketchConfig, Sketcher};
        let mut engine = SketchConfig::builder()
            .uniform_dims(15, 1024)
            .rank(4)
            .beta(0.9)
            .seed(3)
            .build_engine()
            .unwrap();
        engine.ensure_projections(128);
        engine.memory()
    };
    let total_sketch = sketch_state + svc.monitor_bytes();
    let reduction = 1.0 - total_sketch as f64 / full.bytes() as f64;
    assert!(
        reduction > 0.99,
        "measured reduction {reduction} (sketch {total_sketch} vs full {})",
        full.bytes()
    );
}
