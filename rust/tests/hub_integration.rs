//! MonitorHub integration: a healthy and a problematic training run
//! execute concurrently (one thread + one private `SketchEngine` each,
//! heterogeneous widths, tail batches), stream their sketch metrics into
//! one hub, and only the problematic session may be diagnosed.

use std::sync::{mpsc, Arc, Barrier};
use std::thread;

use sketchgrad::coordinator::StepMetrics;
use sketchgrad::data::ActStream;
use sketchgrad::monitor::{step_metrics, MonitorConfig, MonitorHub};
use sketchgrad::sketch::{SketchConfig, Sketcher};

const STEPS: usize = 120;
const N_B: usize = 32;
const TAIL: usize = 9;

/// Produce one run's metric stream on its own thread, from the shared
/// `ActStream` generator (healthy: full-rank gaussian activations,
/// decaying loss; problematic: direction-collapsed activations, flat
/// loss — the same streams `sketchgrad hub` demos).
fn run_session(
    idx: usize,
    dims: Vec<usize>,
    problematic: bool,
    seed: u64,
    start: Arc<Barrier>,
    tx: mpsc::Sender<(usize, StepMetrics, usize)>,
) -> thread::JoinHandle<()> {
    thread::spawn(move || {
        let mut engine = SketchConfig::builder()
            .layer_dims(&dims)
            .rank(4)
            .beta(0.9)
            .seed(seed)
            .build_engine()
            .unwrap();
        let mut stream = ActStream::new(&dims, problematic, seed);
        start.wait();
        for step in 0..STEPS {
            let nb = if step == STEPS - 1 { TAIL } else { N_B };
            engine.ingest(&stream.next_batch(nb)).unwrap();
            let m = step_metrics(stream.loss_at(step, STEPS), &engine.metrics());
            tx.send((idx, m, engine.memory())).unwrap();
        }
    })
}

#[test]
fn healthy_and_problematic_concurrent_only_problematic_flagged() {
    let archs: Vec<(Vec<usize>, bool)> = vec![
        (vec![128, 64, 32], false), // healthy funnel MLP
        (vec![96, 48], true),       // problematic
    ];
    let mut hub = MonitorHub::new();
    let cfg = || MonitorConfig {
        window: STEPS / 4,
        collapse_frac: 0.25,
        ..MonitorConfig::for_rank(4)
    };
    let ids: Vec<_> = archs
        .iter()
        .map(|(dims, problematic)| {
            let name = if *problematic { "problematic" } else { "healthy" };
            hub.register(name, cfg(), dims.len()).unwrap()
        })
        .collect();

    let (tx, rx) = mpsc::channel();
    let start = Arc::new(Barrier::new(archs.len()));
    let handles: Vec<_> = archs
        .iter()
        .enumerate()
        .map(|(i, (dims, problematic))| {
            run_session(
                i,
                dims.clone(),
                *problematic,
                42 + i as u64,
                start.clone(),
                tx.clone(),
            )
        })
        .collect();
    drop(tx);

    let mut sketch_bytes = vec![0usize; archs.len()];
    let mut interleaved = 0u32;
    let mut last_idx = usize::MAX;
    for (idx, metrics, mem) in rx {
        if idx != last_idx {
            interleaved += 1;
            last_idx = idx;
        }
        hub.observe(ids[idx], &metrics).unwrap();
        sketch_bytes[idx] = mem;
    }
    for h in handles {
        h.join().unwrap();
    }
    for (i, &bytes) in sketch_bytes.iter().enumerate() {
        hub.report_sketch_bytes(ids[i], bytes).unwrap();
    }

    // Both sessions delivered their full streams.
    for &id in &ids {
        assert_eq!(hub.session(id).unwrap().steps_seen(), STEPS as u64);
    }
    // The streams normally interleave (more handoffs than a sequential
    // run's one-per-session); a loaded scheduler can legally serialize
    // them, so record rather than assert — correctness of the hub does
    // not depend on arrival order.
    if interleaved <= archs.len() as u32 {
        eprintln!(
            "note: producer streams arrived sequentially \
             ({interleaved} handoffs) — scheduler did not interleave"
        );
    }

    let healthy = hub.session(ids[0]).unwrap();
    let problematic = hub.session(ids[1]).unwrap();
    assert!(
        healthy.is_healthy(),
        "healthy flagged: {:?}",
        healthy.diagnose()
    );
    assert!(
        !problematic.is_healthy(),
        "problematic not flagged: {:?}",
        problematic.diagnose()
    );
    let d = problematic.diagnose();
    assert!(d.diversity_collapse, "{d:?}");
    assert!(d.stagnation, "{d:?}");

    let report = hub.aggregate();
    assert_eq!(report.sessions, 2);
    assert_eq!(report.healthy, 1);
    assert_eq!(report.flagged.len(), 1);
    assert_eq!(report.flagged[0].1, "problematic");
    assert_eq!(report.steps_seen, 2 * STEPS as u64);

    // Memory accounting: each tenant's measured engine bytes match the
    // fixed accountant within 1% (exact, in fact).
    for (i, (dims, _)) in archs.iter().enumerate() {
        let expected = sketchgrad::sketch::engine_state_bytes(
            dims,
            4,
            &[N_B, TAIL],
            4,
        );
        let rel = (sketch_bytes[i] as f64 - expected as f64).abs()
            / expected as f64;
        assert!(
            rel <= 0.01,
            "session {i}: measured {} vs accountant {expected}",
            sketch_bytes[i]
        );
    }
    assert_eq!(
        report.sketch_bytes,
        sketch_bytes.iter().sum::<usize>()
    );
}

/// Sessions can come and go while others keep streaming — the hub's
/// accounting follows the tenant set.
#[test]
fn tenant_churn() {
    let cfg = MonitorConfig::for_rank(2);
    let mut hub = MonitorHub::new();
    let a = hub.register("a", cfg.clone(), 2).unwrap();
    let m0 = hub.memory();
    let b = hub.register("b", cfg.clone(), 2).unwrap();
    let c = hub.register("c", cfg, 2).unwrap();
    assert_eq!(hub.memory(), 3 * m0);
    let sample = StepMetrics {
        loss: 1.0,
        z_norm: vec![1.0; 2],
        stable_rank: vec![4.0; 2],
        ..Default::default()
    };
    hub.observe(b, &sample).unwrap();
    hub.deregister(a).unwrap();
    assert_eq!(hub.memory(), 2 * m0);
    hub.observe(b, &sample).unwrap();
    hub.observe(c, &sample).unwrap();
    assert!(hub.observe(a, &sample).is_err());
    assert_eq!(hub.session(b).unwrap().steps_seen(), 2);
    assert_eq!(hub.len(), 2);
}
