//! Loopback integration tests for the sketchd daemon: concurrent remote
//! sessions must be *bit-for-bit* equivalent to in-process MonitorHub
//! runs, a kill -> restart cycle must resume every session from the
//! durable snapshot with `max_state_diff == 0`, and the backpressure /
//! error paths must surface as typed protocol replies.

use std::thread;

use sketchgrad::config::{ArchiveConfig, ObsConfig, ServeConfig};
use sketchgrad::data::ActStream;
use sketchgrad::monitor::{step_metrics, MonitorHub, SessionId};
use sketchgrad::serve::daemon::recon_errors;
use sketchgrad::serve::proto::{
    self, monitor_config, ErrorCode, Request, Response, SessionSpec,
};
use sketchgrad::serve::{
    Daemon, Error, SketchClient, SnapshotStore,
};
use sketchgrad::sketch::{
    Mat, Parallelism, SketchConfig, SketchEngine, Sketcher,
};

/// Disjoint per-run architectures (heterogeneous widths); the last run
/// is the direction-collapsed problematic stream.
const ARCHS: [(&[usize], bool); 4] = [
    (&[48, 24, 12], false),
    (&[40, 40], false),
    (&[56, 28, 14, 7], false),
    (&[32, 16], true),
];
const STEPS: usize = 40;
const N_B: usize = 24;
const TAIL: usize = 7;
const WINDOW: usize = 10;
const RANK: usize = 4;
const BETA: f64 = 0.9;

fn unique_snapshot_path(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("sketchd-it-{tag}-{}.snap", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

fn test_config(tag: &str, max_sessions: usize, quota: usize) -> ServeConfig {
    ServeConfig {
        addr: "127.0.0.1:0".into(),
        max_sessions,
        snapshot_interval_secs: 0,
        session_quota_bytes: quota,
        snapshot_path: unique_snapshot_path(tag),
        threads: 1,
        shards: 1,
        archive: ArchiveConfig::default(),
        obs: ObsConfig::default(),
        fault: String::new(),
    }
}

fn spec_for(idx: usize, name: &str) -> SessionSpec {
    SessionSpec {
        name: name.into(),
        layer_dims: ARCHS[idx].0.to_vec(),
        rank: RANK,
        beta: BETA,
        seed: 500 + idx as u64,
        window: WINDOW,
        collapse_frac: 0.25,
    }
}

/// In-process replica of run `idx`: same engine config, same hub config,
/// same deterministic activation stream.
struct Mirror {
    engine: SketchEngine,
    hub: MonitorHub,
    id: SessionId,
    stream: ActStream,
}

impl Mirror {
    fn new(idx: usize) -> Mirror {
        let spec = spec_for(idx, "mirror");
        let engine = SketchConfig::builder()
            .layer_dims(&spec.layer_dims)
            .rank(spec.rank)
            .beta(spec.beta)
            .seed(spec.seed)
            .build_engine()
            .unwrap();
        let mut hub = MonitorHub::new();
        let id = hub
            .register("mirror", monitor_config(&spec), spec.layer_dims.len())
            .unwrap();
        Mirror {
            engine,
            hub,
            id,
            stream: ActStream::new(ARCHS[idx].0, ARCHS[idx].1, spec.seed),
        }
    }

    fn step(&mut self, step: usize, total: usize) -> (f32, Vec<Mat>) {
        let n_b = if step == total - 1 { TAIL } else { N_B };
        let acts = self.stream.next_batch(n_b);
        let loss = self.stream.loss_at(step, total);
        self.engine.ingest(&acts).unwrap();
        self.hub
            .observe(self.id, &step_metrics(loss, &self.engine.metrics()))
            .unwrap();
        (loss, acts)
    }
}

/// ACCEPTANCE: 4 concurrent clients ingest disjoint runs; per-session
/// diagnosis, reconstruction errors and memory accounting match an
/// in-process MonitorHub run bit-for-bit, and only the problematic
/// session is flagged.
#[test]
fn four_concurrent_remote_sessions_match_in_process_bit_for_bit() {
    let daemon = Daemon::bind(test_config("concurrent", 8, 0)).unwrap();
    let addr = daemon.local_addr().unwrap().to_string();
    let snap_path = unique_snapshot_path("concurrent");
    let handle = daemon.spawn().unwrap();

    // 4 concurrent clients, one OS thread each, disjoint runs.
    let results: Vec<(usize, u64, Vec<f64>, _)> = thread::scope(|s| {
        let handles: Vec<_> = (0..ARCHS.len())
            .map(|idx| {
                let addr = addr.clone();
                s.spawn(move || {
                    let (mut client, _info) =
                        SketchClient::connect(&addr).unwrap();
                    let mut sess = client
                        .open_session(&spec_for(idx, &format!("run{idx}")))
                        .unwrap();
                    // The client generates the same deterministic stream
                    // the mirror will replay.
                    let mut stream = ActStream::new(
                        ARCHS[idx].0,
                        ARCHS[idx].1,
                        500 + idx as u64,
                    );
                    let mut last_recon = Vec::new();
                    for step in 0..STEPS {
                        let n_b =
                            if step == STEPS - 1 { TAIL } else { N_B };
                        let acts = stream.next_batch(n_b);
                        let loss = stream.loss_at(step, STEPS);
                        let want = step == STEPS - 1;
                        let reply =
                            sess.ingest(loss, &acts, want).unwrap();
                        if want {
                            last_recon = reply.recon_err;
                        }
                    }
                    let diag = sess.diagnose().unwrap();
                    (idx, sess.id(), last_recon, diag)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    for (idx, _session, remote_recon, diag) in &results {
        let idx = *idx;
        // Sequential in-process replay of the identical run.
        let mut mirror = Mirror::new(idx);
        let mut local_recon = Vec::new();
        for step in 0..STEPS {
            let (_loss, acts) = mirror.step(step, STEPS);
            if step == STEPS - 1 {
                local_recon = recon_errors(&mirror.engine, &acts).unwrap();
            }
        }
        let local_diag = mirror.hub.diagnose(mirror.id).unwrap();

        assert_eq!(
            diag.diagnosis, local_diag,
            "run {idx}: remote diagnosis diverged"
        );
        assert_eq!(diag.steps_seen, STEPS as u64, "run {idx}");
        assert_eq!(
            diag.engine_bytes,
            mirror.engine.memory() as u64,
            "run {idx}: accountant diverged"
        );
        assert_eq!(
            remote_recon, &local_recon,
            "run {idx}: reconstruction errors not bit-for-bit"
        );
        let problematic = ARCHS[idx].1;
        assert_eq!(
            diag.healthy, !problematic,
            "run {idx}: healthy={} but problematic={problematic}: {:?}",
            diag.healthy, diag.diagnosis
        );
    }

    handle.stop().unwrap();
    let _ = std::fs::remove_file(&snap_path);
}

/// ACCEPTANCE: kill -> restart resumes every session from the snapshot
/// with engine `max_state_diff == 0` and identical detector verdicts;
/// remote sessions then continue bit-for-bit against an uninterrupted
/// in-process run.
#[test]
fn kill_restart_resumes_sessions_with_zero_state_diff() {
    let cfg = test_config("restart", 8, 0);
    let snap_path = cfg.snapshot_path.clone();
    let first_half = STEPS / 2;

    // Phase 1: two sessions ingest half their runs, then the daemon is
    // stopped (final snapshot on shutdown).
    let daemon = Daemon::bind(cfg.clone()).unwrap();
    let addr1 = daemon.local_addr().unwrap().to_string();
    let handle = daemon.spawn().unwrap();
    let mut mirrors: Vec<Mirror> = (0..2).map(Mirror::new).collect();
    let mut sessions = Vec::new();
    {
        let (mut client, info) = SketchClient::connect(&addr1).unwrap();
        assert_eq!(info.sessions, 0);
        for (idx, mirror) in mirrors.iter_mut().enumerate() {
            let mut sess = client
                .open_session(&spec_for(idx, &format!("run{idx}")))
                .unwrap();
            for step in 0..first_half {
                let (loss, acts) = mirror.step(step, STEPS);
                sess.ingest(loss, &acts, false).unwrap();
            }
            sessions.push(sess.id());
        }
    }
    handle.stop().unwrap();

    // The durable snapshot alone must rebuild engines identical to the
    // uninterrupted mirrors (the direct max_state_diff == 0 witness).
    let snap = SnapshotStore::new(snap_path.clone())
        .load()
        .unwrap()
        .expect("shutdown snapshot written");
    assert_eq!(snap.sessions.len(), 2);
    for rec in &snap.sessions {
        let idx = rec.session.id as usize;
        let restored =
            SketchEngine::from_snapshot(&rec.engine, Parallelism::Serial)
                .unwrap();
        assert_eq!(
            restored.max_state_diff(&mirrors[idx].engine),
            0.0,
            "session {idx}: snapshot state drifted"
        );
    }

    // Phase 2: restart on the same snapshot path; clients reconnect and
    // finish their runs; the mirrors run uninterrupted.
    let daemon = Daemon::bind(cfg).unwrap();
    assert_eq!(daemon.session_count(), 2, "sessions resumed from snapshot");
    let addr2 = daemon.local_addr().unwrap().to_string();
    let handle = daemon.spawn().unwrap();
    {
        let (mut client, info) = SketchClient::connect(&addr2).unwrap();
        assert_eq!(info.sessions, 2);
        for (idx, mirror) in mirrors.iter_mut().enumerate() {
            let mut sess = client.session(sessions[idx]);
            let mut last_reply = None;
            for step in first_half..STEPS {
                let (loss, acts) = mirror.step(step, STEPS);
                let want = step == STEPS - 1;
                let reply = sess.ingest(loss, &acts, want).unwrap();
                assert_eq!(
                    reply.engine_bytes,
                    mirror.engine.memory() as u64,
                    "run {idx} step {step}: accountant diverged post-resume"
                );
                if want {
                    let local =
                        recon_errors(&mirror.engine, &acts).unwrap();
                    assert_eq!(
                        reply.recon_err, local,
                        "run {idx}: post-resume reconstruction diverged"
                    );
                }
                last_reply = Some(reply);
            }
            assert_eq!(
                last_reply.unwrap().batches,
                STEPS as u64,
                "run {idx}: batch count lost across restart"
            );
            let diag = sess.diagnose().unwrap();
            let local = mirror.hub.diagnose(mirror.id).unwrap();
            assert_eq!(diag.steps_seen, STEPS as u64);
            assert_eq!(
                diag.diagnosis, local,
                "run {idx}: diagnosis diverged across restart"
            );
        }
    }
    handle.stop().unwrap();
    let _ = std::fs::remove_file(&snap_path);
}

/// Per-session byte quota: over-quota ingest gets `Busy`; `Diagnose`
/// drains the counter and ingestion resumes.
#[test]
fn backpressure_busy_then_drained_by_diagnose() {
    // Each ingest frame here is ~3 KB; quota admits roughly three of
    // them between diagnoses.
    let quota = 10_000;
    let daemon = Daemon::bind(test_config("quota", 4, quota)).unwrap();
    let addr = daemon.local_addr().unwrap().to_string();
    let snap_path = unique_snapshot_path("quota");
    let handle = daemon.spawn().unwrap();

    let (mut client, _info) = SketchClient::connect(&addr).unwrap();
    let dims: &[usize] = &[16];
    let mut sess = client
        .open_session(&SessionSpec {
            name: "throttled".into(),
            layer_dims: dims.to_vec(),
            rank: 2,
            beta: 0.9,
            seed: 7,
            window: 5,
            collapse_frac: 0.25,
        })
        .unwrap();
    let mut stream = ActStream::new(dims, false, 7);

    let mut accepted = 0usize;
    let busy = loop {
        let acts = stream.next_batch(8);
        match sess.ingest(1.0, &acts, false) {
            Ok(_) => accepted += 1,
            Err(Error::Busy { used, limit }) => break (used, limit),
            Err(e) => panic!("unexpected error: {e}"),
        }
        assert!(accepted < 100, "quota never triggered");
    };
    assert!(accepted >= 1, "first ingest should fit under quota");
    assert_eq!(busy.1, quota as u64);
    assert!(busy.0 <= quota as u64);

    // Diagnose drains the counter; the same ingest now succeeds.
    sess.diagnose().unwrap();
    let acts = stream.next_batch(8);
    sess.ingest(1.0, &acts, false).unwrap();

    handle.stop().unwrap();
    let _ = std::fs::remove_file(&snap_path);
}

/// Typed wire errors: unknown sessions, admission caps and version
/// mismatches all come back as protocol-level replies, not hangups.
#[test]
fn wire_errors_admission_and_version_negotiation() {
    let daemon = Daemon::bind(test_config("errors", 1, 0)).unwrap();
    let addr = daemon.local_addr().unwrap().to_string();
    let snap_path = unique_snapshot_path("errors");
    let handle = daemon.spawn().unwrap();

    let (mut client, info) = SketchClient::connect(&addr).unwrap();
    assert_eq!(info.max_sessions, 1);

    // Unknown session -> typed remote error.
    match client.session(999).diagnose() {
        Err(Error::UnknownSession(_)) => {}
        other => panic!("expected UnknownSession, got {other:?}"),
    }

    // Admission cap: the second OpenSession is Busy.
    let spec = SessionSpec {
        name: "only".into(),
        layer_dims: vec![8],
        rank: 2,
        beta: 0.9,
        seed: 1,
        window: 5,
        collapse_frac: 0.25,
    };
    let first = client.open_session(&spec).unwrap().id();
    match client.open_session(&spec) {
        Err(Error::Busy { used, limit }) => {
            assert_eq!((used, limit), (1, 1))
        }
        Err(other) => panic!("expected Busy, got {other}"),
        Ok(_) => panic!("second open_session must hit the admission cap"),
    }
    client.session(first).close().unwrap();
    client.open_session(&spec).unwrap();

    // A frame with a future protocol version gets UnsupportedVersion.
    let mut raw = std::net::TcpStream::connect(&addr).unwrap();
    let req = Request::Hello {
        client: "time-traveller".into(),
    };
    proto::write_frame_versioned(
        &mut raw,
        proto::PROTO_VERSION + 1,
        req.msg_type(),
        &req.encode(),
    )
    .unwrap();
    let (header, payload) = proto::read_frame(&mut raw).unwrap();
    match Response::decode(header.msg, &payload).unwrap() {
        Response::Error { code, .. } => {
            assert_eq!(code, ErrorCode::UnsupportedVersion)
        }
        other => panic!("expected Error, got {other:?}"),
    }

    handle.stop().unwrap();
    let _ = std::fs::remove_file(&snap_path);
}
