//! Snapshot round-trip property tests: engine and monitor state must
//! survive save -> codec encode -> decode -> load with *zero* state
//! drift (`max_state_diff == 0`, identical diagnoses) across
//! heterogeneous widths, tail batches and post-`set_rank` states — the
//! invariant the sketchd warm-restart path rests on.

use sketchgrad::coordinator::StepMetrics;
use sketchgrad::monitor::{MonitorConfig, MonitorHub, MonitorService};
use sketchgrad::serve::codec::{Dec, Enc};
use sketchgrad::serve::store::{
    dec_engine_snapshot, dec_service_state, enc_engine_snapshot,
    enc_service_state,
};
use sketchgrad::sketch::{
    Mat, Parallelism, SketchConfig, SketchEngine, Sketcher,
};
use sketchgrad::util::prop::Prop;
use sketchgrad::util::rng::Rng;

fn random_dims(rng: &mut Rng) -> Vec<usize> {
    let n_layers = 1 + rng.below(4) as usize;
    (0..n_layers).map(|_| 4 + rng.below(36) as usize).collect()
}

fn random_acts(n_b: usize, dims: &[usize], rng: &mut Rng) -> Vec<Mat> {
    let mut acts = vec![Mat::gaussian(n_b, 8, rng)];
    for &d in dims {
        acts.push(Mat::gaussian(n_b, d, rng));
    }
    acts
}

/// Engine snapshot -> wire bytes -> restore must be exact, and the
/// restored engine must keep evolving identically.
fn check_engine_roundtrip(
    engine: &mut SketchEngine,
    dims: &[usize],
    rng: &mut Rng,
) -> Result<(), String> {
    let snap = engine.snapshot();
    let mut e = Enc::new();
    enc_engine_snapshot(&mut e, &snap);
    let bytes = e.into_bytes();
    let mut d = Dec::new(&bytes);
    let decoded = dec_engine_snapshot(&mut d).map_err(|e| e.to_string())?;
    d.finish().map_err(|e| e.to_string())?;

    let mut restored =
        SketchEngine::from_snapshot(&decoded, Parallelism::Serial)
            .map_err(|e| e.to_string())?;
    let diff = restored.max_state_diff(engine);
    if diff != 0.0 {
        return Err(format!("state diff {diff} after roundtrip"));
    }
    if restored.memory() != engine.memory() {
        return Err(format!(
            "memory {} != {}",
            restored.memory(),
            engine.memory()
        ));
    }
    if restored.batch_sizes_seen() != engine.batch_sizes_seen() {
        return Err("batch sizes diverged".into());
    }
    if restored.batches_ingested() != engine.batches_ingested() {
        return Err("batches_ingested diverged".into());
    }

    // Continued ingestion + reconstruction stay bitwise identical (the
    // re-derived projections must equal the originals).
    let n_b = 3 + rng.below(24) as usize;
    let acts = random_acts(n_b, dims, rng);
    engine.ingest(&acts).map_err(|e| e.to_string())?;
    restored.ingest(&acts).map_err(|e| e.to_string())?;
    let diff = restored.max_state_diff(engine);
    if diff != 0.0 {
        return Err(format!("state diff {diff} after continued ingest"));
    }
    for l in 0..dims.len() {
        let a = engine.reconstruct(l).map_err(|e| e.to_string())?;
        let b = restored.reconstruct(l).map_err(|e| e.to_string())?;
        if a.max_abs_diff(&b) != 0.0 {
            return Err(format!("reconstruction diverged at layer {l}"));
        }
    }
    Ok(())
}

#[test]
fn engine_snapshot_roundtrip_hetero_widths_and_tail_batches() {
    Prop::new(16).check("engine_roundtrip", |rng, i| {
        let dims = random_dims(rng);
        let rank = 1 + i % 5;
        let mut engine = SketchConfig::builder()
            .layer_dims(&dims)
            .rank(rank)
            .beta(0.85)
            .seed(1000 + i as u64)
            .build_engine()
            .map_err(|e| e.to_string())?;
        // A nominal batch size, a repeat, and a smaller tail batch.
        let n_b = 8 + rng.below(24) as usize;
        let tail = 1 + rng.below(n_b as u64 / 2) as usize;
        for &b in &[n_b, n_b, tail] {
            let acts = random_acts(b, &dims, rng);
            engine.ingest(&acts).map_err(|e| e.to_string())?;
        }
        check_engine_roundtrip(&mut engine, &dims, rng)
    });
}

#[test]
fn engine_snapshot_roundtrip_after_set_rank() {
    Prop::new(12).check("set_rank_roundtrip", |rng, i| {
        let dims = random_dims(rng);
        let mut engine = SketchConfig::builder()
            .layer_dims(&dims)
            .rank(2)
            .beta(0.9)
            .seed(2000 + i as u64)
            .build_engine()
            .map_err(|e| e.to_string())?;
        let n_b = 6 + rng.below(12) as usize;
        engine
            .ingest(&random_acts(n_b, &dims, rng))
            .map_err(|e| e.to_string())?;
        // Algorithm-1 rank change resets sketches and resamples Psi; the
        // snapshot must capture the *new* rank's state.
        let new_rank = 1 + rng.below(6) as usize;
        engine.set_rank(new_rank);
        if i % 2 == 0 {
            // Half the cases snapshot a freshly-reset engine, half after
            // re-accumulating at the new rank.
            engine
                .ingest(&random_acts(n_b, &dims, rng))
                .map_err(|e| e.to_string())?;
        }
        let snap = engine.snapshot();
        if snap.rank != new_rank.max(1) {
            return Err(format!("snapshot rank {} != {new_rank}", snap.rank));
        }
        check_engine_roundtrip(&mut engine, &dims, rng)
    });
}

#[test]
fn service_state_roundtrip_through_codec() {
    Prop::new(16).check("service_roundtrip", |rng, i| {
        let n_layers = 1 + rng.below(5) as usize;
        let cfg = MonitorConfig {
            window: 5 + rng.below(20) as usize,
            collapse_frac: 0.1 + 0.4 * rng.uniform(),
            ..MonitorConfig::for_rank(1 + i % 8)
        };
        let mut svc = MonitorService::new(cfg, n_layers);
        let steps = rng.below(80) as usize;
        for step in 0..steps {
            svc.observe(&StepMetrics {
                loss: (2.0 * (-0.02 * step as f64).exp()) as f32,
                z_norm: vec![rng.uniform() as f32 * 50.0; n_layers],
                stable_rank: vec![rng.uniform() as f32 * 9.0; n_layers],
                ..Default::default()
            });
        }
        let st = svc.state();
        let mut e = Enc::new();
        enc_service_state(&mut e, &st);
        let bytes = e.into_bytes();
        let mut d = Dec::new(&bytes);
        let back = dec_service_state(&mut d).map_err(|e| e.to_string())?;
        d.finish().map_err(|e| e.to_string())?;

        let mut restored = MonitorService::from_state(&back);
        if restored.diagnose() != svc.diagnose() {
            return Err("diagnosis diverged after roundtrip".into());
        }
        if restored.steps_seen != svc.steps_seen {
            return Err("steps_seen diverged".into());
        }
        if restored.monitor_bytes() != svc.monitor_bytes() {
            return Err("monitor_bytes diverged".into());
        }
        // Continued observation (ring-buffer head included) matches.
        let m = StepMetrics {
            loss: 0.5,
            z_norm: vec![7.0; n_layers],
            stable_rank: vec![4.0; n_layers],
            ..Default::default()
        };
        svc.observe(&m);
        restored.observe(&m);
        if restored.diagnose() != svc.diagnose() {
            return Err("diagnosis diverged after continued observe".into());
        }
        Ok(())
    });
}

/// Hub-level: session states restored into a fresh hub aggregate to the
/// same report.
#[test]
fn hub_session_states_restore_to_identical_report() {
    let cfg = MonitorConfig {
        window: 10,
        collapse_frac: 0.5,
        ..MonitorConfig::for_rank(4)
    };
    let mut hub = MonitorHub::new();
    let mut ids = Vec::new();
    for i in 0..3 {
        ids.push(hub.register(&format!("t{i}"), cfg.clone(), 2).unwrap());
    }
    for step in 0..60 {
        for (i, &id) in ids.iter().enumerate() {
            let healthy = i != 1;
            let m = if healthy {
                StepMetrics {
                    loss: 2.0 * (-0.05 * step as f32).exp(),
                    z_norm: vec![40.0 + (step % 3) as f32; 2],
                    stable_rank: vec![8.0; 2],
                    ..Default::default()
                }
            } else {
                StepMetrics {
                    loss: 2.3,
                    z_norm: vec![9.0; 2],
                    stable_rank: vec![1.2; 2],
                    ..Default::default()
                }
            };
            hub.observe(id, &m).unwrap();
        }
        hub.report_sketch_bytes(ids[0], 1000).unwrap();
    }

    let mut restored = MonitorHub::new();
    for s in hub.sessions() {
        restored.restore_session(&s.state()).unwrap();
    }
    let (a, b) = (hub.aggregate(), restored.aggregate());
    assert_eq!(a.sessions, b.sessions);
    assert_eq!(a.healthy, b.healthy);
    assert_eq!(a.flagged.len(), b.flagged.len());
    assert_eq!(a.monitor_bytes, b.monitor_bytes);
    assert_eq!(a.sketch_bytes, b.sketch_bytes);
    assert_eq!(a.steps_seen, b.steps_seen);
    for ((ia, na, da), (ib, nb, db)) in a.flagged.iter().zip(&b.flagged) {
        assert_eq!((ia, na), (ib, nb));
        assert_eq!(da, db);
    }
}
