//! Zero-allocation steady-state ingest: once an engine has seen a batch
//! size (projections cached, pool threads parked, workspace warm), every
//! further `SketchEngine::ingest` call must perform **no heap
//! allocations at all** — the fused EMA kernels write into the resident
//! sketches through register accumulators, the layer fan-out claims
//! indices straight off the activation list, and the pool handoff is a
//! condvar protocol over pre-existing state.  The same holds for archive
//! recording: once the ring is full, `SessionArchive::maybe_record`
//! overwrites resident slots in place (`copy_from_slice`) and must not
//! allocate either.
//!
//! Pinned with a counting global allocator.  This file deliberately
//! holds a single test: the counter is process-global, and libtest runs
//! tests in one process (concurrently when there are several).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use sketchgrad::archive::SessionArchive;
use sketchgrad::sketch::{Mat, SketchConfig, SketchEngine, Sketcher};
use sketchgrad::util::rng::Rng;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: Layout,
        new_size: usize,
    ) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn engine(dims: &[usize], threads: usize) -> SketchEngine {
    SketchConfig::builder()
        .layer_dims(dims)
        .rank(4)
        .beta(0.9)
        .seed(11)
        .threads(threads)
        .build_engine()
        .unwrap()
}

fn acts(n_b: usize, dims: &[usize], rng: &mut Rng) -> Vec<Mat> {
    let mut out = vec![Mat::gaussian(n_b, dims[0], rng)];
    for &d in dims {
        out.push(Mat::gaussian(n_b, d, rng));
    }
    out
}

#[test]
fn steady_state_ingest_allocates_nothing() {
    let dims = [48usize, 32, 24, 16];
    let mut rng = Rng::new(1);
    let nominal = acts(64, &dims, &mut rng);
    let tail = acts(21, &dims, &mut rng);
    // 1 lane = serial inline; 2 lanes = whole-layer fan-out (2 <= 4
    // layers); 8 lanes = intra-kernel row-stripe fan-out (8 > 4 layers).
    for threads in [1usize, 2, 8] {
        let mut e = engine(&dims, threads);
        // Archive ring sized so the warm-up fills it completely; after
        // that every record is an in-place slot overwrite.
        let mut archive = SessionArchive::new(4, 1, 4);
        // Warm-up: observe both batch sizes so the per-size projections
        // are cached, the pool threads are spawned and parked, and every
        // lazy one-time initialisation has happened.
        for _ in 0..2 {
            e.ingest(&nominal).unwrap();
            archive.maybe_record(e.batches_ingested(), 0.5, e.layers());
            e.ingest(&tail).unwrap();
            archive.maybe_record(e.batches_ingested(), 0.5, e.layers());
        }
        assert_eq!(archive.len(), archive.capacity(), "ring warmed up full");
        let before = ALLOCS.load(Ordering::Relaxed);
        for _ in 0..5 {
            e.ingest(&nominal).unwrap();
            archive.maybe_record(e.batches_ingested(), 0.5, e.layers());
            e.ingest(&tail).unwrap();
            archive.maybe_record(e.batches_ingested(), 0.5, e.layers());
        }
        let after = ALLOCS.load(Ordering::Relaxed);
        assert_eq!(
            after - before,
            0,
            "steady-state ingest+record allocated at {threads} thread(s)"
        );
    }
}
