//! Loopback tests for the v5 observability surfaces (DESIGN.md §10):
//! the typed `Events` / `MetricsWindow` ops, the HTTP exposition
//! endpoint, and the exactness guarantees behind them — window-ring
//! sums equal to lifetime counters on every surface, per-session
//! sketch-health agreement between protocol and scrape, exact journal
//! drop accounting under a tiny ring, and clean v5 version gating.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};

use sketchgrad::config::{ArchiveConfig, ObsConfig, ServeConfig};
use sketchgrad::data::ActStream;
use sketchgrad::serve::obs::{events::kind, EventKind};
use sketchgrad::serve::proto::{
    self, ErrorCode, Request, Response, SessionSpec,
};
use sketchgrad::serve::{Daemon, SketchClient};
use sketchgrad::sketch::Mat;

fn unique_snapshot_path(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("sketchd-obs-{tag}-{}.snap", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

/// Config with the exposition endpoint on an ephemeral port, fast
/// window ticks and a slow-request threshold high enough that no
/// legitimate request journals as slow (keeps event counts exact).
fn test_config(tag: &str, shards: usize, obs: ObsConfig) -> ServeConfig {
    ServeConfig {
        addr: "127.0.0.1:0".into(),
        max_sessions: 16,
        snapshot_interval_secs: 0,
        session_quota_bytes: 0,
        snapshot_path: unique_snapshot_path(tag),
        threads: 1,
        shards,
        archive: ArchiveConfig::default(),
        obs,
        fault: String::new(),
    }
}

fn obs_on() -> ObsConfig {
    ObsConfig {
        addr: "127.0.0.1:0".into(),
        window_ms: 50,
        window_count: 16,
        slow_ms: 600_000,
        ..ObsConfig::default()
    }
}

fn spec(name: &str, dims: &[usize], seed: u64) -> SessionSpec {
    SessionSpec {
        name: name.into(),
        layer_dims: dims.to_vec(),
        rank: 3,
        beta: 0.9,
        seed,
        window: 8,
        collapse_frac: 0.25,
    }
}

/// Wire payload bytes of one `Ingest` frame (mirrors the daemon's
/// `payload_len` accounting).
fn ingest_payload_bytes(acts: &[Mat]) -> u64 {
    17 + acts
        .iter()
        .map(|m| 8 + (m.rows * m.cols * 8) as u64)
        .sum::<u64>()
}

/// Minimal HTTP/1.1 GET against the exposition endpoint; returns the
/// status line and the body (the server always closes after one reply).
fn http_get(addr: SocketAddr, path: &str) -> (String, String) {
    let mut s = TcpStream::connect(addr).unwrap();
    write!(
        s,
        "GET {path} HTTP/1.1\r\nHost: sketchd\r\nConnection: close\r\n\r\n"
    )
    .unwrap();
    let mut buf = String::new();
    s.read_to_string(&mut buf).unwrap();
    let (head, body) = buf.split_once("\r\n\r\n").expect("header/body split");
    (
        head.lines().next().unwrap_or("").to_string(),
        body.to_string(),
    )
}

/// Value of an unlabeled metric line (`name value`).
fn metric(body: &str, name: &str) -> u64 {
    body.lines()
        .find_map(|l| l.strip_prefix(name)?.strip_prefix(' '))
        .unwrap_or_else(|| panic!("metric {name} missing:\n{body}"))
        .trim()
        .parse::<u64>()
        .unwrap_or_else(|e| panic!("metric {name} not a u64: {e}"))
}

/// Sum of every sample of a labeled metric (`name{...} value`).
fn labeled_sum(body: &str, name: &str) -> u64 {
    body.lines()
        .filter_map(|l| l.strip_prefix(name)?.strip_prefix('{'))
        .map(|rest| {
            rest.split_once("} ")
                .unwrap_or_else(|| panic!("bad labeled line for {name}"))
                .1
                .trim()
                .parse::<u64>()
                .unwrap()
        })
        .sum()
}

/// One daemon, two ingesting sessions: the v5 `MetricsWindow` report,
/// the v3 lifetime counters and the `/metrics` scrape all report the
/// same exact frame/byte totals; the window balance terms published on
/// the scrape telescope to the lifetime counter; health gauges carry
/// the same values on both surfaces; the journal records the session
/// lifecycle with zero drops.
#[test]
fn obs_surfaces_agree_on_exact_counters() {
    const DIMS: &[usize] = &[32, 16];
    let daemon = Daemon::bind(test_config("agree", 1, obs_on())).unwrap();
    let addr = daemon.local_addr().unwrap().to_string();
    let snap_path = unique_snapshot_path("agree");
    let handle = daemon.spawn().unwrap();
    let obs_addr = handle.obs_addr().expect("obs endpoint enabled");

    let (mut client, _info) = SketchClient::connect(&addr).unwrap();
    let s1 = client.open_session(&spec("obs-a", DIMS, 11)).unwrap().id();
    let s2 = client.open_session(&spec("obs-b", DIMS, 22)).unwrap().id();
    let mut stream_a = ActStream::new(DIMS, false, 11);
    let mut stream_b = ActStream::new(DIMS, false, 22);
    let mut bytes = 0u64;
    for step in 0..6 {
        let acts = stream_a.next_batch(8);
        bytes += ingest_payload_bytes(&acts);
        client
            .session(s1)
            .ingest(stream_a.loss_at(step, 6), &acts, false)
            .unwrap();
        if step % 2 == 0 {
            let acts = stream_b.next_batch(5);
            bytes += ingest_payload_bytes(&acts);
            client
                .session(s2)
                .ingest(stream_b.loss_at(step, 6), &acts, false)
                .unwrap();
        }
    }
    client.session(s1).diagnose().unwrap();

    // Window report first: its open bucket closes at the capture, so
    // its total is the lifetime capture at that instant; the ingest
    // counters cannot move afterwards (this client is the only tenant
    // and only sends control traffic from here on).
    let w = client.metrics_window().unwrap();
    let m = client.metrics().unwrap();
    let total = w.report.total();
    assert_eq!(total.ingest_frames, 9);
    assert_eq!(m.ingest.count, 9);
    assert_eq!(total.ingest_bytes, bytes);
    assert_eq!(m.ingest_bytes, bytes);
    assert_eq!(total.busy, 0);
    assert_eq!(w.report.interval_ms, 50);

    // Health rides the same reply: both sessions, one row per layer,
    // with the documented gauge invariants.
    assert_eq!(w.health.len(), 2);
    assert_eq!(w.health[0].session, s1.min(s2), "sorted by session id");
    for h in &w.health {
        assert_eq!(h.layers.len(), DIMS.len());
        for l in &h.layers {
            assert!(l.z_norm > 0.0, "ingested sketch must be nonzero");
            assert!(l.top_sigma > 0.0 && l.top_sigma <= l.z_norm * (1.0 + 1e-9));
            assert!(l.stable_rank >= 1.0 - 1e-9);
        }
    }

    // The journal saw the lifecycle: the connection accept and both
    // opens, in timestamp order, nothing dropped.
    let ev = client.events(0).unwrap();
    assert_eq!(ev.dropped, 0);
    assert!(ev.base_unix_ms > 0);
    let opens = ev
        .events
        .iter()
        .filter(|e| e.kind == kind::SESSION_OPEN)
        .count();
    assert_eq!(opens, 2);
    assert!(ev
        .events
        .iter()
        .any(|e| matches!(e.unpack(), Some(EventKind::ShardAccept { .. }))));
    assert!(
        ev.events.windows(2).all(|p| p[0].ts_ns <= p[1].ts_ns),
        "merged journal must be chronological"
    );

    // Scrape: same exact totals, and the window balance terms the CI
    // leg asserts telescope to the lifetime counter.
    let (status, body) = http_get(obs_addr, "/metrics");
    assert_eq!(status, "HTTP/1.1 200 OK");
    assert_eq!(metric(&body, "sketchd_ingest_frames_total"), 9);
    assert_eq!(metric(&body, "sketchd_ingest_bytes_total"), bytes);
    assert_eq!(metric(&body, "sketchd_sessions_open"), 2);
    assert_eq!(labeled_sum(&body, "sketchd_busy_total"), 0);
    let balance = metric(&body, "sketchd_window_frames_baseline")
        + metric(&body, "sketchd_window_frames_evicted")
        + metric(&body, "sketchd_window_frames_retained")
        + metric(&body, "sketchd_window_frames_open");
    assert_eq!(balance, 9, "window terms must telescope to the counter");
    assert_eq!(
        metric(&body, "sketchd_journal_dropped_total"),
        0,
        "nothing dropped in a roomy journal"
    );
    // The scrape recomputes health from the same resident sketches, so
    // the gauge values match the protocol reply bit for bit.
    for h in &w.health {
        let line = format!(
            "sketchd_session_z_norm{{session=\"{}\",name=\"{}\",layer=\"0\"}} {}",
            h.session, h.name, h.layers[0].z_norm
        );
        assert!(body.contains(&line), "missing {line:?} in:\n{body}");
    }

    let (status, events_body) = http_get(obs_addr, "/events");
    assert_eq!(status, "HTTP/1.1 200 OK");
    assert!(events_body.starts_with("# sketchd event journal:"));
    assert!(events_body.contains(&format!("session-open session={s1}")));

    let (status, _) = http_get(obs_addr, "/nope");
    assert_eq!(status, "HTTP/1.1 404 Not Found");

    client.session(s1).close().unwrap();
    client.session(s2).close().unwrap();
    let ev = client.events(0).unwrap();
    let closes = ev
        .events
        .iter()
        .filter(|e| e.kind == kind::SESSION_CLOSE)
        .count();
    assert_eq!(closes, 2);

    handle.stop().unwrap();
    let _ = std::fs::remove_file(&snap_path);
}

/// A journal ring of 4 slots per writer under 12 open/close cycles:
/// retention is exactly the ring capacity, the dropped counter is the
/// exact overflow, and the scrape's journal totals agree with the
/// protocol reply (`retained + dropped == emitted`).
#[test]
fn tiny_journal_drops_exactly_and_totals_balance() {
    const DIMS: &[usize] = &[16, 8];
    let obs = ObsConfig {
        journal_capacity: 4,
        ..obs_on()
    };
    let daemon = Daemon::bind(test_config("drops", 1, obs)).unwrap();
    let addr = daemon.local_addr().unwrap().to_string();
    let snap_path = unique_snapshot_path("drops");
    let handle = daemon.spawn().unwrap();
    let obs_addr = handle.obs_addr().unwrap();

    let (mut client, _info) = SketchClient::connect(&addr).unwrap();
    for i in 0..12 {
        let id = client
            .open_session(&spec(&format!("churn-{i}"), DIMS, i))
            .unwrap()
            .id();
        client.session(id).close().unwrap();
    }

    // Shard 0's writer has seen exactly 1 accept + 12 opens + 12
    // closes; the control writer is idle (no snapshots, no failures).
    let ev = client.events(0).unwrap();
    assert_eq!(ev.events.len(), 4, "retention is exactly the capacity");
    assert_eq!(ev.dropped, 25 - 4, "dropped is the exact overflow");

    let (_, body) = http_get(obs_addr, "/metrics");
    assert_eq!(metric(&body, "sketchd_journal_events_total"), 25);
    assert_eq!(metric(&body, "sketchd_journal_dropped_total"), 21);

    // `max` caps from the newest side.
    let ev2 = client.events(2).unwrap();
    assert_eq!(ev2.events.len(), 2);
    assert_eq!(
        ev2.events.last().map(|e| (e.ts_ns, e.kind, e.a)),
        ev.events.last().map(|e| (e.ts_ns, e.kind, e.a)),
        "capped read keeps the newest events"
    );

    handle.stop().unwrap();
    let _ = std::fs::remove_file(&snap_path);
}

/// Four shards, five connections: accounting stays exact across the
/// sharded journal and windows — per-shard scrape counters and the
/// window ring both sum to the client's frame total, and every shard's
/// writer journaled its accepts.
#[test]
fn four_shard_obs_accounting_stays_exact() {
    const DIMS: &[usize] = &[16, 8];
    let daemon = Daemon::bind(test_config("shards", 4, obs_on())).unwrap();
    let addr = daemon.local_addr().unwrap().to_string();
    let snap_path = unique_snapshot_path("shards");
    let handle = daemon.spawn().unwrap();
    let obs_addr = handle.obs_addr().unwrap();

    // Four tenant connections (round-robin lands one per shard), three
    // ingests each, all complete before the control captures.
    let mut bytes = 0u64;
    for t in 0..4u64 {
        let (mut client, _info) = SketchClient::connect(&addr).unwrap();
        let id = client
            .open_session(&spec(&format!("t{t}"), DIMS, t))
            .unwrap()
            .id();
        let mut stream = ActStream::new(DIMS, false, t);
        for step in 0..3 {
            let acts = stream.next_batch(4);
            bytes += ingest_payload_bytes(&acts);
            client
                .session(id)
                .ingest(stream.loss_at(step, 3), &acts, false)
                .unwrap();
        }
    }

    let (mut control, _info) = SketchClient::connect(&addr).unwrap();
    let w = control.metrics_window().unwrap();
    let m = control.metrics().unwrap();
    assert_eq!(w.report.total().ingest_frames, 12);
    assert_eq!(m.ingest.count, 12);
    assert_eq!(w.report.total().ingest_bytes, bytes);
    assert_eq!(w.health.len(), 4, "sessions outlive their connections");

    let ev = control.events(0).unwrap();
    assert_eq!(ev.dropped, 0);
    let accept_slots: std::collections::BTreeSet<u32> = ev
        .events
        .iter()
        .filter(|e| e.kind == kind::SHARD_ACCEPT)
        .map(|e| e.slot)
        .collect();
    assert_eq!(
        accept_slots.len(),
        4,
        "round-robin accept must journal on every shard: {accept_slots:?}"
    );

    let (_, body) = http_get(obs_addr, "/metrics");
    assert_eq!(
        labeled_sum(&body, "sketchd_shard_ingest_frames_total"),
        12,
        "per-shard scrape counters must sum to the client total"
    );
    let balance = metric(&body, "sketchd_window_frames_baseline")
        + metric(&body, "sketchd_window_frames_evicted")
        + metric(&body, "sketchd_window_frames_retained")
        + metric(&body, "sketchd_window_frames_open");
    assert_eq!(balance, 12);

    handle.stop().unwrap();
    let _ = std::fs::remove_file(&snap_path);
}

/// The v5 ops are cleanly version-gated: raw v4 `Events` and
/// `MetricsWindow` frames get a typed `UnsupportedVersion` error (not
/// a hangup), while v4 `Metrics` on the same connection still works.
#[test]
fn obs_ops_are_version_gated_below_v5() {
    let daemon =
        Daemon::bind(test_config("gate", 1, ObsConfig::default())).unwrap();
    let addr = daemon.local_addr().unwrap().to_string();
    let snap_path = unique_snapshot_path("gate");
    let handle = daemon.spawn().unwrap();

    let mut raw = TcpStream::connect(&addr).unwrap();
    for req in [Request::Events { max: 0 }, Request::MetricsWindow] {
        proto::write_frame_versioned(&mut raw, 4, req.msg_type(), &req.encode())
            .unwrap();
        let (header, payload) = proto::read_frame(&mut raw).unwrap();
        assert_eq!(header.version, 4, "reply echoes the request version");
        match Response::decode_v(header.msg, &payload, header.version).unwrap()
        {
            Response::Error { code, .. } => {
                assert_eq!(code, ErrorCode::UnsupportedVersion)
            }
            other => panic!("expected Error, got {other:?}"),
        }
    }

    // The same v4 connection keeps working for v4-era ops.
    let metrics = Request::Metrics;
    proto::write_frame_versioned(
        &mut raw,
        4,
        metrics.msg_type(),
        &metrics.encode(),
    )
    .unwrap();
    let (header, payload) = proto::read_frame(&mut raw).unwrap();
    match Response::decode_v(header.msg, &payload, header.version).unwrap() {
        Response::MetricsOk(report) => {
            assert!(report.frames_served >= 2, "the two rejections counted")
        }
        other => panic!("expected MetricsOk, got {other:?}"),
    }

    handle.stop().unwrap();
    let _ = std::fs::remove_file(&snap_path);
}
