//! End-to-end smoke tests for the loadgen harness: tiny scenarios
//! against a real in-process daemon must account for every frame
//! (client counters vs the daemon's v3 metrics, cross-checked inside
//! `run_scenario`), exercise the Busy/retry path under a tiny quota,
//! and emit a `BENCH_serve.json` whose keys the CI gate can read.

use sketchgrad::config::{ArchiveConfig, ClientConfig, ObsConfig, ServeConfig};
use sketchgrad::loadgen::{
    run_scenario, write_report, DaemonDelta, Scenario, ScenarioReport,
};
use sketchgrad::serve::obs::{WindowBucket, WindowReport, WindowTotals};
use sketchgrad::serve::{Daemon, Histogram, ShardStats};
use sketchgrad::util::json::Json;

/// Run `sc` against a fresh daemon on an ephemeral port (quota from
/// `sc.quota`, throwaway snapshot path, `shards` connection shards).
fn run_on_spawned(sc: &Scenario, shards: usize) -> ScenarioReport {
    let snap = std::env::temp_dir()
        .join(format!(
            "sketchd-lg-{}-{}.snap",
            sc.name,
            std::process::id()
        ))
        .to_string_lossy()
        .into_owned();
    let _ = std::fs::remove_file(&snap);
    let daemon = Daemon::bind(ServeConfig {
        addr: "127.0.0.1:0".into(),
        max_sessions: sc.tenants * 2 + 4,
        snapshot_interval_secs: 0,
        session_quota_bytes: sc.quota,
        snapshot_path: snap.clone(),
        threads: 1,
        shards,
        archive: ArchiveConfig::default(),
        obs: ObsConfig::default(),
        fault: String::new(),
    })
    .unwrap();
    let addr = daemon.local_addr().unwrap().to_string();
    let handle = daemon.spawn().unwrap();
    let rep = run_scenario(&addr, sc, &ClientConfig::default()).unwrap();
    handle.stop().unwrap();
    let _ = std::fs::remove_file(&snap);
    rep
}

/// Unthrottled steady traffic: every interval lands, nothing is Busy,
/// and the daemon-metrics cross-check inside `run_scenario` holds.
#[test]
fn tiny_steady_scenario_accounts_for_every_frame() {
    let sc = Scenario {
        name: "it-steady".into(),
        tenants: 3,
        intervals: 8,
        layer_dims: vec![16, 8],
        batch: 4,
        hz: 0.0,
        ..Scenario::default()
    };
    let rep = run_on_spawned(&sc, 1);
    assert_eq!(rep.ingests_ok, 24);
    assert_eq!(rep.ingest_frames_sent, 24);
    assert_eq!(rep.busy, 0);
    assert_eq!(rep.dropped, 0);
    assert_eq!(rep.ingest_hist.count, 24);
    assert!(rep.throughput() > 0.0);
    assert!(rep.bytes_sent > 0);
    let delta = rep.daemon.expect("v3 daemon must yield a metrics delta");
    assert_eq!(delta.ingest_frames, 24);
    assert_eq!(delta.ingest_bytes, rep.bytes_sent);
    assert_eq!(delta.busy, 0);
    assert!(delta.frames_served >= 24, "at least the ingest replies");
    assert_eq!(rep.shard_stats.len(), 1, "v4 daemon reports its shard");
    assert_eq!(rep.shard_stats[0].ingest_frames, 24);
    assert_eq!(rep.shard_p99_skew(), None, "one shard has no skew");
    // v5: the client window series accounts for every successful
    // ingest, and run_scenario already proved the daemon's window-ring
    // sums equal its lifetime counters (else it would have failed).
    assert_eq!(rep.win_ok.iter().sum::<u64>(), rep.ingests_ok);
    let w = rep
        .daemon_windows
        .as_ref()
        .expect("v5 daemon must yield a window report");
    assert_eq!(w.total().ingest_frames, 24);
}

/// A 4-shard daemon under mixed churn traffic: the frame/byte
/// cross-check still balances exactly, per-shard ingest frames sum to
/// the client total, and every shard carried work (round-robin accept
/// spreads the tenants).
#[test]
fn four_shard_daemon_keeps_accounting_exact_and_balanced() {
    let sc = Scenario {
        name: "it-shards".into(),
        tenants: 8,
        intervals: 6,
        layer_dims: vec![16, 8],
        batch: 4,
        hz: 0.0,
        churn_every: 3,
        ..Scenario::default()
    };
    let rep = run_on_spawned(&sc, 4);
    assert_eq!(rep.ingests_ok, 48);
    let delta = rep.daemon.expect("metrics cross-check must run");
    assert_eq!(delta.ingest_frames, rep.ingest_frames_sent);
    assert_eq!(delta.ingest_bytes, rep.bytes_sent);
    assert_eq!(rep.shard_stats.len(), 4);
    let summed: u64 =
        rep.shard_stats.iter().map(|s| s.ingest_frames).sum();
    assert_eq!(
        summed, rep.ingest_frames_sent,
        "per-shard ingest frames must sum to the client total"
    );
    assert!(
        rep.shard_stats.iter().all(|s| s.ingest_frames > 0),
        "round-robin accept must land tenants on every shard: {:?}",
        rep.shard_stats
    );
    assert!(rep.shard_p99_skew().is_some(), "4 active shards have skew");
}

/// A quota small enough to trip every few intervals: Busy shows up in
/// the client counters, the post-Diagnose retry always lands, and the
/// byte cross-check still balances (rejected frames carry no bytes).
#[test]
fn tiny_quota_scenario_exercises_busy_retry_path() {
    let sc = Scenario {
        name: "it-busy".into(),
        tenants: 2,
        intervals: 10,
        layer_dims: vec![16, 8],
        batch: 4,
        hz: 0.0,
        quota: 4096,
        ..Scenario::default()
    };
    let rep = run_on_spawned(&sc, 1);
    assert!(rep.busy > 0, "workload must actually trip the quota");
    assert_eq!(rep.ingests_ok, 20, "every interval lands after retry");
    assert_eq!(rep.dropped, 0);
    assert!(rep.busy_rate() > 0.0 && rep.busy_rate() < 1.0);
    // Each Busy forced a quota-draining diagnose.
    assert!(rep.queries >= rep.busy);
    let delta = rep.daemon.unwrap();
    assert_eq!(delta.busy, rep.busy);
    assert_eq!(delta.ingest_bytes, rep.bytes_sent);
}

/// Churn, periodic queries and snapshot requests all ride along without
/// breaking the frame/byte accounting.
#[test]
fn churn_query_snapshot_mix_keeps_accounting_exact() {
    let sc = Scenario {
        name: "it-mix".into(),
        tenants: 2,
        intervals: 9,
        layer_dims: vec![16, 8],
        batch: 4,
        hz: 0.0,
        query_every: 2,
        churn_every: 3,
        snapshot_every: 4,
        ..Scenario::default()
    };
    let rep = run_on_spawned(&sc, 1);
    assert_eq!(rep.ingests_ok, 18);
    assert!(rep.queries > 0);
    assert_eq!(rep.reopens, 2 * 2, "two churns per tenant (not the last)");
    assert!(rep.snapshots >= 1, "tenant 0 snapshots every 4 intervals");
    let delta = rep.daemon.unwrap();
    assert!(delta.snapshot_count >= rep.snapshots);
    assert_eq!(delta.ingest_frames, rep.ingest_frames_sent);
}

/// `write_report` emits the exact keys the CI `shard-smoke` gate greps:
/// per-scenario latency rows with p99/max and the flat summary scalars.
#[test]
fn report_json_has_the_keys_the_ci_gate_reads() {
    let mut ingest_hist = Histogram::default();
    for ns in [900u64, 2_000, 15_000, 1_200_000] {
        ingest_hist.record(ns);
    }
    let mut query_hist = Histogram::default();
    query_hist.record(30_000);
    let rep = ScenarioReport {
        name: "x".into(),
        tenants: 2,
        intervals: 2,
        wall: std::time::Duration::from_millis(80),
        ingests_ok: 4,
        ingest_frames_sent: 5,
        busy: 1,
        dropped: 0,
        queries: 1,
        reopens: 0,
        snapshots: 1,
        bytes_sent: 4096,
        ingest_hist,
        query_hist,
        daemon: Some(DaemonDelta {
            ingest_frames: 5,
            frames_served: 12,
            ingest_bytes: 4096,
            busy: 1,
            snapshot_count: 1,
            snapshot_pause: std::time::Duration::from_millis(3),
        }),
        shard_stats: vec![
            ShardStats {
                shard: 0,
                ingest_frames: 3,
                ingest_p99_ns: 9_000,
                ..ShardStats::default()
            },
            ShardStats {
                shard: 1,
                ingest_frames: 2,
                ingest_p99_ns: 3_000,
                ..ShardStats::default()
            },
        ],
        win_ok: vec![3, 1],
        daemon_windows: Some(WindowReport {
            interval_ms: 1000,
            capacity: 120,
            baseline: WindowTotals::default(),
            evicted: WindowTotals::default(),
            buckets: vec![WindowBucket {
                index: 0,
                dur_ms: 1000,
                ingest_frames: 5,
                ..WindowBucket::default()
            }],
            open: WindowBucket {
                index: 1,
                ..WindowBucket::default()
            },
        }),
    };
    let path = std::env::temp_dir()
        .join(format!("bench-serve-it-{}.json", std::process::id()))
        .to_string_lossy()
        .into_owned();
    write_report(&[rep], true, &path).unwrap();

    let parsed =
        Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    assert_eq!(parsed.get("title").unwrap().as_str().unwrap(), "serve_load");
    assert_eq!(parsed.get("quick").unwrap(), &Json::Bool(true));
    assert_eq!(
        parsed.get("scenarios").unwrap().as_f64().unwrap(),
        1.0
    );
    assert!(parsed.get("x_throughput").unwrap().as_f64().unwrap() > 0.0);
    let busy_rate = parsed.get("x_busy_rate").unwrap().as_f64().unwrap();
    assert!((busy_rate - 0.2).abs() < 1e-9, "1 busy of 5 frames");
    assert!(parsed.get("x_p99_ms").unwrap().as_f64().unwrap() > 0.0);
    assert_eq!(
        parsed.get("x_metrics_verified").unwrap().as_f64().unwrap(),
        1.0
    );
    assert!(
        parsed.get("x_snapshot_pause_ms").unwrap().as_f64().unwrap() > 0.0
    );
    assert_eq!(parsed.get("x_shards").unwrap().as_f64().unwrap(), 2.0);
    let skew = parsed.get("x_shard_p99_skew").unwrap().as_f64().unwrap();
    assert!((skew - 3.0).abs() < 1e-9, "9us/3us skew, got {skew}");
    assert_eq!(
        parsed.get("x_window_verified").unwrap().as_f64().unwrap(),
        1.0
    );
    assert_eq!(
        parsed.get("x_client_windows").unwrap().as_f64().unwrap(),
        2.0
    );
    assert_eq!(
        parsed
            .get("x_win0_ingests_per_s")
            .unwrap()
            .as_f64()
            .unwrap(),
        3.0
    );
    assert_eq!(
        parsed
            .get("x_win1_ingests_per_s")
            .unwrap()
            .as_f64()
            .unwrap(),
        1.0
    );
    let results = parsed.get("results").unwrap().as_arr().unwrap();
    assert_eq!(results.len(), 2, "ingest + query rows");
    assert_eq!(
        results[0].get("name").unwrap().as_str().unwrap(),
        "x_ingest"
    );
    let p99 = results[0].get("p99_ns").unwrap().as_f64().unwrap();
    let max = results[0].get("max_ns").unwrap().as_f64().unwrap();
    assert!(max >= p99 && p99 > 0.0);
    assert!(results[0].get("throughput").unwrap().as_f64().unwrap() > 0.0);
    assert_eq!(
        results[1].get("name").unwrap().as_str().unwrap(),
        "x_query"
    );
    let _ = std::fs::remove_file(&path);
}
