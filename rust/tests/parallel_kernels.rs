//! Parallel-vs-serial equivalence for the kernel subsystem: the
//! `Parallelism` knob must be a pure throughput knob.  Engines configured
//! with 1/2/4 worker threads and fed identical streams (heterogeneous
//! widths, multiple ranks, tail batches) must hold triplet state and
//! reconstructions within 1e-12 of the serial engine — the kernel
//! determinism contract says bitwise, the Lemma-4.1 property tests rely
//! on at most 1e-12.

use sketchgrad::sketch::kernel;
use sketchgrad::sketch::{Mat, Parallelism, SketchConfig, SketchEngine, Sketcher};
use sketchgrad::util::prop::Prop;
use sketchgrad::util::rng::Rng;

fn engine(dims: &[usize], rank: usize, threads: usize) -> SketchEngine {
    SketchConfig::builder()
        .layer_dims(dims)
        .rank(rank)
        .beta(0.9)
        .seed(17)
        .threads(threads)
        .build_engine()
        .unwrap()
}

fn acts(n_b: usize, dims: &[usize], rng: &mut Rng) -> Vec<Mat> {
    let mut out = vec![Mat::gaussian(n_b, dims[0], rng)];
    for &d in dims {
        out.push(Mat::gaussian(n_b, d, rng));
    }
    out
}

#[test]
fn parallel_ingest_equals_serial_across_thread_counts() {
    // Heterogeneous widths, ranks 2/4, a nominal and a tail batch size,
    // across 1/2/4 threads — the satellite's exact matrix.
    let dims = [48usize, 32, 24, 16];
    for rank in [2usize, 4] {
        let mut serial = engine(&dims, rank, 1);
        let mut threaded: Vec<SketchEngine> =
            [2usize, 4].iter().map(|&t| engine(&dims, rank, t)).collect();
        let mut rng = Rng::new(100 + rank as u64);
        for step in 0..6 {
            // Every third batch is a tail batch (n_b 7 instead of 20).
            let n_b = if step % 3 == 2 { 7 } else { 20 };
            let batch = acts(n_b, &dims, &mut rng);
            serial.ingest(&batch).unwrap();
            for e in &mut threaded {
                e.ingest(&batch).unwrap();
            }
        }
        for (i, e) in threaded.iter().enumerate() {
            let diff = serial.max_state_diff(e);
            assert!(
                diff <= 1e-12,
                "rank {rank}, {} threads: triplet diff {diff:.2e}",
                [2, 4][i]
            );
            for layer in 0..dims.len() {
                let rs = serial.reconstruct(layer).unwrap();
                let rp = e.reconstruct(layer).unwrap();
                let rdiff = rs.max_abs_diff(&rp);
                assert!(
                    rdiff <= 1e-12,
                    "rank {rank}, layer {layer}: reconstruct diff {rdiff:.2e}"
                );
            }
        }
    }
}

#[test]
fn single_layer_engine_uses_intra_kernel_parallelism() {
    // One layer means the fan-out seam has nothing to split; the pool
    // must flow into the projection products instead, same numerics.
    let dims = [96usize];
    let mut serial = engine(&dims, 4, 1);
    let mut par = engine(&dims, 4, 4);
    let mut rng = Rng::new(5);
    for _ in 0..4 {
        let batch = acts(64, &dims, &mut rng);
        serial.ingest(&batch).unwrap();
        par.ingest(&batch).unwrap();
    }
    assert!(serial.max_state_diff(&par) <= 1e-12);
}

#[test]
fn kernel_products_match_serial_property() {
    // One persistent pool per lane count, reused across every property
    // iteration — repeated pool reuse is part of the property.
    let pools = [kernel::Pool::with_lanes(2), kernel::Pool::with_lanes(4)];
    Prop::new(24).check("kernel_parity", |rng, i| {
        let m = 1 + (i % 40);
        let k = 1 + (i * 7) % 150;
        let n = 1 + (i * 3) % 30;
        let a = Mat::gaussian(m, k, rng);
        let b = Mat::gaussian(k, n, rng);
        let c = Mat::gaussian(m, n, rng);
        let serial = kernel::Pool::serial();
        for pool in &pools {
            let lanes = pool.lanes();
            let mm = kernel::matmul(&a, &b, pool)
                .max_abs_diff(&kernel::matmul(&a, &b, serial));
            if mm > 0.0 {
                return Err(format!(
                    "matmul not bitwise at {lanes} lanes: {mm:.2e}"
                ));
            }
            let tm = kernel::t_matmul(&a, &c, pool)
                .max_abs_diff(&kernel::t_matmul(&a, &c, serial));
            if tm > 0.0 {
                return Err(format!(
                    "t_matmul not bitwise at {lanes} lanes: {tm:.2e}"
                ));
            }
            let mt = kernel::matmul_t(&b, &c, pool)
                .max_abs_diff(&kernel::matmul_t(&b, &c, serial));
            if mt > 0.0 {
                return Err(format!(
                    "matmul_t not bitwise at {lanes} lanes: {mt:.2e}"
                ));
            }
            // The persistent-pool kernels must also agree with the
            // PR3-era spawn-per-call reference bitwise.
            let sc = kernel::t_matmul(&a, &c, pool)
                .max_abs_diff(&kernel::scoped::t_matmul(&a, &c, lanes));
            if sc > 0.0 {
                return Err(format!(
                    "pool vs scoped not bitwise at {lanes} lanes: {sc:.2e}"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn builder_exposes_the_knob() {
    let cfg = SketchConfig::builder()
        .layer_dims(&[8])
        .threads(4)
        .build()
        .unwrap();
    assert_eq!(cfg.parallelism, Parallelism::Threads(4));
    let cfg = SketchConfig::builder()
        .layer_dims(&[8])
        .threads(1)
        .build()
        .unwrap();
    assert_eq!(cfg.parallelism, Parallelism::Serial);
    // set_rank must keep the worker pool.
    let mut e = engine(&[8, 8], 2, 4);
    e.set_rank(4);
    assert_eq!(e.config().parallelism, Parallelism::Threads(4));
}
