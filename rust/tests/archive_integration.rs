//! Loopback integration tests for the archive subsystem (DESIGN.md §7):
//! a remote session records 64+ sketch intervals through a capacity-48
//! ring (forcing oldest-first eviction over the wire), every analytics
//! query — trajectory, similarity, drift, archive info — answers
//! bit-for-bit identically to an in-process replica, and a daemon
//! kill -> warm-restart serves the *same* answers from the ring restored
//! out of the durable snapshot.

use sketchgrad::archive::SessionArchive;
use sketchgrad::config::{ArchiveConfig, ObsConfig, ServeConfig};
use sketchgrad::data::ActStream;
use sketchgrad::serve::proto::SessionSpec;
use sketchgrad::serve::{Daemon, Error, SketchClient};
use sketchgrad::sketch::{Mat, SketchConfig, SketchEngine, Sketcher};

const DIMS: [usize; 2] = [20, 10];
const RANK: usize = 2;
const STEPS: usize = 70;
const CAPACITY: usize = 48;
const N_B: usize = 16;
const SEED: u64 = 0xA7C4;

fn snapshot_path(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("sketchd-arc-{tag}-{}.snap", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

fn config(tag: &str, capacity: usize, stride: usize) -> ServeConfig {
    ServeConfig {
        addr: "127.0.0.1:0".into(),
        max_sessions: 4,
        snapshot_interval_secs: 0,
        session_quota_bytes: 0,
        snapshot_path: snapshot_path(tag),
        threads: 1,
        shards: 1,
        archive: ArchiveConfig { capacity, stride },
        obs: ObsConfig::default(),
        fault: String::new(),
    }
}

fn spec() -> SessionSpec {
    SessionSpec {
        name: "archived".into(),
        layer_dims: DIMS.to_vec(),
        rank: RANK,
        beta: 0.9,
        seed: SEED,
        window: 10,
        collapse_frac: 0.25,
    }
}

/// In-process replica: same engine, same deterministic stream, same
/// archive ring parameters as the daemon-side tenant.
struct Replica {
    engine: SketchEngine,
    stream: ActStream,
    archive: SessionArchive,
}

impl Replica {
    fn new(capacity: usize, stride: usize) -> Replica {
        let engine = SketchConfig::builder()
            .layer_dims(&DIMS)
            .rank(RANK)
            .beta(0.9)
            .seed(SEED)
            .build_engine()
            .unwrap();
        let archive = SessionArchive::new(
            capacity,
            stride,
            engine.config().precision.bytes(),
        );
        Replica {
            engine,
            stream: ActStream::new(&DIMS, false, SEED),
            archive,
        }
    }

    fn step(&mut self, step: usize) -> (f32, Vec<Mat>) {
        let acts = self.stream.next_batch(N_B);
        let loss = self.stream.loss_at(step, STEPS);
        self.engine.ingest(&acts).unwrap();
        self.archive.maybe_record(
            self.engine.batches_ingested(),
            loss,
            self.engine.layers(),
        );
        (loss, acts)
    }
}

/// ACCEPTANCE: 70 remote intervals through a capacity-48 ring; eviction
/// over the wire; every query bit-identical to the replica; the restored
/// ring answers identically after kill -> restart, and keeps recording.
#[test]
fn archive_queries_bit_identical_across_eviction_and_restart() {
    let cfg = config("restart", CAPACITY, 1);
    let snap_path = cfg.snapshot_path.clone();

    let daemon = Daemon::bind(cfg.clone()).unwrap();
    let addr = daemon.local_addr().unwrap().to_string();
    let handle = daemon.spawn().unwrap();

    let mut replica = Replica::new(CAPACITY, 1);
    let session;
    let pre_traj;
    let mut pre_sims = Vec::new();
    let mut pre_drifts = Vec::new();
    let pre_info;
    {
        let (mut client, _info) = SketchClient::connect(&addr).unwrap();
        let mut sess = client.open_session(&spec()).unwrap();
        session = sess.id();
        for step in 0..STEPS {
            let (loss, acts) = replica.step(step);
            sess.ingest(loss, &acts, false).unwrap();
        }

        // 70 > 64 intervals seen; the ring holds the newest 48 with
        // oldest-first eviction (batch counter starts at 1).
        let info = sess.archive_info().unwrap();
        assert_eq!(info.seen, STEPS as u64);
        assert_eq!(info.intervals, CAPACITY as u64);
        assert_eq!(info.capacity, CAPACITY as u64);
        assert_eq!(info.stride, 1);
        assert_eq!(info.layers, DIMS.len() as u64);
        assert_eq!(info.oldest_step, (STEPS - CAPACITY + 1) as u64);
        assert_eq!(info.newest_step, STEPS as u64);
        assert_eq!(info.bytes, replica.archive.bytes() as u64);

        // Every analytics answer bit-identical to the replica.
        let traj = sess.query_trajectory().unwrap();
        assert_eq!(traj, replica.archive.trajectory());
        assert_eq!(traj.len(), CAPACITY);
        for layer in 0..DIMS.len() {
            let (steps, sim) = sess.query_similarity(layer).unwrap();
            let (local_steps, local_sim) = replica.archive.similarity(layer);
            assert_eq!(steps, local_steps, "layer {layer} steps");
            assert_eq!(sim, local_sim, "layer {layer} similarity");
            let drift = sess.query_drift(layer).unwrap();
            assert_eq!(drift, replica.archive.drift(layer), "layer {layer}");
            pre_sims.push((steps, sim));
            pre_drifts.push(drift);
        }
        pre_traj = traj;
        pre_info = info;

        // Out-of-range layer is a typed protocol error, not a hangup.
        match sess.query_drift(DIMS.len()) {
            Err(Error::Invalid(_)) => {}
            other => panic!("expected Invalid, got {other:?}"),
        }

        // Observability counters agree with the replica's accounting.
        let stats = sess.client().stats().unwrap();
        assert_eq!(stats.daemon.sessions, 1);
        assert!(stats.daemon.ingest_bytes > 0);
        assert!(stats.daemon.frames_served >= STEPS as u64);
        assert_eq!(
            stats.daemon.archive_bytes,
            replica.archive.bytes() as u64
        );
        let row = stats.sessions.iter().find(|s| s.id == session).unwrap();
        assert_eq!(row.name, "archived");
        assert_eq!(row.steps_seen, STEPS as u64);
        assert_eq!(row.ingest_bytes, stats.daemon.ingest_bytes);
        assert_eq!(row.archive_intervals, CAPACITY as u64);
        assert_eq!(row.archive_bytes, replica.archive.bytes() as u64);
    }
    handle.stop().unwrap();

    // Kill -> warm restart on the same snapshot path: the restored ring
    // must answer every query exactly as the pre-restart daemon did.
    let daemon = Daemon::bind(cfg).unwrap();
    assert_eq!(daemon.session_count(), 1, "session resumed from snapshot");
    let addr = daemon.local_addr().unwrap().to_string();
    let handle = daemon.spawn().unwrap();
    {
        let (mut client, info) = SketchClient::connect(&addr).unwrap();
        assert_eq!(info.sessions, 1);
        let mut sess = client.session(session);
        assert_eq!(sess.archive_info().unwrap(), pre_info);
        assert_eq!(sess.query_trajectory().unwrap(), pre_traj);
        for layer in 0..DIMS.len() {
            let (steps, sim) = sess.query_similarity(layer).unwrap();
            assert_eq!((steps, sim), pre_sims[layer], "layer {layer}");
            assert_eq!(
                sess.query_drift(layer).unwrap(),
                pre_drifts[layer],
                "layer {layer}"
            );
        }

        // Recording continues seamlessly on the restored ring.
        let (loss, acts) = replica.step(STEPS);
        sess.ingest(loss, &acts, false).unwrap();
        let info = sess.archive_info().unwrap();
        assert_eq!(info.seen, STEPS as u64 + 1);
        assert_eq!(info.newest_step, STEPS as u64 + 1);
        assert_eq!(
            sess.query_trajectory().unwrap(),
            replica.archive.trajectory()
        );
    }
    handle.stop().unwrap();
    let _ = std::fs::remove_file(&snap_path);
}

/// Stride sampling over the wire: a stride-4 daemon records every 4th
/// ingest interval; the trajectory exposes exactly the sampled steps.
#[test]
fn stride_sampling_over_the_wire() {
    let daemon = Daemon::bind(config("stride", 8, 4)).unwrap();
    let addr = daemon.local_addr().unwrap().to_string();
    let snap_path = snapshot_path("stride");
    let handle = daemon.spawn().unwrap();

    let mut replica = Replica::new(8, 4);
    let (mut client, _info) = SketchClient::connect(&addr).unwrap();
    let mut sess = client.open_session(&spec()).unwrap();
    for step in 0..20 {
        let (loss, acts) = replica.step(step);
        sess.ingest(loss, &acts, false).unwrap();
    }

    let info = sess.archive_info().unwrap();
    assert_eq!(info.seen, 20);
    assert_eq!(info.intervals, 5);
    let traj = sess.query_trajectory().unwrap();
    let steps: Vec<u64> = traj.iter().map(|p| p.step).collect();
    assert_eq!(steps, vec![1, 5, 9, 13, 17]);
    assert_eq!(traj, replica.archive.trajectory());

    handle.stop().unwrap();
    let _ = std::fs::remove_file(&snap_path);
}
