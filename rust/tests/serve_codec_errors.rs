//! Codec error paths, both directions: a daemon fed garbage, oversized
//! or truncated frames must reply with a typed `BadFrame` (when the
//! framing is still trustworthy) or drop the connection — never panic —
//! and keep serving fresh clients; a client fed malformed replies must
//! surface typed serve `Error`s, never hang or panic.  Snapshot files
//! with a flipped payload bit must be rejected by CRC at bind time.

use std::io::{Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::time::Duration;

use sketchgrad::config::{ArchiveConfig, ClientConfig, ObsConfig, ServeConfig};
use sketchgrad::data::ActStream;
use sketchgrad::serve::proto::{
    self, ErrorCode, FrameHeader, Response, SessionSpec, FRAME_HEADER_LEN,
    MAX_FRAME_LEN, PROTO_VERSION,
};
use sketchgrad::serve::{Daemon, Error, SketchClient};

fn test_config(tag: &str, quota: usize) -> ServeConfig {
    ServeConfig {
        addr: "127.0.0.1:0".into(),
        max_sessions: 4,
        snapshot_interval_secs: 0,
        session_quota_bytes: quota,
        snapshot_path: std::env::temp_dir()
            .join(format!("sketchd-ce-{tag}-{}.snap", std::process::id()))
            .to_string_lossy()
            .into_owned(),
        threads: 1,
        shards: 1,
        archive: ArchiveConfig::default(),
        obs: ObsConfig::default(),
        fault: String::new(),
    }
}

/// The peer hung up on us (EOF or reset) — the daemon's response to an
/// untrustworthy frame.  A timeout means it is still holding the
/// connection open, which would hang real clients.
fn assert_closed(stream: &mut TcpStream) {
    stream
        .set_read_timeout(Some(Duration::from_secs(5)))
        .unwrap();
    let mut buf = [0u8; 64];
    loop {
        match stream.read(&mut buf) {
            Ok(0) => return,
            Ok(_) => continue, // drain any in-flight reply bytes
            Err(e) => match e.kind() {
                std::io::ErrorKind::TimedOut
                | std::io::ErrorKind::WouldBlock => {
                    panic!("daemon kept a poisoned connection open")
                }
                _ => return, // reset/aborted: also closed
            },
        }
    }
}

/// Frames the daemon cannot trust (bad magic, oversized length prefix,
/// truncated payload) close the connection without a reply; frames with
/// sound framing but undecodable payloads get a typed `BadFrame` reply.
/// The daemon survives all of it and keeps serving fresh clients.
#[test]
fn daemon_rejects_malformed_frames_without_panicking() {
    let cfg = test_config("daemon", 0);
    let snap_path = cfg.snapshot_path.clone();
    let daemon = Daemon::bind(cfg).unwrap();
    let addr = daemon.local_addr().unwrap().to_string();
    let handle = daemon.spawn().unwrap();

    // Garbage where the frame magic should be: silent close.
    let mut s = TcpStream::connect(&addr).unwrap();
    s.write_all(&[0xAAu8; FRAME_HEADER_LEN]).unwrap();
    assert_closed(&mut s);

    // Valid magic, length prefix over the protocol cap: the daemon
    // must refuse to allocate and close instead.
    let mut s = TcpStream::connect(&addr).unwrap();
    let header =
        FrameHeader::encode(PROTO_VERSION, proto::msg::DIAGNOSE, MAX_FRAME_LEN + 1);
    s.write_all(&header).unwrap();
    assert_closed(&mut s);

    // Header promises 100 payload bytes, the peer sends 10 and hangs
    // up: the partial frame is dropped, the connection closed.
    let mut s = TcpStream::connect(&addr).unwrap();
    let header = FrameHeader::encode(PROTO_VERSION, proto::msg::DIAGNOSE, 100);
    s.write_all(&header).unwrap();
    s.write_all(&[0u8; 10]).unwrap();
    s.shutdown(Shutdown::Write).unwrap();
    assert_closed(&mut s);

    // Sound framing, undecodable OpenSession payload (string length
    // prefix pointing past the end): typed BadFrame reply, then close.
    let mut s = TcpStream::connect(&addr).unwrap();
    proto::write_frame_versioned(
        &mut s,
        PROTO_VERSION,
        proto::msg::OPEN_SESSION,
        &[7, 0, 0, 0],
    )
    .unwrap();
    let (header, payload) = proto::read_frame(&mut s).unwrap();
    match Response::decode_v(header.msg, &payload, header.version).unwrap() {
        Response::Error { code, .. } => assert_eq!(code, ErrorCode::BadFrame),
        other => panic!("expected BadFrame, got {other:?}"),
    }
    assert_closed(&mut s);

    // A trailing byte after a well-formed Diagnose body is also a
    // framing lie -> BadFrame (strict decode, no silent slack).
    let mut s = TcpStream::connect(&addr).unwrap();
    let mut body = 1u64.to_le_bytes().to_vec();
    body.push(0);
    proto::write_frame_versioned(&mut s, PROTO_VERSION, proto::msg::DIAGNOSE, &body)
        .unwrap();
    let (header, payload) = proto::read_frame(&mut s).unwrap();
    match Response::decode_v(header.msg, &payload, header.version).unwrap() {
        Response::Error { code, message } => {
            assert_eq!(code, ErrorCode::BadFrame);
            assert!(message.contains("trailing"), "{message}");
        }
        other => panic!("expected BadFrame, got {other:?}"),
    }
    assert_closed(&mut s);

    // After all that abuse, a fresh well-behaved client still works.
    let (mut client, _info) = SketchClient::connect(&addr).unwrap();
    let mut sess = client
        .open_session(&SessionSpec {
            name: "survivor".into(),
            layer_dims: vec![16, 8],
            rank: 3,
            beta: 0.9,
            seed: 1,
            window: 8,
            collapse_frac: 0.25,
        })
        .unwrap();
    sess.diagnose().unwrap();
    sess.close().unwrap();

    handle.stop().unwrap();
    let _ = std::fs::remove_file(&snap_path);
}

/// A fake server that reads the client's Hello frame, writes `reply`
/// verbatim, then closes.
fn fake_server(reply: Vec<u8>) -> (String, std::thread::JoinHandle<()>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let h = std::thread::spawn(move || {
        if let Ok((mut s, _)) = listener.accept() {
            let mut hdr = [0u8; FRAME_HEADER_LEN];
            if s.read_exact(&mut hdr).is_err() {
                return;
            }
            let len =
                u32::from_le_bytes(hdr[8..12].try_into().unwrap()) as usize;
            let mut payload = vec![0u8; len.min(MAX_FRAME_LEN as usize)];
            if s.read_exact(&mut payload).is_err() {
                return;
            }
            let _ = s.write_all(&reply);
            let _ = s.flush();
            std::thread::sleep(Duration::from_millis(100));
        }
    });
    (addr, h)
}

fn impatient() -> ClientConfig {
    ClientConfig {
        connect_timeout_ms: 1000,
        io_timeout_ms: 1000,
        connect_retries: 0,
        retry_backoff_ms: 10,
    }
}

/// Malformed replies surface as typed client errors — Io for broken
/// framing, Protocol for out-of-range versions and undecodable
/// payloads — never a panic or hang.
#[test]
fn client_turns_malformed_replies_into_typed_errors() {
    // Garbage where the reply's frame magic should be.
    let (addr, h) = fake_server(vec![0xAA; FRAME_HEADER_LEN]);
    match SketchClient::connect_with(&addr, &impatient()) {
        Err(Error::Io(_)) => {}
        other => panic!("bad magic: expected Io, got {other:?}"),
    }
    h.join().unwrap();

    // Valid framing claiming protocol version 99.
    let hdr = FrameHeader::encode(99, proto::msg::HELLO_OK, 0);
    let (addr, h) = fake_server(hdr.to_vec());
    match SketchClient::connect_with(&addr, &impatient()) {
        Err(Error::Protocol(msg)) => {
            assert!(msg.contains("version"), "{msg}")
        }
        other => panic!("version 99: expected Protocol, got {other:?}"),
    }
    h.join().unwrap();

    // Header promises 50 bytes, the server sends 10 and closes.
    let mut reply =
        FrameHeader::encode(PROTO_VERSION, proto::msg::HELLO_OK, 50).to_vec();
    reply.extend_from_slice(&[0u8; 10]);
    let (addr, h) = fake_server(reply);
    match SketchClient::connect_with(&addr, &impatient()) {
        Err(Error::Io(_)) | Err(Error::Timeout(_)) => {}
        other => panic!("truncated reply: expected Io, got {other:?}"),
    }
    h.join().unwrap();

    // Sound framing, undecodable HelloOk payload.
    let mut reply =
        FrameHeader::encode(PROTO_VERSION, proto::msg::HELLO_OK, 4).to_vec();
    reply.extend_from_slice(&[0xFF, 0xFF, 0xFF, 0xFF]);
    let (addr, h) = fake_server(reply);
    match SketchClient::connect_with(&addr, &impatient()) {
        Err(Error::Protocol(_)) => {}
        other => panic!("garbage payload: expected Protocol, got {other:?}"),
    }
    h.join().unwrap();

    // An oversized request payload is rejected client-side before any
    // bytes hit the wire (the peer could not trust the framing).
    let payload = vec![0u8; MAX_FRAME_LEN as usize + 1];
    let mut sink = Vec::new();
    let err = proto::write_frame_versioned(
        &mut sink,
        PROTO_VERSION,
        proto::msg::HELLO_OK,
        &payload,
    )
    .unwrap_err();
    assert_eq!(err.kind(), std::io::ErrorKind::InvalidInput);
    assert!(sink.is_empty(), "nothing may be written for a rejected frame");
}

/// A flipped payload byte in the snapshot file fails the CRC check at
/// bind time with a diagnosable error instead of resurrecting corrupt
/// session state.
#[test]
fn corrupt_snapshot_fails_bind_with_crc_error() {
    let cfg = test_config("crc", 0);
    let snap_path = cfg.snapshot_path.clone();
    let _ = std::fs::remove_file(&snap_path);

    let daemon = Daemon::bind(cfg.clone()).unwrap();
    let addr = daemon.local_addr().unwrap().to_string();
    let handle = daemon.spawn().unwrap();
    let (mut client, _info) = SketchClient::connect(&addr).unwrap();
    let mut sess = client
        .open_session(&SessionSpec {
            name: "crc".into(),
            layer_dims: vec![16, 8],
            rank: 3,
            beta: 0.9,
            seed: 9,
            window: 8,
            collapse_frac: 0.25,
        })
        .unwrap();
    let mut stream = ActStream::new(&[16, 8], false, 9);
    let acts = stream.next_batch(4);
    sess.ingest(0.5, &acts, false).unwrap();
    drop(client);
    handle.stop().unwrap(); // writes the shutdown snapshot

    let mut bytes = std::fs::read(&snap_path).unwrap();
    let last = bytes.len() - 1;
    bytes[last] ^= 0xFF;
    std::fs::write(&snap_path, &bytes).unwrap();

    let err = match Daemon::bind(cfg) {
        Err(e) => format!("{e:#}"),
        Ok(_) => panic!("bind accepted a corrupt snapshot"),
    };
    assert!(err.contains("CRC"), "{err}");
    let _ = std::fs::remove_file(&snap_path);
}
