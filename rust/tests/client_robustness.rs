//! Client-side robustness: connect/read timeouts and bounded
//! retry-with-backoff must turn dead or unresponsive peers into prompt
//! typed errors — never an indefinite hang.  A real daemon behind the
//! same timeout configuration keeps working normally.

use std::net::TcpListener;
use std::time::{Duration, Instant};

use sketchgrad::config::{ArchiveConfig, ClientConfig, ObsConfig, ServeConfig};
use sketchgrad::serve::{Daemon, Error, SketchClient};

fn impatient(retries: u32) -> ClientConfig {
    ClientConfig {
        connect_timeout_ms: 1000,
        io_timeout_ms: 200,
        connect_retries: retries,
        retry_backoff_ms: 10,
    }
}

/// A listener that accepts the TCP connection but never replies: the
/// Hello round trip must fail with `Error::Timeout` once the read
/// deadline passes, in bounded wall time.
#[test]
fn unresponsive_listener_times_out_with_typed_error() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let sink = std::thread::spawn(move || {
        // Hold the accepted socket without ever writing a byte; drop it
        // once the client has long since given up.
        if let Ok((stream, _)) = listener.accept() {
            std::thread::sleep(Duration::from_secs(2));
            drop(stream);
        }
    });

    let t0 = Instant::now();
    let res = SketchClient::connect_with(&addr, &impatient(0));
    let elapsed = t0.elapsed();
    match res {
        Err(Error::Timeout(_)) => {}
        Err(other) => panic!("expected Timeout, got {other:?}"),
        Ok(_) => panic!("connected to a server that never spoke"),
    }
    assert!(
        elapsed < Duration::from_secs(5),
        "timeout not bounded: {elapsed:?}"
    );
    sink.join().unwrap();
}

/// Nothing listening on the port: bounded retries with backoff, then a
/// typed error — the attempt loop must not spin forever.
#[test]
fn refused_connection_fails_after_bounded_retries() {
    // Bind then drop to get a loopback port that refuses connections.
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    drop(listener);

    let net = ClientConfig {
        retry_backoff_ms: 20,
        ..impatient(2)
    };
    let t0 = Instant::now();
    let res = SketchClient::connect_with(&addr, &net);
    let elapsed = t0.elapsed();
    match res {
        Err(Error::Io(_)) | Err(Error::Timeout(_)) => {}
        Err(other) => panic!("expected Io/Timeout, got {other:?}"),
        Ok(_) => panic!("connected to a dropped listener"),
    }
    assert!(
        elapsed < Duration::from_secs(5),
        "retry loop not bounded: {elapsed:?}"
    );
}

/// The same timeout configuration against a live daemon changes
/// nothing: handshake, metrics and clean close all succeed.
#[test]
fn timeouts_do_not_disturb_a_healthy_daemon() {
    let snap = std::env::temp_dir()
        .join(format!("sketchd-rb-{}.snap", std::process::id()))
        .to_string_lossy()
        .into_owned();
    let daemon = Daemon::bind(ServeConfig {
        addr: "127.0.0.1:0".into(),
        max_sessions: 2,
        snapshot_interval_secs: 0,
        session_quota_bytes: 0,
        snapshot_path: snap.clone(),
        threads: 1,
        shards: 1,
        archive: ArchiveConfig::default(),
        obs: ObsConfig::default(),
        fault: String::new(),
    })
    .unwrap();
    let addr = daemon.local_addr().unwrap().to_string();
    let handle = daemon.spawn().unwrap();

    let net = ClientConfig {
        io_timeout_ms: 5000,
        ..impatient(1)
    };
    let (mut client, info) = SketchClient::connect_with(&addr, &net).unwrap();
    assert!(info.max_sessions == 2);
    let m = client.metrics().unwrap();
    assert_eq!(m.sessions_open, 0);
    assert!(m.frames_served >= 1);

    handle.stop().unwrap();
    let _ = std::fs::remove_file(&snap);
}
