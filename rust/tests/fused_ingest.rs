//! Bitwise equivalence of the fused zero-allocation ingest path against
//! the PR3-era reference (allocating unfused contributions, spawn-per-
//! call scoped threads): the fused in-place EMA kernels and the
//! persistent worker pool are pure throughput changes, never numerics
//! changes.  Covered matrix: 1/2/4 lanes, heterogeneous widths, tail
//! batches, repeated pool reuse across many steps, and rank changes.

use sketchgrad::sketch::{
    Mat, Pool, Projections, SketchConfig, SketchEngine, SketchTriplet,
    Sketcher,
};
use sketchgrad::util::prop::Prop;
use sketchgrad::util::rng::Rng;

fn engine(dims: &[usize], rank: usize, threads: usize) -> SketchEngine {
    SketchConfig::builder()
        .layer_dims(dims)
        .rank(rank)
        .beta(0.9)
        .seed(23)
        .threads(threads)
        .build_engine()
        .unwrap()
}

fn acts(n_b: usize, dims: &[usize], rng: &mut Rng) -> Vec<Mat> {
    let mut out = vec![Mat::gaussian(n_b, dims[0], rng)];
    for &d in dims {
        out.push(Mat::gaussian(n_b, d, rng));
    }
    out
}

/// A PR3-style engine stand-in: bare triplets updated through the
/// unfused, allocating, scoped-thread reference path.
struct ReferenceEngine {
    layers: Vec<SketchTriplet>,
    threads: usize,
}

impl ReferenceEngine {
    fn like(engine: &SketchEngine, threads: usize) -> ReferenceEngine {
        let cfg = engine.config();
        ReferenceEngine {
            layers: (0..cfg.n_layers())
                .map(|l| {
                    SketchTriplet::with_dims(
                        cfg.d_in(l),
                        cfg.d_out(l),
                        cfg.rank,
                        cfg.beta,
                    )
                })
                .collect(),
            threads,
        }
    }

    fn ingest(&mut self, acts: &[Mat], proj: &Projections) {
        for (l, t) in self.layers.iter_mut().enumerate() {
            let a_in = if l == 0 { &acts[1] } else { &acts[l] };
            t.update_scoped(a_in, &acts[l + 1], proj, l, self.threads);
        }
    }
}

/// Largest |fused - reference| element across all layer sketches.
fn state_diff(engine: &SketchEngine, reference: &ReferenceEngine) -> f64 {
    let mut diff: f64 = 0.0;
    for (f, r) in engine.layers().iter().zip(&reference.layers) {
        diff = diff
            .max(f.x.max_abs_diff(&r.x))
            .max(f.y.max_abs_diff(&r.y))
            .max(f.z.max_abs_diff(&r.z));
    }
    diff
}

#[test]
fn fused_ingest_is_bitwise_pr3_reference() {
    // Heterogeneous widths, a nominal and a tail batch size, 12 steps of
    // pool reuse, across 1/2/4 lanes — both engine fan-out regimes
    // (layer fan-out at 2 lanes over 4 layers, intra-kernel at 4+).
    let dims = [48usize, 32, 24, 16];
    for threads in [1usize, 2, 4] {
        let mut fused = engine(&dims, 3, threads);
        let mut reference = ReferenceEngine::like(&fused, threads);
        let mut rng = Rng::new(400 + threads as u64);
        for step in 0..12 {
            let n_b = if step % 3 == 2 { 7 } else { 20 };
            let batch = acts(n_b, &dims, &mut rng);
            fused.ensure_projections(n_b);
            let proj = fused.projections(n_b).unwrap().clone();
            fused.ingest(&batch).unwrap();
            reference.ingest(&batch, &proj);
            let diff = state_diff(&fused, &reference);
            assert_eq!(
                diff, 0.0,
                "{threads} threads, step {step}: fused diverged by {diff:.2e}"
            );
        }
    }
}

#[test]
fn fused_ingest_survives_rank_change_bitwise() {
    let dims = [40usize, 20];
    let mut fused = engine(&dims, 2, 4);
    let mut rng = Rng::new(77);
    fused.ingest(&acts(16, &dims, &mut rng)).unwrap();
    fused.set_rank(4);
    let mut reference = ReferenceEngine::like(&fused, 4);
    for _ in 0..4 {
        let batch = acts(16, &dims, &mut rng);
        fused.ensure_projections(16);
        let proj = fused.projections(16).unwrap().clone();
        fused.ingest(&batch).unwrap();
        reference.ingest(&batch, &proj);
    }
    assert_eq!(state_diff(&fused, &reference), 0.0);
}

#[test]
fn triplet_fused_update_matches_unfused_property() {
    let pools = [
        Pool::with_lanes(1),
        Pool::with_lanes(2),
        Pool::with_lanes(4),
    ];
    Prop::new(16).check("fused_triplet", |rng, i| {
        let n_b = 3 + (i * 5) % 24;
        let (d_in, d_out) = (4 + (i * 7) % 50, 4 + (i * 11) % 50);
        let rank = 1 + i % 4;
        let proj = Projections::sample(n_b, 1, rank, rng);
        let a_in = Mat::gaussian(n_b, d_in, rng);
        let a_out = Mat::gaussian(n_b, d_out, rng);
        for pool in &pools {
            let mut fused = SketchTriplet::with_dims(d_in, d_out, rank, 0.9);
            let mut unfused = SketchTriplet::with_dims(d_in, d_out, rank, 0.9);
            // Several EMA steps so the resident-state blend is exercised,
            // not just the from-zeros first step.
            for _ in 0..3 {
                fused.update_with(&a_in, &a_out, &proj, 0, pool);
                unfused.update_scoped(&a_in, &a_out, &proj, 0, pool.lanes());
            }
            let diff = fused
                .x
                .max_abs_diff(&unfused.x)
                .max(fused.y.max_abs_diff(&unfused.y))
                .max(fused.z.max_abs_diff(&unfused.z));
            if diff > 0.0 {
                return Err(format!(
                    "{} lanes: fused vs unfused diff {diff:.2e}",
                    pool.lanes()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn engines_share_one_pool_bitwise() {
    // The sketchd wiring: several engines (and their reconstructions)
    // multiplexed over one shared pool must match private-pool engines
    // exactly.
    let dims_a = [64usize, 32];
    let dims_b = [24usize, 24, 24];
    let pool = Pool::with_lanes(4);
    let mut shared_a = SketchEngine::with_pool(
        SketchConfig::builder()
            .layer_dims(&dims_a)
            .rank(3)
            .seed(5)
            .build()
            .unwrap(),
        pool.clone(),
    );
    let mut shared_b = SketchEngine::with_pool(
        SketchConfig::builder()
            .layer_dims(&dims_b)
            .rank(2)
            .seed(6)
            .build()
            .unwrap(),
        pool.clone(),
    );
    let mut own_a = engine_with(&dims_a, 3, 5);
    let mut own_b = engine_with(&dims_b, 2, 6);
    let mut rng = Rng::new(9);
    for step in 0..6 {
        let n_b = if step == 5 { 11 } else { 32 };
        let batch_a = acts(n_b, &dims_a, &mut rng);
        let batch_b = acts(n_b, &dims_b, &mut rng);
        shared_a.ingest(&batch_a).unwrap();
        own_a.ingest(&batch_a).unwrap();
        shared_b.ingest(&batch_b).unwrap();
        own_b.ingest(&batch_b).unwrap();
    }
    assert_eq!(shared_a.max_state_diff(&own_a), 0.0);
    assert_eq!(shared_b.max_state_diff(&own_b), 0.0);
    for l in 0..dims_a.len() {
        let (s, o) = (
            shared_a.reconstruct(l).unwrap(),
            own_a.reconstruct(l).unwrap(),
        );
        assert_eq!(s.max_abs_diff(&o), 0.0, "layer {l}");
    }
    assert_eq!(shared_a.pool().lanes(), 4);
    assert!(std::sync::Arc::ptr_eq(shared_a.pool(), shared_b.pool()));
}

fn engine_with(dims: &[usize], rank: usize, seed: u64) -> SketchEngine {
    SketchConfig::builder()
        .layer_dims(dims)
        .rank(rank)
        .seed(seed)
        .threads(4)
        .build_engine()
        .unwrap()
}
