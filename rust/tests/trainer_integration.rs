//! Trainer-level integration: full epochs through chunked artifacts,
//! adaptive rank swaps, evaluation purity, and the PINN pipeline.

use sketchgrad::config::{ExperimentConfig, Variant};
use sketchgrad::coordinator::{run_classifier, run_pinn, AdaptiveConfig, Trainer};
use sketchgrad::data::{make_chunks, synth_mnist, Init};
use sketchgrad::runtime::Runtime;
use sketchgrad::util::rng::Rng;
use std::path::PathBuf;

fn runtime() -> Option<Runtime> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Runtime::new(&dir).expect("runtime"))
}

#[test]
fn sketched_chunk_epoch_learns() {
    let Some(rt) = runtime() else { return };
    let cfg = ExperimentConfig {
        name: "it_sk".into(),
        family: "mnist".into(),
        variant: Variant::Sketched,
        rank: 2,
        adaptive: false,
        epochs: 2,
        train_size: 128 * 50,
        test_size: 128 * 50,
        seed: 5,
        ..Default::default()
    };
    let run = run_classifier(&rt, &cfg, false).unwrap();
    assert_eq!(run.epochs.len(), 2);
    let first = run.epochs[0].mean_loss;
    let last = run.epochs[1].mean_loss;
    assert!(last < first, "epoch loss should drop: {first} -> {last}");
    assert!(run.final_eval_acc.is_finite());
    // Sketch metrics flowed through.
    assert!(!run.history[0].z_norm.is_empty());
    assert!(run.measured_sketch_bytes > 0);
}

#[test]
fn rank_swap_preserves_params_and_resets_sketches() {
    let Some(rt) = runtime() else { return };
    let mut trainer =
        Trainer::new(&rt, "mnist_sk_r2_chunk", Init::Xavier(1.0), 7).unwrap();
    let data = synth_mnist(128 * 50, 7);
    let mut rng = Rng::new(8);
    let chunks = make_chunks(&data, 128, 50, &mut rng, &[784]);
    trainer.run_chunk(&chunks[0]).unwrap();

    let w0_before = trainer.state.get("w0").unwrap().clone();
    let sketch_before = trainer.state.get("sketch_y").unwrap().clone();
    assert_eq!(sketch_before.shape(), &[3, 512, 5]);

    trainer.swap_artifact("mnist_sk_r8_chunk").unwrap();
    // Params carried over identically...
    assert_eq!(trainer.state.get("w0").unwrap(), &w0_before);
    // ...sketches re-initialised at the new k = 17, zeroed.
    let sketch_after = trainer.state.get("sketch_y").unwrap();
    assert_eq!(sketch_after.shape(), &[3, 512, 17]);
    assert!(sketch_after.f32_data().unwrap().iter().all(|&v| v == 0.0));
    // New artifact executes fine with carried state.
    trainer.run_chunk(&chunks[0]).unwrap();
    assert!(trainer.history.last().unwrap().loss.is_finite());
}

#[test]
fn adaptive_run_switches_executables() {
    let Some(rt) = runtime() else { return };
    let cfg = ExperimentConfig {
        name: "it_adaptive".into(),
        family: "mnist".into(),
        variant: Variant::Sketched,
        rank: 2,
        adaptive: true,
        adaptive_cfg: AdaptiveConfig {
            r0: 2,
            p_decrease: 10,           // never decrease in this short run
            p_increase: 1,            // aggressive increase
            min_rel_improvement: 0.9, // nearly impossible -> stagnation
            ..Default::default()
        },
        epochs: 3,
        train_size: 128 * 50,
        test_size: 128 * 50,
        seed: 9,
        ..Default::default()
    };
    let run = run_classifier(&rt, &cfg, false).unwrap();
    assert!(
        !run.rank_decisions.is_empty(),
        "aggressive stagnation settings must trigger a rank change"
    );
}

#[test]
fn evaluation_does_not_mutate_state() {
    let Some(rt) = runtime() else { return };
    let mut trainer =
        Trainer::new(&rt, "mnist_std_chunk", Init::Xavier(1.0), 11).unwrap();
    let data = synth_mnist(128 * 50, 11);
    let mut rng = Rng::new(12);
    let chunks = make_chunks(&data, 128, 50, &mut rng, &[784]);
    trainer.run_chunk(&chunks[0]).unwrap();
    let w0 = trainer.state.get("w0").unwrap().clone();
    let t = trainer.state.get("t").unwrap().clone();
    let (_loss, acc) = trainer.evaluate(&chunks[..1]).unwrap();
    assert!(acc.is_finite());
    assert_eq!(trainer.state.get("w0").unwrap(), &w0);
    assert_eq!(trainer.state.get("t").unwrap(), &t);
}

#[test]
fn pinn_monitored_matches_standard_quality() {
    let Some(rt) = runtime() else { return };
    // Short runs: quality parity (paper Fig. 3's claim) within tolerance.
    let std = run_pinn(&rt, "standard", 2, 3, 21).unwrap();
    let mon = run_pinn(&rt, "monitored", 2, 3, 21).unwrap();
    assert!(std.l2_rel_err.is_finite() && mon.l2_rel_err.is_finite());
    // Loss trajectories should be very close (monitoring-only sketching
    // does not touch updates; small divergence only from fp ordering).
    let d_final =
        (std.losses.last().unwrap() - mon.losses.last().unwrap()).abs();
    assert!(
        d_final < 0.15 * std.losses.last().unwrap().abs().max(1.0),
        "std {} vs mon {}",
        std.losses.last().unwrap(),
        mon.losses.last().unwrap()
    );
    // Monitored run produced sketch metrics; standard did not.
    assert!(!mon.history.is_empty());
    assert!(mon.sketch_bytes > 0);
}
