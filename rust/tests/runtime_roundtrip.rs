//! Integration: HLO-text artifacts load, compile and execute on the PJRT
//! CPU client, and sketched training steps actually optimize.

use std::collections::HashMap;
use std::path::PathBuf;

use sketchgrad::coordinator::{init_state, Trainer};
use sketchgrad::data::{synth_mnist, make_chunks, Init};
use sketchgrad::runtime::{Runtime, Tensor};
use sketchgrad::util::rng::Rng;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn runtime() -> Option<Runtime> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Runtime::new(&dir).expect("runtime"))
}

#[test]
fn standard_step_executes_and_learns() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("mnist_std_step").unwrap();
    let mut rng = Rng::new(1);
    let mut state = init_state(&exe.entry, Init::Kaiming, &mut rng).unwrap();

    let data = synth_mnist(128 * 12, 42);
    let mut first_loss = None;
    let mut last_loss = 0.0;
    for step in 0..12 {
        let mut xs = Vec::with_capacity(128 * 784);
        let mut ys = Vec::with_capacity(128);
        for b in 0..128 {
            let i = step * 128 + b;
            xs.extend_from_slice(data.x_row(i));
            ys.push(data.ys[i]);
        }
        let mut extra: HashMap<&str, Tensor> = HashMap::new();
        extra.insert("batch_x", Tensor::from_f32(&[128, 784], xs));
        extra.insert("batch_y", Tensor::from_i32(&[128], ys));
        let inputs = state.ordered_inputs(&exe.entry, &extra).unwrap();
        let outputs = exe.run(&inputs).unwrap();
        let metrics = state.absorb_outputs(&exe.entry, outputs).unwrap();
        let loss = metrics["loss"].scalar().unwrap();
        assert!(loss.is_finite(), "loss must be finite, got {loss}");
        if first_loss.is_none() {
            first_loss = Some(loss);
        }
        last_loss = loss;
    }
    let first = first_loss.unwrap();
    assert!(
        last_loss < first,
        "loss should decrease: first {first} last {last_loss}"
    );
    // Step counter advanced.
    assert_eq!(state.get("t").unwrap().scalar().unwrap(), 12.0);
}

#[test]
fn sketched_step_executes_updates_sketches_and_learns() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("mnist_sk_r2_step").unwrap();
    let mut rng = Rng::new(2);
    let mut state = init_state(&exe.entry, Init::Kaiming, &mut rng).unwrap();
    let data = synth_mnist(128 * 16, 7);

    let sketch_before = state.get("sketch_y").unwrap().clone();
    let mut losses = Vec::new();
    for step in 0..16 {
        let mut xs = Vec::with_capacity(128 * 784);
        let mut ys = Vec::with_capacity(128);
        for b in 0..128 {
            let i = step * 128 + b;
            xs.extend_from_slice(data.x_row(i));
            ys.push(data.ys[i]);
        }
        let mut extra: HashMap<&str, Tensor> = HashMap::new();
        extra.insert("batch_x", Tensor::from_f32(&[128, 784], xs));
        extra.insert("batch_y", Tensor::from_i32(&[128], ys));
        let inputs = state.ordered_inputs(&exe.entry, &extra).unwrap();
        let outputs = exe.run(&inputs).unwrap();
        let metrics = state.absorb_outputs(&exe.entry, outputs).unwrap();
        losses.push(metrics["loss"].scalar().unwrap());
        // Sketch metrics present and finite.
        for name in ["z_norm", "stable_rank", "y_norm", "x_norm"] {
            let t = &metrics[name];
            assert_eq!(t.len(), 3, "{name} per hidden layer");
            assert!(t.f32_data().unwrap().iter().all(|v| v.is_finite()));
        }
    }
    // Sketches changed from zero.
    let sketch_after = state.get("sketch_y").unwrap();
    assert_ne!(&sketch_before, sketch_after);
    assert!(losses.iter().all(|l| l.is_finite()));
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "sketched training should reduce loss: {losses:?}"
    );
}

#[test]
fn chunked_trainer_runs_an_epoch() {
    let Some(rt) = runtime() else { return };
    let mut trainer =
        Trainer::new(&rt, "mnist_std_chunk", Init::Kaiming, 3).unwrap();
    let data = synth_mnist(128 * 50, 11); // exactly one chunk of K=50
    let mut rng = Rng::new(4);
    let chunks = make_chunks(&data, 128, 50, &mut rng, &[784]);
    assert_eq!(chunks.len(), 1);
    let summary = trainer.run_epoch(&chunks).unwrap();
    assert_eq!(summary.steps, 50);
    assert!(summary.mean_loss.is_finite());
    // Within-epoch improvement: late steps beat early steps on average.
    let early: f32 =
        trainer.history[..10].iter().map(|m| m.loss).sum::<f32>() / 10.0;
    let late: f32 = trainer.history[40..].iter().map(|m| m.loss).sum::<f32>()
        / 10.0;
    assert!(late < early, "early {early} late {late}");
}

#[test]
fn recon_eval_matches_rust_substrate() {
    // The same (A, projections) pushed through the AOT recon_eval artifact
    // and the native substrate must agree on the reconstruction error.
    let Some(rt) = runtime() else { return };
    let exe = rt.load("recon_eval_r2").unwrap();
    let (n_b, d, rank) = (128usize, 512usize, 2usize);
    let k = 2 * rank + 1;
    let mut rng = Rng::new(9);

    let a: Vec<f32> = rng.normal_vec_f32(n_b * d);
    let ups: Vec<f32> = rng.normal_vec_f32(n_b * k);
    let omg: Vec<f32> = rng.normal_vec_f32(n_b * k);
    let phi: Vec<f32> = rng.normal_vec_f32(n_b * k);
    let psi: Vec<f32> = rng.normal_vec_f32(k);

    let outputs = exe
        .run(&[
            Tensor::from_f32(&[n_b, d], a.clone()),
            Tensor::from_f32(&[n_b, k], ups.clone()),
            Tensor::from_f32(&[n_b, k], omg.clone()),
            Tensor::from_f32(&[n_b, k], phi.clone()),
            Tensor::from_f32(&[k], psi.clone()),
        ])
        .unwrap();
    let aot_err = outputs[1].scalar().unwrap() as f64;

    // Native substrate replay (beta=0 single-batch triplet).
    use sketchgrad::sketch::{
        reconstruct::recon_error, Mat, Projections, SketchTriplet,
    };
    let a_m = Mat::from_f32(n_b, d, &a);
    let proj = Projections {
        upsilon: Mat::from_f32(n_b, k, &ups),
        omega: Mat::from_f32(n_b, k, &omg),
        phi: Mat::from_f32(n_b, k, &phi),
        psi: std::sync::Arc::new(vec![psi
            .iter()
            .map(|&x| x as f64)
            .collect()]),
        rank,
    };
    let mut t = SketchTriplet::zeros(d, rank, 0.0);
    t.update(&a_m, &a_m, &proj, 0);
    let native_err = recon_error(&t, &proj.omega, &a_m);

    let rel = (aot_err - native_err).abs() / native_err;
    assert!(
        rel < 2e-2,
        "AOT recon err {aot_err} vs native {native_err} (rel {rel})"
    );
}

#[test]
fn executable_cache_reuses_compilations() {
    let Some(rt) = runtime() else { return };
    let a = rt.load("recon_eval_r2").unwrap();
    let b = rt.load("recon_eval_r2").unwrap();
    assert!(std::rc::Rc::ptr_eq(&a, &b));
    assert_eq!(rt.compile_log.borrow().len(), 1);
}
