//! Loopback tests for the v3 `Metrics` op and the observability
//! counters behind it: histogram/counter agreement with client-observed
//! traffic, per-session backpressure accounting in `Stats`, lifetime
//! counters surviving a daemon restart, and raw v2-frame compatibility
//! (old clients keep working, `Metrics` is cleanly version-gated).

use sketchgrad::config::{ArchiveConfig, ObsConfig, ServeConfig};
use sketchgrad::data::ActStream;
use sketchgrad::serve::proto::{
    self, ErrorCode, Request, Response, SessionSpec, PROTO_VERSION,
};
use sketchgrad::serve::{Daemon, Error, SketchClient};
use sketchgrad::sketch::Mat;

fn unique_snapshot_path(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("sketchd-mt-{tag}-{}.snap", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

fn test_config(tag: &str, max_sessions: usize, quota: usize) -> ServeConfig {
    ServeConfig {
        addr: "127.0.0.1:0".into(),
        max_sessions,
        snapshot_interval_secs: 0,
        session_quota_bytes: quota,
        snapshot_path: unique_snapshot_path(tag),
        threads: 1,
        shards: 1,
        archive: ArchiveConfig::default(),
        obs: ObsConfig::default(),
        fault: String::new(),
    }
}

fn spec(name: &str, dims: &[usize], seed: u64) -> SessionSpec {
    SessionSpec {
        name: name.into(),
        layer_dims: dims.to_vec(),
        rank: 3,
        beta: 0.9,
        seed,
        window: 8,
        collapse_frac: 0.25,
    }
}

/// Wire payload bytes of one `Ingest` frame (must mirror the daemon's
/// `payload_len` accounting): session u64 + loss f32 + recon flag +
/// acts count u32, then rows/cols prefixes and f64 cells per matrix.
fn ingest_payload_bytes(acts: &[Mat]) -> u64 {
    17 + acts
        .iter()
        .map(|m| 8 + (m.rows * m.cols * 8) as u64)
        .sum::<u64>()
}

/// The metrics report agrees with client-observed traffic: histogram
/// counts per op class, exact ingest byte accounting across two
/// sessions, and `frames_served` equal to the replies this client has
/// actually read.  A second `Metrics` call sees the first one recorded
/// in the query histogram (a report never includes its own frame).
#[test]
fn metrics_report_matches_client_observed_traffic() {
    const DIMS: &[usize] = &[32, 16];
    let daemon = Daemon::bind(test_config("counts", 4, 0)).unwrap();
    let addr = daemon.local_addr().unwrap().to_string();
    let snap_path = unique_snapshot_path("counts");
    let handle = daemon.spawn().unwrap();

    let (mut client, info) = SketchClient::connect(&addr).unwrap();
    assert_eq!(info.proto, PROTO_VERSION);
    let s1 = client.open_session(&spec("m-a", DIMS, 11)).unwrap().id();
    let s2 = client.open_session(&spec("m-b", DIMS, 22)).unwrap().id();

    let mut stream_a = ActStream::new(DIMS, false, 11);
    let mut stream_b = ActStream::new(DIMS, false, 22);
    let mut bytes = 0u64;
    let mut ingests = 0u64;
    for step in 0..6 {
        let acts = stream_a.next_batch(8);
        bytes += ingest_payload_bytes(&acts);
        client
            .session(s1)
            .ingest(stream_a.loss_at(step, 6), &acts, false)
            .unwrap();
        ingests += 1;
        if step % 2 == 0 {
            let acts = stream_b.next_batch(5);
            bytes += ingest_payload_bytes(&acts);
            client
                .session(s2)
                .ingest(stream_b.loss_at(step, 6), &acts, false)
                .unwrap();
            ingests += 1;
        }
    }
    client.session(s1).diagnose().unwrap();
    client.session(s2).diagnose().unwrap();
    client.session(s1).query_trajectory().unwrap();

    // Replies read so far: hello + 2 opens + ingests + 2 diagnoses +
    // 1 trajectory.  The metrics reply itself is not yet counted.
    let frames_before_metrics = 1 + 2 + ingests + 2 + 1;
    let m = client.metrics().unwrap();
    assert_eq!(m.sessions_open, 2);
    assert_eq!(m.sessions_peak, 2);
    assert_eq!(m.sessions_opened, 2);
    assert_eq!(m.ingest_bytes, bytes);
    assert_eq!(m.frames_served, frames_before_metrics);
    assert_eq!(m.ingest.count, ingests);
    assert_eq!(m.diagnose.count, 2);
    // Query histogram: the trajectory query only — a Metrics request is
    // recorded after its own report is built.
    assert_eq!(m.query.count, 1);
    assert_eq!(m.busy_total(), 0);
    assert!(m.ingest.sum_ns > 0, "ingest latency must be recorded");
    assert!(m.ingest.min_ns <= m.ingest.max_ns);
    let p99 = m.ingest.quantile(0.99);
    assert!(p99 >= m.ingest.quantile(0.5));

    let m2 = client.metrics().unwrap();
    assert_eq!(m2.frames_served, frames_before_metrics + 1);
    assert_eq!(m2.query.count, 2, "first Metrics call lands in query hist");
    assert_eq!(m2.ingest.count, ingests, "ingest hist unchanged");

    client.session(s1).close().unwrap();
    client.session(s2).close().unwrap();
    let m3 = client.metrics().unwrap();
    assert_eq!(m3.sessions_open, 0);
    assert_eq!(m3.sessions_peak, 2, "peak is a high-water mark");
    assert_eq!(m3.sessions_opened, 2, "opened is a lifetime counter");

    handle.stop().unwrap();
    let _ = std::fs::remove_file(&snap_path);
}

/// Quota backpressure is visible end to end: the daemon's Busy replies,
/// the per-session `Stats` fields (busy_rejections / quota_used /
/// quota_limit) and the metrics `busy_quota` counter all agree with the
/// client's own count, and `Diagnose` drains the quota so the retry
/// succeeds.
#[test]
fn busy_accounting_agrees_across_stats_and_metrics() {
    const DIMS: &[usize] = &[16, 8];
    const QUOTA: usize = 4096;
    let daemon = Daemon::bind(test_config("busy", 2, QUOTA)).unwrap();
    let addr = daemon.local_addr().unwrap().to_string();
    let snap_path = unique_snapshot_path("busy");
    let handle = daemon.spawn().unwrap();

    let (mut client, _info) = SketchClient::connect(&addr).unwrap();
    let mut sess = client.open_session(&spec("bp", DIMS, 7)).unwrap();
    let mut stream = ActStream::new(DIMS, false, 7);

    let mut busy = 0u64;
    let mut quota_model = 0u64; // bytes since the last Diagnose
    for step in 0..12 {
        let acts = stream.next_batch(4);
        let loss = stream.loss_at(step, 12);
        let bytes = ingest_payload_bytes(&acts);
        match sess.ingest(loss, &acts, false) {
            Ok(_) => quota_model += bytes,
            Err(Error::Busy { used, limit }) => {
                busy += 1;
                assert_eq!(used, quota_model);
                assert_eq!(limit, QUOTA as u64);
                assert!(used + bytes > limit, "Busy only past the quota");
                sess.diagnose().unwrap();
                quota_model = 0;
                sess.ingest(loss, &acts, false).unwrap();
                quota_model += bytes;
            }
            Err(e) => panic!("unexpected ingest error: {e}"),
        }
    }
    assert!(busy > 0, "workload must actually trip the quota");

    let stats = client.stats().unwrap();
    assert_eq!(stats.daemon.busy_rejections, busy);
    assert_eq!(stats.sessions.len(), 1);
    assert_eq!(stats.sessions[0].busy_rejections, busy);
    assert_eq!(stats.sessions[0].quota_used, quota_model);
    assert_eq!(stats.sessions[0].quota_limit, QUOTA as u64);

    let m = client.metrics().unwrap();
    assert_eq!(m.busy_quota, busy);
    assert_eq!(m.busy_admission, 0);
    assert_eq!(m.busy_total(), busy);

    handle.stop().unwrap();
    let _ = std::fs::remove_file(&snap_path);
}

/// Lifetime observability counters ride the snapshot: a stop/rebind
/// cycle preserves ingest bytes, histogram contents and session
/// counters, while the process-scoped `frames_served` resets — and the
/// restored counters keep counting.
#[test]
fn metrics_survive_restart_except_process_scoped_pieces() {
    const DIMS: &[usize] = &[24, 12];
    let cfg = test_config("persist", 4, 0);
    let snap_path = cfg.snapshot_path.clone();
    let _ = std::fs::remove_file(&snap_path);

    let daemon = Daemon::bind(cfg.clone()).unwrap();
    let addr = daemon.local_addr().unwrap().to_string();
    let handle = daemon.spawn().unwrap();

    let (mut client, _info) = SketchClient::connect(&addr).unwrap();
    let mut sess = client.open_session(&spec("pp", DIMS, 3)).unwrap();
    let session = sess.id();
    let mut stream = ActStream::new(DIMS, false, 3);
    let mut bytes = 0u64;
    for step in 0..5 {
        let acts = stream.next_batch(6);
        bytes += ingest_payload_bytes(&acts);
        sess.ingest(stream.loss_at(step, 5), &acts, false).unwrap();
    }
    sess.diagnose().unwrap();
    let before = client.metrics().unwrap();
    assert_eq!(before.ingest.count, 5);
    assert_eq!(before.ingest_bytes, bytes);
    drop(client);
    // stop() writes the shutdown snapshot, metrics state included.
    handle.stop().unwrap();

    let daemon = Daemon::bind(cfg).unwrap();
    let addr = daemon.local_addr().unwrap().to_string();
    let handle = daemon.spawn().unwrap();
    let (mut client, info) = SketchClient::connect(&addr).unwrap();
    assert_eq!(info.sessions, 1, "session restored from snapshot");

    let after = client.metrics().unwrap();
    assert_eq!(after.ingest.count, 5, "ingest histogram restored");
    assert_eq!(after.ingest.sum_ns, before.ingest.sum_ns);
    assert_eq!(after.ingest.min_ns, before.ingest.min_ns);
    assert_eq!(after.ingest.max_ns, before.ingest.max_ns);
    assert_eq!(after.ingest_bytes, bytes);
    assert_eq!(after.sessions_opened, 1);
    assert_eq!(after.sessions_peak, 1);
    assert_eq!(after.diagnose.count, 1);
    // Process-scoped: only this connection's hello has been served.
    assert_eq!(after.frames_served, 1);
    assert!(after.uptime_ms <= before.uptime_ms + 60_000);

    // Restored counters continue counting, not restart from zero.
    let acts = stream.next_batch(6);
    let more = ingest_payload_bytes(&acts);
    client.session(session).ingest(0.1, &acts, false).unwrap();
    let cont = client.metrics().unwrap();
    assert_eq!(cont.ingest.count, 6);
    assert_eq!(cont.ingest_bytes, bytes + more);

    handle.stop().unwrap();
    let _ = std::fs::remove_file(&snap_path);
}

/// Raw v2 frames keep working against a v3 daemon: replies echo v2 and
/// decode strictly at v2, `Stats` drops the version-gated fields, and a
/// v2 `Metrics` frame gets a typed `UnsupportedVersion` error instead
/// of a hangup mid-frame.
#[test]
fn v2_frames_remain_compatible_and_metrics_is_gated() {
    let daemon = Daemon::bind(test_config("v2", 2, 0)).unwrap();
    let addr = daemon.local_addr().unwrap().to_string();
    let snap_path = unique_snapshot_path("v2");
    let handle = daemon.spawn().unwrap();

    let mut raw = std::net::TcpStream::connect(&addr).unwrap();
    let hello = Request::Hello {
        client: "legacy".into(),
    };
    proto::write_frame_versioned(&mut raw, 2, hello.msg_type(), &hello.encode())
        .unwrap();
    let (header, payload) = proto::read_frame(&mut raw).unwrap();
    assert_eq!(header.version, 2, "reply echoes the request's version");
    match Response::decode_v(header.msg, &payload, 2).unwrap() {
        Response::HelloOk { proto, .. } => assert_eq!(proto, PROTO_VERSION),
        other => panic!("expected HelloOk, got {other:?}"),
    }

    // v2 Stats: the reply must decode strictly at v2 (no v3 fields on
    // the wire), with the gated counters defaulted.
    let stats = Request::Stats;
    proto::write_frame_versioned(&mut raw, 2, stats.msg_type(), &stats.encode())
        .unwrap();
    let (header, payload) = proto::read_frame(&mut raw).unwrap();
    assert_eq!(header.version, 2);
    match Response::decode_v(header.msg, &payload, 2).unwrap() {
        Response::StatsOk { daemon, .. } => {
            assert_eq!(daemon.busy_rejections, 0, "v3 field absent at v2")
        }
        other => panic!("expected StatsOk, got {other:?}"),
    }

    // v2 Metrics: typed rejection (the op only exists from v3 on).
    let metrics = Request::Metrics;
    proto::write_frame_versioned(
        &mut raw,
        2,
        metrics.msg_type(),
        &metrics.encode(),
    )
    .unwrap();
    let (header, payload) = proto::read_frame(&mut raw).unwrap();
    match Response::decode_v(header.msg, &payload, header.version).unwrap() {
        Response::Error { code, .. } => {
            assert_eq!(code, ErrorCode::UnsupportedVersion)
        }
        other => panic!("expected Error, got {other:?}"),
    }

    // The daemon still serves fresh connections afterwards.
    let (mut client, _info) = SketchClient::connect(&addr).unwrap();
    assert!(client.metrics().unwrap().frames_served >= 1);

    handle.stop().unwrap();
    let _ = std::fs::remove_file(&snap_path);
}
