//! Property tests for the builder-configured `SketchEngine`: Lemma 4.1
//! must hold per layer at heterogeneous widths, variable batch sizes
//! (including tail batches smaller than the nominal n_b) must accumulate
//! consistently, rank changes through `set_rank` must re-initialise, and
//! measured memory must match the fixed accountant.  None of these were
//! expressible with the seed `LayerSketches::new(n_layers, d_hidden, ...)`
//! API, which pinned every layer to one width and one batch size.

use sketchgrad::sketch::{
    engine_state_bytes, Mat, Precision, SketchConfig, Sketcher,
};
use sketchgrad::util::prop::Prop;
use sketchgrad::util::rng::Rng;

/// Random heterogeneous hidden widths (2-4 layers, distinct dims).
fn random_dims(rng: &mut Rng, case: usize) -> Vec<usize> {
    let n_layers = 2 + case % 3;
    (0..n_layers)
        .map(|l| 8 + 4 * l + rng.below(24) as usize)
        .collect()
}

fn random_acts(n_b: usize, dims: &[usize], rng: &mut Rng) -> Vec<Mat> {
    let mut acts = vec![Mat::gaussian(n_b, 6, rng)];
    for &d in dims {
        acts.push(Mat::gaussian(n_b, d, rng));
    }
    acts
}

/// Lemma 4.1 expansion per layer at that layer's own width:
/// X_n^[l] = (1-beta) sum_j beta^{n-j} (A_in,j^[l])^T Upsilon.
#[test]
fn lemma_4_1_holds_per_layer_at_distinct_dims() {
    Prop::new(12).check("hetero_lemma41", |rng, case| {
        let dims = random_dims(rng, case);
        let n_b = 5 + case % 6;
        let beta = 0.85;
        let rank = 1 + case % 3;
        let mut engine = SketchConfig::builder()
            .layer_dims(&dims)
            .rank(rank)
            .beta(beta)
            .seed(1000 + case as u64)
            .build_engine()
            .map_err(|e| e.to_string())?;
        let batches: Vec<Vec<Mat>> =
            (0..4).map(|_| random_acts(n_b, &dims, rng)).collect();
        for acts in &batches {
            engine.ingest(acts).map_err(|e| e.to_string())?;
        }
        let proj = engine
            .projections(n_b)
            .ok_or("projections for n_b missing")?;
        let n = batches.len();
        for (l, &d) in dims.iter().enumerate() {
            // a_in for layer l: acts[l] for l >= 1, acts[1] for l == 0.
            let expected_d_in = if l == 0 { dims[0] } else { dims[l - 1] };
            let mut want = Mat::zeros(expected_d_in, engine.k());
            for (j, acts) in batches.iter().enumerate() {
                let a_in = if l == 0 { &acts[1] } else { &acts[l] };
                let w = (1.0 - beta) * beta.powi((n - 1 - j) as i32);
                want = want.add(&a_in.t_matmul(&proj.upsilon).scale(w));
            }
            let x = &engine.layers()[l].x;
            if (x.rows, x.cols) != (expected_d_in, engine.k()) {
                return Err(format!(
                    "layer {l}: X is {}x{}, want {}x{}",
                    x.rows,
                    x.cols,
                    expected_d_in,
                    engine.k()
                ));
            }
            let diff = x.max_abs_diff(&want);
            if diff > 1e-10 {
                return Err(format!("layer {l} (d={d}): X diff {diff}"));
            }
            // Y/Z live at the layer's own width.
            if engine.layers()[l].y.rows != d {
                return Err(format!("layer {l}: Y width {}", d));
            }
        }
        Ok(())
    });
}

/// Variable batch sizes: a nominal batch stream with a smaller tail batch
/// must (a) ingest without error, (b) cache one projection set per
/// distinct size, and (c) keep each size's EMA contribution tied to that
/// size's own fixed Upsilon (checked via the two-size Lemma-4.1
/// expansion).
#[test]
fn variable_batch_sizes_accumulate_consistently() {
    Prop::new(10).check("variable_nb", |rng, case| {
        let dims = vec![16 + case, 8 + case]; // mildly heterogeneous
        let beta = 0.9;
        let (n_b, tail) = (12, 5);
        let mut engine = SketchConfig::builder()
            .layer_dims(&dims)
            .rank(2)
            .beta(beta)
            .seed(2000 + case as u64)
            .build_engine()
            .map_err(|e| e.to_string())?;
        let mut batches = Vec::new();
        for step in 0..5 {
            let nb = if step == 4 { tail } else { n_b };
            batches.push(random_acts(nb, &dims, rng));
        }
        for acts in &batches {
            engine.ingest(acts).map_err(|e| e.to_string())?;
        }
        if engine.batch_sizes_seen() != vec![tail, n_b] {
            return Err(format!(
                "batch sizes seen {:?}",
                engine.batch_sizes_seen()
            ));
        }
        // Two-size expansion for layer 0 (a_in = acts[1]).
        let proj_full = engine.projections(n_b).unwrap().upsilon.clone();
        let proj_tail = engine.projections(tail).unwrap().upsilon.clone();
        let n = batches.len();
        let mut want = Mat::zeros(dims[0], engine.k());
        for (j, acts) in batches.iter().enumerate() {
            let ups = if acts[1].rows == tail {
                &proj_tail
            } else {
                &proj_full
            };
            let w = (1.0 - beta) * beta.powi((n - 1 - j) as i32);
            want = want.add(&acts[1].t_matmul(ups).scale(w));
        }
        let diff = engine.layers()[0].x.max_abs_diff(&want);
        if diff > 1e-10 {
            return Err(format!("two-size expansion diff {diff}"));
        }
        // Reconstruction after the tail batch uses the tail omega.
        let recon = engine.reconstruct(0).map_err(|e| e.to_string())?;
        if recon.rows != tail || recon.cols != dims[0] {
            return Err(format!("recon {}x{}", recon.rows, recon.cols));
        }
        if !recon.data.iter().all(|x| x.is_finite()) {
            return Err("non-finite reconstruction".into());
        }
        Ok(())
    });
}

/// `set_rank` re-initialises sketches/projections at the new k and the
/// engine keeps working across several rank hops.
#[test]
fn set_rank_walks_the_ladder() {
    Prop::new(8).check("set_rank", |rng, case| {
        let dims = vec![20, 10];
        let mut engine = SketchConfig::builder()
            .layer_dims(&dims)
            .rank(2)
            .seed(3000 + case as u64)
            .build_engine()
            .map_err(|e| e.to_string())?;
        for &r in &[4usize, 8, 2, 16] {
            engine.ingest(&random_acts(9, &dims, rng))
                .map_err(|e| e.to_string())?;
            engine.set_rank(r);
            let k = 2 * r + 1;
            if engine.k() != k {
                return Err(format!("k {} after set_rank({r})", engine.k()));
            }
            for (l, t) in engine.layers().iter().enumerate() {
                if t.x.cols != k || t.y.cols != k || t.z.cols != k {
                    return Err(format!("layer {l} cols not {k}"));
                }
                if t.x.fro_norm() != 0.0 || t.updates != 0 {
                    return Err(format!("layer {l} not zeroed"));
                }
            }
            if !engine.batch_sizes_seen().is_empty() {
                return Err("projection cache survived set_rank".into());
            }
            // Engine must accept new batches at the new rank.
            engine.ingest(&random_acts(7, &dims, rng))
                .map_err(|e| e.to_string())?;
            if engine.layers()[0].x.fro_norm() == 0.0 {
                return Err("no accumulation after rank change".into());
            }
            engine.set_rank(2); // reset between ladder hops
        }
        Ok(())
    });
}

/// Measured memory == fixed accountant, across precisions, dims and
/// observed batch-size sets (within 1% is the CLI gate; here exact).
#[test]
fn memory_matches_accountant_property() {
    Prop::new(10).check("memory", |rng, case| {
        let dims = random_dims(rng, case);
        let rank = 1 + case % 4;
        for precision in [Precision::F32, Precision::F64] {
            let mut engine = SketchConfig::builder()
                .layer_dims(&dims)
                .rank(rank)
                .precision(precision)
                .seed(4000 + case as u64)
                .build_engine()
                .map_err(|e| e.to_string())?;
            let sizes = [6usize, 13, 6];
            for &nb in &sizes {
                engine.ingest(&random_acts(nb, &dims, rng))
                    .map_err(|e| e.to_string())?;
            }
            let expected = engine_state_bytes(
                &dims,
                rank,
                &sizes,
                precision.bytes(),
            );
            if engine.memory() != expected {
                return Err(format!(
                    "measured {} vs accountant {expected} ({precision:?})",
                    engine.memory()
                ));
            }
        }
        Ok(())
    });
}

/// The acceptance-criterion architecture verbatim: an MLP with
/// non-uniform hidden widths 128/64/32 and a tail batch smaller than
/// n_b — both impossible with the seed API.
#[test]
fn funnel_mlp_with_tail_batch() {
    let dims = [128usize, 64, 32];
    let mut engine = SketchConfig::builder()
        .layer_dims(&dims)
        .rank(4)
        .beta(0.9)
        .seed(42)
        .build_engine()
        .unwrap();
    let mut rng = Rng::new(11);
    for step in 0..12 {
        let nb = if step == 11 { 17 } else { 64 }; // tail < n_b
        engine.ingest(&random_acts(nb, &dims, &mut rng)).unwrap();
    }
    assert_eq!(engine.batch_sizes_seen(), vec![17, 64]);
    let metrics = engine.metrics();
    assert_eq!(metrics.len(), 3);
    for (l, m) in metrics.iter().enumerate() {
        assert!(m.z_norm > 0.0, "layer {l} Z empty");
        // Gaussian activations: stable rank should be a healthy fraction
        // of k = 9 at every width.
        assert!(m.stable_rank > 3.0, "layer {l} sr {}", m.stable_rank);
    }
    assert_eq!(
        engine.memory(),
        engine.config().expected_bytes(&[64, 17])
    );
}
