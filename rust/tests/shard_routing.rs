//! Shard-routing properties of the sharded daemon (DESIGN.md §9):
//! session ownership is `id % shards` and *stays* that way across a
//! snapshot/restart cycle, analytics queries answer bit-identically no
//! matter which shard the querying connection lands on, and a
//! pre-shard (single-shard, snapshot v3) snapshot warm-restarts into a
//! multi-shard daemon with bit-identical archive queries and intact
//! lifetime metrics.

use sketchgrad::archive::TrajectoryPoint;
use sketchgrad::config::{ArchiveConfig, ObsConfig, ServeConfig};
use sketchgrad::data::ActStream;
use sketchgrad::serve::proto::SessionSpec;
use sketchgrad::serve::{Daemon, SketchClient};

const DIMS: [usize; 2] = [24, 12];
const SHARDS: usize = 4;
const TENANTS: usize = 8;
const STEPS: usize = 12;

fn snapshot_path(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("sketchd-sr-{tag}-{}.snap", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

fn config(tag: &str, shards: usize) -> ServeConfig {
    ServeConfig {
        addr: "127.0.0.1:0".into(),
        max_sessions: 32,
        snapshot_interval_secs: 0,
        session_quota_bytes: 0,
        snapshot_path: snapshot_path(tag),
        threads: 1,
        shards,
        archive: ArchiveConfig::default(),
        obs: ObsConfig::default(),
        fault: String::new(),
    }
}

fn spec(i: usize) -> SessionSpec {
    SessionSpec {
        name: format!("route-{i}"),
        layer_dims: DIMS.to_vec(),
        rank: 3,
        beta: 0.9,
        seed: 900 + i as u64,
        window: 8,
        collapse_frac: 0.25,
    }
}

/// Open one session per fresh connection (connections round-robin over
/// shards, so ids stride the shard allocators), ingest its
/// deterministic stream, and return `(id, trajectory)` pairs.
fn populate(addr: &str) -> Vec<(u64, Vec<TrajectoryPoint>)> {
    (0..TENANTS)
        .map(|i| {
            let (mut client, _info) = SketchClient::connect(addr).unwrap();
            let mut sess = client.open_session(&spec(i)).unwrap();
            let mut stream = ActStream::new(&DIMS, false, 900 + i as u64);
            for step in 0..STEPS {
                let loss = stream.loss_at(step, STEPS);
                let acts = stream.next_batch(6);
                sess.ingest(loss, &acts, false).unwrap();
            }
            (sess.id(), sess.query_trajectory().unwrap())
        })
        .collect()
}

/// PROPERTY: owner shard is `id % shards`, every query answers
/// bit-identically from any connection (any home shard), and both
/// facts survive a snapshot/restart cycle; post-restart allocations
/// never collide with restored ids.
#[test]
fn routing_is_stable_across_shards_and_restart() {
    let cfg = config("stable", SHARDS);
    let snap = cfg.snapshot_path.clone();
    let _ = std::fs::remove_file(&snap);

    let daemon = Daemon::bind(cfg.clone()).unwrap();
    assert_eq!(daemon.shard_count(), SHARDS);
    let addr = daemon.local_addr().unwrap().to_string();
    let handle = daemon.spawn().unwrap();

    let sessions = populate(&addr);
    let mut ids: Vec<u64> = sessions.iter().map(|(id, _)| *id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), TENANTS, "session ids must be unique");
    // Sequential connections round-robin over 4 shards, and each
    // shard's allocator strides by the shard count, so the 8 ids cover
    // every residue class.
    let mut residues: Vec<u64> =
        ids.iter().map(|id| id % SHARDS as u64).collect();
    residues.sort_unstable();
    residues.dedup();
    assert_eq!(residues.len(), SHARDS, "ids cover every shard: {ids:?}");

    // The per-shard Stats rows pin the ownership rule directly.
    let check_ownership = |addr: &str| {
        let (mut control, _info) = SketchClient::connect(addr).unwrap();
        let stats = control.stats().unwrap();
        assert_eq!(stats.daemon.shards, SHARDS as u64);
        assert_eq!(stats.shards.len(), SHARDS);
        for sh in &stats.shards {
            let owned = sessions
                .iter()
                .filter(|(id, _)| id % SHARDS as u64 == sh.shard)
                .count() as u64;
            assert_eq!(
                sh.sessions, owned,
                "shard {} must own exactly the id % {SHARDS} sessions",
                sh.shard
            );
        }
    };
    check_ownership(&addr);

    // Query every session from several fresh connections: each lands
    // on a different home shard, yet the owner-routed answers are
    // bit-identical every time.
    for round in 0..SHARDS {
        let (mut client, _info) = SketchClient::connect(&addr).unwrap();
        for (id, traj) in &sessions {
            assert_eq!(
                &client.session(*id).query_trajectory().unwrap(),
                traj,
                "round {round}: session {id} answered differently"
            );
        }
    }

    // Restart on the shutdown snapshot: same ids, same owners, same
    // answers.
    handle.stop().unwrap();
    let daemon = Daemon::bind(cfg).unwrap();
    assert_eq!(daemon.session_count(), TENANTS);
    let addr = daemon.local_addr().unwrap().to_string();
    let handle = daemon.spawn().unwrap();

    check_ownership(&addr);
    let (mut client, _info) = SketchClient::connect(&addr).unwrap();
    for (id, traj) in &sessions {
        assert_eq!(
            &client.session(*id).query_trajectory().unwrap(),
            traj,
            "session {id} diverged across restart"
        );
    }

    // New allocations resume *past* every restored id on every shard
    // (fetch_max keeps each allocator id-congruent and ahead).
    let fresh = client.open_session(&spec(99)).unwrap().id();
    assert!(
        !ids.contains(&fresh),
        "post-restart id {fresh} collides with restored ids {ids:?}"
    );
    client.session(fresh).close().unwrap();

    handle.stop().unwrap();
    let _ = std::fs::remove_file(&snap);
}

/// COMPAT: a snapshot written by a single-shard daemon (bytewise the
/// pre-shard v3 format: sessions sorted by id, one merged metrics
/// block) warm-restarts into a 4-shard daemon — sessions route to
/// `id % 4`, every archive query answers bit-identically, lifetime
/// metrics survive the merge, and ingest continues cleanly.
#[test]
fn pre_shard_snapshot_restores_into_sharded_daemon() {
    const N: usize = 3;
    let one = config("preshard", 1);
    let snap = one.snapshot_path.clone();
    let _ = std::fs::remove_file(&snap);

    // Phase 1: a 1-shard daemon (the pre-shard serve path) builds the
    // snapshot.
    let daemon = Daemon::bind(one.clone()).unwrap();
    let addr = daemon.local_addr().unwrap().to_string();
    let handle = daemon.spawn().unwrap();
    let mut sessions = Vec::new();
    {
        let (mut client, _info) = SketchClient::connect(&addr).unwrap();
        for i in 0..N {
            let mut sess = client.open_session(&spec(i)).unwrap();
            let mut stream = ActStream::new(&DIMS, false, 900 + i as u64);
            for step in 0..STEPS {
                let loss = stream.loss_at(step, STEPS);
                let acts = stream.next_batch(6);
                sess.ingest(loss, &acts, false).unwrap();
            }
            let id = sess.id();
            let traj = sess.query_trajectory().unwrap();
            let info = sess.archive_info().unwrap();
            let sims: Vec<_> = (0..DIMS.len())
                .map(|l| sess.query_similarity(l).unwrap())
                .collect();
            let drifts: Vec<_> = (0..DIMS.len())
                .map(|l| sess.query_drift(l).unwrap())
                .collect();
            sessions.push((id, traj, info, sims, drifts));
        }
    }
    let before = {
        let (mut client, _info) = SketchClient::connect(&addr).unwrap();
        client.metrics().unwrap()
    };
    handle.stop().unwrap();

    // Phase 2: the same snapshot boots a 4-shard daemon.
    let four = ServeConfig {
        shards: SHARDS,
        ..one
    };
    let daemon = Daemon::bind(four).unwrap();
    assert_eq!(daemon.session_count(), N);
    assert_eq!(daemon.shard_count(), SHARDS);
    let addr = daemon.local_addr().unwrap().to_string();
    let handle = daemon.spawn().unwrap();

    let (mut client, info) = SketchClient::connect(&addr).unwrap();
    assert_eq!(info.sessions, N as u64);
    for (id, traj, arch, sims, drifts) in &sessions {
        let mut sess = client.session(*id);
        assert_eq!(&sess.query_trajectory().unwrap(), traj, "id {id}");
        assert_eq!(&sess.archive_info().unwrap(), arch, "id {id}");
        for l in 0..DIMS.len() {
            assert_eq!(
                sess.query_similarity(l).unwrap(),
                sims[l],
                "id {id} layer {l} similarity"
            );
            assert_eq!(
                sess.query_drift(l).unwrap(),
                drifts[l],
                "id {id} layer {l} drift"
            );
        }
    }

    // Restored 1-shard ids 0..N route to shards 0..N; the remaining
    // shard owns nothing.
    let stats = client.stats().unwrap();
    assert_eq!(stats.shards.len(), SHARDS);
    for sh in &stats.shards {
        let owned = sessions
            .iter()
            .filter(|(id, ..)| id % SHARDS as u64 == sh.shard)
            .count() as u64;
        assert_eq!(sh.sessions, owned, "shard {}", sh.shard);
    }

    // The merged lifetime metrics survived the format unchanged;
    // frames_served is process-scoped and restarted near zero.
    let after = client.metrics().unwrap();
    assert_eq!(after.ingest.count, before.ingest.count);
    assert_eq!(after.ingest_bytes, before.ingest_bytes);
    assert_eq!(after.sessions_opened, before.sessions_opened);
    assert!(after.frames_served < before.frames_served);

    // Sessions keep ingesting on their new owner shards, and a new
    // session gets a never-used id.
    for (i, (id, ..)) in sessions.iter().enumerate() {
        let mut stream = ActStream::new(&DIMS, false, 777 + i as u64);
        let acts = stream.next_batch(6);
        let reply = client.session(*id).ingest(0.25, &acts, false).unwrap();
        assert_eq!(reply.batches, STEPS as u64 + 1, "id {id}");
    }
    let fresh = client.open_session(&spec(98)).unwrap().id();
    assert!(
        sessions.iter().all(|(id, ..)| id != &fresh),
        "fresh id {fresh} collides with a restored session"
    );

    handle.stop().unwrap();
    let _ = std::fs::remove_file(&snap);
}
