//! Cross-validation: the rust substrate and the AOT (jax/Pallas) path must
//! compute the same sketching mathematics.  Same inputs -> same sketches,
//! reconstructions and monitoring metrics to f32 tolerance.

use sketchgrad::runtime::{Runtime, Tensor};
use sketchgrad::sketch::metrics::stable_rank_power;
use sketchgrad::sketch::reconstruct::reconstruct_batch;
use sketchgrad::sketch::{Mat, Projections, SketchTriplet};
use sketchgrad::util::rng::Rng;
use std::path::PathBuf;

fn runtime() -> Option<Runtime> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Runtime::new(&dir).expect("runtime"))
}

/// Full recon_eval cross-check at every compiled rank.
#[test]
fn recon_eval_cross_rank_agreement() {
    let Some(rt) = runtime() else { return };
    let (n_b, d) = (128usize, 512usize);
    for r in [2usize, 4, 8, 16] {
        let exe = rt.load(&format!("recon_eval_r{r}")).unwrap();
        let k = 2 * r + 1;
        let mut rng = Rng::new(100 + r as u64);
        let a: Vec<f32> = rng.normal_vec_f32(n_b * d);
        let ups = rng.normal_vec_f32(n_b * k);
        let omg = rng.normal_vec_f32(n_b * k);
        let phi = rng.normal_vec_f32(n_b * k);
        let psi = rng.normal_vec_f32(k);

        let outs = exe
            .run(&[
                Tensor::from_f32(&[n_b, d], a.clone()),
                Tensor::from_f32(&[n_b, k], ups.clone()),
                Tensor::from_f32(&[n_b, k], omg.clone()),
                Tensor::from_f32(&[n_b, k], phi.clone()),
                Tensor::from_f32(&[k], psi.clone()),
            ])
            .unwrap();
        let aot_err = outs[1].scalar().unwrap() as f64;
        let aot_atilde = outs[0].f32_data().unwrap();

        // Native replay.
        let a_m = Mat::from_f32(n_b, d, &a);
        let proj = Projections {
            upsilon: Mat::from_f32(n_b, k, &ups),
            omega: Mat::from_f32(n_b, k, &omg),
            phi: Mat::from_f32(n_b, k, &phi),
            psi: std::sync::Arc::new(vec![psi
                .iter()
                .map(|&x| x as f64)
                .collect()]),
            rank: r,
        };
        let mut t = SketchTriplet::zeros(d, r, 0.0);
        t.update(&a_m, &a_m, &proj, 0);
        let native = reconstruct_batch(&t, &proj.omega);
        let native_err = native.sub(&a_m).fro_norm();

        let rel = (aot_err - native_err).abs() / native_err;
        assert!(rel < 3e-2, "r={r}: aot {aot_err} vs native {native_err}");

        // Element-wise agreement of the reconstructions themselves
        // (scaled by the typical magnitude).
        let scale = native.fro_norm() / ((n_b * d) as f64).sqrt();
        let mut max_diff = 0.0f64;
        for (i, &v) in aot_atilde.iter().enumerate() {
            let diff = (v as f64 - native.data[i]).abs() / scale.max(1e-9);
            max_diff = max_diff.max(diff);
        }
        assert!(max_diff < 0.5, "r={r}: elementwise rel diff {max_diff}");
    }
}

/// EMA recursion vs Lemma 4.1 closed form in the native substrate,
/// through the public engine API.
#[test]
fn ema_composition_matches() {
    use sketchgrad::sketch::{SketchConfig, Sketcher};
    let (n_b, d) = (16usize, 32usize);
    let beta = 0.9;
    let mut rng = Rng::new(55);
    let mut engine = SketchConfig::builder()
        .layer_dims(&[d])
        .rank(2)
        .beta(beta)
        .seed(55)
        .build_engine()
        .unwrap();
    let batches: Vec<Mat> =
        (0..4).map(|_| Mat::gaussian(n_b, d, &mut rng)).collect();
    for b in &batches {
        engine.ingest(&[b.clone(), b.clone()]).unwrap();
    }
    let proj = engine.projections(n_b).unwrap();
    let n = batches.len();
    let mut want = Mat::zeros(d, proj.k());
    for (j, b) in batches.iter().enumerate() {
        let w = (1.0 - beta) * beta.powi((n - 1 - j) as i32);
        want = want.add(&b.t_matmul(&proj.upsilon).scale(w));
    }
    assert!(engine.layers()[0].x.max_abs_diff(&want) < 1e-10);
}

/// Stable-rank estimates agree between power iteration and exact Jacobi.
/// Converged power iteration (200 iters) must match Jacobi closely; the
/// production 24-iter estimate is a biased-but-monotone proxy and must be
/// within 15% (gaussian sketches have small top-eigengaps at larger k).
#[test]
fn stable_rank_agreement_native_vs_jacobi() {
    let mut rng = Rng::new(77);
    for cols in [5usize, 9, 17] {
        let y = Mat::gaussian(512, cols, &mut rng);
        let exact = sketchgrad::sketch::eig::stable_rank(&y);
        let converged = stable_rank_power(&y, 200);
        assert!(
            (converged - exact).abs() / exact < 2e-3,
            "cols={cols}: converged {converged} vs exact {exact}"
        );
        let fast = stable_rank_power(&y, 24);
        assert!(
            (fast - exact).abs() / exact < 0.15,
            "cols={cols}: fast {fast} vs exact {exact}"
        );
    }
}
