//! Accept-storm stress: 256 clients connect to a 4-shard daemon at the
//! same instant (DESIGN.md §9 acceptance).  Every connection must be
//! accepted, round-robined to a shard, and fully served — open, two
//! ingests, diagnose, close — with unique session ids, exact frame
//! accounting afterwards, and work landing on all four shards.

use std::sync::Barrier;
use std::thread;

use anyhow::{ensure, Result};

use sketchgrad::config::{ArchiveConfig, ClientConfig, ObsConfig, ServeConfig};
use sketchgrad::data::ActStream;
use sketchgrad::serve::proto::SessionSpec;
use sketchgrad::serve::{Daemon, SketchClient};

const CONNS: usize = 256;
const SHARDS: usize = 4;
const DIMS: [usize; 2] = [12, 6];

fn storm_tenant(addr: &str, i: usize, net: &ClientConfig) -> Result<u64> {
    let (mut client, _info) = SketchClient::connect_with(addr, net)?;
    let mut sess = client.open_session(&SessionSpec {
        name: format!("storm-{i}"),
        layer_dims: DIMS.to_vec(),
        rank: 2,
        beta: 0.9,
        seed: 7_000 + i as u64,
        window: 4,
        collapse_frac: 0.25,
    })?;
    let mut stream = ActStream::new(&DIMS, false, 7_000 + i as u64);
    for step in 0..2 {
        let loss = stream.loss_at(step, 2);
        let acts = stream.next_batch(3);
        sess.ingest(loss, &acts, false)?;
    }
    let d = sess.diagnose()?;
    ensure!(d.steps_seen == 2, "tenant {i}: steps {}", d.steps_seen);
    let id = sess.id();
    sess.close()?;
    Ok(id)
}

#[test]
fn storm_of_256_concurrent_connections_is_fully_served() {
    let snap = std::env::temp_dir()
        .join(format!("sketchd-storm-{}.snap", std::process::id()))
        .to_string_lossy()
        .into_owned();
    let _ = std::fs::remove_file(&snap);
    let daemon = Daemon::bind(ServeConfig {
        addr: "127.0.0.1:0".into(),
        max_sessions: CONNS * 2,
        snapshot_interval_secs: 0,
        session_quota_bytes: 0,
        snapshot_path: snap.clone(),
        threads: 1,
        shards: SHARDS,
        archive: ArchiveConfig::default(),
        obs: ObsConfig::default(),
        fault: String::new(),
    })
    .unwrap();
    let addr = daemon.local_addr().unwrap().to_string();
    let handle = daemon.spawn().unwrap();

    // Generous deadlines + retries: a simultaneous storm can overflow
    // the accept backlog, and retried connects must still land.
    let net = ClientConfig {
        connect_timeout_ms: 10_000,
        io_timeout_ms: 30_000,
        connect_retries: 8,
        retry_backoff_ms: 25,
    };
    let start = Barrier::new(CONNS);
    let start_ref = &start;
    let mut ids: Vec<u64> = thread::scope(|s| {
        let handles: Vec<_> = (0..CONNS)
            .map(|i| {
                let addr = addr.clone();
                let net = net.clone();
                s.spawn(move || {
                    start_ref.wait();
                    storm_tenant(&addr, i, &net)
                })
            })
            .collect();
        handles
            .into_iter()
            .enumerate()
            .map(|(i, h)| {
                h.join()
                    .unwrap_or_else(|_| panic!("tenant {i} panicked"))
                    .unwrap_or_else(|e| panic!("tenant {i} failed: {e:#}"))
            })
            .collect()
    });

    // Every session id handed out under the storm was unique.
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), CONNS, "duplicate session ids under storm");

    // The daemon is intact: zero sessions left open, every frame
    // accounted for, and the round-robin spread all four shards.
    let (mut control, info) = SketchClient::connect_with(&addr, &net).unwrap();
    assert_eq!(info.sessions, 0);
    let m = control.metrics().unwrap();
    assert_eq!(m.sessions_open, 0);
    assert_eq!(m.sessions_opened, CONNS as u64);
    assert_eq!(m.ingest.count, (CONNS * 2) as u64);
    assert_eq!(m.diagnose.count, CONNS as u64);
    assert_eq!(m.busy_total(), 0);

    let stats = control.stats().unwrap();
    assert_eq!(stats.daemon.shards, SHARDS as u64);
    assert_eq!(stats.shards.len(), SHARDS);
    assert!(
        stats.shards.iter().all(|sh| sh.ingest_frames > 0),
        "every shard must have carried ingest traffic: {:?}",
        stats.shards
    );
    let per_shard: u64 = stats.shards.iter().map(|sh| sh.ingest_frames).sum();
    assert_eq!(per_shard, (CONNS * 2) as u64, "per-shard sum must balance");

    handle.stop().unwrap();
    let _ = std::fs::remove_file(&snap);
}
