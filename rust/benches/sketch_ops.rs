//! Micro-benchmarks for the native sketching substrate hot paths: fused
//! zero-allocation engine ingest serial vs pooled vs the PR3-era
//! reference path (allocating unfused contributions + spawn-per-call
//! scoped threads), fused vs unfused reconstruction, the persistent-pool
//! handoff vs a thread spawn, and the monitoring metric kernels.
//!
//! Run: `cargo bench --bench sketch_ops` (add `-- --quick` for the cheap
//! CI sizing).  Always writes `BENCH_sketch.json` **at the repository
//! root** (so the benchmark trajectory accumulates across PRs) — ns/op
//! per bench plus summary scalars (`ingest_speedup_2t/4t`,
//! `fused_speedup_vs_pr3`, `pool_reuse_speedup`, ...) — which the CI
//! `bench-smoke` job uploads and gates on.  The parallel path is also
//! numerically cross-checked against serial here (<= 1e-12, expected
//! bitwise) so a kernel regression fails the bench run itself.

use sketchgrad::archive::{archive_record_bytes, SessionArchive};
use sketchgrad::benchkit::{quick_requested, Bench};
use sketchgrad::config::{ArchiveConfig, ObsConfig, ServeConfig};
use sketchgrad::monitor::{step_metrics, MonitorHub};
use sketchgrad::serve::{monitor_config, Daemon, SessionSpec, SketchClient};
use sketchgrad::sketch::metrics::stable_rank_power;
use sketchgrad::sketch::reconstruct::reconstruct_batch_unfused;
use sketchgrad::sketch::{
    kernel, Mat, Pool, Projections, SketchConfig, SketchEngine,
    SketchTriplet, Sketcher,
};
use sketchgrad::util::rng::Rng;

/// Written at the repository root (the bench runs with CWD = rust/), so
/// the cross-PR benchmark trajectory accumulates in one place.
const BENCH_JSON: &str =
    concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_sketch.json");

/// The default shape the CI perf gate compares at: enough layers for the
/// per-layer fan-out to occupy 4 workers, wide enough that each triplet
/// update is kernel-bound rather than spawn-bound.
const BENCH_DIMS: [usize; 8] = [512; 8];
const BENCH_NB: usize = 128;
const BENCH_RANK: usize = 8;

fn bench_engine(threads: usize) -> SketchEngine {
    SketchConfig::builder()
        .layer_dims(&BENCH_DIMS)
        .rank(BENCH_RANK)
        .beta(0.95)
        .seed(42)
        .threads(threads)
        .build_engine()
        .unwrap()
}

fn bench_acts(rng: &mut Rng) -> Vec<Mat> {
    let mut acts = vec![Mat::gaussian(BENCH_NB, BENCH_DIMS[0], rng)];
    for &d in &BENCH_DIMS {
        acts.push(Mat::gaussian(BENCH_NB, d, rng));
    }
    acts
}

/// Parallel-vs-serial numerics witness: same seed and batches (including a
/// tail batch), triplet state must agree to <= 1e-12 (bitwise, per the
/// kernel determinism contract).
fn max_parallel_divergence() -> f64 {
    let mut serial = bench_engine(1);
    let mut par = bench_engine(4);
    let mut max_diff: f64 = 0.0;
    for step in 0..3 {
        let mut rng = Rng::new(7 + step);
        let mut acts = bench_acts(&mut rng);
        if step == 2 {
            // Tail batch: truncate every activation to 1/3 of the rows.
            let tail = BENCH_NB / 3;
            acts = acts
                .iter()
                .map(|a| {
                    Mat::from_vec(
                        tail,
                        a.cols,
                        a.data[..tail * a.cols].to_vec(),
                    )
                })
                .collect();
        }
        serial.ingest(&acts).unwrap();
        par.ingest(&acts).unwrap();
    }
    max_diff = max_diff.max(serial.max_state_diff(&par));
    for l in 0..serial.n_layers() {
        let rs = serial.reconstruct(l).unwrap();
        let rp = par.reconstruct(l).unwrap();
        max_diff = max_diff.max(rs.max_abs_diff(&rp));
    }
    max_diff
}

fn main() {
    let quick = quick_requested();
    let mut bench = Bench::sized(quick);
    let mut rng = Rng::new(42);

    // --- serial vs threaded ingest/reconstruct at the default shape ---
    let acts = bench_acts(&mut rng);
    let act_bytes: usize = acts.iter().map(|a| a.data.len() * 8).sum();
    for threads in [1usize, 2, 4] {
        let mut engine = bench_engine(threads);
        engine.ingest(&acts).unwrap();
        let bytes = engine.memory() + act_bytes;
        let suffix = if threads == 1 {
            "serial".to_string()
        } else {
            format!("threads{threads}")
        };
        bench.run_bytes(
            &format!("ingest_{suffix}"),
            Some((1.0, "updates/s")),
            Some(bytes),
            || {
                engine.ingest(&acts).unwrap();
            },
        );
        bench.run_bytes(
            &format!("reconstruct_{suffix}"),
            Some((1.0, "recon/s")),
            Some(bytes),
            || {
                let _ = engine.reconstruct(0).unwrap();
            },
        );
    }

    // --- fused ingest vs the PR3 reference path at the same shape ---
    // The reference replays PR3 exactly: three allocated contribution
    // matrices per layer per step (t_matmul -> scale_cols -> ema_blend)
    // through the spawn-per-call scoped kernels.  Serial-vs-serial is
    // the cleanest read on the tiling + fusion + zero-alloc win (no
    // scheduler noise); the threaded pair adds the pool-vs-spawn win.
    {
        let mut proj_rng = Rng::new(42);
        let proj = Projections::sample(
            BENCH_NB,
            BENCH_DIMS.len(),
            BENCH_RANK,
            &mut proj_rng,
        );
        for threads in [1usize, 4] {
            let mut layers: Vec<SketchTriplet> = BENCH_DIMS
                .iter()
                .map(|&d| SketchTriplet::zeros(d, BENCH_RANK, 0.95))
                .collect();
            let suffix = if threads == 1 {
                "serial".to_string()
            } else {
                format!("threads{threads}")
            };
            bench.run_bytes(
                &format!("ingest_pr3_{suffix}"),
                Some((1.0, "updates/s")),
                Some(act_bytes),
                || {
                    for (l, t) in layers.iter_mut().enumerate() {
                        let a_in = if l == 0 { &acts[1] } else { &acts[l] };
                        t.update_scoped(a_in, &acts[l + 1], &proj, l, threads);
                    }
                },
            );
        }
    }

    // --- persistent-pool handoff vs spawn-per-call, same tiled math ---
    // One EMA-shaped product per op: the gap between these two is the
    // dispatch cost the pool amortises away (plus the PR3 scalar loop
    // for the scoped side, which is why the gate only requires >= 1).
    {
        let a = Mat::gaussian(BENCH_NB, 512, &mut rng);
        let b = Mat::gaussian(BENCH_NB, 2 * BENCH_RANK + 1, &mut rng);
        let pool = Pool::with_lanes(4);
        bench.run("t_matmul_pool4", Some((1.0, "ops/s")), || {
            let _ = kernel::t_matmul(&a, &b, &pool);
        });
        bench.run("t_matmul_scoped4", Some((1.0, "ops/s")), || {
            let _ = kernel::scoped::t_matmul(&a, &b, 4);
        });
    }

    let speedup = |a: &str, b: &str| {
        bench.result(a).unwrap().ns_per_op() / bench.result(b).unwrap().ns_per_op()
    };
    let ingest_2t = speedup("ingest_serial", "ingest_threads2");
    let ingest_4t = speedup("ingest_serial", "ingest_threads4");
    let recon_4t = speedup("reconstruct_serial", "reconstruct_threads4");
    let fused_vs_pr3 = speedup("ingest_pr3_serial", "ingest_serial");
    let fused_vs_pr3_4t = speedup("ingest_pr3_threads4", "ingest_threads4");
    let pool_reuse = speedup("t_matmul_scoped4", "t_matmul_pool4");
    let divergence = max_parallel_divergence();

    // --- the original per-rank micro-benches ---
    let (n_b, d) = (128usize, 512usize);
    let ranks: &[usize] = if quick { &[2, 8] } else { &[2, 4, 8, 16] };
    for &rank in ranks {
        let mut engine = SketchConfig::builder()
            .layer_dims(&[d])
            .rank(rank)
            .beta(0.95)
            .seed(42)
            .build_engine()
            .unwrap();
        let a = Mat::gaussian(n_b, d, &mut rng);
        let acts = vec![a.clone(), a];
        engine.ingest(&acts).unwrap();

        bench.run(
            &format!("engine_ingest r={rank}"),
            Some((1.0, "updates/s")),
            || {
                engine.ingest(&acts).unwrap();
            },
        );
        bench.run(
            &format!("reconstruct_fused r={rank}"),
            Some((1.0, "recon/s")),
            || {
                let _ = engine.reconstruct(0).unwrap();
            },
        );
        let t = &engine.layers()[0];
        let omega = &engine.projections(n_b).unwrap().omega;
        bench.run(
            &format!("reconstruct_unfused(dxd) r={rank}"),
            Some((1.0, "recon/s")),
            || {
                let _ = reconstruct_batch_unfused(t, omega);
            },
        );
        bench.run(
            &format!("monitor_metrics r={rank}"),
            Some((1.0, "evals/s")),
            || {
                let _ = engine.metrics();
            },
        );
    }

    if !quick {
        // Stable-rank power iteration on a wide matrix (the Fig-5 metric).
        let y = Mat::gaussian(1024, 9, &mut rng);
        bench.run("stable_rank_power 1024x9", None, || {
            let _ = stable_rank_power(&y, 24);
        });
    }

    // --- archive ring: steady-state record + query (DESIGN.md §7) ---
    // Record benches the in-place slot overwrite a full ring performs on
    // every sampled ingest interval; the trajectory query is the cheapest
    // whole-archive analytics pass (per-layer Frobenius norms over every
    // stored interval) and is the `archive_query_ns` the CI gate tracks.
    let (archive_query_ns, archive_bytes_per_interval) = {
        let mut engine = bench_engine(1);
        let unit = engine.config().precision.bytes();
        let cap = if quick { 16usize } else { 64 };
        let mut archive = SessionArchive::new(cap, 1, unit);
        for _ in 0..cap {
            engine.ingest(&acts).unwrap();
            archive.maybe_record(engine.batches_ingested(), 1.0, engine.layers());
        }
        assert_eq!(archive.len(), cap, "ring filled before steady-state bench");
        bench.run("archive_record", Some((1.0, "records/s")), || {
            archive.maybe_record(engine.batches_ingested(), 1.0, engine.layers());
        });
        bench.run("archive_query_trajectory", Some((1.0, "queries/s")), || {
            let _ = archive.trajectory();
        });
        (
            bench.result("archive_query_trajectory").unwrap().ns_per_op(),
            archive_record_bytes(&BENCH_DIMS, BENCH_RANK, unit) as f64,
        )
    };

    // --- ingest over loopback (serve subsystem, DESIGN.md §5) ---
    // One full monitored step through sketchd on 127.0.0.1 vs the same
    // step in-process (engine ingest + metrics + hub observe): the gap
    // is the wire + framing overhead clients of the daemon pay.
    let snap_path = std::env::temp_dir()
        .join(format!("sketchd-bench-{}.snap", std::process::id()));
    let daemon = Daemon::bind(ServeConfig {
        addr: "127.0.0.1:0".into(),
        max_sessions: 4,
        snapshot_interval_secs: 0,
        session_quota_bytes: 0,
        snapshot_path: snap_path.to_string_lossy().into_owned(),
        threads: 1,
        archive: ArchiveConfig::default(),
        obs: ObsConfig::default(),
        fault: String::new(),
    })
    .expect("bind loopback daemon");
    let addr = daemon.local_addr().unwrap().to_string();
    let handle = daemon.spawn().expect("spawn loopback daemon");
    let spec = SessionSpec {
        name: "bench".into(),
        layer_dims: BENCH_DIMS.to_vec(),
        rank: BENCH_RANK,
        beta: 0.95,
        seed: 42,
        window: 10,
        collapse_frac: 0.1,
    };
    let (mut client, _info) =
        SketchClient::connect(&addr).expect("connect loopback daemon");
    let session = client.open_session(&spec).expect("open bench session");

    let mut local_engine = bench_engine(1);
    let mut local_hub = MonitorHub::new();
    let local_id = local_hub
        .register("bench", monitor_config(&spec), BENCH_DIMS.len())
        .unwrap();
    bench.run_bytes(
        "monitored_step_local",
        Some((1.0, "steps/s")),
        Some(act_bytes),
        || {
            local_engine.ingest(&acts).unwrap();
            local_hub
                .observe(local_id, &step_metrics(1.0, &local_engine.metrics()))
                .unwrap();
        },
    );
    bench.run_bytes(
        "ingest_loopback",
        Some((1.0, "steps/s")),
        Some(act_bytes),
        || {
            client.ingest(session, 1.0, &acts, false).unwrap();
        },
    );
    let loopback_overhead = bench.result("ingest_loopback").unwrap().ns_per_op()
        / bench.result("monitored_step_local").unwrap().ns_per_op();
    client.close_session(session).expect("close bench session");
    handle.stop().expect("stop loopback daemon");
    let _ = std::fs::remove_file(&snap_path);

    bench.report("sketch substrate micro-benches (native rust)");
    println!(
        "\ningest speedup: 2t {ingest_2t:.2}x, 4t {ingest_4t:.2}x | \
         fused vs PR3 {fused_vs_pr3:.2}x (4t {fused_vs_pr3_4t:.2}x) | \
         pool reuse {pool_reuse:.2}x | reconstruct 4t {recon_4t:.2}x | \
         parallel divergence {divergence:.2e} | loopback overhead \
         {loopback_overhead:.2}x | archive query {archive_query_ns:.0} ns \
         ({archive_bytes_per_interval:.0} B/interval)"
    );
    bench
        .write_json(
            "sketch substrate micro-benches",
            quick,
            &[
                ("ingest_speedup_2t", ingest_2t),
                ("ingest_speedup_4t", ingest_4t),
                ("reconstruct_speedup_4t", recon_4t),
                ("fused_speedup_vs_pr3", fused_vs_pr3),
                ("fused_speedup_vs_pr3_4t", fused_vs_pr3_4t),
                ("pool_reuse_speedup", pool_reuse),
                ("parallel_max_abs_diff", divergence),
                ("loopback_overhead_x", loopback_overhead),
                ("archive_query_ns", archive_query_ns),
                ("archive_bytes_per_interval", archive_bytes_per_interval),
            ],
            BENCH_JSON,
        )
        .expect("write BENCH_sketch.json");
    println!("wrote {BENCH_JSON}");

    if divergence > 1e-12 {
        eprintln!("FAIL: parallel ingest diverged from serial ({divergence:.2e} > 1e-12)");
        std::process::exit(1);
    }
}
