//! Micro-benchmarks for the native sketching substrate hot paths: engine
//! ingest (EMA triplet update) serial vs threaded, fused vs unfused
//! reconstruction, and the monitoring metric kernels.
//!
//! Run: `cargo bench --bench sketch_ops` (add `-- --quick` for the cheap
//! CI sizing).  Always writes `BENCH_sketch.json` — ns/op per bench plus
//! `ingest_speedup_2t/4t` summary scalars — which the CI `bench-smoke`
//! job uploads and gates on.  The parallel path is also numerically
//! cross-checked against serial here (<= 1e-12, expected bitwise) so a
//! kernel regression fails the bench run itself.

use sketchgrad::benchkit::{quick_requested, Bench};
use sketchgrad::config::ServeConfig;
use sketchgrad::monitor::{step_metrics, MonitorHub};
use sketchgrad::serve::{monitor_config, Daemon, SessionSpec, SketchClient};
use sketchgrad::sketch::metrics::stable_rank_power;
use sketchgrad::sketch::reconstruct::reconstruct_batch_unfused;
use sketchgrad::sketch::{Mat, SketchConfig, SketchEngine, Sketcher};
use sketchgrad::util::rng::Rng;

const BENCH_JSON: &str = "BENCH_sketch.json";

/// The default shape the CI perf gate compares at: enough layers for the
/// per-layer fan-out to occupy 4 workers, wide enough that each triplet
/// update is kernel-bound rather than spawn-bound.
const BENCH_DIMS: [usize; 8] = [512; 8];
const BENCH_NB: usize = 128;
const BENCH_RANK: usize = 8;

fn bench_engine(threads: usize) -> SketchEngine {
    SketchConfig::builder()
        .layer_dims(&BENCH_DIMS)
        .rank(BENCH_RANK)
        .beta(0.95)
        .seed(42)
        .threads(threads)
        .build_engine()
        .unwrap()
}

fn bench_acts(rng: &mut Rng) -> Vec<Mat> {
    let mut acts = vec![Mat::gaussian(BENCH_NB, BENCH_DIMS[0], rng)];
    for &d in &BENCH_DIMS {
        acts.push(Mat::gaussian(BENCH_NB, d, rng));
    }
    acts
}

/// Parallel-vs-serial numerics witness: same seed and batches (including a
/// tail batch), triplet state must agree to <= 1e-12 (bitwise, per the
/// kernel determinism contract).
fn max_parallel_divergence() -> f64 {
    let mut serial = bench_engine(1);
    let mut par = bench_engine(4);
    let mut max_diff: f64 = 0.0;
    for step in 0..3 {
        let mut rng = Rng::new(7 + step);
        let mut acts = bench_acts(&mut rng);
        if step == 2 {
            // Tail batch: truncate every activation to 1/3 of the rows.
            let tail = BENCH_NB / 3;
            acts = acts
                .iter()
                .map(|a| {
                    Mat::from_vec(
                        tail,
                        a.cols,
                        a.data[..tail * a.cols].to_vec(),
                    )
                })
                .collect();
        }
        serial.ingest(&acts).unwrap();
        par.ingest(&acts).unwrap();
    }
    max_diff = max_diff.max(serial.max_state_diff(&par));
    for l in 0..serial.n_layers() {
        let rs = serial.reconstruct(l).unwrap();
        let rp = par.reconstruct(l).unwrap();
        max_diff = max_diff.max(rs.max_abs_diff(&rp));
    }
    max_diff
}

fn main() {
    let quick = quick_requested();
    let mut bench = Bench::sized(quick);
    let mut rng = Rng::new(42);

    // --- serial vs threaded ingest/reconstruct at the default shape ---
    let acts = bench_acts(&mut rng);
    let act_bytes: usize = acts.iter().map(|a| a.data.len() * 8).sum();
    for threads in [1usize, 2, 4] {
        let mut engine = bench_engine(threads);
        engine.ingest(&acts).unwrap();
        let bytes = engine.memory() + act_bytes;
        let suffix = if threads == 1 {
            "serial".to_string()
        } else {
            format!("threads{threads}")
        };
        bench.run_bytes(
            &format!("ingest_{suffix}"),
            Some((1.0, "updates/s")),
            Some(bytes),
            || {
                engine.ingest(&acts).unwrap();
            },
        );
        bench.run_bytes(
            &format!("reconstruct_{suffix}"),
            Some((1.0, "recon/s")),
            Some(bytes),
            || {
                let _ = engine.reconstruct(0).unwrap();
            },
        );
    }

    let speedup = |a: &str, b: &str| {
        bench.result(a).unwrap().ns_per_op() / bench.result(b).unwrap().ns_per_op()
    };
    let ingest_2t = speedup("ingest_serial", "ingest_threads2");
    let ingest_4t = speedup("ingest_serial", "ingest_threads4");
    let recon_4t = speedup("reconstruct_serial", "reconstruct_threads4");
    let divergence = max_parallel_divergence();

    // --- the original per-rank micro-benches ---
    let (n_b, d) = (128usize, 512usize);
    let ranks: &[usize] = if quick { &[2, 8] } else { &[2, 4, 8, 16] };
    for &rank in ranks {
        let mut engine = SketchConfig::builder()
            .layer_dims(&[d])
            .rank(rank)
            .beta(0.95)
            .seed(42)
            .build_engine()
            .unwrap();
        let a = Mat::gaussian(n_b, d, &mut rng);
        let acts = vec![a.clone(), a];
        engine.ingest(&acts).unwrap();

        bench.run(
            &format!("engine_ingest r={rank}"),
            Some((1.0, "updates/s")),
            || {
                engine.ingest(&acts).unwrap();
            },
        );
        bench.run(
            &format!("reconstruct_fused r={rank}"),
            Some((1.0, "recon/s")),
            || {
                let _ = engine.reconstruct(0).unwrap();
            },
        );
        let t = &engine.layers()[0];
        let omega = &engine.projections(n_b).unwrap().omega;
        bench.run(
            &format!("reconstruct_unfused(dxd) r={rank}"),
            Some((1.0, "recon/s")),
            || {
                let _ = reconstruct_batch_unfused(t, omega);
            },
        );
        bench.run(
            &format!("monitor_metrics r={rank}"),
            Some((1.0, "evals/s")),
            || {
                let _ = engine.metrics();
            },
        );
    }

    if !quick {
        // Stable-rank power iteration on a wide matrix (the Fig-5 metric).
        let y = Mat::gaussian(1024, 9, &mut rng);
        bench.run("stable_rank_power 1024x9", None, || {
            let _ = stable_rank_power(&y, 24);
        });
    }

    // --- ingest over loopback (serve subsystem, DESIGN.md §5) ---
    // One full monitored step through sketchd on 127.0.0.1 vs the same
    // step in-process (engine ingest + metrics + hub observe): the gap
    // is the wire + framing overhead clients of the daemon pay.
    let snap_path = std::env::temp_dir()
        .join(format!("sketchd-bench-{}.snap", std::process::id()));
    let daemon = Daemon::bind(ServeConfig {
        addr: "127.0.0.1:0".into(),
        max_sessions: 4,
        snapshot_interval_secs: 0,
        session_quota_bytes: 0,
        snapshot_path: snap_path.to_string_lossy().into_owned(),
        threads: 1,
    })
    .expect("bind loopback daemon");
    let addr = daemon.local_addr().unwrap().to_string();
    let handle = daemon.spawn().expect("spawn loopback daemon");
    let spec = SessionSpec {
        name: "bench".into(),
        layer_dims: BENCH_DIMS.to_vec(),
        rank: BENCH_RANK,
        beta: 0.95,
        seed: 42,
        window: 10,
        collapse_frac: 0.1,
    };
    let (mut client, _info) =
        SketchClient::connect(&addr).expect("connect loopback daemon");
    let session = client.open_session(&spec).expect("open bench session");

    let mut local_engine = bench_engine(1);
    let mut local_hub = MonitorHub::new();
    let local_id = local_hub
        .register("bench", monitor_config(&spec), BENCH_DIMS.len())
        .unwrap();
    bench.run_bytes(
        "monitored_step_local",
        Some((1.0, "steps/s")),
        Some(act_bytes),
        || {
            local_engine.ingest(&acts).unwrap();
            local_hub
                .observe(local_id, &step_metrics(1.0, &local_engine.metrics()))
                .unwrap();
        },
    );
    bench.run_bytes(
        "ingest_loopback",
        Some((1.0, "steps/s")),
        Some(act_bytes),
        || {
            client.ingest(session, 1.0, &acts, false).unwrap();
        },
    );
    let loopback_overhead = bench.result("ingest_loopback").unwrap().ns_per_op()
        / bench.result("monitored_step_local").unwrap().ns_per_op();
    client.close_session(session).expect("close bench session");
    handle.stop().expect("stop loopback daemon");
    let _ = std::fs::remove_file(&snap_path);

    bench.report("sketch substrate micro-benches (native rust)");
    println!(
        "\ningest speedup: 2t {ingest_2t:.2}x, 4t {ingest_4t:.2}x | \
         reconstruct 4t {recon_4t:.2}x | parallel divergence {divergence:.2e} \
         | loopback overhead {loopback_overhead:.2}x"
    );
    bench
        .write_json(
            "sketch substrate micro-benches",
            quick,
            &[
                ("ingest_speedup_2t", ingest_2t),
                ("ingest_speedup_4t", ingest_4t),
                ("reconstruct_speedup_4t", recon_4t),
                ("parallel_max_abs_diff", divergence),
                ("loopback_overhead_x", loopback_overhead),
            ],
            BENCH_JSON,
        )
        .expect("write BENCH_sketch.json");
    println!("wrote {BENCH_JSON}");

    if divergence > 1e-12 {
        eprintln!("FAIL: parallel ingest diverged from serial ({divergence:.2e} > 1e-12)");
        std::process::exit(1);
    }
}
