//! Micro-benchmarks for the native sketching substrate hot paths: engine
//! ingest (EMA triplet update), fused vs unfused reconstruction (the L3
//! perf item), and the monitoring metric kernels.
//! Run: `cargo bench --bench sketch_ops`.

use sketchgrad::benchkit::Bench;
use sketchgrad::sketch::metrics::stable_rank_power;
use sketchgrad::sketch::reconstruct::reconstruct_batch_unfused;
use sketchgrad::sketch::{Mat, SketchConfig, Sketcher};
use sketchgrad::util::rng::Rng;

fn main() {
    let mut bench = Bench::new(2, 10);
    let (n_b, d) = (128usize, 512usize);
    let mut rng = Rng::new(42);

    for rank in [2usize, 4, 8, 16] {
        let mut engine = SketchConfig::builder()
            .layer_dims(&[d])
            .rank(rank)
            .beta(0.95)
            .seed(42)
            .build_engine()
            .unwrap();
        let a = Mat::gaussian(n_b, d, &mut rng);
        let acts = vec![a.clone(), a];
        engine.ingest(&acts).unwrap();

        bench.run(
            &format!("engine_ingest r={rank}"),
            Some((1.0, "updates/s")),
            || {
                engine.ingest(&acts).unwrap();
            },
        );
        bench.run(
            &format!("reconstruct_fused r={rank}"),
            Some((1.0, "recon/s")),
            || {
                let _ = engine.reconstruct(0).unwrap();
            },
        );
        let t = &engine.layers()[0];
        let omega = &engine.projections(n_b).unwrap().omega;
        bench.run(
            &format!("reconstruct_unfused(dxd) r={rank}"),
            Some((1.0, "recon/s")),
            || {
                let _ = reconstruct_batch_unfused(t, omega);
            },
        );
        bench.run(
            &format!("monitor_metrics r={rank}"),
            Some((1.0, "evals/s")),
            || {
                let _ = engine.metrics();
            },
        );
    }

    // Stable-rank power iteration on a wide matrix (the Fig-5 metric).
    let y = Mat::gaussian(1024, 9, &mut rng);
    bench.run("stable_rank_power 1024x9", None, || {
        let _ = stable_rank_power(&y, 24);
    });

    bench.report("sketch substrate micro-benches (native rust)");
}
