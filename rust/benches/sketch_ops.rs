//! Micro-benchmarks for the native sketching substrate hot paths: EMA
//! triplet update, fused vs unfused reconstruction (the L3 perf item), and
//! the monitoring metric kernels.  Run: `cargo bench --bench sketch_ops`.

use sketchgrad::benchkit::Bench;
use sketchgrad::sketch::metrics::{stable_rank_power, triplet_metrics};
use sketchgrad::sketch::reconstruct::{
    reconstruct_batch, reconstruct_batch_unfused,
};
use sketchgrad::sketch::{Mat, Projections, SketchTriplet};
use sketchgrad::util::rng::Rng;

fn main() {
    let mut bench = Bench::new(2, 10);
    let (n_b, d) = (128usize, 512usize);
    let mut rng = Rng::new(42);

    for rank in [2usize, 4, 8, 16] {
        let proj = Projections::sample(n_b, 1, rank, &mut rng);
        let a = Mat::gaussian(n_b, d, &mut rng);
        let mut t = SketchTriplet::zeros(d, rank, 0.95);
        t.update(&a, &a, &proj, 0);

        bench.run(
            &format!("ema_triplet_update r={rank}"),
            Some((1.0, "updates/s")),
            || {
                t.update(&a, &a, &proj, 0);
            },
        );
        bench.run(
            &format!("reconstruct_fused r={rank}"),
            Some((1.0, "recon/s")),
            || {
                let _ = reconstruct_batch(&t, &proj.omega);
            },
        );
        bench.run(
            &format!("reconstruct_unfused(dxd) r={rank}"),
            Some((1.0, "recon/s")),
            || {
                let _ = reconstruct_batch_unfused(&t, &proj.omega);
            },
        );
        bench.run(
            &format!("monitor_metrics r={rank}"),
            Some((1.0, "evals/s")),
            || {
                let _ = triplet_metrics(&t, 24);
            },
        );
    }

    // Stable-rank power iteration on a wide matrix (the Fig-5 metric).
    let y = Mat::gaussian(1024, 9, &mut rng);
    bench.run("stable_rank_power 1024x9", None, || {
        let _ = stable_rank_power(&y, 24);
    });

    bench.report("sketch substrate micro-benches (native rust)");
}
