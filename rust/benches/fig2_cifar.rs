//! FIG2 bench: CIFAR hybrid CNN-MLP with FC-only sketching — accuracy
//! parity between standard and sketched variants plus chunk throughput.
//! Run: `cargo bench --bench fig2_cifar`.

use sketchgrad::benchkit::Bench;
use sketchgrad::config::{ExperimentConfig, Variant};
use sketchgrad::coordinator::{figure_table, open_runtime, run_classifier};
use sketchgrad::coordinator::Trainer;
use sketchgrad::data::{make_chunks, synth_cifar, Init};
use sketchgrad::util::rng::Rng;

fn main() {
    let rt = match open_runtime() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping (artifacts not built): {e}");
            return;
        }
    };
    let mk = |name: &str, variant: Variant| ExperimentConfig {
        name: name.into(),
        family: "cifar".into(),
        variant,
        rank: 2,
        adaptive: false,
        epochs: 1,
        train_size: 128 * 10,
        test_size: 128 * 10,
        seed: 42,
        ..Default::default()
    };
    let std = run_classifier(&rt, &mk("standard", Variant::Standard), false).unwrap();
    let sk = run_classifier(&rt, &mk("sketched_r2", Variant::Sketched), false).unwrap();
    println!("{}", figure_table("Figure 2 — CIFAR (bench scale)", &[&std, &sk]));
    println!("paper shape: selective FC sketching preserves accuracy (both ~equal).\n");

    let mut bench = Bench::new(1, 2);
    for (label, artifact) in [
        ("cifar_std_chunk(10 steps)", "cifar_std_chunk"),
        ("cifar_sk_r2_chunk(10 steps)", "cifar_sk_r2_chunk"),
    ] {
        let mut trainer = Trainer::new(&rt, artifact, Init::Kaiming, 1).unwrap();
        let data = synth_cifar(128 * 10, 1);
        let mut rng = Rng::new(2);
        let chunks = make_chunks(&data, 128, 10, &mut rng, &[3, 32, 32]);
        bench.run(label, Some((10.0, "steps/s")), || {
            trainer.run_chunk(&chunks[0]).unwrap();
        });
    }
    bench.report("fig2 CNN-MLP throughput");
}
