//! FIG3/FIG4 bench: PINN training with monitoring-only sketching — loss
//! convergence parity, L2 relative error across variants, and the sketch
//! overhead (paper: 0.57 MB, identical 0.31 L2 error).
//! Run: `cargo bench --bench fig3_pinn`.

use sketchgrad::benchkit::Bench;
use sketchgrad::coordinator::{open_runtime, run_pinn};
use sketchgrad::memory::fmt_bytes;

fn main() {
    let rt = match open_runtime() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping (artifacts not built): {e}");
            return;
        }
    };
    let chunks = 4; // 160 Adam steps per variant at bench scale

    let std = run_pinn(&rt, "standard", 2, chunks, 42).unwrap();
    let mon2 = run_pinn(&rt, "monitored", 2, chunks, 42).unwrap();
    let mon4 = run_pinn(&rt, "monitored", 4, chunks, 42).unwrap();

    println!("\n## Figure 3/4 — PINN (bench scale, {} steps)\n", chunks * 20);
    println!("| variant | final loss | L2 rel err | sketch overhead |");
    println!("|---|---|---|---|");
    for r in [&std, &mon2, &mon4] {
        println!(
            "| {} | {:.4} | {:.4} | {} |",
            r.label,
            r.losses.last().copied().unwrap_or(f32::NAN),
            r.l2_rel_err,
            fmt_bytes(r.sketch_bytes)
        );
    }
    println!("paper shape: identical loss/error across variants; sub-MB sketch overhead.\n");

    // Throughput of the PINN chunk artifacts.
    let mut bench = Bench::new(1, 2);
    for (label, variant, rank) in [
        ("pinn_std_chunk(20 steps)", "standard", 2usize),
        ("pinn_mon_r2_chunk(20 steps)", "monitored", 2),
    ] {
        bench.run(label, Some((20.0, "steps/s")), || {
            let _ = run_pinn(&rt, variant, rank, 1, 7).unwrap();
        });
    }
    bench.report("fig3 PINN throughput");
}
