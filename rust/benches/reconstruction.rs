//! THM bench (Thm 4.2): reconstruction error vs the sqrt(6)·tau_{r+1}
//! bound across the rank ladder, through the AOT artifacts, plus AOT-vs-
//! native cross-timing.  Run: `cargo bench --bench reconstruction`.

use sketchgrad::benchkit::Bench;
use sketchgrad::coordinator::open_runtime;
use sketchgrad::runtime::Tensor;
use sketchgrad::sketch::{eig, Mat, SketchConfig, Sketcher};
use sketchgrad::util::rng::Rng;

fn main() {
    let rt = match open_runtime() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping (artifacts not built): {e}");
            return;
        }
    };
    let mut bench = Bench::new(2, 10);
    let (n_b, d) = (128usize, 512usize);

    println!("\n## Thm 4.2 error-vs-bound sweep (low-rank-8 + 0.05 noise)\n");
    println!("| r | k | recon err | sqrt(6) tau_(r+1) | ratio |");
    println!("|---|---|---|---|---|");
    for r in [2usize, 4, 8, 16] {
        let exe = rt.load(&format!("recon_eval_r{r}")).unwrap();
        let k = 2 * r + 1;
        let mut rng = Rng::new(42 + r as u64);
        let u = Mat::gaussian(n_b, 8, &mut rng);
        let v = Mat::gaussian(8, d, &mut rng);
        let a = u.matmul(&v).add(&Mat::gaussian(n_b, d, &mut rng).scale(0.05));
        let inputs = vec![
            Tensor::from_f32(&[n_b, d], a.to_f32()),
            Tensor::from_f32(&[n_b, k], rng.normal_vec_f32(n_b * k)),
            Tensor::from_f32(&[n_b, k], rng.normal_vec_f32(n_b * k)),
            Tensor::from_f32(&[n_b, k], rng.normal_vec_f32(n_b * k)),
            Tensor::from_f32(&[k], rng.normal_vec_f32(k)),
        ];
        let outs = exe.run(&inputs).unwrap();
        let err = outs[1].scalar().unwrap() as f64;
        let bound = 6f64.sqrt() * eig::tail_energy(&a, r);
        println!("| {r} | {k} | {err:.3} | {bound:.3} | {:.3} |", err / bound);

        bench.run(
            &format!("aot_recon_eval r={r}"),
            Some((1.0, "calls/s")),
            || {
                let _ = exe.run(&inputs).unwrap();
            },
        );

        // Native comparison at the same rank (beta=0: pure batch sketch).
        let mut engine = SketchConfig::builder()
            .layer_dims(&[d])
            .rank(r)
            .beta(0.0)
            .seed(42 + r as u64)
            .build_engine()
            .unwrap();
        engine.ingest(&[a.clone(), a.clone()]).unwrap();
        bench.run(
            &format!("native_recon r={r}"),
            Some((1.0, "calls/s")),
            || {
                let _ = engine.reconstruct(0).unwrap();
            },
        );
    }
    bench.report("reconstruction: AOT artifact vs native substrate");
}
