//! TAB-MEM1/TAB-MEM2 bench: regenerates both memory tables from §4.7/§5.3
//! (per-iteration ratios and monitoring-window scaling) and measures the
//! real bytes of the full-storage baseline vs the sketch triplet.
//! Run: `cargo bench --bench memory_model`.

use sketchgrad::baselines::checkpoint::{
    checkpoint_activation_bytes, standard_activation_bytes,
};
use sketchgrad::baselines::FullMonitor;
use sketchgrad::benchkit::Bench;
use sketchgrad::memory::{fmt_bytes, mnist_dims, monitor16_dims, MemoryModel};
use sketchgrad::sketch::{Mat, SketchConfig, Sketcher};
use sketchgrad::util::rng::Rng;

fn main() {
    println!("\n## TAB-MEM1 — per-iteration memory (MNIST MLP, N_b=128)\n");
    println!("| r | k | hidden acts | sketch state | reduction | checkpointing sqrt(L) |");
    println!("|---|---|---|---|---|---|");
    let m = MemoryModel::new(&mnist_dims(), 128);
    let hidden = 3 * 128 * 512 * 4;
    for r in [2usize, 4, 8, 16] {
        println!(
            "| {} | {} | {} | {} | {:.1}% | {} |",
            r,
            2 * r + 1,
            fmt_bytes(hidden),
            fmt_bytes(m.sketch_state(r)),
            100.0 * m.per_iteration_reduction(r),
            fmt_bytes(checkpoint_activation_bytes(4, 128, 512)),
        );
    }

    println!("\n## TAB-MEM2 — monitoring memory (16x1024, r=4)\n");
    println!("| T | traditional (model) | traditional (measured) | sketched (measured) | reduction |");
    println!("|---|---|---|---|---|");
    let mm = MemoryModel::new(&monitor16_dims(), 128);
    let mut rng = Rng::new(42);
    // Measured: actually allocate the baseline + the sketch state.
    let mut engine = SketchConfig::builder()
        .uniform_dims(15, 1024)
        .rank(4)
        .beta(0.9)
        .seed(42)
        .build_engine()
        .unwrap();
    engine.ensure_projections(128);
    for t in [1usize, 5, 10] {
        let mut full = FullMonitor::new(t);
        for step in 0..t {
            let grads: Vec<Mat> = monitor16_dims()
                .windows(2)
                .map(|w| Mat::gaussian(w[1], w[0], &mut rng))
                .collect();
            full.record(step as u64, grads);
        }
        println!(
            "| {} | {} | {} | {} | {:.2}% |",
            t,
            fmt_bytes(mm.monitoring_traditional(t)),
            fmt_bytes(full.bytes()),
            fmt_bytes(engine.memory()),
            100.0 * mm.monitoring_reduction(t, 4),
        );
    }
    println!("\npaper: 320 MB -> 1.7 MB at T=5 (99%); standard vs checkpoint context row included.\n");

    // Cost of the baseline's exact diagnostics vs sketch estimates.
    let mut bench = Bench::new(1, 3);
    let mut full = FullMonitor::new(2);
    for step in 0..2 {
        let grads: Vec<Mat> = mnist_dims()
            .windows(2)
            .map(|w| Mat::gaussian(w[1], w[0], &mut rng))
            .collect();
        full.record(step, grads);
    }
    bench.run("full_monitor.exact_stable_ranks (mnist arch)", None, || {
        let _ = full.latest_stable_ranks();
    });
    bench.run("sketch.metrics (mnist arch, r=4)", None, || {
        let layers = engine.layers();
        for t in &layers[..3.min(layers.len())] {
            let _ = sketchgrad::sketch::metrics::triplet_metrics(t, 24);
        }
    });
    let _ = standard_activation_bytes(4, 128, 512);
    bench.report("memory-model diagnostics cost");
}
