//! FIG5 bench: the gradient-monitoring experiment at bench scale —
//! healthy vs problematic 16x1024 nets, sketch-metric separation, monitor
//! service overhead, and the memory table.
//! Run: `cargo bench --bench fig5_monitoring`.

use sketchgrad::benchkit::Bench;
use sketchgrad::coordinator::{StepMetrics, Trainer};
use sketchgrad::coordinator::open_runtime;
use sketchgrad::data::{make_chunks, synth_mnist, Init};
use sketchgrad::memory::{fmt_bytes, monitor16_dims, MemoryModel};
use sketchgrad::monitor::{MonitorConfig, MonitorHub};
use sketchgrad::util::rng::Rng;

fn main() {
    let rt = match open_runtime() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping (artifacts not built): {e}");
            return;
        }
    };

    // One chunk (20 steps) per configuration, then compare sketch metrics.
    let mut results = Vec::new();
    for (label, artifact, init) in [
        ("healthy", "monitor16_mon_r4_chunk", Init::Kaiming),
        (
            "problematic",
            "monitor16_problematic_chunk",
            Init::KaimingNegBias(-3.0),
        ),
    ] {
        let mut trainer = Trainer::new(&rt, artifact, init, 42).unwrap();
        let data = synth_mnist(128 * 20, 42);
        let mut rng = Rng::new(7);
        let chunks = make_chunks(&data, 128, 20, &mut rng, &[784]);
        trainer.run_chunk(&chunks[0]).unwrap();
        let last = trainer.history.last().unwrap().clone();
        results.push((label, trainer.history.clone(), last));
    }

    println!("\n## Figure 5 — sketch-metric separation (after 20 steps)\n");
    println!("| config | loss | mean ||Z||_F | mean stable rank (k=9) |");
    println!("|---|---|---|---|");
    for (label, _, last) in &results {
        let z: f32 = last.z_norm.iter().sum::<f32>() / last.z_norm.len() as f32;
        let sr: f32 =
            last.stable_rank.iter().sum::<f32>() / last.stable_rank.len() as f32;
        println!("| {label} | {:.3} | {z:.3} | {sr:.2} |", last.loss);
    }
    println!("paper shape: healthy stable rank ~9 (full), problematic collapsed (~3).\n");

    // Hub ingestion throughput (pure L3 hot path): two tenants fed the
    // same 20-step sample, aggregate diagnosis at the end.
    let mut bench = Bench::new(3, 20);
    let sample: Vec<StepMetrics> = results[0].1.clone();
    bench.run("hub.observe 2 tenants x20steps", Some((40.0, "steps/s")), || {
        let mut hub = MonitorHub::new();
        let a = hub.register("healthy", MonitorConfig::for_rank(4), 15).unwrap();
        let b = hub.register("problematic", MonitorConfig::for_rank(4), 15).unwrap();
        for m in &sample {
            hub.observe(a, m).unwrap();
            hub.observe(b, m).unwrap();
        }
        let _ = hub.aggregate();
    });

    let m = MemoryModel::new(&monitor16_dims(), 128);
    println!(
        "\nmemory: traditional T=5 {} vs sketched {} ({:.2}% reduction)",
        fmt_bytes(m.monitoring_traditional(5)),
        fmt_bytes(m.monitoring_sketched(4)),
        100.0 * m.monitoring_reduction(5, 4)
    );
    bench.report("fig5 monitoring throughput");
}
