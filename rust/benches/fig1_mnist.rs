//! FIG1 bench: regenerates Figure 1's panels (memory comparison + training
//! accuracy, standard vs fixed-rank vs adaptive on the MNIST MLP) at bench
//! scale and times end-to-end training throughput per variant.
//! Run: `cargo bench --bench fig1_mnist`.

use sketchgrad::benchkit::Bench;
use sketchgrad::config::{ExperimentConfig, Variant};
use sketchgrad::coordinator::{figure_table, open_runtime, run_classifier};

fn main() {
    let rt = match open_runtime() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping (artifacts not built): {e}");
            return;
        }
    };
    let mk = |name: &str, variant: Variant, adaptive: bool| ExperimentConfig {
        name: name.into(),
        family: "mnist".into(),
        variant,
        rank: 2,
        adaptive,
        epochs: 2,
        train_size: 128 * 50,
        test_size: 128 * 50,
        seed: 42,
        ..Default::default()
    };

    let std = run_classifier(&rt, &mk("standard", Variant::Standard, false), false).unwrap();
    let fixed =
        run_classifier(&rt, &mk("sketched_r2", Variant::Sketched, false), false).unwrap();
    let adaptive =
        run_classifier(&rt, &mk("adaptive", Variant::Sketched, true), false).unwrap();

    println!("{}", figure_table("Figure 1 — MNIST (bench scale)", &[&std, &fixed, &adaptive]));
    println!("paper shape: standard accuracy > sketched (3-5 pt gap); memory std > sketch.\n");

    // Throughput benches: one 50-step chunk per call.
    let mut bench = Bench::new(1, 3);
    for (label, artifact) in [
        ("std_chunk(50 steps)", "mnist_std_chunk"),
        ("sk_r2_chunk(50 steps)", "mnist_sk_r2_chunk"),
        ("sk_r16_chunk(50 steps)", "mnist_sk_r16_chunk"),
    ] {
        use sketchgrad::coordinator::Trainer;
        use sketchgrad::data::{make_chunks, synth_mnist, Init};
        use sketchgrad::util::rng::Rng;
        let mut trainer = Trainer::new(&rt, artifact, Init::Xavier(1.0), 1).unwrap();
        let data = synth_mnist(128 * 50, 1);
        let mut rng = Rng::new(2);
        let chunks = make_chunks(&data, 128, 50, &mut rng, &[784]);
        bench.run(label, Some((50.0, "steps/s")), || {
            trainer.run_chunk(&chunks[0]).unwrap();
        });
    }
    bench.report("fig1 training throughput (per-variant)");
}
