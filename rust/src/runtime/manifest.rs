//! `artifacts/manifest.json` loader: the contract between aot.py and the
//! rust runtime.  Each artifact entry lists its HLO file plus the exact
//! ordered flat input/output tensor interface and experiment metadata.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32"
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn bytes(&self) -> usize {
        self.numel() * 4
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Raw metadata blob (kind, variant, r, beta, dims, chunk, ...).
    pub meta: Json,
}

impl ArtifactEntry {
    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.inputs
            .iter()
            .position(|s| s.name == name)
            .with_context(|| format!("artifact {} has no input {name}", self.name))
    }

    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.outputs
            .iter()
            .position(|s| s.name == name)
            .with_context(|| format!("artifact {} has no output {name}", self.name))
    }

    pub fn meta_usize(&self, key: &str) -> Result<usize> {
        self.meta.get("meta")?.get(key)?.as_usize()
    }

    pub fn meta_f64(&self, key: &str) -> Result<f64> {
        self.meta.get("meta")?.get(key)?.as_f64()
    }

    pub fn meta_str(&self, key: &str) -> Result<String> {
        Ok(self.meta.get("meta")?.get(key)?.as_str()?.to_string())
    }

    pub fn meta_dims(&self) -> Result<Vec<usize>> {
        let arr = self.meta.get("meta")?.get("dims")?.as_arr()?;
        arr.iter().map(|v| v.as_usize()).collect()
    }
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub n_b: usize,
    pub rank_ladder: Vec<usize>,
    pub artifacts: BTreeMap<String, ArtifactEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "cannot read {} — run `make artifacts` first",
                path.display()
            )
        })?;
        let root = Json::parse(&text).context("manifest.json parse error")?;
        let n_b = root.get("n_b")?.as_usize()?;
        let rank_ladder = root
            .get("rank_ladder")?
            .as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Result<Vec<_>>>()?;

        let mut artifacts = BTreeMap::new();
        for (name, entry) in root.get("artifacts")?.as_obj()? {
            let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
                entry
                    .get(key)?
                    .as_arr()?
                    .iter()
                    .map(|s| {
                        Ok(TensorSpec {
                            name: s.get("name")?.as_str()?.to_string(),
                            shape: s
                                .get("shape")?
                                .as_arr()?
                                .iter()
                                .map(|d| d.as_usize())
                                .collect::<Result<Vec<_>>>()?,
                            dtype: s.get("dtype")?.as_str()?.to_string(),
                        })
                    })
                    .collect()
            };
            artifacts.insert(
                name.clone(),
                ArtifactEntry {
                    name: name.clone(),
                    file: dir.join(entry.get("file")?.as_str()?),
                    inputs: parse_specs("inputs")?,
                    outputs: parse_specs("outputs")?,
                    meta: entry.clone(),
                },
            );
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            n_b,
            rank_ladder,
            artifacts,
        })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactEntry> {
        self.artifacts
            .get(name)
            .with_context(|| format!("manifest has no artifact {name:?}"))
    }

    /// Resolve the artifact name for a (family, variant, rank) request —
    /// the adaptive-rank controller's executable lookup.
    pub fn resolve(
        &self,
        family: &str,
        variant: &str,
        rank: Option<usize>,
    ) -> Result<&ArtifactEntry> {
        let name = match (variant, rank) {
            ("standard", _) => format!("{family}_std_chunk"),
            ("sketched", Some(r)) => format!("{family}_sk_r{r}_chunk"),
            ("monitored", Some(r)) => format!("{family}_mon_r{r}_chunk"),
            _ => anyhow::bail!("bad variant/rank: {variant}/{rank:?}"),
        };
        self.get(&name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_real_manifest() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.n_b, 128);
        assert_eq!(m.rank_ladder, vec![2, 4, 8, 16]);
        let e = m.get("mnist_std_step").unwrap();
        // 4 weight layers * 2 + adam m (8) + v (8) + t + x + y = 27 inputs
        assert_eq!(e.inputs.len(), 27);
        assert_eq!(e.inputs[0].name, "w0");
        assert_eq!(e.inputs[0].shape, vec![512, 784]);
        assert_eq!(e.meta_str("variant").unwrap(), "standard");
        assert!(e.file.exists());
    }

    #[test]
    fn resolve_names() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.resolve("mnist", "sketched", Some(4)).is_ok());
        assert!(m.resolve("mnist", "standard", None).is_ok());
        assert!(m.resolve("mnist", "sketched", Some(3)).is_err());
    }
}
