//! PJRT runtime: manifest-driven artifact loading, per-artifact executable
//! cache, and the `Tensor` currency between coordinator and XLA.

pub mod client;
pub mod manifest;
pub mod tensor;

pub use client::{Executable, Runtime};
pub use manifest::{ArtifactEntry, Manifest, TensorSpec};
pub use tensor::Tensor;
