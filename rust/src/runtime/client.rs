//! PJRT runtime: loads AOT HLO-text artifacts, compiles them once on the
//! CPU PJRT client and executes them from the coordinator hot path.
//!
//! Pattern follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! -> `XlaComputation::from_proto` -> `client.compile` -> `execute`.
//! Compiled executables are cached per artifact name — the adaptive-rank
//! controller swaps between per-rank variants without recompiling
//! (DESIGN.md §1, the vLLM-style executable cache).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{Context, Result};

use super::manifest::{ArtifactEntry, Manifest};
use super::tensor::Tensor;

/// One compiled artifact + its manifest interface.
pub struct Executable {
    pub entry: ArtifactEntry,
    exe: xla::PjRtLoadedExecutable,
    /// Cumulative execution statistics (perf pass instrumentation).
    pub calls: RefCell<ExecStats>,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct ExecStats {
    pub n_calls: u64,
    pub total_exec_us: u64,
    pub total_transfer_us: u64,
}

impl Executable {
    /// Execute with tensors ordered per `entry.inputs`; returns tensors
    /// ordered per `entry.outputs`.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        anyhow::ensure!(
            inputs.len() == self.entry.inputs.len(),
            "artifact {} expects {} inputs, got {}",
            self.entry.name,
            self.entry.inputs.len(),
            inputs.len()
        );
        let t0 = Instant::now();
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .enumerate()
            .map(|(i, t)| {
                debug_assert_eq!(
                    t.shape(),
                    &self.entry.inputs[i].shape[..],
                    "input {} ({}) shape mismatch",
                    i,
                    self.entry.inputs[i].name
                );
                t.to_literal()
            })
            .collect::<Result<_>>()?;
        let t1 = Instant::now();
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        let t2 = Instant::now();
        let outs = result.to_tuple()?;
        anyhow::ensure!(
            outs.len() == self.entry.outputs.len(),
            "artifact {} returned {} outputs, manifest says {}",
            self.entry.name,
            outs.len(),
            self.entry.outputs.len()
        );
        let tensors = outs
            .iter()
            .zip(&self.entry.outputs)
            .map(|(lit, spec)| Tensor::from_literal(lit, &spec.shape, &spec.dtype))
            .collect::<Result<Vec<_>>>()?;
        let t3 = Instant::now();
        let mut stats = self.calls.borrow_mut();
        stats.n_calls += 1;
        stats.total_exec_us += (t2 - t1).as_micros() as u64;
        stats.total_transfer_us +=
            ((t1 - t0) + (t3 - t2)).as_micros() as u64;
        Ok(tensors)
    }

    /// Run with a name->tensor map (order-independent convenience used by
    /// tests and examples; the trainer uses positional `run`).
    pub fn run_named(
        &self,
        inputs: &HashMap<String, Tensor>,
    ) -> Result<Vec<Tensor>> {
        let ordered = self
            .entry
            .inputs
            .iter()
            .map(|spec| {
                inputs
                    .get(&spec.name)
                    .cloned()
                    .with_context(|| format!("missing input {}", spec.name))
            })
            .collect::<Result<Vec<_>>>()?;
        self.run(&ordered)
    }
}

/// PJRT client + per-artifact executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
    pub compile_log: RefCell<Vec<(String, f64)>>,
}

impl Runtime {
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client =
            xla::PjRtClient::cpu().context("PJRT CPU client init failed")?;
        Ok(Runtime {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            compile_log: RefCell::new(Vec::new()),
        })
    }

    /// Fetch (compiling on first use) the executable for an artifact.
    pub fn load(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let entry = self.manifest.get(name)?.clone();
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            entry.file.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("loading HLO text {:?}", entry.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {name}"))?;
        let dt = t0.elapsed().as_secs_f64();
        self.compile_log.borrow_mut().push((name.to_string(), dt));
        let exe = Rc::new(Executable {
            entry,
            exe,
            calls: RefCell::new(ExecStats::default()),
        });
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile a set of artifacts (e.g. the whole rank ladder before
    /// an adaptive run so rank switches are instant).
    pub fn warm(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.load(n)?;
        }
        Ok(())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}
