//! Host tensor type + conversion to/from `xla::Literal`.
//!
//! The runtime dtype is f32 (plus i32 labels); shapes come from the
//! manifest.  `Tensor` is the only currency between the coordinator and
//! PJRT — the coordinator never touches `xla` types directly.

use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn zeros_f32(shape: &[usize]) -> Tensor {
        Tensor::F32 {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::F32 {
            shape: vec![],
            data: vec![v],
        }
    }

    pub fn from_f32(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} vs data len {}",
            data.len()
        );
        Tensor::F32 {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn from_i32(shape: &[usize], data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor::I32 {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tensor::F32 { data, .. } => data.len(),
            Tensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn bytes(&self) -> usize {
        self.len() * 4
    }

    pub fn f32_data(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn f32_data_mut(&mut self) -> Result<&mut Vec<f32>> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn i32_data(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    pub fn scalar(&self) -> Result<f32> {
        let d = self.f32_data()?;
        if d.len() != 1 {
            bail!("tensor has {} elements, expected scalar", d.len());
        }
        Ok(d[0])
    }

    /// Convert to an XLA literal.
    ///
    /// Perf (EXPERIMENTS.md §Perf L3): a single copy via
    /// `create_from_shape_and_untyped_data` — the obvious
    /// `vec1(..).reshape(..)` path copies twice (reshape allocates a second
    /// literal), which showed up as ~2x transfer overhead on the chunked
    /// train-step inputs (tens of MB per call for the 16-layer net).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        match self {
            Tensor::F32 { shape, data } => {
                if shape.is_empty() {
                    return Ok(xla::Literal::scalar(data[0]));
                }
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(
                        data.as_ptr() as *const u8,
                        data.len() * 4,
                    )
                };
                Ok(xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::F32,
                    shape,
                    bytes,
                )?)
            }
            Tensor::I32 { shape, data } => {
                if shape.is_empty() {
                    return Ok(xla::Literal::scalar(data[0]));
                }
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(
                        data.as_ptr() as *const u8,
                        data.len() * 4,
                    )
                };
                Ok(xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::S32,
                    shape,
                    bytes,
                )?)
            }
        }
    }

    /// Read back from an XLA literal given the manifest dtype/shape.
    pub fn from_literal(
        lit: &xla::Literal,
        shape: &[usize],
        dtype: &str,
    ) -> Result<Tensor> {
        match dtype {
            "f32" => Ok(Tensor::F32 {
                shape: shape.to_vec(),
                data: lit.to_vec::<f32>()?,
            }),
            "i32" => Ok(Tensor::I32 {
                shape: shape.to_vec(),
                data: lit.to_vec::<i32>()?,
            }),
            other => bail!("unsupported dtype {other}"),
        }
    }

    /// View an (n, m) f32 tensor row.
    pub fn row(&self, r: usize) -> Result<&[f32]> {
        let shape = self.shape();
        if shape.len() != 2 {
            bail!("row() needs rank-2, got {shape:?}");
        }
        let m = shape[1];
        Ok(&self.f32_data()?[r * m..(r + 1) * m])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_accounting() {
        let t = Tensor::zeros_f32(&[3, 4]);
        assert_eq!(t.len(), 12);
        assert_eq!(t.bytes(), 48);
        assert_eq!(t.shape(), &[3, 4]);
    }

    #[test]
    fn scalar_roundtrip() {
        let t = Tensor::scalar_f32(2.5);
        assert_eq!(t.scalar().unwrap(), 2.5);
        assert!(Tensor::zeros_f32(&[2]).scalar().is_err());
    }

    #[test]
    fn row_view() {
        let t = Tensor::from_f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.row(1).unwrap(), &[4., 5., 6.]);
    }

    #[test]
    fn dtype_mismatch_errors() {
        let t = Tensor::from_i32(&[2], vec![1, 2]);
        assert!(t.f32_data().is_err());
        assert!(t.i32_data().is_ok());
    }
}
