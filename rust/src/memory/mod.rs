//! Memory accountant (paper §4.7 / §5.4): exact byte models for every
//! storage regime the paper compares, plus a live tracker fed from actual
//! runtime state.  All figures' "memory" panels are generated from here.

use crate::baselines::checkpoint;
use crate::sketch::engine_state_bytes;

/// Byte model for one experiment configuration.
#[derive(Clone, Debug)]
pub struct MemoryModel {
    /// Weight-layer dims (d_0 .. d_L).
    pub dims: Vec<usize>,
    pub n_b: usize,
}

impl MemoryModel {
    pub fn new(dims: &[usize], n_b: usize) -> Self {
        MemoryModel {
            dims: dims.to_vec(),
            n_b,
        }
    }

    pub fn n_layers(&self) -> usize {
        self.dims.len() - 1
    }

    pub fn n_hidden(&self) -> usize {
        self.dims.len() - 2
    }

    pub fn d_hidden(&self) -> usize {
        self.dims[1]
    }

    /// Per-iteration activation storage under standard backprop:
    /// sum_l n_b * d_l * 4 over stored activations A^[0..L-1].
    pub fn standard_activations(&self) -> usize {
        self.dims[..self.dims.len() - 1]
            .iter()
            .map(|d| self.n_b * d * 4)
            .sum()
    }

    /// Per-iteration sketch state at rank r (replaces hidden-activation
    /// storage; input batch remains resident in both regimes).  Uniform
    /// paper formula — kept for the §4.7/§5.3 tables.
    pub fn sketch_state(&self, r: usize) -> usize {
        checkpoint::sketch_state_bytes(
            self.n_hidden(),
            self.d_hidden(),
            self.n_b,
            r,
        )
    }

    /// Heterogeneous-width engine accountant: the exact bytes a native
    /// `SketchEngine` over this architecture's hidden layers holds at
    /// rank r with this model's single batch size (delegates to
    /// [`engine_state_bytes`], incl. Psi at its stored f64 width).  Use
    /// `sketch_state` when modelling the AOT path, whose psi tensors are
    /// f32.
    pub fn engine_state(&self, r: usize) -> usize {
        engine_state_bytes(self.hidden_dims(), r, &[self.n_b], 4)
    }

    /// The hidden-layer widths d_1..d_H (heterogeneous allowed).
    pub fn hidden_dims(&self) -> &[usize] {
        &self.dims[1..self.dims.len() - 1]
    }

    /// Per-iteration reduction fraction at rank r (hidden activations ->
    /// sketches; the input batch is excluded from both sides).
    pub fn per_iteration_reduction(&self, r: usize) -> f64 {
        let hidden_acts: usize = self.dims[1..self.dims.len() - 1]
            .iter()
            .map(|d| self.n_b * d * 4)
            .sum();
        1.0 - self.sketch_state(r) as f64 / hidden_acts as f64
    }

    /// Traditional monitoring bytes over window T (paper §5.3):
    /// full gradient matrices per checkpoint.
    pub fn monitoring_traditional(&self, t_window: usize) -> usize {
        crate::baselines::full_monitor::FullMonitor::bytes_for_arch(
            &self.dims, t_window,
        )
    }

    /// Sketch-based monitoring bytes — independent of T.
    pub fn monitoring_sketched(&self, r: usize) -> usize {
        self.sketch_state(r)
    }

    /// Monitoring reduction at window T, rank r (the 99% headline).
    pub fn monitoring_reduction(&self, t_window: usize, r: usize) -> f64 {
        1.0 - self.monitoring_sketched(r) as f64
            / self.monitoring_traditional(t_window) as f64
    }

    /// Parameter bytes (weights + biases), for peak-memory context.
    pub fn param_bytes(&self) -> usize {
        self.dims
            .windows(2)
            .map(|w| (w[0] * w[1] + w[1]) * 4)
            .sum()
    }
}

/// Live peak-memory tracker fed by the coordinator (actual tensor bytes).
#[derive(Debug, Default)]
pub struct PeakTracker {
    pub current: usize,
    pub peak: usize,
    pub samples: Vec<(String, usize)>,
}

impl PeakTracker {
    pub fn record(&mut self, label: &str, bytes: usize) {
        self.current = bytes;
        if bytes > self.peak {
            self.peak = bytes;
        }
        if self.samples.len() < 4096 {
            self.samples.push((label.to_string(), bytes));
        }
    }
}

pub fn fmt_bytes(b: usize) -> String {
    let b = b as f64;
    if b >= 1024.0 * 1024.0 * 1024.0 {
        format!("{:.2} GB", b / (1024.0 * 1024.0 * 1024.0))
    } else if b >= 1024.0 * 1024.0 {
        format!("{:.2} MB", b / (1024.0 * 1024.0))
    } else if b >= 1024.0 {
        format!("{:.1} KB", b / 1024.0)
    } else {
        format!("{b} B")
    }
}

/// The paper's monitor architecture (16 weight layers, 1024 hidden).
pub fn monitor16_dims() -> Vec<usize> {
    std::iter::once(784)
        .chain(std::iter::repeat(1024).take(15))
        .chain(std::iter::once(10))
        .collect()
}

pub fn mnist_dims() -> Vec<usize> {
    vec![784, 512, 512, 512, 10]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_headline_99_percent() {
        // §5.3: 16x1024, T=5: 320 MB -> ~1.7 MB, >= 99% reduction at r=4.
        let m = MemoryModel::new(&monitor16_dims(), 128);
        let trad = m.monitoring_traditional(5);
        let sk = m.monitoring_sketched(4);
        let trad_mb = trad as f64 / (1024.0 * 1024.0);
        let sk_mb = sk as f64 / (1024.0 * 1024.0);
        assert!(
            (250.0..400.0).contains(&trad_mb),
            "traditional {trad_mb:.1} MB"
        );
        assert!((1.0..3.0).contains(&sk_mb), "sketched {sk_mb:.2} MB");
        assert!(m.monitoring_reduction(5, 4) > 0.99);
    }

    #[test]
    fn reduction_grows_with_window() {
        let m = MemoryModel::new(&monitor16_dims(), 128);
        let r5 = m.monitoring_reduction(5, 4);
        let r100 = m.monitoring_reduction(100, 4);
        assert!(r100 > r5);
    }

    #[test]
    fn per_iteration_band_matches_paper() {
        let m = MemoryModel::new(&mnist_dims(), 128);
        let red2 = m.per_iteration_reduction(2);
        let red16 = m.per_iteration_reduction(16);
        assert!(red2 > red16, "more rank -> less reduction");
        assert!(red2 > 0.8, "r=2 reduction {red2}");
        assert!(red16 > 0.1, "r=16 reduction {red16}");
    }

    #[test]
    fn engine_accountant_matches_uniform_formula_up_to_psi_width() {
        // engine_state counts Psi at its stored 8 B; the legacy uniform
        // formula charged 4 B.  Everything else must agree exactly.
        let m = MemoryModel::new(&mnist_dims(), 128);
        for r in [2usize, 4, 8] {
            let k = 2 * r + 1;
            let psi_delta = m.n_hidden() * k * 4;
            assert_eq!(m.engine_state(r), m.sketch_state(r) + psi_delta);
        }
        assert_eq!(m.hidden_dims(), &[512, 512, 512]);
    }

    #[test]
    fn peak_tracker() {
        let mut t = PeakTracker::default();
        t.record("a", 100);
        t.record("b", 300);
        t.record("c", 50);
        assert_eq!(t.peak, 300);
        assert_eq!(t.current, 50);
    }

    #[test]
    fn fmt_units() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert!(fmt_bytes(2048).contains("KB"));
        assert!(fmt_bytes(3 * 1024 * 1024).contains("MB"));
    }
}
