//! Experiment configuration: typed configs loadable from TOML files or CLI
//! overrides.  Every figure binary and example resolves its parameters
//! through here so runs are reproducible from a single file.

use std::path::Path;

use anyhow::{bail, Result};

use crate::coordinator::AdaptiveConfig;
use crate::sketch::SketchConfigBuilder;
use crate::util::toml::Toml;

/// Resolve a thread-count knob: `0` means "auto" and maps to the host's
/// available parallelism (never a zero-lane pool); any other value is
/// taken literally (1 = serial).  Both the TOML `threads = 0` and the CLI
/// `--threads 0` spellings route through here.  The resolved count sizes
/// a *persistent* `sketch::kernel::Pool` (`n - 1` parked workers plus
/// the calling thread), created once per engine/hub — or once per
/// process by `sketchd` — and reused for every kernel call.
pub fn resolve_threads(n: usize) -> usize {
    if n == 0 {
        std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
    } else {
        n
    }
}

#[derive(Clone, Debug, PartialEq)]
pub enum Variant {
    Standard,
    Sketched,
    Monitored,
}

impl Variant {
    pub fn parse(s: &str) -> Result<Variant> {
        Ok(match s {
            "standard" => Variant::Standard,
            "sketched" => Variant::Sketched,
            "monitored" => Variant::Monitored,
            other => bail!("unknown variant {other:?}"),
        })
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Variant::Standard => "standard",
            Variant::Sketched => "sketched",
            Variant::Monitored => "monitored",
        }
    }
}

/// One training experiment (a figure panel's single curve).
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub name: String,
    /// Artifact family prefix: mnist | cifar | monitor16 | pinn.
    pub family: String,
    pub variant: Variant,
    pub rank: usize,
    /// EMA decay for the sketch triplets (paper §4.1).
    pub beta: f64,
    /// Persistent kernel worker-pool width for the native sketch
    /// substrate (1 = serial; `0` in TOML/CLI input is resolved to the
    /// host's available parallelism by [`resolve_threads`] before it
    /// lands here).  Numerics are identical at any setting.
    pub threads: usize,
    pub adaptive: bool,
    pub adaptive_cfg: AdaptiveConfig,
    pub epochs: usize,
    pub train_size: usize,
    pub test_size: usize,
    pub seed: u64,
    pub artifacts_dir: String,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: "mnist".into(),
            family: "mnist".into(),
            variant: Variant::Standard,
            rank: 2,
            beta: 0.9,
            threads: 1,
            adaptive: false,
            adaptive_cfg: AdaptiveConfig::default(),
            epochs: 5,
            train_size: 128 * 100,
            test_size: 128 * 10,
            seed: 42,
            artifacts_dir: "artifacts".into(),
        }
    }
}

impl ExperimentConfig {
    pub fn from_toml_file(path: &Path) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml(&Toml::parse(&text)?)
    }

    pub fn from_toml(t: &Toml) -> Result<ExperimentConfig> {
        let d = ExperimentConfig::default();
        let adaptive_cfg = AdaptiveConfig {
            r0: t.usize_or("adaptive.r0", 2)?,
            p_decrease: t.usize_or("adaptive.p_decrease", 3)?,
            p_increase: t.usize_or("adaptive.p_increase", 2)?,
            dr_down: t.usize_or("adaptive.dr_down", 2)?,
            dr_up: t.usize_or("adaptive.dr_up", 4)?,
            tau_reset: t.usize_or("adaptive.tau_reset", 16)?,
            ladder: vec![2, 4, 8, 16],
            min_rel_improvement: t.f64_or("adaptive.min_rel_improvement", 1e-3)?,
        };
        Ok(ExperimentConfig {
            name: t.str_or("experiment.name", &d.name)?,
            family: t.str_or("experiment.family", &d.family)?,
            variant: Variant::parse(&t.str_or(
                "experiment.variant",
                d.variant.as_str(),
            )?)?,
            rank: t.usize_or("sketch.rank", d.rank)?,
            beta: t.f64_or("sketch.beta", d.beta)?,
            threads: resolve_threads(t.usize_or("sketch.threads", d.threads)?),
            adaptive: t.bool_or("sketch.adaptive", d.adaptive)?,
            adaptive_cfg,
            epochs: t.usize_or("experiment.epochs", d.epochs)?,
            train_size: t.usize_or("experiment.train_size", d.train_size)?,
            test_size: t.usize_or("experiment.test_size", d.test_size)?,
            seed: t.usize_or("experiment.seed", d.seed as usize)? as u64,
            artifacts_dir: t
                .str_or("experiment.artifacts_dir", &d.artifacts_dir)?,
        })
    }

    /// The artifact name this config starts on.
    pub fn artifact_name(&self) -> String {
        match self.variant {
            Variant::Standard => format!("{}_std_chunk", self.family),
            Variant::Sketched => {
                format!("{}_sk_r{}_chunk", self.family, self.rank)
            }
            Variant::Monitored => {
                format!("{}_mon_r{}_chunk", self.family, self.rank)
            }
        }
    }

    /// Seed a `SketchConfigBuilder` from this experiment (rank, beta,
    /// seed, worker pool); the caller supplies the architecture's hidden
    /// widths.
    pub fn sketch_builder(&self, layer_dims: &[usize]) -> SketchConfigBuilder {
        SketchConfigBuilder::default()
            .layer_dims(layer_dims)
            .rank(self.rank)
            .beta(self.beta)
            .seed(self.seed)
            .threads(self.threads)
    }

    pub fn validate(&self) -> Result<()> {
        if self.epochs == 0 {
            bail!("epochs must be > 0");
        }
        if !(0.0..1.0).contains(&self.beta) {
            bail!("beta {} outside [0, 1)", self.beta);
        }
        if self.variant != Variant::Standard
            && !self.adaptive_cfg.ladder.contains(&self.rank)
        {
            bail!(
                "rank {} not in compiled ladder {:?}",
                self.rank,
                self.adaptive_cfg.ladder
            );
        }
        Ok(())
    }
}

/// Configuration for the per-session sketch archive (`rust/src/archive`),
/// loadable from an `[archive]` TOML section with CLI overrides
/// (`--archive-capacity` / `--archive-stride`).
#[derive(Clone, Debug, PartialEq)]
pub struct ArchiveConfig {
    /// Retained interval snapshots per session (ring capacity; 0
    /// disables archiving entirely).
    pub capacity: usize,
    /// Sample every N-th ingest interval (>= 1; 1 = every interval).
    pub stride: usize,
}

impl Default for ArchiveConfig {
    fn default() -> Self {
        ArchiveConfig {
            capacity: 64,
            stride: 1,
        }
    }
}

/// Configuration for the daemon's observability layer
/// (`rust/src/serve/obs`), loadable from an `[obs]` TOML section with
/// CLI overrides (`--obs-addr` / `--obs-window-ms` / ...).
#[derive(Clone, Debug, PartialEq)]
pub struct ObsConfig {
    /// HTTP exposition listen address (`GET /metrics`, `GET /events`);
    /// empty = endpoint disabled.  Port 0 binds an ephemeral port.
    pub addr: String,
    /// Width of each time-series window bucket in milliseconds.
    pub window_ms: u64,
    /// Closed window buckets retained in the ring.
    pub window_count: usize,
    /// Event-journal capacity per writer (control plane + one per
    /// shard); older events are overwritten and counted as dropped.
    pub journal_capacity: usize,
    /// Requests taking at least this long are journaled as
    /// `slow-request` events (0 journals every request).
    pub slow_ms: u64,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig {
            addr: String::new(),
            window_ms: 1000,
            window_count: 120,
            journal_capacity: 4096,
            slow_ms: 250,
        }
    }
}

/// Configuration for the `sketchd` monitoring daemon (`rust/src/serve`),
/// loadable from a `[serve]` TOML section with CLI overrides.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeConfig {
    /// Listen address; port 0 binds an ephemeral port (tests/benches).
    pub addr: String,
    /// Admission cap: `OpenSession` beyond this replies `Busy`.
    pub max_sessions: usize,
    /// Seconds between periodic durable snapshots (0 = snapshot only on
    /// client request and at shutdown).
    pub snapshot_interval_secs: u64,
    /// Per-session backpressure quota: ingest payload bytes a tenant may
    /// stream between `Diagnose` calls before the daemon replies `Busy`
    /// (0 = unlimited).  See DESIGN.md §5 backpressure rules.
    pub session_quota_bytes: usize,
    /// Durable snapshot file (written atomically via rename).
    pub snapshot_path: String,
    /// Width of each shard's worker pool, shared by every tenant engine
    /// and hub registered on that shard (0 = auto).
    pub threads: usize,
    /// Connection shards: independent event-loop threads, each owning a
    /// slice of sessions (`session_id % shards`), its own kernel pool
    /// and its own metrics (0 = auto from available parallelism).  See
    /// DESIGN.md §9.
    pub shards: usize,
    /// Per-session sketch-history retention (`[archive]` section).
    pub archive: ArchiveConfig,
    /// Observability layer: event journal, window ring, exposition
    /// endpoint (`[obs]` section).
    pub obs: ObsConfig,
    /// Failpoint spec armed at bind (DESIGN.md §11), e.g.
    /// `"conn.write=err@every:200;handler=panic@oneshot"`.  Empty =
    /// nothing armed (zero-cost checks).  `SKETCHD_FAULT` entries are
    /// merged on top at bind.
    pub fault: String,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7070".into(),
            max_sessions: 16,
            snapshot_interval_secs: 30,
            session_quota_bytes: 64 << 20,
            snapshot_path: "sketchd.snapshot".into(),
            threads: 1,
            shards: 1,
            archive: ArchiveConfig::default(),
            obs: ObsConfig::default(),
            fault: String::new(),
        }
    }
}

impl ServeConfig {
    pub fn from_toml_file(path: &Path) -> Result<ServeConfig> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml(&Toml::parse(&text)?)
    }

    pub fn from_toml(t: &Toml) -> Result<ServeConfig> {
        let d = ServeConfig::default();
        Ok(ServeConfig {
            addr: t.str_or("serve.addr", &d.addr)?,
            max_sessions: t.usize_or("serve.max_sessions", d.max_sessions)?,
            snapshot_interval_secs: t.usize_or(
                "serve.snapshot_interval_secs",
                d.snapshot_interval_secs as usize,
            )? as u64,
            session_quota_bytes: t.usize_or(
                "serve.session_quota_bytes",
                d.session_quota_bytes,
            )?,
            snapshot_path: t.str_or("serve.snapshot_path", &d.snapshot_path)?,
            threads: resolve_threads(t.usize_or("serve.threads", d.threads)?),
            shards: resolve_threads(t.usize_or("serve.shards", d.shards)?),
            archive: ArchiveConfig {
                capacity: t.usize_or("archive.capacity", d.archive.capacity)?,
                stride: t.usize_or("archive.stride", d.archive.stride)?,
            },
            obs: ObsConfig {
                addr: t.str_or("obs.addr", &d.obs.addr)?,
                window_ms: t
                    .usize_or("obs.window_ms", d.obs.window_ms as usize)?
                    as u64,
                window_count: t
                    .usize_or("obs.window_count", d.obs.window_count)?,
                journal_capacity: t.usize_or(
                    "obs.journal_capacity",
                    d.obs.journal_capacity,
                )?,
                slow_ms: t.usize_or("obs.slow_ms", d.obs.slow_ms as usize)?
                    as u64,
            },
            fault: t.str_or("serve.fault", &d.fault)?,
        })
    }

    pub fn validate(&self) -> Result<()> {
        if self.addr.is_empty() {
            bail!("serve.addr must not be empty");
        }
        if self.max_sessions == 0 {
            bail!("serve.max_sessions must be > 0");
        }
        if self.snapshot_path.is_empty() {
            bail!("serve.snapshot_path must not be empty");
        }
        if self.shards == 0 {
            bail!(
                "serve.shards must be > 0 (0 is only valid in TOML, \
                 where it resolves to available parallelism)"
            );
        }
        if self.archive.stride == 0 {
            bail!("archive.stride must be >= 1");
        }
        if self.obs.window_ms == 0 {
            bail!("obs.window_ms must be >= 1");
        }
        if self.obs.window_count == 0 {
            bail!("obs.window_count must be >= 1");
        }
        if self.obs.journal_capacity == 0 {
            bail!("obs.journal_capacity must be >= 1");
        }
        if !self.fault.is_empty() {
            // Parse onto a throwaway registry so a typoed failpoint
            // spec fails at config load, not silently at bind.
            if let Err(e) =
                crate::serve::fault::FaultRegistry::new().arm(&self.fault)
            {
                bail!("serve.fault: {e}");
            }
        }
        Ok(())
    }
}

/// Network robustness knobs for [`crate::serve::SketchClient`],
/// loadable from a `[client]` TOML section with CLI overrides
/// (`--timeout-ms` / `--retries` on `connect` and `loadgen`).
///
/// All durations are milliseconds; `0` means "no deadline" (OS-default
/// connect behaviour / block forever on reads).  Connect attempts retry
/// up to `connect_retries` extra times with a doubling backoff starting
/// at `retry_backoff_ms` and capped at one second — covering both a
/// daemon that is still binding (CI spawn races) and transient refusals
/// under churn.
#[derive(Clone, Debug, PartialEq)]
pub struct ClientConfig {
    /// TCP connect deadline per attempt (ms; 0 = OS default).
    pub connect_timeout_ms: u64,
    /// Socket read/write deadline per frame (ms; 0 = block forever).
    pub io_timeout_ms: u64,
    /// Extra connect attempts after the first failure.
    pub connect_retries: u32,
    /// First inter-attempt sleep; doubles per retry, capped at 1000ms.
    pub retry_backoff_ms: u64,
}

impl Default for ClientConfig {
    fn default() -> Self {
        ClientConfig {
            connect_timeout_ms: 2000,
            io_timeout_ms: 30_000,
            connect_retries: 8,
            retry_backoff_ms: 50,
        }
    }
}

impl ClientConfig {
    pub fn from_toml(t: &Toml) -> Result<ClientConfig> {
        let d = ClientConfig::default();
        Ok(ClientConfig {
            connect_timeout_ms: t.usize_or(
                "client.connect_timeout_ms",
                d.connect_timeout_ms as usize,
            )? as u64,
            io_timeout_ms: t
                .usize_or("client.io_timeout_ms", d.io_timeout_ms as usize)?
                as u64,
            connect_retries: t.usize_or(
                "client.connect_retries",
                d.connect_retries as usize,
            )? as u32,
            retry_backoff_ms: t.usize_or(
                "client.retry_backoff_ms",
                d.retry_backoff_ms as usize,
            )? as u64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_and_artifact_names() {
        let mut c = ExperimentConfig::default();
        assert_eq!(c.artifact_name(), "mnist_std_chunk");
        c.variant = Variant::Sketched;
        c.rank = 4;
        assert_eq!(c.artifact_name(), "mnist_sk_r4_chunk");
        c.family = "monitor16".into();
        c.variant = Variant::Monitored;
        assert_eq!(c.artifact_name(), "monitor16_mon_r4_chunk");
    }

    #[test]
    fn toml_roundtrip() {
        let t = Toml::parse(
            r#"
[experiment]
name = "fig1"
family = "mnist"
variant = "sketched"
epochs = 50
[sketch]
rank = 2
threads = 4
adaptive = true
[adaptive]
p_decrease = 4
"#,
        )
        .unwrap();
        let c = ExperimentConfig::from_toml(&t).unwrap();
        assert_eq!(c.name, "fig1");
        assert_eq!(c.beta, 0.9);
        let sk = c.sketch_builder(&[128, 64]).build().unwrap();
        assert_eq!(sk.rank, c.rank);
        assert_eq!(sk.layer_dims, vec![128, 64]);
        assert_eq!(c.threads, 4);
        assert_eq!(
            sk.parallelism,
            crate::sketch::Parallelism::Threads(4)
        );
        assert_eq!(c.variant, Variant::Sketched);
        assert_eq!(c.epochs, 50);
        assert!(c.adaptive);
        assert_eq!(c.adaptive_cfg.p_decrease, 4);
        c.validate().unwrap();
    }

    #[test]
    fn validation_rejects_off_ladder_rank() {
        let mut c = ExperimentConfig::default();
        c.variant = Variant::Sketched;
        c.rank = 3;
        assert!(c.validate().is_err());
    }

    #[test]
    fn threads_zero_resolves_to_available_parallelism() {
        let avail = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1);
        assert_eq!(resolve_threads(0), avail);
        assert_eq!(resolve_threads(1), 1);
        assert_eq!(resolve_threads(4), 4);

        // TOML path: `threads = 0` must never reach the engine as a
        // zero-worker pool.
        let t = Toml::parse("[sketch]\nthreads = 0\n").unwrap();
        let c = ExperimentConfig::from_toml(&t).unwrap();
        assert_eq!(c.threads, avail);
        assert!(c.threads >= 1);
        let sk = c.sketch_builder(&[16]).build().unwrap();
        assert!(sk.parallelism.threads() >= 1);

        // CLI path: `--threads 0` goes through the same resolver.
        let mut args = crate::util::cli::Args::parse(
            ["--threads", "0"].iter().map(|s| s.to_string()),
        )
        .unwrap();
        let cli = resolve_threads(args.opt_usize("threads", 1).unwrap());
        assert_eq!(cli, avail);
    }

    #[test]
    fn serve_config_from_toml_and_validation() {
        let d = ServeConfig::default();
        assert!(d.validate().is_ok());

        let t = Toml::parse(
            r#"
[serve]
addr = "0.0.0.0:9000"
max_sessions = 4
snapshot_interval_secs = 5
session_quota_bytes = 1024
snapshot_path = "/tmp/snap.bin"
threads = 2
shards = 3
fault = "handler=panic@oneshot"
[archive]
capacity = 12
stride = 3
[obs]
addr = "127.0.0.1:0"
window_ms = 250
window_count = 8
journal_capacity = 32
slow_ms = 10
"#,
        )
        .unwrap();
        let c = ServeConfig::from_toml(&t).unwrap();
        assert_eq!(c.addr, "0.0.0.0:9000");
        assert_eq!(c.max_sessions, 4);
        assert_eq!(c.snapshot_interval_secs, 5);
        assert_eq!(c.session_quota_bytes, 1024);
        assert_eq!(c.snapshot_path, "/tmp/snap.bin");
        assert_eq!(c.threads, 2);
        assert_eq!(c.shards, 3);
        assert_eq!(c.fault, "handler=panic@oneshot");
        assert_eq!(c.archive, ArchiveConfig { capacity: 12, stride: 3 });
        assert_eq!(
            c.obs,
            ObsConfig {
                addr: "127.0.0.1:0".into(),
                window_ms: 250,
                window_count: 8,
                journal_capacity: 32,
                slow_ms: 10,
            }
        );
        c.validate().unwrap();

        // shards = 0 in TOML resolves to available parallelism ...
        let auto = Toml::parse("[serve]\nshards = 0\n").unwrap();
        assert!(ServeConfig::from_toml(&auto).unwrap().shards >= 1);

        // Missing sections fall back to defaults entirely.
        let empty = Toml::parse("").unwrap();
        assert_eq!(ServeConfig::from_toml(&empty).unwrap(), d);
        assert_eq!(d.archive, ArchiveConfig { capacity: 64, stride: 1 });

        let mut bad = d.clone();
        bad.max_sessions = 0;
        assert!(bad.validate().is_err());
        bad = d.clone();
        bad.addr.clear();
        assert!(bad.validate().is_err());
        // ... but a literal shards = 0 never survives validation.
        bad = d.clone();
        bad.shards = 0;
        assert!(bad.validate().is_err());
        bad = d.clone();
        bad.archive.stride = 0;
        assert!(bad.validate().is_err());
        // Obs defaults: endpoint disabled, knobs validated when set.
        assert_eq!(d.obs, ObsConfig::default());
        assert!(d.obs.addr.is_empty());
        bad = d.clone();
        bad.obs.window_ms = 0;
        assert!(bad.validate().is_err());
        bad = d.clone();
        bad.obs.window_count = 0;
        assert!(bad.validate().is_err());
        bad = d.clone();
        bad.obs.journal_capacity = 0;
        assert!(bad.validate().is_err());
        // Fault specs are validated at config load.
        bad = d;
        bad.fault = "handler=frobnicate".into();
        assert!(bad.validate().is_err());
    }

    #[test]
    fn client_config_from_toml() {
        let d = ClientConfig::default();
        assert_eq!(d.connect_timeout_ms, 2000);
        assert_eq!(d.io_timeout_ms, 30_000);
        assert_eq!(d.connect_retries, 8);
        assert_eq!(d.retry_backoff_ms, 50);

        let t = Toml::parse(
            r#"
[client]
connect_timeout_ms = 500
io_timeout_ms = 0
connect_retries = 2
retry_backoff_ms = 10
"#,
        )
        .unwrap();
        let c = ClientConfig::from_toml(&t).unwrap();
        assert_eq!(
            c,
            ClientConfig {
                connect_timeout_ms: 500,
                io_timeout_ms: 0,
                connect_retries: 2,
                retry_backoff_ms: 10,
            }
        );

        // Missing section falls back to defaults entirely.
        let empty = Toml::parse("").unwrap();
        assert_eq!(ClientConfig::from_toml(&empty).unwrap(), d);
    }
}
