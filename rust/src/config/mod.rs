//! Experiment configuration: typed configs loadable from TOML files or CLI
//! overrides.  Every figure binary and example resolves its parameters
//! through here so runs are reproducible from a single file.

use std::path::Path;

use anyhow::{bail, Result};

use crate::coordinator::AdaptiveConfig;
use crate::sketch::SketchConfigBuilder;
use crate::util::toml::Toml;

#[derive(Clone, Debug, PartialEq)]
pub enum Variant {
    Standard,
    Sketched,
    Monitored,
}

impl Variant {
    pub fn parse(s: &str) -> Result<Variant> {
        Ok(match s {
            "standard" => Variant::Standard,
            "sketched" => Variant::Sketched,
            "monitored" => Variant::Monitored,
            other => bail!("unknown variant {other:?}"),
        })
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Variant::Standard => "standard",
            Variant::Sketched => "sketched",
            Variant::Monitored => "monitored",
        }
    }
}

/// One training experiment (a figure panel's single curve).
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub name: String,
    /// Artifact family prefix: mnist | cifar | monitor16 | pinn.
    pub family: String,
    pub variant: Variant,
    pub rank: usize,
    /// EMA decay for the sketch triplets (paper §4.1).
    pub beta: f64,
    /// Kernel worker-pool width for the native sketch substrate (0/1 =
    /// serial).  Numerics are identical at any setting.
    pub threads: usize,
    pub adaptive: bool,
    pub adaptive_cfg: AdaptiveConfig,
    pub epochs: usize,
    pub train_size: usize,
    pub test_size: usize,
    pub seed: u64,
    pub artifacts_dir: String,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: "mnist".into(),
            family: "mnist".into(),
            variant: Variant::Standard,
            rank: 2,
            beta: 0.9,
            threads: 1,
            adaptive: false,
            adaptive_cfg: AdaptiveConfig::default(),
            epochs: 5,
            train_size: 128 * 100,
            test_size: 128 * 10,
            seed: 42,
            artifacts_dir: "artifacts".into(),
        }
    }
}

impl ExperimentConfig {
    pub fn from_toml_file(path: &Path) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml(&Toml::parse(&text)?)
    }

    pub fn from_toml(t: &Toml) -> Result<ExperimentConfig> {
        let d = ExperimentConfig::default();
        let adaptive_cfg = AdaptiveConfig {
            r0: t.usize_or("adaptive.r0", 2)?,
            p_decrease: t.usize_or("adaptive.p_decrease", 3)?,
            p_increase: t.usize_or("adaptive.p_increase", 2)?,
            dr_down: t.usize_or("adaptive.dr_down", 2)?,
            dr_up: t.usize_or("adaptive.dr_up", 4)?,
            tau_reset: t.usize_or("adaptive.tau_reset", 16)?,
            ladder: vec![2, 4, 8, 16],
            min_rel_improvement: t.f64_or("adaptive.min_rel_improvement", 1e-3)?,
        };
        Ok(ExperimentConfig {
            name: t.str_or("experiment.name", &d.name)?,
            family: t.str_or("experiment.family", &d.family)?,
            variant: Variant::parse(&t.str_or(
                "experiment.variant",
                d.variant.as_str(),
            )?)?,
            rank: t.usize_or("sketch.rank", d.rank)?,
            beta: t.f64_or("sketch.beta", d.beta)?,
            threads: t.usize_or("sketch.threads", d.threads)?,
            adaptive: t.bool_or("sketch.adaptive", d.adaptive)?,
            adaptive_cfg,
            epochs: t.usize_or("experiment.epochs", d.epochs)?,
            train_size: t.usize_or("experiment.train_size", d.train_size)?,
            test_size: t.usize_or("experiment.test_size", d.test_size)?,
            seed: t.usize_or("experiment.seed", d.seed as usize)? as u64,
            artifacts_dir: t
                .str_or("experiment.artifacts_dir", &d.artifacts_dir)?,
        })
    }

    /// The artifact name this config starts on.
    pub fn artifact_name(&self) -> String {
        match self.variant {
            Variant::Standard => format!("{}_std_chunk", self.family),
            Variant::Sketched => {
                format!("{}_sk_r{}_chunk", self.family, self.rank)
            }
            Variant::Monitored => {
                format!("{}_mon_r{}_chunk", self.family, self.rank)
            }
        }
    }

    /// Seed a `SketchConfigBuilder` from this experiment (rank, beta,
    /// seed, worker pool); the caller supplies the architecture's hidden
    /// widths.
    pub fn sketch_builder(&self, layer_dims: &[usize]) -> SketchConfigBuilder {
        SketchConfigBuilder::default()
            .layer_dims(layer_dims)
            .rank(self.rank)
            .beta(self.beta)
            .seed(self.seed)
            .threads(self.threads)
    }

    pub fn validate(&self) -> Result<()> {
        if self.epochs == 0 {
            bail!("epochs must be > 0");
        }
        if !(0.0..1.0).contains(&self.beta) {
            bail!("beta {} outside [0, 1)", self.beta);
        }
        if self.variant != Variant::Standard
            && !self.adaptive_cfg.ladder.contains(&self.rank)
        {
            bail!(
                "rank {} not in compiled ladder {:?}",
                self.rank,
                self.adaptive_cfg.ladder
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_and_artifact_names() {
        let mut c = ExperimentConfig::default();
        assert_eq!(c.artifact_name(), "mnist_std_chunk");
        c.variant = Variant::Sketched;
        c.rank = 4;
        assert_eq!(c.artifact_name(), "mnist_sk_r4_chunk");
        c.family = "monitor16".into();
        c.variant = Variant::Monitored;
        assert_eq!(c.artifact_name(), "monitor16_mon_r4_chunk");
    }

    #[test]
    fn toml_roundtrip() {
        let t = Toml::parse(
            r#"
[experiment]
name = "fig1"
family = "mnist"
variant = "sketched"
epochs = 50
[sketch]
rank = 2
threads = 4
adaptive = true
[adaptive]
p_decrease = 4
"#,
        )
        .unwrap();
        let c = ExperimentConfig::from_toml(&t).unwrap();
        assert_eq!(c.name, "fig1");
        assert_eq!(c.beta, 0.9);
        let sk = c.sketch_builder(&[128, 64]).build().unwrap();
        assert_eq!(sk.rank, c.rank);
        assert_eq!(sk.layer_dims, vec![128, 64]);
        assert_eq!(c.threads, 4);
        assert_eq!(
            sk.parallelism,
            crate::sketch::Parallelism::Threads(4)
        );
        assert_eq!(c.variant, Variant::Sketched);
        assert_eq!(c.epochs, 50);
        assert!(c.adaptive);
        assert_eq!(c.adaptive_cfg.p_decrease, 4);
        c.validate().unwrap();
    }

    #[test]
    fn validation_rejects_off_ladder_rank() {
        let mut c = ExperimentConfig::default();
        c.variant = Variant::Sketched;
        c.rank = 3;
        assert!(c.validate().is_err());
    }
}
