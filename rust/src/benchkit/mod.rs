//! Mini-criterion (criterion is unavailable offline): warmup + timed
//! iterations with mean/p50/p95 and throughput, plus markdown table output
//! shared by all `cargo bench` targets.

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
    /// Optional user-supplied throughput unit (e.g. steps/s).
    pub throughput: Option<(f64, &'static str)>,
}

impl BenchResult {
    pub fn row(&self) -> String {
        let tp = match self.throughput {
            Some((v, unit)) => format!("{v:.1} {unit}"),
            None => "-".into(),
        };
        format!(
            "| {} | {} | {} | {} | {} | {} |",
            self.name,
            fmt_dur(self.mean),
            fmt_dur(self.p50),
            fmt_dur(self.p95),
            self.iters,
            tp
        )
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let us = d.as_secs_f64() * 1e6;
    if us < 1000.0 {
        format!("{us:.1} µs")
    } else if us < 1_000_000.0 {
        format!("{:.2} ms", us / 1000.0)
    } else {
        format!("{:.3} s", us / 1e6)
    }
}

pub struct Bench {
    pub warmup: usize,
    pub iters: usize,
    pub results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: 3,
            iters: 20,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new(warmup: usize, iters: usize) -> Self {
        Bench {
            warmup,
            iters,
            results: Vec::new(),
        }
    }

    /// Time `f` over the configured iterations.  `work` gives an optional
    /// per-iteration work amount for throughput (e.g. steps per call).
    pub fn run<F: FnMut()>(
        &mut self,
        name: &str,
        work: Option<(f64, &'static str)>,
        mut f: F,
    ) -> &BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut times = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed());
        }
        times.sort();
        let mean = times.iter().sum::<Duration>() / times.len() as u32;
        let p50 = times[times.len() / 2];
        let p95 = times[(times.len() * 95 / 100).min(times.len() - 1)];
        let min = times[0];
        let throughput = work.map(|(w, unit)| (w / mean.as_secs_f64(), unit));
        self.results.push(BenchResult {
            name: name.to_string(),
            iters: self.iters,
            mean,
            p50,
            p95,
            min,
            throughput,
        });
        self.results.last().unwrap()
    }

    /// Print the accumulated results as a markdown table.
    pub fn report(&self, title: &str) {
        println!("\n## {title}\n");
        println!("| bench | mean | p50 | p95 | iters | throughput |");
        println!("|---|---|---|---|---|---|");
        for r in &self.results {
            println!("{}", r.row());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_sane() {
        let mut b = Bench::new(1, 5);
        let r = b.run("sleep", Some((100.0, "ops/s")), || {
            std::thread::sleep(Duration::from_millis(2));
        });
        assert!(r.mean >= Duration::from_millis(2));
        assert!(r.p95 >= r.p50);
        assert!(r.throughput.unwrap().0 < 100_000.0);
    }

    #[test]
    fn report_formats() {
        let mut b = Bench::new(0, 3);
        b.run("noop", None, || {});
        let row = b.results[0].row();
        assert!(row.contains("noop"));
    }
}
