//! Mini-criterion (criterion is unavailable offline): warmup + timed
//! iterations with mean/p50/p95 and throughput, plus markdown table output
//! shared by all `cargo bench` targets and a machine-readable JSON
//! reporter (`write_json`) consumed by the CI `bench-smoke` perf gate.

use std::time::{Duration, Instant};

use crate::util::json::{obj, Json};

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
    pub min: Duration,
    pub max: Duration,
    /// Optional user-supplied throughput unit (e.g. steps/s).
    pub throughput: Option<(f64, &'static str)>,
    /// Optional bytes touched per op (sketch state + activations) for the
    /// JSON reporter's bandwidth view.
    pub bytes: Option<usize>,
}

impl BenchResult {
    /// Mean nanoseconds per op — the unit the CI perf gate compares.
    pub fn ns_per_op(&self) -> f64 {
        self.mean.as_secs_f64() * 1e9
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("name", Json::Str(self.name.clone())),
            ("ns_per_op", Json::Num(self.ns_per_op())),
            ("p50_ns", Json::Num(self.p50.as_secs_f64() * 1e9)),
            ("p95_ns", Json::Num(self.p95.as_secs_f64() * 1e9)),
            ("p99_ns", Json::Num(self.p99.as_secs_f64() * 1e9)),
            ("min_ns", Json::Num(self.min.as_secs_f64() * 1e9)),
            ("max_ns", Json::Num(self.max.as_secs_f64() * 1e9)),
            ("iters", Json::Num(self.iters as f64)),
        ];
        if let Some(b) = self.bytes {
            pairs.push(("bytes", Json::Num(b as f64)));
        }
        if let Some((v, unit)) = self.throughput {
            pairs.push(("throughput", Json::Num(v)));
            pairs.push(("throughput_unit", Json::Str(unit.to_string())));
        }
        obj(pairs)
    }

    pub fn row(&self) -> String {
        let tp = match self.throughput {
            Some((v, unit)) => format!("{v:.1} {unit}"),
            None => "-".into(),
        };
        format!(
            "| {} | {} | {} | {} | {} | {} |",
            self.name,
            fmt_dur(self.mean),
            fmt_dur(self.p50),
            fmt_dur(self.p95),
            self.iters,
            tp
        )
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let us = d.as_secs_f64() * 1e6;
    if us < 1000.0 {
        format!("{us:.1} µs")
    } else if us < 1_000_000.0 {
        format!("{:.2} ms", us / 1000.0)
    } else {
        format!("{:.3} s", us / 1e6)
    }
}

pub struct Bench {
    pub warmup: usize,
    pub iters: usize,
    pub results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: 3,
            iters: 20,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new(warmup: usize, iters: usize) -> Self {
        Bench {
            warmup,
            iters,
            results: Vec::new(),
        }
    }

    /// CI-friendly sizing: `quick` trades statistical depth for runtime.
    pub fn sized(quick: bool) -> Self {
        if quick {
            Bench::new(1, 5)
        } else {
            Bench::default()
        }
    }

    /// Time `f` over the configured iterations.  `work` gives an optional
    /// per-iteration work amount for throughput (e.g. steps per call).
    pub fn run<F: FnMut()>(
        &mut self,
        name: &str,
        work: Option<(f64, &'static str)>,
        f: F,
    ) -> &BenchResult {
        self.run_bytes(name, work, None, f)
    }

    /// [`Bench::run`] recording the bytes each op touches (for the JSON
    /// reporter's bandwidth view).
    pub fn run_bytes<F: FnMut()>(
        &mut self,
        name: &str,
        work: Option<(f64, &'static str)>,
        bytes: Option<usize>,
        mut f: F,
    ) -> &BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut times = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed());
        }
        times.sort();
        let mean = times.iter().sum::<Duration>() / times.len() as u32;
        let p50 = times[times.len() / 2];
        let p95 = times[(times.len() * 95 / 100).min(times.len() - 1)];
        let p99 = times[(times.len() * 99 / 100).min(times.len() - 1)];
        let min = times[0];
        let max = *times.last().unwrap();
        let throughput = work.map(|(w, unit)| (w / mean.as_secs_f64(), unit));
        self.results.push(BenchResult {
            name: name.to_string(),
            iters: self.iters,
            mean,
            p50,
            p95,
            p99,
            min,
            max,
            throughput,
            bytes,
        });
        self.results.last().unwrap()
    }

    /// Look a result up by name (for cross-result summaries like the
    /// serial-vs-threaded speedup the CI gate checks).
    pub fn result(&self, name: &str) -> Option<&BenchResult> {
        self.results.iter().find(|r| r.name == name)
    }

    /// Print the accumulated results as a markdown table.
    pub fn report(&self, title: &str) {
        println!("\n## {title}\n");
        println!("| bench | mean | p50 | p95 | iters | throughput |");
        println!("|---|---|---|---|---|---|");
        for r in &self.results {
            println!("{}", r.row());
        }
    }

    /// The machine-readable report: all results plus caller-supplied
    /// summary scalars (e.g. `ingest_speedup_4t`), as one JSON object.
    pub fn to_json(
        &self,
        title: &str,
        quick: bool,
        summary: &[(&str, f64)],
    ) -> Json {
        let mut pairs = vec![
            ("title", Json::Str(title.to_string())),
            ("quick", Json::Bool(quick)),
            (
                "results",
                Json::Arr(self.results.iter().map(|r| r.to_json()).collect()),
            ),
        ];
        for &(k, v) in summary {
            pairs.push((k, Json::Num(v)));
        }
        obj(pairs)
    }

    /// Write the JSON report to `path` (the CI `bench-smoke` artifact).
    pub fn write_json(
        &self,
        title: &str,
        quick: bool,
        summary: &[(&str, f64)],
        path: &str,
    ) -> std::io::Result<()> {
        std::fs::write(path, self.to_json(title, quick, summary).to_string())
    }
}

/// `--quick` on the bench command line (`cargo bench -- --quick`) or
/// `BENCH_QUICK=1` in the environment: the cheap CI sizing.
pub fn quick_requested() -> bool {
    std::env::args().any(|a| a == "--quick")
        || std::env::var("BENCH_QUICK").as_deref() == Ok("1")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_sane() {
        let mut b = Bench::new(1, 5);
        let r = b.run("sleep", Some((100.0, "ops/s")), || {
            std::thread::sleep(Duration::from_millis(2));
        });
        assert!(r.mean >= Duration::from_millis(2));
        assert!(r.p95 >= r.p50);
        assert!(r.p99 >= r.p95);
        assert!(r.max >= r.p99 && r.min <= r.p50);
        assert!(r.throughput.unwrap().0 < 100_000.0);
    }

    #[test]
    fn report_formats() {
        let mut b = Bench::new(0, 3);
        b.run("noop", None, || {});
        let row = b.results[0].row();
        assert!(row.contains("noop"));
    }

    #[test]
    fn json_report_roundtrips() {
        let mut b = Bench::new(0, 3);
        b.run_bytes("ingest_serial", Some((1.0, "ops/s")), Some(4096), || {});
        b.run("ingest_threads4", None, || {});
        let j = b.to_json("sketch", true, &[("ingest_speedup_4t", 1.5)]);
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("title").unwrap().as_str().unwrap(), "sketch");
        assert_eq!(parsed.get("quick").unwrap(), &Json::Bool(true));
        assert_eq!(
            parsed.get("ingest_speedup_4t").unwrap().as_f64().unwrap(),
            1.5
        );
        let results = parsed.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(
            results[0].get("name").unwrap().as_str().unwrap(),
            "ingest_serial"
        );
        assert_eq!(results[0].get("bytes").unwrap().as_usize().unwrap(), 4096);
        assert!(results[0].get("ns_per_op").unwrap().as_f64().unwrap() >= 0.0);
        let p99 = results[0].get("p99_ns").unwrap().as_f64().unwrap();
        let max = results[0].get("max_ns").unwrap().as_f64().unwrap();
        assert!(max >= p99 && p99 >= 0.0);
        assert!(results[1].get("bytes").is_err(), "no bytes recorded");
        assert_eq!(
            b.result("ingest_threads4").unwrap().name,
            "ingest_threads4"
        );
    }
}
