//! Traditional gradient-monitoring baseline (paper §5.3's comparator):
//! stores complete gradient matrices at temporal checkpoints, paying the
//! O(L * d^2 * T) memory the sketch-based monitor eliminates.
//!
//! The baseline is real — it actually holds the matrices (f32) and can
//! answer the same diagnostic queries (norms, exact stable rank) — so the
//! memory comparison in Fig-5/TAB-MEM2 is measured, not just modelled.

use crate::sketch::eig;
use crate::sketch::Mat;

/// One checkpoint: full per-layer weight-gradient matrices.
pub struct GradCheckpoint {
    pub step: u64,
    pub grads: Vec<Mat>,
}

pub struct FullMonitor {
    /// Monitoring window: checkpoints retained (paper's T).
    pub window: usize,
    pub checkpoints: Vec<GradCheckpoint>,
}

impl FullMonitor {
    pub fn new(window: usize) -> Self {
        FullMonitor {
            window,
            checkpoints: Vec::new(),
        }
    }

    /// Record a checkpoint, evicting the oldest beyond the window.
    pub fn record(&mut self, step: u64, grads: Vec<Mat>) {
        self.checkpoints.push(GradCheckpoint { step, grads });
        if self.checkpoints.len() > self.window {
            self.checkpoints.remove(0);
        }
    }

    /// Gradient-norm trajectory per layer across retained checkpoints.
    pub fn norm_trajectory(&self) -> Vec<Vec<f64>> {
        self.checkpoints
            .iter()
            .map(|c| c.grads.iter().map(|g| g.fro_norm()).collect())
            .collect()
    }

    /// Exact stable rank of the latest checkpoint's gradients — the
    /// expensive query the sketch estimates cheaply.
    pub fn latest_stable_ranks(&self) -> Vec<f64> {
        match self.checkpoints.last() {
            Some(c) => c.grads.iter().map(eig::stable_rank).collect(),
            None => Vec::new(),
        }
    }

    /// Bytes actually held (runtime f32 accounting).
    pub fn bytes(&self) -> usize {
        self.checkpoints
            .iter()
            .map(|c| c.grads.iter().map(|g| g.runtime_bytes()).sum::<usize>())
            .sum()
    }

    /// Closed-form bytes for the paper's formula O(L * d_l*d_{l-1} * T):
    /// what a full window costs for a given architecture.
    pub fn bytes_for_arch(dims: &[usize], window: usize) -> usize {
        let per_checkpoint: usize = dims
            .windows(2)
            .map(|w| w[0] * w[1] * 4)
            .sum();
        per_checkpoint * window
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn window_eviction() {
        let mut m = FullMonitor::new(3);
        let mut rng = Rng::new(1);
        for step in 0..5 {
            m.record(step, vec![Mat::gaussian(4, 4, &mut rng)]);
        }
        assert_eq!(m.checkpoints.len(), 3);
        assert_eq!(m.checkpoints[0].step, 2);
    }

    #[test]
    fn bytes_match_formula_when_full() {
        let dims = [784usize, 512, 512, 10];
        let mut m = FullMonitor::new(4);
        let mut rng = Rng::new(2);
        for step in 0..4 {
            let grads: Vec<Mat> = dims
                .windows(2)
                .map(|w| Mat::gaussian(w[1], w[0], &mut rng))
                .collect();
            m.record(step, grads);
        }
        assert_eq!(m.bytes(), FullMonitor::bytes_for_arch(&dims, 4));
    }

    #[test]
    fn paper_monitoring_numbers() {
        // Paper §5.3: 16 layers, 1024 hidden, T=5 -> ~320 MB.
        let dims: Vec<usize> =
            std::iter::once(784)
                .chain(std::iter::repeat(1024).take(15))
                .chain(std::iter::once(10))
                .collect();
        let bytes = FullMonitor::bytes_for_arch(&dims, 5);
        let mb = bytes as f64 / (1024.0 * 1024.0);
        assert!(
            (250.0..400.0).contains(&mb),
            "expected ~320 MB, got {mb:.1} MB"
        );
    }

    #[test]
    fn diagnostics_answerable() {
        let mut m = FullMonitor::new(2);
        let mut rng = Rng::new(3);
        m.record(0, vec![Mat::gaussian(8, 8, &mut rng)]);
        m.record(1, vec![Mat::gaussian(8, 8, &mut rng)]);
        assert_eq!(m.norm_trajectory().len(), 2);
        let sr = m.latest_stable_ranks();
        assert_eq!(sr.len(), 1);
        assert!(sr[0] >= 1.0);
    }
}
