//! Baselines the paper compares against: the traditional full-gradient
//! monitoring store (§5.3) and the sqrt(L) checkpointing memory model (§2.1).

pub mod checkpoint;
pub mod full_monitor;

pub use full_monitor::FullMonitor;
