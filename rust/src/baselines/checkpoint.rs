//! Gradient-checkpointing memory baseline (paper §2.1's related-work
//! comparator): the O(sqrt(L)) activation-memory model of Chen et al. 2016
//! with its ~33% recompute overhead, used by the memory-table bench to put
//! the sketching numbers in context.

/// Activation bytes for standard backprop: every layer's batch activation
/// retained, L * n_b * d * 4.
pub fn standard_activation_bytes(n_layers: usize, n_b: usize, d: usize) -> usize {
    n_layers * n_b * d * 4
}

/// Activation bytes under sqrt(L) checkpointing: ceil(sqrt(L)) segment
/// boundaries stored + one segment's activations recomputed at a time.
pub fn checkpoint_activation_bytes(
    n_layers: usize,
    n_b: usize,
    d: usize,
) -> usize {
    let seg = (n_layers as f64).sqrt().ceil() as usize;
    let boundaries = seg;
    let live_segment = n_layers.div_ceil(seg);
    (boundaries + live_segment) * n_b * d * 4
}

/// Relative forward-recompute overhead of checkpointing (Chen et al.: one
/// extra forward ~ 33% of total).
pub const CHECKPOINT_COMPUTE_OVERHEAD: f64 = 0.33;

/// Sketch activation-state bytes per the paper §4.7: 3 sketches of d x k
/// per hidden layer + shared projections (3 * n_b x k) + psi (L * k).
pub fn sketch_state_bytes(
    n_hidden: usize,
    d: usize,
    n_b: usize,
    r: usize,
) -> usize {
    let k = 2 * r + 1;
    let sketches = 3 * n_hidden * d * k;
    let proj = 3 * n_b * k + n_hidden * k;
    (sketches + proj) * 4
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkpointing_saves_memory_for_deep_nets() {
        let std = standard_activation_bytes(50, 128, 1024);
        let ckpt = checkpoint_activation_bytes(50, 128, 1024);
        assert!(ckpt < std / 3, "std {std} ckpt {ckpt}");
    }

    #[test]
    fn paper_per_iteration_ratios() {
        // §4.7: N_b=128, k in {5..33}: per-layer ratio 3k/N_b in
        // [15/128 ~ 0.12, 99/128 ~ 0.77] -> 23-88% per-iteration reduction.
        // Our formula adds projection storage on top, so the r=16 band
        // sits slightly above the paper's 0.77.
        for (r, lo, hi) in [(2usize, 0.03, 0.2), (16, 0.6, 0.95)] {
            let k = 2 * r + 1;
            let act = standard_activation_bytes(3, 128, 512);
            let sk = sketch_state_bytes(3, 512, 128, r);
            let ratio = sk as f64 / act as f64;
            assert!(
                (lo..hi).contains(&ratio),
                "r={r} k={k} ratio {ratio}"
            );
        }
    }

    #[test]
    fn sketch_state_independent_of_batch_dominates() {
        // Doubling n_b doubles activation memory but barely moves sketch
        // state (projection rows only).
        let a1 = standard_activation_bytes(3, 128, 512);
        let a2 = standard_activation_bytes(3, 256, 512);
        let s1 = sketch_state_bytes(3, 512, 128, 4);
        let s2 = sketch_state_bytes(3, 512, 256, 4);
        assert_eq!(a2, 2 * a1);
        assert!((s2 as f64) < 1.2 * s1 as f64);
    }
}
