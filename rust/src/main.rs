//! sketchgrad CLI — the L3 launcher.
//!
//! Subcommands map to the paper's experiments (DESIGN.md §3):
//!   train         one classifier variant (family/variant/rank/adaptive)
//!   fig1          MNIST standard vs fixed-rank vs adaptive (Figure 1)
//!   fig2          CIFAR hybrid CNN-MLP (Figure 2)
//!   pinn          2D Poisson PINN with monitoring (Figures 3-4)
//!   monitor       healthy vs problematic 16-layer MLPs (Figure 5)
//!   hub           K concurrent monitored runs through one MonitorHub
//!                 (native substrate — no artifacts needed)
//!   serve         run the sketchd monitoring daemon in-process
//!   connect       talk to a sketchd daemon (--probe / --probe-resume N /
//!                 --stats / --metrics / --events N / --windows /
//!                 --query-trajectory N / --query-similarity N /
//!                 --query-drift N / --archive-info N / --shutdown /
//!                 status; --json for machine-readable --stats /
//!                 --metrics / --events / --windows output; --timeout-ms /
//!                 --retries tune client deadlines)
//!   memory-table  §4.7 / §5.3 memory models (TAB-MEM1/2)
//!   bound-check   Thm 4.2 sqrt(6)·tau_{r+1} validation
//!   info          manifest + platform summary

use std::sync::mpsc;
use std::thread;
use std::time::Duration;

use anyhow::{bail, Result};

use sketchgrad::benchkit::fmt_dur;
use sketchgrad::config::{
    resolve_threads, ClientConfig, ExperimentConfig, Variant,
};
use sketchgrad::coordinator::experiments::curve_table;
use sketchgrad::coordinator::{
    diagnose_run, figure_table, open_runtime, run_classifier, run_pinn,
    Trainer, VariantRun,
};
use sketchgrad::coordinator::StepMetrics;
use sketchgrad::data::{make_chunks, synth_mnist, ActStream, Init};
use sketchgrad::memory::{fmt_bytes, mnist_dims, monitor16_dims, MemoryModel};
use sketchgrad::monitor::{step_metrics, MonitorConfig, MonitorHub};
use sketchgrad::pinn::field_summary;
use sketchgrad::runtime::{Runtime, Tensor};
use sketchgrad::serve::{
    run_probe, run_probe_resume, serve_from_args, Histogram, MetricsReport,
    MetricsWindowReply, SketchClient, StatsReply,
};
use sketchgrad::sketch::{eig, engine_state_bytes, Mat, Parallelism, SketchConfig, Sketcher};
use sketchgrad::util::cli::Args;
use sketchgrad::util::json::{obj, Json};
use sketchgrad::util::rng::Rng;

fn main() -> Result<()> {
    let mut args = Args::parse_env()?;
    let cmd = args
        .positional
        .first()
        .cloned()
        .unwrap_or_else(|| "info".to_string());
    match cmd.as_str() {
        "train" => cmd_train(&mut args),
        "fig1" => cmd_fig1(&mut args),
        "fig2" => cmd_fig2(&mut args),
        "pinn" => cmd_pinn(&mut args),
        "monitor" => cmd_monitor(&mut args),
        "hub" => cmd_hub(&mut args),
        "serve" => serve_from_args(&mut args),
        "connect" => cmd_connect(&mut args),
        "memory-table" => cmd_memory_table(&mut args),
        "bound-check" => cmd_bound_check(&mut args),
        "info" => cmd_info(),
        other => bail!(
            "unknown command {other:?}; try train|fig1|fig2|pinn|monitor|hub|serve|connect|memory-table|bound-check|info"
        ),
    }
}

fn base_config(args: &mut Args) -> Result<ExperimentConfig> {
    let mut cfg = if let Some(path) = args.opt("config") {
        ExperimentConfig::from_toml_file(std::path::Path::new(&path))?
    } else {
        ExperimentConfig::default()
    };
    cfg.family = args.opt_or("family", &cfg.family);
    cfg.variant = Variant::parse(&args.opt_or("variant", cfg.variant.as_str()))?;
    cfg.rank = args.opt_usize("rank", cfg.rank)?;
    cfg.adaptive = args.flag("adaptive") || cfg.adaptive;
    cfg.epochs = args.opt_usize("epochs", cfg.epochs)?;
    cfg.train_size = args.opt_usize("train-size", cfg.train_size)?;
    cfg.test_size = args.opt_usize("test-size", cfg.test_size)?;
    cfg.seed = args.opt_u64("seed", cfg.seed)?;
    cfg.name = args.opt_or("name", &cfg.name);
    cfg.threads = resolve_threads(args.opt_usize("threads", cfg.threads)?);
    Ok(cfg)
}

fn cmd_train(args: &mut Args) -> Result<()> {
    let cfg = base_config(args)?;
    args.finish()?;
    let rt = open_runtime()?;
    println!("training {} ({})", cfg.artifact_name(), rt.platform());
    let run = run_classifier(&rt, &cfg, false)?;
    for e in &run.epochs {
        println!(
            "epoch {:>3}: loss {:.4} acc {:.3} ({:.1} steps/s)",
            e.epoch, e.mean_loss, e.mean_accuracy, e.steps_per_sec
        );
    }
    println!("{}", figure_table("result", &[&run]));
    if !run.rank_decisions.is_empty() {
        println!("rank decisions: {:?}", run.rank_decisions);
    }
    Ok(())
}

fn cmd_fig1(args: &mut Args) -> Result<()> {
    let epochs = args.opt_usize("epochs", 6)?;
    let train_size = args.opt_usize("train-size", 128 * 100)?;
    let seed = args.opt_u64("seed", 42)?;
    args.finish()?;
    let rt = open_runtime()?;

    let mk = |name: &str, variant: Variant, adaptive: bool| ExperimentConfig {
        name: name.into(),
        family: "mnist".into(),
        variant,
        rank: 2,
        adaptive,
        epochs,
        train_size,
        test_size: 128 * 50,
        seed,
        ..Default::default()
    };
    println!("FIG1 (MNIST): standard vs sketched r=2 vs adaptive");
    let std = run_classifier(&rt, &mk("standard", Variant::Standard, false), false)?;
    let fixed = run_classifier(&rt, &mk("sketched_r2", Variant::Sketched, false), false)?;
    let adaptive = run_classifier(&rt, &mk("adaptive", Variant::Sketched, true), false)?;
    println!("{}", curve_table(&[&std, &fixed, &adaptive]));
    println!("{}", figure_table("Figure 1 — MNIST", &[&std, &fixed, &adaptive]));
    if !adaptive.rank_decisions.is_empty() {
        println!("adaptive rank decisions: {:?}", adaptive.rank_decisions);
    }
    Ok(())
}

fn cmd_fig2(args: &mut Args) -> Result<()> {
    let epochs = args.opt_usize("epochs", 3)?;
    let train_size = args.opt_usize("train-size", 128 * 30)?;
    let seed = args.opt_u64("seed", 42)?;
    args.finish()?;
    let rt = open_runtime()?;
    let mk = |name: &str, variant: Variant| ExperimentConfig {
        name: name.into(),
        family: "cifar".into(),
        variant,
        rank: 2,
        adaptive: false,
        epochs,
        train_size,
        test_size: 128 * 10,
        seed,
        ..Default::default()
    };
    println!("FIG2 (CIFAR CNN-MLP): FC-only sketching");
    let std = run_classifier(&rt, &mk("standard", Variant::Standard), false)?;
    let sk = run_classifier(&rt, &mk("sketched_r2", Variant::Sketched), false)?;
    println!("{}", curve_table(&[&std, &sk]));
    println!("{}", figure_table("Figure 2 — CIFAR", &[&std, &sk]));
    Ok(())
}

fn cmd_pinn(args: &mut Args) -> Result<()> {
    let chunks = args.opt_usize("chunks", 25)?; // 25 * K=20 = 500 steps
    let seed = args.opt_u64("seed", 42)?;
    let show_fields = args.flag("fields");
    args.finish()?;
    let rt = open_runtime()?;
    println!("FIG3/4 (PINN 2D Poisson): standard vs monitored");
    let std = run_pinn(&rt, "standard", 2, chunks, seed)?;
    let mon = run_pinn(&rt, "monitored", 2, chunks, seed)?;
    let mon4 = run_pinn(&rt, "monitored", 4, chunks, seed)?;
    println!("| variant | final loss | L2 rel err | sketch bytes |");
    println!("|---|---|---|---|");
    for r in [&std, &mon, &mon4] {
        println!(
            "| {} | {:.4} | {:.4} | {} |",
            r.label,
            r.losses.last().copied().unwrap_or(f32::NAN),
            r.l2_rel_err,
            fmt_bytes(r.sketch_bytes)
        );
    }
    if show_fields {
        println!("{}", field_summary(&sketchgrad::pinn::exact_field(51), 51, "exact u*"));
        println!("{}", field_summary(&std.u_field, 51, "standard u"));
        println!("{}", field_summary(&mon.u_field, 51, "monitored u"));
        println!("{}", field_summary(&mon.err_field, 51, "monitored |err|"));
    }
    Ok(())
}

fn cmd_monitor(args: &mut Args) -> Result<()> {
    let epochs = args.opt_usize("epochs", 3)?;
    let train_size = args.opt_usize("train-size", 128 * 40)?;
    let seed = args.opt_u64("seed", 42)?;
    args.finish()?;
    let rt = open_runtime()?;
    println!("FIG5 (gradient monitoring): healthy vs problematic 16x1024");
    let healthy_cfg = ExperimentConfig {
        name: "healthy".into(),
        family: "monitor16".into(),
        variant: Variant::Monitored,
        rank: 4,
        adaptive: false,
        epochs,
        train_size,
        test_size: 128 * 20,
        seed,
        ..Default::default()
    };
    let healthy = run_classifier(&rt, &healthy_cfg, false)?;
    let problematic = run_with_artifact(
        &rt,
        "problematic",
        "monitor16_problematic_chunk",
        Init::KaimingNegBias(-3.0),
        epochs,
        train_size,
        seed,
    )?;
    println!("{}", curve_table(&[&healthy, &problematic]));
    println!("{}", figure_table("Figure 5 — monitoring", &[&healthy, &problematic]));
    for (label, run) in [("healthy", &healthy), ("problematic", &problematic)] {
        let d = diagnose_run(run, 4, 15);
        let last = run.history.last().unwrap();
        let mean_sr: f32 =
            last.stable_rank.iter().sum::<f32>() / last.stable_rank.len() as f32;
        let mean_z: f32 =
            last.z_norm.iter().sum::<f32>() / last.z_norm.len() as f32;
        println!(
            "{label}: mean ||Z|| {mean_z:.3}, stable rank {mean_sr:.2}/9, diagnosis {d:?}"
        );
    }
    let m = MemoryModel::new(&monitor16_dims(), 128);
    println!(
        "monitoring memory: traditional T=5 {} vs sketched {} ({:.1}% reduction)",
        fmt_bytes(m.monitoring_traditional(5)),
        fmt_bytes(m.monitoring_sketched(4)),
        100.0 * m.monitoring_reduction(5, 4)
    );
    Ok(())
}

/// Heterogeneous architecture menu for hub tenants (hidden widths per
/// sketched layer) — every session gets a different shape to exercise the
/// per-layer-width path.
const HUB_ARCHS: [&[usize]; 4] = [
    &[128, 64, 32],
    &[96, 96],
    &[160, 80, 40, 20],
    &[64, 48, 32],
];

enum HubMsg {
    Step { idx: usize, metrics: StepMetrics },
    Done { idx: usize, measured_bytes: usize },
}

/// `sketchgrad hub --sessions K`: K concurrent monitored training runs —
/// one thread + one `SketchEngine` each, heterogeneous hidden widths, a
/// tail batch smaller than the nominal n_b — multiplexed through a single
/// `MonitorHub`.  The last session is deliberately pathological
/// (direction-collapsed activations + flat loss) and must be the only one
/// flagged; every session's measured engine memory must match the fixed
/// accountant within 1%.  Runs entirely on the native substrate, so no
/// AOT artifacts are required.
fn cmd_hub(args: &mut Args) -> Result<()> {
    let sessions = args.opt_usize("sessions", 3)?;
    let steps = args.opt_usize("steps", 160)?;
    let n_b = args.opt_usize("batch", 64)?;
    let rank = args.opt_usize("rank", 4)?;
    let seed = args.opt_u64("seed", 42)?;
    let threads = resolve_threads(args.opt_usize("threads", 1)?);
    args.finish()?;
    if sessions == 0 {
        bail!("--sessions must be > 0");
    }
    if steps < 20 {
        bail!("--steps must be >= 20 for a meaningful diagnostic window");
    }
    let par = Parallelism::from_threads(threads);
    let tail = (n_b / 3).max(1);
    let window = (steps / 4).clamp(5, 50);
    println!(
        "MonitorHub demo: {sessions} concurrent monitored runs, \
         {steps} steps each, n_b={n_b} (tail batch {tail}), r={rank}, \
         kernels {par}"
    );

    let mut hub = MonitorHub::with_parallelism(par);
    let mut ids = Vec::new();
    for idx in 0..sessions {
        let dims = HUB_ARCHS[idx % HUB_ARCHS.len()];
        let problematic = idx == sessions - 1;
        let label = format!(
            "run{idx}[{}]{}",
            dims.iter()
                .map(|d| d.to_string())
                .collect::<Vec<_>>()
                .join("/"),
            if problematic { " (problematic)" } else { "" }
        );
        let cfg = MonitorConfig {
            window,
            collapse_frac: 0.25,
            ..MonitorConfig::for_rank(rank)
        };
        ids.push(hub.register(&label, cfg, dims.len())?);
    }

    // One producer thread per tenant; the hub consumes on this thread.
    let (tx, rx) = mpsc::channel::<HubMsg>();
    let mut handles = Vec::new();
    for idx in 0..sessions {
        let dims: Vec<usize> = HUB_ARCHS[idx % HUB_ARCHS.len()].to_vec();
        let problematic = idx == sessions - 1;
        let tx = tx.clone();
        handles.push(thread::spawn(move || {
            run_hub_session(
                idx,
                &dims,
                rank,
                seed + idx as u64,
                steps,
                n_b,
                tail,
                problematic,
                par,
                &tx,
            )
        }));
    }
    drop(tx);

    let mut measured = vec![0usize; sessions];
    for msg in rx {
        match msg {
            HubMsg::Step { idx, metrics } => hub.observe(ids[idx], &metrics)?,
            HubMsg::Done {
                idx,
                measured_bytes,
            } => {
                measured[idx] = measured_bytes;
                hub.report_sketch_bytes(ids[idx], measured_bytes)?;
            }
        }
    }
    for h in handles {
        h.join()
            .map_err(|_| anyhow::anyhow!("hub session thread panicked"))??;
    }

    println!("\n| session | steps | sketch bytes (measured) | accountant | healthy |");
    println!("|---|---|---|---|---|");
    let mut self_check_ok = true;
    for idx in 0..sessions {
        let dims = HUB_ARCHS[idx % HUB_ARCHS.len()];
        let problematic = idx == sessions - 1;
        // The fixed accountant, computed independently of the engine:
        // nominal batches plus the final tail batch were observed
        // (engine_state_bytes dedups if tail == n_b).
        let expected = engine_state_bytes(dims, rank, &[n_b, tail], 4);
        let session = hub.session(ids[idx])?;
        let healthy = session.is_healthy();
        let rel = (measured[idx] as f64 - expected as f64).abs()
            / expected as f64;
        println!(
            "| {} | {} | {} | {} | {} |",
            session.name,
            session.steps_seen(),
            fmt_bytes(measured[idx]),
            fmt_bytes(expected),
            healthy
        );
        if rel > 0.01 {
            bail!(
                "session {idx}: measured {} vs accountant {} ({:.2}% off)",
                measured[idx],
                expected,
                100.0 * rel
            );
        }
        if healthy == problematic {
            self_check_ok = false;
            println!(
                "  !! session {idx} mis-diagnosed \
                 (problematic={problematic}, healthy={healthy}): {:?}",
                session.diagnose()
            );
        }
    }

    let report = hub.aggregate();
    println!(
        "\naggregate: {} sessions, {} healthy, {} flagged; \
         monitor state {} + tenant sketch state {}",
        report.sessions,
        report.healthy,
        report.flagged.len(),
        fmt_bytes(report.monitor_bytes),
        fmt_bytes(report.sketch_bytes),
    );
    for (id, name, d) in &report.flagged {
        println!("  flagged {id} {name}: {:?}", d.notes);
    }
    if !self_check_ok {
        bail!("hub self-check failed: diagnosis did not match session design");
    }
    println!("hub OK");
    Ok(())
}

/// Tenant worker: feeds a synthetic training run's activation stream
/// through a private `SketchEngine`, emitting per-step metrics.
#[allow(clippy::too_many_arguments)]
fn run_hub_session(
    idx: usize,
    dims: &[usize],
    rank: usize,
    seed: u64,
    steps: usize,
    n_b: usize,
    tail: usize,
    problematic: bool,
    par: Parallelism,
    tx: &mpsc::Sender<HubMsg>,
) -> Result<()> {
    let mut engine = SketchConfig::builder()
        .layer_dims(dims)
        .rank(rank)
        .beta(0.9)
        .seed(seed)
        .parallelism(par)
        .build_engine()?;
    let mut stream = ActStream::new(dims, problematic, seed);
    for step in 0..steps {
        let nb = if step == steps - 1 { tail } else { n_b };
        engine.ingest(&stream.next_batch(nb))?;
        let loss = stream.loss_at(step, steps);
        let metrics = step_metrics(loss, &engine.metrics());
        if tx.send(HubMsg::Step { idx, metrics }).is_err() {
            bail!("hub receiver hung up");
        }
    }
    let _ = tx.send(HubMsg::Done {
        idx,
        measured_bytes: engine.memory(),
    });
    Ok(())
}

/// Run a specific artifact by name (the Fig-5 problematic config differs
/// by artifact — SGD optimizer — not by rank, so it bypasses the
/// family/variant resolver).
fn run_with_artifact(
    rt: &Runtime,
    label: &str,
    artifact: &str,
    init: Init,
    epochs: usize,
    train_size: usize,
    seed: u64,
) -> Result<VariantRun> {
    let entry = rt.manifest.get(artifact)?;
    let chunk_k = entry.meta_usize("chunk")?;
    let n_b = entry.meta_usize("n_b")?;
    let rank = entry.meta_usize("r").unwrap_or(4);
    let mut trainer = Trainer::new(rt, artifact, init, seed)?;
    let train = synth_mnist(train_size, seed);
    let mut data_rng = Rng::new(seed ^ 0xDA7A);
    let mut wall = 0.0;
    let mut steps = 0;
    for _ in 0..epochs {
        let chunks = make_chunks(&train, n_b, chunk_k, &mut data_rng, &[784]);
        let s = trainer.run_epoch(&chunks)?;
        wall += s.wall_secs;
        steps += s.steps;
    }
    let dims = entry.meta_dims()?;
    let model = MemoryModel::new(&dims, n_b);
    Ok(VariantRun {
        label: label.into(),
        epochs: trainer.epochs.clone(),
        final_eval_loss: f32::NAN,
        final_eval_acc: f32::NAN,
        model_bytes: model.sketch_state(rank),
        measured_sketch_bytes: trainer.sketch_bytes(),
        rank_decisions: Vec::new(),
        steps_per_sec: steps as f64 / wall.max(1e-9),
        history: trainer.history,
    })
}

/// `sketchgrad connect`: client-side access to a running sketchd.
/// `--probe` drives a full mirrored ingest/diagnose/snapshot cycle,
/// `--probe-resume N` verifies a warm resume after a daemon restart,
/// `--stats` prints daemon-wide and per-session counters,
/// `--metrics` prints the v3 observability report (lifetime counters +
/// ingest/diagnose/query latency percentiles, DESIGN.md §8),
/// `--events N` dumps the newest N journal events (0 = all) and
/// `--windows` the windowed time-series report + sketch-health gauges
/// (both v5, DESIGN.md §10),
/// `--query-trajectory N` / `--query-similarity N` / `--query-drift N`
/// (with `--layer L`, default 0) and `--archive-info N` read the
/// session's archived sketch history (DESIGN.md §7),
/// `--shutdown` snapshots and stops the daemon; with none of those the
/// command prints the daemon's capacity status.  `--json` switches
/// `--metrics` / `--stats` / `--events` / `--windows` output to a
/// single machine-readable JSON object on stdout.  `--timeout-ms` and
/// `--retries` tune the client's socket deadline and connect retries.
fn cmd_connect(args: &mut Args) -> Result<()> {
    let addr = args.opt_or("addr", "127.0.0.1:7070");
    let probe = args.flag("probe");
    let probe_resume = args.opt("probe-resume");
    let stats = args.flag("stats");
    let metrics = args.flag("metrics");
    let events = args.opt("events");
    let windows = args.flag("windows");
    let json_out = args.flag("json");
    let query_trajectory = args.opt("query-trajectory");
    let query_similarity = args.opt("query-similarity");
    let query_drift = args.opt("query-drift");
    let archive_info = args.opt("archive-info");
    let layer = args.opt_usize("layer", 0)?;
    let shutdown = args.flag("shutdown");
    let dnet = ClientConfig::default();
    let net = ClientConfig {
        io_timeout_ms: args.opt_u64("timeout-ms", dnet.io_timeout_ms)?,
        connect_retries: args
            .opt_usize("retries", dnet.connect_retries as usize)?
            as u32,
        ..dnet
    };
    args.finish()?;
    let mut acted = false;
    if probe {
        run_probe(&addr)?;
        acted = true;
    }
    if let Some(raw) = probe_resume {
        let session: u64 = raw
            .parse()
            .map_err(|_| anyhow::anyhow!("--probe-resume needs a session id"))?;
        run_probe_resume(&addr, session)?;
        acted = true;
    }
    if stats {
        let (mut client, _info) = SketchClient::connect_with(&addr, &net)?;
        let reply = client.stats()?;
        if json_out {
            println!("{}", stats_json(&reply).to_string());
        } else {
            print_stats_human(&reply);
        }
        acted = true;
    }
    if metrics {
        let (mut client, _info) = SketchClient::connect_with(&addr, &net)?;
        let m = client.metrics()?;
        if json_out {
            println!("{}", metrics_json(&m).to_string());
        } else {
            print_metrics_human(&m);
        }
        acted = true;
    }
    if let Some(raw) = events {
        let max: u32 = raw
            .parse()
            .map_err(|_| anyhow::anyhow!("--events needs a max count (0 = all)"))?;
        let (mut client, _info) = SketchClient::connect_with(&addr, &net)?;
        let reply = client.events(max)?;
        if json_out {
            let rows = reply
                .events
                .iter()
                .map(|ev| {
                    obj(vec![
                        ("ts_ns", Json::Num(ev.ts_ns as f64)),
                        ("slot", Json::Num(ev.slot as f64)),
                        ("what", Json::Str(ev.describe())),
                    ])
                })
                .collect();
            let out = obj(vec![
                ("dropped", Json::Num(reply.dropped as f64)),
                ("base_unix_ms", Json::Num(reply.base_unix_ms as f64)),
                ("events", Json::Arr(rows)),
            ]);
            println!("{}", out.to_string());
        } else {
            println!(
                "event journal: {} retained, {} dropped, base_unix_ms {}",
                reply.events.len(),
                reply.dropped,
                reply.base_unix_ms
            );
            for ev in &reply.events {
                println!(
                    "  [{:>12.6}s w{}] {}",
                    ev.ts_ns as f64 / 1e9,
                    ev.slot,
                    ev.describe()
                );
            }
        }
        acted = true;
    }
    if windows {
        let (mut client, _info) = SketchClient::connect_with(&addr, &net)?;
        let reply = client.metrics_window()?;
        if json_out {
            println!("{}", windows_json(&reply).to_string());
        } else {
            print_windows_human(&reply);
        }
        acted = true;
    }
    if let Some(raw) = query_trajectory {
        let session = parse_session(&raw, "--query-trajectory")?;
        let (mut client, _info) = SketchClient::connect_with(&addr, &net)?;
        let points = client.session(session).query_trajectory()?;
        println!("trajectory for session {session} ({} intervals):", points.len());
        for p in &points {
            let norms = p
                .z_norms
                .iter()
                .map(|v| format!("{v:.4}"))
                .collect::<Vec<_>>()
                .join(" ");
            println!("  step {:>6}  loss {:.4}  ||Z|| [{}]", p.step, p.loss, norms);
        }
        acted = true;
    }
    if let Some(raw) = query_similarity {
        let session = parse_session(&raw, "--query-similarity")?;
        let (mut client, _info) = SketchClient::connect_with(&addr, &net)?;
        let (steps, sim) = client.session(session).query_similarity(layer)?;
        println!(
            "cosine similarity, session {session} layer {layer}, steps {steps:?}:"
        );
        for i in 0..sim.rows {
            let row = (0..sim.cols)
                .map(|j| format!("{:+.3}", sim.data[i * sim.cols + j]))
                .collect::<Vec<_>>()
                .join(" ");
            println!("  [{row}]");
        }
        acted = true;
    }
    if let Some(raw) = query_drift {
        let session = parse_session(&raw, "--query-drift")?;
        let (mut client, _info) = SketchClient::connect_with(&addr, &net)?;
        let points = client.session(session).query_drift(layer)?;
        println!("spectral drift, session {session} layer {layer}:");
        for p in &points {
            println!(
                "  step {:>6}  top sigma {:.4}  stable rank {:.3}",
                p.step, p.top_sigma, p.stable_rank
            );
        }
        acted = true;
    }
    if let Some(raw) = archive_info {
        let session = parse_session(&raw, "--archive-info")?;
        let (mut client, _info) = SketchClient::connect_with(&addr, &net)?;
        let a = client.session(session).archive_info()?;
        println!(
            "archive for session {session}: {}/{} intervals (stride {}, \
             {} seen), steps {}..{}, {} layers, {}",
            a.intervals,
            a.capacity,
            a.stride,
            a.seen,
            a.oldest_step,
            a.newest_step,
            a.layers,
            fmt_bytes(a.bytes as usize),
        );
        acted = true;
    }
    if shutdown {
        let (mut client, _info) = SketchClient::connect_with(&addr, &net)?;
        let sessions = client.shutdown_daemon()?;
        println!("daemon shutting down ({sessions} sessions snapshotted)");
        acted = true;
    }
    if !acted {
        let (_client, info) = SketchClient::connect_with(&addr, &net)?;
        println!(
            "{} proto v{} — {}/{} sessions",
            info.server, info.proto, info.sessions, info.max_sessions
        );
    }
    Ok(())
}

fn parse_session(raw: &str, flag: &str) -> Result<u64> {
    raw.parse()
        .map_err(|_| anyhow::anyhow!("{flag} needs a session id"))
}

fn print_stats_human(reply: &StatsReply) {
    let daemon = &reply.daemon;
    println!(
        "daemon: {}/{} sessions, {} ingested, {} frames served, \
         {} busy rejections, {} archived, {} shards",
        daemon.sessions,
        daemon.max_sessions,
        fmt_bytes(daemon.ingest_bytes as usize),
        daemon.frames_served,
        daemon.busy_rejections,
        fmt_bytes(daemon.archive_bytes as usize),
        daemon.shards.max(1),
    );
    for sh in &reply.shards {
        println!(
            "  shard {}: {} sessions, {} ingest frames ({}), \
             ingest p50 {} p99 {}, {} frames served",
            sh.shard,
            sh.sessions,
            sh.ingest_frames,
            fmt_bytes(sh.ingest_bytes as usize),
            fmt_dur(Duration::from_nanos(sh.ingest_p50_ns)),
            fmt_dur(Duration::from_nanos(sh.ingest_p99_ns)),
            sh.frames_served,
        );
    }
    for s in &reply.sessions {
        let quota = if s.quota_limit == 0 {
            "unlimited".to_string()
        } else {
            format!(
                "{}/{}",
                fmt_bytes(s.quota_used as usize),
                fmt_bytes(s.quota_limit as usize)
            )
        };
        println!(
            "  session {} {:?}: {} steps, {} ingested, \
             archive {} intervals / {}, quota {quota}, {} busy",
            s.id,
            s.name,
            s.steps_seen,
            fmt_bytes(s.ingest_bytes as usize),
            s.archive_intervals,
            fmt_bytes(s.archive_bytes as usize),
            s.busy_rejections,
        );
    }
}

fn stats_json(reply: &StatsReply) -> Json {
    let d = &reply.daemon;
    let num = |v: u64| Json::Num(v as f64);
    let shards = reply
        .shards
        .iter()
        .map(|sh| {
            obj(vec![
                ("shard", num(sh.shard)),
                ("sessions", num(sh.sessions)),
                ("ingest_frames", num(sh.ingest_frames)),
                ("ingest_bytes", num(sh.ingest_bytes)),
                ("ingest_p50_ns", num(sh.ingest_p50_ns)),
                ("ingest_p99_ns", num(sh.ingest_p99_ns)),
                ("frames_served", num(sh.frames_served)),
            ])
        })
        .collect();
    let sessions = reply
        .sessions
        .iter()
        .map(|s| {
            obj(vec![
                ("id", num(s.id)),
                ("name", Json::Str(s.name.clone())),
                ("steps_seen", num(s.steps_seen)),
                ("ingest_bytes", num(s.ingest_bytes)),
                ("archive_bytes", num(s.archive_bytes)),
                ("archive_intervals", num(s.archive_intervals)),
                ("busy_rejections", num(s.busy_rejections)),
                ("quota_used", num(s.quota_used)),
                ("quota_limit", num(s.quota_limit)),
            ])
        })
        .collect();
    obj(vec![
        (
            "daemon",
            obj(vec![
                ("sessions", num(d.sessions)),
                ("max_sessions", num(d.max_sessions)),
                ("ingest_bytes", num(d.ingest_bytes)),
                ("frames_served", num(d.frames_served)),
                ("archive_bytes", num(d.archive_bytes)),
                ("busy_rejections", num(d.busy_rejections)),
                ("shards", num(d.shards)),
            ]),
        ),
        ("shards", Json::Arr(shards)),
        ("sessions", Json::Arr(sessions)),
    ])
}

fn print_metrics_human(m: &MetricsReport) {
    println!(
        "uptime {:.1}s | sessions {} open / {} peak / {} opened",
        m.uptime_ms as f64 / 1e3,
        m.sessions_open,
        m.sessions_peak,
        m.sessions_opened
    );
    println!(
        "ingested {} ({}/s) over {} ingest frames; {} frames served",
        fmt_bytes(m.ingest_bytes as usize),
        fmt_bytes(m.ingest_bytes_per_sec() as usize),
        m.ingest.count,
        m.frames_served
    );
    println!(
        "busy: {} admission + {} quota = {}",
        m.busy_admission,
        m.busy_quota,
        m.busy_total()
    );
    println!(
        "snapshots: {} ({} total pause)",
        m.snapshot_count,
        fmt_dur(Duration::from_nanos(m.snapshot_pause_ns))
    );
    println!("| op | count | p50 | p95 | p99 | max |");
    println!("|---|---|---|---|---|---|");
    for (op, h) in [
        ("ingest", &m.ingest),
        ("diagnose", &m.diagnose),
        ("query", &m.query),
    ] {
        println!(
            "| {op} | {} | {} | {} | {} | {} |",
            h.count,
            fmt_dur(Duration::from_nanos(h.quantile(0.50) as u64)),
            fmt_dur(Duration::from_nanos(h.quantile(0.95) as u64)),
            fmt_dur(Duration::from_nanos(h.quantile(0.99) as u64)),
            fmt_dur(Duration::from_nanos(h.max_ns)),
        );
    }
}

fn hist_json(h: &Histogram) -> Json {
    let num = |v: u64| Json::Num(v as f64);
    obj(vec![
        ("count", num(h.count)),
        ("p50_ns", Json::Num(h.quantile(0.50))),
        ("p95_ns", Json::Num(h.quantile(0.95))),
        ("p99_ns", Json::Num(h.quantile(0.99))),
        ("max_ns", num(h.max_ns)),
    ])
}

fn metrics_json(m: &MetricsReport) -> Json {
    let num = |v: u64| Json::Num(v as f64);
    obj(vec![
        ("uptime_ms", num(m.uptime_ms)),
        ("sessions_open", num(m.sessions_open)),
        ("sessions_peak", num(m.sessions_peak)),
        ("sessions_opened", num(m.sessions_opened)),
        ("ingest_bytes", num(m.ingest_bytes)),
        ("ingest_frames", num(m.ingest.count)),
        ("frames_served", num(m.frames_served)),
        ("busy_admission", num(m.busy_admission)),
        ("busy_quota", num(m.busy_quota)),
        ("snapshot_count", num(m.snapshot_count)),
        ("snapshot_pause_ns", num(m.snapshot_pause_ns)),
        ("ingest", hist_json(&m.ingest)),
        ("diagnose", hist_json(&m.diagnose)),
        ("query", hist_json(&m.query)),
    ])
}

fn windows_json(reply: &MetricsWindowReply) -> Json {
    let num = |v: u64| Json::Num(v as f64);
    let r = &reply.report;
    let totals = |t: &sketchgrad::serve::obs::WindowTotals| {
        obj(vec![
            ("ingest_frames", num(t.ingest_frames)),
            ("ingest_bytes", num(t.ingest_bytes)),
            ("busy", num(t.busy)),
            ("frames_served", num(t.frames_served)),
        ])
    };
    let buckets = r
        .buckets
        .iter()
        .map(|b| {
            obj(vec![
                ("index", num(b.index)),
                ("start_ms", num(b.start_ms)),
                ("dur_ms", num(b.dur_ms)),
                ("ingest_frames", num(b.ingest_frames)),
                ("ingest_bytes", num(b.ingest_bytes)),
                ("busy", num(b.busy)),
                ("frames_served", num(b.frames_served)),
                ("ingest_p50_ns", num(b.ingest_p50_ns)),
                ("ingest_p99_ns", num(b.ingest_p99_ns)),
                ("throughput", Json::Num(b.throughput())),
            ])
        })
        .collect();
    let health = reply
        .health
        .iter()
        .map(|s| {
            let layers = s
                .layers
                .iter()
                .map(|l| {
                    obj(vec![
                        ("z_norm", Json::Num(l.z_norm)),
                        ("top_sigma", Json::Num(l.top_sigma)),
                        ("stable_rank", Json::Num(l.stable_rank)),
                    ])
                })
                .collect();
            obj(vec![
                ("session", num(s.session)),
                ("name", Json::Str(s.name.clone())),
                ("layers", Json::Arr(layers)),
            ])
        })
        .collect();
    obj(vec![
        ("interval_ms", num(r.interval_ms)),
        ("capacity", num(r.capacity)),
        ("baseline", totals(&r.baseline)),
        ("evicted", totals(&r.evicted)),
        ("open", totals(&r.open.totals())),
        ("total", totals(&r.total())),
        ("buckets", Json::Arr(buckets)),
        ("health", Json::Arr(health)),
    ])
}

fn print_windows_human(reply: &MetricsWindowReply) {
    let r = &reply.report;
    let t = r.total();
    println!(
        "windows: {} x {}ms retained; lifetime ingest frames {} \
         (baseline {} + evicted {} + windows {} + open {})",
        r.buckets.len(),
        r.interval_ms,
        t.ingest_frames,
        r.baseline.ingest_frames,
        r.evicted.ingest_frames,
        t.ingest_frames
            .saturating_sub(r.baseline.ingest_frames)
            .saturating_sub(r.evicted.ingest_frames)
            .saturating_sub(r.open.ingest_frames),
        r.open.ingest_frames,
    );
    for b in &r.buckets {
        println!(
            "  [{:>8}ms +{:>5}ms] {:>6} frames ({:.1}/s), {} busy, \
             ingest p50 {} p99 {}",
            b.start_ms,
            b.dur_ms,
            b.ingest_frames,
            b.throughput(),
            b.busy,
            fmt_dur(Duration::from_nanos(b.ingest_p50_ns)),
            fmt_dur(Duration::from_nanos(b.ingest_p99_ns)),
        );
    }
    for s in &reply.health {
        println!("  session {} {:?}:", s.session, s.name);
        for (i, l) in s.layers.iter().enumerate() {
            println!(
                "    layer {i}: ||Z||_F {:.4}, top sigma {:.4}, \
                 stable rank {:.3}",
                l.z_norm, l.top_sigma, l.stable_rank
            );
        }
    }
}

fn cmd_memory_table(args: &mut Args) -> Result<()> {
    let monitoring = args.flag("monitoring");
    args.finish()?;
    if monitoring {
        println!("TAB-MEM2 — monitoring memory (16x1024 net, r=4):");
        println!("| T (epochs) | traditional | sketched | reduction |");
        println!("|---|---|---|---|");
        let m = MemoryModel::new(&monitor16_dims(), 128);
        for t in [1usize, 5, 10, 50, 100, 500] {
            println!(
                "| {} | {} | {} | {:.2}% |",
                t,
                fmt_bytes(m.monitoring_traditional(t)),
                fmt_bytes(m.monitoring_sketched(4)),
                100.0 * m.monitoring_reduction(t, 4)
            );
        }
    } else {
        println!("TAB-MEM1 — per-iteration memory (MNIST MLP, N_b=128):");
        println!("| rank r | k | hidden acts | sketch state | reduction |");
        println!("|---|---|---|---|---|");
        let m = MemoryModel::new(&mnist_dims(), 128);
        let hidden: usize = 3 * 128 * 512 * 4;
        for r in [2usize, 4, 8, 16] {
            println!(
                "| {} | {} | {} | {} | {:.1}% |",
                r,
                2 * r + 1,
                fmt_bytes(hidden),
                fmt_bytes(m.sketch_state(r)),
                100.0 * m.per_iteration_reduction(r)
            );
        }
    }
    Ok(())
}

fn cmd_bound_check(args: &mut Args) -> Result<()> {
    let trials = args.opt_usize("trials", 5)?;
    let seed = args.opt_u64("seed", 42)?;
    args.finish()?;
    let rt = open_runtime()?;
    println!("THM (Thm 4.2): E||A - A~||_F vs sqrt(6) tau_(r+1)(A)");
    println!("| r | k | mean recon err | sqrt(6) tau_(r+1) | ratio |");
    println!("|---|---|---|---|---|");
    let (n_b, d) = (128usize, 512usize);
    for r in [2usize, 4, 8, 16] {
        let exe = rt.load(&format!("recon_eval_r{r}"))?;
        let k = 2 * r + 1;
        let mut errs = Vec::new();
        let mut bounds = Vec::new();
        for trial in 0..trials {
            let mut rng = Rng::new(seed + trial as u64 * 7919);
            // Low-rank-plus-tail activation surrogate: rank-8 dominant
            // structure + decaying noise (realistic activation spectrum).
            let u = Mat::gaussian(n_b, 8, &mut rng);
            let v = Mat::gaussian(8, d, &mut rng);
            let a = u.matmul(&v).add(&Mat::gaussian(n_b, d, &mut rng).scale(0.05));
            let a32: Vec<f32> = a.to_f32();
            let outs = exe.run(&[
                Tensor::from_f32(&[n_b, d], a32),
                Tensor::from_f32(&[n_b, k], rng.normal_vec_f32(n_b * k)),
                Tensor::from_f32(&[n_b, k], rng.normal_vec_f32(n_b * k)),
                Tensor::from_f32(&[n_b, k], rng.normal_vec_f32(n_b * k)),
                Tensor::from_f32(&[k], rng.normal_vec_f32(k)),
            ])?;
            errs.push(outs[1].scalar()? as f64);
            bounds.push(6f64.sqrt() * eig::tail_energy(&a, r));
        }
        let mean_err = errs.iter().sum::<f64>() / errs.len() as f64;
        let mean_bound = bounds.iter().sum::<f64>() / bounds.len() as f64;
        println!(
            "| {} | {} | {:.3} | {:.3} | {:.3} |",
            r,
            k,
            mean_err,
            mean_bound,
            mean_err / mean_bound
        );
    }
    println!(
        "\nNote: the bound applies to the Tropp-style reconstruction; the\n\
         paper's adapted pipeline (P_X mixing, Eq. 6-7) is not an exact\n\
         projector, so ratios > 1 quantify the adaptation gap (DESIGN.md §2/S2)."
    );
    Ok(())
}

fn cmd_info() -> Result<()> {
    let rt = open_runtime()?;
    println!("platform: {}", rt.platform());
    println!("artifacts ({}):", rt.manifest.artifacts.len());
    for (name, e) in &rt.manifest.artifacts {
        println!(
            "  {name}: {} inputs, {} outputs",
            e.inputs.len(),
            e.outputs.len()
        );
    }
    for (name, secs) in rt.compile_log.borrow().iter() {
        println!("  compiled {name} in {secs:.2}s");
    }
    Ok(())
}
