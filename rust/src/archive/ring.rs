//! The per-session snapshot ring: bounded retention of interval
//! Z-sketches with stride sampling, oldest-first eviction and honest
//! byte accounting.
//!
//! Steady-state recording is **allocation-free**: once the ring is full
//! every further record overwrites the oldest slot's resident matrices
//! element-wise (`copy_from_slice`), so the daemon's zero-allocation
//! ingest hot path (see `tests/ingest_alloc.rs`) is preserved with
//! archiving enabled.  Allocation only happens while the ring is still
//! filling (warm-up) or after a rank change reshapes the sketches.

use crate::sketch::{Mat, SketchTriplet};

/// One retained ingest interval: the step counter (engine
/// `batches_ingested` at capture time), the observed loss and a copy of
/// every layer's Z sketch (d_out x k).
#[derive(Clone, Debug, PartialEq)]
pub struct IntervalRecord {
    pub step: u64,
    pub loss: f32,
    pub zs: Vec<Mat>,
}

/// Accountant bytes for one interval record at `unit` bytes per sketch
/// element (the engine's precision width) plus the per-record scalars
/// (step u64 + loss f32).  Mirrors `sketch::engine_state_bytes`: a
/// fixed formula, independent of container overheads.
pub fn archive_record_bytes(
    layer_dims: &[usize],
    rank: usize,
    unit: usize,
) -> usize {
    let k = 2 * rank + 1;
    layer_dims.iter().map(|d| d * k * unit).sum::<usize>() + 12
}

fn record_bytes(rec: &IntervalRecord, unit: usize) -> usize {
    rec.zs
        .iter()
        .map(|z| z.rows * z.cols * unit)
        .sum::<usize>()
        + 12
}

/// Plain-data image of a [`SessionArchive`] for durable snapshots;
/// records are stored oldest-first, so a restored archive answers every
/// query bit-identically to the archive it was captured from.
#[derive(Clone, Debug, PartialEq)]
pub struct ArchiveState {
    pub capacity: usize,
    pub stride: usize,
    pub seen: u64,
    pub unit: usize,
    /// Retained records, oldest first.
    pub records: Vec<IntervalRecord>,
}

/// Ring buffer of interval sketch snapshots for one monitored session.
///
/// * `capacity` bounds retained intervals (0 disables archiving);
/// * `stride` samples every N-th ingest interval (the first observed
///   interval is always eligible);
/// * eviction is strictly oldest-first;
/// * [`SessionArchive::bytes`] reports retained bytes at the accountant
///   unit handed in at construction.
#[derive(Clone, Debug)]
pub struct SessionArchive {
    capacity: usize,
    stride: usize,
    /// Ingest intervals observed (recorded or skipped by the stride).
    seen: u64,
    /// Accountant bytes per sketch element (engine precision width).
    unit: usize,
    slots: Vec<IntervalRecord>,
    /// Index of the oldest record once the ring has wrapped.
    head: usize,
}

impl SessionArchive {
    /// `stride` is clamped to >= 1 (0 would never sample anything and
    /// is rejected by config validation before it gets here).
    pub fn new(capacity: usize, stride: usize, unit: usize) -> Self {
        SessionArchive {
            capacity,
            stride: stride.max(1),
            seen: 0,
            unit,
            slots: Vec::new(),
            head: 0,
        }
    }

    /// Observe one ingest interval; record it if the stride selects it.
    /// Returns whether a record was written.  In steady state (ring
    /// full, shapes unchanged) this performs no heap allocation: the
    /// oldest slot is overwritten in place.
    pub fn maybe_record(
        &mut self,
        step: u64,
        loss: f32,
        layers: &[SketchTriplet],
    ) -> bool {
        if self.capacity == 0 {
            return false;
        }
        let due = self.seen % self.stride as u64 == 0;
        self.seen += 1;
        if !due {
            return false;
        }
        if self.slots.len() < self.capacity {
            self.slots.push(IntervalRecord {
                step,
                loss,
                zs: layers.iter().map(|t| t.z.clone()).collect(),
            });
        } else {
            let slot = &mut self.slots[self.head];
            slot.step = step;
            slot.loss = loss;
            for (dst, t) in slot.zs.iter_mut().zip(layers) {
                if dst.rows == t.z.rows && dst.cols == t.z.cols {
                    dst.data.copy_from_slice(&t.z.data);
                } else {
                    // Rank change reshaped the sketches — not a
                    // steady-state path; reallocate the slot.
                    *dst = t.z.clone();
                }
            }
            self.head = (self.head + 1) % self.capacity;
        }
        true
    }

    /// Retained records.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Ingest intervals observed so far (recorded + stride-skipped).
    pub fn intervals_seen(&self) -> u64 {
        self.seen
    }

    /// Accountant unit (bytes per sketch element).
    pub fn unit(&self) -> usize {
        self.unit
    }

    /// The `i`-th retained record in logical (oldest-first) order.
    pub fn get(&self, i: usize) -> Option<&IntervalRecord> {
        if i >= self.slots.len() {
            return None;
        }
        Some(&self.slots[(self.head + i) % self.slots.len()])
    }

    /// Retained records, oldest first.
    pub fn iter(&self) -> impl Iterator<Item = &IntervalRecord> {
        (0..self.slots.len())
            .map(move |i| &self.slots[(self.head + i) % self.slots.len()])
    }

    /// Honest retained-bytes accounting: sketch elements at the
    /// accountant unit plus the per-record scalars.  Bounded by
    /// `capacity * archive_record_bytes(..)` for fixed layer shapes.
    pub fn bytes(&self) -> usize {
        self.slots.iter().map(|r| record_bytes(r, self.unit)).sum()
    }

    /// Plain-data image (records oldest-first) for durable snapshots.
    pub fn state(&self) -> ArchiveState {
        ArchiveState {
            capacity: self.capacity,
            stride: self.stride,
            seen: self.seen,
            unit: self.unit,
            records: self.iter().cloned().collect(),
        }
    }

    /// Rebuild from a snapshot image.  The restored ring is re-packed
    /// oldest-first (head 0); logical order — and therefore every query
    /// answer — is identical to the archive the state was captured from.
    pub fn from_state(st: &ArchiveState) -> Self {
        let mut slots = st.records.clone();
        slots.truncate(st.capacity);
        SessionArchive {
            capacity: st.capacity,
            stride: st.stride.max(1),
            seen: st.seen,
            unit: st.unit,
            slots,
            head: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::SketchTriplet;

    fn layers(dims: &[usize], rank: usize, fill: f64) -> Vec<SketchTriplet> {
        dims.iter()
            .map(|&d| {
                let mut t = SketchTriplet::zeros(d, rank, 0.9);
                t.z.data.iter_mut().for_each(|v| *v = fill);
                t
            })
            .collect()
    }

    #[test]
    fn ring_evicts_oldest_first() {
        let dims = [6usize, 4];
        let mut ar = SessionArchive::new(3, 1, 4);
        for step in 1..=7u64 {
            assert!(ar.maybe_record(step, step as f32, &layers(&dims, 2, step as f64)));
        }
        assert_eq!(ar.len(), 3);
        let steps: Vec<u64> = ar.iter().map(|r| r.step).collect();
        assert_eq!(steps, vec![5, 6, 7]);
        // Payloads travelled with their records.
        assert_eq!(ar.get(0).unwrap().zs[0].data[0], 5.0);
        assert_eq!(ar.get(2).unwrap().zs[1].data[0], 7.0);
        assert!(ar.get(3).is_none());
    }

    #[test]
    fn stride_samples_every_nth_interval() {
        let dims = [4usize];
        let mut ar = SessionArchive::new(16, 3, 4);
        let mut recorded = Vec::new();
        for step in 1..=10u64 {
            if ar.maybe_record(step, 0.0, &layers(&dims, 1, 0.0)) {
                recorded.push(step);
            }
        }
        // First interval always eligible, then every 3rd.
        assert_eq!(recorded, vec![1, 4, 7, 10]);
        assert_eq!(ar.intervals_seen(), 10);
        assert_eq!(ar.len(), 4);
    }

    #[test]
    fn capacity_zero_disables_recording() {
        let mut ar = SessionArchive::new(0, 1, 4);
        assert!(!ar.maybe_record(1, 0.0, &layers(&[4], 1, 1.0)));
        assert!(ar.is_empty());
        assert_eq!(ar.bytes(), 0);
    }

    #[test]
    fn byte_accounting_matches_fixed_formula_and_caps() {
        let dims = [8usize, 6, 4];
        let rank = 2;
        let unit = 4;
        let per = archive_record_bytes(&dims, rank, unit);
        let k = 2 * rank + 1;
        assert_eq!(per, (8 + 6 + 4) * k * unit + 12);
        let mut ar = SessionArchive::new(4, 1, unit);
        for step in 1..=9u64 {
            ar.maybe_record(step, 0.5, &layers(&dims, rank, 1.0));
            assert_eq!(ar.bytes(), ar.len() * per);
        }
        // Full ring: retained bytes are capped and constant.
        assert_eq!(ar.bytes(), 4 * per);
    }

    #[test]
    fn state_roundtrip_preserves_logical_order() {
        let dims = [5usize, 3];
        let mut ar = SessionArchive::new(3, 2, 4);
        for step in 1..=8u64 {
            ar.maybe_record(step, step as f32 * 0.1, &layers(&dims, 2, step as f64));
        }
        let st = ar.state();
        let back = SessionArchive::from_state(&st);
        assert_eq!(back.len(), ar.len());
        assert_eq!(back.intervals_seen(), ar.intervals_seen());
        assert_eq!(back.stride(), ar.stride());
        assert_eq!(back.capacity(), ar.capacity());
        assert_eq!(back.bytes(), ar.bytes());
        let a: Vec<&IntervalRecord> = ar.iter().collect();
        let b: Vec<&IntervalRecord> = back.iter().collect();
        assert_eq!(a, b);
        // And recording continues seamlessly after a restore.
        let mut back = back;
        back.maybe_record(9, 0.9, &layers(&dims, 2, 9.0));
        assert_eq!(back.iter().last().unwrap().step, 9);
    }
}
