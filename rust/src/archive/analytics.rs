//! Analytics computed entirely from archived sketches.
//!
//! Everything here reads only [`super::SessionArchive`] records and the
//! existing `sketch::eig` machinery — no access to raw activations or
//! gradients is needed, which is the point: the retained Z sketches
//! (gradient-weighted activation sketches, paper Eq. 5c) are a
//! sufficient statistic for
//!
//! * **trajectory** — per-layer Frobenius gradient-norm proxies per
//!   retained interval,
//! * **similarity** — cross-step cosine similarity between a layer's
//!   sketches (candidate training-data attribution scores in the sense
//!   of Schioppa, arXiv 2402.03994),
//! * **drift** — top singular value and stable rank of a layer's sketch
//!   across the run (per-layer invariant scalars à la BASIS).
//!
//! All three are deterministic functions of the stored records, so a
//! warm-restarted daemon whose archive round-tripped through a snapshot
//! answers bit-identically.

use crate::sketch::{eig, Mat};

use super::ring::SessionArchive;

/// One interval of the gradient-norm trajectory.
#[derive(Clone, Debug, PartialEq)]
pub struct TrajectoryPoint {
    pub step: u64,
    pub loss: f32,
    /// `||Z^[l]||_F` per layer — the sketched gradient-energy proxy.
    pub z_norms: Vec<f64>,
}

/// One interval of the spectral-drift series for a single layer.
#[derive(Clone, Debug, PartialEq)]
pub struct DriftPoint {
    pub step: u64,
    /// Top singular value of the layer's Z sketch.
    pub top_sigma: f64,
    /// `||Z||_F^2 / sigma_1^2` (0.0 for a zero or empty sketch).
    pub stable_rank: f64,
}

impl SessionArchive {
    /// Gradient-norm trajectory over every retained interval, oldest
    /// first.
    pub fn trajectory(&self) -> Vec<TrajectoryPoint> {
        self.iter()
            .map(|rec| TrajectoryPoint {
                step: rec.step,
                loss: rec.loss,
                z_norms: rec.zs.iter().map(|z| z.fro_norm()).collect(),
            })
            .collect()
    }

    /// Cross-step cosine similarity of one layer's Z sketch: the (i, j)
    /// entry is `<Z_i, Z_j>_F / (||Z_i||_F ||Z_j||_F)` between the i-th
    /// and j-th retained intervals (oldest first).  Returns the interval
    /// steps alongside the dense n x n matrix.  Pairs involving a zero
    /// sketch score 0.0; the matrix is exactly symmetric (each pair is
    /// computed once and mirrored).
    pub fn similarity(&self, layer: usize) -> (Vec<u64>, Mat) {
        let recs: Vec<_> = self
            .iter()
            .filter(|rec| layer < rec.zs.len())
            .collect();
        let n = recs.len();
        let steps: Vec<u64> = recs.iter().map(|r| r.step).collect();
        let norms: Vec<f64> =
            recs.iter().map(|r| r.zs[layer].fro_norm()).collect();
        let mut sim = Mat::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let denom = norms[i] * norms[j];
                let v = if denom == 0.0 {
                    0.0
                } else {
                    let a = &recs[i].zs[layer].data;
                    let b = &recs[j].zs[layer].data;
                    let dot: f64 =
                        a.iter().zip(b).map(|(x, y)| x * y).sum();
                    dot / denom
                };
                sim.data[i * n + j] = v;
                sim.data[j * n + i] = v;
            }
        }
        (steps, sim)
    }

    /// Top singular value + stable rank of one layer's Z sketch per
    /// retained interval, oldest first.  Cold or zero sketches yield
    /// (0.0, 0.0) — `eig` handles degenerate inputs without panicking.
    pub fn drift(&self, layer: usize) -> Vec<DriftPoint> {
        self.iter()
            .filter(|rec| layer < rec.zs.len())
            .map(|rec| {
                let z = &rec.zs[layer];
                let sv = eig::singular_values(z);
                let top = sv.first().copied().unwrap_or(0.0);
                let stable_rank = if top == 0.0 {
                    0.0
                } else {
                    let f = z.fro_norm();
                    (f * f) / (top * top)
                };
                DriftPoint { step: rec.step, top_sigma: top, stable_rank }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::SketchTriplet;

    fn layers(dims: &[usize], rank: usize, fill: f64) -> Vec<SketchTriplet> {
        dims.iter()
            .map(|&d| {
                let mut t = SketchTriplet::zeros(d, rank, 0.9);
                t.z.data.iter_mut().for_each(|v| *v = fill);
                t
            })
            .collect()
    }

    #[test]
    fn trajectory_reports_fro_norms_per_layer() {
        let dims = [3usize, 2];
        let mut ar = SessionArchive::new(8, 1, 4);
        ar.maybe_record(1, 0.5, &layers(&dims, 1, 2.0));
        ar.maybe_record(2, 0.25, &layers(&dims, 1, 0.0));
        let traj = ar.trajectory();
        assert_eq!(traj.len(), 2);
        assert_eq!(traj[0].step, 1);
        assert_eq!(traj[0].loss, 0.5);
        // Z is d x k with k = 3; ||fill * ones||_F = fill * sqrt(d * k).
        let expect = |d: usize| 2.0 * ((d * 3) as f64).sqrt();
        assert!((traj[0].z_norms[0] - expect(3)).abs() < 1e-12);
        assert!((traj[0].z_norms[1] - expect(2)).abs() < 1e-12);
        assert_eq!(traj[1].z_norms, vec![0.0, 0.0]);
    }

    #[test]
    fn similarity_is_symmetric_with_unit_diagonal() {
        let dims = [4usize];
        let mut ar = SessionArchive::new(8, 1, 4);
        ar.maybe_record(1, 0.0, &layers(&dims, 1, 1.0));
        ar.maybe_record(2, 0.0, &layers(&dims, 1, -3.0));
        ar.maybe_record(3, 0.0, &layers(&dims, 1, 0.0));
        let (steps, sim) = ar.similarity(0);
        assert_eq!(steps, vec![1, 2, 3]);
        assert_eq!(sim.rows, 3);
        // Parallel fills: cosine is exactly +/-1; zero sketch scores 0.
        assert!((sim.data[0] - 1.0).abs() < 1e-12);
        assert!((sim.data[1] + 1.0).abs() < 1e-12);
        assert_eq!(sim.data[2], 0.0);
        assert_eq!(sim.data[8], 0.0); // zero-vs-zero diagonal
        for i in 0..3 {
            for j in 0..3 {
                assert_eq!(sim.data[i * 3 + j], sim.data[j * 3 + i]);
            }
        }
    }

    #[test]
    fn drift_matches_eig_on_stored_sketches() {
        let dims = [5usize];
        let mut ar = SessionArchive::new(8, 1, 4);
        ar.maybe_record(1, 0.0, &layers(&dims, 2, 0.0));
        ar.maybe_record(2, 0.0, &layers(&dims, 2, 1.5));
        let drift = ar.drift(0);
        assert_eq!(drift.len(), 2);
        // Zero sketch: degenerate but well-defined.
        assert_eq!(drift[0].top_sigma, 0.0);
        assert_eq!(drift[0].stable_rank, 0.0);
        // Rank-1 constant matrix: sigma_1 = ||Z||_F, stable rank 1.
        let z = &ar.get(1).unwrap().zs[0];
        assert!((drift[1].top_sigma - z.fro_norm()).abs() < 1e-9);
        assert!((drift[1].stable_rank - 1.0).abs() < 1e-9);
    }

    #[test]
    fn out_of_range_layer_yields_empty_results() {
        let mut ar = SessionArchive::new(4, 1, 4);
        ar.maybe_record(1, 0.0, &layers(&[3], 1, 1.0));
        let (steps, sim) = ar.similarity(7);
        assert!(steps.is_empty());
        assert_eq!(sim.rows, 0);
        assert!(ar.drift(7).is_empty());
    }
}
