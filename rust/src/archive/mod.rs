//! The sketch archive: a queryable per-session history of interval
//! sketch snapshots, turning the monitor from an alarm into an
//! analytics service (ROADMAP item 5; Schioppa, arXiv 2402.03994).
//!
//! Each monitored session may retain a bounded **ring** of per-ingest
//! Z-sketch snapshots ([`ring::SessionArchive`]): configurable capacity
//! and sampling stride, oldest-first eviction, and honest byte
//! accounting in the same accountant unit the engine charges for its
//! resident sketches.  Only the Z sketch (the gradient-weighted
//! activation sketch, paper Eq. 5c) is retained — it alone carries the
//! gradient-norm, similarity and spectral-drift signals the analytics
//! layer serves, at a third of the bytes of a full (X, Y, Z) triplet.
//!
//! The analytics layer ([`analytics`]) is computed **entirely from the
//! stored sketches** through the existing [`crate::sketch::eig`]
//! machinery:
//!
//! * gradient-norm trajectories — per-layer `||Z||_F` per interval,
//! * cross-step sketch cosine similarity (candidate attribution
//!   scores between training intervals),
//! * top singular-value / stable-rank drift across a run.
//!
//! The serve layer exposes all of it over the wire
//! (`QueryTrajectory`/`QuerySimilarity`/`QueryDrift`/`ArchiveInfo`,
//! proto v2) and piggybacks archive persistence on the daemon's
//! durable snapshots, so query answers survive a warm restart
//! bit-exactly.  See DESIGN.md §7.

pub mod analytics;
pub mod ring;

pub use analytics::{DriftPoint, TrajectoryPoint};
pub use ring::{
    archive_record_bytes, ArchiveState, IntervalRecord, SessionArchive,
};
