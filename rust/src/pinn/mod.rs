//! PINN substrate (paper §5.2.2, Figs. 3-4): the 2D Poisson problem's
//! exact solution, evaluation grid and error metrics on the rust side —
//! used to validate the AOT `pinn_eval` artifact and render Fig-4's
//! field/error tables.

use crate::data::PoissonSampler;

/// Exact solution u*(x,y) = 0.5 sin(2 pi x) sin(2 pi y) of
/// -Lap u = 4 pi^2 sin(2 pi x) sin(2 pi y), u=0 on the unit-square boundary.
pub fn exact_solution(x: f64, y: f64) -> f64 {
    0.5 * (std::f64::consts::TAU * x).sin() * (std::f64::consts::TAU * y).sin()
}

/// Forcing term f(x,y).
pub fn forcing(x: f64, y: f64) -> f64 {
    4.0 * std::f64::consts::PI.powi(2)
        * (std::f64::consts::TAU * x).sin()
        * (std::f64::consts::TAU * y).sin()
}

/// L2 relative error over paired predictions/points.
pub fn l2_relative_error(pred: &[f32], points: &[f32]) -> f64 {
    assert_eq!(points.len(), 2 * pred.len());
    let mut num = 0.0;
    let mut den = 0.0;
    for (i, &p) in pred.iter().enumerate() {
        let ue = exact_solution(points[2 * i] as f64, points[2 * i + 1] as f64);
        num += (p as f64 - ue).powi(2);
        den += ue * ue;
    }
    (num / den.max(1e-300)).sqrt()
}

/// Render an ASCII heat-map row summary of a field on a g x g grid —
/// Fig-4's "solution quality" panels in terminal form.
pub fn field_summary(values: &[f32], g: usize, label: &str) -> String {
    assert_eq!(values.len(), g * g);
    let vmax = values.iter().cloned().fold(f32::MIN, f32::max);
    let vmin = values.iter().cloned().fold(f32::MAX, f32::min);
    let chars = [' ', '.', ':', '-', '=', '+', '*', '#', '%', '@'];
    let mut out = format!(
        "{label}: min {vmin:.4} max {vmax:.4}\n"
    );
    let stride = (g / 26).max(1);
    for row in (0..g).step_by(stride) {
        for col in (0..g).step_by(stride) {
            let v = values[row * g + col];
            let t = if vmax > vmin {
                ((v - vmin) / (vmax - vmin)).clamp(0.0, 1.0)
            } else {
                0.0
            };
            let idx = (t * (chars.len() - 1) as f32).round() as usize;
            out.push(chars[idx]);
        }
        out.push('\n');
    }
    out
}

/// Exact-solution field on the standard evaluation grid.
pub fn exact_field(g: usize) -> Vec<f32> {
    let pts = PoissonSampler::grid(g);
    (0..g * g)
        .map(|i| exact_solution(pts[2 * i] as f64, pts[2 * i + 1] as f64) as f32)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_solution_satisfies_pde() {
        // Finite-difference Laplacian check: -Lap u ~ f.
        let h = 1e-4;
        for (x, y) in [(0.3, 0.7), (0.52, 0.11), (0.9, 0.4)] {
            let lap = (exact_solution(x + h, y)
                + exact_solution(x - h, y)
                + exact_solution(x, y + h)
                + exact_solution(x, y - h)
                - 4.0 * exact_solution(x, y))
                / (h * h);
            let rel = (-lap - forcing(x, y)).abs() / forcing(x, y).abs().max(1.0);
            assert!(rel < 1e-4, "PDE residual {rel} at ({x},{y})");
        }
    }

    #[test]
    fn boundary_is_zero() {
        for t in [0.0, 0.25, 0.5, 0.75, 1.0] {
            assert!(exact_solution(t, 0.0).abs() < 1e-12);
            assert!(exact_solution(0.0, t).abs() < 1e-12);
            assert!(exact_solution(t, 1.0).abs() < 1e-12);
            assert!(exact_solution(1.0, t).abs() < 1e-12);
        }
    }

    #[test]
    fn l2_error_of_exact_is_zero() {
        let g = 21;
        let pts = PoissonSampler::grid(g);
        let pred: Vec<f32> = (0..g * g)
            .map(|i| {
                exact_solution(pts[2 * i] as f64, pts[2 * i + 1] as f64) as f32
            })
            .collect();
        assert!(l2_relative_error(&pred, &pts) < 1e-6);
        // And of zeros is exactly 1.
        let zeros = vec![0.0f32; g * g];
        assert!((l2_relative_error(&zeros, &pts) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn field_summary_renders() {
        let f = exact_field(51);
        let s = field_summary(&f, 51, "exact");
        assert!(s.contains("exact"));
        assert!(s.lines().count() > 10);
    }
}
