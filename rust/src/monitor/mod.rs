//! Gradient-monitor service (paper §4.6/§5.3): constant-memory sketch-based
//! diagnostics with pathology detectors, multiplexed across concurrent
//! training runs by the multi-tenant [`hub::MonitorHub`].

pub mod hub;
pub mod service;

pub use hub::{
    step_metrics, HubError, HubReport, MonitorHub, MonitorSession,
    SessionId, SessionState,
};
pub use service::{
    Diagnosis, MonitorConfig, MonitorService, Rolling, RollingState,
    ServiceState,
};
