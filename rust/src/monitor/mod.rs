//! Gradient-monitor service (paper §4.6/§5.3): constant-memory sketch-based
//! diagnostics with pathology detectors.

pub mod service;

pub use service::{Diagnosis, MonitorConfig, MonitorService, Rolling};
