//! Multi-tenant monitoring: one `MonitorHub` multiplexes N independent
//! `MonitorSession`s, one per concurrent training run.
//!
//! Each session owns its own `MonitorConfig` and constant-memory
//! `Rolling` state (via an embedded [`MonitorService`]), so the hub's
//! footprint is O(sessions) and independent of monitoring duration — the
//! paper's §4.6 memory story, multiplied across tenants.  The hub also
//! aggregates diagnosis and memory accounting across tenants, which is
//! what the `sketchgrad hub` subcommand and the serving-path roadmap
//! items build on.

use std::collections::BTreeMap;
use std::fmt;
use std::sync::Arc;

use crate::coordinator::StepMetrics;
use crate::sketch::metrics::LayerMetrics;
use crate::sketch::{Parallelism, Pool};

use super::service::{
    Diagnosis, MonitorConfig, MonitorService, ServiceState,
};

/// Opaque tenant handle issued by [`MonitorHub::register`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct SessionId(u64);

impl SessionId {
    pub fn raw(self) -> u64 {
        self.0
    }

    /// Rebuild a handle from its raw id (snapshot restore / wire layer).
    pub fn from_raw(raw: u64) -> SessionId {
        SessionId(raw)
    }
}

/// Typed hub failures, so the serve wire layer can map each case to a
/// protocol error code instead of stringly-typed (or panicking) paths.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum HubError {
    /// `restore_session` was handed an id the hub already holds.
    DuplicateSession(SessionId),
    /// The id space is exhausted (`u64::MAX` is reserved as a sentinel).
    SessionsExhausted,
    /// An operation referenced an id the hub does not hold.
    NoSuchSession(SessionId),
}

impl fmt::Display for HubError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            HubError::DuplicateSession(id) => {
                write!(f, "hub already has session {id}")
            }
            HubError::SessionsExhausted => {
                write!(f, "hub session id space exhausted")
            }
            HubError::NoSuchSession(id) => {
                write!(f, "hub has no session {id}")
            }
        }
    }
}

impl std::error::Error for HubError {}

impl std::fmt::Display for SessionId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "s{}", self.0)
    }
}

/// One tenant: a monitored training run with its own detector config and
/// constant-memory summaries.
pub struct MonitorSession {
    pub id: SessionId,
    pub name: String,
    svc: MonitorService,
    /// Last sketch-state bytes the tenant's engine reported (the hub does
    /// not own engines — tenants push their accountant reading).
    pub sketch_bytes: usize,
    /// Last archive-retained bytes the tenant reported (the hub does not
    /// own archives either — the daemon pushes the ring's accounting).
    pub archive_bytes: usize,
}

impl MonitorSession {
    pub fn observe(&mut self, m: &StepMetrics) {
        self.svc.observe(m);
    }

    pub fn diagnose(&self) -> Diagnosis {
        self.svc.diagnose()
    }

    pub fn is_healthy(&self) -> bool {
        self.svc.is_healthy()
    }

    pub fn steps_seen(&self) -> u64 {
        self.svc.steps_seen
    }

    pub fn config(&self) -> &MonitorConfig {
        &self.svc.cfg
    }

    /// Bytes of monitor state this session holds (constant in duration).
    pub fn monitor_bytes(&self) -> usize {
        self.svc.monitor_bytes()
    }

    /// Plain-data image of the session (id, name, tenant-reported sketch
    /// bytes and the full detector state) for durable snapshots.
    pub fn state(&self) -> SessionState {
        SessionState {
            id: self.id.raw(),
            name: self.name.clone(),
            sketch_bytes: self.sketch_bytes as u64,
            service: self.svc.state(),
        }
    }
}

/// Snapshot image of one [`MonitorSession`]; restored with
/// [`MonitorHub::restore_session`].
#[derive(Clone, Debug)]
pub struct SessionState {
    pub id: u64,
    pub name: String,
    pub sketch_bytes: u64,
    pub service: ServiceState,
}

/// Aggregate view over all tenants.
#[derive(Debug, Default)]
pub struct HubReport {
    pub sessions: usize,
    pub healthy: usize,
    /// (id, name, diagnosis) for every unhealthy session.
    pub flagged: Vec<(SessionId, String, Diagnosis)>,
    /// Monitor-state bytes across all sessions.
    pub monitor_bytes: usize,
    /// Sum of tenant-reported sketch-state bytes.
    pub sketch_bytes: usize,
    /// Sum of tenant-reported archive-retained bytes.
    pub archive_bytes: usize,
    pub steps_seen: u64,
}

/// The multiplexer: owns every session, routes observations by id.
pub struct MonitorHub {
    sessions: BTreeMap<SessionId, MonitorSession>,
    next_id: u64,
    /// Config-surface record of the requested fan-out width.
    parallelism: Parallelism,
    /// Persistent worker pool for cross-tenant fan-out (diagnosis /
    /// aggregation) — shared with the engines when the daemon wires
    /// everything onto one process-lifetime pool.  Verdicts are
    /// identical to the serial path; only wall-clock changes.
    pool: Arc<Pool>,
}

impl Default for MonitorHub {
    fn default() -> Self {
        MonitorHub {
            sessions: BTreeMap::new(),
            next_id: 0,
            parallelism: Parallelism::Serial,
            pool: Arc::clone(Pool::serial()),
        }
    }
}

impl MonitorHub {
    pub fn new() -> Self {
        Self::default()
    }

    /// A hub whose per-session diagnosis work fans out across `par`
    /// (its own persistent pool).
    pub fn with_parallelism(par: Parallelism) -> Self {
        MonitorHub {
            parallelism: par,
            pool: Pool::new(par),
            ..Self::default()
        }
    }

    /// A hub fanning out across an existing shared pool — the daemon
    /// hands the same pool to the hub and every tenant engine.
    pub fn with_pool(pool: Arc<Pool>) -> Self {
        MonitorHub {
            parallelism: Parallelism::from_threads(pool.lanes()),
            pool,
            ..Self::default()
        }
    }

    pub fn set_parallelism(&mut self, par: Parallelism) {
        self.parallelism = par;
        self.pool = Pool::new(par);
    }

    pub fn parallelism(&self) -> Parallelism {
        self.parallelism
    }

    /// The pool cross-tenant fan-out runs on.
    pub fn pool(&self) -> &Arc<Pool> {
        &self.pool
    }

    /// Map a read-only closure over every session, the indices claimed
    /// across the pool's lanes.  Results keep the deterministic BTreeMap
    /// (registration-id) order regardless of lane count.
    fn par_map<R, F>(&self, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(&MonitorSession) -> R + Sync,
    {
        let sessions: Vec<&MonitorSession> = self.sessions.values().collect();
        if !self.pool.is_parallel() || sessions.len() <= 1 {
            return sessions.into_iter().map(f).collect();
        }
        let mut slots: Vec<Option<R>> =
            (0..sessions.len()).map(|_| None).collect();
        self.pool
            .for_each_mut(&mut slots, |i, slot| *slot = Some(f(sessions[i])));
        slots
            .into_iter()
            .map(|r| r.expect("pool fan-out filled every slot"))
            .collect()
    }

    /// Admit a tenant; `n_layers` sizes its per-layer rolling stats.
    ///
    /// Errors with [`HubError::SessionsExhausted`] once the id space is
    /// used up (`u64::MAX` is reserved) — a typed error the wire layer
    /// maps to a protocol error code rather than a panic.
    pub fn register(
        &mut self,
        name: &str,
        cfg: MonitorConfig,
        n_layers: usize,
    ) -> Result<SessionId, HubError> {
        if self.next_id == u64::MAX {
            return Err(HubError::SessionsExhausted);
        }
        let id = SessionId(self.next_id);
        self.next_id += 1;
        self.sessions.insert(
            id,
            MonitorSession {
                id,
                name: name.to_string(),
                svc: MonitorService::new(cfg, n_layers),
                sketch_bytes: 0,
                archive_bytes: 0,
            },
        );
        Ok(id)
    }

    /// Admit a tenant under a caller-chosen id.  The sharded daemon
    /// allocates session ids itself (per-shard strided counters, so
    /// `id % shards` names the owning shard — DESIGN.md §9) and each
    /// shard's hub records them verbatim.  Rejects an id the hub
    /// already holds or the reserved sentinel; on success the internal
    /// allocator is advanced past `raw` so interleaved `register`
    /// calls cannot collide with it.
    pub fn register_with_id(
        &mut self,
        raw: u64,
        name: &str,
        cfg: MonitorConfig,
        n_layers: usize,
    ) -> Result<SessionId, HubError> {
        if raw == u64::MAX {
            return Err(HubError::SessionsExhausted);
        }
        let id = SessionId(raw);
        if self.sessions.contains_key(&id) {
            return Err(HubError::DuplicateSession(id));
        }
        self.sessions.insert(
            id,
            MonitorSession {
                id,
                name: name.to_string(),
                svc: MonitorService::new(cfg, n_layers),
                sketch_bytes: 0,
                archive_bytes: 0,
            },
        );
        self.next_id = self.next_id.max(raw + 1);
        Ok(id)
    }

    /// Re-admit a snapshotted session under its original id.  Rejects an
    /// id the hub already holds (`DuplicateSession`) or the reserved
    /// sentinel (`SessionsExhausted`); on success the id allocator is
    /// advanced past the restored id so later `register` calls cannot
    /// collide with it.
    pub fn restore_session(
        &mut self,
        st: &SessionState,
    ) -> Result<SessionId, HubError> {
        if st.id == u64::MAX {
            return Err(HubError::SessionsExhausted);
        }
        let id = SessionId(st.id);
        if self.sessions.contains_key(&id) {
            return Err(HubError::DuplicateSession(id));
        }
        self.sessions.insert(
            id,
            MonitorSession {
                id,
                name: st.name.clone(),
                svc: MonitorService::from_state(&st.service),
                sketch_bytes: st.sketch_bytes as usize,
                // Re-reported by the owner (the daemon re-derives it
                // from the restored ring) — not part of SessionState.
                archive_bytes: 0,
            },
        );
        self.next_id = self.next_id.max(st.id + 1);
        Ok(id)
    }

    /// Evict a tenant, returning its final session state.
    pub fn deregister(
        &mut self,
        id: SessionId,
    ) -> Result<MonitorSession, HubError> {
        self.sessions
            .remove(&id)
            .ok_or(HubError::NoSuchSession(id))
    }

    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    pub fn session(
        &self,
        id: SessionId,
    ) -> Result<&MonitorSession, HubError> {
        self.sessions.get(&id).ok_or(HubError::NoSuchSession(id))
    }

    pub fn sessions(&self) -> impl Iterator<Item = &MonitorSession> {
        self.sessions.values()
    }

    /// Route one step's metrics to a tenant.
    pub fn observe(
        &mut self,
        id: SessionId,
        m: &StepMetrics,
    ) -> Result<(), HubError> {
        self.sessions
            .get_mut(&id)
            .ok_or(HubError::NoSuchSession(id))?
            .observe(m);
        Ok(())
    }

    /// Record the tenant's current engine memory (accountant bytes).
    pub fn report_sketch_bytes(
        &mut self,
        id: SessionId,
        bytes: usize,
    ) -> Result<(), HubError> {
        self.sessions
            .get_mut(&id)
            .ok_or(HubError::NoSuchSession(id))?
            .sketch_bytes = bytes;
        Ok(())
    }

    /// Record the tenant's current archive retention (accountant bytes).
    pub fn report_archive_bytes(
        &mut self,
        id: SessionId,
        bytes: usize,
    ) -> Result<(), HubError> {
        self.sessions
            .get_mut(&id)
            .ok_or(HubError::NoSuchSession(id))?
            .archive_bytes = bytes;
        Ok(())
    }

    pub fn diagnose(&self, id: SessionId) -> Result<Diagnosis, HubError> {
        Ok(self.session(id)?.diagnose())
    }

    /// Diagnose every tenant (id, name, diagnosis, healthy) — the
    /// detector pass per session fans out across the hub's worker pool.
    pub fn diagnose_all(&self) -> Vec<(SessionId, String, Diagnosis, bool)> {
        self.par_map(|s| {
            let d = s.diagnose();
            let healthy = d.healthy();
            (s.id, s.name.clone(), d, healthy)
        })
    }

    /// Aggregate diagnosis + memory accounting across tenants; the
    /// per-session detector work runs on the hub's worker pool, the fold
    /// stays on the caller's thread in session order.
    pub fn aggregate(&self) -> HubReport {
        let rows = self.par_map(|s| {
            (
                s.id,
                s.name.clone(),
                s.diagnose(),
                s.monitor_bytes(),
                s.sketch_bytes,
                s.archive_bytes,
                s.steps_seen(),
            )
        });
        let mut report = HubReport {
            sessions: rows.len(),
            ..HubReport::default()
        };
        for (id, name, d, monitor_bytes, sketch_bytes, archive, steps) in rows
        {
            if d.healthy() {
                report.healthy += 1;
            } else {
                report.flagged.push((id, name, d));
            }
            report.monitor_bytes += monitor_bytes;
            report.sketch_bytes += sketch_bytes;
            report.archive_bytes += archive;
            report.steps_seen += steps;
        }
        report
    }

    /// Hub-held monitor bytes across all sessions — grows with tenants,
    /// never with monitoring duration.
    pub fn memory(&self) -> usize {
        self.sessions.values().map(|s| s.monitor_bytes()).sum()
    }

    /// One-shot convenience used by the experiment harnesses: run a
    /// finished history through a throwaway session and return the
    /// diagnosis.
    pub fn diagnose_history(
        cfg: MonitorConfig,
        n_layers: usize,
        history: &[StepMetrics],
    ) -> Diagnosis {
        let mut hub = MonitorHub::new();
        let id = hub
            .register("history", cfg, n_layers)
            .expect("fresh hub cannot be exhausted");
        for m in history {
            hub.observe(id, m).expect("session just registered");
        }
        hub.diagnose(id).expect("session just registered")
    }
}

/// Bridge from engine metrics to the monitor-service metric domain: the
/// per-layer f64 sketch metrics become one `StepMetrics` sample.
pub fn step_metrics(loss: f32, layer_metrics: &[LayerMetrics]) -> StepMetrics {
    StepMetrics {
        loss,
        z_norm: layer_metrics.iter().map(|m| m.z_norm as f32).collect(),
        stable_rank: layer_metrics
            .iter()
            .map(|m| m.stable_rank as f32)
            .collect(),
        y_norm: layer_metrics.iter().map(|m| m.y_norm as f32).collect(),
        x_norm: layer_metrics.iter().map(|m| m.x_norm as f32).collect(),
        ..Default::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn metrics(loss: f32, z: f32, sr: f32, n_layers: usize) -> StepMetrics {
        StepMetrics {
            loss,
            z_norm: vec![z; n_layers],
            stable_rank: vec![sr; n_layers],
            ..Default::default()
        }
    }

    fn cfg() -> MonitorConfig {
        MonitorConfig {
            window: 20,
            collapse_frac: 0.5,
            ..MonitorConfig::for_rank(4)
        }
    }

    #[test]
    fn register_observe_deregister_roundtrip() {
        let mut hub = MonitorHub::new();
        let a = hub.register("a", cfg(), 3).unwrap();
        let b = hub.register("b", cfg(), 3).unwrap();
        assert_ne!(a, b);
        assert_eq!(hub.len(), 2);
        hub.observe(a, &metrics(1.0, 5.0, 8.0, 3)).unwrap();
        assert_eq!(hub.session(a).unwrap().steps_seen(), 1);
        assert_eq!(hub.session(b).unwrap().steps_seen(), 0);
        let gone = hub.deregister(a).unwrap();
        assert_eq!(gone.steps_seen(), 1);
        assert!(hub.observe(a, &metrics(1.0, 5.0, 8.0, 3)).is_err());
        assert_eq!(hub.len(), 1);
    }

    #[test]
    fn sessions_are_independent() {
        let mut hub = MonitorHub::new();
        let good = hub.register("good", cfg(), 4).unwrap();
        let bad = hub.register("bad", cfg(), 4).unwrap();
        for step in 0..120 {
            let loss = 2.3 * (-0.03 * step as f32).exp() + 0.05;
            hub.observe(good, &metrics(loss, 80.0 + (step % 5) as f32, 8.5, 4))
                .unwrap();
            hub.observe(bad, &metrics(2.3, 9.0, 1.2, 4)).unwrap();
        }
        let report = hub.aggregate();
        assert_eq!(report.sessions, 2);
        assert_eq!(report.healthy, 1);
        assert_eq!(report.flagged.len(), 1);
        assert_eq!(report.flagged[0].1, "bad");
        assert!(report.flagged[0].2.diversity_collapse);
        assert_eq!(report.steps_seen, 240);
    }

    #[test]
    fn hub_memory_scales_with_tenants_not_duration() {
        let mut hub = MonitorHub::new();
        let a = hub.register("a", cfg(), 8).unwrap();
        let m1 = hub.memory();
        let _b = hub.register("b", cfg(), 8).unwrap();
        assert_eq!(hub.memory(), 2 * m1);
        for _ in 0..5_000 {
            hub.observe(a, &metrics(1.0, 1.0, 1.0, 8)).unwrap();
        }
        assert_eq!(hub.memory(), 2 * m1, "duration must not grow memory");
    }

    #[test]
    fn parallel_diagnosis_matches_serial() {
        // Identical tenant histories through a serial and a 4-worker hub:
        // every verdict, order and aggregate must match exactly.
        let mut serial = MonitorHub::new();
        let mut par = MonitorHub::with_parallelism(Parallelism::Threads(4));
        for hub in [&mut serial, &mut par] {
            let mut ids = Vec::new();
            for i in 0..6 {
                ids.push(hub.register(&format!("t{i}"), cfg(), 3).unwrap());
            }
            for step in 0..120 {
                for (i, &id) in ids.iter().enumerate() {
                    // Alternate healthy / collapsed tenants.
                    let m = if i % 2 == 0 {
                        metrics(
                            2.3 * (-0.03 * step as f32).exp(),
                            80.0 + (step % 5) as f32,
                            8.5,
                            3,
                        )
                    } else {
                        metrics(2.3, 9.0, 1.2, 3)
                    };
                    hub.observe(id, &m).unwrap();
                }
            }
        }
        let (a, b) = (serial.diagnose_all(), par.diagnose_all());
        assert_eq!(a.len(), b.len());
        for ((ia, na, da, ha), (ib, nb, db, hb)) in a.iter().zip(&b) {
            assert_eq!((ia, na, ha), (ib, nb, hb));
            assert_eq!(da, db);
        }
        let (ra, rb) = (serial.aggregate(), par.aggregate());
        assert_eq!(ra.healthy, rb.healthy);
        assert_eq!(ra.flagged.len(), rb.flagged.len());
        assert_eq!(ra.monitor_bytes, rb.monitor_bytes);
        assert_eq!(ra.steps_seen, rb.steps_seen);
        assert_eq!(ra.healthy, 3);
    }

    #[test]
    fn typed_errors_for_missing_duplicate_and_exhausted_sessions() {
        let mut hub = MonitorHub::new();
        let ghost = SessionId::from_raw(99);
        assert_eq!(
            hub.observe(ghost, &metrics(1.0, 1.0, 1.0, 2)),
            Err(HubError::NoSuchSession(ghost))
        );
        assert_eq!(
            hub.diagnose(ghost).unwrap_err(),
            HubError::NoSuchSession(ghost)
        );
        // (`unwrap_err` would need `MonitorSession: Debug`; go via `err`.)
        assert_eq!(
            hub.deregister(ghost).err(),
            Some(HubError::NoSuchSession(ghost))
        );

        let a = hub.register("a", cfg(), 2).unwrap();
        let st = hub.session(a).unwrap().state();
        assert_eq!(
            hub.restore_session(&st).unwrap_err(),
            HubError::DuplicateSession(a)
        );

        // The reserved sentinel id is rejected, and restoring the largest
        // valid id exhausts the allocator for subsequent registers.
        let mut tail = st.clone();
        tail.id = u64::MAX;
        assert_eq!(
            hub.restore_session(&tail).unwrap_err(),
            HubError::SessionsExhausted
        );
        tail.id = u64::MAX - 1;
        hub.restore_session(&tail).unwrap();
        assert_eq!(
            hub.register("overflow", cfg(), 2).unwrap_err(),
            HubError::SessionsExhausted
        );
    }

    #[test]
    fn restore_session_resumes_detector_state() {
        let mut hub = MonitorHub::new();
        let a = hub.register("a", cfg(), 3).unwrap();
        for _ in 0..60 {
            hub.observe(a, &metrics(2.3, 9.0, 1.2, 3)).unwrap();
        }
        hub.report_sketch_bytes(a, 4096).unwrap();
        let st = hub.session(a).unwrap().state();

        let mut fresh = MonitorHub::new();
        let rid = fresh.restore_session(&st).unwrap();
        assert_eq!(rid, a);
        let (orig, back) =
            (hub.session(a).unwrap(), fresh.session(rid).unwrap());
        assert_eq!(back.steps_seen(), orig.steps_seen());
        assert_eq!(back.diagnose(), orig.diagnose());
        assert_eq!(back.sketch_bytes, 4096);
        assert_eq!(back.name, "a");
        // The allocator skips past the restored id.
        let next = fresh.register("next", cfg(), 3).unwrap();
        assert!(next.raw() > rid.raw());
    }

    #[test]
    fn sketch_bytes_reporting_aggregates() {
        let mut hub = MonitorHub::new();
        let a = hub.register("a", cfg(), 2).unwrap();
        let b = hub.register("b", cfg(), 2).unwrap();
        hub.report_sketch_bytes(a, 1000).unwrap();
        hub.report_sketch_bytes(b, 500).unwrap();
        hub.report_archive_bytes(a, 300).unwrap();
        hub.report_archive_bytes(b, 200).unwrap();
        let report = hub.aggregate();
        assert_eq!(report.sketch_bytes, 1500);
        assert_eq!(report.archive_bytes, 500);
        assert_eq!(
            hub.report_archive_bytes(SessionId::from_raw(42), 1),
            Err(HubError::NoSuchSession(SessionId::from_raw(42)))
        );
    }

    #[test]
    fn step_metrics_bridge_maps_layers() {
        let lm = vec![
            LayerMetrics {
                z_norm: 2.0,
                stable_rank: 3.0,
                y_norm: 4.0,
                x_norm: 5.0,
            };
            3
        ];
        let m = step_metrics(0.5, &lm);
        assert_eq!(m.loss, 0.5);
        assert_eq!(m.z_norm, vec![2.0f32; 3]);
        assert_eq!(m.stable_rank, vec![3.0f32; 3]);
    }
}
