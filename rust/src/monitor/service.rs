//! Gradient-monitor service (paper §4.6 / §5.3): consumes per-step sketch
//! metrics, maintains constant-memory history, and runs the pathology
//! detectors that distinguish the Fig-5 "healthy" and "problematic" runs.
//!
//! Memory story (the paper's headline): the service holds ONE set of EMA
//! sketch metrics + bounded summaries regardless of monitoring duration T,
//! versus the traditional baseline's O(L * d^2 * T) gradient checkpoints
//! (`baselines::full_monitor`).

use crate::coordinator::StepMetrics;

/// Rolling scalar summary (constant memory per metric stream).
#[derive(Clone, Debug, Default)]
pub struct Rolling {
    pub n: u64,
    pub mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
    pub last: f64,
}

impl Rolling {
    pub fn push(&mut self, x: f64) {
        if self.n == 0 {
            self.min = x;
            self.max = x;
        }
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        self.last = x;
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    /// Full internal state (including the private Welford `m2`) as plain
    /// data, so snapshots restore bit-for-bit.
    pub fn state(&self) -> RollingState {
        RollingState {
            n: self.n,
            mean: self.mean,
            m2: self.m2,
            min: self.min,
            max: self.max,
            last: self.last,
        }
    }

    pub fn from_state(st: &RollingState) -> Rolling {
        Rolling {
            n: st.n,
            mean: st.mean,
            m2: st.m2,
            min: st.min,
            max: st.max,
            last: st.last,
        }
    }
}

/// Plain-data image of a [`Rolling`] summary (snapshot hook).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RollingState {
    pub n: u64,
    pub mean: f64,
    pub m2: f64,
    pub min: f64,
    pub max: f64,
    pub last: f64,
}

/// Detector verdicts over a monitoring window.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Diagnosis {
    /// Gradient norms collapsing toward zero across layers.
    pub vanishing_gradients: bool,
    /// Gradient norms exploding (rapid exponential growth).
    pub exploding_gradients: bool,
    /// Loss not improving while gradients stay flat: optimizer stagnation.
    pub stagnation: bool,
    /// Stable rank far below sketch capacity: collapsed gradient diversity
    /// (the paper's most discriminative signal, §5.3).
    pub diversity_collapse: bool,
    /// Mean stable rank over the window, normalised by k.
    pub mean_stable_rank_frac: f64,
    pub notes: Vec<String>,
}

impl Diagnosis {
    /// "Healthy" = no hard pathologies flagged (stagnation alone is a
    /// warning, not a failure — only combined with collapsed diversity
    /// does it indicate a dead run).
    pub fn healthy(&self) -> bool {
        !(self.vanishing_gradients
            || self.exploding_gradients
            || (self.stagnation && self.diversity_collapse))
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct MonitorConfig {
    /// Sketch dimension k = 2r + 1 (for stable-rank normalisation).
    pub k: usize,
    /// Steps per diagnostic evaluation window.
    pub window: usize,
    /// ||Z|| ratio (first->last window) below which gradients "vanish".
    pub vanish_ratio: f64,
    /// ||Z|| growth ratio above which gradients "explode".
    pub explode_ratio: f64,
    /// Relative loss improvement below which the run is stagnant.
    pub stagnation_eps: f64,
    /// stable_rank / k below which diversity has collapsed.
    pub collapse_frac: f64,
}

impl MonitorConfig {
    pub fn for_rank(r: usize) -> Self {
        MonitorConfig {
            k: 2 * r + 1,
            window: 50,
            vanish_ratio: 1e-3,
            explode_ratio: 1e3,
            stagnation_eps: 2e-2,
            // The paper reports stable rank ~9/9 (healthy) vs 2.9/9
            // (problematic).  On our substrate tanh/relu activations are
            // more correlated, compressing both scales (healthy ~0.13k,
            // collapsed <0.01k); 0.1 separates them with margin either way.
            collapse_frac: 0.1,
        }
    }
}

/// Plain-data image of a [`MonitorService`] (snapshot hook; the serve
/// subsystem's codec turns this into wire/disk bytes).
#[derive(Clone, Debug)]
pub struct ServiceState {
    pub cfg: MonitorConfig,
    pub loss: RollingState,
    pub z_norm: Vec<RollingState>,
    pub stable_rank: Vec<RollingState>,
    /// Recent-window ring buffer entries: (loss, z_norms, sranks).
    pub recent: Vec<(f64, Vec<f64>, Vec<f64>)>,
    pub head: u64,
    pub steps_seen: u64,
    pub first_window_z: Option<f64>,
    pub window_start_loss: Option<f64>,
}

/// The monitor: constant-memory summaries + a bounded recent window.
pub struct MonitorService {
    pub cfg: MonitorConfig,
    pub loss: Rolling,
    /// Per-layer rolling ||Z||_F.
    pub z_norm: Vec<Rolling>,
    pub stable_rank: Vec<Rolling>,
    /// Recent window ring buffer (bounded at cfg.window entries).
    recent: Vec<(f64, Vec<f64>, Vec<f64>)>, // (loss, z_norms, sranks)
    head: usize,
    pub steps_seen: u64,
    first_window_z: Option<f64>,
    window_start_loss: Option<f64>,
}

impl MonitorService {
    pub fn new(cfg: MonitorConfig, n_layers: usize) -> Self {
        MonitorService {
            cfg,
            loss: Rolling::default(),
            z_norm: vec![Rolling::default(); n_layers],
            stable_rank: vec![Rolling::default(); n_layers],
            recent: Vec::new(),
            head: 0,
            steps_seen: 0,
            first_window_z: None,
            window_start_loss: None,
        }
    }

    pub fn observe(&mut self, m: &StepMetrics) {
        self.steps_seen += 1;
        self.loss.push(m.loss as f64);
        for (i, &z) in m.z_norm.iter().enumerate() {
            if i < self.z_norm.len() {
                self.z_norm[i].push(z as f64);
            }
        }
        for (i, &s) in m.stable_rank.iter().enumerate() {
            if i < self.stable_rank.len() {
                self.stable_rank[i].push(s as f64);
            }
        }
        let entry = (
            m.loss as f64,
            m.z_norm.iter().map(|&v| v as f64).collect(),
            m.stable_rank.iter().map(|&v| v as f64).collect(),
        );
        if self.recent.len() < self.cfg.window {
            self.recent.push(entry);
        } else {
            self.recent[self.head] = entry;
            self.head = (self.head + 1) % self.cfg.window;
        }
        if self.steps_seen == self.cfg.window as u64 {
            self.first_window_z = Some(self.mean_recent_z());
            self.window_start_loss = Some(self.loss.mean);
        }
    }

    fn mean_recent_z(&self) -> f64 {
        let mut sum = 0.0;
        let mut n = 0usize;
        for (_, zs, _) in &self.recent {
            for z in zs {
                sum += z;
                n += 1;
            }
        }
        sum / n.max(1) as f64
    }

    fn mean_recent_srank(&self) -> f64 {
        let mut sum = 0.0;
        let mut n = 0usize;
        for (_, _, ss) in &self.recent {
            for s in ss {
                sum += s;
                n += 1;
            }
        }
        sum / n.max(1) as f64
    }

    fn mean_recent_loss(&self) -> f64 {
        let s: f64 = self.recent.iter().map(|(l, _, _)| l).sum();
        s / self.recent.len().max(1) as f64
    }

    /// Run the pathology detectors over everything observed so far.
    pub fn diagnose(&self) -> Diagnosis {
        let mut d = Diagnosis::default();
        if self.steps_seen < (2 * self.cfg.window) as u64 {
            d.notes.push("window too short for diagnosis".into());
            return d;
        }
        let z_now = self.mean_recent_z();
        let z_first = self.first_window_z.unwrap_or(z_now);
        if z_first > 0.0 && z_now / z_first < self.cfg.vanish_ratio {
            d.vanishing_gradients = true;
            d.notes
                .push(format!("||Z|| ratio {:.2e}", z_now / z_first));
        }
        if z_first > 0.0 && z_now / z_first > self.cfg.explode_ratio {
            d.exploding_gradients = true;
            d.notes
                .push(format!("||Z|| ratio {:.2e}", z_now / z_first));
        }
        let loss_then = self.window_start_loss.unwrap_or(self.loss.mean);
        let loss_now = self.mean_recent_loss();
        if loss_then > 0.0
            && (loss_then - loss_now) / loss_then < self.cfg.stagnation_eps
        {
            d.stagnation = true;
            d.notes.push(format!(
                "loss {:.4} -> {:.4} (rel impr {:.3})",
                loss_then,
                loss_now,
                (loss_then - loss_now) / loss_then
            ));
        }
        let sr = self.mean_recent_srank();
        d.mean_stable_rank_frac = sr / self.cfg.k as f64;
        if d.mean_stable_rank_frac < self.cfg.collapse_frac {
            d.diversity_collapse = true;
            d.notes.push(format!(
                "stable rank {:.2} of k={} ({:.0}%)",
                sr,
                self.cfg.k,
                100.0 * d.mean_stable_rank_frac
            ));
        }
        d
    }

    /// "Healthy" = no pathologies flagged (see [`Diagnosis::healthy`]).
    pub fn is_healthy(&self) -> bool {
        self.diagnose().healthy()
    }

    /// Full detector state as plain data ([`ServiceState`]): rolling
    /// summaries, the bounded recent window (ring buffer + head) and the
    /// first-window baselines — everything `diagnose` reads, so a
    /// restored service diagnoses identically.
    pub fn state(&self) -> ServiceState {
        ServiceState {
            cfg: self.cfg.clone(),
            loss: self.loss.state(),
            z_norm: self.z_norm.iter().map(Rolling::state).collect(),
            stable_rank: self.stable_rank.iter().map(Rolling::state).collect(),
            recent: self.recent.clone(),
            head: self.head as u64,
            steps_seen: self.steps_seen,
            first_window_z: self.first_window_z,
            window_start_loss: self.window_start_loss,
        }
    }

    pub fn from_state(st: &ServiceState) -> MonitorService {
        MonitorService {
            cfg: st.cfg.clone(),
            loss: Rolling::from_state(&st.loss),
            z_norm: st.z_norm.iter().map(Rolling::from_state).collect(),
            stable_rank: st
                .stable_rank
                .iter()
                .map(Rolling::from_state)
                .collect(),
            recent: st.recent.clone(),
            head: st.head as usize,
            steps_seen: st.steps_seen,
            first_window_z: st.first_window_z,
            window_start_loss: st.window_start_loss,
        }
    }

    /// Bytes held by the monitor — constant in monitoring duration
    /// (the paper's key claim: no T factor).
    pub fn monitor_bytes(&self) -> usize {
        let rolling = std::mem::size_of::<Rolling>();
        let per_layer = (self.z_norm.len() + self.stable_rank.len()) * rolling;
        let window_entry = 8 + self.z_norm.len() * 8 * 2;
        per_layer + rolling + self.cfg.window * window_entry
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn metrics(loss: f32, z: f32, sr: f32, n_layers: usize) -> StepMetrics {
        StepMetrics {
            loss,
            z_norm: vec![z; n_layers],
            stable_rank: vec![sr; n_layers],
            ..Default::default()
        }
    }

    #[test]
    fn rolling_stats() {
        let mut r = Rolling::default();
        for x in [1.0, 2.0, 3.0, 4.0] {
            r.push(x);
        }
        assert_eq!(r.mean, 2.5);
        assert!((r.var() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!((r.min, r.max, r.last), (1.0, 4.0, 4.0));
    }

    #[test]
    fn healthy_run_is_clean() {
        let cfg = MonitorConfig {
            collapse_frac: 0.5,
            ..MonitorConfig::for_rank(4)
        };
        let mut svc = MonitorService::new(cfg, 15);
        for step in 0..300 {
            // Loss decays, gradients lively, stable rank near k.
            let loss = 2.3 * (-0.01 * step as f32).exp() + 0.1;
            svc.observe(&metrics(loss, 100.0 + (step % 7) as f32, 8.7, 15));
        }
        let d = svc.diagnose();
        assert!(!d.vanishing_gradients);
        assert!(!d.diversity_collapse, "{d:?}");
        assert!(!d.stagnation, "{d:?}");
        assert!(svc.is_healthy());
    }

    #[test]
    fn problematic_run_is_flagged() {
        // Paper-scale stable ranks (2.9 of k=9): use the paper's 0.5
        // threshold for this synthetic trace.
        let cfg = MonitorConfig {
            collapse_frac: 0.5,
            ..MonitorConfig::for_rank(4)
        };
        let mut svc = MonitorService::new(cfg, 15);
        for step in 0..300 {
            // Flat loss, flat small gradients, collapsed stable rank.
            let _ = step;
            svc.observe(&metrics(2.30, 10.0, 2.9, 15));
        }
        let d = svc.diagnose();
        assert!(d.stagnation, "{d:?}");
        assert!(d.diversity_collapse, "{d:?}");
        assert!(!svc.is_healthy());
        assert!((d.mean_stable_rank_frac - 2.9 / 9.0).abs() < 1e-6);
    }

    #[test]
    fn vanishing_gradients_detected() {
        let cfg = MonitorConfig::for_rank(4);
        let mut svc = MonitorService::new(cfg, 4);
        for step in 0..400 {
            let z = 100.0 * (-0.05 * step as f32).exp();
            svc.observe(&metrics(2.3, z, 8.0, 4));
        }
        assert!(svc.diagnose().vanishing_gradients);
    }

    #[test]
    fn service_state_roundtrip_preserves_diagnosis() {
        let cfg = MonitorConfig {
            window: 10,
            collapse_frac: 0.5,
            ..MonitorConfig::for_rank(4)
        };
        let mut svc = MonitorService::new(cfg, 3);
        for step in 0..35 {
            // Past the window boundary so the ring buffer has wrapped and
            // the first-window baselines are set.
            svc.observe(&metrics(2.3, 10.0 + step as f32, 2.9, 3));
        }
        let st = svc.state();
        assert_eq!(st.steps_seen, 35);
        assert_eq!(st.recent.len(), 10);
        let mut back = MonitorService::from_state(&st);
        assert_eq!(back.diagnose(), svc.diagnose());
        assert_eq!(back.monitor_bytes(), svc.monitor_bytes());
        assert_eq!(back.loss.var(), svc.loss.var());
        // Continued observation behaves identically (same ring head).
        svc.observe(&metrics(1.0, 50.0, 8.0, 3));
        back.observe(&metrics(1.0, 50.0, 8.0, 3));
        assert_eq!(back.diagnose(), svc.diagnose());
    }

    #[test]
    fn monitor_memory_is_constant_in_duration() {
        let cfg = MonitorConfig::for_rank(4);
        let mut svc = MonitorService::new(cfg, 15);
        svc.observe(&metrics(1.0, 1.0, 1.0, 15));
        let b0 = svc.monitor_bytes();
        for _ in 0..10_000 {
            svc.observe(&metrics(1.0, 1.0, 1.0, 15));
        }
        assert_eq!(svc.monitor_bytes(), b0, "memory must not grow with T");
    }
}
