//! # sketchgrad
//!
//! Production-grade reproduction of *"Randomized Matrix Sketching for
//! Neural Network Training and Gradient Monitoring"* (Antil & Verma 2025)
//! as a three-layer rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — coordinator: config, launcher, data pipeline,
//!   training orchestrator, Algorithm-1 adaptive-rank controller, the
//!   sketch-based gradient-monitor service, baselines and the memory
//!   accountant.  Owns the event loop and all experiment harnesses.
//! * **L2 (python/compile, build-time only)** — JAX model fwd/bwd with the
//!   paper's sketched backpropagation, AOT-lowered to HLO text consumed by
//!   the [`runtime`] PJRT client.  Python never runs at request time.
//! * **L1 (python/compile/kernels)** — Pallas kernels for the EMA sketch
//!   update and gradient assembly hot-spots, lowered into the same HLO.
//!
//! See DESIGN.md for the full system inventory and experiment index.

pub mod archive;
pub mod baselines;
pub mod benchkit;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod loadgen;
pub mod memory;
pub mod monitor;
pub mod pinn;
pub mod runtime;
pub mod serve;
pub mod sketch;
pub mod util;
