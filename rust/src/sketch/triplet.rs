//! The EMA three-sketch triplet (paper §4.1, Eqs. 5a-5c) and its shared
//! projections — the native-rust mirror of `python/compile/sketching.py`.
//!
//! One `SketchTriplet` holds the (X, Y, Z) sketches for a single hidden
//! layer; `LayerSketches` stacks them for a network.  The monitor service
//! updates these from activation batches without any PJRT round-trip, and
//! the adaptive-rank controller reads reconstruction diagnostics from them.

use crate::util::rng::Rng;

use super::matrix::Mat;

/// Shared batch projections (Upsilon, Omega, Phi) + per-layer Psi weights.
#[derive(Clone, Debug)]
pub struct Projections {
    pub upsilon: Mat, // (n_b, k)
    pub omega: Mat,   // (n_b, k)
    pub phi: Mat,     // (n_b, s)
    pub psi: Vec<Vec<f64>>, // per layer, length s
    pub rank: usize,
}

impl Projections {
    /// k = s = 2r + 1 (paper §4.1).
    pub fn sample(n_b: usize, n_layers: usize, rank: usize, rng: &mut Rng) -> Self {
        let k = 2 * rank + 1;
        Projections {
            upsilon: Mat::gaussian(n_b, k, rng),
            omega: Mat::gaussian(n_b, k, rng),
            phi: Mat::gaussian(n_b, k, rng),
            psi: (0..n_layers).map(|_| rng.normal_vec(k)).collect(),
            rank,
        }
    }

    pub fn k(&self) -> usize {
        2 * self.rank + 1
    }
}

/// (X, Y, Z) EMA sketches for one hidden layer (each d x k).
#[derive(Clone, Debug)]
pub struct SketchTriplet {
    pub x: Mat,
    pub y: Mat,
    pub z: Mat,
    pub beta: f64,
    /// Number of EMA updates applied (for bias diagnostics: the implicit
    /// EMA weight mass is 1 - beta^n).
    pub updates: usize,
}

impl SketchTriplet {
    pub fn zeros(d: usize, rank: usize, beta: f64) -> Self {
        let k = 2 * rank + 1;
        SketchTriplet {
            x: Mat::zeros(d, k),
            y: Mat::zeros(d, k),
            z: Mat::zeros(d, k),
            beta,
            updates: 0,
        }
    }

    /// Eqs. 5a-5c: fused one-pass EMA update from a batch.
    ///
    /// `a_in`  (n_b, d): activations entering the layer's weight (A^[l-1])
    /// `a_out` (n_b, d): activations leaving the nonlinearity (A^[l])
    pub fn update(
        &mut self,
        a_in: &Mat,
        a_out: &Mat,
        proj: &Projections,
        layer: usize,
    ) {
        let beta = self.beta;
        let contrib_x = a_in.t_matmul(&proj.upsilon);
        self.x.ema_blend(&contrib_x, beta);
        let contrib_y = a_out.t_matmul(&proj.omega);
        self.y.ema_blend(&contrib_y, beta);
        let contrib_z = a_out
            .t_matmul(&proj.phi)
            .scale_cols(&proj.psi[layer]);
        self.z.ema_blend(&contrib_z, beta);
        self.updates += 1;
    }

    /// Runtime bytes of the triplet at f32 (memory accountant unit).
    pub fn runtime_bytes(&self) -> usize {
        self.x.runtime_bytes() + self.y.runtime_bytes() + self.z.runtime_bytes()
    }
}

/// Stacked triplets for all hidden layers of one network.
#[derive(Clone, Debug)]
pub struct LayerSketches {
    pub layers: Vec<SketchTriplet>,
    pub proj: Projections,
}

impl LayerSketches {
    pub fn new(
        n_layers: usize,
        d_hidden: usize,
        n_b: usize,
        rank: usize,
        beta: f64,
        rng: &mut Rng,
    ) -> Self {
        LayerSketches {
            layers: (0..n_layers)
                .map(|_| SketchTriplet::zeros(d_hidden, rank, beta))
                .collect(),
            proj: Projections::sample(n_b, n_layers, rank, rng),
        }
    }

    /// Update every layer's triplet from the forward activations
    /// `acts[j] = A^[j]` (acts[0] = input batch), matching the python
    /// indexing: triplet j-1 takes a_in = A^[j-1] for j >= 2 and A^[1]
    /// itself for j = 1.
    pub fn update_from_acts(&mut self, acts: &[Mat]) {
        let n_hidden = acts.len() - 1;
        assert_eq!(n_hidden, self.layers.len());
        for j in 1..=n_hidden {
            let a_in = if j >= 2 { &acts[j - 1] } else { &acts[1] };
            // Split borrow: triplet j-1 vs shared projections.
            let proj = &self.proj;
            self.layers[j - 1].update_ref(a_in, &acts[j], proj, j - 1);
        }
    }

    /// Rank change (Algorithm 1 lines 16/21/23): reinitialise projections
    /// and zero sketches with new k = s = 2r + 1.
    pub fn reinitialize(&mut self, rank: usize, n_b: usize, rng: &mut Rng) {
        let n_layers = self.layers.len();
        let d = self.layers[0].x.rows;
        let beta = self.layers[0].beta;
        self.proj = Projections::sample(n_b, n_layers, rank, rng);
        for t in &mut self.layers {
            *t = SketchTriplet::zeros(d, rank, beta);
        }
    }

    pub fn runtime_bytes(&self) -> usize {
        let sketches: usize =
            self.layers.iter().map(|t| t.runtime_bytes()).sum();
        let proj = self.proj.upsilon.runtime_bytes()
            + self.proj.omega.runtime_bytes()
            + self.proj.phi.runtime_bytes()
            + self.proj.psi.iter().map(|p| p.len() * 4).sum::<usize>();
        sketches + proj
    }
}

impl SketchTriplet {
    /// Borrow-friendly variant of `update` used by `LayerSketches`.
    fn update_ref(
        &mut self,
        a_in: &Mat,
        a_out: &Mat,
        proj: &Projections,
        layer: usize,
    ) {
        self.update(a_in, a_out, proj, layer);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Prop;

    #[test]
    fn ema_expansion_lemma_4_1() {
        // Lemma 4.1: X_n = (1-beta) sum_j beta^{n-j} A_j^T Upsilon.
        Prop::new(16).check("lemma41", |rng, i| {
            let (n_b, d, rank) = (8, 12, 1 + i % 3);
            let beta = 0.8;
            let proj = Projections::sample(n_b, 1, rank, rng);
            let mut t = SketchTriplet::zeros(d, rank, beta);
            let batches: Vec<Mat> =
                (0..5).map(|_| Mat::gaussian(n_b, d, rng)).collect();
            for a in &batches {
                t.update(a, a, &proj, 0);
            }
            // Explicit expansion.
            let n = batches.len();
            let mut want = Mat::zeros(d, proj.k());
            for (j, a) in batches.iter().enumerate() {
                let w = (1.0 - beta) * beta.powi((n - 1 - j) as i32);
                want = want.add(&a.t_matmul(&proj.upsilon).scale(w));
            }
            if t.x.max_abs_diff(&want) > 1e-10 {
                return Err(format!("diff {}", t.x.max_abs_diff(&want)));
            }
            Ok(())
        });
    }

    #[test]
    fn z_sketch_psi_scaling() {
        let mut rng = Rng::new(5);
        let proj = Projections::sample(6, 1, 2, &mut rng);
        let mut t = SketchTriplet::zeros(10, 2, 0.0); // beta=0: pure batch
        let a = Mat::gaussian(6, 10, &mut rng);
        t.update(&a, &a, &proj, 0);
        let want = a.t_matmul(&proj.phi).scale_cols(&proj.psi[0]);
        assert!(t.z.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn reinitialize_changes_dims_and_zeroes() {
        let mut rng = Rng::new(6);
        let mut ls = LayerSketches::new(3, 16, 8, 2, 0.9, &mut rng);
        let acts: Vec<Mat> =
            (0..4).map(|_| Mat::gaussian(8, 16, &mut rng)).collect();
        ls.update_from_acts(&acts);
        assert!(ls.layers[0].x.fro_norm() > 0.0);
        ls.reinitialize(4, 8, &mut rng);
        assert_eq!(ls.proj.k(), 9);
        assert_eq!(ls.layers[0].x.cols, 9);
        assert_eq!(ls.layers[0].x.fro_norm(), 0.0);
    }

    #[test]
    fn runtime_bytes_formula() {
        let mut rng = Rng::new(7);
        let ls = LayerSketches::new(2, 32, 16, 2, 0.9, &mut rng);
        // 2 layers * 3 sketches * 32*5 floats * 4B
        let sketch_bytes = 2 * 3 * 32 * 5 * 4;
        assert!(ls.runtime_bytes() >= sketch_bytes);
    }
}
