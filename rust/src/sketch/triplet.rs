//! The EMA three-sketch triplet (paper §4.1, Eqs. 5a-5c) and its shared
//! projections — the native-rust mirror of `python/compile/sketching.py`.
//!
//! One `SketchTriplet` holds the (X, Y, Z) sketches for a single hidden
//! layer.  Stacking triplets for a whole network, sampling projections per
//! observed batch size and rank changes live in [`super::engine`]: all
//! call sites outside the sketch module go through
//! `SketchConfigBuilder`/`SketchEngine` rather than assembling these
//! low-level pieces by hand.

use std::sync::Arc;

use crate::util::rng::Rng;

use super::kernel::{self, Pool};
use super::matrix::Mat;

/// Shared batch projections (Upsilon, Omega, Phi) + per-layer Psi weights.
///
/// Upsilon/Omega/Phi are tied to one batch size `n_b`; Psi is batch-size
/// independent (length k per layer) and is shared — one `Arc` allocation —
/// by every projection set the engine samples, so cloning a `Projections`
/// never duplicates the Psi storage.
#[derive(Clone, Debug)]
pub struct Projections {
    pub upsilon: Mat, // (n_b, k)
    pub omega: Mat,   // (n_b, k)
    pub phi: Mat,     // (n_b, s)
    pub psi: Arc<Vec<Vec<f64>>>, // per layer, length s
    pub rank: usize,
}

impl Projections {
    /// k = s = 2r + 1 (paper §4.1).
    pub fn sample(n_b: usize, n_layers: usize, rank: usize, rng: &mut Rng) -> Self {
        let k = 2 * rank + 1;
        let psi = Arc::new(
            (0..n_layers)
                .map(|_| rng.normal_vec(k))
                .collect::<Vec<_>>(),
        );
        Self::with_psi(n_b, rank, psi, rng)
    }

    /// Sample fresh batch projections around an existing Psi — the engine
    /// uses this so every batch size shares one set of Psi weights (the
    /// EMA triplets must see a consistent Z-weighting across batches).
    pub fn with_psi(
        n_b: usize,
        rank: usize,
        psi: Arc<Vec<Vec<f64>>>,
        rng: &mut Rng,
    ) -> Self {
        let k = 2 * rank + 1;
        Projections {
            upsilon: Mat::gaussian(n_b, k, rng),
            omega: Mat::gaussian(n_b, k, rng),
            phi: Mat::gaussian(n_b, k, rng),
            psi,
            rank,
        }
    }

    pub fn k(&self) -> usize {
        2 * self.rank + 1
    }

    /// Batch size these projections were sampled for.
    pub fn n_b(&self) -> usize {
        self.upsilon.rows
    }

    /// Accountant bytes for the batch projections at `unit` bytes per
    /// element, EXCLUDING Psi (the engine counts the shared Psi once,
    /// not per cached batch size).
    pub fn batch_bytes(&self, unit: usize) -> usize {
        3 * self.upsilon.rows * self.upsilon.cols * unit
    }

    /// Bytes of the Psi weights as stored: f64, 8 bytes per element.
    /// The `Arc` means every projection set sharing this Psi holds the
    /// same single allocation — count it once.
    pub fn psi_bytes(&self) -> usize {
        self.psi.iter().map(|p| p.len() * 8).sum()
    }
}

/// (X, Y, Z) EMA sketches for one hidden layer.
///
/// X sketches the layer's *incoming* activation (d_in x k) while Y and Z
/// sketch the *outgoing* activation (d_out x k); for uniform-width
/// networks d_in == d_out and the seed behaviour is recovered.
#[derive(Clone, Debug)]
pub struct SketchTriplet {
    pub x: Mat,
    pub y: Mat,
    pub z: Mat,
    pub beta: f64,
    /// Number of EMA updates applied (for bias diagnostics: the implicit
    /// EMA weight mass is 1 - beta^n).
    pub updates: usize,
}

impl SketchTriplet {
    /// Heterogeneous-width constructor: X is (d_in, k), Y/Z are (d_out, k).
    pub fn with_dims(d_in: usize, d_out: usize, rank: usize, beta: f64) -> Self {
        let k = 2 * rank + 1;
        SketchTriplet {
            x: Mat::zeros(d_in, k),
            y: Mat::zeros(d_out, k),
            z: Mat::zeros(d_out, k),
            beta,
            updates: 0,
        }
    }

    /// Uniform-width convenience (d_in == d_out == d).
    pub fn zeros(d: usize, rank: usize, beta: f64) -> Self {
        Self::with_dims(d, d, rank, beta)
    }

    /// Eqs. 5a-5c: fused one-pass EMA update from a batch.
    ///
    /// `a_in`  (n_b, d_in):  activations entering the layer's weight (A^[l-1])
    /// `a_out` (n_b, d_out): activations leaving the nonlinearity (A^[l])
    pub fn update(
        &mut self,
        a_in: &Mat,
        a_out: &Mat,
        proj: &Projections,
        layer: usize,
    ) {
        self.update_with(a_in, a_out, proj, layer, Pool::serial());
    }

    /// [`SketchTriplet::update`] with the three projection products fused
    /// into the resident X/Y/Z sketches ([`kernel::t_matmul_ema`] /
    /// [`kernel::t_matmul_ema_scaled`]) on the given worker pool: no
    /// contribution temporaries are ever allocated, and the result is
    /// bitwise identical to the unfused serial form at any lane count
    /// (the kernel determinism contract), so Lemma 4.1 holds unchanged.
    pub fn update_with(
        &mut self,
        a_in: &Mat,
        a_out: &Mat,
        proj: &Projections,
        layer: usize,
        pool: &Pool,
    ) {
        let beta = self.beta;
        kernel::t_matmul_ema(a_in, &proj.upsilon, &mut self.x, beta, pool);
        kernel::t_matmul_ema(a_out, &proj.omega, &mut self.y, beta, pool);
        kernel::t_matmul_ema_scaled(
            a_out,
            &proj.phi,
            &proj.psi[layer],
            &mut self.z,
            beta,
            pool,
        );
        self.updates += 1;
    }

    /// PR3-path reference update: allocating unfused contributions
    /// (`t_matmul` -> `ema_blend`, plus `scale_cols` for Z) through the
    /// spawn-per-call [`kernel::scoped`] kernels.  Kept as the bitwise
    /// equivalence witness for [`SketchTriplet::update_with`] and the
    /// `bench-smoke` perf gate's ingest baseline; not a production path.
    pub fn update_scoped(
        &mut self,
        a_in: &Mat,
        a_out: &Mat,
        proj: &Projections,
        layer: usize,
        threads: usize,
    ) {
        let beta = self.beta;
        let contrib_x = kernel::scoped::t_matmul(a_in, &proj.upsilon, threads);
        self.x.ema_blend(&contrib_x, beta);
        let contrib_y = kernel::scoped::t_matmul(a_out, &proj.omega, threads);
        self.y.ema_blend(&contrib_y, beta);
        let contrib_z = kernel::scoped::t_matmul(a_out, &proj.phi, threads)
            .scale_cols(&proj.psi[layer]);
        self.z.ema_blend(&contrib_z, beta);
        self.updates += 1;
    }

    /// Runtime bytes of the triplet at f32 (memory accountant unit).
    pub fn runtime_bytes(&self) -> usize {
        self.x.runtime_bytes() + self.y.runtime_bytes() + self.z.runtime_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Prop;

    #[test]
    fn ema_expansion_lemma_4_1() {
        // Lemma 4.1: X_n = (1-beta) sum_j beta^{n-j} A_j^T Upsilon.
        Prop::new(16).check("lemma41", |rng, i| {
            let (n_b, d, rank) = (8, 12, 1 + i % 3);
            let beta = 0.8;
            let proj = Projections::sample(n_b, 1, rank, rng);
            let mut t = SketchTriplet::zeros(d, rank, beta);
            let batches: Vec<Mat> =
                (0..5).map(|_| Mat::gaussian(n_b, d, rng)).collect();
            for a in &batches {
                t.update(a, a, &proj, 0);
            }
            // Explicit expansion.
            let n = batches.len();
            let mut want = Mat::zeros(d, proj.k());
            for (j, a) in batches.iter().enumerate() {
                let w = (1.0 - beta) * beta.powi((n - 1 - j) as i32);
                want = want.add(&a.t_matmul(&proj.upsilon).scale(w));
            }
            if t.x.max_abs_diff(&want) > 1e-10 {
                return Err(format!("diff {}", t.x.max_abs_diff(&want)));
            }
            Ok(())
        });
    }

    #[test]
    fn z_sketch_psi_scaling() {
        let mut rng = Rng::new(5);
        let proj = Projections::sample(6, 1, 2, &mut rng);
        let mut t = SketchTriplet::zeros(10, 2, 0.0); // beta=0: pure batch
        let a = Mat::gaussian(6, 10, &mut rng);
        t.update(&a, &a, &proj, 0);
        let want = a.t_matmul(&proj.phi).scale_cols(&proj.psi[0]);
        assert!(t.z.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn with_psi_shares_one_psi_allocation() {
        let mut rng = Rng::new(8);
        let base = Projections::sample(6, 2, 3, &mut rng);
        let other = Projections::with_psi(12, 3, base.psi.clone(), &mut rng);
        assert!(Arc::ptr_eq(&other.psi, &base.psi), "psi must be shared");
        assert_eq!(other.n_b(), 12);
        assert_eq!(other.k(), 7);
    }

    #[test]
    fn heterogeneous_triplet_dims() {
        let t = SketchTriplet::with_dims(64, 32, 2, 0.9);
        assert_eq!((t.x.rows, t.x.cols), (64, 5));
        assert_eq!((t.y.rows, t.y.cols), (32, 5));
        assert_eq!((t.z.rows, t.z.cols), (32, 5));
    }

    #[test]
    fn psi_bytes_counts_f64_storage() {
        // Psi is stored as f64: the accountant must charge 8 B/element
        // (the seed under-counted at 4 B).
        let mut rng = Rng::new(9);
        let proj = Projections::sample(4, 3, 2, &mut rng);
        assert_eq!(proj.psi_bytes(), 3 * 5 * 8);
        assert_eq!(proj.batch_bytes(4), 3 * 4 * 5 * 4);
    }
}
