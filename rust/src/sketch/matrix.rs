//! Dense row-major matrix substrate (f64).
//!
//! This is the rust mirror of `python/compile/linalg.py`: the monitoring
//! hot path, adaptive-rank controller and baselines run the same sketch
//! mathematics natively so diagnostics never require a PJRT round-trip.
//! Integration tests cross-validate this substrate against the AOT
//! artifacts (same inputs -> same sketches/reconstructions to fp tolerance).

use std::fmt;
use std::ops::{Index, IndexMut};

use crate::util::rng::Rng;

use super::kernel::{self, Pool};

#[derive(Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Mat({}x{})", self.rows, self.cols)
    }
}

impl Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f64 {
        &self.data[r * self.cols + c]
    }
}

impl IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f64 {
        &mut self.data[r * self.cols + c]
    }
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Mat {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Mat {
        assert_eq!(data.len(), rows * cols);
        Mat {
            rows,
            cols,
            data: data.iter().map(|&x| x as f64).collect(),
        }
    }

    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }

    /// i.i.d. standard normal entries — the random projections required by
    /// the sketching theory (paper §3.2.1).
    pub fn gaussian(rows: usize, cols: usize, rng: &mut Rng) -> Mat {
        Mat {
            rows,
            cols,
            data: rng.normal_vec(rows * cols),
        }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f64] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out[(c, r)] = self[(r, c)];
            }
        }
        out
    }

    /// `self @ other` — the substrate's workhorse, delegating to the
    /// register-tiled [`kernel`] on the serial path.  Call sites needing
    /// a worker pool for this shape use `kernel::matmul` directly.
    pub fn matmul(&self, other: &Mat) -> Mat {
        kernel::matmul(self, other, Pool::serial())
    }

    /// `self^T @ other` without materialising the transpose (the EMA
    /// sketch update's A^T P shape).
    pub fn t_matmul(&self, other: &Mat) -> Mat {
        kernel::t_matmul(self, other, Pool::serial())
    }

    /// `self^T @ other` on the given worker pool.
    pub fn t_matmul_with(&self, other: &Mat, pool: &Pool) -> Mat {
        kernel::t_matmul(self, other, pool)
    }

    /// `self @ other^T` without materialising the transpose (the
    /// reconstruction's `... Q_X^T` shape).
    pub fn matmul_t(&self, other: &Mat) -> Mat {
        kernel::matmul_t(self, other, Pool::serial())
    }

    /// `self @ other^T` on the given worker pool.
    pub fn matmul_t_with(&self, other: &Mat, pool: &Pool) -> Mat {
        kernel::matmul_t(self, other, pool)
    }

    pub fn scale(&self, s: f64) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| x * s).collect(),
        }
    }

    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a + b)
                .collect(),
        }
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a - b)
                .collect(),
        }
    }

    /// In-place EMA blend: `self = beta*self + (1-beta)*other` — the
    /// allocation-free hot-path form used by the monitor service.
    pub fn ema_blend(&mut self, other: &Mat, beta: f64) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let ob = 1.0 - beta;
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a = beta * *a + ob * *b;
        }
    }

    /// Column-wise scale (the Z-sketch's ⊙ Psi^T).
    pub fn scale_cols(&self, scale: &[f64]) -> Mat {
        assert_eq!(scale.len(), self.cols);
        let mut out = self.clone();
        for r in 0..self.rows {
            for (c, &s) in scale.iter().enumerate() {
                out[(r, c)] *= s;
            }
        }
        out
    }

    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Bytes this matrix occupies at runtime dtype (f32) — the unit the
    /// memory accountant works in.
    pub fn runtime_bytes(&self) -> usize {
        self.rows * self.cols * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(1);
        let a = Mat::gaussian(7, 5, &mut rng);
        let i = Mat::eye(5);
        assert!(a.matmul(&i).max_abs_diff(&a) < 1e-12);
    }

    #[test]
    fn t_matmul_matches_explicit_transpose() {
        let mut rng = Rng::new(2);
        let a = Mat::gaussian(6, 4, &mut rng);
        let b = Mat::gaussian(6, 3, &mut rng);
        let fast = a.t_matmul(&b);
        let slow = a.transpose().matmul(&b);
        assert!(fast.max_abs_diff(&slow) < 1e-12);
    }

    #[test]
    fn matmul_t_matches_explicit_transpose() {
        let mut rng = Rng::new(6);
        let a = Mat::gaussian(5, 7, &mut rng);
        let b = Mat::gaussian(4, 7, &mut rng);
        let fast = a.matmul_t(&b);
        let slow = a.matmul(&b.transpose());
        assert!(fast.max_abs_diff(&slow) < 1e-12);
    }

    #[test]
    fn ema_blend_formula() {
        let mut rng = Rng::new(3);
        let mut s = Mat::gaussian(4, 4, &mut rng);
        let s0 = s.clone();
        let c = Mat::gaussian(4, 4, &mut rng);
        s.ema_blend(&c, 0.9);
        let want = s0.scale(0.9).add(&c.scale(0.1));
        assert!(s.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Rng::new(4);
        let g = Mat::gaussian(200, 200, &mut rng);
        let n = (g.rows * g.cols) as f64;
        let mean = g.data.iter().sum::<f64>() / n;
        let var = g.data.iter().map(|x| x * x).sum::<f64>() / n;
        assert!(mean.abs() < 0.02);
        assert!((var - 1.0).abs() < 0.03);
    }

    #[test]
    fn scale_cols_matches_manual() {
        let m = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let s = m.scale_cols(&[2.0, 0.5, -1.0]);
        assert_eq!(s.data, vec![2., 1., -3., 8., 2.5, -6.]);
    }
}
