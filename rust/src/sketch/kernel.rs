//! Register-tiled, pool-parallel matmul kernels — the ingest hot path.
//!
//! Every sketch update is one of three product shapes: `A @ B` (matmul),
//! `A^T @ B` (t_matmul, the EMA projection `A^T Upsilon`) and `A @ B^T`
//! (matmul_t, the reconstruction's `... Q_X^T`), plus the fused in-place
//! EMA forms [`t_matmul_ema`]/[`t_matmul_ema_scaled`] that write straight
//! into the resident sketches.  All of them run through the same scheme:
//!
//! * **Register tiling** — inner loops produce 4-row x 4-column output
//!   tiles with explicit accumulators, so each element's sum runs in a
//!   register FMA chain (16 independent chains per tile) instead of a
//!   read-modify-write against memory per `k` step.  Unrolling is over
//!   the output coordinates `i`/`j` only; the shared dimension `k` is
//!   walked in full, in ascending order, per element.  The shared-`k`
//!   working band of a tile (4 columns of each operand) is a few KiB for
//!   every shape this substrate runs (`k` is bounded by `max(n_b, 3k)`),
//!   so the band stays L1-resident without an explicit cache block — the
//!   PR3-era `BLOCK_K` panel tiling is retired with it (it survives only
//!   in [`scoped`], the PR3 reference path).
//! * **Persistent worker pool** — output rows are split into contiguous
//!   stripes claimed from a shared [`Pool`] of long-lived parked worker
//!   threads (rayon is not in the dependency closure).  The pool replaces
//!   the PR3 `std::thread::scope` spawn-per-call fan-out: a handoff is a
//!   condvar wake (~1-2 µs) instead of ~30 µs/worker of spawn, which is
//!   why [`PAR_MIN_FLOPS`] dropped 8x — MNIST-scale per-layer products
//!   now clear the threshold and parallelise.
//!
//! # Pool handoff protocol
//!
//! A [`Pool`] owns `lanes - 1` parked workers; the calling thread is the
//! remaining lane.  [`Pool::run`]`(n, f)` posts one job under the pool
//! mutex — a raw pointer to the caller's closure, a shared atomic task
//! counter and the task count — bumps a job sequence number and wakes
//! every worker.  Workers and the caller then claim task indices with
//! `fetch_add` until the counter passes `n`; each worker decrements the
//! job's `active` count when the counter is drained, and the last one
//! records the completed sequence number and wakes the caller.  `run`
//! returns only once its own sequence number is marked done, which is
//! what makes the borrowed-closure handoff sound: no worker can touch the
//! job pointers after `active` hits zero.  The whole protocol is two
//! mutex/condvar round-trips and **zero heap allocations** per call —
//! the property the zero-allocation ingest test pins down.  Posting is
//! serialised (a second caller parks until the previous job drains), and
//! a `run` issued *from* a pool worker executes inline on that worker
//! (nesting the protocol would self-deadlock).  Panics are contained:
//! workers catch a task panic (staying alive and still decrementing
//! `active`) and re-raise it on the posting thread once the job drains,
//! while a panic in the *caller's* own task unwinds through a guard
//! that waits for the workers first — the erased borrows never dangle
//! and the pool never wedges.
//!
//! **Determinism contract:** every output element is accumulated in
//! ascending-`k` order from `0.0` regardless of tiling or lane count, so
//! the pool kernels are *bitwise identical* to the serial ones — and to
//! the PR3 [`scoped`] reference on any input free of exact zeros and
//! non-finite values (the PR3 kernels skipped `a_ik == 0.0` terms, a
//! branch that pessimised the dense case and is dropped here).  The
//! Lemma-4.1 property tests and the parallel-vs-serial ingest tests rely
//! on this: `Parallelism` is a throughput knob, never a numerics knob.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};
use std::thread;

use super::matrix::Mat;

/// Madds below which the pool handoff overhead exceeds the win.  The
/// PR3 spawn-per-call threshold was `64 * 1024` (~30 µs/worker spawn vs
/// ~1 madd/ns serial throughput); a parked-pool handoff is a condvar
/// wake (~1-2 µs), so the break-even shrinks 8x and MNIST-scale layer
/// products (e.g. 128x128 @ 128x9 ≈ 147k madds) now parallelise.
const PAR_MIN_FLOPS: usize = 8 * 1024;

/// Worker-pool width for the sketch substrate.  `Serial` is the default
/// and the reference semantics; `Threads(n)` resolves to a persistent
/// [`Pool`] of `n` lanes.  Results are bitwise identical either way (see
/// module docs).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Parallelism {
    #[default]
    Serial,
    Threads(usize),
}

impl Parallelism {
    /// Normalise a thread-count knob: 0 and 1 both mean the serial path.
    pub fn from_threads(n: usize) -> Self {
        if n <= 1 {
            Parallelism::Serial
        } else {
            Parallelism::Threads(n)
        }
    }

    /// Effective worker count (>= 1).
    pub fn threads(self) -> usize {
        match self {
            Parallelism::Serial => 1,
            Parallelism::Threads(n) => n.max(1),
        }
    }

    pub fn is_parallel(self) -> bool {
        self.threads() > 1
    }
}

impl std::fmt::Display for Parallelism {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Parallelism::Serial => write!(f, "serial"),
            Parallelism::Threads(n) => write!(f, "{n} threads"),
        }
    }
}

// ---------------------------------------------------------------------
// Persistent worker pool
// ---------------------------------------------------------------------

/// One posted job: a type-erased pointer to the caller's task closure
/// plus the shared claim counter.  The pointers borrow the caller's
/// stack; [`Pool::run`] blocks until the job is drained, which bounds
/// their lifetime (see the module-level protocol docs).
#[derive(Clone, Copy)]
struct Job {
    f: *const (dyn Fn(usize) + Sync),
    next: *const AtomicUsize,
    total: usize,
}

// Safety: the raw pointers are only dereferenced between job posting and
// the final `active` decrement, a window during which `Pool::run` keeps
// the referents alive on the posting thread's stack.
unsafe impl Send for Job {}

struct PoolState {
    /// Monotonic id of the most recently posted job.
    seq: u64,
    /// Id of the most recently *completed* job.
    done_seq: u64,
    /// Workers still draining the current job.
    active: usize,
    job: Option<Job>,
    /// A worker task of the current job panicked (caught; re-raised on
    /// the posting thread once the job drains).
    panicked: bool,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Workers park here waiting for `seq` to advance.
    work_cv: Condvar,
    /// Posters park here waiting for `done_seq` (or for `active == 0`
    /// before posting).
    done_cv: Condvar,
}

fn lock_state(shared: &PoolShared) -> MutexGuard<'_, PoolState> {
    // A poisoned lock means a kernel body panicked on some thread; the
    // counters themselves are plain integers and stay usable.
    shared.state.lock().unwrap_or_else(|p| p.into_inner())
}

thread_local! {
    /// Set for the lifetime of a pool worker thread: a nested `run`
    /// issued from inside a task executes inline instead of deadlocking
    /// on the single-job handoff slot.
    static IN_POOL_WORKER: std::cell::Cell<bool> = std::cell::Cell::new(false);
}

fn worker_loop(shared: Arc<PoolShared>) {
    IN_POOL_WORKER.with(|flag| flag.set(true));
    let mut last_seq = 0u64;
    loop {
        let (job, seq) = {
            let mut st = lock_state(&shared);
            loop {
                if st.shutdown {
                    return;
                }
                if st.seq != last_seq {
                    last_seq = st.seq;
                    break (st.job.expect("posted job present"), st.seq);
                }
                st = shared
                    .work_cv
                    .wait(st)
                    .unwrap_or_else(|p| p.into_inner());
            }
        };
        // Safety: `Pool::run` keeps the closure and counter alive until
        // this job's `done_seq` is recorded below.
        let f = unsafe { &*job.f };
        let next = unsafe { &*job.next };
        // Catch task panics so the worker always decrements `active`
        // (a missing decrement would wedge every future job) and stays
        // alive for the next job; the panic is re-raised on the posting
        // thread via the `panicked` flag.
        let outcome =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= job.total {
                    break;
                }
                f(i);
            }));
        let mut st = lock_state(&shared);
        if outcome.is_err() {
            st.panicked = true;
        }
        st.active -= 1;
        if st.active == 0 {
            st.done_seq = seq;
            st.job = None;
            shared.done_cv.notify_all();
        }
    }
}

/// Persistent worker pool: `lanes - 1` long-lived parked threads plus
/// the calling thread.  Created once (per engine, or shared process-wide
/// by the daemon) and reused for every kernel call — see the module docs
/// for the handoff protocol and its zero-allocation guarantee.
pub struct Pool {
    shared: Arc<PoolShared>,
    handles: Vec<thread::JoinHandle<()>>,
    lanes: usize,
}

impl std::fmt::Debug for Pool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Pool({} lanes)", self.lanes)
    }
}

impl Pool {
    /// Pool sized by the config knob: `Serial` -> 1 lane (no threads
    /// spawned), `Threads(n)` -> `n` lanes (`n - 1` parked workers).
    pub fn new(par: Parallelism) -> Arc<Pool> {
        Pool::with_lanes(par.threads())
    }

    /// Pool with an explicit lane count (>= 1; the caller is a lane).
    pub fn with_lanes(lanes: usize) -> Arc<Pool> {
        let lanes = lanes.max(1);
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                seq: 0,
                done_seq: 0,
                active: 0,
                job: None,
                panicked: false,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let handles = (1..lanes)
            .map(|_| {
                let shared = Arc::clone(&shared);
                thread::Builder::new()
                    .name("sketch-pool".into())
                    .spawn(move || worker_loop(shared))
                    .expect("spawn pool worker")
            })
            .collect();
        Arc::new(Pool {
            shared,
            handles,
            lanes,
        })
    }

    /// The shared single-lane pool — the serial path.  `run` on it is a
    /// plain inline loop; no threads are ever spawned.
    pub fn serial() -> &'static Arc<Pool> {
        static SERIAL: OnceLock<Arc<Pool>> = OnceLock::new();
        SERIAL.get_or_init(|| Pool::with_lanes(1))
    }

    /// Parallel lanes available, counting the caller (>= 1).
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    pub fn is_parallel(&self) -> bool {
        self.lanes > 1
    }

    /// Run `f(0), f(1), ..., f(total - 1)` across the pool's lanes, each
    /// index claimed exactly once, returning after all have finished.
    /// Allocation-free; see the module docs for the handoff protocol.
    pub fn run(&self, total: usize, f: &(dyn Fn(usize) + Sync)) {
        if total == 0 {
            return;
        }
        let in_worker = IN_POOL_WORKER.with(|flag| flag.get());
        if self.handles.is_empty() || total == 1 || in_worker {
            for i in 0..total {
                f(i);
            }
            return;
        }
        let next = AtomicUsize::new(0);
        // Safety of the lifetime erasure: `run` does not return until the
        // job's completion is recorded, so the erased borrows outlive
        // every dereference (module docs).  (A plain `as` cast cannot
        // extend the trait object's lifetime bound to the `'static` the
        // pointer type carries, hence the transmute.)
        #[allow(clippy::useless_transmute)]
        let fp: *const (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<
                &(dyn Fn(usize) + Sync),
                *const (dyn Fn(usize) + Sync),
            >(f)
        };
        let my_seq = {
            let mut st = lock_state(&self.shared);
            while st.active > 0 {
                st = self
                    .shared
                    .done_cv
                    .wait(st)
                    .unwrap_or_else(|p| p.into_inner());
            }
            st.seq += 1;
            st.active = self.handles.len();
            st.panicked = false;
            st.job = Some(Job {
                f: fp,
                next: &next,
                total,
            });
            self.shared.work_cv.notify_all();
            st.seq
        };
        // From here the workers hold erased pointers into this stack
        // frame, so we MUST NOT leave before the job drains — even by
        // panic.  The guard performs the completion wait in `drop` when
        // a panic in the caller's own `f(i)` unwinds this frame (the
        // workers finish the remaining indices first, then the panic
        // continues); on the normal path it is disarmed and the wait
        // happens inline so the panic flag is read under the same lock
        // acquisition that observes completion.
        struct JobGuard<'a> {
            shared: &'a PoolShared,
            my_seq: u64,
            armed: bool,
        }
        impl Drop for JobGuard<'_> {
            fn drop(&mut self) {
                if !self.armed {
                    return;
                }
                let mut st = lock_state(self.shared);
                while st.done_seq < self.my_seq {
                    st = self
                        .shared
                        .done_cv
                        .wait(st)
                        .unwrap_or_else(|p| p.into_inner());
                }
            }
        }
        let mut guard = JobGuard {
            shared: &*self.shared,
            my_seq,
            armed: true,
        };
        // The caller is a lane too: claim indices alongside the workers.
        loop {
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= total {
                break;
            }
            f(i);
        }
        guard.armed = false;
        let panicked = {
            let mut st = lock_state(&self.shared);
            while st.done_seq < my_seq {
                st = self
                    .shared
                    .done_cv
                    .wait(st)
                    .unwrap_or_else(|p| p.into_inner());
            }
            st.panicked
        };
        if panicked {
            panic!("pool task panicked");
        }
    }

    /// `f(i, &mut items[i])` for every item, indices claimed across the
    /// pool's lanes.  The safe fan-out primitive `SketchEngine::ingest`
    /// (whole layers) and `MonitorHub` (per-session diagnosis) build on.
    pub fn for_each_mut<T, F>(&self, items: &mut [T], f: F)
    where
        T: Send,
        F: Fn(usize, &mut T) + Sync,
    {
        let base = SendPtr(items.as_mut_ptr());
        self.run(items.len(), &|i| {
            // Safety: `run` hands each index to exactly one lane, so the
            // `&mut` slots are disjoint.
            let item = unsafe { &mut *base.0.add(i) };
            f(i, item);
        });
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        {
            let mut st = lock_state(&self.shared);
            st.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Raw-pointer wrapper that may cross lane boundaries; every use hands
/// out disjoint regions (one stripe / slot per claimed index).
struct SendPtr<T>(*mut T);
unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

/// Split `out`'s rows into one contiguous stripe per lane and run
/// `body(first_row, last_row_exclusive, stripe)` on each.  The serial
/// path is the single-stripe call, so both paths share one kernel body.
fn for_row_stripes<F>(out: &mut Mat, pool: &Pool, flops: usize, body: F)
where
    F: Fn(usize, usize, &mut [f64]) + Sync,
{
    let (rows, cols) = (out.rows, out.cols);
    if rows * cols == 0 {
        return;
    }
    let stripes = pool.lanes().min(rows);
    if stripes <= 1 || flops < PAR_MIN_FLOPS {
        body(0, rows, &mut out.data);
        return;
    }
    let stripe_rows = rows.div_ceil(stripes);
    let base = SendPtr(out.data.as_mut_ptr());
    pool.run(stripes, &|s| {
        let i0 = s * stripe_rows;
        if i0 >= rows {
            return;
        }
        let i1 = (i0 + stripe_rows).min(rows);
        // Safety: stripes are disjoint row ranges of `out.data`, and
        // `run` hands each stripe index to exactly one lane.
        let stripe = unsafe {
            std::slice::from_raw_parts_mut(
                base.0.add(i0 * cols),
                (i1 - i0) * cols,
            )
        };
        body(i0, i1, stripe);
    });
}

// ---------------------------------------------------------------------
// Register-tiled kernel bodies
// ---------------------------------------------------------------------

/// How a finished 4x4 (or tail) accumulator tile lands in the output.
#[derive(Clone, Copy)]
enum Store<'a> {
    /// `out = acc` — the pure-product kernels (output starts untouched).
    Assign,
    /// `out = beta*out + (1-beta)*acc` — the fused EMA update.
    Ema { beta: f64 },
    /// `out = beta*out + (1-beta)*(acc*scale[j])` — the Z sketch's
    /// psi-column-scaled EMA update.
    EmaScaled { beta: f64, scale: &'a [f64] },
}

impl Store<'_> {
    /// Write one element; `j` is the output column (for the psi scale).
    /// The expression trees mirror the unfused
    /// `t_matmul` -> `scale_cols` -> `ema_blend` chain exactly, so fused
    /// and unfused results are bitwise identical.
    #[inline(always)]
    fn store(self, out: &mut f64, acc: f64, j: usize) {
        match self {
            Store::Assign => *out = acc,
            Store::Ema { beta } => {
                *out = beta * *out + (1.0 - beta) * acc;
            }
            Store::EmaScaled { beta, scale } => {
                let scaled = acc * scale[j];
                *out = beta * *out + (1.0 - beta) * scaled;
            }
        }
    }
}

/// `a^T @ b` over output rows [i0, i1) (columns of `a`), register-tiled
/// 4x4.  Element (i, j) accumulates `a[k, i] * b[k, j]` for k ascending
/// from 0 — per row k, both operands are read as short contiguous spans,
/// so the shared-k band of a tile is 8 streamed doubles per step.
fn t_matmul_body(a: &Mat, b: &Mat, i0: usize, i1: usize, stripe: &mut [f64], st: Store<'_>) {
    let n = b.cols;
    let m = a.rows;
    let mut i = i0;
    while i + 4 <= i1 {
        let mut j = 0;
        while j + 4 <= n {
            let mut acc = [[0.0f64; 4]; 4];
            for k in 0..m {
                let ar = &a.row(k)[i..i + 4];
                let br = &b.row(k)[j..j + 4];
                for (r, accr) in acc.iter_mut().enumerate() {
                    let av = ar[r];
                    accr[0] += av * br[0];
                    accr[1] += av * br[1];
                    accr[2] += av * br[2];
                    accr[3] += av * br[3];
                }
            }
            for (r, accr) in acc.iter().enumerate() {
                let row = &mut stripe[(i + r - i0) * n + j..];
                for (c, &v) in accr.iter().enumerate() {
                    st.store(&mut row[c], v, j + c);
                }
            }
            j += 4;
        }
        while j < n {
            let mut acc = [0.0f64; 4];
            for k in 0..m {
                let ar = &a.row(k)[i..i + 4];
                let bv = b.row(k)[j];
                for (r, accr) in acc.iter_mut().enumerate() {
                    *accr += ar[r] * bv;
                }
            }
            for (r, &v) in acc.iter().enumerate() {
                st.store(&mut stripe[(i + r - i0) * n + j], v, j);
            }
            j += 1;
        }
        i += 4;
    }
    while i < i1 {
        let mut j = 0;
        while j + 4 <= n {
            let mut acc = [0.0f64; 4];
            for k in 0..m {
                let av = a.row(k)[i];
                let br = &b.row(k)[j..j + 4];
                acc[0] += av * br[0];
                acc[1] += av * br[1];
                acc[2] += av * br[2];
                acc[3] += av * br[3];
            }
            let row = &mut stripe[(i - i0) * n + j..];
            for (c, &v) in acc.iter().enumerate() {
                st.store(&mut row[c], v, j + c);
            }
            j += 4;
        }
        while j < n {
            let mut acc = 0.0f64;
            for k in 0..m {
                acc += a.row(k)[i] * b.row(k)[j];
            }
            st.store(&mut stripe[(i - i0) * n + j], acc, j);
            j += 1;
        }
        i += 1;
    }
}

/// `a @ b` over output rows [i0, i1), register-tiled 4x4: element (i, j)
/// accumulates `a[i, k] * b[k, j]` for k ascending from 0.
fn matmul_body(a: &Mat, b: &Mat, i0: usize, i1: usize, stripe: &mut [f64]) {
    let n = b.cols;
    let m = a.cols;
    let mut i = i0;
    while i + 4 <= i1 {
        let ar: [&[f64]; 4] =
            [a.row(i), a.row(i + 1), a.row(i + 2), a.row(i + 3)];
        let mut j = 0;
        while j + 4 <= n {
            let mut acc = [[0.0f64; 4]; 4];
            for k in 0..m {
                let br = &b.row(k)[j..j + 4];
                for (r, accr) in acc.iter_mut().enumerate() {
                    let av = ar[r][k];
                    accr[0] += av * br[0];
                    accr[1] += av * br[1];
                    accr[2] += av * br[2];
                    accr[3] += av * br[3];
                }
            }
            for (r, accr) in acc.iter().enumerate() {
                let row = &mut stripe[(i + r - i0) * n + j..];
                row[..4].copy_from_slice(accr);
            }
            j += 4;
        }
        while j < n {
            let mut acc = [0.0f64; 4];
            for k in 0..m {
                let bv = b.row(k)[j];
                for (r, accr) in acc.iter_mut().enumerate() {
                    *accr += ar[r][k] * bv;
                }
            }
            for (r, &v) in acc.iter().enumerate() {
                stripe[(i + r - i0) * n + j] = v;
            }
            j += 1;
        }
        i += 4;
    }
    while i < i1 {
        let arow = a.row(i);
        let mut j = 0;
        while j + 4 <= n {
            let mut acc = [0.0f64; 4];
            for (k, &av) in arow.iter().enumerate() {
                let br = &b.row(k)[j..j + 4];
                acc[0] += av * br[0];
                acc[1] += av * br[1];
                acc[2] += av * br[2];
                acc[3] += av * br[3];
            }
            stripe[(i - i0) * n + j..(i - i0) * n + j + 4]
                .copy_from_slice(&acc);
            j += 4;
        }
        while j < n {
            let mut acc = 0.0f64;
            for (k, &av) in arow.iter().enumerate() {
                acc += av * b.row(k)[j];
            }
            stripe[(i - i0) * n + j] = acc;
            j += 1;
        }
        i += 1;
    }
}

/// `a @ b^T` over output rows [i0, i1), register-tiled 4x4: element
/// (i, j) is the ascending-k dot of `a.row(i)` and `b.row(j)`.
fn matmul_t_body(a: &Mat, b: &Mat, i0: usize, i1: usize, stripe: &mut [f64]) {
    let n = b.rows;
    let m = a.cols;
    let mut i = i0;
    while i + 4 <= i1 {
        let ar: [&[f64]; 4] =
            [a.row(i), a.row(i + 1), a.row(i + 2), a.row(i + 3)];
        let mut j = 0;
        while j + 4 <= n {
            let br: [&[f64]; 4] =
                [b.row(j), b.row(j + 1), b.row(j + 2), b.row(j + 3)];
            let mut acc = [[0.0f64; 4]; 4];
            for k in 0..m {
                for (r, accr) in acc.iter_mut().enumerate() {
                    let av = ar[r][k];
                    accr[0] += av * br[0][k];
                    accr[1] += av * br[1][k];
                    accr[2] += av * br[2][k];
                    accr[3] += av * br[3][k];
                }
            }
            for (r, accr) in acc.iter().enumerate() {
                let row = &mut stripe[(i + r - i0) * n + j..];
                row[..4].copy_from_slice(accr);
            }
            j += 4;
        }
        while j < n {
            let brow = b.row(j);
            let mut acc = [0.0f64; 4];
            for (k, &bv) in brow.iter().enumerate() {
                for (r, accr) in acc.iter_mut().enumerate() {
                    *accr += ar[r][k] * bv;
                }
            }
            for (r, &v) in acc.iter().enumerate() {
                stripe[(i + r - i0) * n + j] = v;
            }
            j += 1;
        }
        i += 4;
    }
    while i < i1 {
        let arow = a.row(i);
        for j in 0..n {
            let mut acc = 0.0f64;
            for (&x, &y) in arow.iter().zip(b.row(j)) {
                acc += x * y;
            }
            stripe[(i - i0) * n + j] = acc;
        }
        i += 1;
    }
}

// ---------------------------------------------------------------------
// Public kernels
// ---------------------------------------------------------------------

/// `a @ b` — register-tiled, parallel over output rows.
pub fn matmul(a: &Mat, b: &Mat, pool: &Pool) -> Mat {
    assert_eq!(
        a.cols, b.rows,
        "matmul shape mismatch {}x{} @ {}x{}",
        a.rows, a.cols, b.rows, b.cols
    );
    let mut out = Mat::zeros(a.rows, b.cols);
    let flops = a.rows * a.cols * b.cols;
    for_row_stripes(&mut out, pool, flops, |i0, i1, stripe| {
        matmul_body(a, b, i0, i1, stripe);
    });
    out
}

/// `a^T @ b` without materialising the transpose — the EMA sketch
/// update's `A^T P` shape.  Parallel over output rows (columns of `a`).
pub fn t_matmul(a: &Mat, b: &Mat, pool: &Pool) -> Mat {
    assert_eq!(
        a.rows, b.rows,
        "t_matmul shape mismatch {}x{}^T @ {}x{}",
        a.rows, a.cols, b.rows, b.cols
    );
    let mut out = Mat::zeros(a.cols, b.cols);
    let flops = a.rows * a.cols * b.cols;
    for_row_stripes(&mut out, pool, flops, |i0, i1, stripe| {
        t_matmul_body(a, b, i0, i1, stripe, Store::Assign);
    });
    out
}

/// `a @ b^T` without materialising the transpose — the reconstruction's
/// `... Q_X^T` shape.  Parallel over output rows.
pub fn matmul_t(a: &Mat, b: &Mat, pool: &Pool) -> Mat {
    assert_eq!(
        a.cols, b.cols,
        "matmul_t shape mismatch {}x{} @ {}x{}^T",
        a.rows, a.cols, b.rows, b.cols
    );
    let mut out = Mat::zeros(a.rows, b.rows);
    let flops = a.rows * a.cols * b.rows;
    for_row_stripes(&mut out, pool, flops, |i0, i1, stripe| {
        matmul_t_body(a, b, i0, i1, stripe);
    });
    out
}

fn assert_ema_shapes(a: &Mat, b: &Mat, out: &Mat) {
    assert_eq!(
        a.rows, b.rows,
        "t_matmul_ema shape mismatch {}x{}^T @ {}x{}",
        a.rows, a.cols, b.rows, b.cols
    );
    assert_eq!(
        (out.rows, out.cols),
        (a.cols, b.cols),
        "t_matmul_ema output is {}x{}, product is {}x{}",
        out.rows,
        out.cols,
        a.cols,
        b.cols
    );
}

/// Fused in-place EMA update `out = beta*out + (1-beta) * a^T @ b`,
/// writing directly into the resident sketch: no contribution temporary
/// is ever allocated.  Bitwise identical to
/// `out.ema_blend(&t_matmul(a, b, pool), beta)` — the per-element chain
/// (ascending-k product sum from 0, then one blend) is the same.
pub fn t_matmul_ema(a: &Mat, b: &Mat, out: &mut Mat, beta: f64, pool: &Pool) {
    assert_ema_shapes(a, b, out);
    let flops = a.rows * a.cols * b.cols;
    for_row_stripes(out, pool, flops, |i0, i1, stripe| {
        t_matmul_body(a, b, i0, i1, stripe, Store::Ema { beta });
    });
}

/// [`t_matmul_ema`] with the contribution's columns scaled by `scale`
/// (the Z sketch's psi weighting) before blending:
/// `out = beta*out + (1-beta) * ((a^T @ b) * scale[j])`.  Bitwise
/// identical to the unfused `t_matmul` -> `scale_cols` -> `ema_blend`.
pub fn t_matmul_ema_scaled(
    a: &Mat,
    b: &Mat,
    scale: &[f64],
    out: &mut Mat,
    beta: f64,
    pool: &Pool,
) {
    assert_ema_shapes(a, b, out);
    assert_eq!(scale.len(), b.cols, "psi scale length mismatch");
    let flops = a.rows * a.cols * b.cols;
    for_row_stripes(out, pool, flops, |i0, i1, stripe| {
        t_matmul_body(a, b, i0, i1, stripe, Store::EmaScaled { beta, scale });
    });
}

/// PR3-era reference kernels: cache-blocked scalar inner loops (with the
/// `a_ik == 0.0` skip) fanned across `std::thread::scope` workers spawned
/// per call.  Kept verbatim for two jobs: the pool-vs-scoped bitwise
/// equivalence tests, and the `bench-smoke` perf gate's fused-vs-PR3
/// ingest baseline.  Not used on any production path.
pub mod scoped {
    use super::super::matrix::Mat;

    /// B-panel tile height of the PR3 scheme: 64 rows x up to ~33
    /// columns (k <= 2*16 + 1 at the largest ladder rank) is a <=17 KiB
    /// panel, L1-resident alongside the output stripe.  (The PR3 comment
    /// claimed "~512 columns ≈ 256 KiB L2 slice", sized for a B panel as
    /// wide as a hidden layer; no sketch product ever has more than
    /// `3k` output columns, so the panel was always an order of
    /// magnitude smaller than advertised.)
    pub const BLOCK_K: usize = 64;

    /// The PR3 spawn-per-call threshold (~30 µs/worker spawn cost).
    const PAR_MIN_FLOPS: usize = 64 * 1024;

    fn for_row_stripes<F>(out: &mut Mat, threads: usize, flops: usize, body: F)
    where
        F: Fn(usize, usize, &mut [f64]) + Sync,
    {
        let (rows, cols) = (out.rows, out.cols);
        let workers = threads.max(1).min(rows.max(1));
        if workers <= 1 || rows * cols == 0 || flops < PAR_MIN_FLOPS {
            body(0, rows, &mut out.data);
            return;
        }
        let stripe_rows = rows.div_ceil(workers);
        std::thread::scope(|s| {
            for (w, stripe) in
                out.data.chunks_mut(stripe_rows * cols).enumerate()
            {
                let body = &body;
                s.spawn(move || {
                    let i0 = w * stripe_rows;
                    body(i0, i0 + stripe.len() / cols, stripe);
                });
            }
        });
    }

    /// PR3 `a @ b`: k-blocked scalar loops, spawn-per-call fan-out.
    pub fn matmul(a: &Mat, b: &Mat, threads: usize) -> Mat {
        assert_eq!(a.cols, b.rows, "matmul shape mismatch");
        let mut out = Mat::zeros(a.rows, b.cols);
        let n = b.cols;
        let flops = a.rows * a.cols * n;
        for_row_stripes(&mut out, threads, flops, |i0, i1, stripe| {
            for kk in (0..a.cols).step_by(BLOCK_K) {
                let kend = (kk + BLOCK_K).min(a.cols);
                for i in i0..i1 {
                    let a_row = a.row(i);
                    let out_row =
                        &mut stripe[(i - i0) * n..(i - i0 + 1) * n];
                    for (k, &a_ik) in a_row[kk..kend].iter().enumerate() {
                        if a_ik == 0.0 {
                            continue;
                        }
                        let b_row = b.row(kk + k);
                        for (o, &bv) in out_row.iter_mut().zip(b_row) {
                            *o += a_ik * bv;
                        }
                    }
                }
            }
        });
        out
    }

    /// PR3 `a^T @ b`: k-blocked scalar loops, spawn-per-call fan-out.
    pub fn t_matmul(a: &Mat, b: &Mat, threads: usize) -> Mat {
        assert_eq!(a.rows, b.rows, "t_matmul shape mismatch");
        let mut out = Mat::zeros(a.cols, b.cols);
        let n = b.cols;
        let flops = a.rows * a.cols * n;
        for_row_stripes(&mut out, threads, flops, |i0, i1, stripe| {
            for kk in (0..a.rows).step_by(BLOCK_K) {
                let kend = (kk + BLOCK_K).min(a.rows);
                for i in i0..i1 {
                    let out_row =
                        &mut stripe[(i - i0) * n..(i - i0 + 1) * n];
                    for k in kk..kend {
                        let a_ki = a[(k, i)];
                        if a_ki == 0.0 {
                            continue;
                        }
                        let b_row = b.row(k);
                        for (o, &bv) in out_row.iter_mut().zip(b_row) {
                            *o += a_ki * bv;
                        }
                    }
                }
            }
        });
        out
    }

    /// PR3 `a @ b^T`: row-dot scalar loops, spawn-per-call fan-out.
    pub fn matmul_t(a: &Mat, b: &Mat, threads: usize) -> Mat {
        assert_eq!(a.cols, b.cols, "matmul_t shape mismatch");
        let mut out = Mat::zeros(a.rows, b.rows);
        let n = b.rows;
        let flops = a.rows * a.cols * n;
        for_row_stripes(&mut out, threads, flops, |i0, i1, stripe| {
            for i in i0..i1 {
                let a_row = a.row(i);
                let out_row = &mut stripe[(i - i0) * n..(i - i0 + 1) * n];
                for (j, o) in out_row.iter_mut().enumerate() {
                    let b_row = b.row(j);
                    let mut acc = 0.0;
                    for (&x, &y) in a_row.iter().zip(b_row) {
                        acc += x * y;
                    }
                    *o = acc;
                }
            }
        });
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Unblocked, unthreaded reference with the same ascending-k
    /// accumulation order the kernels guarantee.
    fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
        let mut out = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for k in 0..a.cols {
                for j in 0..b.cols {
                    out[(i, j)] += a[(i, k)] * b[(k, j)];
                }
            }
        }
        out
    }

    #[test]
    fn tiled_matmul_is_bitwise_naive() {
        let mut rng = Rng::new(11);
        // Off-multiple-of-4 shapes exercise every tail path of the tile.
        let a = Mat::gaussian(9, 135, &mut rng);
        let b = Mat::gaussian(135, 13, &mut rng);
        let want = naive_matmul(&a, &b);
        for lanes in [1usize, 2, 4] {
            let pool = Pool::with_lanes(lanes);
            let got = matmul(&a, &b, &pool);
            assert_eq!(got.data, want.data, "lanes={lanes}");
        }
    }

    #[test]
    fn t_matmul_matches_transpose_matmul_bitwise() {
        let mut rng = Rng::new(12);
        let a = Mat::gaussian(69, 17, &mut rng);
        let b = Mat::gaussian(69, 11, &mut rng);
        let want = naive_matmul(&a.transpose(), &b);
        for lanes in [1usize, 3] {
            let pool = Pool::with_lanes(lanes);
            let got = t_matmul(&a, &b, &pool);
            assert_eq!(got.data, want.data, "lanes={lanes}");
        }
    }

    #[test]
    fn matmul_t_matches_transpose_path() {
        let mut rng = Rng::new(13);
        let a = Mat::gaussian(12, 33, &mut rng);
        let b = Mat::gaussian(21, 33, &mut rng);
        let want = naive_matmul(&a, &b.transpose());
        for lanes in [1usize, 4] {
            let pool = Pool::with_lanes(lanes);
            let got = matmul_t(&a, &b, &pool);
            // Same dot-product order per element; identical fp result.
            assert!(got.max_abs_diff(&want) < 1e-12, "lanes={lanes}");
        }
    }

    #[test]
    fn fused_ema_matches_unfused_bitwise() {
        let mut rng = Rng::new(15);
        let a = Mat::gaussian(22, 37, &mut rng); // tail rows and cols
        let b = Mat::gaussian(22, 9, &mut rng);
        let psi: Vec<f64> = rng.normal_vec(9);
        let beta = 0.9;
        let pool4 = Pool::with_lanes(4);
        for pool in [Pool::serial(), &pool4] {
            let mut fused = Mat::gaussian(37, 9, &mut rng);
            let mut unfused = fused.clone();
            t_matmul_ema(&a, &b, &mut fused, beta, pool);
            unfused.ema_blend(&t_matmul(&a, &b, Pool::serial()), beta);
            assert_eq!(fused.data, unfused.data, "{pool:?}");

            let mut fused_z = Mat::gaussian(37, 9, &mut rng);
            let mut unfused_z = fused_z.clone();
            t_matmul_ema_scaled(&a, &b, &psi, &mut fused_z, beta, pool);
            unfused_z.ema_blend(
                &t_matmul(&a, &b, Pool::serial()).scale_cols(&psi),
                beta,
            );
            assert_eq!(fused_z.data, unfused_z.data, "{pool:?} (scaled)");
        }
    }

    #[test]
    fn pool_matches_scoped_reference_bitwise() {
        let mut rng = Rng::new(16);
        // Above both parallel thresholds so every path actually fans out.
        let a = Mat::gaussian(96, 150, &mut rng);
        let b = Mat::gaussian(96, 13, &mut rng);
        let pool = Pool::with_lanes(4);
        assert_eq!(
            t_matmul(&a, &b, &pool).data,
            scoped::t_matmul(&a, &b, 4).data
        );
        let c = Mat::gaussian(150, 96, &mut rng);
        assert_eq!(matmul(&c, &b, &pool).data, scoped::matmul(&c, &b, 4).data);
        let d = Mat::gaussian(40, 96, &mut rng);
        assert_eq!(
            matmul_t(&c, &d, &pool).data,
            scoped::matmul_t(&c, &d, 4).data
        );
    }

    #[test]
    fn pool_reuse_is_stable() {
        // Many products through one pool: results never drift and the
        // handoff protocol survives repeated reuse.
        let mut rng = Rng::new(17);
        let a = Mat::gaussian(64, 120, &mut rng);
        let b = Mat::gaussian(64, 9, &mut rng);
        let pool = Pool::with_lanes(3);
        let want = t_matmul(&a, &b, Pool::serial());
        for round in 0..50 {
            let got = t_matmul(&a, &b, &pool);
            assert_eq!(got.data, want.data, "round {round}");
        }
    }

    #[test]
    fn pool_run_covers_every_index_once() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let pool = Pool::with_lanes(4);
        let hits: Vec<AtomicUsize> =
            (0..97).map(|_| AtomicUsize::new(0)).collect();
        for _ in 0..10 {
            pool.run(hits.len(), &|i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
        }
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 10, "index {i}");
        }
    }

    #[test]
    fn nested_run_executes_inline() {
        // A task that itself calls `run` must not deadlock: the inner
        // call detects the worker thread and runs inline.
        use std::sync::atomic::{AtomicUsize, Ordering};
        let pool = Pool::with_lanes(2);
        let count = AtomicUsize::new(0);
        pool.run(4, &|_| {
            pool.run(3, &|_| {
                count.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(count.load(Ordering::Relaxed), 12);
    }

    #[test]
    fn pool_survives_task_panic() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let pool = Pool::with_lanes(3);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || {
                pool.run(8, &|i| {
                    if i == 3 {
                        panic!("boom");
                    }
                });
            },
        ));
        assert!(result.is_err(), "task panic must reach the caller");
        // The pool is not wedged: later jobs still run to completion.
        let hits: Vec<AtomicUsize> =
            (0..16).map(|_| AtomicUsize::new(0)).collect();
        pool.run(hits.len(), &|i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "index {i}");
        }
    }

    #[test]
    fn parallel_handles_more_lanes_than_rows() {
        let mut rng = Rng::new(14);
        let a = Mat::gaussian(2, 300, &mut rng);
        let b = Mat::gaussian(300, 400, &mut rng);
        let pool = Pool::with_lanes(16);
        let got = matmul(&a, &b, &pool);
        assert_eq!(got.data, matmul(&a, &b, Pool::serial()).data);
    }

    #[test]
    fn degenerate_shapes() {
        let pool = Pool::with_lanes(4);
        let a = Mat::zeros(0, 5);
        let b = Mat::zeros(5, 3);
        let out = matmul(&a, &b, &pool);
        assert_eq!((out.rows, out.cols), (0, 3));
        let out = t_matmul(&Mat::zeros(4, 0), &Mat::zeros(4, 3), &pool);
        assert_eq!((out.rows, out.cols), (0, 3));
        let mut ema = Mat::zeros(0, 3);
        t_matmul_ema(&Mat::zeros(4, 0), &Mat::zeros(4, 3), &mut ema, 0.9, &pool);
        assert_eq!((ema.rows, ema.cols), (0, 3));
    }

    #[test]
    fn parallelism_knob() {
        assert_eq!(Parallelism::from_threads(0), Parallelism::Serial);
        assert_eq!(Parallelism::from_threads(1), Parallelism::Serial);
        assert_eq!(Parallelism::from_threads(4), Parallelism::Threads(4));
        assert_eq!(Parallelism::Threads(0).threads(), 1);
        assert!(!Parallelism::Serial.is_parallel());
        assert_eq!(format!("{}", Parallelism::Threads(4)), "4 threads");
        assert_eq!(Pool::new(Parallelism::Serial).lanes(), 1);
        assert_eq!(Pool::new(Parallelism::Threads(4)).lanes(), 4);
        assert!(!Pool::serial().is_parallel());
        assert_eq!(format!("{:?}", Pool::with_lanes(2)), "Pool(2 lanes)");
    }
}
