//! Cache-blocked, thread-parallel matmul kernels — the ingest hot path.
//!
//! Every sketch update is one of three product shapes: `A @ B` (matmul),
//! `A^T @ B` (t_matmul, the EMA projection `A^T Upsilon`) and `A @ B^T`
//! (matmul_t, the reconstruction's `... Q_X^T`).  All three run through
//! the same scheme here:
//!
//! * **Blocking** — the shared `k` dimension is tiled (`BLOCK_K` rows of
//!   the B panel) so the panel stays hot in cache while a stripe of output
//!   rows streams through it.
//! * **Worker fan-out** — output rows are split into contiguous stripes,
//!   one per worker, executed on scoped `std::thread`s (rayon is not in
//!   the dependency closure).  Spawn cost is a few tens of µs, amortised
//!   over millisecond-scale products; sub-threshold shapes
//!   (`PAR_MIN_FLOPS`) short-circuit to the serial path.
//!
//! **Determinism contract:** every output element is accumulated in
//! ascending-`k` order regardless of blocking or worker count, so the
//! parallel kernels are *bitwise identical* to the serial ones.  The
//! Lemma-4.1 property tests (and the parallel-vs-serial ingest tests)
//! rely on this: `Parallelism` is a throughput knob, never a numerics
//! knob.

use super::matrix::Mat;

/// B-panel tile height (f64 elements): 64 rows x up to ~512 columns keeps
/// the panel within a typical 256 KiB L2 slice alongside the output stripe.
const BLOCK_K: usize = 64;

/// Madds below which threading overhead exceeds the win; measured spawn
/// cost is ~30 µs/worker vs ~1 madd/ns serial throughput.
const PAR_MIN_FLOPS: usize = 64 * 1024;

/// Worker-pool width for the sketch substrate.  `Serial` is the default
/// and the reference semantics; `Threads(n)` fans work across `n` scoped
/// workers.  Results are bitwise identical either way (see module docs).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Parallelism {
    #[default]
    Serial,
    Threads(usize),
}

impl Parallelism {
    /// Normalise a thread-count knob: 0 and 1 both mean the serial path.
    pub fn from_threads(n: usize) -> Self {
        if n <= 1 {
            Parallelism::Serial
        } else {
            Parallelism::Threads(n)
        }
    }

    /// Effective worker count (>= 1).
    pub fn threads(self) -> usize {
        match self {
            Parallelism::Serial => 1,
            Parallelism::Threads(n) => n.max(1),
        }
    }

    pub fn is_parallel(self) -> bool {
        self.threads() > 1
    }
}

impl std::fmt::Display for Parallelism {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Parallelism::Serial => write!(f, "serial"),
            Parallelism::Threads(n) => write!(f, "{n} threads"),
        }
    }
}

/// Split `out`'s rows into one contiguous stripe per worker and run
/// `body(first_row, last_row_exclusive, stripe)` on each.  The serial
/// path is the single-stripe call, so both paths share one kernel body.
fn for_row_stripes<F>(out: &mut Mat, par: Parallelism, flops: usize, body: F)
where
    F: Fn(usize, usize, &mut [f64]) + Sync,
{
    let (rows, cols) = (out.rows, out.cols);
    let workers = par.threads().min(rows.max(1));
    if workers <= 1 || rows * cols == 0 || flops < PAR_MIN_FLOPS {
        body(0, rows, &mut out.data);
        return;
    }
    let stripe_rows = rows.div_ceil(workers);
    std::thread::scope(|s| {
        for (w, stripe) in out.data.chunks_mut(stripe_rows * cols).enumerate() {
            let body = &body;
            s.spawn(move || {
                let i0 = w * stripe_rows;
                body(i0, i0 + stripe.len() / cols, stripe);
            });
        }
    });
}

/// `a @ b` — blocked over the shared dimension, parallel over output rows.
pub fn matmul(a: &Mat, b: &Mat, par: Parallelism) -> Mat {
    assert_eq!(
        a.cols, b.rows,
        "matmul shape mismatch {}x{} @ {}x{}",
        a.rows, a.cols, b.rows, b.cols
    );
    let mut out = Mat::zeros(a.rows, b.cols);
    let n = b.cols;
    let flops = a.rows * a.cols * n;
    for_row_stripes(&mut out, par, flops, |i0, i1, stripe| {
        for kk in (0..a.cols).step_by(BLOCK_K) {
            let kend = (kk + BLOCK_K).min(a.cols);
            for i in i0..i1 {
                let a_row = a.row(i);
                let out_row = &mut stripe[(i - i0) * n..(i - i0 + 1) * n];
                for (k, &a_ik) in a_row[kk..kend].iter().enumerate() {
                    if a_ik == 0.0 {
                        continue;
                    }
                    let b_row = b.row(kk + k);
                    for (o, &bv) in out_row.iter_mut().zip(b_row) {
                        *o += a_ik * bv;
                    }
                }
            }
        }
    });
    out
}

/// `a^T @ b` without materialising the transpose — the EMA sketch update's
/// `A^T P` shape.  Blocked over the shared (batch) dimension, parallel
/// over output rows (columns of `a`).
pub fn t_matmul(a: &Mat, b: &Mat, par: Parallelism) -> Mat {
    assert_eq!(
        a.rows, b.rows,
        "t_matmul shape mismatch {}x{}^T @ {}x{}",
        a.rows, a.cols, b.rows, b.cols
    );
    let mut out = Mat::zeros(a.cols, b.cols);
    let n = b.cols;
    let flops = a.rows * a.cols * n;
    for_row_stripes(&mut out, par, flops, |i0, i1, stripe| {
        for kk in (0..a.rows).step_by(BLOCK_K) {
            let kend = (kk + BLOCK_K).min(a.rows);
            for i in i0..i1 {
                let out_row = &mut stripe[(i - i0) * n..(i - i0 + 1) * n];
                for k in kk..kend {
                    let a_ki = a[(k, i)];
                    if a_ki == 0.0 {
                        continue;
                    }
                    let b_row = b.row(k);
                    for (o, &bv) in out_row.iter_mut().zip(b_row) {
                        *o += a_ki * bv;
                    }
                }
            }
        }
    });
    out
}

/// `a @ b^T` without materialising the transpose — the reconstruction's
/// `... Q_X^T` shape.  Row-by-row dot products (both operands are read
/// along rows, so this shape is cache-friendly without a k-tile), parallel
/// over output rows.
pub fn matmul_t(a: &Mat, b: &Mat, par: Parallelism) -> Mat {
    assert_eq!(
        a.cols, b.cols,
        "matmul_t shape mismatch {}x{} @ {}x{}^T",
        a.rows, a.cols, b.rows, b.cols
    );
    let mut out = Mat::zeros(a.rows, b.rows);
    let n = b.rows;
    let flops = a.rows * a.cols * n;
    for_row_stripes(&mut out, par, flops, |i0, i1, stripe| {
        for i in i0..i1 {
            let a_row = a.row(i);
            let out_row = &mut stripe[(i - i0) * n..(i - i0 + 1) * n];
            for (j, o) in out_row.iter_mut().enumerate() {
                let b_row = b.row(j);
                let mut acc = 0.0;
                for (&x, &y) in a_row.iter().zip(b_row) {
                    acc += x * y;
                }
                *o = acc;
            }
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Unblocked, unthreaded reference with the same ascending-k
    /// accumulation order the kernels guarantee.
    fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
        let mut out = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for k in 0..a.cols {
                for j in 0..b.cols {
                    out[(i, j)] += a[(i, k)] * b[(k, j)];
                }
            }
        }
        out
    }

    #[test]
    fn blocked_matmul_is_bitwise_naive() {
        let mut rng = Rng::new(11);
        // Spans multiple k-blocks (>BLOCK_K) and a tail block.
        let a = Mat::gaussian(9, 2 * BLOCK_K + 7, &mut rng);
        let b = Mat::gaussian(2 * BLOCK_K + 7, 13, &mut rng);
        let want = naive_matmul(&a, &b);
        for par in [
            Parallelism::Serial,
            Parallelism::Threads(2),
            Parallelism::Threads(4),
        ] {
            let got = matmul(&a, &b, par);
            assert_eq!(got.data, want.data, "par={par}");
        }
    }

    #[test]
    fn t_matmul_matches_transpose_matmul_bitwise() {
        let mut rng = Rng::new(12);
        let a = Mat::gaussian(BLOCK_K + 5, 17, &mut rng);
        let b = Mat::gaussian(BLOCK_K + 5, 11, &mut rng);
        let want = naive_matmul(&a.transpose(), &b);
        for par in [Parallelism::Serial, Parallelism::Threads(3)] {
            let got = t_matmul(&a, &b, par);
            assert_eq!(got.data, want.data, "par={par}");
        }
    }

    #[test]
    fn matmul_t_matches_transpose_path() {
        let mut rng = Rng::new(13);
        let a = Mat::gaussian(12, 33, &mut rng);
        let b = Mat::gaussian(21, 33, &mut rng);
        let want = naive_matmul(&a, &b.transpose());
        for par in [Parallelism::Serial, Parallelism::Threads(4)] {
            let got = matmul_t(&a, &b, par);
            // Same dot-product order per element; identical fp result.
            assert!(got.max_abs_diff(&want) < 1e-12, "par={par}");
        }
    }

    #[test]
    fn parallel_handles_more_threads_than_rows() {
        let mut rng = Rng::new(14);
        let a = Mat::gaussian(2, 300, &mut rng);
        let b = Mat::gaussian(300, 400, &mut rng);
        let got = matmul(&a, &b, Parallelism::Threads(16));
        assert_eq!(got.data, matmul(&a, &b, Parallelism::Serial).data);
    }

    #[test]
    fn degenerate_shapes() {
        let a = Mat::zeros(0, 5);
        let b = Mat::zeros(5, 3);
        let out = matmul(&a, &b, Parallelism::Threads(4));
        assert_eq!((out.rows, out.cols), (0, 3));
        let out = t_matmul(&Mat::zeros(4, 0), &Mat::zeros(4, 3), Parallelism::Threads(2));
        assert_eq!((out.rows, out.cols), (0, 3));
    }

    #[test]
    fn parallelism_knob() {
        assert_eq!(Parallelism::from_threads(0), Parallelism::Serial);
        assert_eq!(Parallelism::from_threads(1), Parallelism::Serial);
        assert_eq!(Parallelism::from_threads(4), Parallelism::Threads(4));
        assert_eq!(Parallelism::Threads(0).threads(), 1);
        assert!(!Parallelism::Serial.is_parallel());
        assert_eq!(format!("{}", Parallelism::Threads(4)), "4 threads");
    }
}
