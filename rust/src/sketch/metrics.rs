//! Sketch-derived monitoring metrics (paper §4.6) computed natively:
//! gradient-norm proxy ||Z||_F, stable-rank gradient-diversity estimate,
//! and the power-iteration spectral norm they rely on.

use super::matrix::Mat;
use super::triplet::SketchTriplet;

/// Spectral norm by power iteration on A^T A with a deterministic start
/// vector (mirrors `linalg.spectral_norm` in the AOT path).
pub fn spectral_norm_power(a: &Mat, iters: usize) -> f64 {
    let n = a.cols;
    let mut v: Vec<f64> = (0..n).map(|i| 1.0 + 0.01 * i as f64).collect();
    let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
    v.iter_mut().for_each(|x| *x /= norm);
    for _ in 0..iters {
        // w = A^T (A v)
        let mut av = vec![0.0; a.rows];
        for r in 0..a.rows {
            let row = a.row(r);
            av[r] = row.iter().zip(&v).map(|(x, y)| x * y).sum();
        }
        let mut w = vec![0.0; n];
        for r in 0..a.rows {
            let row = a.row(r);
            for (j, x) in row.iter().enumerate() {
                w[j] += x * av[r];
            }
        }
        let wn = (w.iter().map(|x| x * x).sum::<f64>() + 1e-300).sqrt();
        v = w.into_iter().map(|x| x / wn).collect();
    }
    let mut av = vec![0.0; a.rows];
    for r in 0..a.rows {
        av[r] = a.row(r).iter().zip(&v).map(|(x, y)| x * y).sum();
    }
    av.iter().map(|x| x * x).sum::<f64>().sqrt()
}

/// Stable rank ||A||_F^2 / ||A||_2^2 via power iteration (paper §4.6's
/// "without requiring expensive singular value decomposition").
pub fn stable_rank_power(a: &Mat, iters: usize) -> f64 {
    let f = a.fro_norm();
    if f == 0.0 {
        return 0.0;
    }
    let s = spectral_norm_power(a, iters);
    (f * f) / (s * s).max(1e-300)
}

/// Per-layer metric snapshot used by the monitor service.
#[derive(Clone, Debug, Default)]
pub struct LayerMetrics {
    pub z_norm: f64,
    pub stable_rank: f64,
    pub y_norm: f64,
    pub x_norm: f64,
}

pub fn triplet_metrics(t: &SketchTriplet, power_iters: usize) -> LayerMetrics {
    LayerMetrics {
        z_norm: t.z.fro_norm(),
        stable_rank: stable_rank_power(&t.y, power_iters),
        y_norm: t.y.fro_norm(),
        x_norm: t.x.fro_norm(),
    }
}

pub fn all_metrics(
    layers: &[SketchTriplet],
    power_iters: usize,
) -> Vec<LayerMetrics> {
    layers
        .iter()
        .map(|t| triplet_metrics(t, power_iters))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::eig;
    use crate::util::prop::Prop;

    #[test]
    fn power_iteration_matches_jacobi() {
        Prop::new(16).check("specnorm", |rng, i| {
            let m = 6 + i % 20;
            let n = 3 + i % 8;
            let a = Mat::gaussian(m, n, rng);
            let power = spectral_norm_power(&a, 60);
            let exact = eig::spectral_norm(&a);
            let rel = (power - exact).abs() / exact;
            if rel > 1e-3 {
                return Err(format!("power {power} vs exact {exact}"));
            }
            Ok(())
        });
    }

    #[test]
    fn stable_rank_bounds() {
        Prop::new(16).check("srank", |rng, i| {
            let n = 4 + i % 10;
            let a = Mat::gaussian(20, n, rng);
            let sr = stable_rank_power(&a, 60);
            // 1 <= stable rank <= rank <= n
            if !(0.99..=(n as f64) + 1e-6).contains(&sr) {
                return Err(format!("stable rank {sr} out of [1, {n}]"));
            }
            Ok(())
        });
    }

    #[test]
    fn stable_rank_of_rank_one_is_one() {
        let mut rng = crate::util::rng::Rng::new(30);
        let u = Mat::gaussian(20, 1, &mut rng);
        let v = Mat::gaussian(1, 8, &mut rng);
        let a = u.matmul(&v);
        let sr = stable_rank_power(&a, 80);
        assert!((sr - 1.0).abs() < 1e-6, "sr {sr}");
    }

    #[test]
    fn zero_matrix_metrics() {
        let t = SketchTriplet::zeros(8, 2, 0.9);
        let m = triplet_metrics(&t, 16);
        assert_eq!(m.z_norm, 0.0);
        assert_eq!(m.stable_rank, 0.0);
    }
}
