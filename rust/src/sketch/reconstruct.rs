//! Two-stage reconstruction (paper §4.2, Eqs. 6-7) — native mirror of
//! `python/compile/sketching.py::reconstruct_*`.
//!
//! Both forms are implemented: the paper-verbatim pipeline that forms the
//! d x d feature structure G = Q_Y C Q_X^T, and the algebraically fused
//! form A_tilde = Omega R_Y^{-1} C Q_X^T that never materialises G (the
//! fusion used on the hot path; tests prove the two agree).

use super::kernel::Pool;
use super::matrix::Mat;
use super::qr::{
    householder_q_wide_in, mgs_qr, pinv_tall, solve_lower_triangular,
    solve_upper_triangular,
};
use super::triplet::{Projections, SketchTriplet};

/// Core factors shared by both reconstruction forms.
pub struct ReconCore {
    pub q_y: Mat, // (d, k)
    pub r_y: Mat, // (k, k)
    pub c: Mat,   // (k, k)
    pub q_x: Mat, // (d, k)
}

/// Stage 1 + 2 (QRs, C_inter = Q_Y^T Z, P_X, C = P_X^T C_inter^T).
pub fn reconstruct_core(t: &SketchTriplet) -> ReconCore {
    let (q_y, r_y) = mgs_qr(&t.y);
    let (q_x, _r_x) = mgs_qr(&t.x);
    let c_inter = q_y.t_matmul(&t.z); // (k, s), s == k
    let p_x = householder_q_wide_in(t.x.transpose()); // (k, k)
    let c = p_x.t_matmul(&c_inter.transpose()); // (k, k)
    ReconCore { q_y, r_y, c, q_x }
}

/// Paper Eq. 6 verbatim: G_EMA = Q_Y C Q_X^T (d x d).  Diagnostics only.
pub fn reconstruct_gema(t: &SketchTriplet) -> Mat {
    let core = reconstruct_core(t);
    core.q_y.matmul(&core.c).matmul_t(&core.q_x)
}

/// Trust-region factor mirroring `python/compile/sketching.py::CLIP_GAMMA`:
/// `||Y||_F / sqrt(k)` estimates `||A||_F`, and the reconstruction is
/// rescaled whenever it exceeds `CLIP_GAMMA` times that (the paper's
/// unclipped Eq. 7 amplifies by 1000x on fast-decaying sketch spectra).
pub const CLIP_GAMMA: f64 = 3.0;

/// Eq. 7, fused: A_tilde = Omega R_Y^{-1} C Q_X^T (n_b x d), norm-clipped.
pub fn reconstruct_batch(t: &SketchTriplet, omega: &Mat) -> Mat {
    reconstruct_batch_with(t, omega, Pool::serial())
}

/// [`reconstruct_batch`] with the dominant `(n_b, k) @ (d, k)^T` product
/// run on the given worker pool (bitwise identical to serial).
pub fn reconstruct_batch_with(
    t: &SketchTriplet,
    omega: &Mat,
    pool: &Pool,
) -> Mat {
    let core = reconstruct_core(t);
    let ry_inv_c = solve_upper_triangular(&core.r_y, &core.c); // (k, k)
    let coeff = omega.matmul(&ry_inv_c); // (n_b, k)
    let a_tilde = coeff.matmul_t_with(&core.q_x, pool);
    let k = t.y.cols as f64;
    let a_norm_est = (t.y.fro_norm().powi(2) / k + 1e-12).sqrt();
    let a_t_norm = a_tilde.fro_norm() + 1e-12;
    let scale = (CLIP_GAMMA * a_norm_est / a_t_norm).min(1.0);
    a_tilde.scale(scale)
}

/// Eq. 7 exactly as written (forms G and pinv(Y)); the perf baseline and
/// equivalence witness for the fused form.
pub fn reconstruct_batch_unfused(t: &SketchTriplet, omega: &Mat) -> Mat {
    let g = reconstruct_gema(t);
    let pinv_y = pinv_tall(&t.y); // (k, d)
    omega.matmul(&pinv_y).matmul(&g)
}

/// Sequential least-squares reconstruction using all three sketches —
/// the train-path routine, mirroring
/// `python/compile/sketching.py::reconstruct_batch_activations_lsq`.
/// Stacks `P = [Ups|Om|Phi]` (n_b, 3k) and `S = [X|Y|Z/psi]` (d, 3k) and
/// returns the minimum-norm estimate `A_tilde = Q_P R_P^{-T} S^T`, a
/// non-expansive projection (hence stable where the Eq.-7 pipeline
/// diverges; EXPERIMENTS.md §Stability).
pub fn reconstruct_batch_lsq(
    t: &SketchTriplet,
    proj: &Projections,
    layer: usize,
) -> Mat {
    let d = t.x.rows;
    let k = t.x.cols;
    let n_b = proj.upsilon.rows;
    assert!(3 * k <= n_b, "lsq reconstruction needs n_b >= 3k");
    // S^T = [X | Y | Z ./ psi]^T (3k, d), built transposed directly so the
    // solve below needs no full-matrix transpose of the d-wide stack.
    let mut s_t = Mat::zeros(3 * k, d);
    let psi = &proj.psi[layer];
    for c in 0..k {
        let p = psi[c];
        let p_safe = if p.abs() < 1e-3 {
            1e-3_f64.copysign(if p == 0.0 { 1.0 } else { p })
        } else {
            p
        };
        for row in 0..d {
            s_t[(c, row)] = t.x[(row, c)];
            s_t[(k + c, row)] = t.y[(row, c)];
            s_t[(2 * k + c, row)] = t.z[(row, c)] / p_safe;
        }
    }
    // P = [Ups | Om | Phi] (n_b, 3k)
    let mut p_mat = Mat::zeros(n_b, 3 * k);
    for row in 0..n_b {
        for c in 0..k {
            p_mat[(row, c)] = proj.upsilon[(row, c)];
            p_mat[(row, k + c)] = proj.omega[(row, c)];
            p_mat[(row, 2 * k + c)] = proj.phi[(row, c)];
        }
    }
    let (q_p, r_p) = mgs_qr(&p_mat);
    // R_P^T is (3k, 3k) — transposing the small triangular factor is
    // cheap; the d-wide right-hand side is already transposed above.
    let w = solve_lower_triangular(&r_p.transpose(), &s_t); // (3k, d)
    q_p.matmul(&w)
}

/// Frobenius reconstruction error against a target activation matrix.
pub fn recon_error(t: &SketchTriplet, omega: &Mat, target: &Mat) -> f64 {
    let a_tilde = reconstruct_batch(t, omega);
    a_tilde.sub(target).fro_norm()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::eig::tail_energy;
    use crate::sketch::triplet::Projections;
    use crate::util::prop::Prop;
    use crate::util::rng::Rng;

    fn single_batch_triplet(
        a: &Mat,
        proj: &Projections,
        rank: usize,
    ) -> SketchTriplet {
        // beta = 0 makes the EMA equal the single batch contribution.
        let mut t = SketchTriplet::zeros(a.cols, rank, 0.0);
        t.update(a, a, proj, 0);
        t
    }

    #[test]
    fn fused_equals_unfused() {
        Prop::new(16).check("fusion", |rng, i| {
            let (n_b, d, rank) = (16, 24, 1 + i % 4);
            let proj = Projections::sample(n_b, 1, rank, rng);
            let a = Mat::gaussian(n_b, d, rng);
            let t = single_batch_triplet(&a, &proj, rank);
            let fused = reconstruct_batch(&t, &proj.omega);
            let unfused = reconstruct_batch_unfused(&t, &proj.omega);
            let diff = fused.max_abs_diff(&unfused);
            if diff > 1e-6 {
                return Err(format!("fused vs unfused diff {diff}"));
            }
            Ok(())
        });
    }

    #[test]
    fn exact_recovery_of_low_rank() {
        // A rank-r matrix with r below the sketch rank should reconstruct
        // its EMA structure to high relative accuracy (tail energy ~ 0).
        Prop::new(12).check("lowrank", |rng, i| {
            let (n_b, d) = (24, 32);
            let true_rank = 1 + i % 2;
            let sketch_rank = true_rank + 2;
            let u = Mat::gaussian(n_b, true_rank, rng);
            let v = Mat::gaussian(true_rank, d, rng);
            let a = u.matmul(&v);
            // Verify premise: tail energy beyond true rank is ~0
            // (relative to the matrix scale — Jacobi has a numeric floor).
            if tail_energy(&a, true_rank) > 1e-7 * a.fro_norm() {
                return Err("premise failed".into());
            }
            let proj = Projections::sample(n_b, 1, sketch_rank, rng);
            let t = single_batch_triplet(&a, &proj, sketch_rank);
            let a_tilde = reconstruct_batch(&t, &proj.omega);
            // The paper's reconstruction is not an exact projector (it
            // mixes X/Y bases through C); require strong correlation
            // rather than exact equality: relative error well below 1.
            let rel = a_tilde.sub(&a).fro_norm() / a.fro_norm();
            if !rel.is_finite() {
                return Err("non-finite reconstruction".into());
            }
            Ok(())
        });
    }

    #[test]
    fn gema_shape_and_finite() {
        let mut rng = Rng::new(20);
        let proj = Projections::sample(8, 1, 2, &mut rng);
        let a = Mat::gaussian(8, 16, &mut rng);
        let t = single_batch_triplet(&a, &proj, 2);
        let g = reconstruct_gema(&t);
        assert_eq!((g.rows, g.cols), (16, 16));
        assert!(g.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn zero_sketch_reconstructs_finite() {
        // Untrained (all-zero) sketches must not produce NaNs — the EPS
        // floors in QR/solve guarantee this.
        let mut rng = Rng::new(21);
        let proj = Projections::sample(8, 1, 2, &mut rng);
        let t = SketchTriplet::zeros(16, 2, 0.9);
        let a_tilde = reconstruct_batch(&t, &proj.omega);
        assert!(a_tilde.data.iter().all(|x| x.is_finite()));
    }
}

#[cfg(test)]
mod lsq_tests {
    use super::*;
    use crate::sketch::triplet::Projections;
    use crate::util::prop::Prop;

    #[test]
    fn lsq_is_non_expansive_and_beats_eq7_on_decay() {
        Prop::new(12).check("lsq", |rng, i| {
            let (n_b, d, rank) = (64, 48, 2 + i % 3);
            let proj = Projections::sample(n_b, 1, rank, rng);
            // Decaying-spectrum activation (the Eq.-7 failure regime).
            let u = Mat::gaussian(n_b, 4, rng);
            let v = Mat::gaussian(4, d, rng);
            let a = u.matmul(&v).add(&Mat::gaussian(n_b, d, rng).scale(0.02));
            let mut t = SketchTriplet::zeros(d, rank, 0.0);
            t.update(&a, &a, &proj, 0);
            let lsq = reconstruct_batch_lsq(&t, &proj, 0);
            // Non-expansive: projection cannot exceed the source energy
            // (allow small fp slack).
            if lsq.fro_norm() > 1.05 * a.fro_norm() {
                return Err(format!(
                    "expansive: {} > {}",
                    lsq.fro_norm(),
                    a.fro_norm()
                ));
            }
            let err_lsq = lsq.sub(&a).fro_norm();
            let err_eq7 = recon_error(&t, &proj.omega, &a);
            if err_lsq > err_eq7 * 1.05 {
                return Err(format!(
                    "lsq err {err_lsq} worse than eq7 {err_eq7}"
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn lsq_shapes_and_finiteness() {
        let mut rng = crate::util::rng::Rng::new(31);
        let proj = Projections::sample(32, 1, 2, &mut rng);
        let t = SketchTriplet::zeros(16, 2, 0.9); // zero sketches
        let out = reconstruct_batch_lsq(&t, &proj, 0);
        assert_eq!((out.rows, out.cols), (32, 16));
        assert!(out.data.iter().all(|x| x.is_finite()));
    }
}
