//! Pure-rust sketching substrate: dense linear algebra, the EMA
//! three-sketch triplet (paper §4.1), two-stage reconstruction (§4.2),
//! spectra (Jacobi) and the sketch-derived monitoring metrics (§4.6).
//!
//! The public entry point is the builder-configured [`engine::SketchEngine`]
//! (heterogeneous layer widths, variable batch sizes, rank changes); the
//! lower-level triplet/projection types stay available for the AOT
//! cross-validation tests that must inject externally-fixed projections.
//!
//! This mirrors the AOT python path (`python/compile/{linalg,sketching}.py`)
//! so the monitoring hot path and the adaptive-rank controller run without
//! PJRT round-trips; integration tests cross-validate both sides.

pub mod eig;
pub mod engine;
pub mod kernel;
pub mod matrix;
pub mod metrics;
pub mod qr;
pub mod reconstruct;
pub mod triplet;

pub use engine::{
    engine_state_bytes, EngineSnapshot, Precision, SketchConfig,
    SketchConfigBuilder, SketchEngine, Sketcher, TripletState, Workspace,
};
pub use kernel::{Parallelism, Pool};
pub use matrix::Mat;
pub use triplet::{Projections, SketchTriplet};
