//! QR factorizations mirroring `python/compile/linalg.py`:
//! modified Gram–Schmidt with re-orthogonalisation for tall matrices and
//! Householder for the wide `P_X` factor.

use super::matrix::Mat;

const EPS: f64 = 1e-12;

/// Economy QR of a tall matrix (m x n, m >= n) via MGS2.
/// Returns (Q m x n with orthonormal columns, R n x n upper triangular).
pub fn mgs_qr(a: &Mat) -> (Mat, Mat) {
    let (m, n) = (a.rows, a.cols);
    assert!(m >= n, "mgs_qr needs tall input, got {m}x{n}");
    let mut q = Mat::zeros(m, n);
    let mut r = Mat::zeros(n, n);
    for j in 0..n {
        let mut v: Vec<f64> = (0..m).map(|i| a[(i, j)]).collect();
        // Two projection passes ("twice is enough").
        for _pass in 0..2 {
            for p in 0..j {
                let mut coeff = 0.0;
                for i in 0..m {
                    coeff += q[(i, p)] * v[i];
                }
                for i in 0..m {
                    v[i] -= coeff * q[(i, p)];
                }
                r[(p, j)] += coeff;
            }
        }
        let norm = (v.iter().map(|x| x * x).sum::<f64>() + EPS).sqrt();
        r[(j, j)] = norm;
        for i in 0..m {
            q[(i, j)] = v[i] / norm;
        }
    }
    (q, r)
}

/// Full orthogonal Q factor (k x k) of the QR of a wide matrix (k x d,
/// k <= d) via Householder reflections; R is discarded (the reconstruction
/// only consumes P_X).
pub fn householder_q_wide(a: &Mat) -> Mat {
    householder_q_wide_in(a.clone())
}

/// [`householder_q_wide`] consuming its input as the working buffer —
/// call sites that already own a freshly-built matrix (e.g. the
/// reconstruction's `X^T`) skip the defensive clone.
pub fn householder_q_wide_in(a: Mat) -> Mat {
    let (k, d) = (a.rows, a.cols);
    assert!(k <= d, "householder_q_wide needs wide input, got {k}x{d}");
    let mut r = a;
    let mut q = Mat::eye(k);
    for j in 0..k {
        // Reflector from column j, rows j..k.
        let mut x = vec![0.0; k];
        for i in j..k {
            x[i] = r[(i, j)];
        }
        let alpha_mag = (x.iter().map(|v| v * v).sum::<f64>() + EPS).sqrt();
        let alpha = if x[j] >= 0.0 { -alpha_mag } else { alpha_mag };
        x[j] -= alpha;
        let vnorm = (x.iter().map(|v| v * v).sum::<f64>() + EPS).sqrt();
        for v in x.iter_mut() {
            *v /= vnorm;
        }
        // r -= 2 v (v^T r); q -= 2 (q v) v^T
        for c in 0..d {
            let mut dot = 0.0;
            for i in j..k {
                dot += x[i] * r[(i, c)];
            }
            for i in j..k {
                r[(i, c)] -= 2.0 * x[i] * dot;
            }
        }
        for row in 0..k {
            let mut dot = 0.0;
            for i in j..k {
                dot += q[(row, i)] * x[i];
            }
            for i in j..k {
                q[(row, i)] -= 2.0 * dot * x[i];
            }
        }
    }
    q
}

/// Solve R X = B for upper-triangular R (n x n), B (n x p).
///
/// Truncated solve mirroring `python/compile/linalg.py`: solution rows
/// whose pivot falls below `RCOND * max|diag|` are zeroed — the
/// triangular-solve analogue of a truncated pseudoinverse.  The paper's
/// unregularized `R_Y^{-1}` in Eq. 7 explodes on fast-decaying sketch
/// spectra (DESIGN.md §7).
pub const SOLVE_RCOND: f64 = 1e-4;

pub fn solve_upper_triangular(r: &Mat, b: &Mat) -> Mat {
    let n = r.rows;
    assert_eq!(r.cols, n);
    assert_eq!(b.rows, n);
    let p = b.cols;
    let max_diag = (0..n).map(|i| r[(i, i)].abs()).fold(0.0, f64::max);
    let floor = SOLVE_RCOND * max_diag + EPS;
    let mut x = Mat::zeros(n, p);
    for row in (0..n).rev() {
        for c in 0..p {
            let mut acc = b[(row, c)];
            for j in row + 1..n {
                acc -= r[(row, j)] * x[(j, c)];
            }
            let diag = r[(row, row)];
            x[(row, c)] = if diag.abs() >= floor { acc / diag } else { 0.0 };
        }
    }
    x
}

/// Solve L X = B for lower-triangular L by forward substitution, with the
/// same truncated-pivot policy as the upper solver.
pub fn solve_lower_triangular(l: &Mat, b: &Mat) -> Mat {
    let n = l.rows;
    assert_eq!(l.cols, n);
    assert_eq!(b.rows, n);
    let p = b.cols;
    let max_diag = (0..n).map(|i| l[(i, i)].abs()).fold(0.0, f64::max);
    let floor = SOLVE_RCOND * max_diag + EPS;
    let mut x = Mat::zeros(n, p);
    for row in 0..n {
        for c in 0..p {
            let mut acc = b[(row, c)];
            for j in 0..row {
                acc -= l[(row, j)] * x[(j, c)];
            }
            let diag = l[(row, row)];
            x[(row, c)] = if diag.abs() >= floor { acc / diag } else { 0.0 };
        }
    }
    x
}

/// Solve R X = B^T for upper-triangular R (n x n) with B given
/// *untransposed* (p x n) — the right-hand side is read through swapped
/// indices, so no transpose of B is ever materialised.  Same truncated
/// pivots as [`solve_upper_triangular`].
pub fn solve_upper_triangular_tb(r: &Mat, b: &Mat) -> Mat {
    let n = r.rows;
    assert_eq!(r.cols, n);
    assert_eq!(b.cols, n, "rhs^T needs {n} columns in b");
    let p = b.rows;
    let max_diag = (0..n).map(|i| r[(i, i)].abs()).fold(0.0, f64::max);
    let floor = SOLVE_RCOND * max_diag + EPS;
    let mut x = Mat::zeros(n, p);
    for row in (0..n).rev() {
        for c in 0..p {
            let mut acc = b[(c, row)];
            for j in row + 1..n {
                acc -= r[(row, j)] * x[(j, c)];
            }
            let diag = r[(row, row)];
            x[(row, c)] = if diag.abs() >= floor { acc / diag } else { 0.0 };
        }
    }
    x
}

/// Moore–Penrose pseudoinverse of a tall full-column-rank matrix via
/// economy QR: `a^+ = R^{-1} Q^T` (n x m) — `Q^T` stays virtual via the
/// transposed-rhs solver.
pub fn pinv_tall(a: &Mat) -> Mat {
    let (q, r) = mgs_qr(a);
    solve_upper_triangular_tb(&r, &q)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Prop;
    use crate::util::rng::Rng;

    fn orth_err(q: &Mat) -> f64 {
        let qtq = q.t_matmul(q);
        let n = q.cols;
        let mut err: f64 = 0.0;
        for i in 0..n {
            for j in 0..n {
                let want = if i == j { 1.0 } else { 0.0 };
                err = err.max((qtq[(i, j)] - want).abs());
            }
        }
        err
    }

    #[test]
    fn mgs_reconstructs_and_is_orthonormal() {
        Prop::new(32).check("mgs_qr", |rng, i| {
            let m = 8 + (i % 40);
            let n = 1 + (i % 7).min(m - 1);
            let a = Mat::gaussian(m, n, rng);
            let (q, r) = mgs_qr(&a);
            let recon = q.matmul(&r);
            if recon.max_abs_diff(&a) > 1e-9 {
                return Err(format!("recon err {}", recon.max_abs_diff(&a)));
            }
            if orth_err(&q) > 1e-9 {
                return Err(format!("orth err {}", orth_err(&q)));
            }
            // R upper triangular
            for i in 0..n {
                for j in 0..i {
                    if r[(i, j)].abs() > 1e-12 {
                        return Err("R not upper triangular".to_string());
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn householder_q_is_orthogonal() {
        Prop::new(32).check("householder", |rng, i| {
            let k = 2 + (i % 12);
            let d = k + (i % 50);
            let a = Mat::gaussian(k, d, rng);
            let q = householder_q_wide(&a);
            if orth_err(&q) > 1e-7 {
                return Err(format!("orth err {}", orth_err(&q)));
            }
            // Q^T A must be upper-trapezoidal (zeros below diagonal).
            let r = q.t_matmul(&a);
            for i in 0..k {
                for j in 0..i.min(r.cols) {
                    if r[(i, j)].abs() > 1e-8 {
                        return Err(format!(
                            "R[{i},{j}] = {} not zero",
                            r[(i, j)]
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn trisolve_and_pinv() {
        Prop::new(32).check("pinv", |rng, i| {
            let m = 10 + (i % 30);
            let n = 2 + (i % 6);
            let a = Mat::gaussian(m, n, rng);
            let pinv = pinv_tall(&a);
            // a^+ a = I_n
            let id = pinv.matmul(&a);
            let mut err: f64 = 0.0;
            for r in 0..n {
                for c in 0..n {
                    let want = if r == c { 1.0 } else { 0.0 };
                    err = err.max((id[(r, c)] - want).abs());
                }
            }
            if err > 1e-8 {
                return Err(format!("pinv err {err}"));
            }
            Ok(())
        });
    }

    #[test]
    fn tb_solve_matches_explicit_transpose() {
        Prop::new(24).check("tb_solve", |rng, i| {
            let n = 2 + i % 8;
            let p = 1 + i % 5;
            let a = Mat::gaussian(n + 4, n, rng);
            let (_q, r) = mgs_qr(&a);
            let b = Mat::gaussian(p, n, rng);
            let fast = solve_upper_triangular_tb(&r, &b);
            let slow = solve_upper_triangular(&r, &b.transpose());
            let diff = fast.max_abs_diff(&slow);
            if diff > 1e-12 {
                return Err(format!("tb vs transpose diff {diff}"));
            }
            Ok(())
        });
    }

    #[test]
    fn mgs_handles_near_rank_deficient() {
        let mut rng = Rng::new(99);
        let mut a = Mat::gaussian(20, 4, &mut rng);
        // Make column 3 a copy of column 0 (exactly dependent).
        for i in 0..20 {
            a[(i, 3)] = a[(i, 0)];
        }
        let (q, r) = mgs_qr(&a);
        // Must stay finite and still reconstruct.
        assert!(q.data.iter().all(|x| x.is_finite()));
        assert!(q.matmul(&r).max_abs_diff(&a) < 1e-6);
    }
}

#[cfg(test)]
mod lower_tests {
    use super::*;
    use crate::util::prop::Prop;

    #[test]
    fn lower_solve_matches_upper_on_transpose() {
        Prop::new(24).check("lower", |rng, i| {
            let n = 2 + i % 10;
            let p = 1 + i % 4;
            // Well-conditioned lower-triangular via QR's R transposed +
            // diagonal boost.
            let a = Mat::gaussian(n + 4, n, rng);
            let (_q, r) = mgs_qr(&a);
            let l = r.transpose();
            let b = Mat::gaussian(n, p, rng);
            let x = solve_lower_triangular(&l, &b);
            let resid = l.matmul(&x).sub(&b).fro_norm();
            if resid > 1e-8 * (1.0 + b.fro_norm()) {
                return Err(format!("resid {resid}"));
            }
            Ok(())
        });
    }
}
