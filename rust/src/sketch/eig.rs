//! Symmetric eigensolver (cyclic Jacobi) — the substrate piece behind
//! singular values, tail energy tau_{r+1} (Thm 4.2's bound) and exact
//! stable-rank references.  LAPACK is unavailable both offline and inside
//! the AOT artifacts, so spectra are computed here.

use super::matrix::Mat;

/// Eigenvalues of a symmetric matrix via cyclic Jacobi rotations.
/// Returns eigenvalues sorted descending.  O(n^3) per sweep; converges in
/// ~log(n) sweeps for the modest n (<= a few hundred) this repo needs.
/// Degenerate inputs are well-defined: a 0x0 matrix has no eigenvalues
/// (empty result) and a zero matrix converges on the first sweep.
pub fn sym_eigenvalues(a: &Mat, max_sweeps: usize) -> Vec<f64> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    if n == 0 {
        return Vec::new();
    }
    let mut m = a.clone();
    for _ in 0..max_sweeps {
        let mut off = 0.0;
        for i in 0..n {
            for j in i + 1..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if off.sqrt() < 1e-12 * (1.0 + m.fro_norm()) {
            break;
        }
        for p in 0..n {
            for q in p + 1..n {
                let apq = m[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Apply rotation J(p,q,theta) on both sides.
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
            }
        }
    }
    let mut ev: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    ev.sort_by(|a, b| b.partial_cmp(a).unwrap());
    ev
}

/// Singular values of an arbitrary matrix via the Gram matrix of its
/// smaller side (sigma_i = sqrt(lambda_i(A^T A))), sorted descending.
/// An empty matrix (either dimension 0) has no singular values.
pub fn singular_values(a: &Mat) -> Vec<f64> {
    if a.rows == 0 || a.cols == 0 {
        return Vec::new();
    }
    let gram = if a.rows <= a.cols {
        // A A^T (rows x rows), transpose-free
        a.matmul_t(a)
    } else {
        a.t_matmul(a)
    };
    sym_eigenvalues(&gram, 30)
        .into_iter()
        .map(|l| l.max(0.0).sqrt())
        .collect()
}

/// (r+1)-st tail energy: tau_{r+1}(A) = sqrt(sum_{i > r} sigma_i^2)
/// (paper Eq. 4 / Thm 4.2).
pub fn tail_energy(a: &Mat, r: usize) -> f64 {
    let sv = singular_values(a);
    sv.iter().skip(r).map(|s| s * s).sum::<f64>().sqrt()
}

/// Spectral norm ||A||_2 (largest singular value).
pub fn spectral_norm(a: &Mat) -> f64 {
    singular_values(a).first().copied().unwrap_or(0.0)
}

/// Exact stable rank ||A||_F^2 / ||A||_2^2 — the reference the sketch-based
/// estimate (power iteration) is validated against.
pub fn stable_rank(a: &Mat) -> f64 {
    let f = a.fro_norm();
    let s = spectral_norm(a);
    if s == 0.0 {
        0.0
    } else {
        (f * f) / (s * s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Prop;
    use crate::util::rng::Rng;

    #[test]
    fn diagonal_eigenvalues() {
        let mut d = Mat::zeros(4, 4);
        for (i, v) in [5.0, -1.0, 3.0, 0.5].iter().enumerate() {
            d[(i, i)] = *v;
        }
        let ev = sym_eigenvalues(&d, 10);
        assert_eq!(ev, vec![5.0, 3.0, 0.5, -1.0]);
    }

    #[test]
    fn eigenvalue_sum_is_trace() {
        Prop::new(24).check("trace", |rng, i| {
            let n = 3 + (i % 10);
            let g = Mat::gaussian(n, n, rng);
            let sym = g.add(&g.transpose()).scale(0.5);
            let trace: f64 = (0..n).map(|i| sym[(i, i)]).sum();
            let ev = sym_eigenvalues(&sym, 30);
            let sum: f64 = ev.iter().sum();
            if (trace - sum).abs() > 1e-8 * (1.0 + trace.abs()) {
                return Err(format!("trace {trace} vs sum {sum}"));
            }
            Ok(())
        });
    }

    #[test]
    fn singular_values_of_orthogonal_cols() {
        // Q from QR has all singular values 1.
        let mut rng = Rng::new(8);
        let a = Mat::gaussian(30, 5, &mut rng);
        let (q, _) = crate::sketch::qr::mgs_qr(&a);
        let sv = singular_values(&q);
        for s in sv {
            assert!((s - 1.0).abs() < 1e-8, "sv {s}");
        }
    }

    #[test]
    fn tail_energy_low_rank_matrix_is_zero() {
        // rank-2 matrix: tau_3 ~ 0, tau_1 > 0.
        let mut rng = Rng::new(9);
        let u = Mat::gaussian(20, 2, &mut rng);
        let v = Mat::gaussian(2, 15, &mut rng);
        let a = u.matmul(&v);
        let rel_floor = 1e-7 * a.fro_norm();
        assert!(tail_energy(&a, 2) < rel_floor, "tail {}", tail_energy(&a, 2));
        assert!(tail_energy(&a, 0) > 1.0);
    }

    #[test]
    fn degenerate_inputs_are_well_defined() {
        // Empty matrices: no spectrum, zero norms — not a panic.  The
        // archive's drift query hits these on cold sessions.
        let empty_sq = Mat::zeros(0, 0);
        assert!(sym_eigenvalues(&empty_sq, 10).is_empty());
        assert!(singular_values(&empty_sq).is_empty());
        assert!(singular_values(&Mat::zeros(0, 5)).is_empty());
        assert!(singular_values(&Mat::zeros(5, 0)).is_empty());
        assert_eq!(spectral_norm(&empty_sq), 0.0);
        assert_eq!(stable_rank(&empty_sq), 0.0);
        assert_eq!(tail_energy(&Mat::zeros(0, 3), 1), 0.0);

        // Zero matrices: all-zero spectrum, stable rank 0.0 (not NaN).
        let z = Mat::zeros(4, 6);
        let sv = singular_values(&z);
        assert_eq!(sv.len(), 4);
        assert!(sv.iter().all(|s| *s == 0.0));
        assert_eq!(sym_eigenvalues(&Mat::zeros(3, 3), 10), vec![0.0; 3]);
        assert_eq!(spectral_norm(&z), 0.0);
        assert_eq!(stable_rank(&z), 0.0);
        assert_eq!(tail_energy(&z, 2), 0.0);
    }

    #[test]
    fn frobenius_identity() {
        // ||A||_F^2 = sum sigma_i^2.
        let mut rng = Rng::new(10);
        let a = Mat::gaussian(12, 9, &mut rng);
        let sv = singular_values(&a);
        let sum: f64 = sv.iter().map(|s| s * s).sum();
        let f2 = a.fro_norm().powi(2);
        assert!((sum - f2).abs() < 1e-8 * f2);
    }
}
