//! Builder-configured sketching engine: the public entry point to the EMA
//! three-sketch substrate (paper §4.1).
//!
//! `SketchConfig`/`SketchConfigBuilder` describe a network's sketching
//! setup — per-layer hidden widths (`layer_dims`), rank, EMA beta, seed
//! and accounting precision — and `SketchEngine` owns the triplets and
//! projections behind the narrow [`Sketcher`] surface:
//! `ingest(acts)`, `reconstruct(layer)`, `metrics()`, `set_rank(r)`,
//! `memory()`.
//!
//! Two generalisations over the seed `LayerSketches` API:
//! * **Heterogeneous widths** — every hidden layer carries its own d, so
//!   funnel-shaped MLPs (e.g. 128/64/32) sketch naturally; Lemma 4.1
//!   holds per layer at that layer's width.
//! * **Variable batch sizes** — batch projections (Upsilon/Omega/Phi) are
//!   resampled lazily per *observed* batch size and cached, so tail
//!   batches smaller than the nominal n_b and multi-dataset feeds just
//!   work.  Sampling is keyed on (seed, rank, n_b): the same batch size
//!   always sees the same projections regardless of arrival order, which
//!   keeps the per-size EMA contributions consistent (Lemma 4.1 requires
//!   a fixed Upsilon per batch size).  Psi is batch-size independent and
//!   shared by every cached projection set.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::util::rng::Rng;

use super::kernel::{Parallelism, Pool};
use super::matrix::Mat;
use super::metrics::{all_metrics, LayerMetrics};
use super::reconstruct::reconstruct_batch_with;
use super::triplet::{Projections, SketchTriplet};

/// Stream constants mixing seed, rank and batch size into independent
/// deterministic RNG streams (splitmix-style odd multipliers).
const PSI_STREAM: u64 = 0x9E3779B97F4A7C15;
const BATCH_STREAM: u64 = 0xD1B54A32D192ED03;
const RANK_STREAM: u64 = 0x2545F4914F6CDD1D;

/// Power-iteration count used by `metrics()` (matches the monitoring AOT
/// artifacts; see `sketch::metrics`).
pub const METRIC_POWER_ITERS: usize = 24;

/// Accounting precision: the byte width the memory accountant charges per
/// matrix element.  The native substrate computes in f64 but the runtime
/// dtype (and the paper's memory model) is f32, hence the default.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    F32,
    F64,
}

impl Precision {
    pub fn bytes(self) -> usize {
        match self {
            Precision::F32 => 4,
            Precision::F64 => 8,
        }
    }
}

/// Immutable engine configuration produced by [`SketchConfigBuilder`].
#[derive(Clone, Debug)]
pub struct SketchConfig {
    /// Hidden-layer widths d_1..d_H (one entry per sketched layer).
    pub layer_dims: Vec<usize>,
    pub rank: usize,
    pub beta: f64,
    pub seed: u64,
    pub precision: Precision,
    /// Worker pool for ingest/reconstruct kernels.  A throughput knob
    /// only: results are bitwise identical to `Serial` (kernel contract).
    pub parallelism: Parallelism,
}

impl SketchConfig {
    pub fn builder() -> SketchConfigBuilder {
        SketchConfigBuilder::default()
    }

    pub fn k(&self) -> usize {
        2 * self.rank + 1
    }

    pub fn n_layers(&self) -> usize {
        self.layer_dims.len()
    }

    /// Width of the activation entering layer `l`'s weight: layer 0
    /// sketches its own output as input (the seed convention for A^[1]),
    /// deeper layers take the previous hidden width.
    pub fn d_in(&self, l: usize) -> usize {
        if l == 0 {
            self.layer_dims[0]
        } else {
            self.layer_dims[l - 1]
        }
    }

    pub fn d_out(&self, l: usize) -> usize {
        self.layer_dims[l]
    }

    /// The fixed accountant: exact bytes a `SketchEngine` built from this
    /// config holds after observing the given batch sizes (duplicates
    /// ignored).  Mirrors [`engine_state_bytes`].
    pub fn expected_bytes(&self, batch_sizes: &[usize]) -> usize {
        engine_state_bytes(
            &self.layer_dims,
            self.rank,
            batch_sizes,
            self.precision.bytes(),
        )
    }
}

/// The accountant formula shared by `SketchConfig::expected_bytes`,
/// `SketchEngine::memory` and the coordinator's memory model:
/// per layer (d_in + 2 d_out) k `unit` bytes of sketches, 3 n_b k `unit`
/// bytes of batch projections per distinct observed batch size, and the
/// shared Psi counted once at its stored f64 width (8 B — the seed
/// under-counted this at 4 B).
pub fn engine_state_bytes(
    layer_dims: &[usize],
    rank: usize,
    batch_sizes: &[usize],
    unit: usize,
) -> usize {
    let k = 2 * rank + 1;
    let mut sketches = 0usize;
    for (l, &d_out) in layer_dims.iter().enumerate() {
        let d_in = if l == 0 { layer_dims[0] } else { layer_dims[l - 1] };
        sketches += (d_in + 2 * d_out) * k * unit;
    }
    let distinct: std::collections::BTreeSet<usize> =
        batch_sizes.iter().copied().collect();
    let proj: usize = distinct.iter().map(|n_b| 3 * n_b * k * unit).sum();
    let psi = layer_dims.len() * k * 8;
    sketches + proj + psi
}

/// Builder with validation; the only way call sites outside the sketch
/// module configure sketching.
#[derive(Clone, Debug)]
pub struct SketchConfigBuilder {
    layer_dims: Vec<usize>,
    rank: usize,
    beta: f64,
    seed: u64,
    precision: Precision,
    parallelism: Parallelism,
}

impl Default for SketchConfigBuilder {
    fn default() -> Self {
        SketchConfigBuilder {
            layer_dims: Vec::new(),
            rank: 2,
            beta: 0.9,
            seed: 42,
            precision: Precision::F32,
            parallelism: Parallelism::Serial,
        }
    }
}

impl SketchConfigBuilder {
    /// Per-layer hidden widths (heterogeneous allowed).
    pub fn layer_dims(mut self, dims: &[usize]) -> Self {
        self.layer_dims = dims.to_vec();
        self
    }

    /// Uniform-width convenience: `n_layers` hidden layers of width `d`.
    pub fn uniform_dims(mut self, n_layers: usize, d: usize) -> Self {
        self.layer_dims = vec![d; n_layers];
        self
    }

    pub fn rank(mut self, rank: usize) -> Self {
        self.rank = rank;
        self
    }

    pub fn beta(mut self, beta: f64) -> Self {
        self.beta = beta;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn precision(mut self, precision: Precision) -> Self {
        self.precision = precision;
        self
    }

    pub fn parallelism(mut self, parallelism: Parallelism) -> Self {
        self.parallelism = parallelism;
        self
    }

    /// Thread-count convenience: 0 and 1 mean the serial path.
    pub fn threads(self, n: usize) -> Self {
        self.parallelism(Parallelism::from_threads(n))
    }

    pub fn build(self) -> Result<SketchConfig> {
        if self.layer_dims.is_empty() {
            bail!("sketch config needs at least one hidden layer width");
        }
        if let Some(l) = self.layer_dims.iter().position(|&d| d == 0) {
            bail!("layer {l} has zero width");
        }
        if self.rank == 0 {
            bail!("rank must be >= 1 (k = 2r + 1)");
        }
        if !(0.0..1.0).contains(&self.beta) {
            bail!("beta {} outside [0, 1)", self.beta);
        }
        Ok(SketchConfig {
            layer_dims: self.layer_dims,
            rank: self.rank,
            beta: self.beta,
            seed: self.seed,
            precision: self.precision,
            parallelism: self.parallelism,
        })
    }

    /// Build the config and stand the engine up in one call.
    pub fn build_engine(self) -> Result<SketchEngine> {
        Ok(SketchEngine::new(self.build()?))
    }
}

/// Reusable per-engine execution workspace: the persistent worker-pool
/// handle every fused ingest/reconstruct kernel runs on.
///
/// The pool is the *only* resource here by design: the fused EMA kernels
/// ([`super::kernel::t_matmul_ema`]) accumulate contributions in
/// registers and write straight into the resident X/Y/Z sketches, so
/// steady-state ingest needs no scratch buffers at all — and therefore
/// performs **zero heap allocations** (pinned by the counting-allocator
/// test).  For the memory accountant the workspace contributes 0 bytes:
/// pool threads are execution resources, not sketch state.
///
/// Cloning shares the pool (an `Arc`); [`Workspace::shared`] is how
/// `sketchd` hands one process-lifetime pool to every tenant engine.
#[derive(Clone, Debug)]
pub struct Workspace {
    pool: Arc<Pool>,
}

impl Workspace {
    /// Workspace with its own pool sized by the config knob.
    pub fn new(par: Parallelism) -> Workspace {
        Workspace {
            pool: Pool::new(par),
        }
    }

    /// Workspace over an existing shared pool.
    pub fn shared(pool: Arc<Pool>) -> Workspace {
        Workspace { pool }
    }

    pub fn pool(&self) -> &Arc<Pool> {
        &self.pool
    }
}

/// Plain-data image of one triplet's EMA state ([`EngineSnapshot`]).
#[derive(Clone, Debug)]
pub struct TripletState {
    pub x: Mat,
    pub y: Mat,
    pub z: Mat,
    pub updates: u64,
}

/// Plain-data image of a `SketchEngine` for durable snapshots and the
/// serve wire format: the triplets' EMA state plus everything needed to
/// re-derive the random state (Psi and the per-batch-size projections are
/// deterministic in (seed, rank, n_b), so only the observed batch sizes
/// are recorded, not the projection matrices themselves).
///
/// `Parallelism` is deliberately absent: it is a runtime throughput knob
/// with no effect on numerics, so the restoring host chooses its own.
#[derive(Clone, Debug)]
pub struct EngineSnapshot {
    pub layer_dims: Vec<usize>,
    pub rank: usize,
    pub beta: f64,
    pub seed: u64,
    pub precision: Precision,
    pub triplets: Vec<TripletState>,
    /// Distinct batch sizes observed (ascending) — projections are
    /// resampled from (seed, rank, n_b) on restore.
    pub batch_sizes: Vec<usize>,
    pub last_batch: Option<usize>,
    pub batches_ingested: u64,
}

/// The narrow surface call sites program against.
pub trait Sketcher {
    /// Ingest one forward pass: `acts[0]` is the input batch, `acts[j]`
    /// (j >= 1) the j-th hidden activation, all with the same row count.
    fn ingest(&mut self, acts: &[Mat]) -> Result<()>;
    /// Eq.-7 reconstruction of the layer's incoming activation estimate
    /// using the most recently observed batch size's Omega.
    ///
    /// Caveat for mixed batch-size streams: the EMA sketches blend
    /// contributions projected through each batch size's own
    /// Upsilon/Omega/Phi, while Eq. 7 (and the Thm-4.2 bound) assume one
    /// fixed projection set.  With a single observed batch size the
    /// paper's guarantees apply verbatim; after a tail batch or a
    /// multi-size feed the result is a best-effort estimate dominated by
    /// the majority batch size's contributions — fine for the monitoring
    /// diagnostics built on sketch norms, but not covered by the bound.
    fn reconstruct(&self, layer: usize) -> Result<Mat>;
    /// Per-layer monitoring metrics (||Z||_F, stable rank, ...).
    fn metrics(&self) -> Vec<LayerMetrics>;
    /// Rank change (Algorithm 1 lines 16/21/23): zero sketches, resample
    /// Psi and drop cached batch projections at the new k = 2r + 1.
    /// `r = 0` is clamped to 1 (k = 3) — unlike the builder, this cannot
    /// fail, so the degenerate request maps to the smallest valid rank.
    fn set_rank(&mut self, r: usize);
    /// Measured bytes currently held, per the fixed accountant.
    fn memory(&self) -> usize;
}

/// Owns the per-layer triplets, the shared Psi and the lazily-sampled
/// per-batch-size projections for one training run.
#[derive(Clone, Debug)]
pub struct SketchEngine {
    cfg: SketchConfig,
    layers: Vec<SketchTriplet>,
    /// Shared per-layer Psi (length k each): one `Arc` allocation shared
    /// with every cached projection set, hence accounted once.
    psi: Arc<Vec<Vec<f64>>>,
    /// Batch projections keyed by observed batch size.
    proj: BTreeMap<usize, Projections>,
    /// Persistent worker-pool handle for the fused kernels; cloning an
    /// engine shares the pool.
    ws: Workspace,
    last_batch: Option<usize>,
    batches_ingested: u64,
}

impl SketchEngine {
    pub fn new(cfg: SketchConfig) -> Self {
        let ws = Workspace::new(cfg.parallelism);
        Self::with_workspace(cfg, ws)
    }

    /// Engine over a shared worker pool — how `sketchd` multiplexes many
    /// tenant engines onto one process-lifetime pool.  The pool wins
    /// over `cfg.parallelism` (which remains the config-surface record
    /// of the requested width).
    pub fn with_pool(cfg: SketchConfig, pool: Arc<Pool>) -> Self {
        Self::with_workspace(cfg, Workspace::shared(pool))
    }

    fn with_workspace(cfg: SketchConfig, ws: Workspace) -> Self {
        let (layers, psi) = Self::fresh_state(&cfg);
        SketchEngine {
            cfg,
            layers,
            psi,
            proj: BTreeMap::new(),
            ws,
            last_batch: None,
            batches_ingested: 0,
        }
    }

    /// The engine's execution workspace (worker-pool handle).
    pub fn workspace(&self) -> &Workspace {
        &self.ws
    }

    /// The worker pool ingest/reconstruct kernels run on — share it
    /// (`Arc::clone`) to run several engines on one set of threads.
    pub fn pool(&self) -> &Arc<Pool> {
        self.ws.pool()
    }

    fn fresh_state(
        cfg: &SketchConfig,
    ) -> (Vec<SketchTriplet>, Arc<Vec<Vec<f64>>>) {
        let k = cfg.k();
        let mut psi_rng = Rng::new(
            cfg.seed ^ PSI_STREAM ^ (cfg.rank as u64).wrapping_mul(RANK_STREAM),
        );
        let psi = Arc::new(
            (0..cfg.n_layers())
                .map(|_| psi_rng.normal_vec(k))
                .collect::<Vec<_>>(),
        );
        let layers = (0..cfg.n_layers())
            .map(|l| {
                SketchTriplet::with_dims(
                    cfg.d_in(l),
                    cfg.d_out(l),
                    cfg.rank,
                    cfg.beta,
                )
            })
            .collect();
        (layers, psi)
    }

    pub fn config(&self) -> &SketchConfig {
        &self.cfg
    }

    pub fn k(&self) -> usize {
        self.cfg.k()
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Read access to the triplets (diagnostics / benches); mutation goes
    /// through `ingest`/`set_rank` only.
    pub fn layers(&self) -> &[SketchTriplet] {
        &self.layers
    }

    /// Largest elementwise |diff| between this engine's triplet state
    /// and another's (layer-by-layer X/Y/Z) — the parallel-vs-serial
    /// equivalence witness shared by the benches, the perf probe and the
    /// kernel tests, so a future change to triplet state updates every
    /// gate at once.
    pub fn max_state_diff(&self, other: &SketchEngine) -> f64 {
        assert_eq!(self.layers.len(), other.layers.len());
        let mut diff: f64 = 0.0;
        for (s, o) in self.layers.iter().zip(&other.layers) {
            diff = diff
                .max(s.x.max_abs_diff(&o.x))
                .max(s.y.max_abs_diff(&o.y))
                .max(s.z.max_abs_diff(&o.z));
        }
        diff
    }

    /// The projections used for batches of size `n_b`, if that size has
    /// been observed (or prepared) — cross-validation tests read these
    /// out instead of sampling their own.
    pub fn projections(&self, n_b: usize) -> Option<&Projections> {
        self.proj.get(&n_b)
    }

    /// Distinct batch sizes observed so far (ascending).
    pub fn batch_sizes_seen(&self) -> Vec<usize> {
        self.proj.keys().copied().collect()
    }

    pub fn batches_ingested(&self) -> u64 {
        self.batches_ingested
    }

    /// Capture the engine's full state as plain data (see
    /// [`EngineSnapshot`] for what is stored vs re-derived).
    pub fn snapshot(&self) -> EngineSnapshot {
        EngineSnapshot {
            layer_dims: self.cfg.layer_dims.clone(),
            rank: self.cfg.rank,
            beta: self.cfg.beta,
            seed: self.cfg.seed,
            precision: self.cfg.precision,
            triplets: self
                .layers
                .iter()
                .map(|t| TripletState {
                    x: t.x.clone(),
                    y: t.y.clone(),
                    z: t.z.clone(),
                    updates: t.updates as u64,
                })
                .collect(),
            batch_sizes: self.proj.keys().copied().collect(),
            last_batch: self.last_batch,
            batches_ingested: self.batches_ingested,
        }
    }

    /// Rebuild an engine from a snapshot: configuration is re-validated
    /// through the builder, Psi and batch projections are re-derived from
    /// (seed, rank, n_b), and the triplets' EMA state is installed
    /// verbatim — `restored.max_state_diff(&original) == 0` exactly.
    pub fn from_snapshot(
        snap: &EngineSnapshot,
        par: Parallelism,
    ) -> Result<SketchEngine> {
        Self::from_snapshot_ws(snap, par, Workspace::new(par))
    }

    /// [`SketchEngine::from_snapshot`] restoring onto a shared worker
    /// pool (the daemon's warm-restart path: every resumed tenant lands
    /// on the one process-lifetime pool).
    pub fn from_snapshot_with_pool(
        snap: &EngineSnapshot,
        pool: Arc<Pool>,
    ) -> Result<SketchEngine> {
        let par = Parallelism::from_threads(pool.lanes());
        Self::from_snapshot_ws(snap, par, Workspace::shared(pool))
    }

    fn from_snapshot_ws(
        snap: &EngineSnapshot,
        par: Parallelism,
        ws: Workspace,
    ) -> Result<SketchEngine> {
        let cfg = SketchConfig::builder()
            .layer_dims(&snap.layer_dims)
            .rank(snap.rank)
            .beta(snap.beta)
            .seed(snap.seed)
            .precision(snap.precision)
            .parallelism(par)
            .build()?;
        if snap.triplets.len() != cfg.n_layers() {
            bail!(
                "snapshot has {} triplets for {} layers",
                snap.triplets.len(),
                cfg.n_layers()
            );
        }
        let k = cfg.k();
        for (l, t) in snap.triplets.iter().enumerate() {
            let (d_in, d_out) = (cfg.d_in(l), cfg.d_out(l));
            if (t.x.rows, t.x.cols) != (d_in, k)
                || (t.y.rows, t.y.cols) != (d_out, k)
                || (t.z.rows, t.z.cols) != (d_out, k)
            {
                bail!(
                    "snapshot triplet {l} shapes ({}x{}, {}x{}, {}x{}) \
                     do not match config (d_in {d_in}, d_out {d_out}, k {k})",
                    t.x.rows,
                    t.x.cols,
                    t.y.rows,
                    t.y.cols,
                    t.z.rows,
                    t.z.cols
                );
            }
        }
        let mut engine = SketchEngine::with_workspace(cfg, ws);
        for (layer, t) in engine.layers.iter_mut().zip(&snap.triplets) {
            layer.x = t.x.clone();
            layer.y = t.y.clone();
            layer.z = t.z.clone();
            layer.updates = t.updates as usize;
        }
        for &n_b in &snap.batch_sizes {
            engine.ensure_projections(n_b);
        }
        if let Some(n_b) = snap.last_batch {
            engine.ensure_projections(n_b);
        }
        engine.last_batch = snap.last_batch;
        engine.batches_ingested = snap.batches_ingested;
        Ok(engine)
    }

    /// Pre-sample the projections for a batch size without ingesting —
    /// deterministic in (seed, rank, n_b), so preparation and lazy
    /// sampling agree.
    pub fn ensure_projections(&mut self, n_b: usize) -> &Projections {
        let cfg = &self.cfg;
        let psi = &self.psi;
        self.proj.entry(n_b).or_insert_with(|| {
            let mut rng = Rng::new(
                cfg.seed
                    ^ (n_b as u64).wrapping_mul(BATCH_STREAM)
                    ^ (cfg.rank as u64).wrapping_mul(RANK_STREAM),
            );
            Projections::with_psi(n_b, cfg.rank, psi.clone(), &mut rng)
        })
    }
}

impl Sketcher for SketchEngine {
    fn ingest(&mut self, acts: &[Mat]) -> Result<()> {
        if acts.len() != self.cfg.n_layers() + 1 {
            bail!(
                "ingest expects input batch + {} hidden activations, got {} matrices",
                self.cfg.n_layers(),
                acts.len()
            );
        }
        let n_b = acts[0].rows;
        if n_b == 0 {
            bail!("empty batch");
        }
        for (j, a) in acts.iter().enumerate() {
            if a.rows != n_b {
                bail!(
                    "activation {} has batch size {} but the input batch has {}",
                    j,
                    a.rows,
                    n_b
                );
            }
            if j >= 1 && a.cols != self.cfg.layer_dims[j - 1] {
                bail!(
                    "hidden activation {} is {} wide, config says {}",
                    j - 1,
                    a.cols,
                    self.cfg.layer_dims[j - 1]
                );
            }
        }
        self.ensure_projections(n_b);
        // Steady state (a previously seen batch size) from here on is
        // allocation-free: the fused kernels write into the resident
        // sketches through the workspace pool, and the layer fan-out
        // below claims indices straight off the activation list — no
        // job vector, no contribution temporaries, no thread spawns.
        let proj = &self.proj[&n_b];
        let pool = self.ws.pool();
        let lanes = pool.lanes();
        // Incoming activation for layer l: layer 0 sketches its own
        // output as input (the seed convention for A^[1]).
        let a_in = |l: usize| if l == 0 { &acts[1] } else { &acts[l] };
        if lanes > 1 && lanes <= self.layers.len() {
            // At least one layer per lane: fan whole layers out across
            // the pool; each triplet update is independent (own X/Y/Z,
            // shared read-only projections) and runs serial kernels.
            pool.for_each_mut(&mut self.layers, |l, t| {
                t.update_with(a_in(l), &acts[l + 1], proj, l, Pool::serial());
            });
        } else {
            // Serial config, or fewer layers than lanes (the per-layer
            // seam can't fill the pool): run layers sequentially and fan
            // each fused projection product across the full pool instead.
            for (l, t) in self.layers.iter_mut().enumerate() {
                t.update_with(a_in(l), &acts[l + 1], proj, l, pool);
            }
        }
        self.last_batch = Some(n_b);
        self.batches_ingested += 1;
        Ok(())
    }

    fn reconstruct(&self, layer: usize) -> Result<Mat> {
        if layer >= self.layers.len() {
            bail!(
                "layer {layer} out of range ({} sketched layers)",
                self.layers.len()
            );
        }
        let n_b = self
            .last_batch
            .context("reconstruct before any batch was ingested")?;
        let proj = &self.proj[&n_b];
        Ok(reconstruct_batch_with(
            &self.layers[layer],
            &proj.omega,
            self.ws.pool(),
        ))
    }

    fn metrics(&self) -> Vec<LayerMetrics> {
        all_metrics(&self.layers, METRIC_POWER_ITERS)
    }

    fn set_rank(&mut self, r: usize) {
        self.cfg.rank = r.max(1);
        let (layers, psi) = Self::fresh_state(&self.cfg);
        self.layers = layers;
        self.psi = psi;
        self.proj.clear();
        self.last_batch = None;
    }

    fn memory(&self) -> usize {
        let unit = self.cfg.precision.bytes();
        let k = self.cfg.k();
        let sketches: usize = self
            .layers
            .iter()
            .map(|t| (t.x.rows + t.y.rows + t.z.rows) * k * unit)
            .sum();
        let proj: usize = self.proj.values().map(|p| p.batch_bytes(unit)).sum();
        let psi: usize = self.psi.iter().map(|p| p.len() * 8).sum();
        sketches + proj + psi
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine(dims: &[usize], rank: usize) -> SketchEngine {
        SketchConfig::builder()
            .layer_dims(dims)
            .rank(rank)
            .beta(0.9)
            .seed(7)
            .build_engine()
            .unwrap()
    }

    fn acts(n_b: usize, dims: &[usize], rng: &mut Rng) -> Vec<Mat> {
        let mut out = vec![Mat::gaussian(n_b, 16, rng)]; // input batch
        for &d in dims {
            out.push(Mat::gaussian(n_b, d, rng));
        }
        out
    }

    #[test]
    fn builder_validates() {
        assert!(SketchConfig::builder().build().is_err()); // no dims
        assert!(SketchConfig::builder()
            .layer_dims(&[8, 0])
            .build()
            .is_err());
        assert!(SketchConfig::builder()
            .uniform_dims(2, 8)
            .rank(0)
            .build()
            .is_err());
        assert!(SketchConfig::builder()
            .uniform_dims(2, 8)
            .beta(1.0)
            .build()
            .is_err());
        let cfg = SketchConfig::builder()
            .layer_dims(&[128, 64, 32])
            .rank(4)
            .build()
            .unwrap();
        assert_eq!(cfg.k(), 9);
        assert_eq!(cfg.d_in(0), 128);
        assert_eq!(cfg.d_in(2), 64);
        assert_eq!(cfg.d_out(2), 32);
    }

    #[test]
    fn ingest_validates_shapes() {
        let mut e = engine(&[12, 6], 2);
        let mut rng = Rng::new(1);
        // Wrong count.
        assert!(e.ingest(&[Mat::gaussian(4, 12, &mut rng)]).is_err());
        // Wrong hidden width.
        let bad = vec![
            Mat::gaussian(4, 16, &mut rng),
            Mat::gaussian(4, 12, &mut rng),
            Mat::gaussian(4, 7, &mut rng),
        ];
        assert!(e.ingest(&bad).is_err());
        // Mismatched batch size across activations.
        let bad2 = vec![
            Mat::gaussian(4, 16, &mut rng),
            Mat::gaussian(5, 12, &mut rng),
            Mat::gaussian(4, 6, &mut rng),
        ];
        assert!(e.ingest(&bad2).is_err());
        let ok = acts(4, &[12, 6], &mut rng);
        e.ingest(&ok).unwrap();
        assert_eq!(e.batches_ingested(), 1);
    }

    #[test]
    fn projections_are_deterministic_per_batch_size() {
        let mut rng = Rng::new(2);
        let mut a = engine(&[10], 2);
        let mut b = engine(&[10], 2);
        // Observe sizes in different orders; same (seed, rank, n_b) must
        // yield identical projections.
        a.ingest(&acts(8, &[10], &mut rng)).unwrap();
        a.ingest(&acts(3, &[10], &mut rng)).unwrap();
        b.ensure_projections(3);
        b.ensure_projections(8);
        for n_b in [3usize, 8] {
            let pa = a.projections(n_b).unwrap();
            let pb = b.projections(n_b).unwrap();
            assert_eq!(pa.upsilon.data, pb.upsilon.data, "n_b={n_b}");
            assert_eq!(pa.psi, pb.psi);
        }
    }

    #[test]
    fn set_rank_reinitialises() {
        let mut rng = Rng::new(3);
        let mut e = engine(&[10, 5], 2);
        e.ingest(&acts(8, &[10, 5], &mut rng)).unwrap();
        assert!(e.layers()[0].x.fro_norm() > 0.0);
        let psi_before = e.projections(8).unwrap().psi.clone();
        e.set_rank(4);
        assert_eq!(e.k(), 9);
        assert_eq!(e.layers()[0].x.cols, 9);
        assert_eq!(e.layers()[0].x.fro_norm(), 0.0);
        assert!(e.batch_sizes_seen().is_empty());
        assert!(e.reconstruct(0).is_err(), "no batch after rank change");
        e.ingest(&acts(8, &[10, 5], &mut rng)).unwrap();
        assert_ne!(e.projections(8).unwrap().psi, psi_before);
    }

    #[test]
    fn memory_matches_fixed_accountant() {
        let mut rng = Rng::new(4);
        let dims = [64usize, 32, 16];
        let mut e = engine(&dims, 4);
        e.ingest(&acts(32, &dims, &mut rng)).unwrap();
        e.ingest(&acts(7, &dims, &mut rng)).unwrap(); // tail batch
        e.ingest(&acts(32, &dims, &mut rng)).unwrap(); // repeat size: no growth
        let want = e.config().expected_bytes(&[32, 7, 32]);
        assert_eq!(e.memory(), want);
        // Hand formula: k=9, sketches (64+128 + 64+64 + 32+32)*9*4,
        // proj (32+7)*3*9*4, psi 3*9*8.
        let hand = (64 + 2 * 64 + 64 + 2 * 32 + 32 + 2 * 16) * 9 * 4
            + 3 * (32 + 7) * 9 * 4
            + 3 * 9 * 8;
        assert_eq!(e.memory(), hand);
    }

    #[test]
    fn snapshot_roundtrip_restores_exact_state() {
        let mut rng = Rng::new(6);
        let dims = [20usize, 10];
        let mut e = engine(&dims, 3);
        e.ingest(&acts(16, &dims, &mut rng)).unwrap();
        e.ingest(&acts(5, &dims, &mut rng)).unwrap(); // tail batch
        let snap = e.snapshot();
        assert_eq!(snap.batch_sizes, vec![5, 16]);
        assert_eq!(snap.last_batch, Some(5));
        let mut r =
            SketchEngine::from_snapshot(&snap, Parallelism::Serial).unwrap();
        assert_eq!(r.max_state_diff(&e), 0.0);
        assert_eq!(r.memory(), e.memory());
        assert_eq!(r.batches_ingested(), e.batches_ingested());
        assert_eq!(r.batch_sizes_seen(), e.batch_sizes_seen());
        // Projections were re-derived, not copied: continued ingestion
        // and reconstruction stay bitwise identical.
        let next = acts(16, &dims, &mut rng);
        e.ingest(&next).unwrap();
        r.ingest(&next).unwrap();
        assert_eq!(r.max_state_diff(&e), 0.0);
        for l in 0..dims.len() {
            let (a, b) = (e.reconstruct(l).unwrap(), r.reconstruct(l).unwrap());
            assert_eq!(a.max_abs_diff(&b), 0.0);
        }
    }

    #[test]
    fn snapshot_rejects_mismatched_triplets() {
        let mut rng = Rng::new(7);
        let dims = [12usize, 6];
        let mut e = engine(&dims, 2);
        e.ingest(&acts(8, &dims, &mut rng)).unwrap();
        let mut snap = e.snapshot();
        snap.triplets.pop();
        assert!(
            SketchEngine::from_snapshot(&snap, Parallelism::Serial).is_err()
        );
        let mut snap2 = e.snapshot();
        snap2.triplets[0].x = Mat::zeros(3, 3);
        assert!(
            SketchEngine::from_snapshot(&snap2, Parallelism::Serial).is_err()
        );
    }

    #[test]
    fn reconstruct_shapes_follow_layer_dims() {
        let mut rng = Rng::new(5);
        let dims = [24usize, 12];
        let mut e = engine(&dims, 2);
        e.ingest(&acts(16, &dims, &mut rng)).unwrap();
        let r0 = e.reconstruct(0).unwrap(); // d_in(0) = 24
        let r1 = e.reconstruct(1).unwrap(); // d_in(1) = 24
        assert_eq!((r0.rows, r0.cols), (16, 24));
        assert_eq!((r1.rows, r1.cols), (16, 24));
        assert!(e.reconstruct(2).is_err());
        let m = e.metrics();
        assert_eq!(m.len(), 2);
        assert!(m.iter().all(|lm| lm.z_norm.is_finite()));
    }
}
