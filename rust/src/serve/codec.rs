//! Explicit little-endian codec primitives shared by the wire protocol
//! (`serve::proto`) and the durable snapshot format (`serve::store`).
//!
//! Everything is written through [`Enc`] and read back through [`Dec`]:
//! fixed-width integers, IEEE-754 bit patterns for floats (so snapshots
//! and wire replies are *bit-exact*, not printf round-trips), u32
//! length-prefixed strings/arrays and dense [`Mat`] payloads.  The
//! decoder validates every length against the remaining payload before
//! allocating, so a corrupt or hostile frame fails with a typed
//! [`CodecError`] instead of an OOM or panic.

use std::fmt;
use std::sync::OnceLock;

use crate::sketch::Mat;

/// Typed decode failures (the encode side is infallible).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CodecError {
    /// Needed `need` more bytes, only `have` remained.
    Eof { need: usize, have: usize },
    /// A length prefix exceeds the remaining payload.
    BadLength { len: usize, have: usize },
    /// String bytes were not valid UTF-8.
    Utf8,
    /// A tag byte had no mapped value.
    BadTag { what: &'static str, tag: u8 },
    /// Payload had trailing bytes after the message was fully decoded.
    Trailing(usize),
}

impl fmt::Display for CodecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CodecError::Eof { need, have } => {
                write!(f, "unexpected end of payload (need {need}, have {have})")
            }
            CodecError::BadLength { len, have } => {
                write!(f, "length prefix {len} exceeds remaining {have} bytes")
            }
            CodecError::Utf8 => write!(f, "invalid UTF-8 in string"),
            CodecError::BadTag { what, tag } => {
                write!(f, "invalid {what} tag {tag}")
            }
            CodecError::Trailing(n) => {
                write!(f, "{n} trailing bytes after message")
            }
        }
    }
}

impl std::error::Error for CodecError {}

/// Append-only little-endian encoder.
#[derive(Debug, Default)]
pub struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    pub fn new() -> Enc {
        Enc::default()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    /// Clear for reuse, keeping the grown capacity — long-lived
    /// connections encode every frame through one `Enc` without
    /// reallocating in steady state.
    pub fn reset(&mut self) {
        self.buf.clear();
    }

    /// The encoded bytes so far (borrowed; see [`Enc::into_bytes`] for
    /// the owning form).
    pub fn bytes(&self) -> &[u8] {
        &self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// f32 as its IEEE-754 bit pattern (bit-exact round-trip).
    pub fn f32(&mut self, v: f32) {
        self.u32(v.to_bits());
    }

    /// f64 as its IEEE-754 bit pattern (bit-exact round-trip).
    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    pub fn bool(&mut self, v: bool) {
        self.u8(v as u8);
    }

    /// usize as u32 (wire quantities — dims, counts — are < 4 B entries).
    pub fn len32(&mut self, n: usize) {
        debug_assert!(n <= u32::MAX as usize);
        self.u32(n as u32);
    }

    pub fn str(&mut self, s: &str) {
        self.len32(s.len());
        self.buf.extend_from_slice(s.as_bytes());
    }

    pub fn f64s(&mut self, xs: &[f64]) {
        self.len32(xs.len());
        for &x in xs {
            self.f64(x);
        }
    }

    pub fn f32s(&mut self, xs: &[f32]) {
        self.len32(xs.len());
        for &x in xs {
            self.f32(x);
        }
    }

    pub fn usizes(&mut self, xs: &[usize]) {
        self.len32(xs.len());
        for &x in xs {
            self.len32(x);
        }
    }

    pub fn u64s(&mut self, xs: &[u64]) {
        self.len32(xs.len());
        for &x in xs {
            self.u64(x);
        }
    }

    pub fn opt_f64(&mut self, v: Option<f64>) {
        match v {
            Some(x) => {
                self.u8(1);
                self.f64(x);
            }
            None => self.u8(0),
        }
    }

    pub fn opt_usize(&mut self, v: Option<usize>) {
        match v {
            Some(x) => {
                self.u8(1);
                self.len32(x);
            }
            None => self.u8(0),
        }
    }

    pub fn mat(&mut self, m: &Mat) {
        self.len32(m.rows);
        self.len32(m.cols);
        for &x in &m.data {
            self.f64(x);
        }
    }
}

/// Bounds-checked little-endian decoder over a borrowed payload.
pub struct Dec<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Dec<'a> {
    pub fn new(b: &'a [u8]) -> Dec<'a> {
        Dec { b, i: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.b.len() - self.i
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], CodecError> {
        if self.remaining() < n {
            return Err(CodecError::Eof {
                need: n,
                have: self.remaining(),
            });
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, CodecError> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> Result<u16, CodecError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn u32(&mut self) -> Result<u32, CodecError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64, CodecError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> Result<f32, CodecError> {
        Ok(f32::from_bits(self.u32()?))
    }

    pub fn f64(&mut self) -> Result<f64, CodecError> {
        Ok(f64::from_bits(self.u64()?))
    }

    pub fn bool(&mut self) -> Result<bool, CodecError> {
        Ok(self.u8()? != 0)
    }

    /// A u32 length prefix for items of `elem` bytes each, validated
    /// against the remaining payload before any allocation.
    pub fn len32(&mut self, elem: usize) -> Result<usize, CodecError> {
        let n = self.u32()? as usize;
        let need = n.checked_mul(elem.max(1)).ok_or_else(|| {
            CodecError::BadLength {
                len: n,
                have: self.remaining(),
            }
        })?;
        if elem > 0 && need > self.remaining() {
            return Err(CodecError::BadLength {
                len: n,
                have: self.remaining(),
            });
        }
        Ok(n)
    }

    pub fn str(&mut self) -> Result<String, CodecError> {
        let n = self.len32(1)?;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| CodecError::Utf8)
    }

    pub fn f64s(&mut self) -> Result<Vec<f64>, CodecError> {
        let n = self.len32(8)?;
        (0..n).map(|_| self.f64()).collect()
    }

    pub fn f32s(&mut self) -> Result<Vec<f32>, CodecError> {
        let n = self.len32(4)?;
        (0..n).map(|_| self.f32()).collect()
    }

    pub fn usizes(&mut self) -> Result<Vec<usize>, CodecError> {
        let n = self.len32(4)?;
        (0..n).map(|_| Ok(self.u32()? as usize)).collect()
    }

    pub fn u64s(&mut self) -> Result<Vec<u64>, CodecError> {
        let n = self.len32(8)?;
        (0..n).map(|_| self.u64()).collect()
    }

    pub fn opt_f64(&mut self) -> Result<Option<f64>, CodecError> {
        match self.u8()? {
            0 => Ok(None),
            1 => Ok(Some(self.f64()?)),
            tag => Err(CodecError::BadTag {
                what: "option",
                tag,
            }),
        }
    }

    pub fn opt_usize(&mut self) -> Result<Option<usize>, CodecError> {
        match self.u8()? {
            0 => Ok(None),
            1 => Ok(Some(self.u32()? as usize)),
            tag => Err(CodecError::BadTag {
                what: "option",
                tag,
            }),
        }
    }

    pub fn mat(&mut self) -> Result<Mat, CodecError> {
        let rows = self.u32()? as usize;
        let cols = self.u32()? as usize;
        let n = rows.checked_mul(cols).ok_or_else(|| {
            CodecError::BadLength {
                len: rows,
                have: self.remaining(),
            }
        })?;
        let need = n.checked_mul(8).ok_or_else(|| {
            CodecError::BadLength {
                len: n,
                have: self.remaining(),
            }
        })?;
        if need > self.remaining() {
            return Err(CodecError::BadLength {
                len: n,
                have: self.remaining(),
            });
        }
        let mut data = Vec::with_capacity(n);
        for _ in 0..n {
            data.push(self.f64()?);
        }
        Ok(Mat::from_vec(rows, cols, data))
    }

    /// Assert the payload was consumed exactly.
    pub fn finish(&self) -> Result<(), CodecError> {
        if self.remaining() != 0 {
            return Err(CodecError::Trailing(self.remaining()));
        }
        Ok(())
    }
}

/// CRC-32 (IEEE 802.3, reflected) over `bytes` — the snapshot store's
/// integrity check.  Table built once on first use.
pub fn crc32(bytes: &[u8]) -> u32 {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, slot) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 {
                    0xEDB8_8320 ^ (c >> 1)
                } else {
                    c >> 1
                };
            }
            *slot = c;
        }
        t
    });
    let mut crc = !0u32;
    for &b in bytes {
        crc = table[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    !crc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primitive_roundtrip() {
        let mut e = Enc::new();
        e.u8(7);
        e.u16(513);
        e.u32(70_000);
        e.u64(u64::MAX - 3);
        e.f32(-1.5);
        e.f64(std::f64::consts::PI);
        e.bool(true);
        e.str("héllo");
        e.f64s(&[1.0, -2.5]);
        e.f32s(&[0.5]);
        e.usizes(&[3, 0, 9]);
        e.u64s(&[u64::MAX, 0, 17]);
        e.opt_f64(Some(2.0));
        e.opt_f64(None);
        e.opt_usize(Some(5));
        e.opt_usize(None);
        let bytes = e.into_bytes();
        let mut d = Dec::new(&bytes);
        assert_eq!(d.u8().unwrap(), 7);
        assert_eq!(d.u16().unwrap(), 513);
        assert_eq!(d.u32().unwrap(), 70_000);
        assert_eq!(d.u64().unwrap(), u64::MAX - 3);
        assert_eq!(d.f32().unwrap(), -1.5);
        assert_eq!(d.f64().unwrap(), std::f64::consts::PI);
        assert!(d.bool().unwrap());
        assert_eq!(d.str().unwrap(), "héllo");
        assert_eq!(d.f64s().unwrap(), vec![1.0, -2.5]);
        assert_eq!(d.f32s().unwrap(), vec![0.5]);
        assert_eq!(d.usizes().unwrap(), vec![3, 0, 9]);
        assert_eq!(d.u64s().unwrap(), vec![u64::MAX, 0, 17]);
        assert_eq!(d.opt_f64().unwrap(), Some(2.0));
        assert_eq!(d.opt_f64().unwrap(), None);
        assert_eq!(d.opt_usize().unwrap(), Some(5));
        assert_eq!(d.opt_usize().unwrap(), None);
        d.finish().unwrap();
    }

    #[test]
    fn floats_are_bit_exact() {
        // NaN payloads and signed zeros survive (printf would not).
        let vals = [f64::NAN, -0.0, f64::MIN_POSITIVE, 1.0 / 3.0];
        let mut e = Enc::new();
        for &v in &vals {
            e.f64(v);
        }
        let bytes = e.into_bytes();
        let mut d = Dec::new(&bytes);
        for &v in &vals {
            assert_eq!(d.f64().unwrap().to_bits(), v.to_bits());
        }
    }

    #[test]
    fn mat_roundtrip() {
        let m = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, -4.0, 5.5, -0.0]);
        let mut e = Enc::new();
        e.mat(&m);
        let bytes = e.into_bytes();
        let mut d = Dec::new(&bytes);
        let back = d.mat().unwrap();
        d.finish().unwrap();
        assert_eq!((back.rows, back.cols), (2, 3));
        assert_eq!(back.max_abs_diff(&m), 0.0);
    }

    #[test]
    fn truncated_and_oversized_inputs_error() {
        let mut e = Enc::new();
        e.u64(42);
        let bytes = e.into_bytes();
        let mut d = Dec::new(&bytes[..5]);
        assert!(matches!(d.u64(), Err(CodecError::Eof { .. })));

        // A length prefix larger than the payload must not allocate.
        let mut e = Enc::new();
        e.u32(u32::MAX); // claimed length
        let bytes = e.into_bytes();
        let mut d = Dec::new(&bytes);
        assert!(matches!(d.f64s(), Err(CodecError::BadLength { .. })));

        let mut e = Enc::new();
        e.u32(1_000_000); // rows
        e.u32(1_000_000); // cols
        let bytes = e.into_bytes();
        let mut d = Dec::new(&bytes);
        assert!(matches!(d.mat(), Err(CodecError::BadLength { .. })));

        // Trailing garbage is flagged.
        let mut e = Enc::new();
        e.u8(1);
        e.u8(2);
        let bytes = e.into_bytes();
        let mut d = Dec::new(&bytes);
        d.u8().unwrap();
        assert_eq!(d.finish(), Err(CodecError::Trailing(1)));
    }

    #[test]
    fn crc32_matches_reference_vectors() {
        // Well-known IEEE CRC-32 test vectors.
        assert_eq!(crc32(b""), 0x0000_0000);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"),
            0x414F_A339);
    }
}
