//! Std-only HTTP/1.1 text exposition endpoint (DESIGN.md §10).
//!
//! One listener thread (`sketchd --obs-addr`), two routes:
//!
//! - `GET /metrics` — Prometheus text exposition (version 0.0.4):
//!   the merged lifetime counters, latency summaries, per-shard
//!   counters, window-ring balance terms, journal totals, and the
//!   per-session sketch-health gauges.
//! - `GET /events` — the merged chronological journal dump, one event
//!   per line, headed by the exact totals.
//!
//! The protocol surface (v5 `Events` / `MetricsWindow`) serves the
//! same data, so a scraper and a protocol client can be cross-checked
//! to equality — which is exactly what the CI scrape leg does.
//!
//! The server is deliberately minimal: GET only, `Connection: close`,
//! bounded request read (8 KiB / 2 s), no keep-alive, no TLS.  It
//! renders from an [`ExpoSnapshot`] assembled by the daemon, so this
//! module owns formatting and transport but no daemon state.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Duration;

use super::events::Event;
use super::window::WindowReport;
use super::SessionHealth;
use crate::serve::metrics::MetricsReport;
use crate::serve::proto::ShardStats;

/// Everything `/metrics` renders, assembled by the daemon outside this
/// module (one shard lock at a time, never under the listener).
#[derive(Clone, Debug, Default)]
pub struct ExpoSnapshot {
    /// Merged lifetime report (same payload as the v3 `Metrics` op).
    pub report: MetricsReport,
    /// Per-shard counters (same rows as the v4 `Stats` op).
    pub shards: Vec<ShardStats>,
    /// Window ring + open window (same payload as v5 `MetricsWindow`).
    pub windows: WindowReport,
    /// Per-session sketch-health gauges.
    pub health: Vec<SessionHealth>,
    pub journal_total: u64,
    pub journal_dropped: u64,
}

fn sanitize_label(s: &str) -> String {
    s.chars()
        .map(|c| match c {
            '"' | '\\' | '\n' => '_',
            c => c,
        })
        .collect()
}

/// Render the Prometheus text body for `GET /metrics`.
pub fn render_metrics(s: &ExpoSnapshot) -> String {
    let mut o = String::with_capacity(4096);
    let r = &s.report;
    let push = |o: &mut String, name: &str, ty: &str, val: String| {
        o.push_str(&format!("# TYPE {name} {ty}\n{name} {val}\n"));
    };
    push(
        &mut o,
        "sketchd_uptime_seconds",
        "gauge",
        format!("{}", r.uptime_ms as f64 / 1e3),
    );
    push(
        &mut o,
        "sketchd_sessions_open",
        "gauge",
        r.sessions_open.to_string(),
    );
    push(
        &mut o,
        "sketchd_sessions_peak",
        "gauge",
        r.sessions_peak.to_string(),
    );
    push(
        &mut o,
        "sketchd_sessions_opened_total",
        "counter",
        r.sessions_opened.to_string(),
    );
    push(
        &mut o,
        "sketchd_ingest_frames_total",
        "counter",
        r.ingest.count.to_string(),
    );
    push(
        &mut o,
        "sketchd_ingest_bytes_total",
        "counter",
        r.ingest_bytes.to_string(),
    );
    push(
        &mut o,
        "sketchd_frames_served_total",
        "counter",
        r.frames_served.to_string(),
    );
    o.push_str("# TYPE sketchd_busy_total counter\n");
    o.push_str(&format!(
        "sketchd_busy_total{{cause=\"admission\"}} {}\n",
        r.busy_admission
    ));
    o.push_str(&format!(
        "sketchd_busy_total{{cause=\"quota\"}} {}\n",
        r.busy_quota
    ));
    push(
        &mut o,
        "sketchd_snapshots_total",
        "counter",
        r.snapshot_count.to_string(),
    );
    push(
        &mut o,
        "sketchd_snapshot_pause_seconds_total",
        "counter",
        format!("{}", r.snapshot_pause_ns as f64 / 1e9),
    );
    push(
        &mut o,
        "sketchd_snapshot_failures_total",
        "counter",
        r.snapshot_failures.to_string(),
    );
    push(
        &mut o,
        "sketchd_handler_panics_total",
        "counter",
        r.handler_panics.to_string(),
    );

    o.push_str("# TYPE sketchd_request_latency_seconds summary\n");
    for (op, h) in [
        ("ingest", &r.ingest),
        ("diagnose", &r.diagnose),
        ("query", &r.query),
    ] {
        for q in [0.5, 0.95, 0.99] {
            o.push_str(&format!(
                "sketchd_request_latency_seconds{{op=\"{op}\",quantile=\"{q}\"}} {}\n",
                h.quantile(q) / 1e9
            ));
        }
        o.push_str(&format!(
            "sketchd_request_latency_seconds_count{{op=\"{op}\"}} {}\n",
            h.count
        ));
        o.push_str(&format!(
            "sketchd_request_latency_seconds_sum{{op=\"{op}\"}} {}\n",
            h.sum_ns as f64 / 1e9
        ));
    }

    o.push_str("# TYPE sketchd_shard_ingest_frames_total counter\n");
    for sh in &s.shards {
        o.push_str(&format!(
            "sketchd_shard_ingest_frames_total{{shard=\"{}\"}} {}\n",
            sh.shard, sh.ingest_frames
        ));
    }
    o.push_str("# TYPE sketchd_shard_sessions gauge\n");
    for sh in &s.shards {
        o.push_str(&format!(
            "sketchd_shard_sessions{{shard=\"{}\"}} {}\n",
            sh.shard, sh.sessions
        ));
    }

    // Window-ring balance terms: baseline + evicted + retained + open
    // must equal sketchd_ingest_frames_total exactly (the CI scrape
    // leg asserts this equality from outside).
    let w = &s.windows;
    let retained: u64 = w.buckets.iter().map(|b| b.ingest_frames).sum();
    push(
        &mut o,
        "sketchd_window_interval_seconds",
        "gauge",
        format!("{}", w.interval_ms as f64 / 1e3),
    );
    push(
        &mut o,
        "sketchd_windows_retained",
        "gauge",
        w.buckets.len().to_string(),
    );
    push(
        &mut o,
        "sketchd_window_frames_baseline",
        "gauge",
        w.baseline.ingest_frames.to_string(),
    );
    push(
        &mut o,
        "sketchd_window_frames_evicted",
        "gauge",
        w.evicted.ingest_frames.to_string(),
    );
    push(
        &mut o,
        "sketchd_window_frames_retained",
        "gauge",
        retained.to_string(),
    );
    push(
        &mut o,
        "sketchd_window_frames_open",
        "gauge",
        w.open.ingest_frames.to_string(),
    );
    if let Some(last) = w.buckets.last() {
        push(
            &mut o,
            "sketchd_window_last_throughput",
            "gauge",
            format!("{}", last.throughput()),
        );
        push(
            &mut o,
            "sketchd_window_last_ingest_p99_seconds",
            "gauge",
            format!("{}", last.ingest_p99_ns as f64 / 1e9),
        );
    }

    push(
        &mut o,
        "sketchd_journal_events_total",
        "counter",
        s.journal_total.to_string(),
    );
    push(
        &mut o,
        "sketchd_journal_dropped_total",
        "counter",
        s.journal_dropped.to_string(),
    );

    o.push_str("# TYPE sketchd_session_z_norm gauge\n");
    o.push_str("# TYPE sketchd_session_top_sigma gauge\n");
    o.push_str("# TYPE sketchd_session_stable_rank gauge\n");
    for h in &s.health {
        let name = sanitize_label(&h.name);
        for (l, lh) in h.layers.iter().enumerate() {
            let labels = format!(
                "{{session=\"{}\",name=\"{name}\",layer=\"{l}\"}}",
                h.session
            );
            o.push_str(&format!(
                "sketchd_session_z_norm{labels} {}\n",
                lh.z_norm
            ));
            o.push_str(&format!(
                "sketchd_session_top_sigma{labels} {}\n",
                lh.top_sigma
            ));
            o.push_str(&format!(
                "sketchd_session_stable_rank{labels} {}\n",
                lh.stable_rank
            ));
        }
    }
    o
}

/// Render the text body for `GET /events`.
pub fn render_events(events: &[Event], dropped: u64, base_unix_ms: u64) -> String {
    let mut o = String::with_capacity(256 + events.len() * 64);
    o.push_str(&format!(
        "# sketchd event journal: {} retained, {} dropped, base_unix_ms {}\n",
        events.len(),
        dropped,
        base_unix_ms
    ));
    for e in events {
        o.push_str(&e.describe());
        o.push('\n');
    }
    o
}

fn http_response(status: &str, body: &str) -> Vec<u8> {
    format!(
        "HTTP/1.1 {status}\r\nContent-Type: text/plain; version=0.0.4; \
         charset=utf-8\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
    .into_bytes()
}

/// Parse the request line and headers of one bounded HTTP request and
/// return the GET path, or an error status string.
fn read_request(stream: &mut TcpStream) -> Result<String, &'static str> {
    let mut buf = [0u8; 8192];
    let mut n = 0usize;
    loop {
        match stream.read(&mut buf[n..]) {
            Ok(0) => break,
            Ok(k) => {
                n += k;
                if buf[..n].windows(4).any(|w| w == b"\r\n\r\n") {
                    break;
                }
                if n == buf.len() {
                    return Err("431 Request Header Fields Too Large");
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                return Err("408 Request Timeout")
            }
            Err(_) => return Err("400 Bad Request"),
        }
    }
    let text = String::from_utf8_lossy(&buf[..n]);
    let mut parts = text.lines().next().unwrap_or("").split_whitespace();
    let method = parts.next().unwrap_or("");
    let target = parts.next().unwrap_or("");
    if method != "GET" {
        return Err("405 Method Not Allowed");
    }
    // Strip any query string; the endpoint takes no parameters.
    Ok(target.split('?').next().unwrap_or("").to_string())
}

fn handle_conn(
    mut stream: TcpStream,
    handler: &(dyn Fn(&str) -> Option<String> + Sync),
) {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(2)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(5)));
    let reply = match read_request(&mut stream) {
        Ok(path) => match handler(&path) {
            Some(body) => http_response("200 OK", &body),
            None => http_response("404 Not Found", "not found\n"),
        },
        Err(status) => http_response(status, ""),
    };
    let _ = stream.write_all(&reply);
    let _ = stream.flush();
    let _ = stream.shutdown(std::net::Shutdown::Both);
}

/// Run the listener loop until `shutdown` is set.  `handler` maps a
/// GET path to a response body (None = 404); it is invoked on the
/// listener thread, one request at a time — scrapes are rare and
/// cheap, so there is no per-connection thread.
pub fn serve(
    listener: TcpListener,
    shutdown: &AtomicBool,
    handler: &(dyn Fn(&str) -> Option<String> + Sync),
) {
    let _ = listener.set_nonblocking(true);
    while !shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                let _ = stream.set_nonblocking(false);
                handle_conn(stream, handler);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(20));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(20)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::obs::events::EventKind;
    use crate::serve::obs::window::{WindowBucket, WindowTotals};
    use crate::serve::obs::LayerHealth;

    fn snapshot() -> ExpoSnapshot {
        let mut report = MetricsReport {
            uptime_ms: 2500,
            sessions_open: 2,
            sessions_peak: 3,
            sessions_opened: 5,
            ingest_bytes: 123_456,
            frames_served: 900,
            busy_admission: 1,
            busy_quota: 4,
            snapshot_count: 2,
            snapshot_pause_ns: 3_000_000,
            snapshot_failures: 1,
            handler_panics: 2,
            ..MetricsReport::default()
        };
        for ns in [1000u64, 2000, 50_000] {
            report.ingest.record(ns);
        }
        ExpoSnapshot {
            report,
            shards: vec![
                ShardStats {
                    shard: 0,
                    sessions: 1,
                    ingest_frames: 2,
                    ..ShardStats::default()
                },
                ShardStats {
                    shard: 1,
                    sessions: 1,
                    ingest_frames: 1,
                    ..ShardStats::default()
                },
            ],
            windows: WindowReport {
                interval_ms: 1000,
                capacity: 120,
                baseline: WindowTotals::default(),
                evicted: WindowTotals::default(),
                buckets: vec![WindowBucket {
                    index: 0,
                    dur_ms: 1000,
                    ingest_frames: 2,
                    ingest_p99_ns: 50_000,
                    ..WindowBucket::default()
                }],
                open: WindowBucket {
                    index: 1,
                    ingest_frames: 1,
                    ..WindowBucket::default()
                },
            },
            health: vec![SessionHealth {
                session: 7,
                name: "t\"0".into(),
                layers: vec![LayerHealth {
                    z_norm: 1.5,
                    top_sigma: 1.2,
                    stable_rank: 1.5625,
                }],
            }],
            journal_total: 42,
            journal_dropped: 0,
        }
    }

    #[test]
    fn metrics_rendering_carries_the_balance_terms() {
        let body = render_metrics(&snapshot());
        assert!(body.contains("sketchd_ingest_frames_total 3\n"));
        assert!(body.contains("sketchd_ingest_bytes_total 123456\n"));
        assert!(body.contains("sketchd_busy_total{cause=\"quota\"} 4\n"));
        assert!(body.contains("sketchd_snapshot_failures_total 1\n"));
        assert!(body.contains("sketchd_handler_panics_total 2\n"));
        assert!(body.contains("sketchd_window_frames_retained 2\n"));
        assert!(body.contains("sketchd_window_frames_open 1\n"));
        assert!(body.contains("sketchd_window_frames_baseline 0\n"));
        assert!(body.contains("sketchd_window_frames_evicted 0\n"));
        assert!(body.contains("sketchd_journal_dropped_total 0\n"));
        assert!(body
            .contains("sketchd_shard_ingest_frames_total{shard=\"1\"} 1\n"));
        assert!(body.contains(
            "sketchd_request_latency_seconds_count{op=\"ingest\"} 3\n"
        ));
        // Labels are sanitized (no raw quote from the session name).
        assert!(body.contains("name=\"t_0\""));
        assert!(body.contains("sketchd_session_stable_rank"));
        // Balance: baseline + evicted + retained + open == lifetime.
        assert_eq!(0 + 0 + 2 + 1, 3u64);
    }

    #[test]
    fn events_rendering_is_line_per_event() {
        let ev = |ts, k: EventKind| {
            let (kind, code, a, b) = k.pack();
            Event {
                ts_ns: ts,
                slot: 1,
                kind,
                code,
                a,
                b,
            }
        };
        let events = vec![
            ev(1_000_000, EventKind::SessionOpen { session: 3 }),
            ev(2_000_000, EventKind::SlowRequest {
                msg: 3,
                elapsed_ns: 400_000_000,
            }),
        ];
        let body = render_events(&events, 7, 1_700_000_000_000);
        assert!(body.starts_with("# sketchd event journal: 2 retained, 7 dropped"));
        assert_eq!(body.lines().count(), 3);
        assert!(body.contains("session-open session=3"));
        assert!(body.contains("slow-request msg=3"));
    }

    #[test]
    fn listener_serves_routes_and_shuts_down() {
        use std::sync::atomic::AtomicBool;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let shutdown = AtomicBool::new(false);
        std::thread::scope(|scope| {
            let shutdown = &shutdown;
            scope.spawn(move || {
                serve(listener, shutdown, &|path| match path {
                    "/metrics" => Some("metric 1\n".to_string()),
                    _ => None,
                });
            });
            let get = |path: &str| {
                let mut s = TcpStream::connect(addr).unwrap();
                write!(s, "GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
                let mut out = String::new();
                s.read_to_string(&mut out).unwrap();
                out
            };
            let ok = get("/metrics");
            assert!(ok.starts_with("HTTP/1.1 200 OK\r\n"), "{ok}");
            assert!(ok.ends_with("metric 1\n"));
            assert!(ok.contains("Content-Length: 9\r\n"));
            let ok_query = get("/metrics?x=1");
            assert!(ok_query.starts_with("HTTP/1.1 200 OK\r\n"));
            let missing = get("/nope");
            assert!(missing.starts_with("HTTP/1.1 404"));
            let mut s = TcpStream::connect(addr).unwrap();
            write!(s, "POST /metrics HTTP/1.1\r\n\r\n").unwrap();
            let mut out = String::new();
            s.read_to_string(&mut out).unwrap();
            assert!(out.starts_with("HTTP/1.1 405"));
            shutdown.store(true, Ordering::SeqCst);
        });
    }
}
