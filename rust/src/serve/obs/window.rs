//! Windowed time-series metrics (DESIGN.md §10).
//!
//! A fixed ring of per-interval buckets layered on the lifetime
//! counters of `serve::metrics`.  The daemon's run loop closes a
//! bucket every `interval_ms` by capturing the merged cross-shard
//! [`Sample`] and differencing it against the previous capture, so a
//! bucket's counters are *exact deltas between two snapshots of the
//! same monotone lifetime counters* — no second accounting path that
//! could drift.
//!
//! ## The sum == lifetime-delta invariant
//!
//! Consecutive-capture deltas telescope.  With `baseline` the lifetime
//! counters at ring creation (non-zero after a warm restart),
//! `evicted` the running sum of buckets pushed out of the bounded
//! ring, and `open` the in-progress window (current capture minus the
//! last closed boundary):
//!
//! ```text
//! baseline + evicted + Σ retained buckets + open == current lifetime
//! ```
//!
//! holds *exactly*, always — not just when the ring hasn't wrapped.
//! `loadgen` fails a run if this equality breaks, and the CI scrape
//! leg re-checks it from the exposition endpoint.
//!
//! Per-window latency quantiles come from bucketwise histogram
//! subtraction (exact, since merge is bucketwise addition and every
//! bucket is monotone); the delta histogram's min/max are widened to
//! the enclosing bucket bounds, which keeps the quantile estimate
//! within the same sqrt(2) factor as the lifetime histograms.

use std::collections::VecDeque;
use std::sync::Mutex;

use crate::serve::codec::{CodecError, Dec, Enc};
use crate::serve::metrics::{
    bucket_bounds, Histogram, MetricsState, NUM_BUCKETS,
};

/// A point-in-time capture of the merged lifetime counters the window
/// ring tracks.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Sample {
    pub ingest_frames: u64,
    pub ingest_bytes: u64,
    pub busy: u64,
    pub frames_served: u64,
    pub ingest: Histogram,
}

impl Sample {
    /// Build from a merged [`MetricsState`] plus the (process-scoped)
    /// reply count, which a state does not carry.
    pub fn from_state(s: &MetricsState, frames_served: u64) -> Sample {
        Sample {
            ingest_frames: s.ingest.count,
            ingest_bytes: s.ingest_bytes,
            busy: s.busy_admission + s.busy_quota,
            frames_served,
            ingest: s.ingest.clone(),
        }
    }
}

/// The additive counter subset (everything in a bucket except the
/// latency quantiles), used for the telescoping-sum bookkeeping.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WindowTotals {
    pub ingest_frames: u64,
    pub ingest_bytes: u64,
    pub busy: u64,
    pub frames_served: u64,
}

impl WindowTotals {
    pub fn add(&mut self, other: &WindowTotals) {
        self.ingest_frames += other.ingest_frames;
        self.ingest_bytes += other.ingest_bytes;
        self.busy += other.busy;
        self.frames_served += other.frames_served;
    }
}

/// One closed (or, in a report, the open) window.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct WindowBucket {
    /// Window sequence number since daemon start (never reused).
    pub index: u64,
    /// Window start, milliseconds since daemon start.
    pub start_ms: u64,
    /// Actual covered duration — the nominal interval unless the
    /// ticker stalled (e.g. behind a long snapshot pause).
    pub dur_ms: u64,
    pub ingest_frames: u64,
    pub ingest_bytes: u64,
    pub busy: u64,
    pub frames_served: u64,
    pub ingest_p50_ns: u64,
    pub ingest_p99_ns: u64,
}

impl WindowBucket {
    pub fn totals(&self) -> WindowTotals {
        WindowTotals {
            ingest_frames: self.ingest_frames,
            ingest_bytes: self.ingest_bytes,
            busy: self.busy,
            frames_served: self.frames_served,
        }
    }

    /// Frames per second over the actual window duration.
    pub fn throughput(&self) -> f64 {
        if self.dur_ms == 0 {
            0.0
        } else {
            self.ingest_frames as f64 * 1e3 / self.dur_ms as f64
        }
    }
}

/// Exact bucketwise difference `cur - prev` of two cumulative
/// histograms (`prev` must be an earlier capture of the same
/// histogram). min/max are widened to the bounds of the outermost
/// non-empty delta buckets — the tightest recoverable range.
pub fn histogram_delta(cur: &Histogram, prev: &Histogram) -> Histogram {
    let mut d = Histogram::new();
    d.count = cur.count.saturating_sub(prev.count);
    d.sum_ns = cur.sum_ns.saturating_sub(prev.sum_ns);
    for i in 0..NUM_BUCKETS {
        d.buckets[i] = cur.buckets[i].saturating_sub(prev.buckets[i]);
    }
    if d.count > 0 {
        if let Some(first) = d.buckets.iter().position(|&c| c > 0) {
            d.min_ns = bucket_bounds(first).0;
        }
        if let Some(last) = d.buckets.iter().rposition(|&c| c > 0) {
            let (_, hi) = bucket_bounds(last);
            d.max_ns = if hi == u64::MAX {
                cur.max_ns
            } else {
                hi - 1
            };
        }
    }
    d
}

struct Inner {
    /// Lifetime counters at ring creation (restored snapshot values on
    /// a warm restart).
    baseline: WindowTotals,
    /// Running sum of buckets evicted from the bounded ring.
    evicted: WindowTotals,
    /// Capture at the last closed window boundary.
    prev: Sample,
    /// When `prev` was captured, ms since daemon start.
    prev_ms: u64,
    next_index: u64,
    ring: VecDeque<WindowBucket>,
}

/// The daemon's window ring. Ticked by the run loop; read by any
/// thread (shard threads serving v5 ops, the exposition listener).
pub struct Windows {
    interval_ms: u64,
    capacity: usize,
    inner: Mutex<Inner>,
}

impl Windows {
    /// `initial` is the lifetime capture at daemon bind (it becomes
    /// the baseline, so restored counters don't show up as a giant
    /// first window).
    pub fn new(interval_ms: u64, capacity: usize, initial: Sample) -> Windows {
        Windows {
            interval_ms: interval_ms.max(1),
            capacity: capacity.max(1),
            inner: Mutex::new(Inner {
                baseline: WindowTotals {
                    ingest_frames: initial.ingest_frames,
                    ingest_bytes: initial.ingest_bytes,
                    busy: initial.busy,
                    frames_served: initial.frames_served,
                },
                evicted: WindowTotals::default(),
                prev: initial,
                prev_ms: 0,
                next_index: 0,
                ring: VecDeque::new(),
            }),
        }
    }

    pub fn interval_ms(&self) -> u64 {
        self.interval_ms
    }

    /// Has the next window boundary passed?
    pub fn due(&self, now_ms: u64) -> bool {
        let inner = self.inner.lock().unwrap();
        now_ms >= inner.prev_ms + self.interval_ms
    }

    fn close_bucket(
        inner: &mut Inner,
        now_ms: u64,
        current: &Sample,
    ) -> WindowBucket {
        let hist = histogram_delta(&current.ingest, &inner.prev.ingest);
        WindowBucket {
            index: inner.next_index,
            start_ms: inner.prev_ms,
            dur_ms: now_ms.saturating_sub(inner.prev_ms),
            ingest_frames: current
                .ingest_frames
                .saturating_sub(inner.prev.ingest_frames),
            ingest_bytes: current
                .ingest_bytes
                .saturating_sub(inner.prev.ingest_bytes),
            busy: current.busy.saturating_sub(inner.prev.busy),
            frames_served: current
                .frames_served
                .saturating_sub(inner.prev.frames_served),
            ingest_p50_ns: hist.quantile(0.50) as u64,
            ingest_p99_ns: hist.quantile(0.99) as u64,
        }
    }

    /// Close the in-progress window at `now_ms` using the fresh merged
    /// capture `current`.
    pub fn tick(&self, now_ms: u64, current: Sample) {
        let mut inner = self.inner.lock().unwrap();
        let bucket = Self::close_bucket(&mut inner, now_ms, &current);
        inner.next_index += 1;
        inner.prev = current;
        inner.prev_ms = now_ms;
        inner.ring.push_back(bucket);
        while inner.ring.len() > self.capacity {
            let gone = inner.ring.pop_front().unwrap();
            let t = gone.totals();
            inner.evicted.add(&t);
        }
    }

    /// Snapshot the ring plus the open window measured against
    /// `current`. `WindowReport::total()` equals `current`'s lifetime
    /// counters exactly (see module docs).
    pub fn report(&self, now_ms: u64, current: &Sample) -> WindowReport {
        let mut inner = self.inner.lock().unwrap();
        let open = Self::close_bucket(&mut inner, now_ms, current);
        WindowReport {
            interval_ms: self.interval_ms,
            capacity: self.capacity as u64,
            baseline: inner.baseline,
            evicted: inner.evicted,
            buckets: inner.ring.iter().cloned().collect(),
            open,
        }
    }
}

/// Wire payload of the v5 `MetricsWindow` op (minus the health gauges,
/// which ride alongside in the response).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct WindowReport {
    pub interval_ms: u64,
    pub capacity: u64,
    pub baseline: WindowTotals,
    pub evicted: WindowTotals,
    /// Closed windows, oldest first.
    pub buckets: Vec<WindowBucket>,
    /// The in-progress window at report time.
    pub open: WindowBucket,
}

impl WindowReport {
    /// `baseline + evicted + Σ buckets + open` — equal to the lifetime
    /// counters at the moment the report was taken.
    pub fn total(&self) -> WindowTotals {
        let mut t = self.baseline;
        t.add(&self.evicted);
        for b in &self.buckets {
            let bt = b.totals();
            t.add(&bt);
        }
        let ot = self.open.totals();
        t.add(&ot);
        t
    }
}

pub fn enc_window_totals(e: &mut Enc, t: &WindowTotals) {
    e.u64(t.ingest_frames);
    e.u64(t.ingest_bytes);
    e.u64(t.busy);
    e.u64(t.frames_served);
}

pub fn dec_window_totals(d: &mut Dec) -> Result<WindowTotals, CodecError> {
    Ok(WindowTotals {
        ingest_frames: d.u64()?,
        ingest_bytes: d.u64()?,
        busy: d.u64()?,
        frames_served: d.u64()?,
    })
}

pub fn enc_window_bucket(e: &mut Enc, b: &WindowBucket) {
    e.u64(b.index);
    e.u64(b.start_ms);
    e.u64(b.dur_ms);
    e.u64(b.ingest_frames);
    e.u64(b.ingest_bytes);
    e.u64(b.busy);
    e.u64(b.frames_served);
    e.u64(b.ingest_p50_ns);
    e.u64(b.ingest_p99_ns);
}

pub fn dec_window_bucket(d: &mut Dec) -> Result<WindowBucket, CodecError> {
    Ok(WindowBucket {
        index: d.u64()?,
        start_ms: d.u64()?,
        dur_ms: d.u64()?,
        ingest_frames: d.u64()?,
        ingest_bytes: d.u64()?,
        busy: d.u64()?,
        frames_served: d.u64()?,
        ingest_p50_ns: d.u64()?,
        ingest_p99_ns: d.u64()?,
    })
}

pub fn enc_window_report(e: &mut Enc, r: &WindowReport) {
    e.u64(r.interval_ms);
    e.u64(r.capacity);
    enc_window_totals(e, &r.baseline);
    enc_window_totals(e, &r.evicted);
    e.len32(r.buckets.len());
    for b in &r.buckets {
        enc_window_bucket(e, b);
    }
    enc_window_bucket(e, &r.open);
}

pub fn dec_window_report(d: &mut Dec) -> Result<WindowReport, CodecError> {
    let interval_ms = d.u64()?;
    let capacity = d.u64()?;
    let baseline = dec_window_totals(d)?;
    let evicted = dec_window_totals(d)?;
    let n = d.len32(9 * 8)?;
    let mut buckets = Vec::with_capacity(n);
    for _ in 0..n {
        buckets.push(dec_window_bucket(d)?);
    }
    let open = dec_window_bucket(d)?;
    Ok(WindowReport {
        interval_ms,
        capacity,
        baseline,
        evicted,
        buckets,
        open,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample(frames: u64, bytes: u64, busy: u64, hist: &Histogram) -> Sample {
        Sample {
            ingest_frames: frames,
            ingest_bytes: bytes,
            busy,
            frames_served: frames + busy,
            ingest: hist.clone(),
        }
    }

    #[test]
    fn histogram_delta_is_exact_and_quantiles_hold() {
        let mut rng = Rng::new(0xD1FF);
        let mut early = Histogram::new();
        for _ in 0..500 {
            early.record(rng.below(1 << 24));
        }
        let mut late = early.clone();
        let mut alone = Histogram::new();
        for _ in 0..700 {
            let ns = rng.below(1 << 26);
            late.record(ns);
            alone.record(ns);
        }
        let delta = histogram_delta(&late, &early);
        assert_eq!(delta.count, alone.count);
        assert_eq!(delta.sum_ns, alone.sum_ns);
        assert_eq!(delta.buckets, alone.buckets);
        // Widened bounds still bracket the true extrema...
        assert!(delta.min_ns <= alone.min_ns);
        assert!(delta.max_ns >= alone.max_ns);
        // ...within one bucket (factor-of-two) on each side.
        assert!(delta.min_ns * 2 > alone.min_ns);
        assert!(delta.max_ns < alone.max_ns.saturating_mul(2));
        // Quantiles stay within sqrt(2) of the exact-only histogram's.
        for q in [0.5, 0.99] {
            let (a, b) = (delta.quantile(q), alone.quantile(q));
            assert!(a <= b * 2f64.sqrt() * 1.000001 && a * 2f64.sqrt() * 1.000001 >= b);
        }
        // Empty delta.
        let none = histogram_delta(&late, &late);
        assert!(none.is_empty());
        assert_eq!(none.min_ns, 0);
        assert_eq!(none.max_ns, 0);
    }

    /// The signature invariant: however the lifetime counters advance
    /// and whenever ticks land, every report's total() equals the
    /// lifetime counters at report time exactly — including after the
    /// bounded ring has evicted buckets.
    #[test]
    fn window_sums_equal_lifetime_deltas_exactly() {
        let mut rng = Rng::new(0x77);
        // Warm-restart shape: non-zero baseline.
        let mut hist = Histogram::new();
        for _ in 0..37 {
            hist.record(rng.below(1 << 20));
        }
        let mut cur = sample(37, 12_345, 3, &hist);
        let w = Windows::new(10, 4, cur.clone());

        let mut now = 0u64;
        for step in 0..40u64 {
            // Random traffic between ticks.
            for _ in 0..rng.below(50) {
                let ns = rng.below(1 << 22);
                cur.ingest.record(ns);
                cur.ingest_frames += 1;
                cur.ingest_bytes += 100 + ns % 1000;
                cur.frames_served += 1;
            }
            if rng.below(4) == 0 {
                cur.busy += 1;
                cur.frames_served += 1;
            }
            now += 5 + rng.below(20);
            if w.due(now) {
                w.tick(now, cur.clone());
            }
            // Report at arbitrary instants, mid-window included.
            let probe = now + rng.below(7);
            let rep = w.report(probe, &cur);
            let t = rep.total();
            assert_eq!(t.ingest_frames, cur.ingest_frames, "step {step}");
            assert_eq!(t.ingest_bytes, cur.ingest_bytes);
            assert_eq!(t.busy, cur.busy);
            assert_eq!(t.frames_served, cur.frames_served);
            assert!(rep.buckets.len() <= 4, "ring is bounded");
        }
        // The ring genuinely wrapped (40 steps x >=5ms vs 10ms window,
        // capacity 4), so eviction was exercised, not vacuous.
        let rep = w.report(now, &cur);
        assert!(rep.evicted.ingest_frames > 0 || rep.evicted.busy > 0);
        assert_eq!(rep.baseline.ingest_frames, 37);
        // Window indices are consecutive and never reused.
        for pair in rep.buckets.windows(2) {
            assert_eq!(pair[0].index + 1, pair[1].index);
        }
    }

    #[test]
    fn bucket_covers_actual_duration_and_throughput() {
        let cur0 = sample(0, 0, 0, &Histogram::new());
        let w = Windows::new(100, 8, cur0);
        let mut hist = Histogram::new();
        for _ in 0..50 {
            hist.record(1000);
        }
        let cur = sample(50, 5000, 0, &hist);
        // Tick lands late: the bucket must cover the true 250ms.
        w.tick(250, cur.clone());
        let rep = w.report(250, &cur);
        assert_eq!(rep.buckets.len(), 1);
        let b = &rep.buckets[0];
        assert_eq!(b.dur_ms, 250);
        assert_eq!(b.ingest_frames, 50);
        assert!((b.throughput() - 200.0).abs() < 1e-9, "50 / 0.25s");
        assert!(b.ingest_p50_ns > 0 && b.ingest_p99_ns >= b.ingest_p50_ns);
        // Open window right at the boundary is empty.
        assert_eq!(rep.open.ingest_frames, 0);
        assert_eq!(rep.open.dur_ms, 0);
        assert_eq!(WindowBucket::default().throughput(), 0.0);
    }

    #[test]
    fn sample_from_state_pulls_the_lifetime_counters() {
        let mut st = MetricsState {
            ingest_bytes: 4096,
            busy_admission: 2,
            busy_quota: 3,
            ..MetricsState::default()
        };
        for ns in [10u64, 20, 30] {
            st.ingest.record(ns);
        }
        let s = Sample::from_state(&st, 99);
        assert_eq!(s.ingest_frames, 3);
        assert_eq!(s.ingest_bytes, 4096);
        assert_eq!(s.busy, 5);
        assert_eq!(s.frames_served, 99);
        assert_eq!(s.ingest, st.ingest);
    }

    #[test]
    fn window_report_wire_roundtrip() {
        let mut hist = Histogram::new();
        hist.record(5000);
        let rep = WindowReport {
            interval_ms: 1000,
            capacity: 120,
            baseline: WindowTotals {
                ingest_frames: 1,
                ingest_bytes: 2,
                busy: 3,
                frames_served: 4,
            },
            evicted: WindowTotals::default(),
            buckets: vec![
                WindowBucket {
                    index: 0,
                    start_ms: 0,
                    dur_ms: 1000,
                    ingest_frames: 10,
                    ingest_bytes: 1000,
                    busy: 0,
                    frames_served: 11,
                    ingest_p50_ns: 700,
                    ingest_p99_ns: 9000,
                },
                WindowBucket {
                    index: 1,
                    ..WindowBucket::default()
                },
            ],
            open: WindowBucket {
                index: 2,
                start_ms: 2000,
                dur_ms: 381,
                ingest_frames: 4,
                ..WindowBucket::default()
            },
        };
        let mut e = Enc::new();
        enc_window_report(&mut e, &rep);
        let bytes = e.into_bytes();
        let mut d = Dec::new(&bytes);
        assert_eq!(dec_window_report(&mut d).unwrap(), rep);
        d.finish().unwrap();
        // Truncation is a typed error.
        let mut d = Dec::new(&bytes[..bytes.len() - 2]);
        assert!(dec_window_report(&mut d).is_err());
    }
}
