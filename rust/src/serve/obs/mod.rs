//! `serve::obs` — the daemon's observability layer (DESIGN.md §10).
//!
//! Three surfaces over one set of primitives:
//!
//! - [`events`]: a bounded lock-free event journal — per-shard writer
//!   rings, merged chronological reads, an exact `dropped` counter.
//! - [`window`]: a fixed ring of per-interval metric buckets whose
//!   sums provably equal the lifetime-counter deltas of
//!   `serve::metrics`.
//! - [`expo`]: a std-only HTTP/1.1 text exposition endpoint
//!   (`sketchd --obs-addr`; `GET /metrics` in Prometheus text format,
//!   `GET /events` as a journal dump).
//!
//! The same data is served in-protocol by the v5 `Events` /
//! `MetricsWindow` ops, so protocol clients and external scrapers see
//! one truth.  This module also carries the per-session sketch-health
//! gauges (per-layer ‖Z‖_F, top-σ, stable rank — the BASIS-style
//! invariant scalars) and the `SKETCHD_LOG`-filtered structured
//! logger that replaced the daemon's ad-hoc `eprintln!`s.

pub mod events;
pub mod expo;
pub mod window;

pub use events::{Event, EventJournal, EventKind, JournalWriter};
pub use expo::ExpoSnapshot;
pub use window::{
    Sample, WindowBucket, WindowReport, WindowTotals, Windows,
};

use crate::config::ObsConfig;
use crate::serve::codec::{CodecError, Dec, Enc};
use crate::sketch::{metrics as skmetrics, Mat};

/// Power iterations for the health-gauge spectral norm (same ballpark
/// as the archive drift analytics; the gauges are monitoring signals,
/// not reconstruction inputs).
const HEALTH_POWER_ITERS: usize = 24;

/// Per-layer sketch-health scalars computed from the resident Z sketch
/// (Eq. 5c's gradient-weighted sketch): Frobenius norm as the
/// gradient-magnitude proxy, top singular value, and the stable rank
/// ‖Z‖_F² / σ₁² as the gradient-diversity estimate.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LayerHealth {
    pub z_norm: f64,
    pub top_sigma: f64,
    pub stable_rank: f64,
}

/// One session's health gauges, one row per layer.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SessionHealth {
    pub session: u64,
    pub name: String,
    pub layers: Vec<LayerHealth>,
}

/// Compute the health scalars for one layer's Z sketch.
pub fn layer_health(z: &Mat) -> LayerHealth {
    let z_norm = z.fro_norm();
    if z_norm == 0.0 {
        return LayerHealth::default();
    }
    let top_sigma = skmetrics::spectral_norm_power(z, HEALTH_POWER_ITERS);
    LayerHealth {
        z_norm,
        top_sigma,
        stable_rank: (z_norm * z_norm) / (top_sigma * top_sigma).max(1e-300),
    }
}

pub fn enc_session_health(e: &mut Enc, s: &SessionHealth) {
    e.u64(s.session);
    e.str(&s.name);
    e.len32(s.layers.len());
    for l in &s.layers {
        e.f64(l.z_norm);
        e.f64(l.top_sigma);
        e.f64(l.stable_rank);
    }
}

pub fn dec_session_health(d: &mut Dec) -> Result<SessionHealth, CodecError> {
    let session = d.u64()?;
    let name = d.str()?;
    let n = d.len32(24)?;
    let mut layers = Vec::with_capacity(n);
    for _ in 0..n {
        layers.push(LayerHealth {
            z_norm: d.f64()?,
            top_sigma: d.f64()?,
            stable_rank: d.f64()?,
        });
    }
    Ok(SessionHealth {
        session,
        name,
        layers,
    })
}

/// Log severities for the journal-backed structured logger.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 1,
    Info = 2,
    Debug = 3,
}

/// Stderr verbosity filter, parsed once from `SKETCHD_LOG`
/// (`error` / `info` / `debug`; anything else or unset = silent, so
/// test and CI output stays clean).  The journal always records the
/// typed event regardless of the filter — the filter only gates the
/// human-readable stderr line.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LogFilter {
    max: u8,
}

impl LogFilter {
    pub fn from_env() -> LogFilter {
        Self::parse(std::env::var("SKETCHD_LOG").as_deref().unwrap_or(""))
    }

    pub fn parse(s: &str) -> LogFilter {
        let max = match s.trim().to_ascii_lowercase().as_str() {
            "error" => 1,
            "info" => 2,
            "debug" => 3,
            _ => 0,
        };
        LogFilter { max }
    }

    /// Should a record at `level` be written to stderr?
    pub fn on(&self, level: Level) -> bool {
        (level as u8) <= self.max
    }
}

/// Everything the daemon's observability layer owns, constructed once
/// at bind time and shared (by reference) with every shard, the run
/// loop, and the exposition listener.
pub struct Obs {
    pub journal: EventJournal,
    pub windows: Windows,
    pub log: LogFilter,
    /// Requests slower than this are journaled as `slow-request`.
    pub slow_ns: u64,
}

impl Obs {
    /// `initial` is the merged lifetime capture at bind (post-restore),
    /// which seeds the window ring's baseline.
    pub fn new(cfg: &ObsConfig, shards: usize, initial: Sample) -> Obs {
        Obs {
            journal: EventJournal::new(1 + shards, cfg.journal_capacity),
            windows: Windows::new(cfg.window_ms, cfg.window_count, initial),
            log: LogFilter::from_env(),
            slow_ns: cfg.slow_ms.saturating_mul(1_000_000),
        }
    }

    /// The control plane's writer (acceptor / snapshot / run loop).
    pub fn control(&self) -> JournalWriter<'_> {
        self.journal.writer(0)
    }

    /// Shard `k`'s writer.
    pub fn shard(&self, k: usize) -> JournalWriter<'_> {
        self.journal.writer(1 + k)
    }

    /// Structured log record: always journaled as a typed `Log` event;
    /// the human-readable line (built lazily) goes to stderr only when
    /// `SKETCHD_LOG` admits the level.
    pub fn log(
        &self,
        w: &JournalWriter<'_>,
        level: Level,
        tag: u8,
        detail: u64,
        text: impl FnOnce() -> String,
    ) {
        w.emit(EventKind::Log {
            tag,
            level: level as u64,
            detail,
        });
        if self.log.on(level) {
            eprintln!("sketchd: {}", text());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn log_filter_parses_levels_and_defaults_silent() {
        let off = LogFilter::parse("");
        assert!(!off.on(Level::Error));
        let garbage = LogFilter::parse("loud");
        assert!(!garbage.on(Level::Error));
        let err = LogFilter::parse("error");
        assert!(err.on(Level::Error) && !err.on(Level::Info));
        let info = LogFilter::parse(" INFO ");
        assert!(info.on(Level::Error) && info.on(Level::Info));
        assert!(!info.on(Level::Debug));
        let dbg = LogFilter::parse("debug");
        assert!(dbg.on(Level::Debug));
    }

    #[test]
    fn layer_health_matches_reference_metrics() {
        let mut rng = Rng::new(0x4EA1);
        let z = Mat::gaussian(24, 7, &mut rng);
        let h = layer_health(&z);
        assert!((h.z_norm - z.fro_norm()).abs() < 1e-12);
        let sr = skmetrics::stable_rank_power(&z, HEALTH_POWER_ITERS);
        assert!(
            (h.stable_rank - sr).abs() / sr < 1e-9,
            "stable rank {} vs reference {sr}",
            h.stable_rank
        );
        assert!(h.top_sigma > 0.0 && h.stable_rank >= 1.0 - 1e-9);
        // Zero sketch: all-zero gauges, no NaN.
        let zero = layer_health(&Mat::zeros(8, 3));
        assert_eq!(zero, LayerHealth::default());
    }

    #[test]
    fn session_health_wire_roundtrip() {
        let s = SessionHealth {
            session: 42,
            name: "tenant-a".into(),
            layers: vec![
                LayerHealth {
                    z_norm: 1.5,
                    top_sigma: 1.2,
                    stable_rank: 1.5625,
                },
                LayerHealth::default(),
            ],
        };
        let mut e = Enc::new();
        enc_session_health(&mut e, &s);
        let bytes = e.into_bytes();
        let mut d = Dec::new(&bytes);
        assert_eq!(dec_session_health(&mut d).unwrap(), s);
        d.finish().unwrap();
    }
}
