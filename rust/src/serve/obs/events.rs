//! Bounded lock-free event journal (DESIGN.md §10).
//!
//! The daemon records typed, fixed-size events — session lifecycle,
//! Busy rejections by cause, snapshot writes, rank changes, shard
//! accepts, slow requests, structured log records — into per-writer
//! ring buffers.  One writer slot belongs to the daemon control plane
//! (acceptor / snapshot loop) and one to each connection shard, so
//! every slot has exactly one writing thread and recording is a handful
//! of atomic stores: no locks, no allocation, no formatting on the hot
//! path.
//!
//! ## Slot protocol (per-field seqlock)
//!
//! Each ring slot is five atomics: a sequence word plus the event's
//! four payload words.  The writer stamps the slot's sequence *odd*
//! (`2·i + 1` for logical index `i`), stores the payload, then stamps
//! it *even* (`2·(i + 1)`).  A reader targeting logical index `i`
//! accepts the payload only if the sequence reads `2·(i + 1)` both
//! before and after the payload loads — anything else means the slot
//! was mid-write or has been overwritten by a newer event, and the
//! reader skips it.  All accesses are `SeqCst`: events are rare (tens
//! per second at most, vs. tens of thousands of frames), so the cost
//! of the strongest ordering is irrelevant and the reasoning is
//! simple.  Readers never block writers and vice versa.
//!
//! ## Drop accounting
//!
//! The ring is bounded: once a writer has recorded more than
//! `capacity` events, each new event overwrites the oldest retained
//! one and bumps that writer's `dropped` counter — an *exact* count of
//! events that are no longer retrievable.  `merged()` returns the
//! retained events of every writer in one chronological (timestamp-
//! ordered) list together with the exact total drop count.

use std::sync::atomic::{AtomicU64, Ordering::SeqCst};
use std::time::{Instant, SystemTime, UNIX_EPOCH};

/// Event kind discriminants (the `kind` byte on the wire and in the
/// ring). Public so the exposition/CLI layers can render by name.
pub mod kind {
    pub const SESSION_OPEN: u8 = 1;
    pub const SESSION_CLOSE: u8 = 2;
    pub const BUSY: u8 = 3;
    pub const SNAPSHOT: u8 = 4;
    pub const RANK_CHANGE: u8 = 5;
    pub const SHARD_ACCEPT: u8 = 6;
    pub const SLOW_REQUEST: u8 = 7;
    pub const LOG: u8 = 8;
    pub const HANDLER_PANIC: u8 = 9;
}

/// `code` values for [`kind::BUSY`] events.
pub mod busy_cause {
    pub const ADMISSION: u8 = 1;
    pub const QUOTA: u8 = 2;
}

/// `code` values for [`kind::LOG`] events (the structured-logger tags;
/// the human text, if any, goes to stderr under `SKETCHD_LOG`).
pub mod log_tag {
    pub const POLLER_INIT_FAILED: u8 = 1;
    pub const SNAPSHOT_FAILED: u8 = 2;
    pub const ACCEPT_FAILED: u8 = 3;
    pub const OBS_LISTENER_FAILED: u8 = 4;
    /// A post-commit step of an applied ingest degraded (archive/hub
    /// accounting, reconstruction): the frame was acked, the reply is
    /// still `IngestOk`, and the shortfall is recorded here instead of
    /// an error reply (DESIGN.md §11 — an error reply to `Ingest` must
    /// mean "nothing was applied").
    pub const INGEST_DEGRADED: u8 = 5;
}

/// One journal record. `ts_ns` is monotonic nanoseconds since the
/// journal was created (the daemon start); `slot` identifies the
/// writer (0 = control plane, `1 + k` = shard `k`); `kind`/`code` type
/// the event and `a`/`b` carry its two payload words (see
/// [`EventKind`] for the packing).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Event {
    pub ts_ns: u64,
    pub slot: u32,
    pub kind: u8,
    pub code: u8,
    pub a: u64,
    pub b: u64,
}

/// Typed view of an event's payload; `pack`/`unpack` define the only
/// mapping between the enum and the four raw words.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    SessionOpen { session: u64 },
    SessionClose { session: u64 },
    BusyAdmission { used: u64, limit: u64 },
    BusyQuota { session: u64, used: u64 },
    /// A snapshot was written: session count + the pause it cost.
    Snapshot { sessions: u64, pause_ns: u64 },
    RankChange { session: u64, from: u32, to: u32 },
    /// A shard picked up a handed-off connection; `conn` is that
    /// shard's lifetime accept count.
    ShardAccept { conn: u64 },
    /// A request took longer than the configured threshold.
    SlowRequest { msg: u8, elapsed_ns: u64 },
    /// Structured log record (tag from [`log_tag`], level 1=error
    /// 2=info 3=debug, `detail` is tag-specific, e.g. a shard index).
    Log { tag: u8, level: u64, detail: u64 },
    /// A request handler panicked and was caught at the shard's
    /// isolation boundary (`msg` is the request's message type;
    /// `session` is 0 when the request named none).
    HandlerPanic { msg: u8, session: u64 },
}

impl EventKind {
    /// (kind, code, a, b)
    pub fn pack(&self) -> (u8, u8, u64, u64) {
        match *self {
            EventKind::SessionOpen { session } => {
                (kind::SESSION_OPEN, 0, session, 0)
            }
            EventKind::SessionClose { session } => {
                (kind::SESSION_CLOSE, 0, session, 0)
            }
            EventKind::BusyAdmission { used, limit } => {
                (kind::BUSY, busy_cause::ADMISSION, used, limit)
            }
            EventKind::BusyQuota { session, used } => {
                (kind::BUSY, busy_cause::QUOTA, session, used)
            }
            EventKind::Snapshot { sessions, pause_ns } => {
                (kind::SNAPSHOT, 0, sessions, pause_ns)
            }
            EventKind::RankChange { session, from, to } => (
                kind::RANK_CHANGE,
                0,
                session,
                ((from as u64) << 32) | to as u64,
            ),
            EventKind::ShardAccept { conn } => (kind::SHARD_ACCEPT, 0, conn, 0),
            EventKind::SlowRequest { msg, elapsed_ns } => {
                (kind::SLOW_REQUEST, msg, elapsed_ns, 0)
            }
            EventKind::Log { tag, level, detail } => {
                (kind::LOG, tag, level, detail)
            }
            EventKind::HandlerPanic { msg, session } => {
                (kind::HANDLER_PANIC, msg, session, 0)
            }
        }
    }
}

impl Event {
    /// Typed view of the payload (None for unknown kinds, e.g. from a
    /// newer daemon).
    pub fn unpack(&self) -> Option<EventKind> {
        Some(match self.kind {
            kind::SESSION_OPEN => EventKind::SessionOpen { session: self.a },
            kind::SESSION_CLOSE => EventKind::SessionClose { session: self.a },
            kind::BUSY if self.code == busy_cause::ADMISSION => {
                EventKind::BusyAdmission {
                    used: self.a,
                    limit: self.b,
                }
            }
            kind::BUSY => EventKind::BusyQuota {
                session: self.a,
                used: self.b,
            },
            kind::SNAPSHOT => EventKind::Snapshot {
                sessions: self.a,
                pause_ns: self.b,
            },
            kind::RANK_CHANGE => EventKind::RankChange {
                session: self.a,
                from: (self.b >> 32) as u32,
                to: self.b as u32,
            },
            kind::SHARD_ACCEPT => EventKind::ShardAccept { conn: self.a },
            kind::SLOW_REQUEST => EventKind::SlowRequest {
                msg: self.code,
                elapsed_ns: self.a,
            },
            kind::LOG => EventKind::Log {
                tag: self.code,
                level: self.a,
                detail: self.b,
            },
            kind::HANDLER_PANIC => EventKind::HandlerPanic {
                msg: self.code,
                session: self.a,
            },
            _ => return None,
        })
    }

    /// Stable one-line rendering used by `/events` and `connect
    /// --events`.
    pub fn describe(&self) -> String {
        let who = if self.slot == 0 {
            "control".to_string()
        } else {
            format!("shard {}", self.slot - 1)
        };
        let what = match self.unpack() {
            Some(EventKind::SessionOpen { session }) => {
                format!("session-open session={session}")
            }
            Some(EventKind::SessionClose { session }) => {
                format!("session-close session={session}")
            }
            Some(EventKind::BusyAdmission { used, limit }) => {
                format!("busy cause=admission used={used} limit={limit}")
            }
            Some(EventKind::BusyQuota { session, used }) => {
                format!("busy cause=quota session={session} used={used}")
            }
            Some(EventKind::Snapshot { sessions, pause_ns }) => format!(
                "snapshot sessions={sessions} pause_ms={:.3}",
                pause_ns as f64 / 1e6
            ),
            Some(EventKind::RankChange { session, from, to }) => {
                format!("rank-change session={session} from={from} to={to}")
            }
            Some(EventKind::ShardAccept { conn }) => {
                format!("shard-accept conn={conn}")
            }
            Some(EventKind::SlowRequest { msg, elapsed_ns }) => format!(
                "slow-request msg={msg} elapsed_ms={:.3}",
                elapsed_ns as f64 / 1e6
            ),
            Some(EventKind::Log { tag, level, detail }) => {
                let tag = match tag {
                    log_tag::POLLER_INIT_FAILED => "poller-init-failed",
                    log_tag::SNAPSHOT_FAILED => "snapshot-failed",
                    log_tag::ACCEPT_FAILED => "accept-failed",
                    log_tag::OBS_LISTENER_FAILED => "obs-listener-failed",
                    log_tag::INGEST_DEGRADED => "ingest-degraded",
                    _ => "unknown",
                };
                let level = match level {
                    1 => "error",
                    2 => "info",
                    _ => "debug",
                };
                format!("log level={level} tag={tag} detail={detail}")
            }
            Some(EventKind::HandlerPanic { msg, session }) => {
                format!("handler-panic msg={msg} session={session}")
            }
            None => format!(
                "unknown kind={} code={} a={} b={}",
                self.kind, self.code, self.a, self.b
            ),
        };
        format!("{:>12.6}s {who:<9} {what}", self.ts_ns as f64 / 1e9)
    }
}

/// One seqlock slot (see module docs for the protocol).
struct Slot {
    seq: AtomicU64,
    ts: AtomicU64,
    /// `kind << 8 | code`.
    meta: AtomicU64,
    a: AtomicU64,
    b: AtomicU64,
}

impl Slot {
    fn new() -> Slot {
        Slot {
            seq: AtomicU64::new(0),
            ts: AtomicU64::new(0),
            meta: AtomicU64::new(0),
            a: AtomicU64::new(0),
            b: AtomicU64::new(0),
        }
    }
}

/// One writer's bounded ring. Written by exactly one thread; read by
/// any number of threads concurrently.
struct WriterRing {
    /// Total events ever recorded by this writer.
    head: AtomicU64,
    /// Exact count of events overwritten before retrieval was possible.
    dropped: AtomicU64,
    slots: Vec<Slot>,
}

impl WriterRing {
    fn new(capacity: usize) -> WriterRing {
        WriterRing {
            head: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            slots: (0..capacity.max(1)).map(|_| Slot::new()).collect(),
        }
    }

    fn record(&self, ts_ns: u64, kind: u8, code: u8, a: u64, b: u64) {
        let cap = self.slots.len() as u64;
        let h = self.head.load(SeqCst);
        let slot = &self.slots[(h % cap) as usize];
        slot.seq.store(2 * h + 1, SeqCst);
        slot.ts.store(ts_ns, SeqCst);
        slot.meta.store(((kind as u64) << 8) | code as u64, SeqCst);
        slot.a.store(a, SeqCst);
        slot.b.store(b, SeqCst);
        slot.seq.store(2 * (h + 1), SeqCst);
        if h >= cap {
            self.dropped.fetch_add(1, SeqCst);
        }
        self.head.store(h + 1, SeqCst);
    }

    /// Read the retained events (oldest first). Events overwritten
    /// mid-read are skipped — they will have been counted as dropped
    /// by their writer.
    fn collect(&self, slot_id: u32, out: &mut Vec<Event>) {
        let cap = self.slots.len() as u64;
        let head = self.head.load(SeqCst);
        let lo = head.saturating_sub(cap);
        for i in lo..head {
            let slot = &self.slots[(i % cap) as usize];
            let want = 2 * (i + 1);
            if slot.seq.load(SeqCst) != want {
                continue;
            }
            let ts = slot.ts.load(SeqCst);
            let meta = slot.meta.load(SeqCst);
            let a = slot.a.load(SeqCst);
            let b = slot.b.load(SeqCst);
            if slot.seq.load(SeqCst) != want {
                continue;
            }
            out.push(Event {
                ts_ns: ts,
                slot: slot_id,
                kind: (meta >> 8) as u8,
                code: meta as u8,
                a,
                b,
            });
        }
    }
}

/// Handle for one writer slot; cheap to copy around a shard loop.
pub struct JournalWriter<'a> {
    journal: &'a EventJournal,
    slot: u32,
}

impl JournalWriter<'_> {
    pub fn emit(&self, ev: EventKind) {
        let (kind, code, a, b) = ev.pack();
        self.journal.writers[self.slot as usize].record(
            self.journal.now_ns(),
            kind,
            code,
            a,
            b,
        );
    }
}

/// The daemon-wide journal: one bounded ring per writer slot.
pub struct EventJournal {
    started: Instant,
    base_unix_ms: u64,
    writers: Vec<WriterRing>,
}

impl EventJournal {
    /// `writers` slots (the daemon uses `1 + shards`), each retaining
    /// up to `capacity` events.
    pub fn new(writers: usize, capacity: usize) -> EventJournal {
        EventJournal {
            started: Instant::now(),
            base_unix_ms: SystemTime::now()
                .duration_since(UNIX_EPOCH)
                .map(|d| d.as_millis() as u64)
                .unwrap_or(0),
            writers: (0..writers.max(1))
                .map(|_| WriterRing::new(capacity))
                .collect(),
        }
    }

    /// Monotonic nanoseconds since journal creation.
    pub fn now_ns(&self) -> u64 {
        self.started.elapsed().as_nanos().min(u64::MAX as u128) as u64
    }

    /// Unix epoch milliseconds at journal creation: `base_unix_ms +
    /// ts_ns / 1e6` is an event's absolute wall time.
    pub fn base_unix_ms(&self) -> u64 {
        self.base_unix_ms
    }

    pub fn writer(&self, slot: usize) -> JournalWriter<'_> {
        assert!(slot < self.writers.len(), "journal writer slot {slot}");
        JournalWriter {
            journal: self,
            slot: slot as u32,
        }
    }

    /// Total events ever recorded across all writers.
    pub fn total(&self) -> u64 {
        self.writers.iter().map(|w| w.head.load(SeqCst)).sum()
    }

    /// Exact total of events no longer retrievable.
    pub fn dropped(&self) -> u64 {
        self.writers.iter().map(|w| w.dropped.load(SeqCst)).sum()
    }

    /// All retained events merged chronologically (stable on ties), at
    /// most `max` of the *newest* (0 = no cap), plus the exact dropped
    /// total.
    pub fn merged(&self, max: usize) -> (Vec<Event>, u64) {
        let mut out = Vec::new();
        for (i, w) in self.writers.iter().enumerate() {
            w.collect(i as u32, &mut out);
        }
        out.sort_by_key(|e| e.ts_ns);
        if max > 0 && out.len() > max {
            out.drain(..out.len() - max);
        }
        (out, self.dropped())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packing_roundtrips_every_kind() {
        let kinds = [
            EventKind::SessionOpen { session: 7 },
            EventKind::SessionClose { session: u64::MAX },
            EventKind::BusyAdmission { used: 4, limit: 4 },
            EventKind::BusyQuota {
                session: 3,
                used: 9000,
            },
            EventKind::Snapshot {
                sessions: 5,
                pause_ns: 1_234_567,
            },
            EventKind::RankChange {
                session: 2,
                from: 4,
                to: 8,
            },
            EventKind::ShardAccept { conn: 31 },
            EventKind::SlowRequest {
                msg: 3,
                elapsed_ns: 300_000_000,
            },
            EventKind::Log {
                tag: log_tag::ACCEPT_FAILED,
                level: 1,
                detail: 0,
            },
            EventKind::HandlerPanic { msg: 3, session: 42 },
        ];
        for k in kinds {
            let (kind, code, a, b) = k.pack();
            let ev = Event {
                ts_ns: 1,
                slot: 0,
                kind,
                code,
                a,
                b,
            };
            assert_eq!(ev.unpack(), Some(k));
            assert!(!ev.describe().is_empty());
        }
        let bogus = Event {
            ts_ns: 0,
            slot: 0,
            kind: 200,
            code: 0,
            a: 0,
            b: 0,
        };
        assert_eq!(bogus.unpack(), None);
        assert!(bogus.describe().contains("unknown"));
    }

    #[test]
    fn ring_retains_newest_and_counts_drops_exactly() {
        let j = EventJournal::new(1, 4);
        let w = j.writer(0);
        for s in 0..10u64 {
            w.emit(EventKind::SessionOpen { session: s });
        }
        let (events, dropped) = j.merged(0);
        assert_eq!(j.total(), 10);
        assert_eq!(dropped, 6, "10 written into capacity 4");
        assert_eq!(events.len(), 4);
        let sessions: Vec<u64> = events.iter().map(|e| e.a).collect();
        assert_eq!(sessions, vec![6, 7, 8, 9], "newest retained, in order");
        // Timestamps are monotone.
        for pair in events.windows(2) {
            assert!(pair[0].ts_ns <= pair[1].ts_ns);
        }
    }

    #[test]
    fn merged_interleaves_writers_chronologically() {
        let j = EventJournal::new(3, 16);
        // Alternate writers; creation order == timestamp order.
        for i in 0..12u64 {
            j.writer((i % 3) as usize)
                .emit(EventKind::ShardAccept { conn: i });
        }
        let (events, dropped) = j.merged(0);
        assert_eq!(dropped, 0);
        assert_eq!(events.len(), 12);
        let conns: Vec<u64> = events.iter().map(|e| e.a).collect();
        assert_eq!(conns, (0..12).collect::<Vec<u64>>());
        assert_eq!(events[4].slot, 1, "writer slot rides along");
        // A `max` cap keeps the newest tail.
        let (tail, _) = j.merged(5);
        let conns: Vec<u64> = tail.iter().map(|e| e.a).collect();
        assert_eq!(conns, vec![7, 8, 9, 10, 11]);
    }

    #[test]
    fn concurrent_readers_never_see_torn_events() {
        use std::sync::atomic::AtomicBool;
        let j = EventJournal::new(2, 8);
        let stop = AtomicBool::new(false);
        std::thread::scope(|scope| {
            for slot in 0..2usize {
                let j = &j;
                let stop = &stop;
                scope.spawn(move || {
                    let w = j.writer(slot);
                    for i in 0..20_000u64 {
                        // a and b carry the same value; a torn read
                        // would break the equality below.
                        w.emit(EventKind::BusyQuota {
                            session: i,
                            used: i,
                        });
                    }
                    stop.store(true, SeqCst);
                });
            }
            let mut seen = 0usize;
            while !stop.load(SeqCst) || seen == 0 {
                let (events, _) = j.merged(0);
                for e in &events {
                    assert_eq!(e.a, e.b, "torn event payload");
                    assert_eq!(e.kind, kind::BUSY);
                }
                seen += events.len();
            }
        });
        // Exact accounting: everything written is retained or dropped.
        assert_eq!(j.total(), 40_000);
        let (events, dropped) = j.merged(0);
        assert_eq!(events.len() as u64 + dropped, 40_000);
    }
}
