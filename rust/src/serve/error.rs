//! The serve subsystem's single error vocabulary.
//!
//! Before the shard rewrite the daemon translated
//! [`HubError`](crate::monitor::HubError) into protocol codes in
//! `daemon.rs`, the client re-materialised those codes as a
//! `Remote { code, message }` catch-all, and both sides kept their own
//! ad-hoc `invalid(..)` helpers.  [`Error`] collapses all three
//! vocabularies: every wire [`ErrorCode`] has exactly one variant, and
//! the [`Error::code`] / [`Error::from_code`] pair is the *only*
//! mapping table — daemon encode and client decode go through it, so a
//! new code can't silently diverge between the two sides
//! (`error_code_round_trip` pins the bijection).
//!
//! Some variants never cross the wire as codes: [`Error::Busy`] has
//! its own protocol frame (it is backpressure, not failure — it
//! carries the quota numbers a client needs for the documented
//! Diagnose-drain remedy), while [`Error::Timeout`] / [`Error::Io`] /
//! [`Error::Protocol`] / [`Error::Unexpected`] are client-side
//! observations.  `Protocol` vs `Unexpected` is the replay split:
//! a reply that couldn't be *decoded* may be a torn frame and is
//! retried by resumable sessions; a reply that decoded fine but
//! answers the wrong request is a logic error and is surfaced.

use std::fmt;
use std::io;

use crate::monitor::HubError;

use super::proto::{ErrorCode, Response};

/// Everything that can go wrong in the serve subsystem, daemon- or
/// client-side.  `ServeError` remains as a deprecated alias.
#[derive(Debug)]
pub enum Error {
    /// Backpressure (admission cap or session quota): retryable after
    /// the documented remedy (wait, or Diagnose to drain the quota).
    Busy { used: u64, limit: u64 },
    /// Frame-layer violation: bad magic, oversized length, or an
    /// undecodable payload.  Fatal — the connection closes after the
    /// reply because framing can no longer be trusted.
    BadFrame(String),
    /// Protocol version outside the daemon's accepted range (also
    /// per-op gates, e.g. `Metrics` below v3).  Fatal like `BadFrame`.
    UnsupportedVersion(String),
    /// Request named a session id the daemon doesn't have.
    UnknownSession(String),
    /// `OpenSession` raced an identical registration.
    DuplicateSession(String),
    /// The hub ran out of session ids (u64 exhaustion sentinel).
    SessionsExhausted(String),
    /// Semantically invalid request (zero window, layer out of range).
    Invalid(String),
    /// Daemon-side invariant failure; nothing the client can fix.
    Internal(String),
    /// Client-side: the reply frame itself could not be trusted —
    /// undecodable payload, out-of-range version.  Plausibly a torn
    /// frame from a daemon dying mid-write, so resumable sessions
    /// treat it as a transport failure and reconnect + replay.
    Protocol(String),
    /// Client-side: a well-formed, in-protocol reply that does not
    /// answer the request that was sent (e.g. `Diagnosis` in reply to
    /// `Ingest`).  A daemon logic error, NOT a transport failure —
    /// resumable sessions surface it instead of masking it behind a
    /// reconnect-and-replay cycle.
    Unexpected(String),
    /// Client-side: a socket deadline expired.
    Timeout(io::Error),
    /// Client-side: any other transport failure.
    Io(io::Error),
}

/// The deprecated name for [`Error`], kept one release for callers
/// that imported it before the unification.
#[deprecated(since = "0.3.0", note = "use serve::Error")]
pub type ServeError = Error;

impl Error {
    /// The wire code for this error, or `None` for the three variants
    /// that never travel as an `Error` frame (`Busy` has its own frame;
    /// `Protocol`/`Timeout`/`Io` are client-side observations).
    ///
    /// This table and [`Error::from_code`] are intentionally the only
    /// two places that know the variant ↔ code pairing.
    pub fn code(&self) -> Option<ErrorCode> {
        Some(match self {
            Error::BadFrame(_) => ErrorCode::BadFrame,
            Error::UnsupportedVersion(_) => ErrorCode::UnsupportedVersion,
            Error::UnknownSession(_) => ErrorCode::UnknownSession,
            Error::DuplicateSession(_) => ErrorCode::DuplicateSession,
            Error::SessionsExhausted(_) => ErrorCode::SessionsExhausted,
            Error::Invalid(_) => ErrorCode::Invalid,
            Error::Internal(_) => ErrorCode::Internal,
            Error::Busy { .. }
            | Error::Protocol(_)
            | Error::Unexpected(_)
            | Error::Timeout(_)
            | Error::Io(_) => return None,
        })
    }

    /// Inverse of [`Error::code`]: materialise a received wire code.
    pub fn from_code(code: ErrorCode, message: String) -> Error {
        match code {
            ErrorCode::BadFrame => Error::BadFrame(message),
            ErrorCode::UnsupportedVersion => {
                Error::UnsupportedVersion(message)
            }
            ErrorCode::UnknownSession => Error::UnknownSession(message),
            ErrorCode::DuplicateSession => Error::DuplicateSession(message),
            ErrorCode::SessionsExhausted => {
                Error::SessionsExhausted(message)
            }
            ErrorCode::Invalid => Error::Invalid(message),
            ErrorCode::Internal => Error::Internal(message),
        }
    }

    /// The human-readable detail carried by this error.
    pub fn message(&self) -> String {
        match self {
            Error::Busy { used, limit } => {
                format!("busy: {used}/{limit}")
            }
            Error::BadFrame(m)
            | Error::UnsupportedVersion(m)
            | Error::UnknownSession(m)
            | Error::DuplicateSession(m)
            | Error::SessionsExhausted(m)
            | Error::Invalid(m)
            | Error::Internal(m)
            | Error::Protocol(m)
            | Error::Unexpected(m) => m.clone(),
            Error::Timeout(e) | Error::Io(e) => e.to_string(),
        }
    }

    /// Whether the daemon must close the connection after replying:
    /// once framing or version negotiation is broken, later bytes on
    /// the same socket can't be trusted.
    pub fn is_fatal(&self) -> bool {
        matches!(
            self,
            Error::BadFrame(_) | Error::UnsupportedVersion(_)
        )
    }

    /// The daemon's reply frame for this error.  `Busy` keeps its
    /// dedicated backpressure frame; everything else becomes the coded
    /// `Error` frame (client-side-only variants fold to `Internal`,
    /// which a daemon never constructs from them in practice).
    pub fn response(&self) -> Response {
        match self {
            Error::Busy { used, limit } => Response::Busy {
                used: *used,
                limit: *limit,
            },
            other => Response::Error {
                code: other.code().unwrap_or(ErrorCode::Internal),
                message: other.message(),
            },
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Busy { used, limit } => write!(
                f,
                "daemon busy (used {used} of {limit}); retry after \
                 Diagnose or wait"
            ),
            Error::Timeout(e) => write!(f, "timed out: {e}"),
            Error::Io(e) => write!(f, "transport error: {e}"),
            Error::Protocol(m) => write!(f, "protocol violation: {m}"),
            Error::Unexpected(m) => {
                write!(f, "unexpected reply: {m}")
            }
            other => match other.code() {
                Some(code) => write!(f, "{code}: {}", other.message()),
                None => unreachable!("non-coded variants matched above"),
            },
        }
    }
}

impl std::error::Error for Error {}

impl From<HubError> for Error {
    fn from(e: HubError) -> Error {
        match e {
            HubError::NoSuchSession(id) => {
                Error::UnknownSession(format!("no session {}", id.raw()))
            }
            HubError::DuplicateSession(id) => Error::DuplicateSession(
                format!("session {} already registered", id.raw()),
            ),
            HubError::SessionsExhausted => {
                Error::SessionsExhausted("session ids exhausted".into())
            }
        }
    }
}

impl From<io::Error> for Error {
    fn from(e: io::Error) -> Error {
        match e.kind() {
            io::ErrorKind::TimedOut | io::ErrorKind::WouldBlock => {
                Error::Timeout(e)
            }
            _ => Error::Io(e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const ALL_CODES: [ErrorCode; 7] = [
        ErrorCode::BadFrame,
        ErrorCode::UnsupportedVersion,
        ErrorCode::UnknownSession,
        ErrorCode::DuplicateSession,
        ErrorCode::SessionsExhausted,
        ErrorCode::Invalid,
        ErrorCode::Internal,
    ];

    #[test]
    fn error_code_round_trip() {
        // Every wire code maps to exactly one variant and back: the
        // daemon's encode table IS the client's decode table.
        for code in ALL_CODES {
            let err = Error::from_code(code, format!("ctx for {code}"));
            assert_eq!(err.code(), Some(code), "{code} round-trips");
            assert_eq!(err.message(), format!("ctx for {code}"));
            match err.response() {
                Response::Error { code: c, message } => {
                    assert_eq!(c, code);
                    assert_eq!(message, format!("ctx for {code}"));
                }
                other => panic!("coded error became {other:?}"),
            }
        }
        // Codes are distinct variants (the mapping is a bijection).
        let discriminants: Vec<_> = ALL_CODES
            .iter()
            .map(|&c| {
                std::mem::discriminant(&Error::from_code(c, String::new()))
            })
            .collect();
        for (i, a) in discriminants.iter().enumerate() {
            for b in &discriminants[i + 1..] {
                assert_ne!(a, b, "two codes collapsed to one variant");
            }
        }
    }

    #[test]
    fn non_coded_variants_have_no_code() {
        assert_eq!(Error::Busy { used: 1, limit: 2 }.code(), None);
        assert_eq!(Error::Protocol("x".into()).code(), None);
        assert_eq!(Error::Unexpected("x".into()).code(), None);
        assert_eq!(Error::Unexpected("x".into()).message(), "x");
        let t: Error = io::Error::from(io::ErrorKind::TimedOut).into();
        assert!(matches!(t, Error::Timeout(_)));
        assert_eq!(t.code(), None);
        let o: Error = io::Error::from(io::ErrorKind::BrokenPipe).into();
        assert!(matches!(o, Error::Io(_)));
    }

    #[test]
    fn busy_keeps_its_own_frame() {
        match (Error::Busy { used: 7, limit: 9 }).response() {
            Response::Busy { used, limit } => {
                assert_eq!((used, limit), (7, 9));
            }
            other => panic!("Busy became {other:?}"),
        }
    }

    #[test]
    fn hub_errors_map_through_the_table() {
        use crate::monitor::SessionId;
        let e: Error = HubError::NoSuchSession(SessionId::from_raw(4)).into();
        assert_eq!(e.code(), Some(ErrorCode::UnknownSession));
        let e: Error =
            HubError::DuplicateSession(SessionId::from_raw(4)).into();
        assert_eq!(e.code(), Some(ErrorCode::DuplicateSession));
        let e: Error = HubError::SessionsExhausted.into();
        assert_eq!(e.code(), Some(ErrorCode::SessionsExhausted));
    }

    #[test]
    fn fatality_matches_the_daemon_close_rule() {
        assert!(Error::BadFrame("m".into()).is_fatal());
        assert!(Error::UnsupportedVersion("m".into()).is_fatal());
        assert!(!Error::UnknownSession("m".into()).is_fatal());
        assert!(!Error::Busy { used: 0, limit: 0 }.is_fatal());
    }
}
