//! The `sketchd` daemon: a multi-tenant sketch-monitoring service over
//! TCP (std-only: `TcpListener` + scoped worker threads).
//!
//! One daemon owns one [`MonitorHub`] plus a [`SketchEngine`] per remote
//! session; clients multiplex through the length-prefixed binary
//! protocol in [`super::proto`].  Responsibilities:
//!
//! * **Admission**: `OpenSession` beyond `max_sessions` gets `Busy`.
//! * **Backpressure**: each session accrues its ingest payload bytes; a
//!   tenant that streams more than `session_quota_bytes` without an
//!   intervening `Diagnose` (the "consume your diagnostics" contract)
//!   gets `Busy` until it does.  `Diagnose` drains the counter.
//! * **Durability**: state snapshots to [`SnapshotStore`] on an
//!   interval, on client request (`Snapshot`) and at shutdown; a daemon
//!   restarted on the same snapshot path resumes every session warm
//!   (engine `max_state_diff == 0`, detector verdicts identical).
//! * **History**: every ingest interval is (stride-sampled) recorded
//!   into the session's [`SessionArchive`] ring; `QueryTrajectory` /
//!   `QuerySimilarity` / `QueryDrift` / `ArchiveInfo` answer analytics
//!   from it and `Stats` reports daemon/session counters.  The archive
//!   rides in the snapshot, so query answers survive a warm restart
//!   bit-exactly.
//! * **Observability**: every handled frame's latency lands in a
//!   lock-free [`ServeMetrics`] histogram (ingest/diagnose/query), with
//!   counters for Busy rejections, bytes, sessions and snapshot pauses;
//!   the v3 `Metrics` op serves the report and the lifetime pieces ride
//!   in the snapshot.
//!
//! Sessions outlive connections: a client may disconnect and a later
//! connection (or a daemon restart) continues the same session id.

use std::collections::BTreeMap;
use std::io::Read;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::archive::SessionArchive;
use crate::config::{resolve_threads, ServeConfig};
use crate::monitor::{step_metrics, HubError, MonitorHub, SessionId};
use crate::sketch::{
    Mat, Parallelism, Pool, SketchConfig, SketchEngine, Sketcher,
};
use crate::util::cli::Args;

use super::codec::Enc;
use super::metrics::ServeMetrics;
use super::proto::{
    self, monitor_config, ArchiveInfo, DaemonStats, ErrorCode, FrameHeader,
    Request, Response, SessionStats, FRAME_HEADER_LEN, METRICS_MIN_VERSION,
    PROTO_MIN_VERSION, PROTO_VERSION,
};
use super::store::{DaemonSnapshot, SessionRecord, SnapshotStore};

/// Per-session sketch-side state (the monitor side lives in the hub).
struct Tenant {
    engine: SketchEngine,
    /// Ingest payload bytes since the session's last `Diagnose`.
    quota_used: u64,
    /// Lifetime ingest payload bytes (Stats counter; persisted).
    ingest_bytes: u64,
    /// Lifetime quota-Busy rejections this session absorbed (persisted).
    busy_rejections: u64,
    /// Retained sketch history for archive queries.
    archive: SessionArchive,
}

struct State {
    hub: MonitorHub,
    tenants: BTreeMap<u64, Tenant>,
}

struct Shared {
    cfg: ServeConfig,
    /// Requested kernel fan-out width, resolved once at bind time.
    par: Parallelism,
    /// The process-lifetime worker pool: every tenant engine and the
    /// hub's cross-tenant diagnosis fan out over these same parked
    /// threads, so per-request kernel work never pays a thread spawn.
    pool: Arc<Pool>,
    store: SnapshotStore,
    state: Mutex<State>,
    shutdown: AtomicBool,
    /// State changed since the last snapshot.  Only mutated while the
    /// state lock is held, so `save_snapshot`'s capture-and-clear cannot
    /// lose a concurrent mutation's mark.
    dirty: AtomicBool,
    /// Lock-free observability counters + latency histograms, updated by
    /// every connection thread outside the state lock. Lifetime pieces
    /// ride in the snapshot; `frames_served` stays process-scoped.
    metrics: ServeMetrics,
}

fn lock(state: &Mutex<State>) -> MutexGuard<'_, State> {
    // A poisoned lock means a handler panicked; the state itself is a
    // BTreeMap of value types and stays usable.
    state.lock().unwrap_or_else(|p| p.into_inner())
}

/// Per-layer relative reconstruction errors for a just-ingested batch:
/// `||A - A~||_F / ||A||_F` against the activation the layer's incoming
/// sketch actually saw (layer 0 sketches its own output — the seed
/// convention).  Shared by the daemon and the in-process mirrors in the
/// probe/tests so both sides compute bit-for-bit identical values.
pub fn recon_errors(engine: &SketchEngine, acts: &[Mat]) -> Result<Vec<f64>> {
    (0..engine.n_layers())
        .map(|l| {
            let rec = engine.reconstruct(l)?;
            let reference = &acts[l.max(1)];
            let err = reference.sub(&rec).fro_norm();
            let denom = reference.fro_norm();
            Ok(if denom == 0.0 { err } else { err / denom })
        })
        .collect()
}

fn hub_error(e: HubError) -> Response {
    let code = match e {
        HubError::NoSuchSession(_) => ErrorCode::UnknownSession,
        HubError::DuplicateSession(_) => ErrorCode::DuplicateSession,
        HubError::SessionsExhausted => ErrorCode::SessionsExhausted,
    };
    Response::Error {
        code,
        message: e.to_string(),
    }
}

fn invalid(message: String) -> Response {
    Response::Error {
        code: ErrorCode::Invalid,
        message,
    }
}

/// Build the durable snapshot under the state lock and write it out.
/// The dirty flag is cleared at capture time *under the lock* (every
/// mutation also happens under it, so no concurrent change's mark can
/// be wiped) and re-set if the write fails, so un-persisted state is
/// always retried at the next opportunity.
fn save_snapshot(shared: &Shared) -> Result<(u64, u64)> {
    let t0 = Instant::now();
    let snap = {
        let st = lock(&shared.state);
        let mut sessions = Vec::with_capacity(st.hub.len());
        for s in st.hub.sessions() {
            let raw = s.id.raw();
            let tenant = st
                .tenants
                .get(&raw)
                .with_context(|| format!("session {raw} has no engine"))?;
            sessions.push(SessionRecord {
                session: s.state(),
                engine: tenant.engine.snapshot(),
                quota_used: tenant.quota_used,
                ingest_bytes: tenant.ingest_bytes,
                busy_rejections: tenant.busy_rejections,
                archive: tenant.archive.state(),
            });
        }
        shared.dirty.store(false, Ordering::SeqCst);
        DaemonSnapshot {
            sessions,
            metrics: shared.metrics.state(),
        }
    };
    let count = snap.sessions.len() as u64;
    match shared.store.save(&snap) {
        Ok(bytes) => {
            // Wall time of capture + write; the lock-held capture above
            // is the slice that stalls concurrent ingest.
            shared.metrics.note_snapshot(t0.elapsed());
            Ok((bytes, count))
        }
        Err(e) => {
            shared.dirty.store(true, Ordering::SeqCst);
            Err(e)
        }
    }
}

fn handle_request(
    shared: &Shared,
    req: Request,
    payload_len: usize,
) -> Response {
    match req {
        Request::Hello { client: _ } => {
            let st = lock(&shared.state);
            Response::HelloOk {
                server: concat!("sketchd/", env!("CARGO_PKG_VERSION"))
                    .to_string(),
                proto: PROTO_VERSION,
                sessions: st.hub.len() as u64,
                max_sessions: shared.cfg.max_sessions as u64,
            }
        }
        Request::OpenSession(spec) => {
            let mut st = lock(&shared.state);
            if st.hub.len() >= shared.cfg.max_sessions {
                shared.metrics.note_busy_admission();
                return Response::Busy {
                    used: st.hub.len() as u64,
                    limit: shared.cfg.max_sessions as u64,
                };
            }
            if spec.window == 0 {
                return invalid("window must be > 0".into());
            }
            let engine = match SketchConfig::builder()
                .layer_dims(&spec.layer_dims)
                .rank(spec.rank)
                .beta(spec.beta)
                .seed(spec.seed)
                .parallelism(shared.par)
                .build()
            {
                // All tenants share the daemon's process-lifetime pool.
                Ok(cfg) => {
                    SketchEngine::with_pool(cfg, Arc::clone(&shared.pool))
                }
                Err(e) => return invalid(format!("bad session spec: {e}")),
            };
            let id = match st.hub.register(
                &spec.name,
                monitor_config(&spec),
                spec.layer_dims.len(),
            ) {
                Ok(id) => id,
                Err(e) => return hub_error(e),
            };
            let unit = engine.config().precision.bytes();
            st.tenants.insert(
                id.raw(),
                Tenant {
                    engine,
                    quota_used: 0,
                    ingest_bytes: 0,
                    busy_rejections: 0,
                    archive: SessionArchive::new(
                        shared.cfg.archive.capacity,
                        shared.cfg.archive.stride,
                        unit,
                    ),
                },
            );
            shared.dirty.store(true, Ordering::SeqCst);
            shared.metrics.note_session_open(st.hub.len() as u64);
            Response::SessionOpened { session: id.raw() }
        }
        Request::Ingest {
            session,
            loss,
            want_recon,
            acts,
        } => {
            let mut st = lock(&shared.state);
            let State { hub, tenants } = &mut *st;
            let id = SessionId::from_raw(session);
            let tenant = match tenants.get_mut(&session) {
                Some(t) => t,
                None => return hub_error(HubError::NoSuchSession(id)),
            };
            let quota = shared.cfg.session_quota_bytes as u64;
            if quota > 0 && tenant.quota_used + payload_len as u64 > quota {
                tenant.busy_rejections += 1;
                shared.metrics.note_busy_quota();
                return Response::Busy {
                    used: tenant.quota_used,
                    limit: quota,
                };
            }
            if let Err(e) = tenant.engine.ingest(&acts) {
                return invalid(format!("ingest rejected: {e}"));
            }
            tenant.quota_used += payload_len as u64;
            tenant.ingest_bytes += payload_len as u64;
            shared.metrics.note_ingest_bytes(payload_len as u64);
            // Archive this interval (ring-buffered, stride-sampled) and
            // push the ring's honest byte accounting into the hub.
            if tenant.archive.maybe_record(
                tenant.engine.batches_ingested(),
                loss,
                tenant.engine.layers(),
            ) {
                let archive_bytes = tenant.archive.bytes();
                if let Err(e) = hub.report_archive_bytes(id, archive_bytes) {
                    return hub_error(e);
                }
            }
            let metrics = tenant.engine.metrics();
            if let Err(e) = hub.observe(id, &step_metrics(loss, &metrics)) {
                return hub_error(e);
            }
            let engine_bytes = tenant.engine.memory();
            if let Err(e) = hub.report_sketch_bytes(id, engine_bytes) {
                return hub_error(e);
            }
            let recon_err = if want_recon {
                match recon_errors(&tenant.engine, &acts) {
                    Ok(v) => v,
                    Err(e) => {
                        return invalid(format!("reconstruction failed: {e}"))
                    }
                }
            } else {
                Vec::new()
            };
            shared.dirty.store(true, Ordering::SeqCst);
            Response::IngestOk {
                batches: tenant.engine.batches_ingested(),
                engine_bytes: engine_bytes as u64,
                recon_err,
            }
        }
        Request::Observe { session, metrics } => {
            let mut st = lock(&shared.state);
            let id = SessionId::from_raw(session);
            if let Err(e) = st.hub.observe(id, &metrics) {
                return hub_error(e);
            }
            shared.dirty.store(true, Ordering::SeqCst);
            let steps_seen =
                st.hub.session(id).map(|s| s.steps_seen()).unwrap_or(0);
            Response::ObserveOk { steps_seen }
        }
        Request::Diagnose { session } => {
            let mut st = lock(&shared.state);
            let id = SessionId::from_raw(session);
            let (diagnosis, steps_seen, monitor_bytes) =
                match st.hub.session(id) {
                    Ok(s) => (s.diagnose(), s.steps_seen(), s.monitor_bytes()),
                    Err(e) => return hub_error(e),
                };
            let engine_bytes = match st.tenants.get_mut(&session) {
                Some(t) => {
                    // Diagnose is the tenant's check-in: drain the
                    // backpressure counter.
                    t.quota_used = 0;
                    t.engine.memory()
                }
                None => 0,
            };
            let healthy = diagnosis.healthy();
            Response::Diagnosis {
                diagnosis,
                healthy,
                steps_seen,
                engine_bytes: engine_bytes as u64,
                monitor_bytes: monitor_bytes as u64,
            }
        }
        Request::Snapshot => match save_snapshot(shared) {
            Ok((bytes, sessions)) => Response::SnapshotOk {
                path: shared.cfg.snapshot_path.clone(),
                bytes,
                sessions,
            },
            Err(e) => Response::Error {
                code: ErrorCode::Internal,
                message: format!("snapshot failed: {e:#}"),
            },
        },
        Request::Close { session } => {
            let mut st = lock(&shared.state);
            let id = SessionId::from_raw(session);
            if let Err(e) = st.hub.deregister(id) {
                return hub_error(e);
            }
            st.tenants.remove(&session);
            shared.dirty.store(true, Ordering::SeqCst);
            Response::Closed { session }
        }
        Request::Shutdown => {
            let sessions = match save_snapshot(shared) {
                Ok((_, n)) => n,
                Err(e) => {
                    return Response::Error {
                        code: ErrorCode::Internal,
                        message: format!("shutdown snapshot failed: {e:#}"),
                    }
                }
            };
            shared.shutdown.store(true, Ordering::SeqCst);
            Response::ShutdownOk { sessions }
        }
        Request::Stats => {
            let st = lock(&shared.state);
            let mut daemon = DaemonStats {
                sessions: st.hub.len() as u64,
                max_sessions: shared.cfg.max_sessions as u64,
                frames_served: shared.metrics.frames_served(),
                busy_rejections: shared.metrics.busy_total(),
                ..DaemonStats::default()
            };
            let quota_limit = shared.cfg.session_quota_bytes as u64;
            let mut sessions = Vec::with_capacity(st.hub.len());
            for s in st.hub.sessions() {
                let raw = s.id.raw();
                let (ingest, ar_bytes, ar_n, busy, quota_used) =
                    match st.tenants.get(&raw) {
                        Some(t) => (
                            t.ingest_bytes,
                            t.archive.bytes() as u64,
                            t.archive.len() as u64,
                            t.busy_rejections,
                            t.quota_used,
                        ),
                        None => (0, 0, 0, 0, 0),
                    };
                daemon.ingest_bytes += ingest;
                daemon.archive_bytes += ar_bytes;
                sessions.push(SessionStats {
                    id: raw,
                    name: s.name.clone(),
                    steps_seen: s.steps_seen(),
                    ingest_bytes: ingest,
                    archive_bytes: ar_bytes,
                    archive_intervals: ar_n,
                    busy_rejections: busy,
                    quota_used,
                    quota_limit,
                });
            }
            Response::StatsOk { daemon, sessions }
        }
        Request::Metrics => {
            let open = lock(&shared.state).hub.len() as u64;
            Response::MetricsOk(shared.metrics.report(open))
        }
        Request::QueryTrajectory { session } => {
            let st = lock(&shared.state);
            match st.tenants.get(&session) {
                Some(t) => Response::Trajectory {
                    points: t.archive.trajectory(),
                },
                None => hub_error(HubError::NoSuchSession(
                    SessionId::from_raw(session),
                )),
            }
        }
        Request::QuerySimilarity { session, layer } => {
            let st = lock(&shared.state);
            let tenant = match st.tenants.get(&session) {
                Some(t) => t,
                None => {
                    return hub_error(HubError::NoSuchSession(
                        SessionId::from_raw(session),
                    ))
                }
            };
            if layer >= tenant.engine.n_layers() {
                return invalid(format!(
                    "layer {layer} out of range (session has {} layers)",
                    tenant.engine.n_layers()
                ));
            }
            let (steps, sim) = tenant.archive.similarity(layer);
            Response::Similarity { steps, sim }
        }
        Request::QueryDrift { session, layer } => {
            let st = lock(&shared.state);
            let tenant = match st.tenants.get(&session) {
                Some(t) => t,
                None => {
                    return hub_error(HubError::NoSuchSession(
                        SessionId::from_raw(session),
                    ))
                }
            };
            if layer >= tenant.engine.n_layers() {
                return invalid(format!(
                    "layer {layer} out of range (session has {} layers)",
                    tenant.engine.n_layers()
                ));
            }
            Response::Drift {
                points: tenant.archive.drift(layer),
            }
        }
        Request::ArchiveInfo { session } => {
            let st = lock(&shared.state);
            match st.tenants.get(&session) {
                Some(t) => Response::ArchiveInfoOk(ArchiveInfo {
                    capacity: t.archive.capacity() as u64,
                    stride: t.archive.stride() as u64,
                    intervals: t.archive.len() as u64,
                    seen: t.archive.intervals_seen(),
                    bytes: t.archive.bytes() as u64,
                    layers: t.engine.n_layers() as u64,
                    oldest_step: t.archive.get(0).map_or(0, |r| r.step),
                    newest_step: t
                        .archive
                        .get(t.archive.len().wrapping_sub(1))
                        .map_or(0, |r| r.step),
                }),
                None => hub_error(HubError::NoSuchSession(
                    SessionId::from_raw(session),
                )),
            }
        }
    }
}

/// Read one frame into the connection's reusable `payload` buffer,
/// tolerating idle read timeouts: a timeout before any header byte just
/// polls the shutdown flag; a timeout mid-frame keeps reading (the
/// client is mid-send).  `Ok(None)` = clean EOF/shutdown.
fn read_frame_idle(
    stream: &mut TcpStream,
    shutdown: &AtomicBool,
    payload: &mut Vec<u8>,
) -> Result<Option<FrameHeader>> {
    let mut hdr = [0u8; FRAME_HEADER_LEN];
    let mut got = 0usize;
    while got < hdr.len() {
        if shutdown.load(Ordering::SeqCst) {
            return Ok(None);
        }
        match stream.read(&mut hdr[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(None);
                }
                anyhow::bail!("connection closed mid-header");
            }
            Ok(n) => got += n,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) => {}
            Err(e) => return Err(e.into()),
        }
    }
    let header = FrameHeader::parse(&hdr)?;
    payload.clear();
    payload.resize(header.len as usize, 0);
    let mut got = 0usize;
    while got < payload.len() {
        if shutdown.load(Ordering::SeqCst) {
            return Ok(None);
        }
        match stream.read(&mut payload[got..]) {
            Ok(0) => anyhow::bail!("connection closed mid-payload"),
            Ok(n) => got += n,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) => {}
            Err(e) => return Err(e.into()),
        }
    }
    Ok(Some(header))
}

fn handle_conn(mut stream: TcpStream, shared: &Shared) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(100)));
    // Per-connection reusable buffers: request payloads land in
    // `payload`, responses are encoded into `enc` and framed through
    // `frame`, so a long-lived client's steady-state traffic allocates
    // no fresh buffers per frame.
    let mut payload = Vec::new();
    let mut enc = Enc::new();
    let mut frame = Vec::new();
    loop {
        let header = match read_frame_idle(
            &mut stream,
            &shared.shutdown,
            &mut payload,
        ) {
            Ok(Some(h)) => h,
            Ok(None) | Err(_) => return,
        };
        let version_ok = (PROTO_MIN_VERSION..=PROTO_VERSION)
            .contains(&header.version);
        let resp = if !version_ok {
            Response::Error {
                code: ErrorCode::UnsupportedVersion,
                message: format!(
                    "server speaks proto v{PROTO_MIN_VERSION}..v{PROTO_VERSION}, \
                     frame is v{}",
                    header.version
                ),
            }
        } else if header.msg == proto::msg::METRICS
            && header.version < METRICS_MIN_VERSION
        {
            Response::Error {
                code: ErrorCode::UnsupportedVersion,
                message: format!(
                    "Metrics requires proto v{METRICS_MIN_VERSION}, \
                     frame is v{}",
                    header.version
                ),
            }
        } else {
            match Request::decode(header.msg, &payload) {
                Ok(req) => {
                    let t0 = Instant::now();
                    let resp = handle_request(shared, req, payload.len());
                    shared.metrics.observe_request(header.msg, t0.elapsed());
                    resp
                }
                Err(e) => Response::Error {
                    code: ErrorCode::BadFrame,
                    message: e.to_string(),
                },
            }
        };
        let fatal = matches!(
            resp,
            Response::Error {
                code: ErrorCode::UnsupportedVersion | ErrorCode::BadFrame,
                ..
            }
        );
        // Echo the request's version on the reply (clamped into range for
        // rejections of out-of-range frames) so version-gated response
        // fields match what the peer can decode.
        let reply_version =
            header.version.clamp(PROTO_MIN_VERSION, PROTO_VERSION);
        enc.reset();
        resp.encode_into_v(&mut enc, reply_version);
        if proto::write_frame_versioned_reusing(
            &mut stream,
            reply_version,
            resp.msg_type(),
            enc.bytes(),
            &mut frame,
        )
        .is_err()
        {
            return;
        }
        shared.metrics.note_frame_served();
        if fatal {
            return;
        }
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
    }
}

/// A bound (but not yet running) daemon.  Binding and running are split
/// so in-process embedders (tests, benches) can learn the ephemeral port
/// before serving starts.
pub struct Daemon {
    listener: TcpListener,
    shared: Arc<Shared>,
}

impl Daemon {
    /// Bind the listen socket and, if a snapshot exists at
    /// `cfg.snapshot_path`, restore every session from it.
    pub fn bind(cfg: ServeConfig) -> Result<Daemon> {
        cfg.validate()?;
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding {}", cfg.addr))?;
        listener.set_nonblocking(true)?;
        let store = SnapshotStore::new(cfg.snapshot_path.clone());
        let par = Parallelism::from_threads(resolve_threads(cfg.threads));
        let pool = Pool::new(par);
        let mut state = State {
            hub: MonitorHub::with_pool(Arc::clone(&pool)),
            tenants: BTreeMap::new(),
        };
        let metrics = ServeMetrics::new();
        if let Some(snap) = store
            .load()
            .with_context(|| format!("loading snapshot {}", cfg.snapshot_path))?
        {
            // Lifetime observability counters resume where the snapshot
            // left them (uptime + frames_served stay process-scoped).
            metrics.restore(&snap.metrics);
            for rec in &snap.sessions {
                let id = state.hub.restore_session(&rec.session)?;
                let archive = SessionArchive::from_state(&rec.archive);
                // The hub does not persist archive accounting; re-derive
                // it from the restored ring.
                state.hub.report_archive_bytes(id, archive.bytes())?;
                state.tenants.insert(
                    rec.session.id,
                    Tenant {
                        engine: SketchEngine::from_snapshot_with_pool(
                            &rec.engine,
                            Arc::clone(&pool),
                        )?,
                        quota_used: rec.quota_used,
                        ingest_bytes: rec.ingest_bytes,
                        busy_rejections: rec.busy_rejections,
                        archive,
                    },
                );
            }
        }
        Ok(Daemon {
            listener,
            shared: Arc::new(Shared {
                cfg,
                par,
                pool,
                store,
                state: Mutex::new(state),
                shutdown: AtomicBool::new(false),
                dirty: AtomicBool::new(false),
                metrics,
            }),
        })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Sessions currently held (restored + live).
    pub fn session_count(&self) -> usize {
        lock(&self.shared.state).hub.len()
    }

    /// Serve until the shutdown flag is set (by a `Shutdown` frame or a
    /// [`DaemonHandle`]), then write a final snapshot if state changed.
    pub fn run(self) -> Result<()> {
        let shared: &Shared = &self.shared;
        let mut last_snapshot = Instant::now();
        thread::scope(|s| {
            loop {
                if shared.shutdown.load(Ordering::SeqCst) {
                    break;
                }
                let interval = shared.cfg.snapshot_interval_secs;
                if interval > 0
                    && last_snapshot.elapsed().as_secs() >= interval
                {
                    if shared.dirty.load(Ordering::SeqCst) {
                        if let Err(e) = save_snapshot(shared) {
                            eprintln!("sketchd: periodic snapshot failed: {e:#}");
                        }
                    }
                    last_snapshot = Instant::now();
                }
                match self.listener.accept() {
                    Ok((stream, _peer)) => {
                        s.spawn(move || handle_conn(stream, shared));
                    }
                    Err(e)
                        if e.kind() == std::io::ErrorKind::WouldBlock =>
                    {
                        thread::sleep(Duration::from_millis(10));
                    }
                    Err(e) => {
                        eprintln!("sketchd: accept failed: {e}");
                        thread::sleep(Duration::from_millis(50));
                    }
                }
            }
        });
        if shared.dirty.load(Ordering::SeqCst) {
            save_snapshot(shared)?;
        }
        Ok(())
    }

    /// Run on a background thread; the returned handle stops the daemon
    /// (with a final snapshot) on [`DaemonHandle::stop`].  Used by the
    /// loopback tests and benches.
    pub fn spawn(self) -> Result<DaemonHandle> {
        let addr = self.local_addr()?;
        let shared = Arc::clone(&self.shared);
        let join = thread::spawn(move || self.run());
        Ok(DaemonHandle { addr, shared, join })
    }
}

/// Handle to an in-process daemon spawned with [`Daemon::spawn`].
pub struct DaemonHandle {
    addr: SocketAddr,
    shared: Arc<Shared>,
    join: thread::JoinHandle<Result<()>>,
}

impl DaemonHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Request shutdown and wait for the final snapshot to land.
    pub fn stop(self) -> Result<()> {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        match self.join.join() {
            Ok(r) => r,
            Err(_) => anyhow::bail!("daemon thread panicked"),
        }
    }
}

/// `sketchd`/`sketchgrad serve` entry point: `[serve]` TOML config with
/// CLI overrides, then serve until shutdown.
pub fn serve_from_args(args: &mut Args) -> Result<()> {
    let mut cfg = if let Some(path) = args.opt("config") {
        ServeConfig::from_toml_file(std::path::Path::new(&path))?
    } else {
        ServeConfig::default()
    };
    cfg.addr = args.opt_or("addr", &cfg.addr);
    cfg.max_sessions = args.opt_usize("max-sessions", cfg.max_sessions)?;
    cfg.snapshot_interval_secs =
        args.opt_u64("snapshot-interval", cfg.snapshot_interval_secs)?;
    cfg.session_quota_bytes =
        args.opt_usize("quota", cfg.session_quota_bytes)?;
    cfg.snapshot_path = args.opt_or("snapshot-path", &cfg.snapshot_path);
    cfg.threads = resolve_threads(args.opt_usize("threads", cfg.threads)?);
    cfg.archive.capacity =
        args.opt_usize("archive-capacity", cfg.archive.capacity)?;
    cfg.archive.stride =
        args.opt_usize("archive-stride", cfg.archive.stride)?;
    args.finish()?;

    let daemon = Daemon::bind(cfg)?;
    println!(
        "sketchd listening on {} ({} resumed sessions, snapshots -> {})",
        daemon.local_addr()?,
        daemon.session_count(),
        daemon.shared.cfg.snapshot_path,
    );
    daemon.run()
}
