//! The `sketchd` daemon: a multi-tenant sketch-monitoring service over
//! TCP, served by a sharded nonblocking event loop (DESIGN.md §9).
//!
//! One acceptor thread hands connections round-robin to N *shards*.
//! Each shard is a thread running a readiness loop ([`super::poll`]:
//! epoll on Linux, a portable hint-based fallback elsewhere) over its
//! slice of connections, and owns a slice of the sessions: session id
//! `s` lives on shard `s % N`, with per-shard strided id allocators
//! (shard `k` mints `k, k+N, k+2N, ...`) so a session opened over a
//! connection is owned by that connection's shard.  A request naming a
//! session on another shard locks that shard's state — one lock at a
//! time, never nested, so cross-shard requests are slower but can
//! never deadlock.  Each shard also owns its own kernel [`Pool`] and
//! its own [`ServeMetrics`]; daemon-wide views (`Stats`, `Metrics`,
//! snapshots) aggregate across shards ([`MetricsState::merge`] is
//! exact, so the loadgen frame/byte cross-checks still balance).
//!
//! Responsibilities (unchanged from the single-threaded daemon):
//!
//! * **Admission**: `OpenSession` beyond `max_sessions` gets `Busy`
//!   (one global atomic admission counter across shards).
//! * **Backpressure**: each session accrues its ingest payload bytes; a
//!   tenant that streams more than `session_quota_bytes` without an
//!   intervening `Diagnose` (the "consume your diagnostics" contract)
//!   gets `Busy` until it does.  `Diagnose` drains the counter.
//! * **Durability**: state snapshots to [`SnapshotStore`] on an
//!   interval, on client request (`Snapshot`) and at shutdown; the
//!   snapshot format is unchanged (sessions sorted by id, one merged
//!   metrics record), so pre-shard snapshots restore cleanly — ids
//!   re-route to `id % N` and the merged metrics land on shard 0.
//! * **History**: every ingest interval is (stride-sampled) recorded
//!   into the session's [`SessionArchive`] ring; `QueryTrajectory` /
//!   `QuerySimilarity` / `QueryDrift` / `ArchiveInfo` answer analytics
//!   from it and `Stats` reports daemon/session/shard counters.
//! * **Observability**: every handled frame's latency lands in the
//!   owning shard's lock-free [`ServeMetrics`] histograms; the v3
//!   `Metrics` op serves the merged report, and the v4 `Stats` op adds
//!   per-shard rows so skew across shards is visible.
//! * **Supervision** (DESIGN.md §11): request dispatch runs inside a
//!   `catch_unwind` boundary — a panicking handler answers that one
//!   request with a typed `Internal` error, bumps `handler_panics`,
//!   journals a `handler-panic` event and the shard keeps serving.
//!   The shared [`FaultRegistry`] threads deterministic failpoints
//!   through the socket, snapshot and handler paths, and the v6
//!   ingest-seq protocol lets a client resume a session exactly
//!   across a daemon crash (replays deduped against the persisted
//!   `acked_seq`).
//!
//! Sessions outlive connections: a client may disconnect and a later
//! connection (or a daemon restart) continues the same session id.

use std::collections::BTreeMap;
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, MutexGuard};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::archive::SessionArchive;
use crate::config::{resolve_threads, ServeConfig};
use crate::monitor::{step_metrics, HubError, MonitorHub, SessionId};
use crate::sketch::{
    Mat, Parallelism, Pool, SketchConfig, SketchEngine, Sketcher,
};
use crate::util::cli::Args;

use super::codec::Enc;
use super::error::Error;
use super::fault::{self, FaultRegistry};
use super::metrics::{MetricsState, ServeMetrics};
use super::obs::events::log_tag;
use super::obs::{
    expo, layer_health, EventKind, JournalWriter, Level, Obs, Sample,
    SessionHealth,
};
use super::poll::{Event, Interest, Poller};
use super::proto::{
    self, monitor_config, ArchiveInfo, DaemonStats, FrameHeader, Request,
    Response, SessionStats, ShardStats, FRAME_HEADER_LEN,
    METRICS_MIN_VERSION, OBS_MIN_VERSION, PROTO_MIN_VERSION, PROTO_VERSION,
};
use super::store::{DaemonSnapshot, SessionRecord, SnapshotStore};

/// Per-session sketch-side state (the monitor side lives in the shard's
/// hub).
struct Tenant {
    engine: SketchEngine,
    /// Sketch rank last journaled for this session; an ingest that
    /// observes a different engine rank emits a `rank-change` event.
    rank: u32,
    /// Ingest payload bytes since the session's last `Diagnose`.
    quota_used: u64,
    /// Lifetime ingest payload bytes (Stats counter; persisted).
    ingest_bytes: u64,
    /// Lifetime quota-Busy rejections this session absorbed (persisted).
    busy_rejections: u64,
    /// Resume epoch: 1 at open, bumped each time the daemon restores
    /// the session from snapshot (persisted; DESIGN.md §11).
    epoch: u64,
    /// Highest applied client ingest seq (persisted *with* the engine
    /// state, so both restore from the same snapshot and a resuming
    /// client replays exactly the unacked suffix).  0 while the client
    /// opts out of numbering.
    acked_seq: u64,
    /// Retained sketch history for archive queries.
    archive: SessionArchive,
}

/// One shard's slice of the session space.
struct State {
    hub: MonitorHub,
    tenants: BTreeMap<u64, Tenant>,
}

/// One connection shard: a slice of sessions behind its own lock, its
/// own kernel pool and its own metrics.  Session `s` is owned by shard
/// `s % shards.len()`.
struct Shard {
    state: Mutex<State>,
    /// This shard's persistent worker pool: its tenant engines and its
    /// hub's cross-tenant diagnosis fan out over these parked threads.
    pool: Arc<Pool>,
    /// Lock-free counters + latency histograms for work owned by this
    /// shard.  The daemon-wide view is the exact merge across shards.
    metrics: ServeMetrics,
    /// Strided session-id allocator: shard `k` of `N` mints ids
    /// `k, k+N, k+2N, ...`, so freshly opened sessions are owned by
    /// the shard of the connection that opened them.
    next_id: AtomicU64,
}

struct Shared {
    cfg: ServeConfig,
    /// Requested kernel fan-out width, resolved once at bind time.
    par: Parallelism,
    shards: Vec<Shard>,
    store: SnapshotStore,
    shutdown: AtomicBool,
    /// State changed since the last snapshot.  Set under a shard lock
    /// by every mutation; cleared by `save_snapshot` *before* capture,
    /// so a mutation racing the capture either lands in the snapshot or
    /// re-marks the flag for the next one.
    dirty: AtomicBool,
    /// Global admission counter (sessions open across all shards).
    sessions_open: AtomicU64,
    /// Process start, for the merged report's `uptime_ms`.
    started: Instant,
    /// Observability layer: event journal (writer 0 = control plane,
    /// `1 + k` = shard `k`), window ring, log filter (DESIGN.md §10).
    obs: Obs,
    /// Armed failpoints shared by the shard loops, the snapshot store
    /// and request dispatch (DESIGN.md §11).  Empty in production:
    /// every site check is one relaxed atomic load.
    faults: Arc<FaultRegistry>,
    /// Set by [`DaemonHandle::kill`]: skip the final shutdown snapshot
    /// so the stop is indistinguishable from a crash (the chaos
    /// harness relies on this).
    skip_final_snapshot: AtomicBool,
}

impl Shared {
    fn n_shards(&self) -> u64 {
        self.shards.len() as u64
    }

    /// The shard owning `session` (`session % N`).
    fn owner(&self, session: u64) -> &Shard {
        &self.shards[(session % self.n_shards()) as usize]
    }
}

fn lock(state: &Mutex<State>) -> MutexGuard<'_, State> {
    // A poisoned lock means a handler panicked; the state itself is a
    // BTreeMap of value types and stays usable.
    state.lock().unwrap_or_else(|p| p.into_inner())
}

/// Exact cross-shard merge of the lifetime counters plus the summed
/// (process-scoped) reply count — the one capture every daemon-wide
/// view (`Metrics`, window ticks, the exposition endpoint) is built
/// from, so they all agree by construction.
fn merge_shard_metrics(shards: &[Shard]) -> (MetricsState, u64) {
    let mut state = MetricsState::default();
    let mut frames_served = 0u64;
    for shard in shards {
        state.merge(&shard.metrics.state());
        frames_served += shard.metrics.frames_served();
    }
    (state, frames_served)
}

fn merged_sample(shared: &Shared) -> Sample {
    let (state, frames_served) = merge_shard_metrics(&shared.shards);
    Sample::from_state(&state, frames_served)
}

/// Per-session sketch-health gauges, one shard lock at a time, sorted
/// by session id.  The gauges are recomputed from the resident Z
/// sketches on demand — health is polled (scrapes, v5 ops), not paid
/// for on the ingest path.
fn collect_health(shared: &Shared) -> Vec<SessionHealth> {
    let mut out = Vec::new();
    for shard in &shared.shards {
        let st = lock(&shard.state);
        for s in st.hub.sessions() {
            let raw = s.id.raw();
            let Some(tenant) = st.tenants.get(&raw) else {
                continue;
            };
            out.push(SessionHealth {
                session: raw,
                name: s.name.clone(),
                layers: tenant
                    .engine
                    .layers()
                    .iter()
                    .map(|t| layer_health(&t.z))
                    .collect(),
            });
        }
    }
    out.sort_by_key(|h| h.session);
    out
}

/// Per-shard counter rows (the v4 `Stats` rows, also scraped via
/// `/metrics`).
fn shard_rows(shared: &Shared) -> Vec<ShardStats> {
    let mut rows = Vec::with_capacity(shared.shards.len());
    for (i, shard) in shared.shards.iter().enumerate() {
        let sessions = lock(&shard.state).hub.len() as u64;
        let ms = shard.metrics.state();
        rows.push(ShardStats {
            shard: i as u64,
            sessions,
            ingest_frames: ms.ingest.count,
            ingest_bytes: ms.ingest_bytes,
            ingest_p50_ns: ms.ingest.quantile(0.5) as u64,
            ingest_p99_ns: ms.ingest.quantile(0.99) as u64,
            frames_served: shard.metrics.frames_served(),
        });
    }
    rows
}

/// Assemble everything `GET /metrics` renders.  Same underlying
/// captures as the protocol ops, so scraper and client cross-check to
/// exact equality.
fn expo_snapshot(shared: &Shared) -> expo::ExpoSnapshot {
    let (state, frames_served) = merge_shard_metrics(&shared.shards);
    let current = Sample::from_state(&state, frames_served);
    let now_ms = shared.started.elapsed().as_millis() as u64;
    let windows = shared.obs.windows.report(now_ms, &current);
    let report = state.into_report(
        now_ms,
        shared.sessions_open.load(Ordering::SeqCst),
        frames_served,
    );
    expo::ExpoSnapshot {
        report,
        shards: shard_rows(shared),
        windows,
        health: collect_health(shared),
        journal_total: shared.obs.journal.total(),
        journal_dropped: shared.obs.journal.dropped(),
    }
}

/// Per-layer relative reconstruction errors for a just-ingested batch:
/// `||A - A~||_F / ||A||_F` against the activation the layer's incoming
/// sketch actually saw (layer 0 sketches its own output — the seed
/// convention).  Shared by the daemon and the in-process mirrors in the
/// probe/tests so both sides compute bit-for-bit identical values.
pub fn recon_errors(engine: &SketchEngine, acts: &[Mat]) -> Result<Vec<f64>> {
    (0..engine.n_layers())
        .map(|l| {
            let rec = engine.reconstruct(l)?;
            let reference = &acts[l.max(1)];
            let err = reference.sub(&rec).fro_norm();
            let denom = reference.fro_norm();
            Ok(if denom == 0.0 { err } else { err / denom })
        })
        .collect()
}

/// Build the durable snapshot (shard by shard, one lock at a time) and
/// write it out.  The dirty flag is cleared *before* capture: a
/// mutation concurrent with the capture either happens-before its
/// shard's lock (and is captured) or re-sets the flag afterwards (and
/// is retried at the next opportunity).  The flag is re-set if the
/// write fails.  Sessions are sorted by id and the per-shard metrics
/// are merged into one record, so the snapshot format is byte-wise
/// indistinguishable from the pre-shard daemon's.
fn save_snapshot(
    shared: &Shared,
    journal: &JournalWriter<'_>,
) -> Result<(u64, u64)> {
    let t0 = Instant::now();
    shared.dirty.store(false, Ordering::SeqCst);
    let mut sessions = Vec::new();
    let mut metrics = MetricsState::default();
    for shard in &shared.shards {
        let st = lock(&shard.state);
        for s in st.hub.sessions() {
            let raw = s.id.raw();
            let tenant = st
                .tenants
                .get(&raw)
                .with_context(|| format!("session {raw} has no engine"))?;
            sessions.push(SessionRecord {
                session: s.state(),
                engine: tenant.engine.snapshot(),
                quota_used: tenant.quota_used,
                ingest_bytes: tenant.ingest_bytes,
                busy_rejections: tenant.busy_rejections,
                epoch: tenant.epoch,
                acked_seq: tenant.acked_seq,
                archive: tenant.archive.state(),
            });
        }
        drop(st);
        metrics.merge(&shard.metrics.state());
    }
    sessions.sort_by_key(|r| r.session.id);
    let snap = DaemonSnapshot { sessions, metrics };
    let count = snap.sessions.len() as u64;
    match shared.store.save(&snap) {
        Ok(bytes) => {
            // Wall time of capture + write; the per-shard lock-held
            // captures are the slices that stall concurrent ingest.
            // Snapshot accounting lives on shard 0 (where a restored
            // merged record also lands).
            let pause = t0.elapsed();
            shared.shards[0].metrics.note_snapshot(pause);
            journal.emit(EventKind::Snapshot {
                sessions: count,
                pause_ns: pause.as_nanos().min(u64::MAX as u128) as u64,
            });
            Ok((bytes, count))
        }
        Err(e) => {
            shared.dirty.store(true, Ordering::SeqCst);
            // Every failure path — periodic, client-requested,
            // shutdown — counts on shard 0 (same slot as snapshot
            // accounting) and lands one journaled error.
            shared.shards[0].metrics.note_snapshot_failure();
            shared.obs.log(
                journal,
                Level::Error,
                log_tag::SNAPSHOT_FAILED,
                0,
                || format!("snapshot save failed: {e:#}"),
            );
            Err(e)
        }
    }
}

/// Handle one decoded request.  `home` is the shard of the connection
/// the request arrived on: global ops (`OpenSession` admission Busy,
/// `Hello`) account there, session-scoped ops account on — and lock —
/// the owning shard.  At most one shard lock is held at any point.
fn handle_request(
    shared: &Shared,
    home: usize,
    req: Request,
    payload_len: usize,
) -> Result<Response, Error> {
    // This thread's journal writer: handle_request always runs on the
    // connection's home shard thread (cross-shard requests lock the
    // owner's state but execute here), so `home`'s slot keeps its
    // single-writer guarantee.
    let journal = shared.obs.shard(home);
    match req {
        Request::Hello { client: _ } => Ok(Response::HelloOk {
            server: concat!("sketchd/", env!("CARGO_PKG_VERSION"))
                .to_string(),
            proto: PROTO_VERSION,
            sessions: shared.sessions_open.load(Ordering::SeqCst),
            max_sessions: shared.cfg.max_sessions as u64,
        }),
        Request::OpenSession(spec) => {
            let limit = shared.cfg.max_sessions as u64;
            // Optimistic global admission: claim a slot, undo on any
            // failure below.  `prev` is the pre-claim open count.
            let prev =
                shared.sessions_open.fetch_add(1, Ordering::SeqCst);
            if prev >= limit {
                shared.sessions_open.fetch_sub(1, Ordering::SeqCst);
                shared.shards[home].metrics.note_busy_admission();
                journal.emit(EventKind::BusyAdmission { used: prev, limit });
                return Err(Error::Busy { used: prev, limit });
            }
            let undo_admission = || {
                shared.sessions_open.fetch_sub(1, Ordering::SeqCst);
            };
            if spec.window == 0 {
                undo_admission();
                return Err(Error::Invalid("window must be > 0".into()));
            }
            let shard = &shared.shards[home];
            let engine = match SketchConfig::builder()
                .layer_dims(&spec.layer_dims)
                .rank(spec.rank)
                .beta(spec.beta)
                .seed(spec.seed)
                .parallelism(shared.par)
                .build()
            {
                // All of a shard's tenants share that shard's pool.
                Ok(cfg) => {
                    SketchEngine::with_pool(cfg, Arc::clone(&shard.pool))
                }
                Err(e) => {
                    undo_admission();
                    return Err(Error::Invalid(format!(
                        "bad session spec: {e}"
                    )));
                }
            };
            // Strided mint: the id is congruent to `home` mod N, so the
            // opening connection's shard owns the session.
            let raw = shard
                .next_id
                .fetch_add(shared.n_shards(), Ordering::SeqCst);
            let mut st = lock(&shard.state);
            let id = match st.hub.register_with_id(
                raw,
                &spec.name,
                monitor_config(&spec),
                spec.layer_dims.len(),
            ) {
                Ok(id) => id,
                Err(e) => {
                    drop(st);
                    undo_admission();
                    return Err(e.into());
                }
            };
            let unit = engine.config().precision.bytes();
            st.tenants.insert(
                raw,
                Tenant {
                    engine,
                    rank: spec.rank as u32,
                    quota_used: 0,
                    ingest_bytes: 0,
                    busy_rejections: 0,
                    epoch: 1,
                    acked_seq: 0,
                    archive: SessionArchive::new(
                        shared.cfg.archive.capacity,
                        shared.cfg.archive.stride,
                        unit,
                    ),
                },
            );
            shared.dirty.store(true, Ordering::SeqCst);
            // Record the *global* open count, so the merged peak (a max
            // across shards) is the true daemon-wide peak.
            shard.metrics.note_session_open(prev + 1);
            journal.emit(EventKind::SessionOpen { session: id.raw() });
            Ok(Response::SessionOpened {
                session: id.raw(),
                epoch: 1,
            })
        }
        Request::Ingest {
            session,
            seq,
            loss,
            want_recon,
            acts,
        } => {
            let shard = shared.owner(session);
            let mut st = lock(&shard.state);
            let State { hub, tenants } = &mut *st;
            let id = SessionId::from_raw(session);
            let tenant = tenants
                .get_mut(&session)
                .ok_or(HubError::NoSuchSession(id))?;
            // Crash-safe resumption (seq > 0 only; pre-v6 peers and
            // opted-out clients send 0).  A replay of an already-acked
            // seq — a client resending its unacked window after a
            // reconnect — is re-acked with *no* engine, quota or
            // archive side effects, so a kill→restart mid-run never
            // double-ingests.  The replayed ack is a fresh reply, not
            // a recording of the original: `recon_err` is empty even
            // if the replayed frame asked for reconstruction, and
            // `batches`/`engine_bytes` reflect the session's *current*
            // state.  A gap past acked+1 means frames were lost (e.g.
            // the client's replay ring overflowed); reject loudly
            // rather than silently corrupt the sketch.
            if seq > 0 {
                if seq <= tenant.acked_seq {
                    return Ok(Response::IngestOk {
                        batches: tenant.engine.batches_ingested(),
                        engine_bytes: tenant.engine.memory() as u64,
                        recon_err: Vec::new(),
                        acked_seq: tenant.acked_seq,
                    });
                }
                if seq != tenant.acked_seq + 1 {
                    return Err(Error::Invalid(format!(
                        "ingest seq gap: got {seq}, expected {} — \
                         frames were lost beyond the replay window",
                        tenant.acked_seq + 1
                    )));
                }
            }
            let quota = shared.cfg.session_quota_bytes as u64;
            if quota > 0 && tenant.quota_used + payload_len as u64 > quota {
                tenant.busy_rejections += 1;
                shard.metrics.note_busy_quota();
                journal.emit(EventKind::BusyQuota {
                    session,
                    used: tenant.quota_used,
                });
                return Err(Error::Busy {
                    used: tenant.quota_used,
                    limit: quota,
                });
            }
            // The engine ingest is the LAST fallible step before the
            // ack commits: it validates every activation shape before
            // touching any sketch, so an error reply to `Ingest` always
            // means "nothing was applied, acked_seq did not move".
            // That contract is what lets a resumable client roll a
            // rejected seq back and reuse it on retry — Busy
            // backpressure included — instead of wedging on a seq gap.
            tenant.engine.ingest(&acts).map_err(|e| {
                Error::Invalid(format!("ingest rejected: {e}"))
            })?;
            // Commit: the ack becomes visible together with the engine
            // step it acknowledges, before anything that could still
            // fail.  (A panic *inside* the engine ingest above is the
            // one residual at-least-once window: partial sketch
            // updates with no ack, so a client replay re-applies on
            // top — see DESIGN.md §11.)
            if seq > 0 {
                tenant.acked_seq = seq;
            }
            shared.dirty.store(true, Ordering::SeqCst);
            // Post-commit tail: accounting, archive, monitor and recon
            // run best-effort — the frame is applied and acked, so a
            // failure here must NOT become an error reply (a resumable
            // client would roll the seq back and the dedup would then
            // swallow its next, different frame).  Hub inconsistencies
            // and recon failures degrade to a journaled error; a panic
            // is caught, counted and journaled like any handler panic;
            // the reply stays the honest IngestOk either way.
            let tail = catch_unwind(AssertUnwindSafe(|| {
                // Journal a rank transition if the engine's rank moved
                // (future adaptive-rank resizing; static engines never
                // trigger this).
                let engine_rank = tenant.engine.config().rank as u32;
                if engine_rank != tenant.rank {
                    journal.emit(EventKind::RankChange {
                        session,
                        from: tenant.rank,
                        to: engine_rank,
                    });
                    tenant.rank = engine_rank;
                }
                tenant.quota_used += payload_len as u64;
                tenant.ingest_bytes += payload_len as u64;
                shard.metrics.note_ingest_bytes(payload_len as u64);
                // Archive this interval (ring-buffered, stride-sampled)
                // and push the ring's honest byte accounting into the
                // hub.
                if tenant.archive.maybe_record(
                    tenant.engine.batches_ingested(),
                    loss,
                    tenant.engine.layers(),
                ) {
                    let archive_bytes = tenant.archive.bytes();
                    if let Err(e) = hub.report_archive_bytes(id, archive_bytes)
                    {
                        shared.obs.log(
                            journal,
                            Level::Error,
                            log_tag::INGEST_DEGRADED,
                            session,
                            || format!("archive-bytes report failed: {e}"),
                        );
                    }
                }
                let metrics = tenant.engine.metrics();
                if let Err(e) = hub.observe(id, &step_metrics(loss, &metrics))
                {
                    shared.obs.log(
                        journal,
                        Level::Error,
                        log_tag::INGEST_DEGRADED,
                        session,
                        || format!("monitor observe failed: {e}"),
                    );
                }
                if let Err(e) =
                    hub.report_sketch_bytes(id, tenant.engine.memory())
                {
                    shared.obs.log(
                        journal,
                        Level::Error,
                        log_tag::INGEST_DEGRADED,
                        session,
                        || format!("sketch-bytes report failed: {e}"),
                    );
                }
                if want_recon {
                    match recon_errors(&tenant.engine, &acts) {
                        Ok(errs) => errs,
                        Err(e) => {
                            shared.obs.log(
                                journal,
                                Level::Error,
                                log_tag::INGEST_DEGRADED,
                                session,
                                || format!("reconstruction failed: {e:#}"),
                            );
                            Vec::new()
                        }
                    }
                } else {
                    Vec::new()
                }
            }));
            let recon_err = tail.unwrap_or_else(|panic| {
                shard.metrics.note_handler_panic();
                journal.emit(EventKind::HandlerPanic {
                    msg: proto::msg::INGEST,
                    session,
                });
                shared.obs.log(
                    journal,
                    Level::Error,
                    log_tag::INGEST_DEGRADED,
                    session,
                    || {
                        format!(
                            "post-commit ingest tail panicked: {}",
                            panic_message(panic.as_ref())
                        )
                    },
                );
                Vec::new()
            });
            Ok(Response::IngestOk {
                batches: tenant.engine.batches_ingested(),
                engine_bytes: tenant.engine.memory() as u64,
                recon_err,
                acked_seq: tenant.acked_seq,
            })
        }
        Request::Observe { session, metrics } => {
            let shard = shared.owner(session);
            let mut st = lock(&shard.state);
            let id = SessionId::from_raw(session);
            st.hub.observe(id, &metrics)?;
            shared.dirty.store(true, Ordering::SeqCst);
            let steps_seen =
                st.hub.session(id).map(|s| s.steps_seen()).unwrap_or(0);
            Ok(Response::ObserveOk { steps_seen })
        }
        Request::Diagnose { session } => {
            let shard = shared.owner(session);
            let mut st = lock(&shard.state);
            let id = SessionId::from_raw(session);
            let (diagnosis, steps_seen, monitor_bytes) = {
                let s = st.hub.session(id)?;
                (s.diagnose(), s.steps_seen(), s.monitor_bytes())
            };
            let engine_bytes = match st.tenants.get_mut(&session) {
                Some(t) => {
                    // Diagnose is the tenant's check-in: drain the
                    // backpressure counter.
                    t.quota_used = 0;
                    t.engine.memory()
                }
                None => 0,
            };
            let healthy = diagnosis.healthy();
            Ok(Response::Diagnosis {
                diagnosis,
                healthy,
                steps_seen,
                engine_bytes: engine_bytes as u64,
                monitor_bytes: monitor_bytes as u64,
            })
        }
        Request::Snapshot => match save_snapshot(shared, &journal) {
            Ok((bytes, sessions)) => Ok(Response::SnapshotOk {
                path: shared.cfg.snapshot_path.clone(),
                bytes,
                sessions,
            }),
            Err(e) => {
                Err(Error::Internal(format!("snapshot failed: {e:#}")))
            }
        },
        Request::Close { session } => {
            let shard = shared.owner(session);
            let mut st = lock(&shard.state);
            let id = SessionId::from_raw(session);
            st.hub.deregister(id)?;
            st.tenants.remove(&session);
            shared.dirty.store(true, Ordering::SeqCst);
            drop(st);
            shared.sessions_open.fetch_sub(1, Ordering::SeqCst);
            journal.emit(EventKind::SessionClose { session });
            Ok(Response::Closed { session })
        }
        Request::Shutdown => {
            let sessions = save_snapshot(shared, &journal).map_err(|e| {
                Error::Internal(format!("shutdown snapshot failed: {e:#}"))
            })?;
            shared.shutdown.store(true, Ordering::SeqCst);
            Ok(Response::ShutdownOk {
                sessions: sessions.1,
            })
        }
        Request::Stats => {
            let quota_limit = shared.cfg.session_quota_bytes as u64;
            let mut daemon = DaemonStats {
                sessions: shared.sessions_open.load(Ordering::SeqCst),
                max_sessions: shared.cfg.max_sessions as u64,
                shards: shared.n_shards(),
                ..DaemonStats::default()
            };
            let mut sessions = Vec::new();
            let mut shard_rows = Vec::with_capacity(shared.shards.len());
            for (i, shard) in shared.shards.iter().enumerate() {
                let st = lock(&shard.state);
                for s in st.hub.sessions() {
                    let raw = s.id.raw();
                    let (ingest, ar_bytes, ar_n, busy, quota_used) =
                        match st.tenants.get(&raw) {
                            Some(t) => (
                                t.ingest_bytes,
                                t.archive.bytes() as u64,
                                t.archive.len() as u64,
                                t.busy_rejections,
                                t.quota_used,
                            ),
                            None => (0, 0, 0, 0, 0),
                        };
                    daemon.ingest_bytes += ingest;
                    daemon.archive_bytes += ar_bytes;
                    sessions.push(SessionStats {
                        id: raw,
                        name: s.name.clone(),
                        steps_seen: s.steps_seen(),
                        ingest_bytes: ingest,
                        archive_bytes: ar_bytes,
                        archive_intervals: ar_n,
                        busy_rejections: busy,
                        quota_used,
                        quota_limit,
                    });
                }
                let shard_sessions = st.hub.len() as u64;
                drop(st);
                let ms = shard.metrics.state();
                let frames = shard.metrics.frames_served();
                daemon.frames_served += frames;
                daemon.busy_rejections += shard.metrics.busy_total();
                shard_rows.push(ShardStats {
                    shard: i as u64,
                    sessions: shard_sessions,
                    ingest_frames: ms.ingest.count,
                    ingest_bytes: ms.ingest_bytes,
                    ingest_p50_ns: ms.ingest.quantile(0.5) as u64,
                    ingest_p99_ns: ms.ingest.quantile(0.99) as u64,
                    frames_served: frames,
                });
            }
            // Shards interleave the id space; present rows in global
            // session-id order as the protocol documents.
            sessions.sort_by_key(|s| s.id);
            Ok(Response::StatsOk {
                daemon,
                sessions,
                shards: shard_rows,
            })
        }
        Request::Metrics => {
            let (state, frames_served) =
                merge_shard_metrics(&shared.shards);
            let open = shared.sessions_open.load(Ordering::SeqCst);
            Ok(Response::MetricsOk(state.into_report(
                shared.started.elapsed().as_millis() as u64,
                open,
                frames_served,
            )))
        }
        Request::Events { max } => {
            let (events, dropped) = shared.obs.journal.merged(max as usize);
            Ok(Response::EventsOk {
                dropped,
                base_unix_ms: shared.obs.journal.base_unix_ms(),
                events,
            })
        }
        Request::MetricsWindow => {
            let current = merged_sample(shared);
            let now_ms = shared.started.elapsed().as_millis() as u64;
            let report = shared.obs.windows.report(now_ms, &current);
            Ok(Response::MetricsWindowOk {
                report,
                health: collect_health(shared),
            })
        }
        Request::QueryTrajectory { session } => {
            let st = lock(&shared.owner(session).state);
            match st.tenants.get(&session) {
                Some(t) => Ok(Response::Trajectory {
                    points: t.archive.trajectory(),
                }),
                None => Err(HubError::NoSuchSession(SessionId::from_raw(
                    session,
                ))
                .into()),
            }
        }
        Request::QuerySimilarity { session, layer } => {
            let st = lock(&shared.owner(session).state);
            let tenant = st.tenants.get(&session).ok_or_else(|| {
                HubError::NoSuchSession(SessionId::from_raw(session))
            })?;
            if layer >= tenant.engine.n_layers() {
                return Err(Error::Invalid(format!(
                    "layer {layer} out of range (session has {} layers)",
                    tenant.engine.n_layers()
                )));
            }
            let (steps, sim) = tenant.archive.similarity(layer);
            Ok(Response::Similarity { steps, sim })
        }
        Request::QueryDrift { session, layer } => {
            let st = lock(&shared.owner(session).state);
            let tenant = st.tenants.get(&session).ok_or_else(|| {
                HubError::NoSuchSession(SessionId::from_raw(session))
            })?;
            if layer >= tenant.engine.n_layers() {
                return Err(Error::Invalid(format!(
                    "layer {layer} out of range (session has {} layers)",
                    tenant.engine.n_layers()
                )));
            }
            Ok(Response::Drift {
                points: tenant.archive.drift(layer),
            })
        }
        Request::ArchiveInfo { session } => {
            let st = lock(&shared.owner(session).state);
            match st.tenants.get(&session) {
                Some(t) => Ok(Response::ArchiveInfoOk(ArchiveInfo {
                    capacity: t.archive.capacity() as u64,
                    stride: t.archive.stride() as u64,
                    intervals: t.archive.len() as u64,
                    seen: t.archive.intervals_seen(),
                    bytes: t.archive.bytes() as u64,
                    layers: t.engine.n_layers() as u64,
                    oldest_step: t.archive.get(0).map_or(0, |r| r.step),
                    newest_step: t
                        .archive
                        .get(t.archive.len().wrapping_sub(1))
                        .map_or(0, |r| r.step),
                })),
                None => Err(HubError::NoSuchSession(SessionId::from_raw(
                    session,
                ))
                .into()),
            }
        }
    }
}

/// The shard whose metrics should record a request's handle latency:
/// the owning shard for session-scoped ops, the connection's shard for
/// global ops.
fn metrics_shard(shared: &Shared, home: usize, req: &Request) -> usize {
    let session = match req {
        Request::Ingest { session, .. }
        | Request::Observe { session, .. }
        | Request::Diagnose { session }
        | Request::QueryTrajectory { session }
        | Request::QuerySimilarity { session, .. }
        | Request::QueryDrift { session, .. }
        | Request::ArchiveInfo { session } => *session,
        _ => return home,
    };
    (session % shared.n_shards()) as usize
}

/// The session a request names (0 for global ops) — journaled with
/// handler-panic events so a blast radius is attributable.
fn request_session(req: &Request) -> u64 {
    match req {
        Request::Ingest { session, .. }
        | Request::Observe { session, .. }
        | Request::Diagnose { session }
        | Request::Close { session }
        | Request::QueryTrajectory { session }
        | Request::QuerySimilarity { session, .. }
        | Request::QueryDrift { session, .. }
        | Request::ArchiveInfo { session } => *session,
        _ => 0,
    }
}

/// Render a caught panic payload (almost always a `&str` or `String`
/// from `panic!`/`assert!`) for the error reply and the journal.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Staged-read outcome for one nonblocking read pass.
enum ReadStep {
    /// A complete frame is staged in `hdr`/`payload`.
    Frame,
    /// Out of bytes for now; revisit on the next readiness event.
    NotReady,
    /// EOF, unrecoverable transport error, or untrusted framing.
    Closed,
}

/// One nonblocking connection owned by a shard's event loop.
struct Conn {
    stream: TcpStream,
    hdr: [u8; FRAME_HEADER_LEN],
    hdr_got: usize,
    header: Option<FrameHeader>,
    payload: Vec<u8>,
    payload_got: usize,
    /// Outbound bytes not yet accepted by the kernel (`out_pos` is the
    /// flushed prefix).
    out: Vec<u8>,
    out_pos: usize,
    /// Reply queued for a fatal protocol error: close once flushed.
    close_after_flush: bool,
    /// Whether the poller registration currently includes writability.
    interest_rw: bool,
    enc: Enc,
    frame: Vec<u8>,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        Conn {
            stream,
            hdr: [0u8; FRAME_HEADER_LEN],
            hdr_got: 0,
            header: None,
            payload: Vec::new(),
            payload_got: 0,
            out: Vec::new(),
            out_pos: 0,
            close_after_flush: false,
            interest_rw: false,
            enc: Enc::new(),
            frame: Vec::new(),
        }
    }

    fn out_is_empty(&self) -> bool {
        self.out_pos >= self.out.len()
    }

    /// Advance the staged read as far as the socket allows.
    fn read_step(&mut self, faults: &FaultRegistry) -> ReadStep {
        // `conn.read` failpoint: an injected error drops the peer, an
        // injected WouldBlock is a spurious-readiness storm (the loop
        // just revisits on the next event).
        match faults.check_io(fault::site::CONN_READ) {
            Ok(()) => {}
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                return ReadStep::NotReady
            }
            Err(_) => return ReadStep::Closed,
        }
        if self.header.is_none() {
            while self.hdr_got < FRAME_HEADER_LEN {
                match self.stream.read(&mut self.hdr[self.hdr_got..]) {
                    Ok(0) => return ReadStep::Closed,
                    Ok(n) => self.hdr_got += n,
                    Err(e)
                        if e.kind() == io::ErrorKind::Interrupted => {}
                    Err(e)
                        if e.kind() == io::ErrorKind::WouldBlock =>
                    {
                        return ReadStep::NotReady
                    }
                    Err(_) => return ReadStep::Closed,
                }
            }
            match FrameHeader::parse(&self.hdr) {
                Ok(h) => {
                    self.payload.clear();
                    self.payload.resize(h.len as usize, 0);
                    self.payload_got = 0;
                    self.header = Some(h);
                }
                // Bad magic / oversized length: framing can't be
                // trusted, so no reply is possible — drop the peer.
                Err(_) => return ReadStep::Closed,
            }
        }
        while self.payload_got < self.payload.len() {
            match self.stream.read(&mut self.payload[self.payload_got..]) {
                Ok(0) => return ReadStep::Closed,
                Ok(n) => self.payload_got += n,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    return ReadStep::NotReady
                }
                Err(_) => return ReadStep::Closed,
            }
        }
        ReadStep::Frame
    }

    /// Consume the staged header (the payload stays readable until the
    /// next `read_step` begins a new frame).
    fn take_header(&mut self) -> FrameHeader {
        self.hdr_got = 0;
        self.header.take().expect("take_header without staged frame")
    }

    /// Push queued bytes into the kernel until done or `WouldBlock`.
    fn flush(&mut self, faults: &FaultRegistry) -> io::Result<()> {
        // `conn.write` failpoint: WouldBlock leaves the bytes queued
        // for the next writable event; other errors kill the conn.
        match faults.check_io(fault::site::CONN_WRITE) {
            Ok(()) => {}
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                return Ok(())
            }
            Err(e) => return Err(e),
        }
        while self.out_pos < self.out.len() {
            match self.stream.write(&self.out[self.out_pos..]) {
                Ok(0) => {
                    return Err(io::Error::from(io::ErrorKind::WriteZero))
                }
                Ok(n) => self.out_pos += n,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) => return Err(e),
            }
        }
        if self.out_pos >= self.out.len() {
            self.out.clear();
            self.out_pos = 0;
        }
        Ok(())
    }
}

/// Decode, dispatch and encode one staged frame; the reply is appended
/// to `conn.out` (not yet flushed).  `Ok(fatal)` tells the caller
/// whether the connection must close once the reply drains; `Err(())`
/// means the reply could not even be framed (oversized) and the
/// connection should drop.
fn process_frame(
    shared: &Shared,
    home: usize,
    conn: &mut Conn,
    header: FrameHeader,
) -> std::result::Result<bool, ()> {
    let version_ok =
        (PROTO_MIN_VERSION..=PROTO_VERSION).contains(&header.version);
    let outcome: std::result::Result<Response, Error> = if !version_ok {
        Err(Error::UnsupportedVersion(format!(
            "server speaks proto v{PROTO_MIN_VERSION}..v{PROTO_VERSION}, \
             frame is v{}",
            header.version
        )))
    } else if header.msg == proto::msg::METRICS
        && header.version < METRICS_MIN_VERSION
    {
        Err(Error::UnsupportedVersion(format!(
            "Metrics requires proto v{METRICS_MIN_VERSION}, frame is v{}",
            header.version
        )))
    } else if (header.msg == proto::msg::EVENTS
        || header.msg == proto::msg::METRICS_WINDOW)
        && header.version < OBS_MIN_VERSION
    {
        Err(Error::UnsupportedVersion(format!(
            "Events/MetricsWindow require proto v{OBS_MIN_VERSION}, \
             frame is v{}",
            header.version
        )))
    } else {
        match Request::decode_v(header.msg, &conn.payload, header.version) {
            Ok(req) => {
                let shard = metrics_shard(shared, home, &req);
                let session = request_session(&req);
                let payload_len = conn.payload.len();
                let t0 = Instant::now();
                // Panic isolation (DESIGN.md §11): a handler panic —
                // injected or real — becomes a typed Internal error on
                // this one request; the shard keeps serving (the state
                // lock recovers from poisoning in `lock`).  The
                // `handler` failpoint lives inside the boundary so
                // `handler=panic` exercises exactly this path.
                let r = catch_unwind(AssertUnwindSafe(|| {
                    shared
                        .faults
                        .check_io(fault::site::HANDLER)
                        .map_err(|e| {
                            Error::Internal(format!(
                                "injected handler fault: {e}"
                            ))
                        })?;
                    handle_request(shared, home, req, payload_len)
                }))
                .unwrap_or_else(|panic| {
                    shared.shards[home].metrics.note_handler_panic();
                    shared.obs.shard(home).emit(EventKind::HandlerPanic {
                        msg: header.msg,
                        session,
                    });
                    Err(Error::Internal(format!(
                        "handler panicked: {}",
                        panic_message(panic.as_ref())
                    )))
                });
                let elapsed = t0.elapsed();
                shared.shards[shard]
                    .metrics
                    .observe_request(header.msg, elapsed);
                let elapsed_ns =
                    elapsed.as_nanos().min(u64::MAX as u128) as u64;
                if elapsed_ns >= shared.obs.slow_ns {
                    shared.obs.shard(home).emit(EventKind::SlowRequest {
                        msg: header.msg,
                        elapsed_ns,
                    });
                }
                r
            }
            Err(e) => Err(Error::BadFrame(e.to_string())),
        }
    };
    let (resp, fatal) = match outcome {
        Ok(r) => (r, false),
        Err(e) => {
            let fatal = e.is_fatal();
            (e.response(), fatal)
        }
    };
    // Echo the request's version on the reply (clamped into range for
    // rejections of out-of-range frames) so version-gated response
    // fields match what the peer can decode.
    let reply_version =
        header.version.clamp(PROTO_MIN_VERSION, PROTO_VERSION);
    conn.enc.reset();
    resp.encode_into_v(&mut conn.enc, reply_version);
    if proto::write_frame_versioned_reusing(
        &mut conn.out,
        reply_version,
        resp.msg_type(),
        conn.enc.bytes(),
        &mut conn.frame,
    )
    .is_err()
    {
        return Err(());
    }
    // `conn.truncate` failpoint: cut the just-queued reply frame in
    // half, push what's left to the peer and drop the connection — a
    // daemon dying mid-reply, as seen from the client.
    if shared.faults.fire(fault::site::CONN_TRUNCATE).is_some() {
        let keep = conn.out.len().saturating_sub(conn.frame.len() / 2);
        conn.out.truncate(keep.max(conn.out_pos));
        let _ = conn.flush(&shared.faults);
        return Err(());
    }
    shared.shards[home].metrics.note_frame_served();
    Ok(fatal)
}

/// Service a readable connection: read frames until the socket runs
/// dry, handling each complete frame as it lands.  Returns whether the
/// connection stays alive.
fn service_readable(shared: &Shared, home: usize, conn: &mut Conn) -> bool {
    loop {
        match conn.read_step(&shared.faults) {
            ReadStep::Frame => {
                let header = conn.take_header();
                match process_frame(shared, home, conn, header) {
                    Ok(fatal) => {
                        if conn.flush(&shared.faults).is_err() {
                            return false;
                        }
                        if fatal {
                            conn.close_after_flush = true;
                            // Keep the conn only if the goodbye reply
                            // still needs draining.
                            return !conn.out_is_empty();
                        }
                        if shared.shutdown.load(Ordering::SeqCst) {
                            // Stop consuming requests; the shard loop
                            // drains pending replies and exits.
                            return true;
                        }
                    }
                    Err(()) => return false,
                }
            }
            ReadStep::NotReady => return true,
            ReadStep::Closed => return false,
        }
    }
}

/// One shard's event loop: admit connections from the acceptor, wait
/// for readiness, and service reads/writes nonblockingly.  The poller
/// is treated as a *hint* source (level-triggered epoll or the
/// portable fallback): a spurious "ready" just costs one `WouldBlock`.
fn shard_loop(shared: &Shared, home: usize, rx: mpsc::Receiver<TcpStream>) {
    let journal = shared.obs.shard(home);
    let mut poller = match Poller::new() {
        Ok(p) => p,
        Err(e) => {
            shared.obs.log(
                &journal,
                Level::Error,
                log_tag::POLLER_INIT_FAILED,
                home as u64,
                || format!("shard {home}: poller init failed: {e}"),
            );
            return;
        }
    };
    let mut accepted: u64 = 0;
    let mut conns: BTreeMap<u64, Conn> = BTreeMap::new();
    let mut next_token: u64 = 1;
    let mut events: Vec<Event> = Vec::new();
    let mut dead: Vec<u64> = Vec::new();
    loop {
        // Admit handed-off connections.
        loop {
            match rx.try_recv() {
                Ok(stream) => {
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    let token = next_token;
                    next_token += 1;
                    if poller
                        .register(&stream, token, Interest::READ)
                        .is_err()
                    {
                        continue;
                    }
                    conns.insert(token, Conn::new(stream));
                    accepted += 1;
                    journal.emit(EventKind::ShardAccept { conn: accepted });
                }
                Err(mpsc::TryRecvError::Empty)
                | Err(mpsc::TryRecvError::Disconnected) => break,
            }
        }
        if shared.shutdown.load(Ordering::SeqCst) {
            break;
        }
        if poller.wait(&mut events, 20).is_err() {
            thread::sleep(Duration::from_millis(5));
            continue;
        }
        dead.clear();
        for ev in &events {
            let conn = match conns.get_mut(&ev.token) {
                Some(c) => c,
                None => continue,
            };
            let mut alive = true;
            if ev.writable && conn.flush(&shared.faults).is_err() {
                alive = false;
            }
            if alive && ev.readable {
                alive = service_readable(shared, home, conn);
            }
            if alive && ev.closed && !ev.readable {
                // Peer hung up with nothing left to read.
                alive = false;
            }
            if alive && conn.close_after_flush && conn.out_is_empty() {
                alive = false;
            }
            if alive {
                // Ask for writability only while bytes are queued.
                let want_rw = !conn.out_is_empty();
                if want_rw != conn.interest_rw {
                    let interest = if want_rw {
                        Interest::READ_WRITE
                    } else {
                        Interest::READ
                    };
                    if poller
                        .modify(&conn.stream, ev.token, interest)
                        .is_ok()
                    {
                        conn.interest_rw = want_rw;
                    }
                }
            } else {
                dead.push(ev.token);
            }
        }
        for &token in &dead {
            if let Some(conn) = conns.remove(&token) {
                // Deregister while the fd is still open, then drop.
                let _ = poller.deregister(&conn.stream, token);
            }
        }
    }
    // Shutdown: bounded grace to drain queued replies (e.g. the
    // ShutdownOk that triggered this) before dropping connections.
    let deadline = Instant::now() + Duration::from_millis(500);
    loop {
        let mut pending = false;
        for conn in conns.values_mut() {
            if conn.out_is_empty() {
                continue;
            }
            if conn.flush(&shared.faults).is_err() {
                conn.out.clear();
                conn.out_pos = 0;
            } else if !conn.out_is_empty() {
                pending = true;
            }
        }
        if !pending || Instant::now() >= deadline {
            break;
        }
        thread::sleep(Duration::from_millis(5));
    }
}

/// A bound (but not yet running) daemon.  Binding and running are split
/// so in-process embedders (tests, benches) can learn the ephemeral port
/// before serving starts.
pub struct Daemon {
    listener: TcpListener,
    /// Bound HTTP exposition socket (`cfg.obs.addr`; None = disabled).
    obs_listener: Option<TcpListener>,
    shared: Arc<Shared>,
}

impl Daemon {
    /// Bind the listen socket, build the shards and, if a snapshot
    /// exists at `cfg.snapshot_path`, restore every session from it
    /// (session `s` routes to shard `s % shards`; the merged metrics
    /// record restores into shard 0).
    pub fn bind(cfg: ServeConfig) -> Result<Daemon> {
        cfg.validate()?;
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding {}", cfg.addr))?;
        listener.set_nonblocking(true)?;
        // Failpoints arm once at bind: the config/CLI spec first, then
        // SKETCHD_FAULT on top.  The registry is shared with the store
        // so snapshot I/O sites answer to the same spec.
        let faults = Arc::new(
            FaultRegistry::from_spec_and_env(&cfg.fault)
                .map_err(|e| anyhow::anyhow!("serve.fault: {e}"))?,
        );
        let store = SnapshotStore::with_faults(
            cfg.snapshot_path.clone(),
            Arc::clone(&faults),
        );
        let par = Parallelism::from_threads(resolve_threads(cfg.threads));
        let n_shards = cfg.shards.max(1);
        let mut shards = Vec::with_capacity(n_shards);
        for s in 0..n_shards {
            let pool = Pool::new(par);
            shards.push(Shard {
                state: Mutex::new(State {
                    hub: MonitorHub::with_pool(Arc::clone(&pool)),
                    tenants: BTreeMap::new(),
                }),
                pool,
                metrics: ServeMetrics::new(),
                next_id: AtomicU64::new(s as u64),
            });
        }
        let mut restored = 0u64;
        if let Some(snap) = store.load().with_context(|| {
            format!("loading snapshot {}", cfg.snapshot_path)
        })? {
            // Lifetime observability counters resume where the snapshot
            // left them; the (merged) record lands on shard 0, keeping
            // the cross-shard totals exact.
            shards[0].metrics.restore(&snap.metrics);
            for rec in &snap.sessions {
                let shard =
                    &shards[(rec.session.id % n_shards as u64) as usize];
                let mut st = lock(&shard.state);
                let id = st.hub.restore_session(&rec.session)?;
                let archive = SessionArchive::from_state(&rec.archive);
                // The hub does not persist archive accounting; re-derive
                // it from the restored ring.
                st.hub.report_archive_bytes(id, archive.bytes())?;
                let engine = SketchEngine::from_snapshot_with_pool(
                    &rec.engine,
                    Arc::clone(&shard.pool),
                )?;
                let rank = engine.config().rank as u32;
                st.tenants.insert(
                    rec.session.id,
                    Tenant {
                        engine,
                        rank,
                        quota_used: rec.quota_used,
                        ingest_bytes: rec.ingest_bytes,
                        busy_rejections: rec.busy_rejections,
                        // Restoring = a new incarnation of the session
                        // (pre-v4 snapshots carry epoch 0 → resume as
                        // epoch 1).  acked_seq restores with the engine
                        // state it is exactly consistent with.
                        epoch: rec.epoch + 1,
                        acked_seq: rec.acked_seq,
                        archive,
                    },
                );
                drop(st);
                // Advance the strided allocator past the restored id
                // (pre-shard snapshots have dense ids; `id + N` keeps
                // the id ≡ shard (mod N) invariant).
                shard
                    .next_id
                    .fetch_max(rec.session.id + n_shards as u64, Ordering::SeqCst);
                restored += 1;
            }
        }
        // The window ring's baseline is the lifetime capture right
        // here — restored counters never show up as a fake first
        // window's traffic.
        let (state, frames_served) = merge_shard_metrics(&shards);
        let obs = Obs::new(
            &cfg.obs,
            n_shards,
            Sample::from_state(&state, frames_served),
        );
        let obs_listener = if cfg.obs.addr.is_empty() {
            None
        } else {
            let l = TcpListener::bind(&cfg.obs.addr).with_context(|| {
                format!("binding obs endpoint {}", cfg.obs.addr)
            })?;
            Some(l)
        };
        Ok(Daemon {
            listener,
            obs_listener,
            shared: Arc::new(Shared {
                cfg,
                par,
                shards,
                store,
                shutdown: AtomicBool::new(false),
                dirty: AtomicBool::new(false),
                sessions_open: AtomicU64::new(restored),
                started: Instant::now(),
                obs,
                faults,
                skip_final_snapshot: AtomicBool::new(false),
            }),
        })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// The bound exposition-endpoint address (None when disabled).
    pub fn obs_local_addr(&self) -> Option<SocketAddr> {
        self.obs_listener
            .as_ref()
            .and_then(|l| l.local_addr().ok())
    }

    /// Sessions currently held (restored + live) across all shards.
    pub fn session_count(&self) -> usize {
        self.shared.sessions_open.load(Ordering::SeqCst) as usize
    }

    /// Connection shards this daemon serves with.
    pub fn shard_count(&self) -> usize {
        self.shared.shards.len()
    }

    /// The daemon's shared failpoint registry — tests and the chaos
    /// harness arm/disarm sites mid-run through this handle.
    pub fn faults(&self) -> Arc<FaultRegistry> {
        Arc::clone(&self.shared.faults)
    }

    /// Serve until the shutdown flag is set (by a `Shutdown` frame or a
    /// [`DaemonHandle`]), then write a final snapshot if state changed.
    pub fn run(mut self) -> Result<()> {
        let obs_listener = self.obs_listener.take();
        let shared: &Shared = &self.shared;
        let n = shared.shards.len();
        let mut last_snapshot = Instant::now();
        thread::scope(|s| {
            let mut senders = Vec::with_capacity(n);
            for home in 0..n {
                let (tx, rx) = mpsc::channel::<TcpStream>();
                senders.push(tx);
                s.spawn(move || shard_loop(shared, home, rx));
            }
            // Exposition listener: one thread, GET-only, renders from
            // the same merged captures as the protocol ops.
            if let Some(listener) = obs_listener {
                s.spawn(move || {
                    let handler = |path: &str| match path {
                        "/metrics" => Some(expo::render_metrics(
                            &expo_snapshot(shared),
                        )),
                        "/events" => {
                            let (events, dropped) =
                                shared.obs.journal.merged(0);
                            Some(expo::render_events(
                                &events,
                                dropped,
                                shared.obs.journal.base_unix_ms(),
                            ))
                        }
                        _ => None,
                    };
                    expo::serve(listener, &shared.shutdown, &handler);
                });
            }
            // Event-driven accept when the poller is available; plain
            // paced accept otherwise.
            let mut poller = Poller::new().ok();
            let registered = match poller.as_mut() {
                Some(p) => p
                    .register(&self.listener, 0, Interest::READ)
                    .is_ok(),
                None => false,
            };
            if !registered {
                poller = None;
            }
            let mut events: Vec<Event> = Vec::new();
            let mut next = 0usize;
            loop {
                if shared.shutdown.load(Ordering::SeqCst) {
                    break;
                }
                // Close a time-series window whenever one is due; the
                // poller wait below bounds the tick jitter to ~50ms.
                let now_ms =
                    shared.started.elapsed().as_millis() as u64;
                if shared.obs.windows.due(now_ms) {
                    shared
                        .obs
                        .windows
                        .tick(now_ms, merged_sample(shared));
                }
                let interval = shared.cfg.snapshot_interval_secs;
                if interval > 0
                    && last_snapshot.elapsed().as_secs() >= interval
                {
                    if shared.dirty.load(Ordering::SeqCst) {
                        // A failure is counted + journaled inside
                        // save_snapshot; the dirty flag is re-set so
                        // the next interval retries.
                        let _ =
                            save_snapshot(shared, &shared.obs.control());
                    }
                    last_snapshot = Instant::now();
                }
                match poller.as_mut() {
                    Some(p) => {
                        let _ = p.wait(&mut events, 50);
                    }
                    None => thread::sleep(Duration::from_millis(10)),
                }
                loop {
                    match self.listener.accept() {
                        Ok((stream, _peer)) => {
                            // Round-robin hand-off to the shards.
                            let _ = senders[next % n].send(stream);
                            next = next.wrapping_add(1);
                        }
                        Err(e)
                            if e.kind()
                                == std::io::ErrorKind::WouldBlock =>
                        {
                            break;
                        }
                        Err(e) => {
                            shared.obs.log(
                                &shared.obs.control(),
                                Level::Error,
                                log_tag::ACCEPT_FAILED,
                                0,
                                || format!("accept failed: {e}"),
                            );
                            thread::sleep(Duration::from_millis(50));
                            break;
                        }
                    }
                }
            }
            drop(senders);
        });
        if shared.dirty.load(Ordering::SeqCst)
            && !shared.skip_final_snapshot.load(Ordering::SeqCst)
        {
            save_snapshot(shared, &shared.obs.control())?;
        }
        Ok(())
    }

    /// Run on a background thread; the returned handle stops the daemon
    /// (with a final snapshot) on [`DaemonHandle::stop`].  Used by the
    /// loopback tests and benches.
    pub fn spawn(self) -> Result<DaemonHandle> {
        let addr = self.local_addr()?;
        let obs_addr = self.obs_local_addr();
        let shared = Arc::clone(&self.shared);
        let join = thread::spawn(move || self.run());
        Ok(DaemonHandle {
            addr,
            obs_addr,
            shared,
            join,
        })
    }
}

/// Handle to an in-process daemon spawned with [`Daemon::spawn`].
pub struct DaemonHandle {
    addr: SocketAddr,
    obs_addr: Option<SocketAddr>,
    shared: Arc<Shared>,
    join: thread::JoinHandle<Result<()>>,
}

impl DaemonHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The exposition endpoint's bound address (None when disabled).
    pub fn obs_addr(&self) -> Option<SocketAddr> {
        self.obs_addr
    }

    /// Request shutdown and wait for the final snapshot to land.
    pub fn stop(self) -> Result<()> {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        match self.join.join() {
            Ok(r) => r,
            Err(_) => anyhow::bail!("daemon thread panicked"),
        }
    }

    /// Abrupt stop: shut down *without* the final snapshot, so the
    /// daemon dies with only whatever the last interval/requested
    /// snapshot captured — as close to `kill -9` as an in-process
    /// daemon gets.  The chaos harness uses this to prove clients
    /// resume exactly from durable state (DESIGN.md §11).
    pub fn kill(self) -> Result<()> {
        self.shared
            .skip_final_snapshot
            .store(true, Ordering::SeqCst);
        self.shared.shutdown.store(true, Ordering::SeqCst);
        match self.join.join() {
            Ok(r) => r,
            Err(_) => anyhow::bail!("daemon thread panicked"),
        }
    }

    /// The daemon's shared failpoint registry (see [`Daemon::faults`]).
    pub fn faults(&self) -> Arc<FaultRegistry> {
        Arc::clone(&self.shared.faults)
    }
}

/// `sketchd`/`sketchgrad serve` entry point: `[serve]` TOML config with
/// CLI overrides, then serve until shutdown.
pub fn serve_from_args(args: &mut Args) -> Result<()> {
    let mut cfg = if let Some(path) = args.opt("config") {
        ServeConfig::from_toml_file(std::path::Path::new(&path))?
    } else {
        ServeConfig::default()
    };
    cfg.addr = args.opt_or("addr", &cfg.addr);
    cfg.max_sessions = args.opt_usize("max-sessions", cfg.max_sessions)?;
    cfg.snapshot_interval_secs =
        args.opt_u64("snapshot-interval", cfg.snapshot_interval_secs)?;
    cfg.session_quota_bytes =
        args.opt_usize("quota", cfg.session_quota_bytes)?;
    cfg.snapshot_path = args.opt_or("snapshot-path", &cfg.snapshot_path);
    cfg.threads = resolve_threads(args.opt_usize("threads", cfg.threads)?);
    cfg.shards = resolve_threads(args.opt_usize("shards", cfg.shards)?);
    cfg.archive.capacity =
        args.opt_usize("archive-capacity", cfg.archive.capacity)?;
    cfg.archive.stride =
        args.opt_usize("archive-stride", cfg.archive.stride)?;
    cfg.obs.addr = args.opt_or("obs-addr", &cfg.obs.addr);
    cfg.obs.window_ms = args.opt_u64("obs-window-ms", cfg.obs.window_ms)?;
    cfg.obs.window_count =
        args.opt_usize("obs-window-count", cfg.obs.window_count)?;
    cfg.obs.journal_capacity = args
        .opt_usize("obs-journal-capacity", cfg.obs.journal_capacity)?;
    cfg.obs.slow_ms = args.opt_u64("obs-slow-ms", cfg.obs.slow_ms)?;
    cfg.fault = args.opt_or("fault", &cfg.fault);
    args.finish()?;

    let daemon = Daemon::bind(cfg)?;
    println!(
        "sketchd listening on {} ({} resumed sessions, {} shards, \
         snapshots -> {})",
        daemon.local_addr()?,
        daemon.session_count(),
        daemon.shard_count(),
        daemon.shared.cfg.snapshot_path,
    );
    if let Some(obs) = daemon.obs_local_addr() {
        println!("sketchd obs endpoint on http://{obs} (/metrics, /events)");
    }
    daemon.run()
}
