//! The sketchd wire protocol: versioned length-prefixed binary frames.
//!
//! Frame layout (all little-endian; see DESIGN.md §5 for the diagram):
//!
//! ```text
//! +----------+----------+-----+----------+----------+=============+
//! | magic u32| ver  u16 | msg | reserved | len  u32 | payload ... |
//! | "SKD1"   |          | u8  | u8 (=0)  |          | (len bytes) |
//! +----------+----------+-----+----------+----------+=============+
//! ```
//!
//! Requests (`Hello`/`OpenSession`/`Ingest`/`Observe`/`Diagnose`/
//! `Snapshot`/`Close`/`Shutdown`, plus the v2 observability + archive
//! ops `Stats`/`QueryTrajectory`/`QuerySimilarity`/`QueryDrift`/
//! `ArchiveInfo`) and responses are encoded with the explicit
//! little-endian codecs in [`super::codec`]; floats travel as IEEE-754
//! bit patterns so a remote session is *bit-for-bit* equivalent to an
//! in-process one — and archive query answers are bit-identical across
//! a daemon warm restart.
//!
//! Version negotiation (v3): the server accepts any frame version in
//! `[PROTO_MIN_VERSION, PROTO_VERSION]` and echoes the request's version
//! on the reply, encoding version-gated response fields only when the
//! frame version carries them (see [`Response::encode_into_v`]). Frames
//! outside that range are rejected with
//! [`ErrorCode::UnsupportedVersion`] (the reply is clamped into the
//! supported range so any peer can decode it). The `Metrics` op requires
//! a v3 frame ([`METRICS_MIN_VERSION`]); sending it at v2 is an
//! unsupported-version error.

use std::io::{Read, Write};

use crate::archive::{DriftPoint, TrajectoryPoint};
use crate::coordinator::StepMetrics;
use crate::monitor::{Diagnosis, MonitorConfig};
use crate::sketch::Mat;

use super::codec::{CodecError, Dec, Enc};
use super::metrics::{dec_metrics_report, enc_metrics_report, MetricsReport};
use super::obs::window::{dec_window_report, enc_window_report, WindowReport};
use super::obs::{
    dec_session_health, enc_session_health, Event, SessionHealth,
};

/// `b"SKD1"` interpreted little-endian.
pub const FRAME_MAGIC: u32 = u32::from_le_bytes(*b"SKD1");
/// v2: `Stats` + archive query ops (`QueryTrajectory`/`QuerySimilarity`/
/// `QueryDrift`/`ArchiveInfo`). v3: `Metrics` op + backpressure fields
/// in `StatsOk` (daemon + per-session Busy counts, quota usage).
/// v4: sharded serve — `StatsOk` grows the shard count plus one
/// [`ShardStats`] row per connection shard (DESIGN.md §9).
/// v5: observability — the `Events` / `MetricsWindow` ops (event
/// journal dump, window-ring report + per-session sketch-health
/// gauges; DESIGN.md §10). No pre-v5 payload changes shape.
/// v6: crash-safe resumption (DESIGN.md §11) — `Ingest` carries a
/// client sequence number, `SessionOpened` returns the session's
/// resume epoch, `IngestOk` acks the highest applied seq, and
/// `MetricsOk` grows the snapshot-failure + handler-panic counters.
/// No pre-v6 payload changes shape.
pub const PROTO_VERSION: u16 = 6;
/// Oldest frame version the daemon still speaks (v2 clients keep
/// working; their replies omit the v3/v4 fields).
pub const PROTO_MIN_VERSION: u16 = 2;
/// The `Metrics` op only exists from this frame version on.
pub const METRICS_MIN_VERSION: u16 = 3;
/// The `Events` / `MetricsWindow` ops only exist from this frame
/// version on.
pub const OBS_MIN_VERSION: u16 = 5;
pub const FRAME_HEADER_LEN: usize = 12;
/// Upper bound on a frame payload (a 128-batch, 8x512-layer ingest is
/// ~5 MB; 64 MiB leaves ample headroom while bounding a hostile header).
pub const MAX_FRAME_LEN: u32 = 64 << 20;

/// Message-type bytes (requests < 128, responses >= 128).
pub mod msg {
    pub const HELLO: u8 = 1;
    pub const OPEN_SESSION: u8 = 2;
    pub const INGEST: u8 = 3;
    pub const OBSERVE: u8 = 4;
    pub const DIAGNOSE: u8 = 5;
    pub const SNAPSHOT: u8 = 6;
    pub const CLOSE: u8 = 7;
    pub const SHUTDOWN: u8 = 8;
    pub const STATS: u8 = 9;
    pub const QUERY_TRAJECTORY: u8 = 10;
    pub const QUERY_SIMILARITY: u8 = 11;
    pub const QUERY_DRIFT: u8 = 12;
    pub const ARCHIVE_INFO: u8 = 13;
    pub const METRICS: u8 = 14;
    pub const EVENTS: u8 = 15;
    pub const METRICS_WINDOW: u8 = 16;

    pub const HELLO_OK: u8 = 128;
    pub const SESSION_OPENED: u8 = 129;
    pub const INGEST_OK: u8 = 130;
    pub const OBSERVE_OK: u8 = 131;
    pub const DIAGNOSIS: u8 = 132;
    pub const SNAPSHOT_OK: u8 = 133;
    pub const CLOSED: u8 = 134;
    pub const BUSY: u8 = 135;
    pub const ERROR: u8 = 136;
    pub const SHUTDOWN_OK: u8 = 137;
    pub const STATS_OK: u8 = 138;
    pub const TRAJECTORY: u8 = 139;
    pub const SIMILARITY: u8 = 140;
    pub const DRIFT: u8 = 141;
    pub const ARCHIVE_INFO_OK: u8 = 142;
    pub const METRICS_OK: u8 = 143;
    pub const EVENTS_OK: u8 = 144;
    pub const METRICS_WINDOW_OK: u8 = 145;
}

/// Protocol error codes carried by [`Response::Error`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorCode {
    BadFrame = 1,
    UnsupportedVersion = 2,
    UnknownSession = 3,
    DuplicateSession = 4,
    SessionsExhausted = 5,
    Invalid = 6,
    Internal = 7,
}

impl ErrorCode {
    pub fn as_u16(self) -> u16 {
        self as u16
    }

    pub fn from_u16(v: u16) -> Result<ErrorCode, CodecError> {
        Ok(match v {
            1 => ErrorCode::BadFrame,
            2 => ErrorCode::UnsupportedVersion,
            3 => ErrorCode::UnknownSession,
            4 => ErrorCode::DuplicateSession,
            5 => ErrorCode::SessionsExhausted,
            6 => ErrorCode::Invalid,
            7 => ErrorCode::Internal,
            _ => {
                return Err(CodecError::BadTag {
                    what: "error code",
                    tag: v as u8,
                })
            }
        })
    }
}

impl std::fmt::Display for ErrorCode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            ErrorCode::BadFrame => "bad-frame",
            ErrorCode::UnsupportedVersion => "unsupported-version",
            ErrorCode::UnknownSession => "unknown-session",
            ErrorCode::DuplicateSession => "duplicate-session",
            ErrorCode::SessionsExhausted => "sessions-exhausted",
            ErrorCode::Invalid => "invalid",
            ErrorCode::Internal => "internal",
        };
        write!(f, "{s}")
    }
}

/// Parsed frame header.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FrameHeader {
    pub version: u16,
    pub msg: u8,
    pub len: u32,
}

impl FrameHeader {
    pub fn encode(version: u16, msg: u8, len: u32) -> [u8; FRAME_HEADER_LEN] {
        let mut h = [0u8; FRAME_HEADER_LEN];
        h[0..4].copy_from_slice(&FRAME_MAGIC.to_le_bytes());
        h[4..6].copy_from_slice(&version.to_le_bytes());
        h[6] = msg;
        h[7] = 0;
        h[8..12].copy_from_slice(&len.to_le_bytes());
        h
    }

    /// Parse and sanity-check a header (magic + length cap).  The
    /// version is NOT checked here — the server replies with a typed
    /// `UnsupportedVersion` error instead of dropping the connection.
    pub fn parse(h: &[u8; FRAME_HEADER_LEN]) -> Result<FrameHeader, CodecError> {
        let magic = u32::from_le_bytes(h[0..4].try_into().unwrap());
        if magic != FRAME_MAGIC {
            return Err(CodecError::BadTag {
                what: "frame magic",
                tag: h[0],
            });
        }
        let len = u32::from_le_bytes(h[8..12].try_into().unwrap());
        if len > MAX_FRAME_LEN {
            return Err(CodecError::BadLength {
                len: len as usize,
                have: MAX_FRAME_LEN as usize,
            });
        }
        Ok(FrameHeader {
            version: u16::from_le_bytes(h[4..6].try_into().unwrap()),
            msg: h[6],
            len,
        })
    }
}

/// Write one frame (header + payload) as a single buffer.
pub fn write_frame(
    w: &mut impl Write,
    msg: u8,
    payload: &[u8],
) -> std::io::Result<()> {
    write_frame_versioned(w, PROTO_VERSION, msg, payload)
}

/// [`write_frame`] assembling into the caller's reusable `frame` buffer
/// (cleared first, capacity kept) — the steady-state daemon/client path,
/// which allocates nothing per frame once the buffer has grown.
pub fn write_frame_reusing(
    w: &mut impl Write,
    msg: u8,
    payload: &[u8],
    frame: &mut Vec<u8>,
) -> std::io::Result<()> {
    write_frame_versioned_reusing(w, PROTO_VERSION, msg, payload, frame)
}

/// [`write_frame`] with an explicit version — used by the
/// version-negotiation tests to craft mismatched frames.
pub fn write_frame_versioned(
    w: &mut impl Write,
    version: u16,
    msg: u8,
    payload: &[u8],
) -> std::io::Result<()> {
    let mut frame = Vec::new();
    write_frame_versioned_reusing(w, version, msg, payload, &mut frame)
}

/// The general frame writer behind every `write_frame*` form: header +
/// payload assembled in `frame` (one `write_all`, one syscall with
/// nodelay).
///
/// Rejects payloads over [`MAX_FRAME_LEN`] *before* sending: the peer
/// would drop the connection at the header (it cannot trust the
/// framing), which surfaces as an opaque reset mid-write — and a
/// payload over `u32::MAX` would silently wrap the length field.
pub fn write_frame_versioned_reusing(
    w: &mut impl Write,
    version: u16,
    msg: u8,
    payload: &[u8],
    frame: &mut Vec<u8>,
) -> std::io::Result<()> {
    if payload.len() > MAX_FRAME_LEN as usize {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidInput,
            format!(
                "frame payload is {} bytes, protocol cap is {} — split the \
                 batch (e.g. smaller n_b per Ingest)",
                payload.len(),
                MAX_FRAME_LEN
            ),
        ));
    }
    frame.clear();
    frame.reserve(FRAME_HEADER_LEN + payload.len());
    frame.extend_from_slice(&FrameHeader::encode(
        version,
        msg,
        payload.len() as u32,
    ));
    frame.extend_from_slice(payload);
    w.write_all(frame)
}

/// Blocking frame read (client side; the server uses its own
/// idle-tolerant reader).
pub fn read_frame(
    r: &mut impl Read,
) -> std::io::Result<(FrameHeader, Vec<u8>)> {
    let mut payload = Vec::new();
    let header = read_frame_reusing(r, &mut payload)?;
    Ok((header, payload))
}

/// [`read_frame`] into the caller's reusable payload buffer (cleared
/// first, capacity kept) — no per-frame allocation in steady state.
pub fn read_frame_reusing(
    r: &mut impl Read,
    payload: &mut Vec<u8>,
) -> std::io::Result<FrameHeader> {
    let mut h = [0u8; FRAME_HEADER_LEN];
    r.read_exact(&mut h)?;
    let header = FrameHeader::parse(&h).map_err(|e| {
        std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string())
    })?;
    payload.clear();
    payload.resize(header.len as usize, 0);
    r.read_exact(payload)?;
    Ok(header)
}

/// Parameters a client supplies to open a monitored session.
#[derive(Clone, Debug, PartialEq)]
pub struct SessionSpec {
    pub name: String,
    pub layer_dims: Vec<usize>,
    pub rank: usize,
    pub beta: f64,
    pub seed: u64,
    /// Monitor diagnostic window (steps).
    pub window: usize,
    /// Stable-rank collapse threshold (fraction of k).
    pub collapse_frac: f64,
}

/// The daemon-side `MonitorConfig` for a spec — exposed so in-process
/// mirrors (tests, the probe) configure their hub identically.
pub fn monitor_config(spec: &SessionSpec) -> MonitorConfig {
    MonitorConfig {
        window: spec.window,
        collapse_frac: spec.collapse_frac,
        ..MonitorConfig::for_rank(spec.rank)
    }
}

/// Daemon-wide counters served by [`Request::Stats`].
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct DaemonStats {
    pub sessions: u64,
    pub max_sessions: u64,
    /// Total ingest payload bytes accepted since daemon start (restored
    /// sessions carry their counters across a warm restart).
    pub ingest_bytes: u64,
    /// Response frames written since daemon start (not persisted).
    pub frames_served: u64,
    /// Archive bytes currently retained across all sessions.
    pub archive_bytes: u64,
    /// Busy replies issued since daemon start (admission + quota;
    /// persisted across warm restarts). v3 field — zero when talking to
    /// a v2 peer.
    pub busy_rejections: u64,
    /// Connection shards serving this daemon (v4 field — zero when
    /// talking to a v3-or-older peer).
    pub shards: u64,
}

/// Per-session counters served by [`Request::Stats`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SessionStats {
    pub id: u64,
    pub name: String,
    pub steps_seen: u64,
    pub ingest_bytes: u64,
    pub archive_bytes: u64,
    /// Interval records currently retained in the session's archive.
    pub archive_intervals: u64,
    /// Quota-Busy rejections this session has absorbed (persisted). v3
    /// field — zero when talking to a v2 peer.
    pub busy_rejections: u64,
    /// Quota bytes consumed since the last `Diagnose` drain (v3 field).
    pub quota_used: u64,
    /// The daemon's per-session quota limit, 0 = unlimited (v3 field).
    pub quota_limit: u64,
}

/// Per-shard counters served by [`Request::Stats`] from v4 on — one row
/// per connection shard, so a client (or `loadgen`) can see how evenly
/// sessions and ingest latency spread across shards (DESIGN.md §9).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ShardStats {
    /// Shard index in `0..DaemonStats::shards`.
    pub shard: u64,
    /// Sessions currently owned by this shard.
    pub sessions: u64,
    /// Ingest frames this shard has served since daemon start.
    pub ingest_frames: u64,
    /// Ingest payload bytes this shard has accepted (persisted counters
    /// restore into shard 0 after a warm restart).
    pub ingest_bytes: u64,
    /// Ingest latency p50 in nanoseconds (0 until the first ingest).
    pub ingest_p50_ns: u64,
    /// Ingest latency p99 in nanoseconds (0 until the first ingest).
    pub ingest_p99_ns: u64,
    /// Response frames this shard has written since daemon start.
    pub frames_served: u64,
}

/// Archive shape/occupancy answered by [`Request::ArchiveInfo`] — also
/// how mirrors discover the daemon's ring parameters.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ArchiveInfo {
    pub capacity: u64,
    pub stride: u64,
    /// Retained records.
    pub intervals: u64,
    /// Ingest intervals observed (recorded + stride-skipped).
    pub seen: u64,
    pub bytes: u64,
    /// Monitored layers per record.
    pub layers: u64,
    /// Step of the oldest / newest retained record (0 when empty).
    pub oldest_step: u64,
    pub newest_step: u64,
}

/// Client -> daemon messages.
#[derive(Clone, Debug)]
pub enum Request {
    /// Handshake: announce the client; the reply carries capacity info.
    Hello { client: String },
    OpenSession(SessionSpec),
    /// One monitored training step: the daemon ingests the activations
    /// into the session's engine, derives sketch metrics and observes
    /// them (with `loss`) in the hub.  `want_recon` asks for per-layer
    /// relative reconstruction errors in the reply (costs a
    /// reconstruction per layer server-side).  `seq` (v6+) numbers the
    /// frame for crash-safe resumption: 1, 2, 3, ... per session, or 0
    /// to opt out (legacy peers and fire-and-forget probes) — the
    /// daemon dedupes replays of acked seqs and rejects gaps
    /// (DESIGN.md §11).
    Ingest {
        session: u64,
        seq: u64,
        loss: f32,
        want_recon: bool,
        acts: Vec<Mat>,
    },
    /// Push externally computed step metrics (remote-metrics mode — no
    /// activation shipping, no daemon-side engine update).
    Observe {
        session: u64,
        metrics: StepMetrics,
    },
    Diagnose { session: u64 },
    /// Force a durable snapshot now.
    Snapshot,
    Close { session: u64 },
    /// Snapshot and stop the daemon (clean remote shutdown — pure-std
    /// builds have no signal handling).
    Shutdown,
    /// Daemon-wide + per-session observability counters.
    Stats,
    /// Gradient-norm trajectory over the session's archived intervals.
    QueryTrajectory { session: u64 },
    /// Cross-step cosine similarity of one layer's archived Z sketches.
    QuerySimilarity { session: u64, layer: usize },
    /// Top-sigma / stable-rank drift of one layer across the archive.
    QueryDrift { session: u64, layer: usize },
    /// Archive shape and occupancy for a session.
    ArchiveInfo { session: u64 },
    /// Daemon observability report: counters + latency histograms
    /// (requires a v3 frame; see [`METRICS_MIN_VERSION`]).
    Metrics,
    /// Merged event-journal dump, newest `max` events (0 = all
    /// retained; requires a v5 frame, see [`OBS_MIN_VERSION`]).
    Events { max: u32 },
    /// Window-ring report + per-session sketch-health gauges (v5).
    MetricsWindow,
}

impl Request {
    pub fn msg_type(&self) -> u8 {
        match self {
            Request::Hello { .. } => msg::HELLO,
            Request::OpenSession(_) => msg::OPEN_SESSION,
            Request::Ingest { .. } => msg::INGEST,
            Request::Observe { .. } => msg::OBSERVE,
            Request::Diagnose { .. } => msg::DIAGNOSE,
            Request::Snapshot => msg::SNAPSHOT,
            Request::Close { .. } => msg::CLOSE,
            Request::Shutdown => msg::SHUTDOWN,
            Request::Stats => msg::STATS,
            Request::QueryTrajectory { .. } => msg::QUERY_TRAJECTORY,
            Request::QuerySimilarity { .. } => msg::QUERY_SIMILARITY,
            Request::QueryDrift { .. } => msg::QUERY_DRIFT,
            Request::ArchiveInfo { .. } => msg::ARCHIVE_INFO,
            Request::Metrics => msg::METRICS,
            Request::Events { .. } => msg::EVENTS,
            Request::MetricsWindow => msg::METRICS_WINDOW,
        }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut e = Enc::new();
        self.encode_into(&mut e);
        e.into_bytes()
    }

    /// Encode into a caller-owned (reusable) encoder.
    pub fn encode_into(&self, e: &mut Enc) {
        match self {
            Request::Hello { client } => e.str(client),
            Request::OpenSession(spec) => {
                e.str(&spec.name);
                e.usizes(&spec.layer_dims);
                e.len32(spec.rank);
                e.f64(spec.beta);
                e.u64(spec.seed);
                e.len32(spec.window);
                e.f64(spec.collapse_frac);
            }
            Request::Ingest {
                session,
                seq,
                loss,
                want_recon,
                acts,
            } => enc_ingest(e, *session, *seq, *loss, *want_recon, acts),
            Request::Observe { session, metrics } => {
                e.u64(*session);
                enc_step_metrics(e, metrics);
            }
            Request::Diagnose { session }
            | Request::Close { session }
            | Request::QueryTrajectory { session }
            | Request::ArchiveInfo { session } => e.u64(*session),
            Request::QuerySimilarity { session, layer }
            | Request::QueryDrift { session, layer } => {
                e.u64(*session);
                e.len32(*layer);
            }
            Request::Events { max } => e.u32(*max),
            Request::Snapshot
            | Request::Shutdown
            | Request::Stats
            | Request::Metrics
            | Request::MetricsWindow => {}
        }
    }

    pub fn decode(msg_type: u8, payload: &[u8]) -> Result<Request, CodecError> {
        Request::decode_v(msg_type, payload, PROTO_VERSION)
    }

    /// Version-aware decode; `version` is the request frame's header
    /// version (pre-v6 `Ingest` payloads carry no seq, which decodes
    /// as 0 = resume opted out).  The daemon calls this with the
    /// version parsed from each frame.
    pub fn decode_v(
        msg_type: u8,
        payload: &[u8],
        version: u16,
    ) -> Result<Request, CodecError> {
        let mut d = Dec::new(payload);
        let req = match msg_type {
            msg::HELLO => Request::Hello { client: d.str()? },
            msg::OPEN_SESSION => Request::OpenSession(SessionSpec {
                name: d.str()?,
                layer_dims: d.usizes()?,
                rank: d.u32()? as usize,
                beta: d.f64()?,
                seed: d.u64()?,
                window: d.u32()? as usize,
                collapse_frac: d.f64()?,
            }),
            msg::INGEST => {
                let session = d.u64()?;
                let loss = d.f32()?;
                let want_recon = d.bool()?;
                let n = d.len32(8)?; // a Mat is at least rows+cols
                let mut acts = Vec::with_capacity(n);
                for _ in 0..n {
                    acts.push(d.mat()?);
                }
                let seq = if version >= 6 { d.u64()? } else { 0 };
                Request::Ingest {
                    session,
                    seq,
                    loss,
                    want_recon,
                    acts,
                }
            }
            msg::OBSERVE => Request::Observe {
                session: d.u64()?,
                metrics: dec_step_metrics(&mut d)?,
            },
            msg::DIAGNOSE => Request::Diagnose { session: d.u64()? },
            msg::SNAPSHOT => Request::Snapshot,
            msg::CLOSE => Request::Close { session: d.u64()? },
            msg::SHUTDOWN => Request::Shutdown,
            msg::STATS => Request::Stats,
            msg::QUERY_TRAJECTORY => Request::QueryTrajectory {
                session: d.u64()?,
            },
            msg::QUERY_SIMILARITY => Request::QuerySimilarity {
                session: d.u64()?,
                layer: d.u32()? as usize,
            },
            msg::QUERY_DRIFT => Request::QueryDrift {
                session: d.u64()?,
                layer: d.u32()? as usize,
            },
            msg::ARCHIVE_INFO => Request::ArchiveInfo {
                session: d.u64()?,
            },
            msg::METRICS => Request::Metrics,
            msg::EVENTS => Request::Events { max: d.u32()? },
            msg::METRICS_WINDOW => Request::MetricsWindow,
            other => {
                return Err(CodecError::BadTag {
                    what: "request type",
                    tag: other,
                })
            }
        };
        d.finish()?;
        Ok(req)
    }
}

/// Daemon -> client messages.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    HelloOk {
        server: String,
        proto: u16,
        sessions: u64,
        max_sessions: u64,
    },
    SessionOpened {
        session: u64,
        /// Resume epoch (v6+; 0 from older daemons): 1 for a fresh
        /// session, bumped each time the daemon restarts with the
        /// session restored from snapshot.
        epoch: u64,
    },
    IngestOk {
        batches: u64,
        engine_bytes: u64,
        /// Per-layer relative reconstruction errors (empty unless
        /// `want_recon`).
        recon_err: Vec<f64>,
        /// Highest applied ingest seq for the session (v6+; 0 from
        /// older daemons or when the client opted out with seq 0).
        ///
        /// The ack for a *replayed* (already-applied) frame is a fresh
        /// reply, not a recording of the original: `recon_err` is
        /// empty even if the replayed frame asked for reconstruction,
        /// and `batches`/`engine_bytes` reflect the session's current
        /// — possibly later — state.
        acked_seq: u64,
    },
    ObserveOk { steps_seen: u64 },
    Diagnosis {
        diagnosis: Diagnosis,
        healthy: bool,
        steps_seen: u64,
        engine_bytes: u64,
        monitor_bytes: u64,
    },
    SnapshotOk {
        path: String,
        bytes: u64,
        sessions: u64,
    },
    Closed { session: u64 },
    /// Backpressure: admission or quota limit hit — retry after a
    /// `Diagnose` (which drains the session's quota counter).
    Busy { used: u64, limit: u64 },
    Error { code: ErrorCode, message: String },
    ShutdownOk { sessions: u64 },
    StatsOk {
        daemon: DaemonStats,
        /// Per-session counters sorted by session id.
        sessions: Vec<SessionStats>,
        /// Per-shard counters sorted by shard index (v4+ — empty when
        /// talking to a v3-or-older peer).
        shards: Vec<ShardStats>,
    },
    /// Archived gradient-norm trajectory, oldest interval first.
    Trajectory { points: Vec<TrajectoryPoint> },
    /// Cross-step cosine similarity: `steps[i]` labels row/col `i` of
    /// the dense symmetric `sim` matrix.
    Similarity { steps: Vec<u64>, sim: Mat },
    /// Spectral drift series, oldest interval first.
    Drift { points: Vec<DriftPoint> },
    ArchiveInfoOk(ArchiveInfo),
    /// Daemon observability report (v3+).
    MetricsOk(MetricsReport),
    /// Merged event-journal dump (v5+): retained events oldest first,
    /// the exact dropped total, and the journal's wall-clock base
    /// (`base_unix_ms + ts_ns / 1e6` = absolute event time).
    EventsOk {
        dropped: u64,
        base_unix_ms: u64,
        events: Vec<Event>,
    },
    /// Window-ring report + per-session sketch-health gauges (v5+).
    MetricsWindowOk {
        report: WindowReport,
        /// One row per open session, sorted by session id.
        health: Vec<SessionHealth>,
    },
}

impl Response {
    pub fn msg_type(&self) -> u8 {
        match self {
            Response::HelloOk { .. } => msg::HELLO_OK,
            Response::SessionOpened { .. } => msg::SESSION_OPENED,
            Response::IngestOk { .. } => msg::INGEST_OK,
            Response::ObserveOk { .. } => msg::OBSERVE_OK,
            Response::Diagnosis { .. } => msg::DIAGNOSIS,
            Response::SnapshotOk { .. } => msg::SNAPSHOT_OK,
            Response::Closed { .. } => msg::CLOSED,
            Response::Busy { .. } => msg::BUSY,
            Response::Error { .. } => msg::ERROR,
            Response::ShutdownOk { .. } => msg::SHUTDOWN_OK,
            Response::StatsOk { .. } => msg::STATS_OK,
            Response::Trajectory { .. } => msg::TRAJECTORY,
            Response::Similarity { .. } => msg::SIMILARITY,
            Response::Drift { .. } => msg::DRIFT,
            Response::ArchiveInfoOk(_) => msg::ARCHIVE_INFO_OK,
            Response::MetricsOk(_) => msg::METRICS_OK,
            Response::EventsOk { .. } => msg::EVENTS_OK,
            Response::MetricsWindowOk { .. } => msg::METRICS_WINDOW_OK,
        }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut e = Enc::new();
        self.encode_into(&mut e);
        e.into_bytes()
    }

    /// Encode into a caller-owned (reusable) encoder at the current
    /// protocol version.
    pub fn encode_into(&self, e: &mut Enc) {
        self.encode_into_v(e, PROTO_VERSION);
    }

    /// Version-aware encode: fields introduced after `version` are
    /// omitted entirely, because v2 decoders reject trailing payload
    /// bytes. The daemon calls this with the version echoed from the
    /// request frame.
    pub fn encode_into_v(&self, e: &mut Enc, version: u16) {
        match self {
            Response::HelloOk {
                server,
                proto,
                sessions,
                max_sessions,
            } => {
                e.str(server);
                e.u16(*proto);
                e.u64(*sessions);
                e.u64(*max_sessions);
            }
            Response::SessionOpened { session, epoch } => {
                e.u64(*session);
                if version >= 6 {
                    e.u64(*epoch);
                }
            }
            Response::IngestOk {
                batches,
                engine_bytes,
                recon_err,
                acked_seq,
            } => {
                e.u64(*batches);
                e.u64(*engine_bytes);
                e.f64s(recon_err);
                if version >= 6 {
                    e.u64(*acked_seq);
                }
            }
            Response::ObserveOk { steps_seen } => e.u64(*steps_seen),
            Response::Diagnosis {
                diagnosis,
                healthy,
                steps_seen,
                engine_bytes,
                monitor_bytes,
            } => {
                enc_diagnosis(e, diagnosis);
                e.bool(*healthy);
                e.u64(*steps_seen);
                e.u64(*engine_bytes);
                e.u64(*monitor_bytes);
            }
            Response::SnapshotOk {
                path,
                bytes,
                sessions,
            } => {
                e.str(path);
                e.u64(*bytes);
                e.u64(*sessions);
            }
            Response::Closed { session } => e.u64(*session),
            Response::Busy { used, limit } => {
                e.u64(*used);
                e.u64(*limit);
            }
            Response::Error { code, message } => {
                e.u16(code.as_u16());
                e.str(message);
            }
            Response::ShutdownOk { sessions } => e.u64(*sessions),
            Response::StatsOk {
                daemon,
                sessions,
                shards,
            } => {
                e.u64(daemon.sessions);
                e.u64(daemon.max_sessions);
                e.u64(daemon.ingest_bytes);
                e.u64(daemon.frames_served);
                e.u64(daemon.archive_bytes);
                if version >= 3 {
                    e.u64(daemon.busy_rejections);
                }
                if version >= 4 {
                    e.u64(daemon.shards);
                }
                e.len32(sessions.len());
                for s in sessions {
                    e.u64(s.id);
                    e.str(&s.name);
                    e.u64(s.steps_seen);
                    e.u64(s.ingest_bytes);
                    e.u64(s.archive_bytes);
                    e.u64(s.archive_intervals);
                    if version >= 3 {
                        e.u64(s.busy_rejections);
                        e.u64(s.quota_used);
                        e.u64(s.quota_limit);
                    }
                }
                if version >= 4 {
                    e.len32(shards.len());
                    for s in shards {
                        e.u64(s.shard);
                        e.u64(s.sessions);
                        e.u64(s.ingest_frames);
                        e.u64(s.ingest_bytes);
                        e.u64(s.ingest_p50_ns);
                        e.u64(s.ingest_p99_ns);
                        e.u64(s.frames_served);
                    }
                }
            }
            Response::Trajectory { points } => {
                e.len32(points.len());
                for p in points {
                    e.u64(p.step);
                    e.f32(p.loss);
                    e.f64s(&p.z_norms);
                }
            }
            Response::Similarity { steps, sim } => {
                e.len32(steps.len());
                for s in steps {
                    e.u64(*s);
                }
                e.mat(sim);
            }
            Response::Drift { points } => {
                e.len32(points.len());
                for p in points {
                    e.u64(p.step);
                    e.f64(p.top_sigma);
                    e.f64(p.stable_rank);
                }
            }
            Response::ArchiveInfoOk(info) => {
                e.u64(info.capacity);
                e.u64(info.stride);
                e.u64(info.intervals);
                e.u64(info.seen);
                e.u64(info.bytes);
                e.u64(info.layers);
                e.u64(info.oldest_step);
                e.u64(info.newest_step);
            }
            Response::MetricsOk(report) => {
                enc_metrics_report(e, report);
                if version >= 6 {
                    // The base report encoding is frozen at its v3
                    // shape; v6 fault counters ride after it.
                    e.u64(report.snapshot_failures);
                    e.u64(report.handler_panics);
                }
            }
            Response::EventsOk {
                dropped,
                base_unix_ms,
                events,
            } => {
                e.u64(*dropped);
                e.u64(*base_unix_ms);
                e.len32(events.len());
                for ev in events {
                    e.u64(ev.ts_ns);
                    e.u32(ev.slot);
                    e.u8(ev.kind);
                    e.u8(ev.code);
                    e.u64(ev.a);
                    e.u64(ev.b);
                }
            }
            Response::MetricsWindowOk { report, health } => {
                enc_window_report(e, report);
                e.len32(health.len());
                for h in health {
                    enc_session_health(e, h);
                }
            }
        }
    }

    pub fn decode(
        msg_type: u8,
        payload: &[u8],
    ) -> Result<Response, CodecError> {
        Response::decode_v(msg_type, payload, PROTO_VERSION)
    }

    /// Version-aware decode; `version` is the reply frame's header
    /// version (v2 replies omit the v3 `StatsOk` fields, which decode
    /// as zero).
    pub fn decode_v(
        msg_type: u8,
        payload: &[u8],
        version: u16,
    ) -> Result<Response, CodecError> {
        let mut d = Dec::new(payload);
        let resp = match msg_type {
            msg::HELLO_OK => Response::HelloOk {
                server: d.str()?,
                proto: d.u16()?,
                sessions: d.u64()?,
                max_sessions: d.u64()?,
            },
            msg::SESSION_OPENED => Response::SessionOpened {
                session: d.u64()?,
                epoch: if version >= 6 { d.u64()? } else { 0 },
            },
            msg::INGEST_OK => Response::IngestOk {
                batches: d.u64()?,
                engine_bytes: d.u64()?,
                recon_err: d.f64s()?,
                acked_seq: if version >= 6 { d.u64()? } else { 0 },
            },
            msg::OBSERVE_OK => Response::ObserveOk {
                steps_seen: d.u64()?,
            },
            msg::DIAGNOSIS => Response::Diagnosis {
                diagnosis: dec_diagnosis(&mut d)?,
                healthy: d.bool()?,
                steps_seen: d.u64()?,
                engine_bytes: d.u64()?,
                monitor_bytes: d.u64()?,
            },
            msg::SNAPSHOT_OK => Response::SnapshotOk {
                path: d.str()?,
                bytes: d.u64()?,
                sessions: d.u64()?,
            },
            msg::CLOSED => Response::Closed { session: d.u64()? },
            msg::BUSY => Response::Busy {
                used: d.u64()?,
                limit: d.u64()?,
            },
            msg::ERROR => Response::Error {
                code: ErrorCode::from_u16(d.u16()?)?,
                message: d.str()?,
            },
            msg::SHUTDOWN_OK => Response::ShutdownOk {
                sessions: d.u64()?,
            },
            msg::STATS_OK => {
                let daemon = DaemonStats {
                    sessions: d.u64()?,
                    max_sessions: d.u64()?,
                    ingest_bytes: d.u64()?,
                    frames_served: d.u64()?,
                    archive_bytes: d.u64()?,
                    busy_rejections: if version >= 3 { d.u64()? } else { 0 },
                    shards: if version >= 4 { d.u64()? } else { 0 },
                };
                let n = d.len32(8 + 4 + 8 * 4)?;
                let mut sessions = Vec::with_capacity(n);
                for _ in 0..n {
                    sessions.push(SessionStats {
                        id: d.u64()?,
                        name: d.str()?,
                        steps_seen: d.u64()?,
                        ingest_bytes: d.u64()?,
                        archive_bytes: d.u64()?,
                        archive_intervals: d.u64()?,
                        busy_rejections: if version >= 3 { d.u64()? } else { 0 },
                        quota_used: if version >= 3 { d.u64()? } else { 0 },
                        quota_limit: if version >= 3 { d.u64()? } else { 0 },
                    });
                }
                let mut shards = Vec::new();
                if version >= 4 {
                    let n = d.len32(8 * 7)?;
                    shards.reserve(n);
                    for _ in 0..n {
                        shards.push(ShardStats {
                            shard: d.u64()?,
                            sessions: d.u64()?,
                            ingest_frames: d.u64()?,
                            ingest_bytes: d.u64()?,
                            ingest_p50_ns: d.u64()?,
                            ingest_p99_ns: d.u64()?,
                            frames_served: d.u64()?,
                        });
                    }
                }
                Response::StatsOk {
                    daemon,
                    sessions,
                    shards,
                }
            }
            msg::TRAJECTORY => {
                let n = d.len32(8 + 4 + 4)?;
                let mut points = Vec::with_capacity(n);
                for _ in 0..n {
                    points.push(TrajectoryPoint {
                        step: d.u64()?,
                        loss: d.f32()?,
                        z_norms: d.f64s()?,
                    });
                }
                Response::Trajectory { points }
            }
            msg::SIMILARITY => {
                let n = d.len32(8)?;
                let mut steps = Vec::with_capacity(n);
                for _ in 0..n {
                    steps.push(d.u64()?);
                }
                Response::Similarity {
                    steps,
                    sim: d.mat()?,
                }
            }
            msg::DRIFT => {
                let n = d.len32(8 + 8 + 8)?;
                let mut points = Vec::with_capacity(n);
                for _ in 0..n {
                    points.push(DriftPoint {
                        step: d.u64()?,
                        top_sigma: d.f64()?,
                        stable_rank: d.f64()?,
                    });
                }
                Response::Drift { points }
            }
            msg::ARCHIVE_INFO_OK => Response::ArchiveInfoOk(ArchiveInfo {
                capacity: d.u64()?,
                stride: d.u64()?,
                intervals: d.u64()?,
                seen: d.u64()?,
                bytes: d.u64()?,
                layers: d.u64()?,
                oldest_step: d.u64()?,
                newest_step: d.u64()?,
            }),
            msg::METRICS_OK => {
                let mut report = dec_metrics_report(&mut d)?;
                if version >= 6 {
                    report.snapshot_failures = d.u64()?;
                    report.handler_panics = d.u64()?;
                }
                Response::MetricsOk(report)
            }
            msg::EVENTS_OK => {
                let dropped = d.u64()?;
                let base_unix_ms = d.u64()?;
                let n = d.len32(8 + 4 + 1 + 1 + 8 + 8)?;
                let mut events = Vec::with_capacity(n);
                for _ in 0..n {
                    events.push(Event {
                        ts_ns: d.u64()?,
                        slot: d.u32()?,
                        kind: d.u8()?,
                        code: d.u8()?,
                        a: d.u64()?,
                        b: d.u64()?,
                    });
                }
                Response::EventsOk {
                    dropped,
                    base_unix_ms,
                    events,
                }
            }
            msg::METRICS_WINDOW_OK => {
                let report = dec_window_report(&mut d)?;
                let n = d.len32(8 + 4 + 4)?;
                let mut health = Vec::with_capacity(n);
                for _ in 0..n {
                    health.push(dec_session_health(&mut d)?);
                }
                Response::MetricsWindowOk { report, health }
            }
            other => {
                return Err(CodecError::BadTag {
                    what: "response type",
                    tag: other,
                })
            }
        };
        d.finish()?;
        Ok(resp)
    }
}

/// Encode an `Ingest` request payload straight from borrowed
/// activations — the client's hot path uses this (through its reusable
/// encoder) so a monitored step never clones the activation matrices
/// just to build the frame.  This is the v6 payload shape (trailing
/// `seq`); use [`enc_ingest_v`] when talking to an older daemon.
pub fn enc_ingest(
    e: &mut Enc,
    session: u64,
    seq: u64,
    loss: f32,
    want_recon: bool,
    acts: &[Mat],
) {
    enc_ingest_v(e, session, seq, loss, want_recon, acts, PROTO_VERSION)
}

/// [`enc_ingest`] at an explicit negotiated frame version: pre-v6
/// peers reject trailing payload bytes, so `seq` is omitted (the
/// session simply cannot resume across a daemon of that vintage).
pub fn enc_ingest_v(
    e: &mut Enc,
    session: u64,
    seq: u64,
    loss: f32,
    want_recon: bool,
    acts: &[Mat],
    version: u16,
) {
    e.u64(session);
    e.f32(loss);
    e.bool(want_recon);
    e.len32(acts.len());
    for a in acts {
        e.mat(a);
    }
    if version >= 6 {
        e.u64(seq);
    }
}

pub fn enc_step_metrics(e: &mut Enc, m: &StepMetrics) {
    e.f32(m.loss);
    e.f32(m.accuracy);
    e.f32s(&m.z_norm);
    e.f32s(&m.stable_rank);
    e.f32s(&m.y_norm);
    e.f32s(&m.x_norm);
    e.f32s(&m.grad_norm);
    e.f32(m.pde_mse);
    e.f32(m.bc_mse);
}

pub fn dec_step_metrics(d: &mut Dec) -> Result<StepMetrics, CodecError> {
    Ok(StepMetrics {
        loss: d.f32()?,
        accuracy: d.f32()?,
        z_norm: d.f32s()?,
        stable_rank: d.f32s()?,
        y_norm: d.f32s()?,
        x_norm: d.f32s()?,
        grad_norm: d.f32s()?,
        pde_mse: d.f32()?,
        bc_mse: d.f32()?,
    })
}

pub fn enc_diagnosis(e: &mut Enc, d: &Diagnosis) {
    e.bool(d.vanishing_gradients);
    e.bool(d.exploding_gradients);
    e.bool(d.stagnation);
    e.bool(d.diversity_collapse);
    e.f64(d.mean_stable_rank_frac);
    e.len32(d.notes.len());
    for n in &d.notes {
        e.str(n);
    }
}

pub fn dec_diagnosis(d: &mut Dec) -> Result<Diagnosis, CodecError> {
    let vanishing_gradients = d.bool()?;
    let exploding_gradients = d.bool()?;
    let stagnation = d.bool()?;
    let diversity_collapse = d.bool()?;
    let mean_stable_rank_frac = d.f64()?;
    let n = d.len32(4)?;
    let mut notes = Vec::with_capacity(n);
    for _ in 0..n {
        notes.push(d.str()?);
    }
    Ok(Diagnosis {
        vanishing_gradients,
        exploding_gradients,
        stagnation,
        diversity_collapse,
        mean_stable_rank_frac,
        notes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn spec() -> SessionSpec {
        SessionSpec {
            name: "run0".into(),
            layer_dims: vec![128, 64, 32],
            rank: 4,
            beta: 0.9,
            seed: 42,
            window: 25,
            collapse_frac: 0.25,
        }
    }

    fn roundtrip_req(req: &Request) -> Request {
        Request::decode(req.msg_type(), &req.encode()).unwrap()
    }

    fn roundtrip_resp(resp: &Response) -> Response {
        Response::decode(resp.msg_type(), &resp.encode()).unwrap()
    }

    #[test]
    fn request_roundtrips() {
        match roundtrip_req(&Request::Hello {
            client: "cli".into(),
        }) {
            Request::Hello { client } => assert_eq!(client, "cli"),
            other => panic!("{other:?}"),
        }
        match roundtrip_req(&Request::OpenSession(spec())) {
            Request::OpenSession(s) => assert_eq!(s, spec()),
            other => panic!("{other:?}"),
        }
        let mut rng = Rng::new(1);
        let acts = vec![Mat::gaussian(4, 8, &mut rng), Mat::gaussian(4, 6, &mut rng)];
        match roundtrip_req(&Request::Ingest {
            session: 3,
            seq: 12,
            loss: 0.25,
            want_recon: true,
            acts: acts.clone(),
        }) {
            Request::Ingest {
                session,
                seq,
                loss,
                want_recon,
                acts: back,
            } => {
                assert_eq!((session, seq, loss), (3, 12, 0.25));
                assert!(want_recon);
                assert_eq!(back.len(), 2);
                assert_eq!(back[0].max_abs_diff(&acts[0]), 0.0);
                assert_eq!(back[1].max_abs_diff(&acts[1]), 0.0);
            }
            other => panic!("{other:?}"),
        }
        let m = StepMetrics {
            loss: 1.5,
            z_norm: vec![2.0, 3.0],
            stable_rank: vec![4.0],
            ..Default::default()
        };
        match roundtrip_req(&Request::Observe {
            session: 9,
            metrics: m.clone(),
        }) {
            Request::Observe { session, metrics } => {
                assert_eq!(session, 9);
                assert_eq!(metrics.loss, m.loss);
                assert_eq!(metrics.z_norm, m.z_norm);
                assert_eq!(metrics.stable_rank, m.stable_rank);
            }
            other => panic!("{other:?}"),
        }
        assert!(matches!(
            roundtrip_req(&Request::Diagnose { session: 7 }),
            Request::Diagnose { session: 7 }
        ));
        assert!(matches!(
            roundtrip_req(&Request::Snapshot),
            Request::Snapshot
        ));
        assert!(matches!(
            roundtrip_req(&Request::Close { session: 2 }),
            Request::Close { session: 2 }
        ));
        assert!(matches!(
            roundtrip_req(&Request::Shutdown),
            Request::Shutdown
        ));
        assert!(matches!(roundtrip_req(&Request::Stats), Request::Stats));
        assert!(matches!(
            roundtrip_req(&Request::QueryTrajectory { session: 6 }),
            Request::QueryTrajectory { session: 6 }
        ));
        assert!(matches!(
            roundtrip_req(&Request::QuerySimilarity {
                session: 6,
                layer: 2
            }),
            Request::QuerySimilarity {
                session: 6,
                layer: 2
            }
        ));
        assert!(matches!(
            roundtrip_req(&Request::QueryDrift {
                session: 8,
                layer: 0
            }),
            Request::QueryDrift {
                session: 8,
                layer: 0
            }
        ));
        assert!(matches!(
            roundtrip_req(&Request::ArchiveInfo { session: 4 }),
            Request::ArchiveInfo { session: 4 }
        ));
        assert!(matches!(roundtrip_req(&Request::Metrics), Request::Metrics));
        assert!(matches!(
            roundtrip_req(&Request::Events { max: 50 }),
            Request::Events { max: 50 }
        ));
        assert!(matches!(
            roundtrip_req(&Request::MetricsWindow),
            Request::MetricsWindow
        ));
    }

    #[test]
    fn response_roundtrips() {
        let rs = [
            Response::HelloOk {
                server: "sketchd/0.2".into(),
                proto: PROTO_VERSION,
                sessions: 2,
                max_sessions: 16,
            },
            Response::SessionOpened {
                session: 5,
                epoch: 2,
            },
            Response::IngestOk {
                batches: 10,
                engine_bytes: 4096,
                recon_err: vec![0.5, 0.25],
                acked_seq: 10,
            },
            Response::ObserveOk { steps_seen: 3 },
            Response::Diagnosis {
                diagnosis: Diagnosis {
                    stagnation: true,
                    diversity_collapse: true,
                    mean_stable_rank_frac: 0.322,
                    notes: vec!["stable rank 2.9 of k=9".into()],
                    ..Default::default()
                },
                healthy: false,
                steps_seen: 120,
                engine_bytes: 1000,
                monitor_bytes: 2000,
            },
            Response::SnapshotOk {
                path: "/tmp/s.bin".into(),
                bytes: 999,
                sessions: 1,
            },
            Response::Closed { session: 4 },
            Response::Busy {
                used: 900,
                limit: 1000,
            },
            Response::Error {
                code: ErrorCode::UnknownSession,
                message: "no session s9".into(),
            },
            Response::ShutdownOk { sessions: 2 },
            Response::StatsOk {
                daemon: DaemonStats {
                    sessions: 2,
                    max_sessions: 16,
                    ingest_bytes: 123456,
                    frames_served: 789,
                    archive_bytes: 4096,
                    busy_rejections: 5,
                    shards: 2,
                },
                sessions: vec![
                    SessionStats {
                        id: 1,
                        name: "run0".into(),
                        steps_seen: 40,
                        ingest_bytes: 100000,
                        archive_bytes: 2048,
                        archive_intervals: 8,
                        busy_rejections: 3,
                        quota_used: 51200,
                        quota_limit: 65536,
                    },
                    SessionStats::default(),
                ],
                shards: vec![
                    ShardStats {
                        shard: 0,
                        sessions: 1,
                        ingest_frames: 40,
                        ingest_bytes: 100000,
                        ingest_p50_ns: 1_000,
                        ingest_p99_ns: 9_000,
                        frames_served: 400,
                    },
                    ShardStats {
                        shard: 1,
                        sessions: 1,
                        ingest_frames: 0,
                        ingest_bytes: 23456,
                        ingest_p50_ns: 0,
                        ingest_p99_ns: 0,
                        frames_served: 389,
                    },
                ],
            },
            Response::Trajectory {
                points: vec![
                    TrajectoryPoint {
                        step: 1,
                        loss: 0.5,
                        z_norms: vec![1.5, 2.5],
                    },
                    TrajectoryPoint {
                        step: 2,
                        loss: 0.25,
                        z_norms: vec![0.0, 3.5],
                    },
                ],
            },
            Response::Similarity {
                steps: vec![1, 2],
                sim: Mat::from_vec(2, 2, vec![1.0, 0.5, 0.5, 1.0]),
            },
            Response::Drift {
                points: vec![DriftPoint {
                    step: 3,
                    top_sigma: 2.0,
                    stable_rank: 1.5,
                }],
            },
            Response::ArchiveInfoOk(ArchiveInfo {
                capacity: 64,
                stride: 2,
                intervals: 8,
                seen: 15,
                bytes: 8192,
                layers: 3,
                oldest_step: 1,
                newest_step: 15,
            }),
            Response::MetricsOk(sample_metrics_report()),
            Response::EventsOk {
                dropped: 3,
                base_unix_ms: 1_754_600_000_000,
                events: vec![
                    Event {
                        ts_ns: 1_000_000,
                        slot: 0,
                        kind: crate::serve::obs::events::kind::SESSION_OPEN,
                        code: 0,
                        a: 7,
                        b: 0,
                    },
                    Event {
                        ts_ns: 2_000_000,
                        slot: 2,
                        kind: crate::serve::obs::events::kind::SLOW_REQUEST,
                        code: msg::INGEST,
                        a: 300_000_000,
                        b: 0,
                    },
                ],
            },
            Response::MetricsWindowOk {
                report: WindowReport {
                    interval_ms: 1000,
                    capacity: 120,
                    ..WindowReport::default()
                },
                health: vec![SessionHealth {
                    session: 1,
                    name: "run0".into(),
                    layers: vec![crate::serve::obs::LayerHealth {
                        z_norm: 2.0,
                        top_sigma: 1.5,
                        stable_rank: 16.0 / 9.0,
                    }],
                }],
            },
        ];
        for r in &rs {
            assert_eq!(&roundtrip_resp(r), r, "{r:?}");
        }
    }

    fn sample_metrics_report() -> MetricsReport {
        let mut h = crate::serve::metrics::Histogram::new();
        for ns in [900u64, 40_000, 2_000_000] {
            h.record(ns);
        }
        MetricsReport {
            uptime_ms: 60_000,
            sessions_open: 2,
            sessions_peak: 4,
            sessions_opened: 9,
            ingest_bytes: 1 << 24,
            frames_served: 5000,
            busy_admission: 1,
            busy_quota: 7,
            snapshot_count: 3,
            snapshot_pause_ns: 9_000_000,
            snapshot_failures: 1,
            handler_panics: 2,
            ingest: h.clone(),
            diagnose: crate::serve::metrics::Histogram::new(),
            query: h,
        }
    }

    /// Older peers must receive a `StatsOk` without the newer fields
    /// (their decoders reject trailing bytes), and an old payload must
    /// decode with those fields zeroed/empty.
    #[test]
    fn stats_ok_versioned_encoding() {
        let full = Response::StatsOk {
            daemon: DaemonStats {
                sessions: 1,
                max_sessions: 8,
                ingest_bytes: 777,
                frames_served: 42,
                archive_bytes: 512,
                busy_rejections: 6,
                shards: 2,
            },
            sessions: vec![SessionStats {
                id: 3,
                name: "t".into(),
                steps_seen: 10,
                ingest_bytes: 700,
                archive_bytes: 256,
                archive_intervals: 4,
                busy_rejections: 2,
                quota_used: 100,
                quota_limit: 1000,
            }],
            shards: vec![
                ShardStats {
                    shard: 0,
                    sessions: 1,
                    ingest_frames: 10,
                    ingest_bytes: 700,
                    ingest_p50_ns: 2_000,
                    ingest_p99_ns: 8_000,
                    frames_served: 30,
                },
                ShardStats {
                    shard: 1,
                    ..ShardStats::default()
                },
            ],
        };
        let enc_at = |version| {
            let mut e = Enc::new();
            full.encode_into_v(&mut e, version);
            e.into_bytes()
        };
        let v2_bytes = enc_at(2);
        // A strict v2 decode (finish() included) accepts the payload...
        let back = Response::decode_v(msg::STATS_OK, &v2_bytes, 2).unwrap();
        match back {
            Response::StatsOk {
                daemon,
                sessions,
                shards,
            } => {
                assert_eq!(daemon.ingest_bytes, 777);
                assert_eq!(daemon.busy_rejections, 0, "v3 field dropped at v2");
                assert_eq!(daemon.shards, 0, "v4 field dropped at v2");
                assert_eq!(sessions[0].steps_seen, 10);
                assert_eq!(sessions[0].busy_rejections, 0);
                assert_eq!(sessions[0].quota_limit, 0);
                assert!(shards.is_empty(), "v4 rows dropped at v2");
            }
            other => panic!("{other:?}"),
        }
        // ...and mistaking a payload for a different version is a typed
        // decode error, never a panic.
        assert!(Response::decode_v(msg::STATS_OK, &v2_bytes, 3).is_err());
        let v3_bytes = enc_at(3);
        assert!(v3_bytes.len() > v2_bytes.len());
        match Response::decode_v(msg::STATS_OK, &v3_bytes, 3).unwrap() {
            Response::StatsOk {
                daemon,
                sessions,
                shards,
            } => {
                assert_eq!(daemon.busy_rejections, 6, "v3 field survives");
                assert_eq!(daemon.shards, 0, "v4 field dropped at v3");
                assert_eq!(sessions[0].quota_limit, 1000);
                assert!(shards.is_empty(), "v4 rows dropped at v3");
            }
            other => panic!("{other:?}"),
        }
        assert!(Response::decode_v(msg::STATS_OK, &v3_bytes, 2).is_err());
        assert!(Response::decode_v(msg::STATS_OK, &v3_bytes, 4).is_err());
        let v4_bytes = enc_at(4);
        assert!(v4_bytes.len() > v3_bytes.len());
        assert_eq!(
            Response::decode_v(msg::STATS_OK, &v4_bytes, 4).unwrap(),
            full
        );
        assert!(Response::decode_v(msg::STATS_OK, &v4_bytes, 3).is_err());
    }

    /// The v6 resume fields (`Ingest.seq`, `SessionOpened.epoch`,
    /// `IngestOk.acked_seq`, the `MetricsOk` fault counters) are
    /// encoded only on v6 frames; older payloads decode with them
    /// zeroed, and mixing versions is a typed error, never a panic.
    #[test]
    fn resume_fields_versioned_encoding() {
        // Ingest request: v5 payloads carry no seq.
        let mut rng = Rng::new(2);
        let acts = vec![Mat::gaussian(2, 3, &mut rng)];
        let mut e = Enc::new();
        enc_ingest_v(&mut e, 7, 42, 0.5, false, &acts, 5);
        let v5_req = e.into_bytes();
        let mut e = Enc::new();
        enc_ingest_v(&mut e, 7, 42, 0.5, false, &acts, 6);
        let v6_req = e.into_bytes();
        assert_eq!(v6_req.len(), v5_req.len() + 8, "seq is 8 bytes");
        match Request::decode_v(msg::INGEST, &v5_req, 5).unwrap() {
            Request::Ingest { session, seq, .. } => {
                assert_eq!((session, seq), (7, 0), "seq zeroed at v5");
            }
            other => panic!("{other:?}"),
        }
        match Request::decode_v(msg::INGEST, &v6_req, 6).unwrap() {
            Request::Ingest { seq, .. } => assert_eq!(seq, 42),
            other => panic!("{other:?}"),
        }
        assert!(Request::decode_v(msg::INGEST, &v6_req, 5).is_err());
        assert!(Request::decode_v(msg::INGEST, &v5_req, 6).is_err());

        // SessionOpened / IngestOk / MetricsOk responses.
        let cases = [
            Response::SessionOpened {
                session: 9,
                epoch: 4,
            },
            Response::IngestOk {
                batches: 3,
                engine_bytes: 64,
                recon_err: vec![],
                acked_seq: 17,
            },
            Response::MetricsOk(sample_metrics_report()),
        ];
        for full in &cases {
            let enc_at = |version| {
                let mut e = Enc::new();
                full.encode_into_v(&mut e, version);
                e.into_bytes()
            };
            let v5 = enc_at(5);
            let v6 = enc_at(6);
            assert!(v6.len() > v5.len(), "{full:?}");
            assert_eq!(
                &Response::decode_v(full.msg_type(), &v6, 6).unwrap(),
                full
            );
            assert!(
                Response::decode_v(full.msg_type(), &v6, 5).is_err(),
                "trailing v6 bytes rejected at v5"
            );
            // A v5 payload decodes with the v6 fields zeroed.
            let back = Response::decode_v(full.msg_type(), &v5, 5).unwrap();
            match back {
                Response::SessionOpened { session, epoch } => {
                    assert_eq!((session, epoch), (9, 0));
                }
                Response::IngestOk {
                    batches, acked_seq, ..
                } => {
                    assert_eq!((batches, acked_seq), (3, 0));
                }
                Response::MetricsOk(r) => {
                    assert_eq!(r.snapshot_failures, 0);
                    assert_eq!(r.handler_panics, 0);
                    assert_eq!(r.snapshot_count, 3, "base fields kept");
                }
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn frame_header_roundtrip_and_guards() {
        let h = FrameHeader::encode(PROTO_VERSION, msg::INGEST, 1234);
        let back = FrameHeader::parse(&h).unwrap();
        assert_eq!(
            back,
            FrameHeader {
                version: PROTO_VERSION,
                msg: msg::INGEST,
                len: 1234
            }
        );
        // Bad magic.
        let mut bad = h;
        bad[0] = b'X';
        assert!(FrameHeader::parse(&bad).is_err());
        // Oversized payload claim.
        let huge = FrameHeader::encode(PROTO_VERSION, msg::INGEST, u32::MAX);
        assert!(FrameHeader::parse(&huge).is_err());
    }

    #[test]
    fn frame_io_roundtrip() {
        let req = Request::Diagnose { session: 11 };
        let mut buf = Vec::new();
        write_frame(&mut buf, req.msg_type(), &req.encode()).unwrap();
        let mut r = std::io::Cursor::new(buf);
        let (header, payload) = read_frame(&mut r).unwrap();
        assert_eq!(header.version, PROTO_VERSION);
        assert_eq!(header.msg, msg::DIAGNOSE);
        assert!(matches!(
            Request::decode(header.msg, &payload).unwrap(),
            Request::Diagnose { session: 11 }
        ));
    }

    #[test]
    fn write_frame_rejects_oversized_payloads() {
        let payload = vec![0u8; MAX_FRAME_LEN as usize + 1];
        let mut sink = Vec::new();
        let err =
            write_frame(&mut sink, msg::INGEST, &payload).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidInput);
        assert!(sink.is_empty(), "nothing may reach the wire");
    }

    #[test]
    fn decode_rejects_unknown_types_and_trailing_bytes() {
        assert!(Request::decode(200, &[]).is_err());
        assert!(Response::decode(1, &[]).is_err());
        let mut payload = Request::Diagnose { session: 1 }.encode();
        payload.push(0xFF);
        assert!(matches!(
            Request::decode(msg::DIAGNOSE, &payload),
            Err(CodecError::Trailing(1))
        ));
    }
}
