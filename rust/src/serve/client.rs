//! Blocking client for the sketchd daemon, plus the deterministic
//! `--probe` / `--probe-resume` drivers behind `sketchgrad connect` and
//! the CI `archive-smoke` job.
//!
//! Every method sends one request frame and reads one response frame;
//! `Busy` and remote protocol errors surface as typed [`Error`]
//! variants so callers (and the backpressure tests) can branch on them.
//!
//! Session-scoped traffic goes through an owned [`SessionHandle`]
//! returned by [`SketchClient::open_session`] (or re-adopted with
//! [`SketchClient::session`] after a resume): the handle carries the
//! session id so callers stop threading raw u64 ids through every
//! call.  The id-threading methods on [`SketchClient`] remain one
//! release as deprecated shims.
//!
//! Connection establishment honours a [`ClientConfig`]: a connect
//! timeout, bounded retry-with-backoff (with seeded full jitter so a
//! thundering herd of restarting clients decorrelates), and a socket
//! read/write timeout so a hung daemon yields [`Error::Timeout`]
//! instead of blocking the caller forever.
//! [`SketchClient::connect_with`] negotiates the protocol version: it
//! speaks [`PROTO_VERSION`] first and, if the daemon rejects it as
//! unsupported, reconnects once at [`PROTO_MIN_VERSION`].
//!
//! Crash-safe ingest rides on [`ResumableSession`] (proto v6): every
//! ingest carries a monotonically increasing client sequence number and
//! is retained in a bounded replay ring — deliberately *past* the live
//! ack, since a crash rolls the daemon's acked seq back to its last
//! snapshot.  When the transport fails mid-run — daemon killed, frame
//! torn, socket timeout — the session reconnects and replays the ring
//! in order; the daemon dedupes already-applied frames by seq, so a
//! daemon kill→restart is invisible to the training loop.  An error
//! *reply* (Busy backpressure, an Invalid rejection) instead rolls the
//! frame back — the daemon guarantees it applied nothing — so the seq
//! is reused on retry and backpressure never wedges the session.

use std::collections::hash_map::DefaultHasher;
use std::collections::VecDeque;
use std::hash::{Hash, Hasher};
use std::io;
use std::net::{TcpStream, ToSocketAddrs};
use std::thread;
use std::time::Duration;

use anyhow::{ensure, Context, Result};

use crate::archive::{DriftPoint, SessionArchive, TrajectoryPoint};
use crate::config::ClientConfig;
use crate::coordinator::StepMetrics;
use crate::data::ActStream;
use crate::monitor::{step_metrics, MonitorHub, SessionId};
use crate::sketch::{Mat, SketchConfig, SketchEngine, Sketcher};
use crate::util::rng::Rng;

use super::codec::Enc;
use super::daemon::recon_errors;
use super::error::Error;
use super::metrics::MetricsReport;
use super::obs::{Event, SessionHealth, WindowReport};
use super::proto::{
    self, monitor_config, read_frame_reusing,
    write_frame_versioned_reusing, ArchiveInfo, DaemonStats, Request,
    Response, SessionSpec, SessionStats, ShardStats, METRICS_MIN_VERSION,
    OBS_MIN_VERSION, PROTO_MIN_VERSION, PROTO_VERSION,
};

/// Capacity info from the `Hello` handshake.
#[derive(Clone, Debug)]
pub struct ServerInfo {
    pub server: String,
    pub proto: u16,
    pub sessions: u64,
    pub max_sessions: u64,
}

/// One `Ingest` acknowledgement.
#[derive(Clone, Debug)]
pub struct IngestReply {
    pub batches: u64,
    pub engine_bytes: u64,
    pub recon_err: Vec<f64>,
    /// Highest client sequence number the daemon has applied for this
    /// session (0 on pre-v6 connections or seq-less ingests).  The ack
    /// for a frame the daemon had already applied (a replay after
    /// reconnect) is a fresh reply: `recon_err` comes back empty even
    /// if the frame asked for reconstruction, and `batches` /
    /// `engine_bytes` reflect the session's current state.
    pub acked_seq: u64,
}

/// One `Diagnose` reply.
#[derive(Clone, Debug)]
pub struct DiagnoseReply {
    pub diagnosis: crate::monitor::Diagnosis,
    pub healthy: bool,
    pub steps_seen: u64,
    pub engine_bytes: u64,
    pub monitor_bytes: u64,
}

/// One `Events` reply: the daemon's merged event journal, newest-last.
#[derive(Clone, Debug)]
pub struct EventsReply {
    /// Events overwritten before they could ever be read (exact count).
    pub dropped: u64,
    /// Unix epoch milliseconds at daemon start; add `ts_ns` to place an
    /// event on the wall clock.
    pub base_unix_ms: u64,
    pub events: Vec<Event>,
}

/// One `MetricsWindow` reply: the windowed time-series ring plus the
/// per-session sketch-health gauges captured at the same instant.
#[derive(Clone, Debug)]
pub struct MetricsWindowReply {
    pub report: WindowReport,
    pub health: Vec<SessionHealth>,
}

/// One `Stats` reply: daemon-wide counters, one row per session, and
/// (against a v4 daemon) one row per connection shard.  `shards` is
/// empty when the connection negotiated v3 or older.
#[derive(Clone, Debug)]
pub struct StatsReply {
    pub daemon: DaemonStats,
    pub sessions: Vec<SessionStats>,
    pub shards: Vec<ShardStats>,
}

/// Blocking sketchd client over one TCP connection.  Request encoding,
/// frame assembly and response payloads all run through per-connection
/// reusable buffers, so a monitored step's round trip allocates no
/// fresh frame buffers in steady state.
pub struct SketchClient {
    stream: TcpStream,
    /// Protocol version negotiated for this connection; every request
    /// frame carries it and replies are decoded against the version the
    /// daemon echoes back.
    version: u16,
    /// Daemon address and net config retained for [`Self::reconnect`].
    addr: String,
    net: ClientConfig,
    enc: Enc,
    frame: Vec<u8>,
    payload: Vec<u8>,
}

/// Errors worth another connect attempt: the daemon isn't up yet
/// (refused) or the connect deadline expired (transient under load).
fn retryable_connect(e: &io::Error) -> bool {
    matches!(
        e.kind(),
        io::ErrorKind::ConnectionRefused
            | io::ErrorKind::TimedOut
            | io::ErrorKind::WouldBlock
    )
}

/// Full jitter over the upper half of the backoff window: a uniform
/// draw in `[backoff/2, backoff]`.  Keeps the expected wait close to
/// the nominal schedule while decorrelating clients that all observed
/// the same daemon crash at the same instant.
fn jittered(backoff: Duration, rng: &mut Rng) -> Duration {
    let ns = backoff.as_nanos().min(u64::MAX as u128) as u64;
    let half = ns / 2;
    Duration::from_nanos(half + rng.below(half.max(1) + 1))
}

/// Deterministic per-(addr, thread) jitter seed, so retry timing is
/// reproducible within a worker but distinct across the fleet.
fn jitter_seed(addr: &str) -> u64 {
    let mut h = DefaultHasher::new();
    addr.hash(&mut h);
    thread::current().id().hash(&mut h);
    h.finish()
}

/// Open the TCP stream per `net`: connect timeout (0 = OS default),
/// bounded retries with doubling backoff (capped at 1s, jittered), and
/// socket read/write timeouts (0 = block forever).
fn connect_stream(
    addr: &str,
    net: &ClientConfig,
) -> Result<TcpStream, Error> {
    let connect_timeout = Duration::from_millis(net.connect_timeout_ms);
    let mut backoff = Duration::from_millis(net.retry_backoff_ms.max(1));
    let mut rng = Rng::new(jitter_seed(addr));
    let mut last: Option<io::Error> = None;
    for attempt in 0..=net.connect_retries {
        if attempt > 0 {
            thread::sleep(jittered(backoff, &mut rng));
            backoff = (backoff * 2).min(Duration::from_millis(1000));
        }
        let conn = if connect_timeout.is_zero() {
            TcpStream::connect(addr)
        } else {
            // `connect_timeout` needs a resolved SocketAddr.
            match addr.to_socket_addrs().map(|mut it| it.next()) {
                Ok(Some(sa)) => {
                    TcpStream::connect_timeout(&sa, connect_timeout)
                }
                Ok(None) => Err(io::Error::new(
                    io::ErrorKind::InvalidInput,
                    format!("no address found for {addr}"),
                )),
                Err(e) => Err(e),
            }
        };
        match conn {
            Ok(stream) => {
                stream.set_nodelay(true)?;
                if net.io_timeout_ms > 0 {
                    let t = Duration::from_millis(net.io_timeout_ms);
                    stream.set_read_timeout(Some(t))?;
                    stream.set_write_timeout(Some(t))?;
                }
                return Ok(stream);
            }
            Err(e) if retryable_connect(&e) => last = Some(e),
            Err(e) => return Err(e.into()),
        }
    }
    Err(last.map(Error::from).unwrap_or_else(|| {
        Error::Io(io::Error::new(
            io::ErrorKind::ConnectionRefused,
            "connect failed",
        ))
    }))
}

impl SketchClient {
    /// Connect with default [`ClientConfig`] timeouts and complete the
    /// `Hello` handshake.
    pub fn connect(addr: &str) -> Result<(SketchClient, ServerInfo), Error> {
        SketchClient::connect_with(addr, &ClientConfig::default())
    }

    /// Connect per `net` and complete the `Hello` handshake, negotiating
    /// the protocol version downward if the daemon is older.  A version
    /// rejection is fatal per-connection (the daemon closes the socket
    /// after replying), so the downgrade retry reconnects.
    pub fn connect_with(
        addr: &str,
        net: &ClientConfig,
    ) -> Result<(SketchClient, ServerInfo), Error> {
        let stream = connect_stream(addr, net)?;
        let mut client =
            SketchClient::from_stream(stream, PROTO_VERSION, addr, net);
        let info = client.negotiate()?;
        Ok((client, info))
    }

    fn from_stream(
        stream: TcpStream,
        version: u16,
        addr: &str,
        net: &ClientConfig,
    ) -> SketchClient {
        SketchClient {
            stream,
            version,
            addr: addr.to_string(),
            net: net.clone(),
            enc: Enc::new(),
            frame: Vec::new(),
            payload: Vec::new(),
        }
    }

    /// Complete the `Hello` handshake on the current stream, downgrading
    /// to [`PROTO_MIN_VERSION`] over a fresh connection if the daemon
    /// rejects [`PROTO_VERSION`] (a version rejection is fatal
    /// per-connection: the daemon closes the socket after replying).
    fn negotiate(&mut self) -> Result<ServerInfo, Error> {
        match self.hello() {
            Ok(info) => Ok(info),
            Err(Error::UnsupportedVersion(_))
                if PROTO_MIN_VERSION < PROTO_VERSION =>
            {
                self.stream = connect_stream(&self.addr, &self.net)?;
                self.version = PROTO_MIN_VERSION;
                self.hello()
            }
            Err(e) => Err(e),
        }
    }

    /// Tear down the current stream and re-establish the connection to
    /// the same daemon address (full connect retry/backoff schedule,
    /// fresh `Hello` negotiation).  Session state lives daemon-side, so
    /// ids held by [`SessionHandle`]s stay valid across the reconnect.
    pub fn reconnect(&mut self) -> Result<ServerInfo, Error> {
        self.stream = connect_stream(&self.addr, &self.net)?;
        self.version = PROTO_VERSION;
        self.negotiate()
    }

    /// The protocol version this connection negotiated.
    pub fn proto_version(&self) -> u16 {
        self.version
    }

    fn round_trip(&mut self, req: &Request) -> Result<Response, Error> {
        self.enc.reset();
        req.encode_into(&mut self.enc);
        self.send_encoded(req.msg_type())
    }

    /// Send whatever is in `self.enc` as a `msg` frame and read the
    /// response, mapping `Busy`/`Error` to typed failures through the
    /// single [`Error::from_code`] table.
    fn send_encoded(&mut self, msg: u8) -> Result<Response, Error> {
        write_frame_versioned_reusing(
            &mut self.stream,
            self.version,
            msg,
            self.enc.bytes(),
            &mut self.frame,
        )?;
        self.read_response()
    }

    /// Send a pre-encoded payload (the replay ring stores frames as
    /// owned byte vectors) and read the response.
    fn send_payload(
        &mut self,
        msg: u8,
        payload: &[u8],
    ) -> Result<Response, Error> {
        write_frame_versioned_reusing(
            &mut self.stream,
            self.version,
            msg,
            payload,
            &mut self.frame,
        )?;
        self.read_response()
    }

    fn read_response(&mut self) -> Result<Response, Error> {
        let header = read_frame_reusing(&mut self.stream, &mut self.payload)?;
        if !(PROTO_MIN_VERSION..=PROTO_VERSION).contains(&header.version) {
            return Err(Error::Protocol(format!(
                "response frame version {} (expected \
                 {PROTO_MIN_VERSION}..={PROTO_VERSION})",
                header.version
            )));
        }
        let resp =
            Response::decode_v(header.msg, &self.payload, header.version)
                .map_err(|e| Error::Protocol(e.to_string()))?;
        match resp {
            Response::Busy { used, limit } => {
                Err(Error::Busy { used, limit })
            }
            Response::Error { code, message } => {
                Err(Error::from_code(code, message))
            }
            other => Ok(other),
        }
    }

    fn hello(&mut self) -> Result<ServerInfo, Error> {
        match self.round_trip(&Request::Hello {
            client: concat!("sketchgrad/", env!("CARGO_PKG_VERSION"))
                .to_string(),
        })? {
            Response::HelloOk {
                server,
                proto,
                sessions,
                max_sessions,
            } => Ok(ServerInfo {
                server,
                proto,
                sessions,
                max_sessions,
            }),
            other => Err(unexpected("HelloOk", &other)),
        }
    }

    /// Open a session and return the owned [`SessionHandle`] for it.
    /// Dropping the handle does NOT close the session (sessions outlive
    /// connections by design) — call [`SessionHandle::close`], or
    /// re-adopt the id later with [`SketchClient::session`].
    pub fn open_session(
        &mut self,
        spec: &SessionSpec,
    ) -> Result<SessionHandle<'_>, Error> {
        match self.round_trip(&Request::OpenSession(spec.clone()))? {
            Response::SessionOpened { session, epoch } => Ok(SessionHandle {
                client: self,
                id: session,
                epoch,
            }),
            other => Err(unexpected("SessionOpened", &other)),
        }
    }

    /// Adopt an existing session id (e.g. one persisted across a daemon
    /// restart) as a [`SessionHandle`] on this connection.  No frame is
    /// sent; a stale id surfaces as [`Error::UnknownSession`] on the
    /// first call through the handle.
    pub fn session(&mut self, id: u64) -> SessionHandle<'_> {
        SessionHandle {
            client: self,
            id,
            epoch: 0,
        }
    }

    /// Force a durable snapshot; returns (path, file bytes, sessions).
    pub fn snapshot(&mut self) -> Result<(String, u64, u64), Error> {
        match self.round_trip(&Request::Snapshot)? {
            Response::SnapshotOk {
                path,
                bytes,
                sessions,
            } => Ok((path, bytes, sessions)),
            other => Err(unexpected("SnapshotOk", &other)),
        }
    }

    /// Snapshot + stop the daemon; returns sessions snapshotted.
    pub fn shutdown_daemon(&mut self) -> Result<u64, Error> {
        match self.round_trip(&Request::Shutdown)? {
            Response::ShutdownOk { sessions } => Ok(sessions),
            other => Err(unexpected("ShutdownOk", &other)),
        }
    }

    /// Daemon-wide, per-session and (v4) per-shard observability
    /// counters.
    pub fn stats(&mut self) -> Result<StatsReply, Error> {
        match self.round_trip(&Request::Stats)? {
            Response::StatsOk {
                daemon,
                sessions,
                shards,
            } => Ok(StatsReply {
                daemon,
                sessions,
                shards,
            }),
            other => Err(unexpected("StatsOk", &other)),
        }
    }

    /// Daemon observability report: lifetime counters plus the
    /// ingest/diagnose/query latency histograms (proto v3; a v2
    /// connection fails client-side before touching the wire).
    pub fn metrics(&mut self) -> Result<MetricsReport, Error> {
        if self.version < METRICS_MIN_VERSION {
            return Err(Error::UnsupportedVersion(format!(
                "Metrics requires proto v{METRICS_MIN_VERSION}, \
                 connection negotiated v{}",
                self.version
            )));
        }
        match self.round_trip(&Request::Metrics)? {
            Response::MetricsOk(report) => Ok(report),
            other => Err(unexpected("MetricsOk", &other)),
        }
    }

    /// Sanity check for the v5 observability ops, mirroring the
    /// `metrics()` gate: fail client-side on an older connection
    /// instead of burning a round trip on a typed rejection.
    fn require_obs(&self, op: &str) -> Result<(), Error> {
        if self.version < OBS_MIN_VERSION {
            return Err(Error::UnsupportedVersion(format!(
                "{op} requires proto v{OBS_MIN_VERSION}, connection \
                 negotiated v{}",
                self.version
            )));
        }
        Ok(())
    }

    /// Merged event-journal dump (proto v5).  `max == 0` returns every
    /// retained event; otherwise the newest `max` survive the merge.
    pub fn events(&mut self, max: u32) -> Result<EventsReply, Error> {
        self.require_obs("Events")?;
        match self.round_trip(&Request::Events { max })? {
            Response::EventsOk {
                dropped,
                base_unix_ms,
                events,
            } => Ok(EventsReply {
                dropped,
                base_unix_ms,
                events,
            }),
            other => Err(unexpected("EventsOk", &other)),
        }
    }

    /// Windowed time-series report plus per-session sketch-health
    /// gauges (proto v5).  The report's retained-bucket sums, baseline,
    /// evicted totals and open-bucket partials add up exactly to the
    /// daemon's lifetime counters at the capture instant.
    pub fn metrics_window(&mut self) -> Result<MetricsWindowReply, Error> {
        self.require_obs("MetricsWindow")?;
        match self.round_trip(&Request::MetricsWindow)? {
            Response::MetricsWindowOk { report, health } => {
                Ok(MetricsWindowReply { report, health })
            }
            other => Err(unexpected("MetricsWindowOk", &other)),
        }
    }

    // -- session-scoped wire calls (shared by SessionHandle and the
    //    deprecated id-threading shims) --------------------------------

    fn ingest_raw(
        &mut self,
        session: u64,
        loss: f32,
        acts: &[Mat],
        want_recon: bool,
    ) -> Result<IngestReply, Error> {
        self.enc.reset();
        // seq 0 opts out of resume dedup — plain handles keep the
        // legacy at-most-once semantics; use ResumableSession for
        // exactly-once across daemon restarts.
        proto::enc_ingest_v(
            &mut self.enc,
            session,
            0,
            loss,
            want_recon,
            acts,
            self.version,
        );
        match self.send_encoded(proto::msg::INGEST)? {
            Response::IngestOk {
                batches,
                engine_bytes,
                recon_err,
                acked_seq,
            } => Ok(IngestReply {
                batches,
                engine_bytes,
                recon_err,
                acked_seq,
            }),
            other => Err(unexpected("IngestOk", &other)),
        }
    }

    fn observe_raw(
        &mut self,
        session: u64,
        metrics: &StepMetrics,
    ) -> Result<u64, Error> {
        match self.round_trip(&Request::Observe {
            session,
            metrics: metrics.clone(),
        })? {
            Response::ObserveOk { steps_seen } => Ok(steps_seen),
            other => Err(unexpected("ObserveOk", &other)),
        }
    }

    fn diagnose_raw(&mut self, session: u64) -> Result<DiagnoseReply, Error> {
        match self.round_trip(&Request::Diagnose { session })? {
            Response::Diagnosis {
                diagnosis,
                healthy,
                steps_seen,
                engine_bytes,
                monitor_bytes,
            } => Ok(DiagnoseReply {
                diagnosis,
                healthy,
                steps_seen,
                engine_bytes,
                monitor_bytes,
            }),
            other => Err(unexpected("Diagnosis", &other)),
        }
    }

    fn close_raw(&mut self, session: u64) -> Result<(), Error> {
        match self.round_trip(&Request::Close { session })? {
            Response::Closed { .. } => Ok(()),
            other => Err(unexpected("Closed", &other)),
        }
    }

    fn query_trajectory_raw(
        &mut self,
        session: u64,
    ) -> Result<Vec<TrajectoryPoint>, Error> {
        match self.round_trip(&Request::QueryTrajectory { session })? {
            Response::Trajectory { points } => Ok(points),
            other => Err(unexpected("Trajectory", &other)),
        }
    }

    fn query_similarity_raw(
        &mut self,
        session: u64,
        layer: usize,
    ) -> Result<(Vec<u64>, Mat), Error> {
        match self.round_trip(&Request::QuerySimilarity { session, layer })? {
            Response::Similarity { steps, sim } => Ok((steps, sim)),
            other => Err(unexpected("Similarity", &other)),
        }
    }

    fn query_drift_raw(
        &mut self,
        session: u64,
        layer: usize,
    ) -> Result<Vec<DriftPoint>, Error> {
        match self.round_trip(&Request::QueryDrift { session, layer })? {
            Response::Drift { points } => Ok(points),
            other => Err(unexpected("Drift", &other)),
        }
    }

    fn archive_info_raw(
        &mut self,
        session: u64,
    ) -> Result<ArchiveInfo, Error> {
        match self.round_trip(&Request::ArchiveInfo { session })? {
            Response::ArchiveInfoOk(info) => Ok(info),
            other => Err(unexpected("ArchiveInfoOk", &other)),
        }
    }

    // -- deprecated id-threading shims (one release) -------------------

    /// One monitored training step against an explicit session id.
    #[deprecated(
        since = "0.3.0",
        note = "use SessionHandle::ingest via open_session()/session()"
    )]
    pub fn ingest(
        &mut self,
        session: u64,
        loss: f32,
        acts: &[Mat],
        want_recon: bool,
    ) -> Result<IngestReply, Error> {
        self.ingest_raw(session, loss, acts, want_recon)
    }

    /// Push externally computed metrics against an explicit session id.
    #[deprecated(
        since = "0.3.0",
        note = "use SessionHandle::observe via open_session()/session()"
    )]
    pub fn observe(
        &mut self,
        session: u64,
        metrics: &StepMetrics,
    ) -> Result<u64, Error> {
        self.observe_raw(session, metrics)
    }

    /// Diagnose an explicit session id.
    #[deprecated(
        since = "0.3.0",
        note = "use SessionHandle::diagnose via open_session()/session()"
    )]
    pub fn diagnose(&mut self, session: u64) -> Result<DiagnoseReply, Error> {
        self.diagnose_raw(session)
    }

    /// Close an explicit session id.
    #[deprecated(
        since = "0.3.0",
        note = "use SessionHandle::close via open_session()/session()"
    )]
    pub fn close_session(&mut self, session: u64) -> Result<(), Error> {
        self.close_raw(session)
    }

    /// Trajectory query against an explicit session id.
    #[deprecated(
        since = "0.3.0",
        note = "use SessionHandle::query_trajectory via \
                open_session()/session()"
    )]
    pub fn query_trajectory(
        &mut self,
        session: u64,
    ) -> Result<Vec<TrajectoryPoint>, Error> {
        self.query_trajectory_raw(session)
    }

    /// Similarity query against an explicit session id.
    #[deprecated(
        since = "0.3.0",
        note = "use SessionHandle::query_similarity via \
                open_session()/session()"
    )]
    pub fn query_similarity(
        &mut self,
        session: u64,
        layer: usize,
    ) -> Result<(Vec<u64>, Mat), Error> {
        self.query_similarity_raw(session, layer)
    }

    /// Drift query against an explicit session id.
    #[deprecated(
        since = "0.3.0",
        note = "use SessionHandle::query_drift via \
                open_session()/session()"
    )]
    pub fn query_drift(
        &mut self,
        session: u64,
        layer: usize,
    ) -> Result<Vec<DriftPoint>, Error> {
        self.query_drift_raw(session, layer)
    }

    /// Archive info against an explicit session id.
    #[deprecated(
        since = "0.3.0",
        note = "use SessionHandle::archive_info via \
                open_session()/session()"
    )]
    pub fn archive_info(
        &mut self,
        session: u64,
    ) -> Result<ArchiveInfo, Error> {
        self.archive_info_raw(session)
    }
}

/// Owned handle to one daemon session on one connection: every
/// session-scoped operation without threading the raw id.  Obtained
/// from [`SketchClient::open_session`] (fresh) or
/// [`SketchClient::session`] (adopting a persisted id).
///
/// The handle borrows the connection, so one session is driven at a
/// time per connection — matching the daemon's one-frame-at-a-time
/// connection semantics.  Dropping the handle leaves the session open
/// on the daemon (sessions outlive connections); [`SessionHandle::close`]
/// consumes the handle and deregisters the session.
pub struct SessionHandle<'c> {
    client: &'c mut SketchClient,
    id: u64,
    /// Resume epoch from `SessionOpened` (1 for a fresh session, bumped
    /// on every snapshot restore; 0 when the handle was adopted via
    /// [`SketchClient::session`] or the connection is pre-v6).
    epoch: u64,
}

impl<'c> SessionHandle<'c> {
    /// The daemon-issued session id (persist it to re-adopt the session
    /// after a reconnect or daemon restart).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// The session's resume epoch (see [`Response::SessionOpened`]).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Upgrade to a crash-safe [`ResumableSession`]: ingests carry
    /// sequence numbers and are retained in a replay ring of at most
    /// `ring_cap` frames until acked.  Requires a proto-v6 connection
    /// and a session with no prior numbered ingest history (sequence
    /// numbering starts at 1 — adopting a session another resumable
    /// handle already drove fails loudly on the first ingest rather
    /// than letting the daemon's dedup silently swallow fresh frames).
    pub fn resumable(
        self,
        ring_cap: usize,
    ) -> Result<ResumableSession<'c>, Error> {
        if self.client.version < RESUME_MIN_VERSION {
            return Err(Error::UnsupportedVersion(format!(
                "resumable sessions require proto \
                 v{RESUME_MIN_VERSION}, connection negotiated v{}",
                self.client.version
            )));
        }
        Ok(ResumableSession {
            client: self.client,
            id: self.id,
            epoch: self.epoch,
            next_seq: 1,
            acked: 0,
            ring: VecDeque::new(),
            ring_cap: ring_cap.max(1),
            replays: 0,
        })
    }

    /// Escape hatch to the underlying connection for connection-wide
    /// ops (`stats`, `metrics`, `snapshot`, `shutdown_daemon`) while
    /// the session stays open.
    pub fn client(&mut self) -> &mut SketchClient {
        self.client
    }

    /// One monitored training step (see [`Request::Ingest`]).  The
    /// activations are encoded straight from the borrowed slice into
    /// the connection's reusable buffer — no clone, no per-step frame
    /// allocation.
    pub fn ingest(
        &mut self,
        loss: f32,
        acts: &[Mat],
        want_recon: bool,
    ) -> Result<IngestReply, Error> {
        self.client.ingest_raw(self.id, loss, acts, want_recon)
    }

    /// Push externally computed metrics (no daemon-side engine update).
    pub fn observe(&mut self, metrics: &StepMetrics) -> Result<u64, Error> {
        self.client.observe_raw(self.id, metrics)
    }

    pub fn diagnose(&mut self) -> Result<DiagnoseReply, Error> {
        self.client.diagnose_raw(self.id)
    }

    /// This session's row from the daemon's `Stats` reply.
    pub fn stats(&mut self) -> Result<SessionStats, Error> {
        let reply = self.client.stats()?;
        reply
            .sessions
            .into_iter()
            .find(|s| s.id == self.id)
            .ok_or_else(|| {
                Error::UnknownSession(format!(
                    "no session {} in daemon stats",
                    self.id
                ))
            })
    }

    /// Gradient-norm trajectory over the session's archived intervals.
    pub fn query_trajectory(
        &mut self,
    ) -> Result<Vec<TrajectoryPoint>, Error> {
        self.client.query_trajectory_raw(self.id)
    }

    /// Cross-step cosine similarity of one layer's archived sketches:
    /// (interval steps, dense symmetric matrix).
    pub fn query_similarity(
        &mut self,
        layer: usize,
    ) -> Result<(Vec<u64>, Mat), Error> {
        self.client.query_similarity_raw(self.id, layer)
    }

    /// Top-sigma / stable-rank drift of one layer across the archive.
    pub fn query_drift(
        &mut self,
        layer: usize,
    ) -> Result<Vec<DriftPoint>, Error> {
        self.client.query_drift_raw(self.id, layer)
    }

    /// Archive shape and occupancy for this session.
    pub fn archive_info(&mut self) -> Result<ArchiveInfo, Error> {
        self.client.archive_info_raw(self.id)
    }

    /// Deregister the session on the daemon, consuming the handle.
    pub fn close(self) -> Result<(), Error> {
        self.client.close_raw(self.id)
    }
}

/// Minimum protocol version carrying the resume fields (`Ingest.seq`,
/// `SessionOpened.epoch`, `IngestOk.acked_seq`).
pub const RESUME_MIN_VERSION: u16 = 6;

/// Crash-safe session handle: every ingest carries a client sequence
/// number and the encoded frame is retained in a bounded replay ring.
/// A transport failure mid-ingest — daemon killed, torn frame, socket
/// timeout — triggers a reconnect followed by an in-order replay of
/// the whole ring; the daemon re-acks frames at or below its restored
/// `acked_seq` without re-applying them, so the caller observes
/// exactly-once ingest semantics across daemon restarts.
///
/// An error *reply* (as opposed to a transport failure) — `Busy`
/// backpressure, an `Invalid` rejection — carries the daemon's
/// guarantee that the frame was not applied and its acked seq did not
/// move, so the handle rolls the frame back and reuses its sequence
/// number on the caller's retry.  Busy therefore keeps its documented
/// remedy under resumable sessions: wait or `Diagnose` to drain the
/// quota, then call [`ResumableSession::ingest`] again.
///
/// The ring deliberately retains the most recent `ring_cap` frames
/// even after the live daemon acks them: an in-memory ack is not
/// durable, and a crash rolls `acked_seq` back to the last snapshot.
/// Size the ring to cover the ingests between snapshots; if the daemon
/// restores from a snapshot older than the oldest retained frame,
/// replay surfaces the daemon's seq-gap error ([`Error::Invalid`])
/// instead of silently losing steps.
pub struct ResumableSession<'c> {
    client: &'c mut SketchClient,
    id: u64,
    epoch: u64,
    next_seq: u64,
    /// Highest `acked_seq` the daemon has confirmed to this handle.
    /// Frames above it are pending: sent (or about to be) but not yet
    /// known applied.  Stale-high after a daemon crash — recovery
    /// replays the full ring precisely because live acks are not
    /// durable.
    acked: u64,
    /// Most recent frames, oldest first: (seq, encoded ingest payload).
    ring: VecDeque<(u64, Vec<u8>)>,
    ring_cap: usize,
    replays: u64,
}

impl ResumableSession<'_> {
    /// The daemon-issued session id.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Resume epoch at open time (0 for adopted handles).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// How many reconnect-and-replay recoveries this session has done.
    pub fn replays(&self) -> u64 {
        self.replays
    }

    /// Frames currently retained for replay.
    pub fn retained(&self) -> usize {
        self.ring.len()
    }

    /// Escape hatch to the underlying connection (e.g. for `metrics`).
    pub fn client(&mut self) -> &mut SketchClient {
        self.client
    }

    /// One monitored training step with crash-safe delivery: assigns
    /// the next sequence number, retains the encoded frame until acked,
    /// and transparently reconnects + replays on transport failure.
    ///
    /// On an error *reply* (e.g. [`Error::Busy`] backpressure) the
    /// frame is rolled back — the daemon applied nothing — and the
    /// same sequence number is reused when the caller retries, so
    /// backpressure stays retryable instead of wedging the session on
    /// a sequence gap.
    pub fn ingest(
        &mut self,
        loss: f32,
        acts: &[Mat],
        want_recon: bool,
    ) -> Result<IngestReply, Error> {
        let seq = self.next_seq;
        self.next_seq += 1;
        let mut e = Enc::new();
        proto::enc_ingest_v(
            &mut e,
            self.id,
            seq,
            loss,
            want_recon,
            acts,
            self.client.version,
        );
        if self.ring.len() == self.ring_cap {
            self.ring.pop_front();
        }
        self.ring.push_back((seq, e.bytes().to_vec()));
        match self.drive() {
            Ok(reply) => Ok(reply),
            Err(e) => {
                // An error reply means the daemon rejected the frame
                // without applying it and without advancing its acked
                // seq (the handler's documented contract), so the
                // frame is popped and its seq slot reused on retry —
                // otherwise the next ingest would send seq+1 into a
                // daemon still expecting seq and wedge the session on
                // a permanent seq-gap error.  A transport failure
                // carries no such guarantee (the daemon may have
                // applied the frame and died before the ack), so the
                // frame stays retained for replay.
                if !transport_error(&e)
                    && self.ring.back().map(|f| f.0) == Some(seq)
                {
                    self.ring.pop_back();
                    self.next_seq = seq;
                }
                Err(e)
            }
        }
    }

    /// Send every retained frame the daemon has not acked (oldest
    /// first) — normally just the frame `ingest` pushed, plus any
    /// left pending by an earlier failed recovery — switching to
    /// reconnect + full-ring replay on transport failure.
    fn drive(&mut self) -> Result<IngestReply, Error> {
        let mut last = None;
        for i in 0..self.ring.len() {
            if self.ring[i].0 <= self.acked {
                continue;
            }
            let resp = {
                let payload = &self.ring[i].1;
                self.client.send_payload(proto::msg::INGEST, payload)
            };
            match resp.and_then(ingest_reply) {
                Ok(reply) => {
                    self.note_ack(&reply)?;
                    last = Some(reply);
                }
                Err(e) if transport_error(&e) => return self.recover(),
                Err(e) => return Err(e),
            }
        }
        last.ok_or_else(|| {
            Error::Unexpected("no unacked frames to send".into())
        })
    }

    /// Record a daemon ack.  An ack covering sequence numbers this
    /// handle never issued means the session already had numbered
    /// ingest history (adopted, not freshly opened): the daemon's
    /// dedup would silently swallow this handle's fresh frames, so
    /// fail loudly instead.
    fn note_ack(&mut self, reply: &IngestReply) -> Result<(), Error> {
        if reply.acked_seq >= self.next_seq {
            return Err(Error::Unexpected(format!(
                "daemon acked ingest seq {} but this handle issued \
                 only up to {}; resumable sessions must start on a \
                 freshly opened session",
                reply.acked_seq,
                self.next_seq - 1
            )));
        }
        self.acked = self.acked.max(reply.acked_seq);
        Ok(())
    }

    /// Diagnose through the underlying connection (not replayed —
    /// read-only, safe to simply retry at the caller's discretion).
    pub fn diagnose(&mut self) -> Result<DiagnoseReply, Error> {
        self.client.diagnose_raw(self.id)
    }

    /// Deregister the session on the daemon, consuming the handle.
    pub fn close(self) -> Result<(), Error> {
        self.client.close_raw(self.id)
    }

    /// Reconnect and replay every retained frame in order.  The daemon
    /// dedupes the already-applied prefix by seq; the reply to the last
    /// replayed frame carries the authoritative `acked_seq`.  Retries
    /// the whole cycle a few times so a daemon that dies again
    /// mid-replay still resolves once it is back.
    fn recover(&mut self) -> Result<IngestReply, Error> {
        let mut last_err = None;
        for _ in 0..RECOVER_ATTEMPTS {
            match self.try_replay() {
                Ok(reply) => {
                    self.replays += 1;
                    return Ok(reply);
                }
                Err(e) if transport_error(&e) => last_err = Some(e),
                Err(e) => return Err(e),
            }
        }
        Err(last_err.unwrap_or_else(|| {
            Error::Unexpected("replay ring empty during recovery".into())
        }))
    }

    fn try_replay(&mut self) -> Result<IngestReply, Error> {
        self.client.reconnect()?;
        if self.client.version < RESUME_MIN_VERSION {
            return Err(Error::UnsupportedVersion(format!(
                "daemon downgraded to proto v{} mid-session; cannot \
                 replay unacked ingests",
                self.client.version
            )));
        }
        let mut last = None;
        for i in 0..self.ring.len() {
            let resp = {
                let payload = &self.ring[i].1;
                self.client.send_payload(proto::msg::INGEST, payload)
            };
            let reply = ingest_reply(resp?)?;
            self.note_ack(&reply)?;
            last = Some(reply);
        }
        last.ok_or_else(|| {
            Error::Unexpected("replay ring empty during recovery".into())
        })
    }
}

const RECOVER_ATTEMPTS: usize = 3;

/// Errors that indicate the connection (not the request) failed, and a
/// reconnect + replay can recover: I/O failures, socket timeouts, and
/// torn/garbled frames from a daemon killed mid-write.
/// [`Error::Protocol`] covers only undecodable or out-of-range reply
/// frames; a well-formed reply answering the wrong request is
/// [`Error::Unexpected`] — a daemon logic error that a replay cycle
/// would only mask, so it is surfaced instead.
fn transport_error(e: &Error) -> bool {
    matches!(
        e,
        Error::Io(_) | Error::Timeout(_) | Error::Protocol(_)
    )
}

fn ingest_reply(resp: Response) -> Result<IngestReply, Error> {
    match resp {
        Response::IngestOk {
            batches,
            engine_bytes,
            recon_err,
            acked_seq,
        } => Ok(IngestReply {
            batches,
            engine_bytes,
            recon_err,
            acked_seq,
        }),
        other => Err(unexpected("IngestOk", &other)),
    }
}

fn unexpected(want: &str, got: &Response) -> Error {
    Error::Unexpected(format!("expected {want}, got {got:?}"))
}

// ---------------------------------------------------------------------
// Deterministic probe: the CI smoke and `sketchgrad connect --probe`.
// ---------------------------------------------------------------------

/// Fixed probe workload — both the remote daemon and the in-process
/// mirror replay exactly this, so every comparison can be bit-for-bit.
pub const PROBE_DIMS: [usize; 2] = [48, 24];
pub const PROBE_RANK: usize = 3;
pub const PROBE_BETA: f64 = 0.9;
pub const PROBE_SEED: u64 = 0x5EED;
pub const PROBE_STEPS: usize = 8;
pub const PROBE_NB: usize = 32;
pub const PROBE_TAIL: usize = 11;
pub const PROBE_WINDOW: usize = 2;
pub const PROBE_COLLAPSE: f64 = 0.25;

pub fn probe_spec() -> SessionSpec {
    SessionSpec {
        name: "probe".into(),
        layer_dims: PROBE_DIMS.to_vec(),
        rank: PROBE_RANK,
        beta: PROBE_BETA,
        seed: PROBE_SEED,
        window: PROBE_WINDOW,
        collapse_frac: PROBE_COLLAPSE,
    }
}

/// In-process replica of a probe session: the same engine + hub +
/// archive setup the daemon builds for [`probe_spec`].  The mirror's
/// ring parameters come from the daemon's `ArchiveInfo` reply, so the
/// probe verifies archives under whatever `[archive]` config the daemon
/// actually runs (the CI smoke uses a small capacity to force
/// eviction).
struct Mirror {
    engine: SketchEngine,
    hub: MonitorHub,
    id: SessionId,
    stream: ActStream,
    archive: SessionArchive,
}

impl Mirror {
    fn new(archive_capacity: usize, archive_stride: usize) -> Result<Mirror> {
        let spec = probe_spec();
        let engine = SketchConfig::builder()
            .layer_dims(&spec.layer_dims)
            .rank(spec.rank)
            .beta(spec.beta)
            .seed(spec.seed)
            .build_engine()?;
        let mut hub = MonitorHub::new();
        let id = hub.register(
            &spec.name,
            monitor_config(&spec),
            spec.layer_dims.len(),
        )?;
        let archive = SessionArchive::new(
            archive_capacity,
            archive_stride,
            engine.config().precision.bytes(),
        );
        Ok(Mirror {
            engine,
            hub,
            id,
            stream: ActStream::new(&PROBE_DIMS, false, PROBE_SEED),
            archive,
        })
    }

    /// Generate probe step `step`'s batch and apply it locally,
    /// recording the interval into the mirror archive like the daemon
    /// does.
    fn step(&mut self, step: usize) -> Result<(f32, Vec<Mat>)> {
        let n_b = if step == PROBE_STEPS - 1 {
            PROBE_TAIL
        } else {
            PROBE_NB
        };
        let acts = self.stream.next_batch(n_b);
        let loss = self.stream.loss_at(step, PROBE_STEPS);
        self.engine.ingest(&acts)?;
        self.archive.maybe_record(
            self.engine.batches_ingested(),
            loss,
            self.engine.layers(),
        );
        self.hub
            .observe(self.id, &step_metrics(loss, &self.engine.metrics()))?;
        Ok((loss, acts))
    }
}

/// Assert every archive query answer the daemon gives for the handle's
/// session is bit-for-bit identical to the mirror's locally computed
/// one.
fn verify_archive_queries(
    sess: &mut SessionHandle<'_>,
    mirror: &Mirror,
    what: &str,
) -> Result<()> {
    let remote_traj = sess.query_trajectory()?;
    let local_traj = mirror.archive.trajectory();
    ensure!(
        remote_traj == local_traj,
        "{what}: trajectory diverged: remote {remote_traj:?} local \
         {local_traj:?}"
    );
    for layer in 0..mirror.engine.n_layers() {
        let (remote_steps, remote_sim) = sess.query_similarity(layer)?;
        let (local_steps, local_sim) = mirror.archive.similarity(layer);
        ensure!(
            remote_steps == local_steps
                && remote_sim.rows == local_sim.rows
                && remote_sim.max_abs_diff(&local_sim) == 0.0,
            "{what}: similarity diverged at layer {layer}"
        );
        let remote_drift = sess.query_drift(layer)?;
        let local_drift = mirror.archive.drift(layer);
        ensure!(
            remote_drift == local_drift,
            "{what}: drift diverged at layer {layer}: remote \
             {remote_drift:?} local {local_drift:?}"
        );
    }
    let info = sess.archive_info()?;
    ensure!(
        info.intervals == mirror.archive.len() as u64
            && info.seen == mirror.archive.intervals_seen()
            && info.bytes == mirror.archive.bytes() as u64,
        "{what}: archive info diverged: remote {info:?} local \
         (intervals {}, seen {}, bytes {})",
        mirror.archive.len(),
        mirror.archive.intervals_seen(),
        mirror.archive.bytes()
    );
    Ok(())
}

/// `sketchgrad connect --probe`: drive a fresh monitored session through
/// the daemon while mirroring every step in-process, asserting that the
/// remote diagnosis, reconstruction errors and memory accounting are
/// bit-for-bit identical.  The session is left OPEN (and a snapshot is
/// forced) so a follow-up `--probe-resume` can verify a daemon restart.
/// Returns the session id.
pub fn run_probe(addr: &str) -> Result<u64> {
    let (mut client, info) = SketchClient::connect(addr)?;
    println!(
        "connected to {} (proto v{}, {}/{} sessions)",
        info.server, info.proto, info.sessions, info.max_sessions
    );
    let mut sess = client.open_session(&probe_spec())?;
    let session = sess.id();
    // Mirror the daemon's ring parameters so archive answers can be
    // compared bit-for-bit under any `[archive]` config.
    let ainfo = sess.archive_info()?;
    let mut mirror =
        Mirror::new(ainfo.capacity as usize, ainfo.stride as usize)?;
    for step in 0..PROBE_STEPS {
        let want_recon = step == PROBE_STEPS - 1;
        let (loss, acts) = mirror.step(step)?;
        let reply = sess.ingest(loss, &acts, want_recon)?;
        ensure!(
            reply.engine_bytes == mirror.engine.memory() as u64,
            "engine bytes diverged at step {step}: remote {} local {}",
            reply.engine_bytes,
            mirror.engine.memory()
        );
        if want_recon {
            let local = recon_errors(&mirror.engine, &acts)?;
            ensure!(
                reply.recon_err == local,
                "reconstruction errors diverged: remote {:?} local {:?}",
                reply.recon_err,
                local
            );
        }
    }
    let remote = sess.diagnose()?;
    let local = mirror.hub.diagnose(mirror.id)?;
    ensure!(
        remote.diagnosis == local,
        "diagnosis diverged: remote {:?} local {:?}",
        remote.diagnosis,
        local
    );
    ensure!(
        remote.steps_seen == PROBE_STEPS as u64,
        "steps_seen {} != {PROBE_STEPS}",
        remote.steps_seen
    );
    verify_archive_queries(&mut sess, &mirror, "probe")?;
    let row = sess.stats()?;
    ensure!(
        row.archive_intervals == mirror.archive.len() as u64
            && row.archive_bytes == mirror.archive.bytes() as u64,
        "stats archive counters diverged: {row:?}"
    );
    let stats = sess.client().stats()?;
    ensure!(
        stats.daemon.sessions >= 1 && stats.daemon.frames_served > 0,
        "implausible daemon stats: {:?}",
        stats.daemon
    );
    let (path, bytes, sessions) = sess.client().snapshot()?;
    println!(
        "probe: session={session} steps={} engine_bytes={} healthy={} \
         archive={}x{}B mirror=bit-for-bit-ok snapshot={path} ({bytes} B, \
         {sessions} sessions)",
        remote.steps_seen,
        remote.engine_bytes,
        remote.healthy,
        mirror.archive.len(),
        mirror.archive.bytes()
    );
    Ok(session)
}

/// `sketchgrad connect --probe-resume <id>`: after a daemon restart,
/// rebuild the probe mirror by replaying the probe workload in-process,
/// verify the resumed session diagnoses identically — and that every
/// archive query answers bit-identically to before the restart — then
/// ingest ONE extra batch on both sides: bit-for-bit equal
/// reconstruction errors prove the resumed engine state matches
/// (`max_state_diff == 0`).  Closes the session on success.
pub fn run_probe_resume(addr: &str, session: u64) -> Result<()> {
    let (mut client, info) = SketchClient::connect(addr)?;
    ensure!(
        info.sessions >= 1,
        "daemon resumed {} sessions, expected >= 1",
        info.sessions
    );
    let mut sess = client.session(session);
    let ainfo = sess.archive_info()?;
    let mut mirror =
        Mirror::new(ainfo.capacity as usize, ainfo.stride as usize)?;
    for step in 0..PROBE_STEPS {
        mirror.step(step)?;
    }
    // Archive continuity across the restart: the restored ring answers
    // every query exactly as the pre-restart daemon would have.
    verify_archive_queries(&mut sess, &mirror, "probe-resume")?;
    let remote = sess.diagnose()?;
    let local = mirror.hub.diagnose(mirror.id)?;
    ensure!(
        remote.diagnosis == local,
        "resumed diagnosis diverged: remote {:?} local {:?}",
        remote.diagnosis,
        local
    );
    ensure!(
        remote.steps_seen == PROBE_STEPS as u64,
        "resumed steps_seen {} != {PROBE_STEPS}",
        remote.steps_seen
    );
    ensure!(
        remote.engine_bytes == mirror.engine.memory() as u64,
        "resumed engine bytes {} != {}",
        remote.engine_bytes,
        mirror.engine.memory()
    );
    // The decisive warm-resume check: one more EMA step on both sides.
    let (loss, acts) = mirror.step(PROBE_STEPS)?;
    let reply = sess.ingest(loss, &acts, true)?;
    let local_err = recon_errors(&mirror.engine, &acts)?;
    ensure!(
        reply.recon_err == local_err,
        "post-resume reconstruction diverged: remote {:?} local {:?}",
        reply.recon_err,
        local_err
    );
    // And recording continued seamlessly on the restored ring.
    verify_archive_queries(&mut sess, &mirror, "post-resume")?;
    sess.close().context("closing probe session")?;
    println!(
        "probe-resume: session={session} steps={} resumed warm \
         (diagnosis + reconstruction bit-for-bit, state diff 0)",
        remote.steps_seen + 1
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Jittered backoff stays inside [backoff/2, backoff] and actually
    /// varies across draws (full jitter, not a fixed offset).
    #[test]
    fn jitter_bounds_and_spread() {
        let mut rng = Rng::new(0x7177E2);
        let backoff = Duration::from_millis(400);
        let mut distinct = std::collections::HashSet::new();
        for _ in 0..64 {
            let d = jittered(backoff, &mut rng);
            assert!(d >= backoff / 2, "{d:?} below half-backoff");
            assert!(d <= backoff, "{d:?} above backoff");
            distinct.insert(d.as_nanos());
        }
        assert!(
            distinct.len() > 32,
            "jitter draws barely vary: {} distinct of 64",
            distinct.len()
        );
    }

    /// The jitter seed is stable for the same (addr, thread) and the
    /// resulting schedule is reproducible.
    #[test]
    fn jitter_seed_deterministic_per_thread() {
        let s1 = jitter_seed("127.0.0.1:7700");
        let s2 = jitter_seed("127.0.0.1:7700");
        assert_eq!(s1, s2);
        let a: Vec<_> = {
            let mut rng = Rng::new(s1);
            (0..8)
                .map(|_| jittered(Duration::from_millis(100), &mut rng))
                .collect()
        };
        let b: Vec<_> = {
            let mut rng = Rng::new(s2);
            (0..8)
                .map(|_| jittered(Duration::from_millis(100), &mut rng))
                .collect()
        };
        assert_eq!(a, b);
    }

    /// A 1ms floor backoff must not panic (below(0) is asserted
    /// against) and still lands in-range.
    #[test]
    fn jitter_tiny_backoff() {
        let mut rng = Rng::new(1);
        for _ in 0..16 {
            let d = jittered(Duration::from_nanos(1), &mut rng);
            assert!(d <= Duration::from_nanos(1));
        }
    }
}
